"""Membership-churn latency: incremental lifecycle repair vs host rebuild.

A sensor joining or leaving the network used to mean rebuilding every
frozen plan layer from scratch on the host — ``build_topology`` (O(n^2)
adjacency + greedy distance-2 coloring), ``make_problem`` (reserved-slot
assignment, scatter plans, n Cholesky factorizations) and
``make_serving_plan`` (O(C*n) cell candidate lists) — plus the XLA
recompilations the fresh arrays trigger.  The lifecycle plan layer
(``repro.core.plans``) replaces all of that with O(1)-per-event device-side
repairs: ``streaming.add_sensor`` / ``remove_sensor`` patch the factors and
scatter plans, ``serving.plan_add_sensor`` / ``plan_remove_sensor`` patch
the query-plan candidate lists — at fixed shapes, zero recompiles.

This bench times one warm JOIN+LEAVE cycle of the incremental path against
the full host rebuild, per network size, and derives the amortized speedup
across churn RATES: if E membership events land between serving windows, a
rebuild-based server pays one rebuild per window while the incremental
server pays E repairs, so the advantage is t_rebuild / (E * t_event).

Acceptance (ISSUE 4): incremental repair >= 10x faster than the host
rebuild per event at n=1000, B=16.  ISSUE 5 adds the ``--per-event``
series: joins are now SYMMETRIC (adopters grow reciprocal anchor lanes)
and both join and remove gather only the O(degree) affected rows for
their factor repairs — so the separate join/remove latencies must beat
the PR-4 masked-full-refactorization numbers (>= 2x at n=1000) and stay
flat in n at constant degree.  Results go to ``BENCH_churn.json``;
``churn_fast`` is the trimmed variant ``benchmarks/run.py --fast`` runs so
the numbers land in the CI ``bench-json`` artifact.

Run:  PYTHONPATH=src python -m benchmarks.churn_bench
      PYTHONPATH=src python -m benchmarks.churn_bench --ns 100,1000 --batch 16
"""

from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    Kernel,
    add_sensor,
    build_topology,
    colored_sweep,
    init_state,
    make_batch_problem,
    make_serving_plan,
    plan_add_sensor,
    plan_remove_sensor,
    remove_sensor,
)

KERN = Kernel("rbf", gamma=1.0)


def _build(n, b, radius, lam, spares, seed=0):
    rng = np.random.default_rng(seed)
    pos = np.random.default_rng(seed).uniform(-1, 1, size=(n, 2)).astype(np.float32)
    topo = build_topology(pos, radius)
    d_max = int(np.asarray(topo.degrees).max()) + 4
    topo = build_topology(pos, radius, d_max=d_max, n_max=n + spares)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), lam))
    state = colored_sweep(prob, init_state(prob), n_sweeps=2)
    return pos, topo, ys, prob, state


def _time_incremental(prob, state, plan, b, lam, reps):
    """One warm JOIN + LEAVE cycle (problem + query-plan repairs), seconds."""
    x = np.asarray([0.11, -0.07], np.float32)
    ys_new = np.zeros((b,), np.float32)

    def cycle(prob, state, plan):
        prob, state, _rec = add_sensor(prob, state, x, ys_new, lam=lam)
        slot, _ = _rec.slot, _rec.joined
        plan, _ = plan_add_sensor(plan, x, slot)
        prob, state, _ = remove_sensor(prob, state, slot)
        plan = plan_remove_sensor(plan, slot)
        return prob, state, plan

    prob, state, plan = cycle(prob, state, plan)  # compile
    jax.block_until_ready((prob.chol, plan.cells))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        prob, state, plan = cycle(prob, state, plan)
        jax.block_until_ready((prob.chol, plan.cells))
        best = min(best, time.perf_counter() - t0)
    return best / 2.0  # two membership events per cycle


def _time_per_event(prob, state, b, lam, reps):
    """Separate warm JOIN and REMOVE latencies (seconds each).

    The ISSUE-5 acceptance series: both events gather only the O(degree)
    affected rows (adopter/neighbor lane repairs + one batched masked
    refactorization of those rows), so at constant degree the curve must
    be flat in n — the PR-4 path refactorized all n rows per removal.
    """
    x = np.asarray([0.11, -0.07], np.float32)
    ys_new = np.zeros((b,), np.float32)
    # warm both programs
    p2, s2, _rec = add_sensor(prob, state, x, ys_new, lam=lam)
    slot, _ = _rec.slot, _rec.joined
    jax.block_until_ready(p2.chol)
    p3, s3, _ = remove_sensor(p2, s2, slot)
    jax.block_until_ready(p3.chol)
    t_join = t_rem = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        p2, s2, _rec = add_sensor(prob, state, x, ys_new, lam=lam)
        slot, _ = _rec.slot, _rec.joined
        jax.block_until_ready(p2.chol)
        t_join = min(t_join, time.perf_counter() - t0)
        t0 = time.perf_counter()
        p3, s3, _ = remove_sensor(p2, s2, slot)
        jax.block_until_ready(p3.chol)
        t_rem = min(t_rem, time.perf_counter() - t0)
    return t_join, t_rem


def _time_rebuild(pos, ys, radius, lam, spares, k, reps):
    """Full host-side rebuild after a membership change, seconds."""
    n = pos.shape[0]
    pos2 = np.concatenate([pos, [[0.11, -0.07]]]).astype(np.float32)
    ys2 = np.concatenate([ys, ys[:, :1]], axis=1)
    best = float("inf")
    for _ in range(reps + 1):
        t0 = time.perf_counter()
        topo = build_topology(pos2, radius)
        d_max = int(np.asarray(topo.degrees).max()) + 4
        topo = build_topology(pos2, radius, d_max=d_max, n_max=n + 1 + spares)
        prob = make_batch_problem(topo, KERN, ys2, jnp.full((n + 1,), lam))
        plan = make_serving_plan(prob, k=k)
        jax.block_until_ready((prob.chol, plan.cells))
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(
    ns, batch, rates, radius=0.3, lam=0.1, spares=8, k=3, reps=3,
    per_event=True,
):
    entries = []
    print(f"{'n':>6s} {'D':>4s} {'ms/event inc':>13s} {'ms join':>8s} "
          f"{'ms remove':>10s} {'ms rebuild':>11s} {'speedup':>8s}")
    for n in ns:
        r = radius * math.sqrt(100.0 / n)
        pos, topo, ys, prob, state = _build(n, batch, r, lam, spares)
        plan = make_serving_plan(prob, k=k, spare=4, slack=2)
        t_inc = _time_incremental(prob, state, plan, batch, lam, reps)
        t_reb = _time_rebuild(pos, ys, r, lam, spares, k, reps)
        row = {
            "n": n, "batch": batch, "d_max": prob.topology.d_max,
            "s_per_event_incremental": t_inc,
            "s_per_rebuild": t_reb,
            "speedup_per_event": t_reb / t_inc,
        }
        t_join = t_rem = None
        if per_event:
            t_join, t_rem = _time_per_event(prob, state, batch, lam, reps)
            row["s_per_join"] = t_join
            row["s_per_remove"] = t_rem
        # Amortized advantage when E events share one serving window: a
        # rebuild server pays one rebuild, the incremental server E repairs.
        for e in rates:
            row[f"speedup_rate_{e}"] = t_reb / (e * t_inc)
        entries.append(row)
        print(
            f"{n:6d} {row['d_max']:4d} {t_inc*1e3:13.2f} "
            f"{(t_join or 0)*1e3:8.2f} {(t_rem or 0)*1e3:10.2f} "
            f"{t_reb*1e3:11.1f} {row['speedup_per_event']:8.1f}"
        )
    return entries


def churn_fast(rows):
    """Trimmed sweep for ``benchmarks/run.py --fast`` (CI bench-json rows)."""
    entries = sweep(ns=(100, 300), batch=4, rates=(1, 8), reps=2)
    for e in entries:
        rows.append(
            (
                f"churn.n{e['n']}.incremental",
                e["s_per_event_incremental"] * 1e6,
                f"speedup_vs_rebuild={e['speedup_per_event']:.1f}x",
            )
        )
        rows.append(
            (
                f"churn.n{e['n']}.rebuild",
                e["s_per_rebuild"] * 1e6,
                f"amortized_at_rate8={e['speedup_rate_8']:.1f}x",
            )
        )
        # the O(degree) per-event series (ISSUE-5): flat-in-n at constant
        # degree, tracked per commit via the CI bench-json artifact
        rows.append(
            (f"churn.n{e['n']}.join", e["s_per_join"] * 1e6, "per-event")
        )
        rows.append(
            (f"churn.n{e['n']}.remove", e["s_per_remove"] * 1e6, "per-event")
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", default="100,200,500,1000")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rates", default="1,4,16",
                    help="membership events per serving window (amortization)")
    ap.add_argument("--radius", type=float, default=0.3)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--spares", type=int, default=8)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--no-per-event", dest="per_event", action="store_false",
                    default=True,
                    help="skip the separate join/remove timings (the "
                         "O(degree) per-event series is on by default)")
    ap.add_argument("--out", default="BENCH_churn.json")
    args = ap.parse_args()
    ns = [int(s) for s in args.ns.split(",")]
    rates = [int(s) for s in args.rates.split(",")]
    entries = sweep(
        ns, args.batch, rates,
        radius=args.radius, lam=args.lam, spares=args.spares,
        k=args.k, reps=args.reps, per_event=args.per_event,
    )
    out = {"name": "churn", "batch": args.batch, "rates": rates,
           "entries": entries}
    ref = next((e for e in entries if e["n"] == 1000), entries[-1])
    out["speedup_at_n1000_per_event"] = ref["speedup_per_event"]
    if args.per_event:
        out[f"s_per_join_at_n{ref['n']}"] = ref.get("s_per_join")
        out[f"s_per_remove_at_n{ref['n']}"] = ref.get("s_per_remove")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"speedup_at_n{ref['n']}_per_event: {ref['speedup_per_event']:.1f}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
