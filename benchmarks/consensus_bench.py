"""SOP-gossip vs all-reduce data parallelism (the paper's technique applied
to NN training, DESIGN.md Sec. 3) — host-simulated replicas on CPU.

Reports final loss and replica disagreement for:
  * allreduce          (centralized special case, Lemma 3.1)
  * sop_gossip ring    (relaxed neighbor topology, 2 pairings)
  * local only         (no coupling — the 'local-only' ablation analogue)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus
from repro.data import synthetic_lm_stream
from repro.models import ModelConfig, init_params, loss_fn
from repro.optim import apply_updates, constant, sgd


def _tiny_cfg(vocab=128):
    return ModelConfig(
        name="bench", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=vocab,
    )


def _run(mode: str, n_rep=4, steps=30, seed=0):
    cfg = _tiny_cfg()
    opt = sgd(constant(0.1), momentum=0.0)
    base = init_params(cfg, jax.random.PRNGKey(seed))
    stacked = jax.tree.map(lambda a: jnp.stack([a] * n_rep), base)
    opt_states = [opt.init(base) for _ in range(n_rep)]
    streams = [
        synthetic_lm_stream(cfg.vocab_size, 32, 4, seed=seed, host_id=i, n_hosts=n_rep)
        for i in range(n_rep)
    ]
    sched = consensus.ring_schedule(n_rep)

    @jax.jit
    def local_step(params, opt_state, batch):
        (l, _), g = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        up, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, up), opt_state, l

    losses = []
    for step in range(steps):
        new_leaves, ls = [], []
        for i in range(n_rep):
            p_i = jax.tree.map(lambda a: a[i], stacked)
            b = {k: jnp.asarray(v) for k, v in streams[i].batch_at(step).items()}
            p_i, opt_states[i], l = local_step(p_i, opt_states[i], b)
            new_leaves.append(p_i)
            ls.append(float(l))
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_leaves)
        if mode == "allreduce":
            stacked = jax.tree.map(lambda a: jnp.mean(a, 0, keepdims=True).repeat(n_rep, 0), stacked)
        elif mode == "sop_gossip":
            stacked = consensus.sim_pairwise_project(stacked, sched[step % 2])
        losses.append(np.mean(ls))
    dis = float(consensus.sim_consensus_sq_distance(stacked))
    return losses[-1], dis


def gossip_vs_allreduce(rows):
    for mode in ("allreduce", "sop_gossip", "local"):
        t0 = time.time()
        final_loss, disagreement = _run(mode)
        us = (time.time() - t0) * 1e6
        rows.append(
            (f"consensus.{mode}.final_loss", us, f"{final_loss:.4f}")
        )
        rows.append(
            (f"consensus.{mode}.disagreement_sq", us, f"{disagreement:.3e}")
        )
