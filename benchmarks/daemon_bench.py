"""Serving daemon under sustained mixed traffic: latency SLO through a
fault episode, zero XLA compiles after warmup.

The ISSUE-8 acceptance run: one ``launch.daemon.Daemon`` serves a steady
mix of coalesced bucketed queries, streaming arrival waves, and churn
events while supervised training ticks run between pumps — then a fault
episode (10% link drops injected into every training tick) hits mid-run
and the daemon must keep its promises:

  * ZERO failed queries — every admitted query returns finite values
    from a published snapshot, episode included (queries read the double
    buffer; a struggling trainer can delay them, never corrupt them);
  * p99 latency within 3x the fault-free p99 — the watchdog's
    retry/rollback work during the episode bounds the serving stall;
  * ZERO XLA compiles after warmup — fault rates are traced operands and
    request/arrival sizes ride the power-of-two buckets, so the whole
    mixed trace (episode and recovery included) reuses the warm programs
    (counted via the jit caches, the PR-3/PR-7 witness).

Latency is measured submit -> answer with a training tick between: a
query that arrives mid-tick waits for the next pump, so episode-time
watchdog retries genuinely stretch the tail — the SLO is a real claim
about degraded-mode serving, not a no-op.

Run:  PYTHONPATH=src python -m benchmarks.daemon_bench
      PYTHONPATH=src python -m benchmarks.daemon_bench --n 200 --ticks 40
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax.numpy as jnp

from repro.core import (
    Kernel,
    build_topology,
    faults,
    init_state,
    make_batch_problem,
    make_serving_plan,
    monitor,
    serving,
    streaming,
    uniform_sensors,
)
from repro.launch import daemon as daemon_mod
from repro.launch.daemon import Daemon, DaemonConfig

EPISODE_DROP = 0.1
SLO_P99_RATIO = 3.0


def _build(n, b, radius, gamma, lam, spares, seed=0):
    pos = uniform_sensors(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    freq = rng.uniform(0.5, 2.0, size=(b, 1)).astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi, size=(b, 1)).astype(np.float32)
    ys = (
        np.sin(np.pi * freq * pos[None, :, 0] + phase)
        + 0.1 * rng.normal(size=(b, n))
    ).astype(np.float32)
    topo = build_topology(pos, radius)
    d_max = int(np.asarray(topo.degrees).max()) + 6
    topo = build_topology(pos, radius, d_max=d_max, n_max=n + spares)
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=gamma), ys, jnp.full((n,), lam)
    )
    return pos, prob, init_state(prob), rng


def _cache_sizes():
    """Every program the daemon's steady state dispatches: the bucketed
    serving pair, the supervised faulty trainer, absorbs, churn repairs,
    and the per-publish effective-coefficient read."""
    fns = (
        serving.knn_select_valid,
        serving._eval_selected,
        serving.plan_add_sensor,
        serving.plan_remove_sensor,
        faults._faulty_colored,
        monitor._round_metrics,
        streaming._absorb_many_drop_copy,
        streaming._add_sensor_copy,
        streaming._remove_sensor_copy,
        daemon_mod._ecoef_jit,
    )
    return [f._cache_size() for f in fns]


def _run_phase(
    d, rng, pos, n, b, *, ticks, queries_per_tick, max_q, arrivals_per_tick,
    churn_every=0, label="",
):
    """Mixed traffic: submit -> train tick -> pump, per round.

    Returns (latencies_s, failed, degraded_ticks, rollbacks)."""
    lat, failed, degraded_ticks, rollbacks = [], 0, 0, 0
    for t in range(ticks):
        tickets = []
        for _ in range(queries_per_tick):
            q = int(rng.integers(1, max_q + 1))
            xq = rng.uniform(-0.9, 0.9, size=(q, 1)).astype(np.float32)
            tickets.append(d.submit(xq))
        a = int(rng.integers(1, arrivals_per_tick + 1))
        ss = rng.integers(0, n, size=a)
        d.offer_arrivals(
            rng.integers(0, b, size=a), ss,
            (pos[ss] + 0.05 * rng.normal(size=(a, 1))).astype(np.float32),
            rng.normal(size=a).astype(np.float32),
        )
        if churn_every and t % churn_every == 0:
            # alternate joins and (random-slot) leaves; a leave that picks
            # an already-dead slot is a counted no-op, like production
            if (t // churn_every) % 2 == 0:
                x = rng.uniform(-0.9, 0.9, size=(1,)).astype(np.float32)
                d.offer_join(
                    x, rng.normal(size=b).astype(np.float32), lam=0.1
                )
            else:
                d.offer_leave(int(rng.integers(0, n)))
        rcpt = d.tick()
        degraded_ticks += int(rcpt.degraded)
        rollbacks += int(rcpt.watchdog.rolled_back)
        answers = {a_.id: a_ for a_ in d.pump()}
        for tk in tickets:
            if not tk.admitted:
                continue  # shed at the door is admission, not failure
            ans = answers.get(tk.id)
            if ans is None or not np.isfinite(ans.values).all():
                failed += 1
            else:
                lat.append(ans.latency_s)
    return lat, failed, degraded_ticks, rollbacks


def run_daemon(
    n=60, b=4, *, radius=0.45, gamma=4.0, lam=0.05, ticks_clean=12,
    ticks_fault=8, queries_per_tick=4, max_q=60, arrivals_per_tick=12,
    churn_every=3, sweeps_per_tick=5, seed=0,
):
    spares = 2 + ticks_clean // max(churn_every, 1)
    pos, prob, state, rng = _build(n, b, radius, gamma, lam, spares, seed)
    plan = make_serving_plan(prob, k=3, spare=spares, slack=spares)
    cfg = DaemonConfig(
        k=3, max_batch_rows=64, arrival_rows=16,
        sweeps_per_tick=sweeps_per_tick,
    )
    d = Daemon(prob, state, config=cfg, plan=plan)

    # -- warmup: touch every program the measured trace can dispatch ------
    for q in (8, 16, 32, 64):  # every query bucket under max_batch_rows
        d.submit(rng.uniform(-0.9, 0.9, size=(q, 1)).astype(np.float32))
        d.pump()
    ss = rng.integers(0, n, size=17)  # full 16-window + partial bucket 8
    d.offer_arrivals(
        rng.integers(0, b, size=17), ss,
        (pos[ss] + 0.05 * rng.normal(size=(17, 1))).astype(np.float32),
        rng.normal(size=17).astype(np.float32),
    )
    d.tick()
    d.offer_arrivals(  # partial bucket 16 (9 rows pad up, not coalesce)
        np.zeros(9, np.int32), rng.integers(0, n, size=9),
        pos[rng.integers(0, n, size=9)].astype(np.float32),
        rng.normal(size=9).astype(np.float32),
    )
    d.tick()
    d.offer_join(  # join-only and join+leave tick program sets
        np.array([0.1], np.float32), np.zeros(b, np.float32), lam=0.1
    )
    d.tick()
    d.offer_leave(int(rng.integers(0, n)))
    d.tick()
    streaming.rebuild_chol(d.snapshot.problem)  # watchdog escalation path
    d.set_fault_model(faults.make_fault_model(EPISODE_DROP))
    d.tick()  # drill: same program, rates are traced
    d.set_fault_model(faults.make_fault_model(0.0))
    d.tick()
    base = _cache_sizes()

    # -- clean phase ------------------------------------------------------
    mix = dict(
        queries_per_tick=queries_per_tick, max_q=max_q,
        arrivals_per_tick=arrivals_per_tick, churn_every=churn_every,
    )
    lat_clean, failed_c, _, _ = _run_phase(
        d, rng, pos, n, b, ticks=ticks_clean, **mix
    )

    # -- fault episode: 10% drops injected into every training tick -------
    d.set_fault_model(faults.make_fault_model(EPISODE_DROP))
    lat_fault, failed_f, degraded_ticks, rollbacks = _run_phase(
        d, rng, pos, n, b, ticks=ticks_fault, **mix
    )
    d.set_fault_model(faults.make_fault_model(0.0))
    lat_rec, failed_r, _, _ = _run_phase(d, rng, pos, n, b, ticks=2, **mix)

    compiles = sum(a - b_ for a, b_ in zip(_cache_sizes(), base))
    failed = failed_c + failed_f + failed_r

    def pctl(xs, p):
        return float(np.percentile(np.asarray(xs) * 1e3, p)) if xs else 0.0

    p50_c, p99_c = pctl(lat_clean, 50), pctl(lat_clean, 99)
    p50_f, p99_f = pctl(lat_fault, 50), pctl(lat_fault, 99)
    slo_pass = (
        failed == 0
        and compiles == 0
        and p99_f <= SLO_P99_RATIO * max(p99_c, 1e-9)
    )
    return {
        "name": "daemon",
        "n": n, "batch": b, "ticks_clean": ticks_clean,
        "ticks_fault": ticks_fault, "episode_drop": EPISODE_DROP,
        "queries_served": int(d.served), "queries_shed": int(d.shed),
        "failed_queries": failed,
        "latency_ms": {
            "clean_p50": p50_c, "clean_p99": p99_c,
            "fault_p50": p50_f, "fault_p99": p99_f,
        },
        "p99_ratio_fault_vs_clean": p99_f / max(p99_c, 1e-9),
        "slo_p99_ratio_budget": SLO_P99_RATIO,
        "degraded_ticks": degraded_ticks,
        "rollbacks": rollbacks,
        "final_version": int(d.snapshot.version),
        "compiles_after_warmup": compiles,
        "slo_pass": bool(slo_pass),
    }


def daemon_fast(rows):
    """Trimmed run for ``benchmarks/run.py --fast`` (CI bench-json rows)."""
    r = run_daemon(n=40, b=2, ticks_clean=6, ticks_fault=4,
                   queries_per_tick=3, churn_every=3)
    lm = r["latency_ms"]
    rows.append((
        f"daemon.n{r['n']}.query",
        lm["clean_p50"] * 1e3,  # us, like every other us_per_call row
        f"p99_clean={lm['clean_p99']:.2f}ms;"
        f"p99_fault={lm['fault_p99']:.2f}ms;"
        f"ratio={r['p99_ratio_fault_vs_clean']:.2f}x;"
        f"failed={r['failed_queries']};"
        f"slo_pass={r['slo_pass']}",
    ))
    rows.append((
        f"daemon.n{r['n']}.compiles",
        float(r["compiles_after_warmup"]),
        "xla_compiles_after_warmup_across_mixed_traffic",
    ))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=12,
                    help="clean-phase ticks (fault episode runs 2/3 of it)")
    ap.add_argument("--queries-per-tick", type=int, default=4)
    ap.add_argument("--max-q", type=int, default=60)
    ap.add_argument("--arrivals-per-tick", type=int, default=12)
    ap.add_argument("--churn-every", type=int, default=3)
    ap.add_argument("--out", default="BENCH_daemon.json")
    args = ap.parse_args()
    t0 = time.time()
    r = run_daemon(
        n=args.n, b=args.batch, ticks_clean=args.ticks,
        ticks_fault=max(2, 2 * args.ticks // 3),
        queries_per_tick=args.queries_per_tick, max_q=args.max_q,
        arrivals_per_tick=args.arrivals_per_tick,
        churn_every=args.churn_every,
    )
    r["wall_s"] = time.time() - t0
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    lm = r["latency_ms"]
    print(f"served={r['queries_served']} shed={r['queries_shed']} "
          f"failed={r['failed_queries']}")
    print(f"latency ms: clean p50={lm['clean_p50']:.2f} "
          f"p99={lm['clean_p99']:.2f} | fault p50={lm['fault_p50']:.2f} "
          f"p99={lm['fault_p99']:.2f} "
          f"(ratio {r['p99_ratio_fault_vs_clean']:.2f}x, budget "
          f"{SLO_P99_RATIO:.0f}x)")
    print(f"degraded_ticks={r['degraded_ticks']} rollbacks={r['rollbacks']} "
          f"compiles_after_warmup={r['compiles_after_warmup']} (want 0)")
    print(f"SLO {'PASS' if r['slo_pass'] else 'FAIL'}; wrote {args.out}")


if __name__ == "__main__":
    main()
