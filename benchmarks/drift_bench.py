"""Drift tracking: forgetting-factor streaming on a time-varying field.

The paper trains on a STATIC field: every absorbed measurement keeps unit
weight forever, so on a drifting field the consensus messages average the
field's whole history and the estimate converges to the wrong (stale)
surface.  ISSUE 6 adds per-field exponential forgetting (``beta``): each
new arrival at a sensor ages that sensor's occupied stream lanes one
``sqrt(beta)`` step (anchor weights, Gram, cached Cholesky, messages), so
the effective window is ~1/(1-beta) arrivals and the sweeps track the
field instead of its history.

This bench runs the SAME drifting-field trace over a batch of fields that
differ only in ``beta`` (one mixed-beta problem — one compiled program),
with dense per-round measurement waves (``absorb_wave``: one arrival per
sensor per round, one dispatch), periodic join/leave churn with
``repair_lambda=True``, and per-round kNN-fused RMSE against the CURRENT
truth.  It reports steady-state tracking error per beta across a grid of
drift rates x refresh cadences (sweeps between measurement rounds — the
"rebuild cadence" a non-forgetting deployment would have to re-seed at),
plus the number of XLA program compiles after warmup (must be ZERO: the
whole drift+churn trace runs at fixed shapes).

Acceptance (ISSUE 6): at n=1000, B=16, a tuned ``beta < 1`` tracks the
drifting field with >= 5x lower steady-state RMSE than ``beta = 1.0``.

Run:  PYTHONPATH=src python -m benchmarks.drift_bench
      PYTHONPATH=src python -m benchmarks.drift_bench --n 100 --batch 4
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    Kernel,
    absorb_wave,
    add_sensor,
    build_topology,
    colored_sweep,
    fusion,
    init_state,
    make_batch_problem,
    remove_sensor,
    streaming,
)

BETAS = (1.0, 0.7, 0.5, 0.3)


def _truth(pos, t, v):
    """Drifting field: a unit-scale wave translating v per round along x0."""
    return np.sin(np.pi * (pos[..., 0] - v * t)).astype(np.float32)


def _build(n, b, dim, radius, gamma, lam, w_extra, spares, noise, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(-1, 1, size=(n, dim)).astype(np.float32)
    topo = build_topology(pos, radius)
    d_max = int(np.asarray(topo.degrees).max()) + w_extra
    topo = build_topology(pos, radius, d_max=d_max, n_max=n + spares)
    betas = np.resize(np.asarray(BETAS, np.float32), b)
    ys = _truth(pos, 0, 0.0)[None] + noise * rng.normal(size=(b, n)).astype(
        np.float32
    )
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=gamma), ys, jnp.full((n,), lam), beta=betas
    )
    state = colored_sweep(prob, init_state(prob), n_sweeps=2)
    return pos, prob, state, betas


@jax.jit
def _fused_rmse(problem, state, xq, truth):
    """kNN-fused (k=3) estimate at the sensor sites vs current truth: (B,)."""
    preds = fusion.evaluate_sensors(problem, state, xq)
    fused = fusion.knn_fusion(
        preds, problem.topology.positions, xq, k=3, alive=problem.alive[:-1]
    )
    return jnp.sqrt(jnp.mean((fused - truth[None, :]) ** 2, axis=-1))


def _cache_sizes():
    fns = (
        streaming._absorb_wave_evict_donate,
        streaming._add_sensor_copy,
        streaming._remove_sensor_copy,
        colored_sweep,
        _fused_rmse,
    )
    return [f._cache_size() for f in fns]


def run_trace(
    pos, prob, state, betas, *, v, sweeps, rounds, noise, lam,
    churn_every=5, ss_rounds=10, seed=1,
):
    """One drifting trace; returns (ss_rmse per beta, compiles, s/round)."""
    rng = np.random.default_rng(seed)
    n, b = pos.shape[0], prob.batch_size
    n_cap, dim = prob.n, pos.shape[1]
    jitter = 0.2 * noise + 0.01
    x_join = np.full((dim,), 0.11, np.float32)

    def one_round(prob, state, t):
        xs = np.zeros((b, n_cap, dim), np.float32)
        xs[:, :n] = pos[None] + rng.normal(
            scale=jitter, size=(b, n, dim)
        ).astype(np.float32)
        ys = _truth(xs[..., :n, :], t, v) + noise * rng.normal(
            size=(b, n)
        ).astype(np.float32)
        ysf = np.zeros((b, n_cap), np.float32)
        ysf[:, :n] = ys
        amask = np.zeros((b, n_cap), bool)
        amask[:, :n] = True
        prob, state, _ = absorb_wave(
            prob, state, xs, ysf, mask=amask, donate=True, on_full="evict"
        )
        if churn_every and t % churn_every == 0:
            yj = np.full((b,), float(_truth(x_join[None], t, v)[0]), np.float32)
            prob, state, rcpt = add_sensor(
                prob, state, x_join, yj, lam=lam, repair_lambda=True
            )
            prob, state, _ = remove_sensor(
                prob, state, rcpt.slot, repair_lambda=True
            )
        state = colored_sweep(prob, state, n_sweeps=sweeps)
        return prob, state

    # warm every program in the trace before counting compiles
    prob, state = one_round(prob, state, 0)
    rmse = np.asarray(_fused_rmse(prob, state, pos, _truth(pos, 0, v)))
    jax.block_until_ready(state.z)
    base = _cache_sizes()

    hist = []
    t0 = time.perf_counter()
    for t in range(1, rounds + 1):
        prob, state = one_round(prob, state, t)
        hist.append(np.asarray(_fused_rmse(prob, state, pos, _truth(pos, t, v))))
    jax.block_until_ready(state.z)
    s_per_round = (time.perf_counter() - t0) / rounds
    compiles = sum(a - b2 for a, b2 in zip(_cache_sizes(), base))

    ss = np.mean(np.stack(hist[-ss_rounds:]), axis=0)  # (B,)
    per_beta = {
        round(float(bv), 6): float(np.mean(ss[betas == bv]))
        for bv in np.unique(betas)
    }
    return per_beta, compiles, s_per_round


def sweep_grid(
    n, batch, vs, cadences, dim, radius, gamma, lam, w_extra, spares,
    noise, rounds, ss_rounds, churn_every,
):
    entries = []
    print(f"{'v':>6s} {'sweeps':>7s} " +
          " ".join(f"b={b:<4g}" for b in BETAS) + f" {'ratio':>7s} "
          f"{'compiles':>8s} {'s/round':>8s}")
    for sw in cadences:
        for v in vs:
            pos, prob, state, betas = _build(
                n, batch, dim, radius, gamma, lam, w_extra, spares, noise
            )
            per_beta, compiles, spr = run_trace(
                pos, prob, state, betas, v=v, sweeps=sw, rounds=rounds,
                noise=noise, lam=lam, churn_every=churn_every,
                ss_rounds=ss_rounds,
            )
            best_rmse = min(
                r for bv, r in per_beta.items() if bv < 1.0
            )
            ratio = per_beta[1.0] / best_rmse
            entries.append({
                "n": n, "batch": batch, "v": v, "sweeps_per_round": sw,
                "rounds": rounds, "ss_rmse_per_beta": per_beta,
                "rmse_ratio_beta1_vs_best": ratio,
                "compiles_after_warmup": compiles,
                "s_per_round": spr,
            })
            print(f"{v:6.3f} {sw:7d} " +
                  " ".join(f"{per_beta[b]:.3f}" for b in BETAS) +
                  f" {ratio:6.1f}x {compiles:8d} {spr:8.2f}")
    return entries


def drift_fast(rows):
    """Trimmed trace for ``benchmarks/run.py --fast`` (CI bench-json rows)."""
    entries = sweep_grid(
        n=100, batch=4, vs=(0.05,), cadences=(10,), dim=1, radius=0.09,
        gamma=10.0, lam=0.01, w_extra=12, spares=4, noise=0.01,
        rounds=40, ss_rounds=10, churn_every=5,
    )
    for e in entries:
        rows.append((
            f"drift.n{e['n']}.v{e['v']}.track",
            e["s_per_round"] * 1e6,
            f"rmse_ratio_beta1_vs_best={e['rmse_ratio_beta1_vs_best']:.1f}x",
        ))
        rows.append((
            f"drift.n{e['n']}.v{e['v']}.compiles",
            float(e["compiles_after_warmup"]),
            "xla_compiles_after_warmup",
        ))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vs", default="0.02,0.05,0.1",
                    help="drift rates (field translation per round)")
    ap.add_argument("--cadences", default="4,10",
                    help="refresh sweeps per measurement round")
    ap.add_argument("--dim", type=int, default=1)
    ap.add_argument("--radius", type=float, default=-1.0,
                    help="coupling radius (< 0: scale 0.09 * 100/n for 1D)")
    ap.add_argument("--gamma", type=float, default=10.0)
    ap.add_argument("--lam", type=float, default=0.01)
    ap.add_argument("--w-extra", type=int, default=12,
                    help="reserved stream lanes per sensor (window size)")
    ap.add_argument("--spares", type=int, default=4)
    ap.add_argument("--noise", type=float, default=0.01)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--ss-rounds", type=int, default=10)
    ap.add_argument("--churn-every", type=int, default=5)
    ap.add_argument("--out", default="BENCH_drift.json")
    args = ap.parse_args()
    radius = args.radius
    if radius < 0:
        radius = 0.09 * (100.0 / args.n) ** (1.0 / args.dim)
    vs = [float(s) for s in args.vs.split(",")]
    cadences = [int(s) for s in args.cadences.split(",")]
    entries = sweep_grid(
        args.n, args.batch, vs, cadences, args.dim, radius, args.gamma,
        args.lam, args.w_extra, args.spares, args.noise, args.rounds,
        args.ss_rounds, args.churn_every,
    )
    ref = max(
        (e for e in entries if e["v"] == 0.05),
        key=lambda e: e["sweeps_per_round"],
        default=entries[-1],
    )
    out = {
        "name": "drift", "n": args.n, "batch": args.batch,
        "betas": list(BETAS), "entries": entries,
        "rmse_ratio_at_reference": ref["rmse_ratio_beta1_vs_best"],
        "compiles_after_warmup_total": sum(
            e["compiles_after_warmup"] for e in entries
        ),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"rmse_ratio_at_reference: {ref['rmse_ratio_beta1_vs_best']:.1f}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
