"""Unreliable links: RMSE + sweeps-to-converge vs message drop rate.

The paper's convergence theory (Sec. 3) assumes every inter-sensor message
arrives.  ISSUE 7 adds the ``core.faults`` process (seeded i.i.d. drops,
Gilbert–Elliott bursts, crash/restart schedules) with hold-last-value
semantics in every sweep engine, and the ``core.monitor`` watchdog that
supervises faulty training (retry with fresh draws -> refactorize ->
bitwise rollback).  This bench trains the SAME static multi-field problem
at a grid of drop rates under the watchdog and reports, per rate:

  * kNN-fused (k=3) RMSE against the noiseless truth at the sensor sites;
  * sweeps-to-converge (total supervised sweeps the watchdog executed,
    retried rounds included) and how many fields met the residual tol;
  * watchdog activity (retries / refactorizations / rollbacks).

The fault rates are TRACED operands of one jitted program per engine, so
after the first rate warms the programs every further rate reuses them —
the bench counts the jit caches and reports the growth (must be ZERO).

Acceptance (ISSUE 7): at n=1000, B=16, the colored engine converges within
2x the fault-free RMSE at a 10% i.i.d. drop rate with the watchdog on.

Run:  PYTHONPATH=src python -m benchmarks.fault_bench
      PYTHONPATH=src python -m benchmarks.fault_bench --n 100 --batch 4
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    Kernel,
    build_topology,
    faults,
    fusion,
    init_state,
    make_batch_problem,
    monitor,
)

DROPS = (0.0, 0.05, 0.1, 0.2, 0.3)


def _build(n, b, dim, radius, gamma, lam, noise, seed=0):
    """Static per-field sinusoid targets over one geometric network."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(-1, 1, size=(n, dim)).astype(np.float32)
    topo = build_topology(pos, radius)
    freq = rng.uniform(0.5, 2.0, size=(b, 1)).astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi, size=(b, 1)).astype(np.float32)
    truth = np.sin(np.pi * freq * pos[None, :, 0] + phase).astype(np.float32)
    ys = truth + noise * rng.normal(size=(b, n)).astype(np.float32)
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=gamma), ys, jnp.full((n,), lam)
    )
    return pos, prob, truth


@jax.jit
def _fused_rmse(problem, state, xq, truth):
    """kNN-fused (k=3) estimate at the sensor sites vs truth: (B,)."""
    preds = fusion.evaluate_sensors(problem, state, xq)
    fused = fusion.knn_fusion(
        preds, problem.topology.positions, xq, k=3, alive=problem.alive[:-1]
    )
    return jnp.sqrt(jnp.mean((fused - truth) ** 2, axis=-1))


def _cache_sizes(engine):
    """Jit-cache sizes of every program a watchdog-supervised faulty
    training round dispatches (the zero-recompile assertion's witness)."""
    fns = (
        faults._faulty_serial if engine == "serial" else faults._faulty_colored,
        monitor._round_metrics,
        _fused_rmse,
    )
    return [f._cache_size() for f in fns]


def run_rate(pos, prob, truth, drop, *, engine, cfg, seed=1):
    """Watchdog-supervised training from scratch at one drop rate."""
    state = init_state(prob)
    # A FaultModel even at drop=0: the rate is a traced operand, so the
    # p=0 run warms the exact program every other rate reuses.
    model = faults.make_fault_model(drop)
    t0 = time.perf_counter()
    prob_out, state, receipt = monitor.watch_sweeps(
        prob, state, model=model, key=jax.random.PRNGKey(seed),
        engine=engine, config=cfg,
    )
    jax.block_until_ready(state.z)
    dt = time.perf_counter() - t0
    rmse = np.asarray(_fused_rmse(prob_out, state, pos, truth))
    return {
        "drop": drop,
        "rmse_mean": float(rmse.mean()),
        "rmse_max": float(rmse.max()),
        "sweeps_to_converge": int(receipt.sweeps),
        "rounds": int(receipt.rounds),
        "converged_fields": int(np.sum(receipt.converged)),
        "retries": int(receipt.retries),
        "refactorized": int(receipt.refactorized),
        "rolled_back": bool(receipt.rolled_back),
        "s_per_sweep": dt / max(receipt.sweeps, 1),
    }


def sweep_drops(
    n, batch, drops, *, dim, radius, gamma, lam, noise, engine, tol,
    sweeps_per_round, max_rounds, seed=0,
):
    pos, prob, truth = _build(n, batch, dim, radius, gamma, lam, noise, seed)
    cfg = monitor.WatchdogConfig(
        sweeps_per_round=sweeps_per_round, tol=tol, max_rounds=max_rounds
    )
    # Warm every program on the FIRST rate (a short budget is enough: the
    # programs are keyed on shapes + static sweeps_per_round, not rates).
    warm_cfg = monitor.WatchdogConfig(
        sweeps_per_round=sweeps_per_round, tol=tol, max_rounds=2
    )
    run_rate(pos, prob, truth, drops[0], engine=engine, cfg=warm_cfg)
    base = _cache_sizes(engine)

    entries = []
    print(f"{'drop':>6s} {'rmse':>8s} {'ratio':>7s} {'sweeps':>7s} "
          f"{'conv':>6s} {'retry':>5s} {'s/sweep':>9s}")
    for p in drops:
        e = run_rate(pos, prob, truth, p, engine=engine, cfg=cfg)
        entries.append(e)
        ratio = e["rmse_mean"] / max(entries[0]["rmse_mean"], 1e-12)
        e["rmse_ratio_vs_faultfree"] = ratio
        print(f"{p:6.2f} {e['rmse_mean']:8.4f} {ratio:6.2f}x "
              f"{e['sweeps_to_converge']:7d} "
              f"{e['converged_fields']:3d}/{batch:<2d} {e['retries']:5d} "
              f"{e['s_per_sweep']:9.5f}")
    compiles = sum(a - b for a, b in zip(_cache_sizes(engine), base))
    print(f"compiles after warmup across {len(drops)} rates: {compiles} "
          f"(want 0)")
    return entries, compiles


def fault_fast(rows):
    """Trimmed grid for ``benchmarks/run.py --fast`` (CI bench-json rows)."""
    entries, compiles = sweep_drops(
        100, 4, (0.0, 0.1), dim=1, radius=0.3, gamma=10.0, lam=0.01,
        noise=0.05, engine="plan", tol=1e-3, sweeps_per_round=5,
        max_rounds=40,
    )
    e = entries[-1]
    rows.append((
        f"faults.n100.p{e['drop']:.2f}.watchdog",
        e["s_per_sweep"] * 1e6,
        f"rmse_ratio_vs_faultfree={e['rmse_ratio_vs_faultfree']:.2f}x;"
        f"converged={e['converged_fields']}/4;"
        f"sweeps={e['sweeps_to_converge']}",
    ))
    rows.append((
        f"faults.n100.compiles",
        float(compiles),
        "xla_compiles_after_warmup_across_rates",
    ))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--drops", default=",".join(str(p) for p in DROPS))
    ap.add_argument("--dim", type=int, default=1)
    ap.add_argument("--radius", type=float, default=-1.0,
                    help="coupling radius (< 0: scale 0.3 * (100/n)^(1/dim))")
    ap.add_argument("--gamma", type=float, default=10.0)
    ap.add_argument("--lam", type=float, default=0.01)
    ap.add_argument("--noise", type=float, default=0.05)
    ap.add_argument("--engine", default="plan",
                    choices=["serial", "plan", "onehot", "pallas"])
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--sweeps-per-round", type=int, default=5)
    ap.add_argument("--max-rounds", type=int, default=40)
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    radius = args.radius
    if radius < 0:
        radius = 0.3 * (100.0 / args.n) ** (1.0 / args.dim)
    drops = tuple(float(s) for s in args.drops.split(","))
    entries, compiles = sweep_drops(
        args.n, args.batch, drops, dim=args.dim, radius=radius,
        gamma=args.gamma, lam=args.lam, noise=args.noise,
        engine=args.engine, tol=args.tol,
        sweeps_per_round=args.sweeps_per_round, max_rounds=args.max_rounds,
    )
    at_p10 = next((e for e in entries if abs(e["drop"] - 0.1) < 1e-9), None)
    out = {
        "name": "faults", "n": args.n, "batch": args.batch,
        "engine": args.engine, "tol": args.tol, "entries": entries,
        "rmse_ratio_at_p10":
            None if at_p10 is None else at_p10["rmse_ratio_vs_faultfree"],
        "compiles_after_warmup": compiles,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    if at_p10 is not None:
        print(f"rmse_ratio_at_p10: {at_p10['rmse_ratio_vs_faultfree']:.2f}x "
              f"(acceptance: <= 2x)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
