import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""H3 (§Perf): the paper's technique as the data-parallel transport.

Lowers the SAME train step on an 8-replica mesh with three gradient/param
synchronization modes and parses the collective bytes out of the compiled
HLO — a measured (not modeled) comparison:

  allreduce   — pmean of gradients every step (centralized special case,
                paper Lemma 3.1: complete-graph SOP == all-reduce)
  sop_gossip  — no gradient sync; ONE pairwise SOP projection of params per
                step (ring pairing schedule; SN-Train's neighbor coupling)
  local       — no coupling at all (the paper's 'local-only' ablation)

Run:  PYTHONPATH=src python -m benchmarks.gossip_hlo [--arch smollm-135m]
"""

import argparse
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.core import consensus
from repro.models import init_params, make_train_step
from repro.optim import constant, sgd


def lower_mode(cfg, mode, n_dev=8, batch=8, seq=128):
    mesh = compat.make_mesh((n_dev,), ("data",))
    opt = sgd(constant(1e-2))
    # Use a single pairing for the measurement: with the full 2-pairing ring
    # schedule the lax.switch keeps BOTH branches in the HLO text and the
    # static parse double-counts (only one branch executes per step).
    sched = consensus.ring_schedule(n_dev)[:1]
    dp_mode = {"allreduce": "allreduce", "sop_gossip": "sop_gossip", "local": "none"}[mode]
    step = make_train_step(cfg, opt, dp_axis="data", dp_mode=dp_mode,
                           gossip_schedule=sched)

    def device_fn(params, opt_state, batch, ridx):
        p1 = jax.tree.map(lambda a: a[0], params)
        o1 = jax.tree.map(lambda a: a[0], opt_state)
        p1, o1, m = step(p1, o1, batch, ridx[0])
        lift = lambda a: a[None]
        return jax.tree.map(lift, p1), jax.tree.map(lift, o1), m["loss"]

    sharded = compat.shard_map(
        device_fn, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P()),
    )
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    stack = lambda a: jax.ShapeDtypeStruct((n_dev,) + a.shape, a.dtype)
    params = jax.tree.map(stack, params)
    opt_state = jax.tree.map(stack, jax.eval_shape(opt.init, jax.tree.map(
        lambda s: jnp.zeros(s.shape[1:], s.dtype), params)))
    b = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32),
    }
    ridx = jax.ShapeDtypeStruct((n_dev,), jnp.int32)
    compiled = jax.jit(sharded).lower(params, opt_state, b, ridx).compile()
    from repro.launch.dryrun import collective_bytes

    return collective_bytes(compiled.as_text())


def main(rows=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()
    cfg = get_config(args.arch, variant="smoke")
    out = {}
    for mode in ("allreduce", "sop_gossip", "local"):
        coll = lower_mode(cfg, mode)
        total = sum(v for k, v in coll.items() if k != "count")
        out[mode] = {"total_bytes": total, **coll}
        print(f"{mode:12s} total={total/1e6:8.2f}MB  "
              + " ".join(f"{k}={v/1e6:.2f}MB" for k, v in coll.items()
                         if k != "count" and v > 0),
              flush=True)
    # Convert parsed op-OUTPUT bytes to modeled WIRE bytes:
    #   ring all-reduce moves 2(n-1)/n x tensor; ppermute moves exactly 1x.
    n = 8
    wire_ar = out["allreduce"]["all-reduce"] * 2 * (n - 1) / n
    wire_gossip = out["sop_gossip"]["collective-permute"]
    print(f"\nmodeled wire bytes/step: allreduce={wire_ar/1e6:.2f}MB "
          f"sop_gossip={wire_gossip/1e6:.2f}MB "
          f"(ratio {wire_ar/max(wire_gossip,1):.2f}x; hop depth 2(n-1)=14 vs 1)")
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
