"""Pallas kernel micro-benchmarks (interpret mode on CPU; timings are for the
oracle path which lowers to XLA:CPU — the Pallas path is validated for
correctness and its HBM-traffic advantage is derived analytically).

Derived column = modeled HBM bytes: the fused kernel streams O(Q+N) floats
instead of materializing the (Q, N) Gram matrix (O(Q*N)).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import kernel_matvec
from repro.kernels.ref import kernel_matvec_ref, rbf_gram_ref


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def kernel_matvec_bytes(rows):
    rng = np.random.default_rng(0)
    for q, n in [(512, 2048), (1024, 8192)]:
        xq = jnp.asarray(rng.normal(size=(q, 2)).astype(np.float32))
        an = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        us_ref = _time(jax.jit(lambda a, b, d: kernel_matvec_ref(a, b, d, 1.0)), xq, an, c)
        fused_bytes = 4 * (q * 2 + n * 2 + n + q)
        dense_bytes = 4 * (q * 2 + n * 2 + n + q + q * n)
        rows.append((f"kernel_matvec.ref.q{q}.n{n}", us_ref, f"hbm_bytes={dense_bytes}"))
        rows.append(
            (
                f"kernel_matvec.pallas_model.q{q}.n{n}",
                us_ref,  # interpret-mode timing is not meaningful; report modeled traffic
                f"hbm_bytes={fused_bytes} ({dense_bytes/fused_bytes:.0f}x less traffic)",
            )
        )


def kernel_matvec_correctness(rows):
    """Max |pallas - oracle| over a shape sweep — the CI-visible guarantee."""
    rng = np.random.default_rng(1)
    worst = 0.0
    for q, n, d in [(64, 256, 1), (130, 600, 2), (257, 1000, 3)]:
        xq = rng.normal(size=(q, d)).astype(np.float32)
        an = rng.normal(size=(n, d)).astype(np.float32)
        c = rng.normal(size=(n,)).astype(np.float32)
        t0 = time.time()
        out = kernel_matvec(xq, an, c, gamma=1.0)
        us = (time.time() - t0) * 1e6
        ref = kernel_matvec_ref(jnp.asarray(xq), jnp.asarray(an), jnp.asarray(c), 1.0)
        worst = max(worst, float(jnp.max(jnp.abs(out - ref))))
    rows.append(("kernel_matvec.max_abs_err", us, f"{worst:.2e}"))
