"""Multi-field SN-Train throughput benchmark.

Measures, for batch sizes B = 1 .. 256 over one shared sensor network:

  * fields/sec of the batched colored_sweep engine (the training hot path);
  * the batching speedup of B=64 vs 64 sequential B=1 runs: the batched
    engine's lane-vectorized triangular solves and static-plan message
    scatters amortize the per-color-step overhead that dominates
    bounded-degree networks (the realistic mote regime — the default below
    is a 2-D geometric graph with D ~ 13);
  * streaming per-update latency: one rank-1 (grow-one) Cholesky absorption
    vs a from-scratch refactorization of every local system.

``--scaling`` instead runs the n-scaling sweep of the colored engines
(radius shrinks as 1/sqrt(n) so the padded degree D stays ~constant): the
``onehot`` reference realizes each color-step scatter as a dense
``(M*D, n_z)`` GEMM — O(n^2) per sweep — where the ``plan`` engine's static
gather is O(n*D).  Results (ms/sweep per engine and the speedup at
n = 1000) are written to ``BENCH_colored_scaling.json``.

Run:  PYTHONPATH=src python -m benchmarks.multifield_bench [--sensors 100]
      PYTHONPATH=src python -m benchmarks.multifield_bench --scaling
"""

from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    init_state,
    make_batch_problem,
    streaming,
    uniform_sensors,
)


def _fields(b, n, pos, rng):
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    phase = rng.uniform(0, 2 * np.pi, size=(b, 1))
    return np.sin(np.pi * freq * pos[None, :, 0] + phase) + 0.3 * rng.normal(size=(b, n))


def time_sweeps(prob, state, sweeps, reps=3, engine="plan"):
    run = lambda: colored_sweep(prob, state, n_sweeps=sweeps, engine=engine)
    run().z.block_until_ready()  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run().z.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def scaling_main(args):
    """n-scaling of one colored sweep per engine -> BENCH_colored_scaling.json."""
    rng = np.random.default_rng(0)
    kern = Kernel("rbf", gamma=1.0)
    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    ns = [int(s) for s in args.ns.split(",")]
    b, sweeps = args.batch, args.scaling_sweeps
    entries = []
    hdr = " ".join(f"{('ms/sweep ' + e):>16s}" for e in engines)
    print(f"{'n':>6s} {'D':>4s} {'colors':>6s} {'n_z':>7s} {hdr}")
    for n in ns:
        # Shrink the radius with 1/sqrt(n) so the expected degree (and the
        # padded neighborhood D) stays ~constant — the mote regime where the
        # message traffic, not the local solves, dominates.
        r = args.radius * math.sqrt(100.0 / n)
        pos = uniform_sensors(n, d=2, seed=0)
        topo = build_topology(pos, r)
        prob = make_batch_problem(
            topo, kern, _fields(b, n, pos, rng), jnp.full((n,), args.lam)
        )
        state = init_state(prob)
        row = {
            "n": n, "d_max": topo.d_max, "n_colors": topo.n_colors,
            "n_z": prob.n_z, "batch": b, "sweeps": sweeps,
        }
        for engine in engines:
            t = time_sweeps(prob, state, sweeps, reps=2, engine=engine)
            row[f"ms_per_sweep_{engine}"] = t * 1e3 / sweeps
        entries.append(row)
        cols = " ".join(
            f"{row[f'ms_per_sweep_{e}']:>16.2f}" for e in engines
        )
        print(f"{n:6d} {topo.d_max:4d} {topo.n_colors:6d} {prob.n_z:7d} {cols}")

    out = {"name": "colored_scaling", "batch": b, "entries": entries}
    ref = next((e for e in entries if e["n"] == 1000), None)
    if ref is not None and "ms_per_sweep_onehot" in ref:
        for e in engines:
            if e != "onehot" and f"ms_per_sweep_{e}" in ref:
                out[f"speedup_at_n1000_{e}"] = (
                    ref["ms_per_sweep_onehot"] / ref[f"ms_per_sweep_{e}"]
                )
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    for k, v in out.items():
        if k.startswith("speedup"):
            print(f"{k}: {v:.1f}x")
    print(f"wrote {args.out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sensors", type=int, default=100)
    ap.add_argument("--dim", type=int, default=2, help="sensor-space dimension")
    ap.add_argument("--radius", type=float, default=0.3)
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--stream", type=int, default=64, help="streaming updates to time")
    ap.add_argument("--max_batch", type=int, default=256)
    ap.add_argument("--scaling", action="store_true",
                    help="run the n-scaling engine comparison instead")
    ap.add_argument("--ns", default="100,200,500,1000,2000",
                    help="sensor counts for --scaling")
    ap.add_argument("--batch", type=int, default=16, help="fields for --scaling")
    ap.add_argument("--scaling_sweeps", type=int, default=2)
    ap.add_argument("--engines", default="onehot,plan",
                    help="comma list of colored_sweep engines for --scaling")
    ap.add_argument("--out", default="BENCH_colored_scaling.json")
    args = ap.parse_args()

    if args.scaling:
        scaling_main(args)
        return

    n = args.sensors
    rng = np.random.default_rng(0)
    pos = uniform_sensors(n, d=args.dim, seed=0)
    topo = build_topology(pos, args.radius)
    kern = Kernel("rbf", gamma=1.0)
    lams = jnp.full((n,), args.lam)
    print(f"sensors={n} D={topo.d_max} colors={topo.n_colors} sweeps/run={args.sweeps}")

    # ---- batched sweep throughput ----------------------------------------
    batches = [b for b in (1, 2, 4, 16, 64, 256) if b <= args.max_batch]
    times = {}
    print(f"\n{'B':>5s} {'time/run':>10s} {'fields/s':>12s}")
    for b in batches:
        prob = make_batch_problem(topo, kern, _fields(b, n, pos, rng), lams)
        state = init_state(prob)
        t = time_sweeps(prob, state, args.sweeps)
        times[b] = t
        print(f"{b:5d} {t*1e3:9.1f}ms {b/t:12.1f}")

    # ---- B=64 vs 64 sequential B=1 runs ----------------------------------
    if 64 in times:
        prob1 = make_batch_problem(topo, kern, _fields(1, n, pos, rng), lams)
        state1 = init_state(prob1)
        colored_sweep(prob1, state1, n_sweeps=args.sweeps).z.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(64):
            colored_sweep(prob1, state1, n_sweeps=args.sweeps).z.block_until_ready()
        t_seq = time.perf_counter() - t0
        speedup = t_seq / times[64]
        print(
            f"\nB=64 batched: {times[64]*1e3:.1f}ms   64 x B=1 sequential: "
            f"{t_seq*1e3:.1f}ms   speedup: {speedup:.1f}x"
        )

    # ---- streaming: rank-1 absorb vs full refactorization ----------------
    b_s = min(16, args.max_batch)
    deg_max = int(np.asarray(topo.degrees).max())
    topo_s = build_topology(pos, args.radius, d_max=deg_max + 8)
    prob = make_batch_problem(topo_s, kern, _fields(b_s, n, pos, rng), lams)
    state = init_state(prob)

    def arrival(i):
        f = int(rng.integers(0, b_s))
        s = int(rng.integers(0, n))
        x = pos[s] + 0.05 * rng.normal(size=pos.shape[1]).astype(np.float32)
        return f, s, x, float(rng.normal())

    f, s, x, y = arrival(0)
    prob, state, _ = streaming.absorb(prob, state, f, s, x, y, donate=True)  # compile
    jax.block_until_ready(prob.chol)
    t0 = time.perf_counter()
    n_upd = args.stream - 1
    for i in range(n_upd):
        f, s, x, y = arrival(i)
        prob, state, _ = streaming.absorb(prob, state, f, s, x, y, donate=True)
    jax.block_until_ready(prob.chol)
    t_absorb = (time.perf_counter() - t0) / max(n_upd, 1)

    streaming.rebuild_chol(prob).block_until_ready()  # compile
    t0 = time.perf_counter()
    streaming.rebuild_chol(prob).block_until_ready()
    t_rebuild = time.perf_counter() - t0
    err = float(jnp.max(jnp.abs(streaming.rebuild_chol(prob) - prob.chol)))
    print(
        f"\nstreaming (B={b_s}, D={topo_s.d_max}): {t_absorb*1e3:.3f} ms/update "
        f"(rank-1)   full refactorization: {t_rebuild*1e3:.3f} ms   "
        f"max|chol - rebuild| = {err:.2e}"
    )


if __name__ == "__main__":
    main()
