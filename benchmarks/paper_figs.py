"""Benchmarks reproducing the paper's figures (reduced sizes for CPU).

fig4_convergence_case1 / fig5_convergence_case2:
    test error vs outer iterations T for the three fusion rules, against the
    centralized baseline (paper Figs. 4-5).
fig6_connectivity_case1 / fig6_connectivity_case2:
    test error vs connectivity radius r for SN-Train vs local-only vs
    centralized, single-sensor fusion (paper Fig. 6).

Each returns rows of (label, value) and asserts nothing — the CSV is the
artifact; EXPERIMENTS.md quotes it.
"""

from __future__ import annotations

import os

# Paper-faithful numerics: lambda_i ~ 1e-5 needs f64 solves (see sn_train).
os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_topology,
    colored_sweep,
    fit_krr,
    init_state,
    local_only,
    make_problem,
)
from repro.core import fusion
from repro.core.centralized import predict
from repro.data import case1, case2, sample_field


def _avg_errors(case, radius, t_values, *, n=50, trials=8, rule_list=("single", "nn", "conn")):
    """Mean test error per fusion rule per T, averaged over random networks."""
    errs = {r: np.zeros(len(t_values)) for r in rule_list}
    cent = 0.0
    for s in range(trials):
        d = sample_field(case, n, seed=100 + s)
        topo = build_topology(d["x"], radius)
        prob = make_problem(topo, case.kernel, d["y"], dtype=jnp.float64)
        xq, yq = d["x_test"], d["y_test"]
        state = init_state(prob)
        done = 0
        for ti, t in enumerate(t_values):
            state = colored_sweep(prob, state, n_sweeps=t - done)
            done = t
            for r in rule_list:
                pred = fusion.fuse(prob, state, xq, r)
                errs[r][ti] += float(jnp.mean((pred - yq) ** 2)) / trials
        model = fit_krr(d["x"], d["y"], case.kernel, lam=0.01 / n**2, dtype=jnp.float64)
        cent += float(jnp.mean((predict(model, xq) - yq) ** 2)) / trials
    return errs, cent


def fig4_convergence_case1(rows):
    t_values = [1, 2, 3, 5, 10, 25, 50]
    t0 = time.time()
    errs, cent = _avg_errors(case1(), radius=0.4, t_values=t_values)
    dt = (time.time() - t0) * 1e6
    for r, v in errs.items():
        for t, e in zip(t_values, v):
            rows.append((f"fig4.case1.{r}.T{t}", dt / len(t_values), f"{e:.4f}"))
    rows.append(("fig4.case1.centralized", dt, f"{cent:.4f}"))


def fig5_convergence_case2(rows):
    t_values = [1, 2, 3, 5, 10, 25, 50]
    t0 = time.time()
    errs, cent = _avg_errors(case2(), radius=0.8, t_values=t_values)
    dt = (time.time() - t0) * 1e6
    for r, v in errs.items():
        for t, e in zip(t_values, v):
            rows.append((f"fig5.case2.{r}.T{t}", dt / len(t_values), f"{e:.4f}"))
    rows.append(("fig5.case2.centralized", dt, f"{cent:.4f}"))


def _connectivity(case, radii, *, n=50, trials=6, sweeps=80):
    out = []
    for r in radii:
        sn, lo, ce = 0.0, 0.0, 0.0
        for s in range(trials):
            d = sample_field(case, n, seed=200 + s)
            topo = build_topology(d["x"], r)
            prob = make_problem(topo, case.kernel, d["y"], dtype=jnp.float64)
            xq, yq = d["x_test"], d["y_test"]
            st = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
            sn += float(jnp.mean((fusion.fuse(prob, st, xq, "single") - yq) ** 2)) / trials
            lo += float(
                jnp.mean((fusion.fuse(prob, local_only(prob), xq, "single") - yq) ** 2)
            ) / trials
            model = fit_krr(d["x"], d["y"], case.kernel, lam=0.01 / n**2, dtype=jnp.float64)
            ce += float(jnp.mean((predict(model, xq) - yq) ** 2)) / trials
        out.append((r, sn, lo, ce))
    return out


def fig6_connectivity_case1(rows):
    t0 = time.time()
    data = _connectivity(case1(), radii=[0.1, 0.2, 0.3, 0.45, 0.6])
    dt = (time.time() - t0) * 1e6 / len(data)
    for r, sn, lo, ce in data:
        rows.append((f"fig6.case1.sn_train.r{r}", dt, f"{sn:.4f}"))
        rows.append((f"fig6.case1.local_only.r{r}", dt, f"{lo:.4f}"))
        rows.append((f"fig6.case1.centralized.r{r}", dt, f"{ce:.4f}"))


def fig6_connectivity_case2(rows):
    t0 = time.time()
    data = _connectivity(case2(), radii=[0.1, 0.5, 1.0, 1.5, 2.1])
    dt = (time.time() - t0) * 1e6 / len(data)
    for r, sn, lo, ce in data:
        rows.append((f"fig6.case2.sn_train.r{r}", dt, f"{sn:.4f}"))
        rows.append((f"fig6.case2.local_only.r{r}", dt, f"{lo:.4f}"))
        rows.append((f"fig6.case2.centralized.r{r}", dt, f"{ce:.4f}"))


def knn_k_sweep(rows):
    """Paper Sec. 3.3: k-NN fusion interpolates between nearest-neighbor
    (k=1) and the network average (k=n).  Sweep k for Case 2."""
    case = case2()
    d = sample_field(case, 50, seed=42)
    topo = build_topology(d["x"], 0.8)
    prob = make_problem(topo, case.kernel, d["y"], dtype=jnp.float64)
    t0 = time.time()
    state = colored_sweep(prob, init_state(prob), n_sweeps=60)
    xq, yq = d["x_test"], d["y_test"]
    us = (time.time() - t0) * 1e6
    for k in (1, 2, 5, 10, 25, 50):
        e = float(jnp.mean((fusion.fuse(prob, state, xq, "knn", k=k) - yq) ** 2))
        rows.append((f"knn_sweep.case2.k{k}", us, f"{e:.4f}"))
