"""Quantized + sparsified serving: dtype × energy_tau × n sweep.

Measures the two compounding serving optimizations of the quantized path
against the production configuration they upgrade:

  * ``compute_dtype="bf16"`` — bf16 STORAGE for the anchor tables (the
    VMEM-dominant operand) with register-level upconversion, exact f32
    selection, and coefficient-dtype (f32) accumulation in the fused
    Pallas kernel; the halved footprint doubles the default query tile
    per program (``kernels.knn_fuse.default_block_q``).
  * ``energy_tau`` representer pruning — ``pruning.prune_plan`` compacts
    the per-cell candidate lists to sensors whose coefficient energy
    clears the threshold, shrinking the ``K_max`` gather width that
    lifecycle capacity (``spare``/``slack`` columns) and dead-weight
    representers inflate.
  * bulk tile retuning — pallas rows sweep ``block_q`` beyond the
    latency-oriented shipped default; on this repo's CPU interpret
    backend the per-grid-step table rematerialization dominates, so
    larger bulk tiles amortize it (on real TPU the same knob trades VMEM
    headroom for grid amortization).

The BASELINE is the serving configuration the repo shipped before this
path: the churn-ready capacity plan (spare/slack lifecycle rows), f32,
default tile.  Each (dtype, tau, block) grid cell reports warm
field-queries/s and the field RMSE against the f32 DENSE oracle
(relative, % of field RMS) — retuned f32 rows stay in the JSON so each
lever's contribution is auditable.  Tau values are fractions of the max
live-sensor energy; ``tau = 0`` compacts away only dead/spare candidate
entries (provably exact — nothing live is pruned).

Zero-recompile contract: after one warmup pass over the whole grid, the
timed pass compiles nothing (the jit caches of the pallas launcher and
the plan-engine helpers are counted and asserted; recorded in the JSON).

Results go to ``BENCH_quant.json``; ``quant_fast`` is the trimmed variant
``benchmarks/run.py --fast`` runs for the CI bench-json artifact.

Run:  PYTHONPATH=src python -m benchmarks.quant_bench
      PYTHONPATH=src python -m benchmarks.quant_bench --ns 100,1000 --taus 0,0.02
"""

from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    fusion,
    init_state,
    make_batch_problem,
    make_serving_plan,
    pruning,
    uniform_sensors,
)
from repro.kernels.knn_fuse import default_block_q


def _problem(n, b, radius, lam, seed=0):
    rng = np.random.default_rng(seed)
    pos = uniform_sensors(n, d=2, seed=seed)
    topo = build_topology(pos, radius)
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=1.0), ys, jnp.full((n,), lam)
    )
    state = colored_sweep(prob, init_state(prob), n_sweeps=3)
    return prob, state


def _tracked_caches():
    from repro.core.serving import _eval_selected, knn_select_valid
    from repro.kernels.knn_fuse import knn_fuse_pallas

    return (knn_fuse_pallas, knn_select_valid, _eval_selected)


def _grid_cells(prob, state, plan_cap, taus):
    """(label, plan, report) per tau column: capacity plan + compactions."""
    n = prob.n
    e = np.asarray(pruning.representer_energy(prob, state))[:n]
    e_max = float(e.max()) if e.size else 1.0
    cells = [("cap", plan_cap, None)]  # the unpruned lifecycle plan
    for tau in taus:
        plan_t, rep = pruning.prune_plan(
            prob, state, plan_cap, energy_tau=float(tau) * e_max
        )
        cells.append((f"tau{tau:g}", plan_t, rep))
    return cells


def sweep(ns, queries, k, batch, taus, engines=("pallas", "plan"),
          radius=0.3, lam=0.1, spare=None, slack=4, reps=2,
          blocks=(None, 512)):
    rng = np.random.default_rng(1)
    xq = rng.uniform(-1, 1, size=(queries, 2)).astype(np.float32)
    entries = []
    print(f"{'n':>6s} {'eng':>7s} {'dtype':>6s} {'tau':>8s} {'K_max':>6s} "
          f"{'block':>7s} {'fq/s':>12s} {'rmse%':>8s}")
    for n in ns:
        r = radius * math.sqrt(100.0 / n)
        prob, state = _problem(n, batch, r, lam)
        # The production plan: lifecycle capacity inflates K_max — exactly
        # the dead weight compaction reclaims.  Spare provisions ~2% of
        # the network joining concurrently (min 8), the capacity the
        # daemon's churn tests exercise; compaction re-derives per publish
        # so the NEXT join still finds spare rows on the unpruned plan.
        n_spare = max(8, round(0.02 * n)) if spare is None else spare
        plan_cap = make_serving_plan(prob, k=k, spare=n_spare, slack=slack)
        dense = np.asarray(
            fusion.fuse(prob, state, xq, "knn", k=k, engine="dense")
        )
        dense_rms = float(np.sqrt(np.mean(dense**2)))
        cells = _grid_cells(prob, state, plan_cap, taus)

        def run(engine, cdt, plan, block):
            return fusion.fuse(
                prob, state, xq, "knn", k=k, engine=engine, plan=plan,
                compute_dtype=cdt, block_q=block,
            )

        # Pallas rows additionally sweep the bulk query tile: the shipped
        # default (None -> default_block_q) is latency-oriented (small
        # bucketed requests pad little); offline/bulk serving retunes it.
        grid = [
            (eng, dtype, cell, block)
            for eng in engines
            for dtype in (None, "bf16")
            for cell in cells
            for block in (blocks if eng == "pallas" else (None,))
        ]
        # Warmup pass over the WHOLE grid, then snapshot the jit caches:
        # the timed pass must compile nothing.
        for eng, dtype, (label, plan, _rep), block in grid:
            run(eng, dtype, plan, block).block_until_ready()
        warm = [f._cache_size() for f in _tracked_caches()]
        for eng, dtype, (label, plan, rep), block in grid:
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                run(eng, dtype, plan, block).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            out = np.asarray(run(eng, dtype, plan, block))
            rmse_pct = (
                float(np.sqrt(np.mean((out - dense) ** 2))) / dense_rms * 100
            )
            row = {
                "n": n, "engine": eng,
                "dtype": "f32" if dtype is None else dtype,
                "tau": label, "k": k, "batch": batch, "queries": queries,
                "k_max": plan.k_max, "s_per_call": best,
                "fqps": queries * batch / best, "rmse_pct": rmse_pct,
            }
            if eng == "pallas":
                row["block_q"] = (
                    default_block_q(None if dtype is None else jnp.bfloat16)
                    if block is None else block
                )
                row["block_default"] = block is None
            if rep is not None:
                row["tau_abs"] = rep.energy_tau
                row["pruned"] = rep.n_pruned
                row["n_live"] = rep.n_live
            entries.append(row)
            bq_s = f"bq{row.get('block_q', '-')}"
            print(f"{n:6d} {eng:>7s} {row['dtype']:>6s} {label:>8s} "
                  f"{plan.k_max:6d} {bq_s:>7s} {row['fqps']:12.0f} "
                  f"{rmse_pct:8.3f}")
        recompiles = sum(
            f._cache_size() - w for f, w in zip(_tracked_caches(), warm)
        )
        assert recompiles == 0, (
            f"timed grid pass compiled {recompiles} extra programs"
        )
    return entries


def _acceptance(entries, engines, at_n, rmse_budget_pct=1.0):
    """speedup = previous production config / best admissible quant cell.

    Baseline: f32, capacity plan, default tile — the serving configuration
    the repo shipped before the quantized path.  Admissible: bf16 + some
    (tau, tile) with RMSE within the budget of the dense oracle.  The full
    grid (including retuned f32 rows) stays in ``entries`` so the
    contribution of each lever is auditable.  Per engine, at n = at_n.
    """
    out = {}
    for eng in engines:
        rows = [e for e in entries if e["n"] == at_n and e["engine"] == eng]
        base = next(
            (
                e for e in rows
                if e["dtype"] == "f32" and e["tau"] == "cap"
                and e.get("block_default", True)
            ),
            None,
        )
        quant = [
            e for e in rows
            if e["dtype"] == "bf16" and e["rmse_pct"] <= rmse_budget_pct
        ]
        if base is None or not quant:
            continue
        best = min(quant, key=lambda e: e["s_per_call"])
        out[f"speedup_at_n{at_n}_{eng}"] = (
            base["s_per_call"] / best["s_per_call"]
        )
        out[f"best_cell_at_n{at_n}_{eng}"] = {
            "dtype": best["dtype"], "tau": best["tau"],
            "k_max": best["k_max"], "rmse_pct": best["rmse_pct"],
            "fqps": best["fqps"],
            "block_q": best.get("block_q"),
        }
    return out


def quant_fast(rows):
    """Trimmed grid for ``benchmarks/run.py --fast`` (CI bench-json rows)."""
    entries = sweep(
        ns=(100,), queries=512, k=3, batch=4, taus=(0.0, 0.02),
        engines=("pallas",), reps=1, blocks=(None,),
    )
    for e in entries:
        rows.append(
            (
                f"quant.n{e['n']}.{e['engine']}.{e['dtype']}.{e['tau']}",
                e["s_per_call"] * 1e6,
                f"fqps={e['fqps']:.0f};rmse_pct={e['rmse_pct']:.3f};"
                f"k_max={e['k_max']};recompiles=0",
            )
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", default="100,300,1000")
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--taus", default="0,0.02,0.05",
                    help="energy thresholds as fractions of the max live "
                         "sensor energy")
    ap.add_argument("--engines", default="pallas,plan")
    ap.add_argument("--radius", type=float, default=0.3)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--spare", type=int, default=None,
                    help="join-capacity rows in the baseline plan "
                         "(default: max(8, 2%% of n))")
    ap.add_argument("--slack", type=int, default=4)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--blocks", default="default,512",
                    help="pallas query tiles to sweep ('default' = the "
                         "shipped default_block_q)")
    ap.add_argument("--out", default="BENCH_quant.json")
    args = ap.parse_args()
    ns = [int(s) for s in args.ns.split(",")]
    taus = [float(s) for s in args.taus.split(",")]
    engines = tuple(e.strip() for e in args.engines.split(",") if e.strip())
    blocks = tuple(
        None if s.strip() == "default" else int(s)
        for s in args.blocks.split(",") if s.strip()
    )
    entries = sweep(
        ns, args.queries, args.k, args.batch, taus, engines=engines,
        radius=args.radius, lam=args.lam, spare=args.spare,
        slack=args.slack, reps=args.reps, blocks=blocks,
    )
    out = {
        "name": "quant", "batch": args.batch, "queries": args.queries,
        "k": args.k, "taus": taus, "recompiles_after_warmup": 0,
        "entries": entries,
    }
    for at_n in {1000, ns[-1]}:
        out.update(_acceptance(entries, engines, at_n))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    for key, v in out.items():
        if key.startswith("speedup"):
            print(f"{key}: {v:.2f}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
