"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun] [--mesh pod]
       PYTHONPATH=src python -m benchmarks.roofline_report --serving

``--serving`` prints the per-program VMEM residency of the fused kNN
serving kernel (``kernels.knn_fuse``) at f32 vs bf16 anchor storage —
the static audit behind the quantized path's "halved footprint, doubled
tile" claim.  All TIMING numbers in this repo remain CPU interpret-mode;
the byte accounting here is backend-independent.
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str, mesh: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, unit=""):
    if x == 0:
        return "0"
    for scale, suffix in [(1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")]:
        if abs(x) >= scale:
            return f"{x/scale:.2f}{suffix}{unit}"
    return f"{x:.3g}{unit}"


def serving_tile_report(n=1000, d=2, d_max=15, k_max=85, n_cells=256):
    """Per-program VMEM bytes of ``knn_fuse_pallas``, f32 vs bf16 anchors.

    Shapes mirror the kernel's BlockSpecs (one field slot per program;
    defaults match the BENCH_quant n=1000 configuration after tau=0
    compaction).  Only the anchor table changes dtype on the quantized
    path — queries/positions/selection stay f32 (selection-exact) and the
    coefficients are never downcast.
    """
    from repro.kernels.knn_fuse import default_block_q

    r = n + 1  # padded sensor rows (sentinel)

    def operands(anchor_bytes, block_q):
        return [
            ("xq tile", block_q * d * 4),
            ("qcell tile", block_q * 4),
            ("cells", n_cells * k_max * 4),
            ("cell_mask", n_cells * k_max * 1),
            ("alive", r * 1),
            ("spos", r * d * 4),
            ("nbr_pos", r * d_max * d * anchor_bytes),
            ("nbr_mask", r * d_max * 1),
            ("coef", r * d_max * 4),
            ("out tile", block_q * 4),
        ]

    rows = []
    for label, anchor_bytes, cdt in (("f32", 4, None), ("bf16", 2, "bfloat16")):
        bq = default_block_q(cdt)
        ops = operands(anchor_bytes, bq)
        total = sum(b for _, b in ops)
        anchors = dict(ops)["nbr_pos"]
        rows.append((label, bq, anchors, total))
    print(f"# fused kNN serving kernel, per-program VMEM "
          f"(n={n}, D={d_max}, K_max={k_max}, C={n_cells})")
    print("| anchors | block_q | anchor-table bytes | total resident bytes |")
    print("|---|---|---|---|")
    for label, bq, anchors, total in rows:
        print(f"| {label} | {bq} | {fmt(anchors)}B | {fmt(total)}B |")
    (l0, _, a0, t0), (l1, _, a1, t1) = rows
    print(f"# {l1}/{l0}: anchor table x{a1 / a0:.2f}, "
          f"total x{t1 / t0:.2f} (anchors are the dominant geometric "
          f"operand; coef stays f32 by design)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--serving", action="store_true",
                    help="print the serving-kernel VMEM tile table "
                         "(f32 vs bf16 anchors) and exit")
    args = ap.parse_args()
    if args.serving:
        serving_tile_report()
        return

    recs = load(args.dir, args.mesh)
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))
    recs.sort(key=key)

    if args.csv:
        print("arch,shape,flops_per_chip,bytes_per_chip,coll_bytes,compute_s,memory_s,collective_s,dominant,useful_ratio")
        for r in recs:
            if r.get("skipped"):
                print(f"{r['arch']},{r['shape']},skipped,,,,,,,")
                continue
            ro = r["roofline"]
            print(
                f"{r['arch']},{r['shape']},{r['flops_per_chip']:.3e},{r['bytes_per_chip']:.3e},"
                f"{ro['collective_bytes']:.3e},{ro['compute_s']:.3e},{ro['memory_s']:.3e},"
                f"{ro['collective_s']:.3e},{ro['dominant']},{ro['useful_flops_ratio']:.3f}"
            )
        return

    hdr = ("| arch | shape | FLOPs/chip | bytes/chip | coll bytes/chip | "
           "compute (s) | memory (s) | collective (s) | dominant | 6ND/HLO |")
    print(hdr)
    print("|" + "---|" * 10)
    for r in recs:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | skipped | — |")
            continue
        ro = r["roofline"]
        dom = ro["dominant"].replace("_s", "")
        print(
            f"| {r['arch']} | {r['shape']} | {fmt(r['flops_per_chip'])} | "
            f"{fmt(r['bytes_per_chip'])}B | {fmt(ro['collective_bytes'])}B | "
            f"{ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} | "
            f"{dom} | {ro['useful_flops_ratio']:.2f} |"
        )


if __name__ == "__main__":
    main()
