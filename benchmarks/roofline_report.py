"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun] [--mesh pod]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str, mesh: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, unit=""):
    if x == 0:
        return "0"
    for scale, suffix in [(1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")]:
        if abs(x) >= scale:
            return f"{x/scale:.2f}{suffix}{unit}"
    return f"{x:.3g}{unit}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()

    recs = load(args.dir, args.mesh)
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))
    recs.sort(key=key)

    if args.csv:
        print("arch,shape,flops_per_chip,bytes_per_chip,coll_bytes,compute_s,memory_s,collective_s,dominant,useful_ratio")
        for r in recs:
            if r.get("skipped"):
                print(f"{r['arch']},{r['shape']},skipped,,,,,,,")
                continue
            ro = r["roofline"]
            print(
                f"{r['arch']},{r['shape']},{r['flops_per_chip']:.3e},{r['bytes_per_chip']:.3e},"
                f"{ro['collective_bytes']:.3e},{ro['compute_s']:.3e},{ro['memory_s']:.3e},"
                f"{ro['collective_s']:.3e},{ro['dominant']},{ro['useful_flops_ratio']:.3f}"
            )
        return

    hdr = ("| arch | shape | FLOPs/chip | bytes/chip | coll bytes/chip | "
           "compute (s) | memory (s) | collective (s) | dominant | 6ND/HLO |")
    print(hdr)
    print("|" + "---|" * 10)
    for r in recs:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | skipped | — |")
            continue
        ro = r["roofline"]
        dom = ro["dominant"].replace("_s", "")
        print(
            f"| {r['arch']} | {r['shape']} | {fmt(r['flops_per_chip'])} | "
            f"{fmt(r['bytes_per_chip'])}B | {fmt(ro['collective_bytes'])}B | "
            f"{ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} | "
            f"{dom} | {ro['useful_flops_ratio']:.2f} |"
        )


if __name__ == "__main__":
    main()
