"""Benchmark harness — one function per paper table/figure plus kernel and
consensus benches.  Prints ``name,us_per_call,derived`` CSV and writes one
machine-readable ``BENCH_<name>.json`` (``{name, us_per_call, derived}``)
per row into ``--json-dir`` — the artifacts CI uploads so the perf
trajectory is tracked per commit.

Usage: PYTHONPATH=src python -m benchmarks.run [--only PREFIX] [--fast]
           [--json-dir bench_out]
"""

from __future__ import annotations

import os

# Must precede any jax import: the paper-figure benches solve the paper's
# lambda ~ 1e-5 systems, which need f64 (explicit f32 arrays elsewhere are
# unaffected by the x64 flag).
os.environ.setdefault("JAX_ENABLE_X64", "1")

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="run benches whose name starts with this")
    ap.add_argument("--fast", action="store_true", help="skip the slow paper figures")
    ap.add_argument(
        "--json-dir", default=None,
        help="directory for the per-row BENCH_<name>.json files "
        "(defaults to bench_out under --fast, otherwise off)",
    )
    args = ap.parse_args()
    if args.json_dir is None and args.fast:
        args.json_dir = "bench_out"

    from . import (
        churn_bench,
        consensus_bench,
        daemon_bench,
        drift_bench,
        fault_bench,
        kernels_bench,
        paper_figs,
        quant_bench,
        serving_bench,
    )

    benches = [
        ("fig4_convergence_case1", paper_figs.fig4_convergence_case1, True),
        ("fig5_convergence_case2", paper_figs.fig5_convergence_case2, True),
        ("fig6_connectivity_case1", paper_figs.fig6_connectivity_case1, True),
        ("fig6_connectivity_case2", paper_figs.fig6_connectivity_case2, True),
        ("knn_k_sweep", paper_figs.knn_k_sweep, True),
        ("kernel_matvec_bytes", kernels_bench.kernel_matvec_bytes, False),
        ("kernel_matvec_correctness", kernels_bench.kernel_matvec_correctness, False),
        ("gossip_vs_allreduce", consensus_bench.gossip_vs_allreduce, False),
        ("serving", serving_bench.serving_fast, False),
        ("churn", churn_bench.churn_fast, False),
        ("drift", drift_bench.drift_fast, False),
        ("faults", fault_bench.fault_fast, False),
        ("daemon", daemon_bench.daemon_fast, False),
        ("quant", quant_bench.quant_fast, False),
    ]

    rows: list[tuple[str, float, str]] = []
    for name, fn, slow in benches:
        if args.only and not name.startswith(args.only):
            continue
        if args.fast and slow:
            continue
        print(f"# running {name} ...", file=sys.stderr, flush=True)
        fn(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json_dir:
        import json

        os.makedirs(args.json_dir, exist_ok=True)
        for name, us, derived in rows:
            path = os.path.join(
                args.json_dir, f"BENCH_{name.replace('/', '_')}.json"
            )
            with open(path, "w") as f:
                json.dump(
                    {"name": name, "us_per_call": us, "derived": derived}, f
                )
                f.write("\n")
        print(f"# wrote {len(rows)} BENCH_*.json to {args.json_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
