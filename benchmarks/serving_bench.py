"""kNN-fusion serving throughput: dense oracle vs static query plans.

Sweeps the network size n at fixed query load (Q queries x B fields, kNN
order k) and times one warm dispatch of every ``fusion.fuse(rule="knn")``
engine:

  * ``dense``  — evaluate all n sensors + dense (Q, n) top-k, O(Q*n*D);
  * ``plan``   — static cell-candidate query plan, O(Q*k*D);
  * ``pallas`` — the fused VMEM kernel over the same plan
                 (``repro.kernels.knn_fuse``; interpret mode off-TPU).

The radius shrinks as 1/sqrt(n) so the padded degree D and the plan's
candidate width K_max stay ~constant — the mote regime where per-query
work should not grow with the network.  Expected shape: dense
field-queries/s degrades ~1/n while plan/pallas stay ~flat (the serving
analogue of ``multifield_bench --scaling`` for the training sweep).

Results go to ``BENCH_serving.json``; ``serving_fast`` is the trimmed
variant ``benchmarks/run.py --fast`` runs so the numbers land in the CI
``bench-json`` artifact.

Run:  PYTHONPATH=src python -m benchmarks.serving_bench
      PYTHONPATH=src python -m benchmarks.serving_bench --ns 100,1000 --queries 4096
"""

from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    fusion,
    init_state,
    make_batch_problem,
    make_serving_plan,
    uniform_sensors,
)


def _problem(n, b, radius, lam, seed=0):
    rng = np.random.default_rng(seed)
    pos = uniform_sensors(n, d=2, seed=seed)
    topo = build_topology(pos, radius)
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=1.0), ys, jnp.full((n,), lam)
    )
    state = colored_sweep(prob, init_state(prob), n_sweeps=3)
    return prob, state


def _time_engine(prob, state, xq, k, engine, plan, reps=2):
    run = lambda: fusion.fuse(
        prob, state, xq, "knn", k=k, engine=engine, plan=plan
    )
    run().block_until_ready()  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(ns, queries, k, batch, engines, radius=0.3, lam=0.1, reps=2):
    rng = np.random.default_rng(1)
    xq = rng.uniform(-1, 1, size=(queries, 2)).astype(np.float32)
    entries = []
    hdr = " ".join(f"{('fq/s ' + e):>14s}" for e in engines)
    print(f"{'n':>6s} {'D':>4s} {'K_max':>6s} {hdr}")
    for n in ns:
        r = radius * math.sqrt(100.0 / n)
        prob, state = _problem(n, batch, r, lam)
        plan = make_serving_plan(prob, k=k)
        row = {
            "n": n, "d_max": prob.topology.d_max, "k": k,
            "batch": batch, "queries": queries,
            "plan_cells": plan.n_cells, "plan_k_max": plan.k_max,
        }
        for engine in engines:
            t = _time_engine(prob, state, xq, k, engine, plan, reps=reps)
            row[f"s_per_call_{engine}"] = t
            row[f"fqps_{engine}"] = queries * batch / t
        entries.append(row)
        cols = " ".join(f"{row[f'fqps_{e}']:>14.0f}" for e in engines)
        print(f"{n:6d} {prob.topology.d_max:4d} {plan.k_max:6d} {cols}")
    return entries


def _speedups(out, entries, engines, at_n):
    ref = next((e for e in entries if e["n"] == at_n), None)
    if ref is None or "s_per_call_dense" not in ref:
        return
    for e in engines:
        if e != "dense" and f"s_per_call_{e}" in ref:
            out[f"speedup_at_n{at_n}_{e}"] = (
                ref["s_per_call_dense"] / ref[f"s_per_call_{e}"]
            )


def serving_fast(rows):
    """Trimmed sweep for ``benchmarks/run.py --fast`` (CI bench-json rows)."""
    engines = ("dense", "plan", "pallas")
    entries = sweep(
        ns=(100, 300), queries=512, k=3, batch=4, engines=engines, reps=1
    )
    for e in entries:
        for eng in engines:
            rows.append(
                (
                    f"serving.n{e['n']}.{eng}",
                    e[f"s_per_call_{eng}"] * 1e6,
                    f"fqps={e[f'fqps_{eng}']:.0f}",
                )
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", default="100,200,500,1000,2000")
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--engines", default="dense,plan,pallas")
    ap.add_argument("--radius", type=float, default=0.3)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    ns = [int(s) for s in args.ns.split(",")]
    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    entries = sweep(
        ns, args.queries, args.k, args.batch, engines,
        radius=args.radius, lam=args.lam, reps=args.reps,
    )
    out = {
        "name": "serving", "batch": args.batch, "queries": args.queries,
        "k": args.k, "entries": entries,
    }
    for at_n in (1000, ns[-1]):
        _speedups(out, entries, engines, at_n)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    for key, v in out.items():
        if key.startswith("speedup"):
            print(f"{key}: {v:.1f}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
