"""Distributed SN-Train over a device mesh (the paper's algorithm sharded).

Sensors are distributed across devices with shard_map; each color step runs
the batched local Cholesky solves in parallel on every device and exchanges
the Update messages as a psum of disjoint deltas (DESIGN.md Sec. 2).

Run (8 simulated devices):
  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python examples/distributed_field.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )
# The paper's own regularizers (lambda_i = 0.01/|N_i|^2 ~ 1e-6 at this
# density) condition the local systems at ~1e9: f64 territory.  The solver
# stack is dtype-generic, so enabling x64 is all it takes.
os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    init_state,
    make_problem,
    sharded_sweep,
)
from repro.core import fusion
from repro.data import case2, sample_field


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    case = case2()
    data = sample_field(case, 200, seed=0)
    topo = build_topology(data["x"], radius=0.5)
    # The paper's lambda_i = 0.01/|N_i|^2 (default_lambdas), solvable here
    # because x64 is on — in f32 these systems NaN out (see make_problem).
    prob = make_problem(topo, case.kernel, data["y"], dtype=jnp.float64)
    st0 = init_state(prob)

    mesh = compat.make_mesh((n_dev,), ("sensors",))

    t0 = time.time()
    ref = colored_sweep(prob, st0, n_sweeps=20)
    t_ref = time.time() - t0
    t0 = time.time()
    sh = sharded_sweep(prob, st0, mesh, n_sweeps=20)
    t_sh = time.time() - t0

    diff = float(jnp.max(jnp.abs(ref.z - sh.z)))
    print(f"single-device colored sweep: {t_ref:.2f}s")
    print(f"sharded sweep ({n_dev} devices): {t_sh:.2f}s")
    print(f"max |z_single - z_sharded| = {diff:.2e} (identical message fixed point)")

    xq, yq = data["x_test"], data["y_test"]
    mse = float(jnp.mean((fusion.fuse(prob, sh, xq, "nn") - yq) ** 2))
    print(f"nn-fusion test MSE (200 sensors, distributed training): {mse:.4f}")


if __name__ == "__main__":
    main()
