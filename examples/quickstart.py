"""Quickstart: the paper's field-estimation experiment end to end.

Reproduces the Case-2 setup (Sec. 4.1): 50 sensors on [-1,1] observe
eta(x) = sin(pi x) + N(0,1); SN-Train runs T outer iterations of local
message passing; the fusion center aggregates with the three rules of
Sec. 3.3 and is compared against the centralized kernel estimator (Eq. 6).

Run:  PYTHONPATH=src python examples/quickstart.py [--case 1|2] [--sweeps 50]
"""

import os

# The paper's lambda_i = 0.01/|N_i|^2 conditions the local solves at ~1e9,
# so the faithful reproduction runs in float64 (see DESIGN.md / sn_train).
os.environ.setdefault("JAX_ENABLE_X64", "1")

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_topology,
    colored_sweep,
    fit_krr,
    init_state,
    local_only,
    make_problem,
)
from repro.core import fusion
from repro.core.centralized import predict
from repro.data import case1, case2, sample_field


def ascii_plot(xq, curves, width=72, height=16):
    """Tiny terminal plot: one char per curve."""
    lo = min(float(np.min(v)) for v in curves.values())
    hi = max(float(np.max(v)) for v in curves.values())
    grid = [[" "] * width for _ in range(height)]
    for (label, v), ch in zip(curves.items(), "*o+x#"):
        for i in range(width):
            xi = int(i / width * (len(v) - 1))
            yi = int((float(v[xi]) - lo) / (hi - lo + 1e-9) * (height - 1))
            grid[height - 1 - yi][i] = ch
    print(f"  y in [{lo:.2f}, {hi:.2f}]")
    for row in grid:
        print("  " + "".join(row))
    for (label, _), ch in zip(curves.items(), "*o+x#"):
        print(f"    {ch} = {label}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", type=int, default=2, choices=[1, 2])
    ap.add_argument("--n", type=int, default=50)
    ap.add_argument("--radius", type=float, default=0.0)
    ap.add_argument("--sweeps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    case = case1() if args.case == 1 else case2()
    radius = args.radius or (0.4 if args.case == 1 else 0.8)
    data = sample_field(case, args.n, seed=args.seed)
    print(f"case={case.name}  n={args.n}  r={radius}  kernel={case.kernel.name}")

    topo = build_topology(data["x"], radius)
    print(f"topology: max degree={int(np.asarray(topo.degrees).max())}, "
          f"colors={topo.n_colors} (distance-2 greedy)")

    import jax.numpy as jnp
    prob = make_problem(topo, case.kernel, data["y"], dtype=jnp.float64)
    state = colored_sweep(prob, init_state(prob), n_sweeps=args.sweeps)

    xq = np.linspace(-1, 1, 200)[:, None].astype(np.float32)
    truth = case.eta(xq[:, 0])
    cent = fit_krr(data["x"], data["y"], case.kernel, lam=0.01 / args.n**2,
                   dtype=jnp.float64)

    preds = {
        "truth": truth,
        "sn-train nn-fusion": np.asarray(fusion.fuse(prob, state, xq, "nn")),
        "sn-train single": np.asarray(fusion.fuse(prob, state, xq, "single")),
        "centralized": np.asarray(predict(cent, xq)),
        "local-only single": np.asarray(
            fusion.fuse(prob, local_only(prob), xq, "single")
        ),
    }
    xt, yt = data["x_test"], data["y_test"]
    print("\ntest MSE (vs clean field, 500 held-out points):")
    for name in ["sn-train nn-fusion", "sn-train single", "centralized", "local-only single"]:
        rule = {"sn-train nn-fusion": "nn", "sn-train single": "single"}.get(name)
        if rule:
            e = float(jnp.mean((fusion.fuse(prob, state, xt, rule) - yt) ** 2))
        elif name == "centralized":
            e = float(jnp.mean((predict(cent, xt) - yt) ** 2))
        else:
            e = float(jnp.mean((fusion.fuse(prob, local_only(prob), xt, "single") - yt) ** 2))
        print(f"  {name:22s} {e:8.4f}")

    print()
    ascii_plot(xq[:, 0], preds)


if __name__ == "__main__":
    main()
