"""Batched serving example.

LM mode: prefill + greedy decode for any architecture, including the SSM
path whose state is O(1) in context length.

Field mode: B concurrent field-estimation workloads trained by the batched
SN-Train engine, with streaming measurement absorption and fused multi-field
query evaluation (the paper's algorithm as a throughput-oriented service).

Run:  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-370m --gen 32
      PYTHONPATH=src python examples/serve_batch.py --mode field --fields 64 --stream 64
"""

import argparse
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "field"])
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fields", type=int, default=64)
    ap.add_argument("--sensors", type=int, default=50)
    ap.add_argument("--sweeps", type=int, default=30)
    ap.add_argument("--stream", type=int, default=0)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--fusion", default="conn", choices=["conn", "knn"])
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--engine", default="plan", choices=["dense", "plan", "pallas"])
    args = ap.parse_args()

    if args.mode == "field":
        cmd = [
            sys.executable, "-m", "repro.launch.serve", "--mode", "field",
            "--fields", str(args.fields),
            "--sensors", str(args.sensors),
            "--sweeps", str(args.sweeps),
            "--stream", str(args.stream),
            "--queries", str(args.queries),
            "--fusion", args.fusion,
            "--k", str(args.k),
            "--engine", args.engine,
        ]
    else:
        cmd = [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", args.arch,
            "--variant", "full" if args.full else "smoke",
            "--batch", str(args.batch),
            "--prompt_len", str(args.prompt_len),
            "--gen", str(args.gen),
        ]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    raise SystemExit(subprocess.run(cmd, env=env, cwd=ROOT).returncode)


if __name__ == "__main__":
    main()
