"""Batched serving example: prefill + greedy decode for any architecture,
including the SSM path whose state is O(1) in context length.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-370m --gen 32
"""

import argparse
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch,
        "--variant", "full" if args.full else "smoke",
        "--batch", str(args.batch),
        "--prompt_len", str(args.prompt_len),
        "--gen", str(args.gen),
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    raise SystemExit(subprocess.run(cmd, env=env, cwd=ROOT).returncode)


if __name__ == "__main__":
    main()
