"""End-to-end training driver: a ~135M-param LM for a few hundred steps with
the paper's SOP-gossip data parallelism (or classic all-reduce).

This wraps repro.launch.train.  On real accelerators the full smollm-135m
config trains as-is; the CPU container defaults to the reduced smoke config
so a few hundred steps finish in minutes.  Pass --full for the real 135M.

Run (4 simulated replicas, a few hundred steps):
  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python examples/train_lm.py --steps 300 --dp_mode sop_gossip
"""

import argparse
import subprocess
import sys
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dp_mode", default="sop_gossip", choices=["allreduce", "sop_gossip"])
    ap.add_argument("--full", action="store_true", help="train the real 135M config")
    ap.add_argument("--ckpt_dir", default="")
    args = ap.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m",
        "--variant", "full" if args.full else "smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--dp_mode", args.dp_mode,
        "--log_every", "20",
    ]
    if args.ckpt_dir:
        cmd += ["--ckpt_dir", args.ckpt_dir]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    raise SystemExit(subprocess.run(cmd, env=env, cwd=ROOT).returncode)


if __name__ == "__main__":
    main()
