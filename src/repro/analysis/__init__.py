"""Static-analysis layer: mechanical proofs of the repo's invariants.

Three auditors, one CLI (``tools/audit.py``):

  * :mod:`repro.analysis.jaxpr_audit` — traces every registered public
    entry point with ``jax.make_jaxpr`` on canonical shapes and walks the
    ClosedJaxpr for implicit dtype casts, host callbacks, traced values
    leaking into static positions (the zero-recompile claims), and
    scatters that bypass the ``alive`` liveness gate.
  * :mod:`repro.analysis.compile_ledger` — the central registry of
    jitted programs and their declared compile-cache budgets; tests and
    ``launch/serve.py --churn`` consume it instead of hand-counting
    ``_cache_size`` deltas.
  * :mod:`repro.analysis.ast_lint` — repo-specific AST rules (no host
    syncs inside jitted bodies, ``alive`` parameters must be threaded,
    receipts must expose ``to_json``) with a checked-in baseline so any
    pre-existing finding is explicit, never silent.

Findings are keyed stably (:class:`repro.analysis.report.Finding`) so a
baseline file can pin them; the audit fails on NEW findings and on STALE
baseline entries, which makes the baseline shrink-only by construction.
"""

from .report import Finding, compare_with_baseline, load_baseline

__all__ = [
    "Finding",
    "compare_with_baseline",
    "load_baseline",
    "ast_lint",
    "compile_ledger",
    "jaxpr_audit",
]
