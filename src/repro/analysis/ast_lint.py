"""Repo-specific AST lint rules for ``src/repro``.

Three rules, each encoding a convention the runtime auditors cannot see
from a jaxpr alone:

``ast-host-sync``
    Inside a jit-compiled function body in ``core/`` or ``kernels/``
    (decorated with ``jax.jit`` / ``partial(jax.jit, ...)`` or wrapped
    module-level via ``name = jax.jit(fn, ...)``), no ``float(x)``,
    ``x.item()``, ``np.asarray(x)`` or ``np.array(x)``: each forces a
    trace-time concretization (a recompile per value) or a device sync.

``ast-alive-thread``
    Every public ``core/`` function that accepts an ``alive`` parameter
    must actually thread it onward — the name must be read somewhere
    beyond its ``alive is None`` default guard.  Accepting the mask and
    dropping it silently disables liveness gating for every caller.

``ast-receipt-json``
    Every ``*Receipt`` class in ``core/`` and ``launch/`` must expose a
    ``to_json`` method: receipts are the machine-readable audit trail
    (``WatchdogReceipt.to_json`` set the contract) and a receipt that
    cannot be serialized disappears from daemon health endpoints.

Pre-existing violations live in the checked-in baseline
(``tools/audit_baseline.json``) with a justification each; the audit
fails on anything new and on stale baseline entries (shrink-only).
"""

from __future__ import annotations

import ast
import os

from .report import Finding

_HOST_NP_FUNCS = {"asarray", "array"}


def _is_jit_decorator(node: ast.expr) -> bool:
    """True for jax.jit / jit / partial(jax.jit, ...) decorator shapes."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "jit":
            return True
        if isinstance(sub, ast.Name) and sub.id == "jit":
            return True
    return False


def _jit_wrapped_names(tree: ast.Module) -> set[str]:
    """Function names wrapped module-level: ``x = jax.jit(fn, ...)`` or
    ``x = jax.jit(partial(fn, ...), ...)``."""
    names: set[str] = set()
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not (isinstance(call, ast.Call) and _is_jit_decorator(call.func)):
            continue
        for arg in call.args[:1]:
            if isinstance(arg, ast.Name):
                names.add(arg.id)
            elif isinstance(arg, ast.Call):  # jax.jit(partial(fn, ...))
                for inner in arg.args[:1]:
                    if isinstance(inner, ast.Name):
                        names.add(inner.id)
    return names


def _np_aliases(tree: ast.Module) -> set[str]:
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    aliases.add(a.asname or "numpy")
    return aliases


def _host_sync_calls(fn: ast.FunctionDef, np_aliases: set[str]):
    """Yield (tag, lineno) for host-sync'ing calls inside ``fn``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name) and f.id == "float" and node.args:
            if not isinstance(node.args[0], ast.Constant):
                yield "float", node.lineno
        elif isinstance(f, ast.Attribute) and f.attr == "item":
            yield "item", node.lineno
        elif (
            isinstance(f, ast.Attribute)
            and isinstance(f.value, ast.Name)
            and f.value.id in np_aliases
            and f.attr in _HOST_NP_FUNCS
        ):
            yield f"np.{f.attr}", node.lineno


def _accepts_alive(fn: ast.FunctionDef) -> bool:
    args = fn.args
    every = (
        list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    )
    return any(a.arg == "alive" for a in every)


def _alive_threaded(fn: ast.FunctionDef) -> bool:
    """``alive`` is READ beyond its ``alive is (not) None`` default guard."""
    guard_reads = set()
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Compare)
            and len(node.ops) == 1
            and isinstance(node.ops[0], (ast.Is, ast.IsNot))
            and isinstance(node.left, ast.Name)
            and node.left.id == "alive"
        ):
            guard_reads.add(id(node.left))
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Name)
            and node.id == "alive"
            and isinstance(node.ctx, ast.Load)
            and id(node) not in guard_reads
        ):
            return True
    return False


def lint_file(path: str, repo_root: str) -> list[Finding]:
    rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)

    in_core = "/core/" in f"/{rel}"
    in_kernels = "/kernels/" in f"/{rel}"
    findings: list[Finding] = []
    np_aliases = _np_aliases(tree)
    wrapped = _jit_wrapped_names(tree)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            jitted = node.name in wrapped or any(
                _is_jit_decorator(d) for d in node.decorator_list
            )
            if jitted and (in_core or in_kernels):
                for tag, lineno in _host_sync_calls(node, np_aliases):
                    findings.append(Finding(
                        "ast-host-sync", f"{rel}:{node.name}", tag,
                        f"line {lineno}: {tag} on a value inside a "
                        "jit-compiled body — trace-time concretization "
                        "or a device sync",
                    ))
            if (
                in_core
                and not node.name.startswith("_")
                and isinstance(node, ast.FunctionDef)
                and _accepts_alive(node)
                and not _alive_threaded(node)
            ):
                findings.append(Finding(
                    "ast-alive-thread", f"{rel}:{node.name}", "",
                    f"line {node.lineno}: public function accepts "
                    "'alive' but never threads it into a call or "
                    "return — the liveness gate is dropped",
                ))
        elif isinstance(node, ast.ClassDef):
            if node.name.endswith("Receipt"):
                has = any(
                    (isinstance(b, ast.FunctionDef) and b.name == "to_json")
                    or (
                        isinstance(b, ast.Assign)
                        and any(
                            isinstance(t, ast.Name) and t.id == "to_json"
                            for t in b.targets
                        )
                    )
                    for b in node.body
                )
                if not has:
                    findings.append(Finding(
                        "ast-receipt-json", f"{rel}:{node.name}", "",
                        f"line {node.lineno}: receipt class without "
                        "to_json — unserializable audit trail",
                    ))
    # dedupe by key (one finding per rule x location x tag)
    return list({f.key: f for f in findings}.values())


def default_paths(repo_root: str) -> list[str]:
    """All lintable modules: core/, kernels/, launch/, analysis/."""
    out = []
    for sub in ("core", "kernels", "launch", "analysis"):
        d = os.path.join(repo_root, "src", "repro", sub)
        if not os.path.isdir(d):
            continue
        for name in sorted(os.listdir(d)):
            if name.endswith(".py"):
                out.append(os.path.join(d, name))
    return out


def lint_paths(
    paths: list[str] | None = None, repo_root: str | None = None
) -> list[Finding]:
    if repo_root is None:
        repo_root = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", "..")
        )
    if paths is None:
        paths = default_paths(repo_root)
    findings: list[Finding] = []
    for p in paths:
        findings += lint_file(p, repo_root)
    return findings
