"""Central registry of jitted programs and their compile-cache budgets.

Every ``jax.jit``-compiled program the repo ships is declared here once,
with the budget its caching behavior is allowed to exhibit:

  * ``FROZEN`` — one program per (shape, static-arg) configuration;
    after a warmup call, re-running with new *values* (fault rates, tau,
    beta, churn events, drill toggles) must compile NOTHING.  This is
    the "rates are traced operands" contract the jaxpr auditor proves
    statically (:mod:`repro.analysis.jaxpr_audit`) and tests pin
    dynamically through :func:`snapshot` / :meth:`CacheSnapshot.assert_within`.
  * ``BUCKETS`` — the query axis is padded to power-of-two buckets
    (``kernels.ops.bucket_rows``), so a serving process with arbitrary
    request sizes compiles at most one program per distinct bucket:
    O(log Q) total, bounded by the caller-supplied bucket count.

Consumers (tests, ``launch/serve.py --churn``, benchmarks) take a
:func:`snapshot` of the entries they exercise, do their work, then call
:meth:`CacheSnapshot.assert_within` (or read :meth:`CacheSnapshot.growth`)
— replacing the hand-rolled ``warm = f._cache_size()`` arithmetic that
used to be copy-pasted per test file.  ``tools/audit.py`` verifies every
entry still resolves to a jit-compiled callable.

This ledger is the gate for the ROADMAP's hierarchical-topology
scale-up: cluster-tier consensus must land as new FROZEN entries here
(and pass the jaxpr audit) before it can claim the zero-recompile
property the flat engines already prove.
"""

from __future__ import annotations

import dataclasses
import importlib
from collections.abc import Iterable

from .report import Finding

FROZEN = "frozen"
BUCKETS = "buckets"


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """One jitted program: ``target`` is ``"module.path:attribute"``."""

    name: str
    target: str
    budget: str
    note: str = ""

    def resolve(self):
        mod, _, attr = self.target.partition(":")
        return getattr(importlib.import_module(mod), attr)


def _entries() -> list[LedgerEntry]:
    e = LedgerEntry
    C = "repro.core."
    return [
        # --- training sweeps: one program per engine x shape x n_sweeps
        e("sweep.serial", C + "sn_train:serial_sweep", FROZEN),
        e("sweep.colored", C + "sn_train:colored_sweep", FROZEN),
        e("sweep.random", C + "sn_train:random_sweep", FROZEN),
        e("sweep.weighted", C + "sn_train:weighted_sweep", FROZEN),
        e("sweep.robust_links", C + "sn_train:robust_sweep_links", FROZEN),
        e("sweep.robust_colored", C + "sn_train:_robust_colored", FROZEN,
          "alive trace + delivered masks are traced operands"),
        # --- fault-injected sweeps: rates are traced, structure static
        e("faults.colored", C + "faults:_faulty_colored", FROZEN,
          "one program serves the whole drop/burst rate grid"),
        e("faults.serial", C + "faults:_faulty_serial", FROZEN),
        e("faults.robust", C + "faults:_faulty_robust", FROZEN),
        # --- serving: O(log Q) bucketed programs on the query axis
        e("serving.select", C + "serving:knn_select_valid", BUCKETS),
        e("serving.eval", C + "serving:_eval_selected", BUCKETS),
        e("serving.knn_kernel",
          "repro.kernels.knn_fuse:knn_fuse_pallas", BUCKETS),
        e("serving.matvec",
          "repro.kernels.kernel_matvec:kernel_matvec_pallas", BUCKETS),
        e("serving.matvec_batched",
          "repro.kernels.kernel_matvec:kernel_matvec_batched_pallas",
          BUCKETS),
        e("serving.plan_add", C + "serving:plan_add_sensor", FROZEN),
        e("serving.plan_remove", C + "serving:plan_remove_sensor", FROZEN),
        # --- pruning: tau is a traced operand
        e("pruning.energy", C + "pruning:_lane_energy", FROZEN),
        e("pruning.keep", C + "pruning:_keep_mask", FROZEN,
          "sweeping tau compiles nothing after warmup"),
        # --- fusion / monitoring / kernels
        e("fusion.eval_all", C + "fusion:_eval_all", FROZEN),
        e("monitor.metrics", C + "monitor:_round_metrics", FROZEN),
        e("kernels.color_step",
          "repro.kernels.color_step:color_step_pallas", FROZEN),
        # --- streaming absorb / evict / churn (copy + donated variants)
        e("stream.absorb.copy", C + "streaming:_absorb_copy", FROZEN),
        e("stream.absorb.donate", C + "streaming:_absorb_donate", FROZEN),
        e("stream.absorb_evict.copy",
          C + "streaming:_absorb_evict_copy", FROZEN),
        e("stream.absorb_evict.donate",
          C + "streaming:_absorb_evict_donate", FROZEN),
        e("stream.absorb_many.drop.copy",
          C + "streaming:_absorb_many_drop_copy", FROZEN),
        e("stream.absorb_many.drop.donate",
          C + "streaming:_absorb_many_drop_donate", FROZEN),
        e("stream.absorb_many.evict.copy",
          C + "streaming:_absorb_many_evict_copy", FROZEN),
        e("stream.absorb_many.evict.donate",
          C + "streaming:_absorb_many_evict_donate", FROZEN),
        e("stream.wave.drop.copy",
          C + "streaming:_absorb_wave_drop_copy", FROZEN),
        e("stream.wave.drop.donate",
          C + "streaming:_absorb_wave_drop_donate", FROZEN),
        e("stream.wave.evict.copy",
          C + "streaming:_absorb_wave_evict_copy", FROZEN),
        e("stream.wave.evict.donate",
          C + "streaming:_absorb_wave_evict_donate", FROZEN),
        e("stream.evict.copy", C + "streaming:_evict_jit", FROZEN),
        e("stream.evict.donate", C + "streaming:_evict_donate", FROZEN),
        e("stream.add.copy", C + "streaming:_add_sensor_copy", FROZEN),
        e("stream.add.donate", C + "streaming:_add_sensor_donate", FROZEN),
        e("stream.remove.copy", C + "streaming:_remove_sensor_copy", FROZEN),
        e("stream.remove.donate",
          C + "streaming:_remove_sensor_donate", FROZEN),
        # --- daemon
        e("daemon.ecoef", "repro.launch.daemon:_ecoef_jit", FROZEN),
    ]


LEDGER: dict[str, LedgerEntry] = {x.name: x for x in _entries()}

# Named groups matching the repo's cache-pinning consumers.
GROUPS: dict[str, tuple[str, ...]] = {
    # the daemon's serving path: programs grow only with new buckets
    "daemon": ("serving.select", "serving.eval"),
    # fault drills: toggling rates on/off reuses compiled programs
    "faults": ("faults.colored",),
    # quantized serving: tau sweep + bucket reuse compile nothing
    "quant": ("serving.knn_kernel", "serving.select", "serving.eval",
              "pruning.keep"),
}


def churn_group(*, on_full: str = "drop", donate: bool = True) -> tuple[str, ...]:
    """The program set one churn round exercises (join + leave + absorb +
    refresh sweep + plan repairs + serving select)."""
    v = "donate" if donate else "copy"
    policy = "evict" if on_full == "evict" else "drop"
    return (
        f"stream.add.{v}",
        f"stream.remove.{v}",
        f"stream.absorb_many.{policy}.{v}",
        "sweep.colored",
        "serving.select",
        "serving.plan_add",
        "serving.plan_remove",
    )


def _resolve_names(names: str | Iterable[str]) -> tuple[str, ...]:
    if isinstance(names, str):
        names = GROUPS[names]
    names = tuple(names)
    unknown = [n for n in names if n not in LEDGER]
    if unknown:
        raise KeyError(f"not in the compile ledger: {unknown}")
    return names


def cache_size(name: str) -> int:
    return LEDGER[name].resolve()._cache_size()


class CacheSnapshot:
    """Warm-point cache sizes for a set of ledger entries."""

    def __init__(self, names: tuple[str, ...]):
        self.names = names
        self._base = {n: cache_size(n) for n in names}

    def growth(self) -> dict[str, int]:
        """Programs compiled per entry since the snapshot."""
        return {n: cache_size(n) - self._base[n] for n in self.names}

    def total_growth(self) -> int:
        return sum(self.growth().values())

    def assert_within(self, buckets: int | None = None, context: str = ""):
        """Enforce each entry's declared budget since the snapshot.

        FROZEN entries must not have compiled anything; BUCKETS entries
        may have compiled at most ``buckets`` programs (the number of
        distinct power-of-two query buckets exercised — pass 0 after a
        warmup that already covered them).  Returns the growth dict so
        callers can report it.
        """
        growth = self.growth()
        for name, grown in growth.items():
            budget = LEDGER[name].budget
            if budget == FROZEN:
                limit = 0
            else:
                if buckets is None:
                    raise ValueError(
                        f"{name} is bucket-budgeted: pass buckets= "
                        "(the distinct query buckets exercised)"
                    )
                limit = buckets
            assert grown <= limit, (
                f"compile budget exceeded{' (' + context + ')' if context else ''}: "
                f"{name} [{budget}] compiled {grown} new program(s), "
                f"budget {limit}"
            )
        return growth


def snapshot(names: str | Iterable[str]) -> CacheSnapshot:
    """Snapshot cache sizes for a group name or iterable of entry names."""
    return CacheSnapshot(_resolve_names(names))


def audit() -> list[Finding]:
    """Ledger self-check: every entry resolves to a jit-compiled callable
    with a countable cache, budgets are valid, groups reference entries."""
    findings = []
    for name, entry in LEDGER.items():
        if entry.budget not in (FROZEN, BUCKETS):
            findings.append(Finding(
                "ledger", name, "budget", f"unknown budget {entry.budget!r}"
            ))
        try:
            fn = entry.resolve()
        except (ImportError, AttributeError) as exc:
            findings.append(Finding(
                "ledger", name, "resolve", f"{entry.target}: {exc}"
            ))
            continue
        if not callable(getattr(fn, "_cache_size", None)):
            findings.append(Finding(
                "ledger", name, "interface",
                f"{entry.target} is not a jit-compiled callable "
                "(no _cache_size)",
            ))
    for group, names in GROUPS.items():
        for n in names:
            if n not in LEDGER:
                findings.append(Finding(
                    "ledger", f"group:{group}", n, "group names unknown entry"
                ))
    for kwargs in (dict(on_full="drop", donate=True),
                   dict(on_full="evict", donate=True),
                   dict(on_full="drop", donate=False),
                   dict(on_full="evict", donate=False)):
        for n in churn_group(**kwargs):
            if n not in LEDGER:
                findings.append(Finding(
                    "ledger", "group:churn", n, "group names unknown entry"
                ))
    return findings
