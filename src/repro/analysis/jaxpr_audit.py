"""Jaxpr-level static auditor for the repo's public entry points.

Every registered entry point is traced with ``jax.make_jaxpr`` on a
canonical tiny problem (tracing compiles nothing and runs nothing) and
the resulting ClosedJaxpr is walked — recursively through ``scan`` /
``while`` / ``cond`` / ``pjit`` / ``pallas_call`` sub-jaxprs — for four
violation classes:

``host-sync``
    A host-callback / debug primitive inside a traced hot path
    (``pure_callback``, ``io_callback``, ``debug_print``, ...): each one
    is a device->host round trip per step.

``dtype-narrow`` / ``weak-promo``
    An implicit ``convert_element_type`` between float dtypes.  Narrowing
    (f64 -> f32 on an x64 problem, f32 -> f16 anywhere) silently truncates
    precision; widening above the problem dtype (f32 -> f64 under
    JAX_ENABLE_X64) is Python-scalar / NumPy-scalar contamination — a
    strong float64 constant leaked into f32 arithmetic.  Weak-typed
    operands are exempt (a weak ``0.0`` adapting to the array dtype is
    JAX's intended semantics).  Entries may declare ``allow_dtypes`` for
    intentional storage casts (the bf16 quantized-serving anchors are
    storage-only by contract).

``const-leak`` / ``grid-recompile``
    The zero-recompile claims, proven statically.  A swept parameter
    (fault rate, pruning ``tau``, forgetting ``beta``) is traced as a
    function INPUT; the check fails if tracing concretizes it (a
    ``float()`` / ``if rate:`` on the traced value), if the parameter is
    dead in the jaxpr (its value was baked into a static position or
    closure constant), or if a sentinel grid value shows up as a jaxpr
    literal.  ``grid-recompile`` additionally compares the jit cache
    signature — pytree structure + abstract values — of the full call
    across a grid of parameter values: equal signatures mean ONE compiled
    program serves the whole grid, without executing a sweep.

``alive-dead`` / ``alive-scatter``
    Liveness-gate threading.  The entry's liveness mask is tainted and
    the taint is propagated through the jaxpr (with fixpoints over scan /
    while carries): if no output depends on the mask, the gate was
    dropped (``alive-dead``); if a scatter-family write's indices AND
    updates are both untainted, a table write bypasses the gate
    (``alive-scatter``) — dead rows could be written as if alive.
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Callable

import numpy as np

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.x exposes the stable jaxpr types here
    from jax.extend.core import ClosedJaxpr, Jaxpr, Literal
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr, Literal

from .report import Finding

# Distinctive sentinel for the swept-parameter checks: if this value is
# found baked into a jaxpr literal/const, the parameter leaked out of the
# traced operand position.
MAGIC = 0.6180339887498949

HOST_SYNC_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "host_callback_call", "outside_call", "infeed", "outfeed",
})

# Value-level write primitives into fixed-shape tables.  invars[0] is the
# written-into operand; the gate must reach the indices or the updates.
SCATTER_PRIMITIVES = frozenset({
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
    "dynamic_update_slice",
})


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _jaxprs_of(v):
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _jaxprs_of(x)


def iter_eqns(jaxpr: Jaxpr):
    """All eqns of ``jaxpr`` and (recursively) of every sub-jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _jaxprs_of(v):
                yield from iter_eqns(sub)


# ---------------------------------------------------------------------------
# taint propagation
# ---------------------------------------------------------------------------


def _taint(jaxpr: Jaxpr, in_taint, on_eqn=None):
    """Forward data-flow: which jaxpr outputs depend on tainted invars.

    ``on_eqn(eqn, input_taints)`` is called once per eqn (after loop
    carries reach their fixpoint, so a write gated through the carry is
    never misreported as untainted).
    """
    env: dict = {}
    for v, t in zip(jaxpr.invars, in_taint):
        env[v] = env.get(v, False) or bool(t)
    for v in jaxpr.constvars:
        env.setdefault(v, False)

    def read(a):
        return False if isinstance(a, Literal) else env.get(a, False)

    for eqn in jaxpr.eqns:
        ts = [read(x) for x in eqn.invars]
        if on_eqn is not None:
            on_eqn(eqn, ts)
        out_ts = _eqn_taint(eqn, ts, on_eqn)
        if out_ts is None or len(out_ts) != len(eqn.outvars):
            out_ts = [any(ts)] * len(eqn.outvars)
        for v, t in zip(eqn.outvars, out_ts):
            env[v] = bool(t)
    return [read(v) for v in jaxpr.outvars]


def _eqn_taint(eqn, ts, on_eqn):
    name = eqn.primitive.name
    params = eqn.params
    if name == "scan":
        inner = params["jaxpr"].jaxpr
        nc, ncar = params["num_consts"], params["num_carry"]
        consts, carry, xs = ts[:nc], ts[nc:nc + ncar], ts[nc + ncar:]
        for _ in range(ncar + 2):  # carry-feedback fixpoint
            res = _taint(inner, consts + carry + xs)
            new_carry = [a or b for a, b in zip(carry, res[:ncar])]
            if new_carry == carry:
                break
            carry = new_carry
        res = _taint(inner, consts + carry + xs, on_eqn)
        return [a or b for a, b in zip(carry, res[:ncar])] + res[ncar:]
    if name == "while":
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        body = params["body_jaxpr"].jaxpr
        cconsts, bconsts, carry = ts[:cn], ts[cn:cn + bn], ts[cn + bn:]
        for _ in range(len(carry) + 2):
            res = _taint(body, bconsts + carry)
            new_carry = [a or b for a, b in zip(carry, res)]
            if new_carry == carry:
                break
            carry = new_carry
        _taint(body, bconsts + carry, on_eqn)
        _taint(params["cond_jaxpr"].jaxpr, cconsts + carry, on_eqn)
        return carry
    if name == "cond":
        outs = [
            _taint(br.jaxpr, ts[1:], on_eqn) for br in params["branches"]
        ]
        return [ts[0] or any(col) for col in zip(*outs)]
    if name == "pallas_call":
        inner = params.get("jaxpr")
        if inner is not None:
            ij = inner.jaxpr if isinstance(inner, ClosedJaxpr) else inner
            k = len(ij.invars)
            # kernel invars are [input refs..., output refs..., scratch]
            _taint(ij, (ts + [False] * k)[:k], on_eqn)
        return None  # conservative: any(ts) on all outputs
    for key in ("jaxpr", "call_jaxpr"):  # pjit / remat / custom_* / shard_map
        sub = params.get(key)
        if isinstance(sub, (Jaxpr, ClosedJaxpr)):
            ij = sub.jaxpr if isinstance(sub, ClosedJaxpr) else sub
            if len(ij.invars) == len(ts):
                return _taint(ij, ts, on_eqn)
    return None


# ---------------------------------------------------------------------------
# the checks
# ---------------------------------------------------------------------------


def _check_host_sync(name: str, closed: ClosedJaxpr) -> list[Finding]:
    out = []
    for eqn in iter_eqns(closed.jaxpr):
        if eqn.primitive.name in HOST_SYNC_PRIMITIVES:
            out.append(Finding(
                "host-sync", name, eqn.primitive.name,
                "host callback primitive in a traced hot path "
                "(one device->host round trip per execution)",
            ))
    return out


def _check_dtype(
    name: str, closed: ClosedJaxpr, trace_dtype, allow: frozenset
) -> list[Finding]:
    out = []
    width = np.dtype(trace_dtype).itemsize
    for eqn in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        aval = eqn.invars[0].aval
        old = np.dtype(aval.dtype)
        new = np.dtype(eqn.params["new_dtype"])
        if old.kind != "f" or new.kind != "f" or old == new:
            continue
        if {old.name, new.name} & allow:
            continue
        # Weak-typed operands (Python-scalar literals like ``0.0`` /
        # ``jnp.inf``) adapt to the array dtype BY DESIGN — that convert
        # is JAX's intended promotion semantics, not contamination.  Only
        # strong wider floats (np.float64 scalars, default-dtype arrays
        # under x64) are findings.
        if getattr(aval, "weak_type", False):
            continue
        if new.itemsize < old.itemsize:
            out.append(Finding(
                "dtype-narrow", name, f"{old.name}->{new.name}",
                f"implicit float narrowing inside the {trace_dtype} trace "
                "— values are silently truncated",
            ))
        elif new.itemsize > width:
            out.append(Finding(
                "weak-promo", name, f"{old.name}->{new.name}",
                f"promotion above the {trace_dtype} problem dtype — a "
                "strong wider-float scalar (np.float64 / pinned literal) "
                "contaminated the arithmetic",
            ))
    return out


def _check_alive(name: str, built, do_scatter: bool) -> list[Finding]:
    fn, args = built.alive
    closed = jax.make_jaxpr(fn)(*args)
    in_t = [i == 0 for i in range(len(closed.jaxpr.invars))]
    findings: list[Finding] = []

    def on_eqn(eqn, ts):
        if (
            do_scatter
            and eqn.primitive.name in SCATTER_PRIMITIVES
            and not any(ts[1:])
        ):
            findings.append(Finding(
                "alive-scatter", name, eqn.primitive.name,
                "table write whose indices and updates are both "
                "independent of the liveness mask — dead rows can be "
                "written as if alive",
            ))

    out_t = _taint(closed.jaxpr, in_t, on_eqn)
    if not any(out_t):
        findings.append(Finding(
            "alive-dead", name, "",
            "no output depends on the liveness mask — the alive gate "
            "is accepted but dropped",
        ))
    return findings


def _is_magic(x) -> bool:
    try:
        arr = np.asarray(x)
    except (TypeError, ValueError):
        return False
    return (
        arr.size >= 1
        and arr.dtype.kind == "f"
        and bool(np.any(np.abs(arr.astype(np.float64) - MAGIC) < 1e-6))
    )


def _check_param(name: str, built) -> list[Finding]:
    try:
        closed = jax.make_jaxpr(built.param)(MAGIC)
    except Exception as exc:  # concretization / static-position errors
        return [Finding(
            "const-leak", name, "untraceable",
            f"tracing with an abstract parameter failed — the value is "
            f"concretized or static, so every grid point recompiles "
            f"({type(exc).__name__}: {str(exc)[:200]})",
        )]
    findings = []
    in_t = [i == 0 for i in range(len(closed.jaxpr.invars))]
    if not any(_taint(closed.jaxpr, in_t)):
        findings.append(Finding(
            "const-leak", name, "dead-param",
            "the swept parameter does not influence any output — its "
            "value was baked in elsewhere (closure constant or static "
            "argument), so the sweep result is stale or recompiles",
        ))
    for eqn in iter_eqns(closed.jaxpr):
        for v in eqn.invars:
            if isinstance(v, Literal) and _is_magic(v.val):
                findings.append(Finding(
                    "const-leak", name, "baked-literal",
                    "the sentinel parameter value appears as a jaxpr "
                    "literal — it was constant-folded instead of traced",
                ))
                return findings
    for c in closed.consts:
        if _is_magic(c):
            findings.append(Finding(
                "const-leak", name, "baked-const",
                "the sentinel parameter value appears as a jaxpr "
                "constant — it was closed over instead of traced",
            ))
            break
    return findings


def _leaf_sig(leaf):
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return (
            tuple(leaf.shape), str(leaf.dtype),
            bool(getattr(leaf, "weak_type", False)),
        )
    return ("weak-pyscalar", type(leaf).__name__)


def _check_grid(name: str, built) -> list[Finding]:
    sigs = []
    for v in built.grid:
        args = built.build_call(v)
        leaves, treedef = jax.tree_util.tree_flatten(args)
        sigs.append((str(treedef), tuple(_leaf_sig(x) for x in leaves)))
    bad = [v for v, s in zip(built.grid, sigs) if s != sigs[0]]
    if bad:
        return [Finding(
            "grid-recompile", name, "",
            f"jit cache signature (pytree structure + avals) changes "
            f"across the value grid at {bad} — each such value compiles "
            f"a separate program",
        )]
    return []


# ---------------------------------------------------------------------------
# entry registry
# ---------------------------------------------------------------------------


class Built:
    """Concrete audit material for one entry point.

    fn/args:     canonical call, traced for host-sync + dtype checks.
    alive:       (fn, args) with the liveness mask as argument 0.
    param:       fn(scalar) for the traced-parameter (const-leak) check.
    grid +
    build_call:  values and v -> call-args-pytree for the one-program
                 cache-signature check.
    """

    def __init__(self, fn=None, args=(), alive=None, param=None,
                 grid=None, build_call=None):
        self.fn, self.args = fn, args
        self.alive = alive
        self.param = param
        self.grid = grid
        self.build_call = build_call


@dataclasses.dataclass
class EntrySpec:
    name: str
    build: Callable[[], Built]
    checks: tuple[str, ...] = ("host-sync", "dtype")
    allow_dtypes: frozenset = frozenset()


def audit_entry(spec: EntrySpec, trace_dtype="float32") -> list[Finding]:
    """Run the spec's checks; findings are deduped by key."""
    built = spec.build()
    findings: list[Finding] = []
    if built.fn is not None and (
        "host-sync" in spec.checks or "dtype" in spec.checks
    ):
        closed = jax.make_jaxpr(built.fn)(*built.args)
        if "host-sync" in spec.checks:
            findings += _check_host_sync(spec.name, closed)
        if "dtype" in spec.checks:
            findings += _check_dtype(
                spec.name, closed, trace_dtype, spec.allow_dtypes
            )
    if built.alive is not None and "alive" in spec.checks:
        findings += _check_alive(
            spec.name, built, do_scatter="alive-scatter" in spec.checks
        )
    if built.param is not None and "param" in spec.checks:
        findings += _check_param(spec.name, built)
    if built.grid is not None and "param" in spec.checks:
        findings += _check_grid(spec.name, built)
    return list({f.key: f for f in findings}.values())


def run_entries(
    entries: list[EntrySpec], trace_dtype="float32"
) -> list[Finding]:
    findings = []
    for spec in entries:
        findings += audit_entry(spec, trace_dtype=trace_dtype)
    return findings


# --- canonical fixture -----------------------------------------------------


@functools.lru_cache(maxsize=4)
def _fixture(dtype_name: str):
    """Tiny canonical problems (batched + single-field), built once per
    dtype.  Only traced — never executed — so size is irrelevant beyond
    exercising every code path (streaming slots, spare rows, coloring)."""
    from types import SimpleNamespace

    from repro.core import (
        Kernel, build_topology, init_state, make_batch_problem,
        make_problem, make_serving_plan, uniform_sensors,
    )

    n, b = 12, 2
    # Dtype-consistent canonical shapes: positions in the trace dtype so
    # churn ops don't round-trip through a mixed-precision topology.
    pos = np.asarray(uniform_sensors(n, seed=0)).astype(dtype_name)
    rng = np.random.default_rng(1)
    ys = (
        np.sin(np.pi * pos[None, :, 0] * np.array([[1.0], [1.7]]))
        + 0.1 * rng.normal(size=(b, n))
    ).astype(dtype_name)
    topo = build_topology(pos, 0.7)
    d_max = int(np.asarray(topo.degrees).max()) + 3
    topo = build_topology(pos, 0.7, d_max=d_max, n_max=n + 2)
    kern = Kernel("rbf", gamma=1.0)
    lam = jnp.full((n,), 0.1, dtype_name)
    prob = make_batch_problem(
        topo, kern, ys, lam, dtype=jnp.dtype(dtype_name), beta=0.9
    )
    sprob = make_problem(
        topo, kern, jnp.asarray(ys[0]), lam, dtype=jnp.dtype(dtype_name)
    )
    fx = SimpleNamespace(
        prob=prob, state=init_state(prob),
        sprob=sprob, sstate=init_state(sprob),
        plan=make_serving_plan(prob, k=2),
        xq=jnp.asarray(
            rng.uniform(-0.9, 0.9, size=(8, 1)), jnp.dtype(dtype_name)
        ),
        key=jax.random.PRNGKey(0),
        dtype=jnp.dtype(dtype_name),
    )
    return fx


def _replace_alive(problem, alive):
    return dataclasses.replace(problem, alive=alive)


def default_entries(dtype_name: str = "float32") -> list[EntrySpec]:
    """The registered public entry points, audited on canonical shapes."""
    import repro.core.faults as faults
    import repro.core.fusion as fusion
    import repro.core.monitor as monitor
    import repro.core.pruning as pruning
    import repro.core.serving as serving
    import repro.core.streaming as streaming
    from repro.core import (
        SNTrainState, colored_sweep, random_sweep, robust_sweep,
        robust_sweep_links, serial_sweep, sharded_sweep, weighted_sweep,
    )
    from repro.kernels import kernel_matvec

    fx = _fixture(dtype_name)
    # Sweep engines carry the scatter-level contract (every z/coef write
    # redirects through the liveness sentinel); streaming/churn ops gate
    # their FINAL state writes on alive but legitimately build temporary
    # factors with alive-independent scatters, so they get the
    # output-taint check only.
    SWEEP = ("host-sync", "dtype", "alive", "alive-scatter")
    STREAM = ("host-sync", "dtype", "alive")
    FULL = SWEEP + ("param",)

    def sweep_entry(name, call, **kw):
        def build():
            def f(alive, z, coef):
                return call(
                    _replace_alive(fx.prob, alive), SNTrainState(z, coef)
                )
            args = (fx.prob.alive, fx.state.z, fx.state.coef)
            return Built(fn=f, args=args, alive=(f, args))
        return EntrySpec(name, build, checks=kw.pop("checks", SWEEP), **kw)

    def simple_entry(name, build_fn_args, checks=("host-sync", "dtype"),
                     **kw):
        def build():
            fn, args = build_fn_args()
            return Built(fn=fn, args=args)
        return EntrySpec(name, build, checks=checks, **kw)

    entries = [
        sweep_entry(
            "sweep.serial", lambda p, s: serial_sweep(p, s, n_sweeps=2)
        ),
    ]
    for engine in ("plan", "onehot", "pallas"):
        entries.append(sweep_entry(
            f"sweep.colored.{engine}",
            lambda p, s, e=engine: colored_sweep(p, s, 2, engine=e),
        ))

    def build_sharded():
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]), ("sensors",)
        )
        def f(alive, z, coef):
            return sharded_sweep(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                mesh, n_sweeps=2,
            )
        args = (fx.prob.alive, fx.state.z, fx.state.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("sweep.sharded.plan", build_sharded, SWEEP))

    def build_random():
        def f(alive, z, coef, key):
            return random_sweep(
                _replace_alive(fx.sprob, alive), SNTrainState(z, coef),
                key, n_sweeps=2,
            )
        args = (fx.sprob.alive, fx.sstate.z, fx.sstate.coef, fx.key)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("sweep.random", build_random, SWEEP))

    def build_weighted():
        w = jnp.full((fx.sprob.n,), 2.0, fx.dtype)
        def f(alive, z, coef):
            return weighted_sweep(
                _replace_alive(fx.sprob, alive), SNTrainState(z, coef),
                w, n_sweeps=2,
            )
        args = (fx.sprob.alive, fx.sstate.z, fx.sstate.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("sweep.weighted", build_weighted, SWEEP))

    def build_robust():
        alive_tn = jnp.ones((2, fx.prob.n), bool)
        def f(a, z, coef):
            return robust_sweep(
                fx.prob, SNTrainState(z, coef), a, n_sweeps=2,
                engine="plan",
            )
        args = (alive_tn, fx.state.z, fx.state.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("sweep.robust", build_robust, SWEEP))

    def build_robust_links():
        d_max = fx.sprob.nbr_idx.shape[-1]
        links = jnp.ones((2, fx.sprob.n, d_max), bool)
        def f(a, z, coef):
            return robust_sweep_links(
                fx.sprob, SNTrainState(z, coef), a, n_sweeps=2
            )
        args = (links, fx.sstate.z, fx.sstate.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("sweep.robust_links", build_robust_links, SWEEP))

    # fault-injected sweeps: rate grid must be one program
    def build_faulty(engine, crash):
        def build():
            mk = lambda r: faults.make_fault_model(
                r, burst=(0.05, 0.5, 0.3), crash=crash,
                dtype=fx.dtype,
            )
            def f(alive, z, coef, r):
                return faults.faulty_sweep(
                    _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                    mk(r), fx.key, n_sweeps=2, engine=engine,
                )
            args = (
                fx.prob.alive, fx.state.z, fx.state.coef,
                jnp.asarray(0.1, fx.dtype),
            )
            return Built(
                fn=f, args=args, alive=(f, args),
                param=lambda r: faults.faulty_sweep(
                    fx.prob, fx.state, mk(r), fx.key, n_sweeps=2,
                    engine=engine,
                ),
                grid=(0.0, 0.1, MAGIC, 0.9),
                build_call=lambda v: (fx.prob, fx.state, mk(v), fx.key),
            )
        return build
    for engine in ("plan", "serial", "pallas"):
        entries.append(EntrySpec(
            f"faults.{engine}", build_faulty(engine, None), FULL
        ))
    entries.append(EntrySpec(
        "faults.crash", build_faulty("plan", (0.1, 0.5)), FULL
    ))

    # streaming: absorb (beta grid must be one program), windows, churn
    def build_absorb():
        x = fx.xq[0]
        y = jnp.asarray(0.3, fx.dtype)
        def with_beta(bv):
            beta = jnp.broadcast_to(
                jnp.asarray(bv, fx.prob.beta.dtype), fx.prob.beta.shape
            )
            return dataclasses.replace(fx.prob, beta=beta)
        def f(alive, z, coef):
            return streaming.absorb(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                0, 3, x, y,
            )
        args = (fx.prob.alive, fx.state.z, fx.state.coef)
        return Built(
            fn=f, args=args, alive=(f, args),
            param=lambda bv: streaming.absorb(
                with_beta(bv), fx.state, 0, 3, x, y
            ),
            grid=(1.0, MAGIC, 0.5),
            build_call=lambda v: (with_beta(v), fx.state, 0, 3, x, y),
        )
    entries.append(EntrySpec(
        "stream.absorb", build_absorb, STREAM + ("param",)
    ))

    def build_absorb_many():
        a = 3
        fields = jnp.zeros((a,), jnp.int32)
        sensors = jnp.arange(a, dtype=jnp.int32)
        xs = jnp.broadcast_to(fx.xq[0], (a,) + fx.xq[0].shape)
        ys = jnp.full((a,), 0.2, fx.dtype)
        def f(alive, z, coef):
            return streaming.absorb_many(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                fields, sensors, xs, ys,
            )
        args = (fx.prob.alive, fx.state.z, fx.state.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec(
        "stream.absorb_many", build_absorb_many, STREAM
    ))

    def build_add():
        x = jnp.asarray(np.array([0.05]), fx.dtype)
        ys = jnp.full((fx.prob.batch_size,), 0.1, fx.dtype)
        def f(alive, z, coef):
            return streaming.add_sensor(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                x, ys, lam=0.1,
            )
        args = (fx.prob.alive, fx.state.z, fx.state.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("stream.add_sensor", build_add, STREAM))

    def build_remove():
        def f(alive, z, coef):
            return streaming.remove_sensor(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef), 2
            )
        args = (fx.prob.alive, fx.state.z, fx.state.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("stream.remove_sensor", build_remove, STREAM))

    def build_evict():
        def f(alive, z, coef):
            return streaming.evict_oldest(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef), 0, 3
            )
        args = (fx.prob.alive, fx.state.z, fx.state.coef)
        return Built(fn=f, args=args, alive=(f, args))
    entries.append(EntrySpec("stream.evict_oldest", build_evict, STREAM))

    # serving / fusion: alive gates selection; tau grid is one program
    def build_fuse(engine, compute_dtype=None):
        def build():
            def f(alive, z, coef):
                return fusion.fuse(
                    _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                    fx.xq, "knn", k=2, engine=engine,
                    plan=None if engine == "dense" else fx.plan,
                    compute_dtype=compute_dtype,
                )
            args = (fx.prob.alive, fx.state.z, fx.state.coef)
            return Built(fn=f, args=args, alive=(f, args))
        return build
    entries.append(EntrySpec(
        "serving.knn.plan", build_fuse("plan"),
        ("host-sync", "dtype", "alive"),
    ))
    entries.append(EntrySpec(
        "serving.knn.pallas", build_fuse("pallas"),
        ("host-sync", "dtype", "alive"),
    ))
    entries.append(EntrySpec(
        "serving.knn.quant", build_fuse("pallas", "bfloat16"),
        ("host-sync", "dtype", "alive"),
        allow_dtypes=frozenset({"bfloat16"}),
    ))
    entries.append(EntrySpec(
        "fusion.dense", build_fuse("dense"), ("host-sync", "dtype", "alive"),
    ))

    def build_prune():
        def f(alive, z, coef, tau):
            return pruning.prune_mask(
                _replace_alive(fx.prob, alive), SNTrainState(z, coef),
                energy_tau=tau,
            )
        args = (
            fx.prob.alive, fx.state.z, fx.state.coef,
            jnp.asarray(0.05, fx.dtype),
        )
        return Built(
            fn=f, args=args, alive=(f, args),
            param=lambda t: pruning.prune_mask(
                fx.prob, fx.state, energy_tau=t
            ),
            grid=(0.0, MAGIC, 0.3),
            build_call=lambda v: (
                fx.prob.nbr_mask, fx.prob.alive, fx.state.coef,
                jnp.asarray(v, fx.dtype),
            ),
        )
    entries.append(EntrySpec(
        "pruning.keep", build_prune, ("host-sync", "dtype", "alive", "param")
    ))

    def build_plan_add():
        x = jnp.asarray(np.array([0.05]), fx.plan.centers.dtype)
        return (
            lambda plan_cells, plan_mask: serving.plan_add_sensor(
                dataclasses.replace(
                    fx.plan, cells=plan_cells, cell_mask=plan_mask
                ),
                x, jnp.asarray(5, jnp.int32),
            ),
            (fx.plan.cells, fx.plan.cell_mask),
        )
    entries.append(simple_entry("serving.plan_add", build_plan_add))

    def build_plan_remove():
        return (
            lambda cells, mask: serving.plan_remove_sensor(
                dataclasses.replace(fx.plan, cells=cells, cell_mask=mask),
                jnp.asarray(5, jnp.int32),
            ),
            (fx.plan.cells, fx.plan.cell_mask),
        )
    entries.append(simple_entry("serving.plan_remove", build_plan_remove))

    def build_matvec():
        anchors = jnp.asarray(
            np.linspace(-1, 1, 10)[:, None], fx.dtype
        )
        coef = jnp.full((10,), 0.1, fx.dtype)
        return (
            lambda xq, an, cf: kernel_matvec(xq, an, cf, gamma=1.0),
            (fx.xq, anchors, coef),
        )
    # The Pallas matvec computes in float32 by contract (serving fast
    # path); on an f64 problem the input casts are intentional.
    entries.append(simple_entry(
        "kernels.matvec", build_matvec,
        allow_dtypes=frozenset({"float32"}),
    ))

    def build_watchdog():
        return (
            lambda z, coef, z2, coef2: monitor._round_metrics(
                fx.prob, SNTrainState(z, coef), SNTrainState(z2, coef2)
            ),
            (fx.state.z, fx.state.coef, fx.state.z, fx.state.coef),
        )
    entries.append(simple_entry("monitor.watchdog_step", build_watchdog))

    return entries


def run(trace_dtype: str = "float32") -> list[Finding]:
    """Audit the full default registry at ``trace_dtype``."""
    return run_entries(
        default_entries(trace_dtype), trace_dtype=trace_dtype
    )
