"""Findings and the shrink-only baseline protocol shared by all auditors.

A :class:`Finding` is one violation of one rule at one stable location.
Its :meth:`Finding.key` deliberately excludes line numbers and prose so
the key survives unrelated edits; the baseline file maps keys to a
written justification.  ``compare_with_baseline`` splits findings into
``new`` (not baselined — the audit fails) and reports ``stale`` baseline
entries (baselined but no longer found — the audit also fails, forcing
the baseline entry to be deleted).  Together the two failure modes make
the baseline monotone: it can only shrink.
"""

from __future__ import annotations

import dataclasses
import json
import os


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one stable location.

    rule:   violation class id (``host-sync``, ``dtype-narrow``,
            ``weak-promo``, ``const-leak``, ``grid-recompile``,
            ``alive-dead``, ``alive-scatter``, ``ast-host-sync``,
            ``ast-alive-thread``, ``ast-receipt-json``, ``ledger``).
    where:  the audited object — a jaxpr entry-point name or a
            ``relpath:qualname`` for AST findings.
    tag:    short stable discriminator when one rule can fire more than
            once per location (e.g. ``float64->float32``).
    detail: human explanation; NOT part of the key.
    """

    rule: str
    where: str
    tag: str = ""
    detail: str = ""

    @property
    def key(self) -> str:
        return (
            f"{self.rule}:{self.where}:{self.tag}"
            if self.tag
            else f"{self.rule}:{self.where}"
        )

    def __str__(self) -> str:  # pragma: no cover - formatting only
        msg = f"[{self.rule}] {self.where}"
        if self.tag:
            msg += f" ({self.tag})"
        if self.detail:
            msg += f": {self.detail}"
        return msg


def load_baseline(path: str) -> dict[str, str]:
    """Baseline file -> {finding key: justification}.  Missing file = {}."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    baselined = data.get("baselined", {})
    if not isinstance(baselined, dict):
        raise ValueError(f"{path}: 'baselined' must be an object")
    return dict(baselined)


def save_baseline(path: str, findings: list[Finding]) -> None:
    """Write the current findings as the new baseline (keys + details)."""
    data = {
        "_comment": (
            "Shrink-only baseline for tools/audit.py: every key below is "
            "a known, justified finding.  The audit fails on findings NOT "
            "listed here and on entries listed here that no longer fire "
            "(delete them).  Never add an entry without a justification."
        ),
        "baselined": {
            f.key: f.detail for f in sorted(findings, key=lambda f: f.key)
        },
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=False)
        f.write("\n")


def compare_with_baseline(
    findings: list[Finding], baseline: dict[str, str]
) -> tuple[list[Finding], list[str]]:
    """-> (new findings not in the baseline, stale baseline keys)."""
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, stale
