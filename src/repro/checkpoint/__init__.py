"""Pytree checkpointing: flat .npz + treedef manifest (no orbax offline)."""

from .ckpt import latest_step, restore, save

__all__ = ["latest_step", "restore", "save"]
