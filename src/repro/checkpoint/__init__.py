"""Pytree checkpointing: flat .npz + treedef manifest (no orbax offline)."""

from .ckpt import (
    latest_step,
    restore,
    restore_train,
    save,
    save_train,
    step_valid,
)

__all__ = [
    "latest_step",
    "restore",
    "restore_train",
    "save",
    "save_train",
    "step_valid",
]
