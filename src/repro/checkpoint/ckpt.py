"""Minimal, robust pytree checkpointing.

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/manifest.json
The manifest stores the flattened key paths so restore round-trips arbitrary
nested dict/list/tuple pytrees without pickling.  Writes are atomic
(tmp dir + rename) so a crashed save never corrupts the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import zipfile
from typing import Any

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    keyed = [(f"leaf_{i:05d}", np.asarray(leaf)) for i, leaf in enumerate(leaves)]
    return keyed, treedef


def save(directory: str, step: int, tree: Pytree) -> str:
    keyed, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **dict(keyed))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(
                {
                    "step": step,
                    "treedef": str(treedef),
                    "n_leaves": len(keyed),
                    "dtypes": [str(a.dtype) for _, a in keyed],
                    "shapes": [list(a.shape) for _, a in keyed],
                },
                f,
            )
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def step_valid(directory: str, step: int) -> bool:
    """True when ``step_<N>`` is a complete, readable checkpoint.

    The atomic tmp-dir + rename protocol means a crash mid-``save`` should
    never leave a partial final directory — but the filesystem under it can
    (a SIGKILL between the rename and the data hitting disk, a copied
    checkpoint truncated in transit).  A warm-restart path must therefore
    verify before trusting: the manifest must parse, the npz must be a
    sound zip archive (per-member CRCs checked), and its member set must
    match the manifest's leaf count exactly.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        n_leaves = int(manifest["n_leaves"])
        with zipfile.ZipFile(os.path.join(path, "arrays.npz")) as zf:
            if zf.testzip() is not None:  # CRC failure: truncated member
                return False
            names = {name.removesuffix(".npy") for name in zf.namelist()}
        return names == {f"leaf_{i:05d}" for i in range(n_leaves)}
    except (OSError, ValueError, KeyError, zipfile.BadZipFile):
        return False


def latest_step(directory: str, *, verify: bool = True) -> int | None:
    """Largest step with a checkpoint in ``directory`` (None if none).

    ``verify=True`` (the default) skips steps that fail ``step_valid`` —
    a truncated or partially-written snapshot is ignored and the prior
    intact step is returned instead, so crash-kill -> warm-restart always
    lands on restorable state (the daemon's recovery anchor).
    """
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        (
            int(m.group(1))
            for name in os.listdir(directory)
            if (m := re.fullmatch(r"step_(\d+)", name))
        ),
        reverse=True,
    )
    for step in steps:
        if not verify or step_valid(directory, step):
            return step
    return None


def restore(directory: str, step: int, like: Pytree) -> Pytree:
    """Restore into the structure of `like` (shape/dtype verified)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = [data[f"leaf_{i:05d}"] for i in range(len(data.files))]
    leaves, treedef = jax.tree.flatten(like)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template has {len(leaves)}"
        )
    for i, (tmpl, arr) in enumerate(zip(leaves, arrays)):
        if tuple(np.shape(tmpl)) != tuple(arr.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != template {np.shape(tmpl)}")
    restored = [
        np.asarray(a, dtype=np.asarray(t).dtype) for t, a in zip(leaves, arrays)
    ]
    return jax.tree.unflatten(treedef, restored)


def save_train(directory: str, step: int, problem, state) -> str:
    """Snapshot a full ``SNTrainProblem`` + ``SNTrainState`` pair.

    Both are registered dataclass pytrees, so one atomic ``save`` of the
    two-entry dict captures EVERYTHING the solver owns — topology tables,
    factors, scatter plans, liveness, forgetting weights, messages and
    coefficients.  npz storage is lossless and dtypes match the template
    at restore, so the round-trip is bitwise (the crash-recovery anchor
    of the convergence watchdog, ``repro.core.monitor``).
    """
    return save(directory, step, {"problem": problem, "state": state})


def restore_train(directory: str, step: int, problem, state) -> tuple:
    """Bitwise-inverse of ``save_train``.

    ``problem``/``state`` are live templates (their static fields —
    kernel, n_stream, layout ints — carry over; array leaves are
    replaced by the snapshot).  Returns ``(problem, state)`` with
    device arrays, every leaf bitwise equal to what ``save_train`` saw.
    """
    tree = restore(directory, step, {"problem": problem, "state": state})
    tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree["problem"], tree["state"]
