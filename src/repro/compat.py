"""Version-compatibility shims over the moving parts of the JAX API.

The repo targets the container's jax (0.4.x) while staying forward-compatible
with newer releases:

  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` only
    exist in jax >= 0.5; on 0.4.x meshes are built without axis types.
  * ``jax.shard_map`` graduated from ``jax.experimental.shard_map`` and its
    replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.

Everything that builds meshes or shard_maps goes through this module so the
rest of the codebase can be written against one API.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where supported, plain otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, check=False):
    """shard_map across the jax.experimental -> jax.shard_map migration.

    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old).
    """
    new = getattr(jax, "shard_map", None)
    if new is not None:
        # the flag was spelled check_rep before the check_vma rename, and
        # some intermediate releases promoted shard_map to the top level
        # while still using the old spelling — try both before dropping it
        for kw in ("check_vma", "check_rep"):
            try:
                return new(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{kw: check})
            except TypeError:
                continue
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as legacy

    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)
