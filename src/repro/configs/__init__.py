"""Architecture registry: the 10 assigned configs + the paper's own workload.

Usage:  cfg = get_config("mamba2-370m")
        cfg = get_config("mamba2-370m", variant="long")   # sub-quadratic decode
        cfg = get_config("mamba2-370m", variant="smoke")  # reduced smoke config
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, reduced

from . import (
    internlm2_1_8b,
    jamba_1_5_large_398b,
    llama4_scout_17b_a16e,
    mamba2_370m,
    nemotron_4_15b,
    qwen1_5_32b,
    qwen2_vl_2b,
    qwen3_moe_30b_a3b,
    sensor_field,
    smollm_135m,
    whisper_tiny,
)
from .shapes import SHAPES, InputShape, batch_specs, concrete_batch, decode_specs, input_specs

_MODULES = {
    "smollm-135m": smollm_135m,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "internlm2-1.8b": internlm2_1_8b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "mamba2-370m": mamba2_370m,
    "nemotron-4-15b": nemotron_4_15b,
    "whisper-tiny": whisper_tiny,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "qwen1.5-32b": qwen1_5_32b,
}

ARCH_NAMES = list(_MODULES)

# sliding window used for the long_500k sub-quadratic variant of attention archs
LONG_CONTEXT_WINDOW = 8192


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic variant for long_500k (DESIGN.md Sec. 5).

    SSM is natively O(1)-state.  Attention-bearing archs get a sliding
    window (ring-buffer KV cache of LONG_CONTEXT_WINDOW).  Whisper has no
    long-context analogue and is skipped by the dry-run driver.
    """
    if cfg.family == "ssm":
        return cfg
    if cfg.is_encoder_decoder:
        raise ValueError(f"{cfg.name}: long_500k is skipped for enc-dec (DESIGN.md)")
    return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)


def supports_shape(name: str, shape_name: str) -> bool:
    return not (shape_name == "long_500k" and name == "whisper-tiny")


def get_config(name: str, *, variant: str | None = None) -> ModelConfig:
    cfg = _MODULES[name].config()
    if variant in (None, "full"):
        return cfg
    if variant == "long":
        return long_context_variant(cfg)
    if variant == "smoke":
        return reduced(cfg)
    raise ValueError(f"unknown variant {variant!r}")


def sensor_field_config() -> sensor_field.SensorFieldConfig:
    return sensor_field.config()


__all__ = [
    "ARCH_NAMES",
    "LONG_CONTEXT_WINDOW",
    "SHAPES",
    "InputShape",
    "batch_specs",
    "concrete_batch",
    "decode_specs",
    "get_config",
    "input_specs",
    "long_context_variant",
    "sensor_field_config",
    "supports_shape",
]
