"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Layer pattern: period-8 blocks with attention at in-block index 3 and Mamba2
elsewhere (1 attention : 7 mamba); MoE replaces the dense FFN on every other
layer (odd in-block indices).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        act="silu",
        layer_pattern=("m", "m", "m", "a", "m", "m", "m", "m"),
        n_experts=16,
        top_k=2,
        moe_d_ff=24576,
        moe_period=2,
        moe_offset=1,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        dtype="bfloat16",
        fsdp=True,
        remat=True,
    )
