"""llama4-scout-17b-a16e [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
(+ one shared expert, as in the Llama-4 MoE block).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        act="silu",
        n_experts=16,
        top_k=1,
        moe_d_ff=8192,
        n_shared_experts=1,
        moe_period=1,
        rope_theta=500000.0,
        dtype="bfloat16",
        fsdp=True,
    )
