"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024 (attention-free) d_ff=0 vocab=50280, ssm_state=128.
d_inner = 2*d_model = 2048, head_dim 64 -> 32 SSD heads.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        dtype="bfloat16",
    )
