"""nemotron-4-15b [dense] — GQA, squared-ReLU [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        act="squared_relu",
        rope_theta=10000.0,
        dtype="bfloat16",
        fsdp=True,
    )
