"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family].

64L d_model=5120 40H (MHA kv=40) d_ff=27392 vocab=152064.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152064,
        act="silu",
        qkv_bias=True,
        rope_theta=1000000.0,
        dtype="bfloat16",
        fsdp=True,
        remat=True,
    )
