"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

The vision encoder (ViT + merger) is a stub per the assignment carve-out:
`input_specs()` supplies precomputed patch embeddings (B, n_patches, d_model).
The language decoder with M-RoPE (temporal/height/width sections of the
rotary frequencies) is implemented in full.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        act="silu",
        qkv_bias=True,
        tie_embeddings=True,
        rope_mode="mrope",
        mrope_sections=(16, 24, 24),  # of head_dim//2 = 64
        n_patches=1024,  # stubbed vision prefix length
        rope_theta=1000000.0,
        dtype="bfloat16",
    )
