"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
MoE 128 experts top-8 on every layer (no shared expert, no dense layers).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        act="silu",
        n_experts=128,
        top_k=8,
        moe_d_ff=768,
        moe_period=1,
        rope_theta=1000000.0,
        dtype="bfloat16",
        fsdp=True,
    )
