"""The paper's own workload: distributed field estimation with SN-Train.

Not a transformer — this config describes the sensor-network regression
problem (paper Sec. 4) and is consumed by examples/quickstart.py,
benchmarks, and the sharded SN-Train engine.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SensorFieldConfig:
    name: str = "sensor-field"
    case: str = "case2"  # case1 (linear) | case2 (sinusoid)
    n_sensors: int = 50
    radius: float = 0.8
    kappa: float = 0.01  # lambda_i = kappa / |N_i|^2 (paper Sec. 4.1)
    n_sweeps: int = 100  # outer iterations T
    n_test: int = 500
    fusion: str = "nn"  # single | nn | knn | avg | conn


def config() -> SensorFieldConfig:
    return SensorFieldConfig()
