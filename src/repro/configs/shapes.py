"""Assigned input shapes and abstract input specs for the dry-run.

Shapes (from the assignment):
  train_4k     seq=4096    global_batch=256   -> train_step
  prefill_32k  seq=32768   global_batch=32    -> prefill (forward + cache fill)
  decode_32k   seq=32768   global_batch=128   -> serve_step (1 token vs cache)
  long_500k    seq=524288  global_batch=1     -> serve_step, sub-quadratic only

`input_specs` returns jax.ShapeDtypeStruct stand-ins (weak-type-correct, no
allocation) for everything the step function consumes besides params.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import model as M
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, InputShape] = {
    s.name: s
    for s in [
        InputShape("train_4k", "train", 4096, 256),
        InputShape("prefill_32k", "prefill", 32768, 32),
        InputShape("decode_32k", "decode", 32768, 128),
        InputShape("long_500k", "decode", 524288, 1),
    ]
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape, *, batch: int | None = None):
    """Abstract batch for train/prefill. `batch` overrides global_batch."""
    b = batch if batch is not None else shape.global_batch
    s = shape.seq_len
    itok = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if cfg.is_encoder_decoder:
        return {
            "tokens": _sds((b, s), itok),
            "labels": _sds((b, s), itok),
            "mask": _sds((b, s), jnp.float32),
            "frames": _sds((b, cfg.encoder_seq, cfg.d_model), f),
        }
    spec = {
        "tokens": _sds((b, max(s - cfg.n_patches, 1)), itok),
        "labels": _sds((b, max(s - cfg.n_patches, 1)), itok),
        "mask": _sds((b, max(s - cfg.n_patches, 1)), jnp.float32),
    }
    if cfg.n_patches:
        spec["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_model), f)
    return spec


def decode_specs(cfg: ModelConfig, shape: InputShape, *, batch: int | None = None):
    """Abstract (token, cache, position) for a serve step with a seq_len cache."""
    b = batch if batch is not None else shape.global_batch
    f = jnp.dtype(cfg.dtype)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, b, shape.seq_len, f))
    return {
        "token": _sds((b, 1), jnp.int32),
        "cache": cache,
        "position": _sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape_name: str, **kw):
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return decode_specs(cfg, shape, **kw)
    return batch_specs(cfg, shape, **kw)


def concrete_batch(cfg: ModelConfig, seq: int, batch: int, *, seed: int = 0):
    """Small concrete batch for smoke tests (reduced configs only)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    s_text = max(seq - cfg.n_patches, 1)
    out = {
        "tokens": jax.random.randint(k1, (batch, s_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (batch, s_text), 0, cfg.vocab_size),
        "mask": jnp.ones((batch, s_text), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        out["frames"] = jax.random.normal(
            k3, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    if cfg.n_patches:
        out["patch_embeds"] = jax.random.normal(
            k3, (batch, cfg.n_patches, cfg.d_model), jnp.float32
        )
    return out
