"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        act="silu",
        tie_embeddings=True,
        rope_theta=10000.0,
        dtype="bfloat16",
    )
