"""whisper-tiny [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865; 4 encoder layers; 1500
audio frames; 448 learned decoder positions; GELU; LayerNorm; tied head.

The mel-spectrogram + conv downsampler frontend is a stub per the carve-out:
`input_specs()` supplies frame embeddings (B, 1500, 384).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        is_encoder_decoder=True,
        n_layers=4,
        n_encoder_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        act="gelu",
        norm="layernorm",
        encoder_seq=1500,
        max_target_positions=448,
        tie_embeddings=True,
        dtype="bfloat16",
    )
