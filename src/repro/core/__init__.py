"""The paper's primary contribution: distributed kernel regression via
alternating projections (SN-Train), plus the SOP-consensus generalization
used by the LLM training stack.

Public surface:
  kernels_math — RKHS kernels (linear / RBF / Matern / poly)
  topology     — geometric sensor graphs, distance-2 coloring
  sop          — generic successive-orthogonal-projection machinery
  centralized  — fusion-center regularized kernel least squares (Eq. 6)
  sn_train     — the paper's SN-Train message-passing algorithm (Eq. 18)
  fusion       — single-sensor / kNN / connectivity-averaged aggregation
  consensus    — SOP-gossip data parallelism (pairwise projections == gossip)
  faults       — seeded link-drop/burst/crash fault injection (FaultModel)
  monitor      — convergence watchdog: retry / refactorize / rollback
  pruning      — representer energy scoring, prune masks, plan compaction
"""

from . import (
    centralized,
    consensus,
    faults,
    fusion,
    kernels_math,
    monitor,
    plans,
    pruning,
    serving,
    sn_train,
    sop,
    streaming,
    topology,
)
from .faults import FaultModel, faulty_sweep, make_fault_model
from .monitor import WatchdogConfig, WatchdogReceipt, watch_sweeps
from .centralized import KRRModel, fit_krr, predict
from .kernels_math import Kernel
from .plans import LifecycleLayout
from .pruning import (
    PruneReport,
    answer_bound,
    prune_mask,
    prune_plan,
    representer_energy,
)
from .serving import (
    ServingPlan,
    make_serving_plan,
    plan_add_sensor,
    plan_remove_sensor,
)
from .sn_train import (
    SNTrainProblem,
    SNTrainState,
    colored_sweep,
    default_lambdas,
    effective_coef,
    field_view,
    init_state,
    local_only,
    make_batch_problem,
    make_problem,
    random_sweep,
    robust_sweep,
    robust_sweep_links,
    serial_sweep,
    sharded_sweep,
    weighted_norm_sq,
    weighted_norm_sq_hetero,
    weighted_sweep,
)
from .streaming import (
    AbsorbReceipt,
    JoinReceipt,
    absorb_wave,
    add_sensor,
    remove_sensor,
)
from .topology import (
    SensorTopology,
    build_topology,
    pad_topology,
    ring_topology,
    uniform_sensors,
)

__all__ = [
    "AbsorbReceipt",
    "FaultModel",
    "JoinReceipt",
    "Kernel",
    "KRRModel",
    "LifecycleLayout",
    "SNTrainProblem",
    "SNTrainState",
    "SensorTopology",
    "ServingPlan",
    "WatchdogConfig",
    "WatchdogReceipt",
    "absorb_wave",
    "add_sensor",
    "faults",
    "faulty_sweep",
    "make_fault_model",
    "monitor",
    "watch_sweeps",
    "make_serving_plan",
    "plan_add_sensor",
    "plan_remove_sensor",
    "plans",
    "PruneReport",
    "answer_bound",
    "prune_mask",
    "prune_plan",
    "pruning",
    "representer_energy",
    "serving",
    "build_topology",
    "centralized",
    "colored_sweep",
    "consensus",
    "default_lambdas",
    "effective_coef",
    "field_view",
    "fit_krr",
    "fusion",
    "init_state",
    "kernels_math",
    "local_only",
    "make_batch_problem",
    "make_problem",
    "pad_topology",
    "predict",
    "random_sweep",
    "remove_sensor",
    "ring_topology",
    "robust_sweep",
    "robust_sweep_links",
    "serial_sweep",
    "sharded_sweep",
    "sn_train",
    "sop",
    "streaming",
    "weighted_norm_sq",
    "weighted_norm_sq_hetero",
    "weighted_sweep",
    "topology",
    "uniform_sensors",
]
