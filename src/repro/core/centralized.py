"""Centralized regularized kernel least-squares regression (paper Sec. 2.2).

The fusion-center baseline the paper compares against:

    min_{f in H_K}  sum_i (f(x_i) - y_i)^2 + lambda ||f||^2      (Eq. 4/10)
    c = (K + lambda I)^{-1} y                                    (Eq. 6)
    f(x) = sum_i c_i K(x, x_i)                                   (Eq. 5)

Solved with a Cholesky factorization (K + lambda I is SPD for lambda > 0).
Prediction can optionally route through the Pallas fused kernel-matvec
(`repro.kernels.ops.kernel_matvec`) — the testing-phase hot spot.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .kernels_math import Kernel


@dataclasses.dataclass(frozen=True)
class KRRModel:
    """A fit regularized kernel least-squares model."""

    anchors: jax.Array  # (n, d) training inputs
    coef: jax.Array  # (n,)  representer coefficients c
    kernel: Kernel


@partial(jax.jit, static_argnames=("kernel",))
def _fit(kernel: Kernel, x: jax.Array, y: jax.Array, lam: jax.Array) -> jax.Array:
    n = x.shape[0]
    k = kernel(x, x)
    chol = jsl.cho_factor(k + lam * jnp.eye(n, dtype=k.dtype))
    return jsl.cho_solve(chol, y)


def fit_krr(
    x: jax.Array, y: jax.Array, kernel: Kernel, lam: float, *, dtype=jnp.float32
) -> KRRModel:
    """Train: compute c_lambda = (K + lambda I)^{-1} y (paper Eq. 6).

    Pass dtype=jnp.float64 (with x64 enabled) when lam is tiny relative to
    the Gram spectrum — same conditioning caveat as SN-Train.
    """
    x = jnp.atleast_2d(jnp.asarray(x, dtype))
    y = jnp.asarray(y, dtype)
    coef = _fit(kernel, x, y, jnp.asarray(lam, dtype))
    return KRRModel(anchors=x, coef=coef, kernel=kernel)


@partial(jax.jit, static_argnames=("kernel",))
def _predict(kernel: Kernel, anchors, coef, xq) -> jax.Array:
    return kernel(xq, anchors) @ coef


def predict(model: KRRModel, xq: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """Test: f(x) = sum_i c_i K(x, x_i) for a batch of queries (Q, d)."""
    xq = jnp.atleast_2d(jnp.asarray(xq, model.anchors.dtype))
    if use_pallas and model.kernel.name == "rbf":
        from repro.kernels.ops import kernel_matvec

        return kernel_matvec(xq, model.anchors, model.coef, gamma=model.kernel.gamma)
    return _predict(model.kernel, model.anchors, model.coef, xq)


def mse(model: KRRModel, xq: jax.Array, yq: jax.Array, **kw) -> jax.Array:
    pred = predict(model, xq, **kw)
    return jnp.mean((pred - jnp.asarray(yq)) ** 2)
