"""SOP-consensus ("gossip") data parallelism — the paper's technique applied
to distributed neural-network training (DESIGN.md Sec. 3).

Mapping: data-parallel replica i  <->  sensor i; replica parameters theta_i
<->  the sensor's local function f_i; the coupling constraint f_i = f_j for
neighbors  <->  the consensus subspace C_ij = {theta : theta_i = theta_j}.

The orthogonal projection of (theta_1..theta_n) onto C_ij replaces theta_i
and theta_j by their average and leaves everything else unchanged — so SOP
over a *pairing schedule* is a sequence of exact pairwise parameter
averagings, implemented on hardware with `jax.lax.ppermute` along the `data`
mesh axis.  The paper's Lemma 3.1 ("fully connected = centralized") maps to:
a full hypercube sweep of pairwise projections equals the all-reduce mean
exactly (butterfly all-reduce), which is both a property test and the bridge
to conventional data parallelism.

Two execution modes:
  * device mode — inside shard_map/jit with a named axis (production path);
  * host-sim mode — replicas stacked on a leading array axis (tests,
    benchmarks, single-device CPU).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


# --------------------------------------------------------------------------
# Pairing schedules (partner[i] = who replica i projects with this round).
# --------------------------------------------------------------------------


def hypercube_schedule(n: int) -> list[list[int]]:
    """log2(n) rounds of partner = i XOR 2^d.  Full sweep == global mean."""
    if n & (n - 1):
        raise ValueError(f"hypercube schedule needs power-of-two replicas, got {n}")
    return [[i ^ (1 << d) for i in range(n)] for d in range(int(math.log2(n)))]


def ring_schedule(n: int) -> list[list[int]]:
    """Two alternating even/odd pairings on a ring (the relaxed topology)."""
    if n % 2:
        raise ValueError("ring schedule needs an even replica count")
    even = [i ^ 1 for i in range(n)]  # (0,1)(2,3)...
    odd = [(i - 1) % n if i % 2 == 0 else (i + 1) % n for i in range(n)]
    return [even, odd]


def one_sided_ring_schedule(n: int) -> list[list[int]]:
    """Neighborhood averaging with both ring neighbors (Cimmino-style
    simultaneous projection): theta_i <- (theta_{i-1} + theta_i + theta_{i+1})/3.
    Returned as two shift permutations; see `neighborhood_average`.
    """
    fwd = [(i + 1) % n for i in range(n)]
    bwd = [(i - 1) % n for i in range(n)]
    return [fwd, bwd]


def schedule(name: str, n: int) -> list[list[int]]:
    if name == "hypercube":
        return hypercube_schedule(n)
    if name == "ring":
        return ring_schedule(n)
    raise ValueError(f"unknown gossip schedule {name!r}")


# --------------------------------------------------------------------------
# Device mode (inside shard_map over `axis_name`).
# --------------------------------------------------------------------------


def pairwise_project(params: Pytree, axis_name: str, partners: list[int]) -> Pytree:
    """One SOP projection onto intersect_{paired (i,j)} C_ij.

    `partners` must be an involution (partner[partner[i]] == i).
    """
    perm = [(i, p) for i, p in enumerate(partners)]
    return jax.tree.map(
        lambda x: 0.5 * (x + jax.lax.ppermute(x, axis_name, perm)), params
    )


def neighborhood_average(params: Pytree, axis_name: str, n: int) -> Pytree:
    """Cimmino-style simultaneous projection over ring neighborhoods."""
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    def avg(x):
        return (
            x
            + jax.lax.ppermute(x, axis_name, fwd)
            + jax.lax.ppermute(x, axis_name, bwd)
        ) / 3.0

    return jax.tree.map(avg, params)


def gossip_round(
    params: Pytree, axis_name: str, sched: list[list[int]], round_idx: jax.Array
) -> Pytree:
    """Apply the round_idx-th pairing of a schedule (round-robin)."""
    branches = [
        (lambda p, s=s: pairwise_project(p, axis_name, s)) for s in sched
    ]
    return jax.lax.switch(round_idx % len(sched), branches, params)


def allreduce_average(params: Pytree, axis_name: str) -> Pytree:
    """The centralized special case (complete graph; paper Lemma 3.1)."""
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), params)


def consensus_sq_distance(params: Pytree, axis_name: str) -> jax.Array:
    """sum_i ||theta_i - mean||^2 — the Fejer-monotone disagreement metric."""
    mean = jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), params)
    per = jax.tree.reduce(
        jnp.add,
        jax.tree.map(lambda x, m: jnp.sum((x - m) ** 2), params, mean),
    )
    return jax.lax.psum(per, axis_name)


# --------------------------------------------------------------------------
# Host-sim mode: replicas stacked on axis 0 of every leaf.
# --------------------------------------------------------------------------


def sim_pairwise_project(stacked: Pytree, partners: list[int]) -> Pytree:
    idx = jnp.asarray(partners)
    return jax.tree.map(lambda x: 0.5 * (x + x[idx]), stacked)


def sim_gossip_sweep(stacked: Pytree, sched: list[list[int]]) -> Pytree:
    for partners in sched:
        stacked = sim_pairwise_project(stacked, partners)
    return stacked


def sim_consensus_sq_distance(stacked: Pytree) -> jax.Array:
    def leaf(x):
        mean = jnp.mean(x, axis=0, keepdims=True)
        return jnp.sum((x - mean) ** 2)

    return jax.tree.reduce(jnp.add, jax.tree.map(leaf, stacked))
