"""Fault injection for SN-Train: lossy links, bursts, and sensor crashes.

The paper's whole premise is message passing over *wireless* links
(Sec. 4 "practical aspects"), where delivery is lossy, bursty, and
sensors crash mid-training.  This module is the seeded, shape-static
fault model that drives the degraded-execution paths of
``repro.core.sn_train``:

  * **i.i.d. Bernoulli drops** — every padded neighbor lane ``(s, k)``
    of every sweep independently loses its outgoing message write with
    probability ``drop``.
  * **Gilbert–Elliott bursts** — each lane carries a 2-state Markov
    link (good/bad); the bad state adds ``drop_bad`` loss on top of the
    ambient rate, and the ``burst_to_bad`` / ``burst_to_good``
    transition probabilities set the burst length.  The chain starts at
    its stationary distribution so sweep 0 is statistically identical
    to sweep 10^6.
  * **crash/restart schedules** — a per-sensor up/down Markov chain
    that lowers onto the EXISTING ``alive`` machinery: a crashed sweep
    routes through ``robust_sweep``'s per-sweep masked refactorization,
    so a down sensor neither updates nor is read, exactly as under
    lifecycle churn.

Semantics of a dropped message: **hold-last-value**.  The sender still
runs its local projection (compute is local), but the write to the
target message slot never lands, so the stale z persists — mirroring
the dead-target-slot gates PR 4 threaded through every engine.  An
all-delivered mask is therefore a bitwise identity, engine by engine
(tests/test_faults.py pins this for serial/plan/onehot/pallas/robust).

Everything here is shape-static and seeded: the ``FaultModel`` rates
are *traced* scalars, so sweeping a grid of drop rates reuses ONE
compiled program (zero recompiles across fault rates, exactly like the
PR-4 liveness masks — ``benchmarks/fault_bench.py`` counts the jit
cache to prove it).  Delivery masks are sampled by thresholding
uniforms (``u >= p``), which monotonically couples rates under a fixed
key: raising ``drop`` can only shrink the delivered set — the property
the monotone-degradation soak test leans on.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import sn_train
from .sn_train import SNTrainProblem, SNTrainState


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Seeded link/sensor fault process; all rates are traced scalars.

    ``crash``/``restart`` are ``None`` for the crash-free model — a
    *static* pytree-structure distinction, so the crash-free path never
    pays ``robust_sweep``'s per-sweep refactorization.  Build with
    ``make_fault_model``.
    """

    drop: jnp.ndarray  # () ambient P(per-lane message drop per sweep)
    burst_to_bad: jnp.ndarray  # () P(good -> bad) per sweep
    burst_to_good: jnp.ndarray  # () P(bad -> good) per sweep
    drop_bad: jnp.ndarray  # () EXTRA drop probability while in the bad state
    crash: jnp.ndarray | None = None  # () P(up sensor crashes per sweep)
    restart: jnp.ndarray | None = None  # () P(down sensor restarts per sweep)

    @property
    def has_crash(self) -> bool:
        return self.crash is not None


def make_fault_model(
    drop: float = 0.0,
    burst: tuple | None = None,
    crash: tuple | None = None,
    *,
    dtype=jnp.float32,
) -> FaultModel:
    """Build a FaultModel from plain rates.

    drop: ambient i.i.d. per-lane drop probability.
    burst: optional ``(to_bad, to_good, drop_bad)`` Gilbert–Elliott
        parameters (None: the chain never leaves the good state).
    crash: optional ``(p_crash, p_restart)`` per-sensor Markov rates
        (None: the crash-free — and refactorization-free — path).
    """
    z = lambda v: jnp.asarray(v, dtype)
    to_bad, to_good, drop_bad = burst if burst is not None else (0.0, 1.0, 0.0)
    return FaultModel(
        drop=z(drop),
        burst_to_bad=z(to_bad),
        burst_to_good=z(to_good),
        drop_bad=z(drop_bad),
        crash=None if crash is None else z(crash[0]),
        restart=None if crash is None else z(crash[1]),
    )


def link_masks(
    model: FaultModel, key: jax.Array, n_sweeps: int, lane_shape: tuple
) -> jax.Array:
    """Sample per-sweep delivered masks, shape ``(n_sweeps,) + lane_shape``.

    ``lane_shape`` is the padded neighbor table shape ``(n+1, D)`` —
    delivery is a property of the physical lane, shared across fields
    (every field's message for one sweep rides the same radio packet).
    The Gilbert–Elliott state starts at its stationary distribution;
    within each sweep the lane drops with probability
    ``1 - (1-drop) * (1 - drop_bad * [bad])``.  Delivery thresholds a
    uniform (``u >= p``), so under one key the delivered set shrinks
    monotonically as rates rise.
    """
    k_init, k_seq = jax.random.split(jnp.asarray(key))
    denom = model.burst_to_bad + model.burst_to_good
    pi_bad = jnp.where(
        denom > 0, model.burst_to_bad / jnp.maximum(denom, 1e-20), 0.0
    )
    # Sample in the model dtype: the default uniform dtype is float64
    # under JAX_ENABLE_X64 and would promote every threshold compare.
    udt = model.drop.dtype
    bad0 = jax.random.uniform(k_init, lane_shape, dtype=udt) < pi_bad

    def step(bad, k):
        ku, kb, kg = jax.random.split(k, 3)
        p_drop = 1.0 - (1.0 - model.drop) * (
            1.0 - jnp.where(bad, model.drop_bad, 0.0)
        )
        delivered = jax.random.uniform(ku, lane_shape, dtype=udt) >= p_drop
        go_bad = jax.random.uniform(kb, lane_shape, dtype=udt) < (
            model.burst_to_bad
        )
        go_good = jax.random.uniform(kg, lane_shape, dtype=udt) < (
            model.burst_to_good
        )
        bad = jnp.where(bad, ~go_good, go_bad)
        return bad, delivered

    _, delivered = jax.lax.scan(step, bad0, jax.random.split(k_seq, n_sweeps))
    return delivered


def crash_schedule(
    model: FaultModel, key: jax.Array, n_sweeps: int, n: int
) -> jax.Array:
    """Per-sensor up/down Markov chain, shape ``(n_sweeps, n)`` bool.

    Starts all-up (the problem's persistent ``alive`` mask composes on
    top inside ``robust_sweep``, so lifecycle-dead rows stay dead).
    """
    if model.crash is None:
        return jnp.ones((n_sweeps, n), bool)

    def step(up, k):
        kc, kr = jax.random.split(k)
        udt = model.crash.dtype
        crash = jax.random.uniform(kc, (n,), dtype=udt) < model.crash
        restart = jax.random.uniform(kr, (n,), dtype=udt) < model.restart
        up = jnp.where(up, ~crash, restart)
        return up, up

    _, trace = jax.lax.scan(
        step, jnp.ones((n,), bool), jax.random.split(jnp.asarray(key), n_sweeps)
    )
    return trace


def sample_faults(
    model: FaultModel,
    key: jax.Array,
    n_sweeps: int,
    problem: SNTrainProblem,
) -> tuple[jax.Array, jax.Array | None]:
    """(delivered (n_sweeps, n+1, D), alive trace (n_sweeps, n) or None)."""
    kl, kc = jax.random.split(jnp.asarray(key))
    delivered = link_masks(model, kl, n_sweeps, problem.nbr_idx.shape)
    alive_tn = (
        crash_schedule(model, kc, n_sweeps, problem.n)
        if model.has_crash
        else None
    )
    return delivered, alive_tn


@partial(jax.jit, static_argnames=("n_sweeps", "engine"))
def _faulty_colored(problem, state, model, key, n_sweeps, engine):
    delivered, _ = sample_faults(model, key, n_sweeps, problem)
    return sn_train.colored_sweep(
        problem, state, n_sweeps=n_sweeps, engine=engine, delivered=delivered
    )


@partial(jax.jit, static_argnames=("n_sweeps",))
def _faulty_serial(problem, state, model, key, n_sweeps):
    delivered, _ = sample_faults(model, key, n_sweeps, problem)
    return sn_train.serial_sweep(
        problem, state, n_sweeps=n_sweeps, delivered=delivered
    )


@partial(jax.jit, static_argnames=("n_sweeps", "engine"))
def _faulty_robust(problem, state, model, key, n_sweeps, engine):
    delivered, alive_tn = sample_faults(model, key, n_sweeps, problem)
    return sn_train._robust_colored(
        problem, state, alive_tn, n_sweeps=n_sweeps, engine=engine,
        delivered=delivered,
    )


def faulty_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    model: FaultModel,
    key: jax.Array,
    n_sweeps: int = 1,
    *,
    engine: str = "plan",
) -> SNTrainState:
    """Run ``n_sweeps`` sweeps under the fault model.

    Samples the delivery masks (and, when the model crashes sensors,
    the alive trace) INSIDE jit from ``key``, then dispatches:

      * crash-free models   -> the cached-factor engines
        (``serial_sweep`` / ``colored_sweep``) with the ``delivered``
        operand threaded through — no refactorization;
      * crashing models     -> the ``robust_sweep`` path, which
        refactorizes the masked systems per sweep (the PR-5 transient
        machinery) and composes ``delivered`` on top.

    ``engine``: "serial", or the colored engines "plan"/"onehot"/
    "pallas".  Rates are traced, so one compiled program per
    (n_sweeps, engine, shape) serves EVERY fault rate.
    """
    if engine == "serial":
        if model.has_crash:
            raise NotImplementedError(
                "crash schedules dispatch the colored robust path; "
                "use engine='plan'/'onehot'/'pallas'"
            )
        return _faulty_serial(problem, state, model, key, n_sweeps=n_sweeps)
    if model.has_crash:
        return _faulty_robust(
            problem, state, model, key, n_sweeps=n_sweeps, engine=engine
        )
    return _faulty_colored(
        problem, state, model, key, n_sweeps=n_sweeps, engine=engine
    )


_FAULT_SPEC_USAGE = (
    "usage: drop=P[,burst=to_bad:to_good:drop_bad][,crash=p_crash:p_restart]"
    " — every rate a probability in [0, 1], each key at most once"
    " (e.g. drop=0.1,burst=0.05:0.4:0.5)"
)

# key -> (arity, per-position rate names, used in the error messages)
_FAULT_SPEC_KEYS = {
    "drop": ("drop",),
    "burst": ("to_bad", "to_good", "drop_bad"),
    "crash": ("p_crash", "p_restart"),
}


def parse_fault_spec(spec: str, *, dtype=jnp.float32) -> FaultModel:
    """Parse and VALIDATE the CLI fault spec.

    ``drop=P[,burst=GB:BG:PB][,crash=C:R]`` — examples: ``drop=0.1``;
    ``drop=0.05,burst=0.02:0.3:0.6``; ``drop=0.1,crash=0.01:0.25``.  Used
    by ``serve.py --faults`` and the daemon's fault drills.  Malformed
    specs raise ``ValueError`` with a usage message instead of silently
    building a nonsense model: unknown or repeated keys, wrong arity,
    non-numeric values, and rates outside [0, 1] (a Bernoulli probability)
    are all rejected.
    """
    if not spec.strip():
        raise ValueError(f"empty fault spec; {_FAULT_SPEC_USAGE}")
    seen: dict[str, tuple] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if "=" not in part:
            raise ValueError(
                f"bad fault spec field {part!r} in {spec!r}; "
                f"{_FAULT_SPEC_USAGE}"
            )
        name, _, val = part.partition("=")
        name = name.strip()
        if name not in _FAULT_SPEC_KEYS:
            raise ValueError(
                f"unknown fault spec key {name!r} in {spec!r}; "
                f"{_FAULT_SPEC_USAGE}"
            )
        if name in seen:
            raise ValueError(
                f"repeated fault spec key {name!r} in {spec!r}; "
                f"{_FAULT_SPEC_USAGE}"
            )
        rate_names = _FAULT_SPEC_KEYS[name]
        raw = val.split(":")
        if len(raw) != len(rate_names):
            raise ValueError(
                f"{name} takes {len(rate_names)} value(s) "
                f"({':'.join(rate_names)}), got {val!r}; {_FAULT_SPEC_USAGE}"
            )
        vals = []
        for rname, v in zip(rate_names, raw):
            try:
                rate = float(v)
            except ValueError:
                raise ValueError(
                    f"non-numeric {name} rate {rname}={v!r} in {spec!r}; "
                    f"{_FAULT_SPEC_USAGE}"
                ) from None
            if not (0.0 <= rate <= 1.0):  # also rejects nan
                raise ValueError(
                    f"{name} rate {rname}={v} outside [0, 1] in {spec!r}; "
                    f"{_FAULT_SPEC_USAGE}"
                )
            vals.append(rate)
        seen[name] = tuple(vals)
    return make_fault_model(
        seen.get("drop", (0.0,))[0],
        seen.get("burst"),
        seen.get("crash"),
        dtype=dtype,
    )
