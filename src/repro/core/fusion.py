"""Fusion-center aggregation rules (paper Sec. 3.3 'Aggregation').

After SN-Train, every sensor holds a *global* field estimate
``f_s(x) = sum_{j in N_s} c_{s,j} K(x, x_j)``.  The fusion center combines
them with one of three strategies from the paper:

  * single-sensor:         f(x) = f_s(x) for one arbitrary sensor s
  * k-nearest-neighbor:    f(x) = mean_{s in kNN(x)} f_s(x)        (Eq. 19)
  * connectivity-averaged: f(x) = sum_s |N_s| f_s(x) / sum_s |N_s| (Eq. 20)

k = 1 is "nearest neighbor", k = n is the plain network average.

Every rule accepts single-field problems ((Q,) output) and batched
multi-field problems ((B, Q) output).  Dtypes follow the problem/state
arrays, so x64 problems (the paper-lambda configuration) serve f64
predictions end-to-end through every rule in this module and through the
plan engines of ``repro.core.serving``.  (The one f32 fast path is the
collapsed ``global_coefficients`` expansion when evaluated via
``repro.kernels.kernel_matvec``, whose Pallas matvec computes in f32 by
its documented TPU contract.)

Serving engines (the query-plan taxonomy; the training-side analogue is
``sn_train``'s color-step scatter plans):

  ``fuse(rule="knn"/"nn", engine=...)`` selects how kNN fusion executes —
  ``"dense"`` (default; this module) evaluates ALL n sensors at all Q
  queries and top-k's a dense (Q, n) distance matrix — O(Q*n*D), the
  independently simple oracle; ``"plan"`` and ``"pallas"`` route through
  the static cell-candidate query plans of ``repro.core.serving``
  (``make_serving_plan``), touching one bounded cell neighborhood per
  query — O(Q*k*D), with ``"pallas"`` fusing the whole select+evaluate
  step per query tile in VMEM (``repro.kernels.knn_fuse``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .sn_train import SNTrainProblem, SNTrainState, effective_coef


@partial(jax.jit, static_argnames=("kernel",))
def _eval_all(kernel, nbr_pos, nbr_mask, coef, xq):
    """f_s(xq) for every sensor s: returns (n+1, Q)."""

    def eval_s(pos_s, mask_s, coef_s):
        k = kernel(xq, pos_s)  # (Q, D)
        return k @ jnp.where(mask_s, coef_s, 0.0)

    return jax.vmap(eval_s)(nbr_pos, nbr_mask, coef)


def evaluate_sensors(
    problem: SNTrainProblem, state: SNTrainState, xq: jax.Array
) -> jax.Array:
    """Per-sensor global estimates at queries: (n, Q), batched (B, n, Q).

    Evaluates the TRUE representer coefficients ``effective_coef`` (the
    solved coordinates rescaled by the forgetting anchor weights); for
    static fields (``beta = 1``) the weights are all ones and this is the
    plain coefficient read.
    """
    xq = jnp.atleast_2d(jnp.asarray(xq, problem.nbr_pos.dtype))
    coef = effective_coef(problem, state)
    if problem.batched:
        preds = jax.vmap(
            lambda np_, nm, cf: _eval_all(problem.kernel, np_, nm, cf, xq)
        )(problem.nbr_pos, problem.nbr_mask, coef)
        return preds[:, : problem.n]
    preds = _eval_all(
        problem.kernel, problem.nbr_pos, problem.nbr_mask, coef, xq
    )
    return preds[: problem.n]


def single_sensor(preds: jax.Array, s: int = 0) -> jax.Array:
    return preds[..., s, :]


def knn_fusion(
    preds: jax.Array, positions: jax.Array, xq: jax.Array, k: int,
    alive: jax.Array | None = None,
) -> jax.Array:
    """Average the k LIVE sensors nearest each query (paper Eq. 19).

    preds: (..., n, Q) per-sensor estimates (any leading field axes); the
    selected sensors depend only on the shared positions, so the top-k runs
    once and broadcasts.  ``alive`` is the optional (n,) row liveness of a
    lifecycle problem — dead/spare rows are pushed to +inf distance so they
    are never selected.  This is the dense O(Q*n) oracle — serving goes
    through ``repro.core.serving.knn_fuse``, which answers the same rule
    from a static cell-candidate plan in O(Q*k).
    """
    xq = jnp.atleast_2d(jnp.asarray(xq, preds.dtype))
    positions = positions.astype(preds.dtype)
    d2 = jnp.sum((xq[:, None, :] - positions[None, :, :]) ** 2, axis=-1)  # (Q, n)
    if alive is not None:
        d2 = jnp.where(alive[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)  # (Q, k)
    pt = jnp.swapaxes(preds, -1, -2)  # (..., Q, n)
    gathered = jnp.take_along_axis(
        pt, jnp.broadcast_to(idx, pt.shape[:-2] + idx.shape), axis=-1
    )  # (..., Q, k)
    if alive is None:
        return jnp.mean(gathered, axis=-1)
    # Fewer than k live sensors: top_k must still return k indices, so the
    # overflow picks +inf-distance (dead) rows — average the live ones only.
    valid = jnp.isfinite(neg)  # (Q, k)
    return jnp.sum(jnp.where(valid, gathered, 0.0), axis=-1) / jnp.maximum(
        jnp.sum(valid, axis=-1), 1
    )


def nearest_neighbor(
    preds: jax.Array, positions: jax.Array, xq: jax.Array,
    alive: jax.Array | None = None,
) -> jax.Array:
    return knn_fusion(preds, positions, xq, k=1, alive=alive)


def network_average(
    preds: jax.Array, alive: jax.Array | None = None
) -> jax.Array:
    if alive is None:
        return jnp.mean(preds, axis=-2)
    w = alive.astype(preds.dtype)
    return (w[:, None] * preds).sum(-2) / w.sum()


def connectivity_averaged(
    preds: jax.Array, degrees: jax.Array, alive: jax.Array | None = None
) -> jax.Array:
    """Degree-weighted average (paper Eq. 20) over the LIVE sensors."""
    w = degrees.astype(preds.dtype)
    if alive is not None:
        w = jnp.where(alive, w, 0.0)
    return (w[:, None] * preds).sum(-2) / w.sum()


def global_coefficients(
    problem: SNTrainProblem, state: SNTrainState, rule: str = "conn"
) -> tuple[jax.Array, jax.Array]:
    """Collapse the per-sensor representers into ONE kernel expansion per
    field:  f(x) = sum_a cglob[a] K(x, anchor_a).

    Exactly equals the network-average ('avg') or connectivity-averaged
    ('conn', Eq. 20) fusion of the per-sensor estimates — every sensor's
    expansion is scattered onto the shared anchor set (the n sensor positions
    followed by the n_stream streaming-arrival positions), so the serving hot
    path is one batched kernel matvec (repro.kernels.kernel_matvec) instead
    of n per-sensor evaluations.

    Returns (anchors, coefs): single-field (A, d), (A,); batched
    (B, A, d), (B, A) with A = n + n_stream.  Dtypes follow the state.
    """
    n = problem.n
    s_cap = problem.n_stream
    cdt = state.coef.dtype
    # Dead/spare rows carry zero fusion weight (and their reserved anchors
    # zero coefficients), so churned problems serve from live sensors only.
    live = problem.alive[:n]
    deg = jnp.where(live, problem.topology.degrees, 0).astype(cdt)
    if rule == "conn":
        w = deg / deg.sum()
    elif rule == "avg":
        w = jnp.where(live, 1.0, 0.0).astype(cdt) / jnp.sum(live)
    else:
        raise ValueError(f"global_coefficients supports 'avg'/'conn', got {rule!r}")
    w_pad = jnp.concatenate([w, jnp.zeros((1,), w.dtype)])  # sentinel sensor row

    positions = problem.topology.positions  # (n, d)
    ids = problem.nbr_idx  # (n+1, D) shared; sentinel row targets n + s_cap

    def one_field(nbr_mask, coef, stream_pos):
        contrib = jnp.where(nbr_mask, coef, 0.0) * w_pad[:, None]  # (n+1, D)
        cglob = (
            jnp.zeros((n + s_cap + 1,), coef.dtype)
            .at[ids.reshape(-1)]
            .add(contrib.reshape(-1))
        )
        anchors = jnp.concatenate([positions.astype(stream_pos.dtype), stream_pos])
        return anchors, cglob[: n + s_cap]

    ecoef = effective_coef(problem, state)  # true representer coefficients
    if problem.batched:
        return jax.vmap(one_field)(
            problem.nbr_mask, ecoef, problem.stream_pos
        )
    return one_field(problem.nbr_mask, ecoef, problem.stream_pos)


def fuse(
    problem: SNTrainProblem,
    state: SNTrainState,
    xq: jax.Array,
    rule: str = "nn",
    *,
    k: int = 1,
    sensor: int = 0,
    engine: str = "dense",
    plan=None,
    ecoef: jax.Array | None = None,
    compute_dtype=None,
    prune: jax.Array | None = None,
    block_q: int | None = None,
) -> jax.Array:
    """Convenience dispatcher over the paper's three rules.

    Returns (Q,) for single-field problems, (B, Q) for batched ones.

    engine: for the kNN rules ("nn"/"knn"), "dense" runs the all-sensors
    oracle in this module; "plan"/"pallas" route through the static query
    plans of ``repro.core.serving`` (pass a prebuilt ``plan`` from
    ``make_serving_plan`` to amortize the host-side precomputation across
    requests).  The other rules are already O(n)-per-query and accept only
    "dense".

    ecoef: optional precomputed ``effective_coef(problem, state)`` for the
    plan/pallas kNN engines — snapshot-serving processes (the daemon)
    compute it once per published snapshot and thread it through every
    query dispatch against that snapshot.

    compute_dtype/prune/block_q: the quantized + sparsified serving path
    (plan/pallas kNN engines only — the dense oracle stays full-precision
    by definition).  ``compute_dtype="bf16"`` stores the anchor tables in
    bf16 (selection-exact; accumulation stays in the coefficient dtype);
    ``prune`` is a (n+1,) ``pruning.prune_mask`` keep mask ANDed into
    liveness; ``block_q`` overrides the Pallas query tile for bulk sweeps.
    """
    if rule in ("nn", "knn") and engine != "dense":
        from . import serving

        return serving.knn_fuse(
            problem, state, xq,
            k=(1 if rule == "nn" else k), plan=plan, engine=engine,
            ecoef=ecoef, compute_dtype=compute_dtype, prune=prune,
            block_q=block_q,
        )
    if ecoef is not None:
        raise ValueError(
            "ecoef precomputation applies to the plan/pallas kNN engines "
            f"only; rule {rule!r} engine {engine!r} computes it internally"
        )
    if compute_dtype is not None or prune is not None or block_q is not None:
        raise ValueError(
            "compute_dtype/prune/block_q apply to the plan/pallas kNN "
            f"engines only; rule {rule!r} engine {engine!r} is the "
            "full-precision dense oracle"
        )
    if engine != "dense":
        raise ValueError(
            f"engine={engine!r} applies to the kNN rules only; "
            f"rule {rule!r} supports engine='dense'"
        )
    preds = evaluate_sensors(problem, state, xq)
    live = problem.alive[: problem.n]
    if rule == "single":
        return single_sensor(preds, sensor)
    if rule == "nn":
        return nearest_neighbor(preds, problem.topology.positions, xq, live)
    if rule == "knn":
        return knn_fusion(preds, problem.topology.positions, xq, k, live)
    if rule == "avg":
        return network_average(preds, live)
    if rule == "conn":
        return connectivity_averaged(preds, problem.topology.degrees, live)
    raise ValueError(f"unknown fusion rule {rule!r}")
