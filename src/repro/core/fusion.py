"""Fusion-center aggregation rules (paper Sec. 3.3 'Aggregation').

After SN-Train, every sensor holds a *global* field estimate
``f_s(x) = sum_{j in N_s} c_{s,j} K(x, x_j)``.  The fusion center combines
them with one of three strategies from the paper:

  * single-sensor:         f(x) = f_s(x) for one arbitrary sensor s
  * k-nearest-neighbor:    f(x) = mean_{s in kNN(x)} f_s(x)        (Eq. 19)
  * connectivity-averaged: f(x) = sum_s |N_s| f_s(x) / sum_s |N_s| (Eq. 20)

k = 1 is "nearest neighbor", k = n is the plain network average.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .sn_train import SNTrainProblem, SNTrainState


@partial(jax.jit, static_argnames=("kernel",))
def _eval_all(kernel, nbr_pos, nbr_mask, coef, xq):
    """f_s(xq) for every sensor s: returns (n+1, Q)."""

    def eval_s(pos_s, mask_s, coef_s):
        k = kernel(xq, pos_s)  # (Q, D)
        return k @ jnp.where(mask_s, coef_s, 0.0)

    return jax.vmap(eval_s)(nbr_pos, nbr_mask, coef)


def evaluate_sensors(
    problem: SNTrainProblem, state: SNTrainState, xq: jax.Array
) -> jax.Array:
    """Per-sensor global estimates at queries: (n, Q)."""
    xq = jnp.atleast_2d(jnp.asarray(xq, jnp.float32))
    preds = _eval_all(
        problem.kernel, problem.nbr_pos, problem.nbr_mask, state.coef, xq
    )
    return preds[: problem.n]


def single_sensor(preds: jax.Array, s: int = 0) -> jax.Array:
    return preds[s]


def knn_fusion(
    preds: jax.Array, positions: jax.Array, xq: jax.Array, k: int
) -> jax.Array:
    """Average the k sensors nearest each query (paper Eq. 19)."""
    xq = jnp.atleast_2d(jnp.asarray(xq, jnp.float32))
    d2 = jnp.sum((xq[:, None, :] - positions[None, :, :]) ** 2, axis=-1)  # (Q, n)
    _, idx = jax.lax.top_k(-d2, k)  # (Q, k)
    gathered = jnp.take_along_axis(preds.T, idx, axis=1)  # (Q, k)
    return jnp.mean(gathered, axis=1)


def nearest_neighbor(preds: jax.Array, positions: jax.Array, xq: jax.Array) -> jax.Array:
    return knn_fusion(preds, positions, xq, k=1)


def network_average(preds: jax.Array) -> jax.Array:
    return jnp.mean(preds, axis=0)


def connectivity_averaged(preds: jax.Array, degrees: jax.Array) -> jax.Array:
    """Degree-weighted average (paper Eq. 20)."""
    w = degrees.astype(jnp.float32)
    return (w[:, None] * preds).sum(0) / w.sum()


def global_coefficients(
    problem: SNTrainProblem, state: SNTrainState, rule: str = "conn"
) -> tuple[jax.Array, jax.Array]:
    """Collapse the per-sensor representers into ONE kernel expansion per
    field:  f(x) = sum_a cglob[a] K(x, anchor_a).

    Exactly equals the network-average ('avg') or connectivity-averaged
    ('conn', Eq. 20) fusion of the per-sensor estimates — every sensor's
    expansion is scattered onto the shared anchor set (the n sensor positions
    followed by the n_stream streaming-arrival positions), so the serving hot
    path is one batched kernel matvec (repro.kernels.kernel_matvec) instead
    of n per-sensor evaluations.

    Returns (anchors, coefs): single-field (A, d), (A,); batched
    (B, A, d), (B, A) with A = n + n_stream.
    """
    n = problem.n
    s_cap = problem.n_stream
    deg = problem.topology.degrees.astype(jnp.float32)
    if rule == "conn":
        w = deg / deg.sum()
    elif rule == "avg":
        w = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        raise ValueError(f"global_coefficients supports 'avg'/'conn', got {rule!r}")
    w_pad = jnp.concatenate([w, jnp.zeros((1,), w.dtype)])  # sentinel sensor row

    positions = problem.topology.positions  # (n, d)
    ids = problem.nbr_idx  # (n+1, D) shared; sentinel row targets n + s_cap

    def one_field(nbr_mask, coef, stream_pos):
        contrib = jnp.where(nbr_mask, coef, 0.0) * w_pad[:, None]  # (n+1, D)
        cglob = (
            jnp.zeros((n + s_cap + 1,), coef.dtype)
            .at[ids.reshape(-1)]
            .add(contrib.reshape(-1))
        )
        anchors = jnp.concatenate([positions.astype(stream_pos.dtype), stream_pos])
        return anchors, cglob[: n + s_cap]

    if problem.batched:
        return jax.vmap(one_field)(
            problem.nbr_mask, state.coef, problem.stream_pos
        )
    return one_field(problem.nbr_mask, state.coef, problem.stream_pos)


def fuse(
    problem: SNTrainProblem,
    state: SNTrainState,
    xq: jax.Array,
    rule: str = "nn",
    *,
    k: int = 1,
    sensor: int = 0,
) -> jax.Array:
    """Convenience dispatcher over the paper's three rules."""
    preds = evaluate_sensors(problem, state, xq)
    if rule == "single":
        return single_sensor(preds, sensor)
    if rule == "nn":
        return nearest_neighbor(preds, problem.topology.positions, xq)
    if rule == "knn":
        return knn_fusion(preds, problem.topology.positions, xq, k)
    if rule == "avg":
        return network_average(preds)
    if rule == "conn":
        return connectivity_averaged(preds, problem.topology.degrees)
    raise ValueError(f"unknown fusion rule {rule!r}")
