"""Positive (semi-)definite kernels and Gram-matrix helpers.

The paper (Sec. 2.2) anchors everything in an RKHS ``H_K`` induced by a
positive semi-definite kernel ``K``.  Its experiments use the linear kernel
(Case 1) and the Gaussian/RBF kernel (Case 2); we additionally provide
Matern-3/2 and polynomial kernels, which are common field-estimation choices.

All functions are pure jnp and jit/vmap-safe.  ``X`` arrays are ``(n, d)``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def pairwise_sq_dists(x1: jax.Array, x2: jax.Array) -> jax.Array:
    """Squared Euclidean distances, shape (n1, n2).

    Uses the expanded form so it lowers to two matmuls (MXU-friendly) rather
    than an (n1, n2, d) broadcast.
    """
    x1 = jnp.atleast_2d(x1)
    x2 = jnp.atleast_2d(x2)
    sq1 = jnp.sum(x1 * x1, axis=-1)[:, None]
    sq2 = jnp.sum(x2 * x2, axis=-1)[None, :]
    cross = x1 @ x2.T
    return jnp.maximum(sq1 + sq2 - 2.0 * cross, 0.0)


def linear_kernel(x1: jax.Array, x2: jax.Array, *, bias: float = 1.0) -> jax.Array:
    """K(x, x') = x.x' + bias.

    The affine bias term lets the RKHS contain constant offsets, matching the
    paper's Case 1 target eta(x) = 5x + 5 (a pure linear kernel could not
    represent the intercept).
    """
    x1 = jnp.atleast_2d(x1)
    x2 = jnp.atleast_2d(x2)
    return x1 @ x2.T + bias


def rbf_kernel(x1: jax.Array, x2: jax.Array, *, gamma: float = 1.0) -> jax.Array:
    """Gaussian kernel K(x, x') = exp(-gamma * ||x - x'||^2) (paper Example 2)."""
    return jnp.exp(-gamma * pairwise_sq_dists(x1, x2))


def matern32_kernel(x1: jax.Array, x2: jax.Array, *, length: float = 1.0) -> jax.Array:
    """Matern nu=3/2: (1 + sqrt(3) r / l) exp(-sqrt(3) r / l)."""
    r = jnp.sqrt(pairwise_sq_dists(x1, x2) + 1e-12)
    s = jnp.sqrt(3.0) * r / length
    return (1.0 + s) * jnp.exp(-s)


def poly_kernel(
    x1: jax.Array, x2: jax.Array, *, degree: int = 2, bias: float = 1.0
) -> jax.Array:
    return (jnp.atleast_2d(x1) @ jnp.atleast_2d(x2).T + bias) ** degree


_REGISTRY: dict[str, Callable[..., jax.Array]] = {
    "linear": linear_kernel,
    "rbf": rbf_kernel,
    "matern32": matern32_kernel,
    "poly": poly_kernel,
}


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A named kernel + hyperparameters; hashable so it is a static jit arg."""

    name: str = "rbf"
    gamma: float = 1.0  # rbf
    bias: float = 1.0  # linear / poly
    length: float = 1.0  # matern32
    degree: int = 2  # poly

    def __call__(self, x1: jax.Array, x2: jax.Array) -> jax.Array:
        fn = _REGISTRY[self.name]
        if self.name == "rbf":
            return fn(x1, x2, gamma=self.gamma)
        if self.name == "linear":
            return fn(x1, x2, bias=self.bias)
        if self.name == "matern32":
            return fn(x1, x2, length=self.length)
        if self.name == "poly":
            return fn(x1, x2, degree=self.degree, bias=self.bias)
        raise KeyError(self.name)

    def gram(self, x: jax.Array) -> jax.Array:
        """Full (n, n) Gram matrix K(x_i, x_j)."""
        return self(x, x)


@partial(jax.jit, static_argnames=("kernel",))
def gram_matrix(kernel: Kernel, x1: jax.Array, x2: jax.Array) -> jax.Array:
    return kernel(x1, x2)
