"""Convergence watchdog for SN-Train under unreliable links.

The SOP recursion is Fejér monotone under perfect delivery (Lemma 2.1:
``weighted_norm_sq`` never increases along a sweep), but a partial
delivery is NOT a projection — the distributed-RLS stability line
(arXiv:1109.4627 in PAPERS.md) shows these recursions survive imperfect
exchanges yet can drift or diverge at high loss.  ``watch_sweeps`` is
the supervision loop that makes faulty training safe to leave running:

  per round (``sweeps_per_round`` sweeps in one jitted dispatch):
    track    per-field Fejér norm + relative z-residual;
    detect   divergence: a field's norm grew past ``divergence_ratio``
             (or went non-finite) for ``patience`` consecutive rounds;
    retry    the round with FRESH fault draws (bounded by
             ``max_retries``) — the burst that poisoned it is transient;
    escalate to a full factor refactorization
             (``streaming.rebuild_chol``) — heals drifted/corrupted
             cached factors once;
    rollback to the entry snapshot (in-memory, or an on-disk
             ``checkpoint.save_train`` directory) when even fresh
             factors keep diverging — the state is unrecoverable from
             here, restore the last good one bitwise and stop.

Everything device-side is fixed-shape and jitted once: the host loop
only decides WHICH warmed program to call next, so a whole watchdog run
compiles zero programs after warmup regardless of fault rates or how
many retries fire (``benchmarks/fault_bench.py`` counts the caches).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from . import faults as faults_mod
from . import sn_train
from .sn_train import SNTrainProblem, SNTrainState, weighted_norm_sq


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Host-side knobs of ``watch_sweeps`` (all static)."""

    sweeps_per_round: int = 5
    tol: float = 1e-4  # converged: max |dz| / (max |z| + eps) < tol
    divergence_ratio: float = 1.05  # norm growth flagging a round
    patience: int = 2  # consecutive flagged rounds before acting
    max_retries: int = 3  # fresh-draw re-sweeps before escalating
    max_rounds: int = 60


RECEIPT_SCHEMA = "watchdog_receipt/1"


class WatchdogReceipt(NamedTuple):
    """What happened, per field and overall (printed by serve.py)."""

    converged: np.ndarray  # (B,) bool per-field residual < tol
    residual: np.ndarray  # (B,) final relative z-residual per round
    norm: np.ndarray  # (B,) final Fejér norm
    rounds: int  # rounds accepted or retried
    sweeps: int  # total sweeps executed (incl. retried rounds)
    retries: int  # fresh-draw re-sweeps taken
    refactorized: int  # 0/1: rebuild_chol escalations
    rolled_back: bool  # True: state restored from the snapshot
    diverged: np.ndarray  # (B,) bool fields flagged in the final round

    def to_json(self) -> dict:
        """Machine-readable receipt with a STABLE schema.

        Plain JSON types only (per-field arrays become lists), tagged with
        ``schema`` so consumers — the daemon health endpoint,
        ``serve.py --faults`` — can detect drift.  ``receipt_from_json``
        is the exact inverse (round-trip pinned in tests/test_faults.py).
        """
        return {
            "schema": RECEIPT_SCHEMA,
            "converged": [bool(v) for v in np.atleast_1d(self.converged)],
            "residual": [float(v) for v in np.atleast_1d(self.residual)],
            "norm": [float(v) for v in np.atleast_1d(self.norm)],
            "rounds": int(self.rounds),
            "sweeps": int(self.sweeps),
            "retries": int(self.retries),
            "refactorized": int(self.refactorized),
            "rolled_back": bool(self.rolled_back),
            "diverged": [bool(v) for v in np.atleast_1d(self.diverged)],
        }


def receipt_from_json(payload: dict) -> WatchdogReceipt:
    """Rebuild a ``WatchdogReceipt`` from ``WatchdogReceipt.to_json``."""
    schema = payload.get("schema")
    if schema != RECEIPT_SCHEMA:
        raise ValueError(
            f"unknown watchdog receipt schema {schema!r} "
            f"(expected {RECEIPT_SCHEMA!r})"
        )
    return WatchdogReceipt(
        converged=np.asarray(payload["converged"], bool),
        residual=np.asarray(payload["residual"], float),
        norm=np.asarray(payload["norm"], float),
        rounds=int(payload["rounds"]),
        sweeps=int(payload["sweeps"]),
        retries=int(payload["retries"]),
        refactorized=int(payload["refactorized"]),
        rolled_back=bool(payload["rolled_back"]),
        diverged=np.asarray(payload["diverged"], bool),
    )


@jax.jit
def _round_metrics(problem, state_old, state_new):
    """(Fejér norm of state_new, per-field relative z-residual)."""
    norm = weighted_norm_sq(problem, state_new)
    num = jnp.max(jnp.abs(state_new.z - state_old.z), axis=-1)
    den = jnp.max(jnp.abs(state_old.z), axis=-1) + 1e-12
    return norm, num / den


def _snapshot(problem, state, directory):
    if directory is None:
        return (problem, state)
    from repro.checkpoint import save_train

    save_train(directory, 0, problem, state)
    return None


def _rollback(problem, state, directory, mem):
    if directory is None:
        return mem
    from repro.checkpoint import restore_train

    return restore_train(directory, 0, problem, state)


def watch_sweeps(
    problem: SNTrainProblem,
    state: SNTrainState,
    *,
    model: "faults_mod.FaultModel | None" = None,
    key: jax.Array | None = None,
    engine: str = "plan",
    config: WatchdogConfig = WatchdogConfig(),
    snapshot_dir: str | None = None,
) -> tuple[SNTrainProblem, SNTrainState, WatchdogReceipt]:
    """Train to convergence under supervision; see the module docstring.

    model/key: fault process to inject (None trains fault-free but still
    watches — useful to detect numerically-poisoned states).  engine:
    any of ``faults.faulty_sweep``'s engines.  snapshot_dir: where the
    entry snapshot lives (None = in-memory); rollback restores it
    bitwise.  Returns the (possibly refactorized or rolled-back)
    problem, the final state, and the receipt.
    """
    if model is not None and key is None:
        raise ValueError("fault injection needs a PRNG key")
    key = jax.random.PRNGKey(0) if key is None else key
    mem = _snapshot(problem, state, snapshot_dir)
    spr = config.sweeps_per_round

    def run_round(problem, state, key):
        key, sub = jax.random.split(key)
        if model is None:
            cand = sn_train.colored_sweep(
                problem, state, n_sweeps=spr, engine=engine
            )
        else:
            cand = faults_mod.faulty_sweep(
                problem, state, model, sub, n_sweeps=spr, engine=engine
            )
        norm, resid = _round_metrics(problem, state, cand)
        return cand, np.atleast_1d(np.asarray(norm)), np.atleast_1d(
            np.asarray(resid)
        ), key

    norm_prev = np.atleast_1d(np.asarray(weighted_norm_sq(problem, state)))
    resid = np.full_like(norm_prev, np.inf)
    diverged = np.zeros(norm_prev.shape, bool)
    flags = retries = refactorized = rounds = sweeps = 0
    rolled_back = False

    for _ in range(config.max_rounds):
        cand, norm_new, resid_new, key = run_round(problem, state, key)
        rounds += 1
        sweeps += spr
        diverged = ~np.isfinite(norm_new) | (
            norm_new > norm_prev * config.divergence_ratio + 1e-9
        )
        if diverged.any():
            flags += 1
            if flags >= config.patience:
                flags = 0
                if retries < config.max_retries:
                    # Discard the poisoned round; the next draw resamples
                    # the fault process (fresh key), so a transient burst
                    # doesn't kill the run.
                    retries += 1
                    continue
                if not refactorized:
                    # Factors may have drifted (streaming float history,
                    # repeated masked solves): rebuild them from the Gram
                    # — the bounded-escalation step.
                    from .streaming import rebuild_chol

                    # The retry budget stays spent: if fresh factors still
                    # diverge for `patience` rounds, roll back immediately.
                    problem = dataclasses.replace(
                        problem, chol=rebuild_chol(problem)
                    )
                    refactorized = 1
                    continue
                # Even fresh factors diverge: restore the entry snapshot
                # bitwise and stop — the caller gets the last good state.
                problem, state = _rollback(problem, state, snapshot_dir, mem)
                rolled_back = True
                break
        else:
            flags = 0
        state = cand
        norm_prev = norm_new
        resid = resid_new
        if (resid < config.tol).all():
            break

    receipt = WatchdogReceipt(
        converged=resid < config.tol,
        residual=resid,
        norm=norm_prev,
        rounds=rounds,
        sweeps=sweeps,
        retries=retries,
        refactorized=refactorized,
        rolled_back=rolled_back,
        diverged=diverged,
    )
    return problem, state, receipt


def format_receipt(receipt: WatchdogReceipt) -> str:
    """One watchdog receipt line for CLI surfaces (serve.py --faults)."""
    n_conv = int(np.sum(receipt.converged))
    n_tot = int(receipt.converged.size)
    status = (
        "ROLLED BACK" if receipt.rolled_back
        else ("converged" if n_conv == n_tot else "partial")
    )
    return (
        f"watchdog: {status} {n_conv}/{n_tot} fields | "
        f"rounds={receipt.rounds} sweeps={receipt.sweeps} "
        f"retries={receipt.retries} refactorized={receipt.refactorized} | "
        f"max residual {float(np.max(receipt.residual)):.3e}"
    )
