"""Unified network-lifecycle plan layer: capacity-padded plans + repair ops.

The paper's operating regime (Sec. 3.3 "Robustness") is an ad-hoc network
whose membership churns: motes die, drain batteries, and get redeployed.
PRs 1-3 grew three *separate* host-side frozen plan builders — the
distance-2 coloring (``topology``), the per-color scatter plans
(``sn_train.make_problem``) and the per-cell kNN candidate lists
(``serving.make_serving_plan``) — so any membership change meant a full
numpy rebuild plus an XLA recompilation.  This module is the shared layer
those three now build on, organized around one idea:

  **capacity padding + a device-side alive mask + incremental repairs.**

Build once at capacity ``n_max`` (spare sensor rows parked far away, one
reserved *singleton color* per spare so a joining sensor never conflicts
with the frozen distance-2 coloring), then mutate membership by flipping
the ``alive`` mask and patching plan *values* on device — never plan
*shapes* — so an arbitrary join/leave/churn trace compiles a constant
number of programs.

Host-side builders (numpy, build time — shared by ``topology.build_topology``
/ ``ring_topology``, ``sn_train.make_problem`` and
``serving.make_serving_plan`` instead of each rolling its own):

  ``padded_neighborhoods``  adjacency -> fixed-shape (n, D) neighbor table;
  ``color_classes``         distance-2 greedy coloring of the base graph
                            plus the spare-color budget (one singleton
                            color per spare row);
  ``assign_stream_slots``   the reserved message-slot layout (every free
                            padded lane owns a fixed global id);
  ``slot_owner_map``        message slot -> owning sensor row (the map that
                            turns row liveness into slot liveness);
  ``build_color_plans``     the per-color scatter plans (moved here from
                            ``sn_train``), skipping rows dead at build;
  ``build_cell_lists``      the serving grid's per-cell candidate lists
                            (moved here from ``serving``), with spare
                            candidate columns and a removal-slack radius.

Device-side repair ops (pure jnp, fixed shapes — jitted by their callers in
``streaming`` / ``serving``; each event touches O(degree) rows, their color
classes and O(1) grid cells):

  ``plan_rows_remove``    revert a batch of rows' scatter codes to "keep"
                          (rows occupy distinct colors, so one scatter);
  ``plan_rows_add``       install a batch of rows' scatter codes;
  ``color_plans_remove``  single-row wrappers of the two above;
  ``color_plans_add``
  ``members_clear``       drop rows from their color-class member lists;
  ``members_set``         insert rows into (empty slots of) member lists;
  ``resolve_join_conflicts``  the symmetric-join recoloring rule: adopters
                          of a joining sensor all gain its message slot as
                          a shared neighbor, so any two same-color adopters
                          now conflict under the distance-2 rule — keep the
                          first of each color, move the rest into reserved
                          EMPTY recolor classes (singletons never conflict);
  ``cells_remove``        drop a sensor from every cell candidate list;
  ``cells_add``           insert a joined sensor into the candidate lists
                          of every cell whose exactness radius covers it.

Color assignment is MUTABLE state under symmetric joins (recoloring moves
sensors between classes), so ``color_of`` / ``member_pos`` and the member
tables live on ``SNTrainProblem`` and are patched by the event ops.
``LifecycleLayout`` keeps only the truly event-invariant metadata (slot
ownership, the pristine slot table for row recycling); the mutable
``alive`` vector lives on ``SNTrainProblem`` directly.  See ``sn_train``
for how the sweep engines consume ``alive`` and ``streaming.add_sensor`` /
``remove_sensor`` for the event ops.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

# Spare rows park here until a join gives them a real position: far enough
# that an RBF kernel underflows to 0 and no in-domain query ever selects
# them, near enough that f32 squared distances stay finite.
FAR = 1.0e6


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LifecycleLayout:
    """Event-invariant lifecycle metadata of a capacity-padded problem.

    All arrays are device-side and fixed at build; repairs read them but
    never write them.  ``n`` below is the padded capacity (``n_max``), and
    row ids in ``[n_base, n)`` are the spare rows joins may occupy.
    (Color assignment used to live here too; symmetric joins recolor
    sensors at runtime, so ``color_of`` / ``member_pos`` and the member
    tables are mutable ``SNTrainProblem`` state now.)

    Attributes:
      slot_owner: (n_z,) int32 owning sensor row per message slot: sensor
                  slots own themselves, reserved slots belong to the row
                  whose free lane they back, the sentinel owns itself via
                  the sentinel row ``n``.
      nbr_idx0:   (n+1, D) int32 pristine build-time slot table — the
                  reserved ids a recycled spare row restores its free
                  lanes from, and the per-row reserved-id pool a lane
                  DELETION (neighbor removal) restores freed lanes from.
      n_base:     static int, number of real (build-time) sensors.
    """

    slot_owner: jnp.ndarray
    nbr_idx0: jnp.ndarray
    n_base: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_spare(self) -> int:
        """Capacity reserved for joins (rows [n_base, n))."""
        return int(self.nbr_idx0.shape[0]) - 1 - self.n_base


# ---------------------------------------------------------------------------
# Host-side builders (numpy, build time).
# ---------------------------------------------------------------------------


def padded_neighborhoods(
    adj: np.ndarray, d_max: int | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fixed-shape neighbor table of a bool adjacency (self loops included).

    Rows with no neighbors at all (spare rows) get degree 0 and a fully
    masked row padded with the row's own index.  Returns
    ``(nbr_idx (n, D) int32, nbr_mask (n, D) bool, degrees (n,) int32)``.
    """
    n = adj.shape[0]
    degrees = adj.sum(axis=1).astype(np.int32)
    dm = int(degrees.max()) if d_max is None else int(d_max)
    if dm < int(degrees.max()):
        raise ValueError(f"d_max={dm} < max degree {int(degrees.max())}")
    nbr_idx = np.zeros((n, dm), dtype=np.int32)
    nbr_mask = np.zeros((n, dm), dtype=bool)
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        nbr_idx[i, : len(nbrs)] = nbrs
        nbr_idx[i, len(nbrs):] = i  # pad with self (masked)
        nbr_mask[i, : len(nbrs)] = True
    return nbr_idx, nbr_mask, degrees


def color_classes(
    adj: np.ndarray, greedy_coloring, n_spare: int = 0, n_recolor: int = 0
) -> tuple[np.ndarray, int, np.ndarray, np.ndarray]:
    """Distance-2 color classes of the base graph + the spare-color budgets.

    The first ``n_base`` rows of ``adj`` are colored greedily on G^2 (two
    sensors conflict iff they share a neighbor).  Each of the ``n_spare``
    spare rows is then assigned its own reserved *singleton* color: a
    sensor joining at ANY position updates alone in its color step, so the
    frozen coloring never needs revalidation under churn.  ``n_recolor``
    appends that many EMPTY reserved classes — the recolor pool symmetric
    joins move conflicting adopters into (see
    ``resolve_join_conflicts``); a sensor parked alone in one can never
    conflict again, and the class frees itself when that sensor leaves.

    Returns ``(colors (n,), n_colors, color_members (n_colors, M),
    color_mask (n_colors, M))`` with ``n = n_base + n_spare``, members
    padded with ``n`` (the sentinel row id).  Membership means "this row
    participates in the class's color step", so spare singleton classes
    and the recolor pool start EMPTY — ``streaming.add_sensor`` installs
    a member on join / recolor, ``remove_sensor`` clears it — and a
    join -> leave round trip restores the tables bitwise.
    """
    n_base = adj.shape[0]
    g2 = (adj.astype(np.int64) @ adj.astype(np.int64)) > 0
    base_colors, n_base_colors = greedy_coloring(g2)
    n = n_base + n_spare
    colors = np.concatenate(
        [base_colors, n_base_colors + np.arange(n_spare, dtype=np.int32)]
    ).astype(np.int32)
    n_colors = n_base_colors + n_spare + n_recolor
    max_members = max(
        int(np.bincount(base_colors, minlength=n_base_colors).max()),
        1 if (n_spare or n_recolor) else 0,
    )
    color_members = np.full((n_colors, max_members), n, dtype=np.int32)
    color_mask = np.zeros((n_colors, max_members), dtype=bool)
    for c in range(n_base_colors):
        members = np.nonzero(colors == c)[0]
        color_members[c, : len(members)] = members
        color_mask[c, : len(members)] = True
    return colors, n_colors, color_members, color_mask


def assign_stream_slots(
    nbr_idx: np.ndarray, degrees: np.ndarray
) -> tuple[np.ndarray, int]:
    """Reserve a fixed global message id for every free padded lane.

    Returns ``(idx_full (n+1, D) int32, n_stream)``: row ``i``'s free
    lanes ``[deg_i, D)`` hold the reserved ids ``n + offset_i + ...`` and
    the appended sentinel row points every lane at the write sentinel
    ``n + n_stream``.  Spare rows (degree 0) reserve the full lane budget,
    which doubles as their join capacity.
    """
    n, d_max = nbr_idx.shape
    deg = np.asarray(degrees)
    free = d_max - deg
    n_stream = int(free.sum())
    sentinel = n + n_stream
    offsets = n + np.concatenate([[0], np.cumsum(free)[:-1]])
    idx_np = np.asarray(nbr_idx).copy()
    for i in range(n):
        idx_np[i, deg[i]:] = offsets[i] + np.arange(free[i])
    return (
        np.concatenate([idx_np, np.full((1, d_max), sentinel)]).astype(
            np.int32
        ),
        n_stream,
    )


def slot_owner_map(idx_full: np.ndarray, n_stream: int) -> np.ndarray:
    """(n_z,) int32: the sensor row whose liveness governs each slot.

    Sensor slots own themselves; each reserved slot belongs to the row
    whose free lane it backs (a sensor's absorbed arrivals die with it);
    the sentinel belongs to the sentinel row ``n``.
    """
    n = idx_full.shape[0] - 1
    owner = np.arange(n + n_stream + 1, dtype=np.int32)
    owner[n:] = n  # sentinel default
    for i in range(n):
        stream = idx_full[i][idx_full[i] >= n]
        owner[stream] = i
    owner[n + n_stream] = n
    return owner


def build_color_plans(
    color_members: np.ndarray,
    color_mask: np.ndarray,
    idx_full: np.ndarray,
    n_stream: int,
    alive0: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side static scatter plans, one per color class.

    (Moved from ``sn_train._build_color_plans``.)  The distance-2 coloring
    guarantees that within a color every touched message slot and every
    touched coefficient row has exactly one source, so the color-step
    update is a permutation gather:

      plan_z[c][j]    = j               keep z[j], or
                      = n_z + m*D + k   slot j is owned by lane k of the
                                        color's m-th member;
      plan_coef[c][r] = r               keep coef row r, or
                      = (n+1) + m       row r is the color's m-th member.

    Rows dead at build (the spare rows, ``alive0`` False) start at "keep"
    on every lane — ``plans.color_plans_add`` installs their scatter codes
    on device when a join occupies them, and ``color_plans_remove``
    reverts on leave.  The sentinel slot and sentinel coefficient row
    always KEEP (they are invariantly zero; the one-hot reference engine
    writes zeros there, so both realizations agree bit-for-bit).  Codes
    always reference flat positions < n_z + M_max*D, so the same plan
    applies when a caller pads the member list wider (sharded_sweep pads
    to a device multiple).
    """
    n, d_max = idx_full.shape
    n = n - 1
    n_z = n + n_stream + 1
    members = np.asarray(color_members)
    cmask = np.asarray(color_mask)
    alive0 = np.asarray(alive0, bool)
    n_colors, _ = members.shape
    plan_z = np.tile(np.arange(n_z, dtype=np.int32), (n_colors, 1))
    plan_coef = np.tile(np.arange(n + 1, dtype=np.int32), (n_colors, 1))
    for c in range(n_colors):
        m_pos = np.nonzero(cmask[c])[0]  # positions of real members
        mem = members[c, m_pos]
        live = alive0[mem]
        m_pos, mem = m_pos[live], mem[live]
        plan_coef[c, mem] = (n + 1) + m_pos
        slots = idx_full[mem]  # (m_live, D) unique ids (no sentinel)
        flat = m_pos[:, None] * d_max + np.arange(d_max)[None, :]
        plan_z[c, slots.reshape(-1)] = n_z + flat.reshape(-1)
    # The sentinel slot / sentinel coefficient row ALWAYS keep, even when a
    # row's lane was retired to the sentinel id (a base-neighbor removal
    # with no reserved id left to restore): the lane is masked everywhere,
    # its update is exactly 0, and forcing "keep" here (mirrored by
    # ``plan_rows_add``) keeps the plan deterministic and host == device.
    plan_z[:, n_z - 1] = n_z - 1
    plan_coef[:, n] = n
    return plan_z, plan_coef


def build_layout(
    idx_full: np.ndarray, n_stream: int, n_base: int
) -> LifecycleLayout:
    """Assemble the device-side ``LifecycleLayout`` from the host builders."""
    return LifecycleLayout(
        slot_owner=jnp.asarray(slot_owner_map(idx_full, n_stream)),
        nbr_idx0=jnp.asarray(idx_full, jnp.int32),
        n_base=int(n_base),
    )


def color_assignments(
    colors: np.ndarray, color_members: np.ndarray, color_mask: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side initial (color_of (n+1,), member_pos (n+1,)) assignment.

    These become MUTABLE ``SNTrainProblem`` state: symmetric joins recolor
    conflicting adopters into the reserved recolor classes.  The sentinel
    row holds ``n_colors``, an out-of-range placeholder (device repairs
    that read it are index-clipped and value-gated, so it is inert).
    """
    n = colors.shape[0]
    n_colors = color_members.shape[0]
    color_of = np.concatenate([np.asarray(colors), [n_colors]]).astype(np.int32)
    member_pos = np.zeros(n + 1, dtype=np.int32)
    members = np.asarray(color_members)
    cmask = np.asarray(color_mask)
    for c in range(n_colors):
        m_pos = np.nonzero(cmask[c])[0]
        member_pos[members[c, m_pos]] = m_pos
    return color_of, member_pos


def build_cell_lists(
    pos: np.ndarray,
    live: np.ndarray,
    k: int,
    cells_per_dim: int | None,
    lo,
    hi,
    spare: int = 0,
    slack: int = 0,
) -> dict:
    """Host-side serving-grid precompute (moved from ``make_serving_plan``).

    Buckets the LIVE sensors into a uniform grid and computes per-cell
    padded candidate lists with the covering-bound radius
    ``d_{k+slack} + 2h`` (center's (k+slack)-th live-sensor distance plus
    twice the cell half-diagonal): exact kNN for in-domain queries, and
    still exact after up to ``slack`` of any cell's candidates are removed
    (removals never shrink the radius; adds are covered because a new
    in-radius sensor is inserted by ``cells_add``).  ``spare`` reserves
    extra padded candidate columns for those future inserts.

    Returns the grid dict consumed by ``serving.make_serving_plan``.
    """
    pos = np.asarray(pos, np.float64)
    live = np.asarray(live, bool)
    lpos = pos[live]
    n, d = pos.shape
    n_live = lpos.shape[0]
    kk = int(min(k + slack, n_live))
    lo = lpos.min(axis=0) if lo is None else np.broadcast_to(
        np.asarray(lo, np.float64), (d,)
    )
    hi = lpos.max(axis=0) if hi is None else np.broadcast_to(
        np.asarray(hi, np.float64), (d,)
    )
    span = np.maximum(hi - lo, 1e-6)
    if cells_per_dim is None:
        cells_per_dim = max(1, int(round((n_live / 4.0) ** (1.0 / d))))
    g = int(cells_per_dim)
    cell = span / g
    half_diag = 0.5 * float(np.linalg.norm(cell))

    grid_shape = (g,) * d
    n_cells = g**d
    centers = np.stack(
        np.meshgrid(
            *[lo[j] + (np.arange(g) + 0.5) * cell[j] for j in range(d)],
            indexing="ij",
        ),
        axis=-1,
    ).reshape(n_cells, d)

    # d(center, s) for every (cell, live sensor): O(C*n) host work,
    # build-time only (same budget class as the coloring / scatter plans).
    dc = np.sqrt(
        np.maximum(
            np.sum((centers[:, None, :] - lpos[None, :, :]) ** 2, axis=-1),
            0.0,
        )
    )  # (C, n_live)
    d_k = np.sort(dc, axis=1)[:, kk - 1]  # (C,) (k+slack)-th nearest
    radius = d_k + 2.0 * half_diag + 1e-7  # exactness bound, see above
    member = dc <= radius[:, None]  # (C, n_live)

    live_ids = np.nonzero(live)[0]
    k_max = int(member.sum(axis=1).max()) + int(spare)
    cells = np.full((n_cells, k_max), n, dtype=np.int32)  # sentinel pad
    mask = np.zeros((n_cells, k_max), dtype=bool)
    for c in range(n_cells):
        ids = live_ids[np.nonzero(member[c])[0]]
        cells[c, : len(ids)] = ids
        mask[c, : len(ids)] = True
    return dict(
        origin=lo,
        cell=cell,
        centers=centers,
        radii=radius,
        cells=cells,
        mask=mask,
        grid_shape=grid_shape,
    )


# ---------------------------------------------------------------------------
# Device-side repair ops (fixed shapes; each event touches O(degree) rows,
# their color classes and O(1) grid cells).  All are pure and gate on traced
# bools so callers can fuse them into one jitted event program.  Gated-off
# entries (and index-clipped reads from the sentinel row's out-of-range
# color) always write back the value just read, so they are exact no-ops
# even under scatter-duplicate index collisions.
# ---------------------------------------------------------------------------


def plan_rows_remove(
    plan_z: jax.Array,
    plan_coef: jax.Array,
    colors_r: jax.Array,
    slots_r: jax.Array,
    idx_rows: jax.Array,
    gate_r: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Revert R rows' scatter codes to "keep" in their colors' plans.

    ``colors_r`` / ``slots_r`` (R,), ``idx_rows`` (R, D) the rows' CURRENT
    slot tables, ``gate_r`` (R,) bool.  Scatter-collision contract: any
    two gated rows must either occupy DISTINCT colors or have DISJOINT
    slot tables (the scatter targets are ``(color, slot-id)`` pairs).
    Both callers satisfy it: a removal repairs the departed sensor's
    neighbors, whose colors are pairwise distinct (two same-color rows
    sharing a neighbor would already violate the distance-2 coloring); a
    join repairs the newcomer's adopters with their PRE-join colors and
    tables, where same-color adopters can coexist (the very conflict
    ``resolve_join_conflicts`` is about to fix) but then their pre-join
    tables are disjoint, because the pre-join coloring is still valid.
    One (R*D)-sized scatter per plan table.
    """
    keep_z = jnp.where(gate_r[:, None], idx_rows, 0)
    rows = jnp.broadcast_to(colors_r[:, None], idx_rows.shape)
    cur = plan_z[rows, idx_rows]
    plan_z = plan_z.at[rows, idx_rows].set(
        jnp.where(gate_r[:, None], keep_z, cur).astype(plan_z.dtype)
    )
    curc = plan_coef[colors_r, slots_r]
    plan_coef = plan_coef.at[colors_r, slots_r].set(
        jnp.where(gate_r, slots_r, curc).astype(plan_coef.dtype)
    )
    return plan_z, plan_coef


def plan_rows_add(
    plan_z: jax.Array,
    plan_coef: jax.Array,
    colors_r: jax.Array,
    m_pos_r: jax.Array,
    slots_r: jax.Array,
    idx_rows: jax.Array,
    gate_r: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Install R rows' scatter codes (the inverse of ``plan_rows_remove``).

    Codes follow ``build_color_plans``: slot ``idx_rows[r, k]`` takes
    ``n_z + m*D + k`` with ``m = m_pos_r[r]``, and the coefficient row
    takes ``(n+1) + m``.  Lanes retired to the sentinel slot id stay at
    "keep" (their update is identically zero; see ``build_color_plans``).
    Same scatter-collision contract as ``plan_rows_remove`` — and the
    POST-repair state a join installs here is strictly distinct-colors
    (recoloring has already separated same-color adopters).
    """
    n_z = plan_z.shape[1]
    r, d = idx_rows.shape
    codes = n_z + m_pos_r[:, None] * d + jnp.arange(d, dtype=jnp.int32)[None]
    codes = jnp.where(idx_rows == n_z - 1, idx_rows, codes)  # sentinel keeps
    rows = jnp.broadcast_to(colors_r[:, None], idx_rows.shape)
    cur = plan_z[rows, idx_rows]
    plan_z = plan_z.at[rows, idx_rows].set(
        jnp.where(gate_r[:, None], codes, cur).astype(plan_z.dtype)
    )
    n_rows = plan_coef.shape[1]
    curc = plan_coef[colors_r, slots_r]
    plan_coef = plan_coef.at[colors_r, slots_r].set(
        jnp.where(gate_r, n_rows + m_pos_r, curc).astype(plan_coef.dtype)
    )
    return plan_z, plan_coef


def color_plans_remove(
    plan_z: jax.Array,
    plan_coef: jax.Array,
    color_of: jax.Array,
    slot: jax.Array,
    idx_row: jax.Array,
    gate: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single-row wrapper of ``plan_rows_remove`` (reads the row's color)."""
    slot = jnp.asarray(slot, jnp.int32)
    return plan_rows_remove(
        plan_z, plan_coef, color_of[slot][None], slot[None], idx_row[None],
        jnp.asarray(gate, bool)[None],
    )


def color_plans_add(
    plan_z: jax.Array,
    plan_coef: jax.Array,
    color_of: jax.Array,
    member_pos: jax.Array,
    slot: jax.Array,
    idx_row: jax.Array,
    gate: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single-row wrapper of ``plan_rows_add`` (reads color + position)."""
    slot = jnp.asarray(slot, jnp.int32)
    return plan_rows_add(
        plan_z, plan_coef, color_of[slot][None], member_pos[slot][None],
        slot[None], idx_row[None], jnp.asarray(gate, bool)[None],
    )


def _member_hits(
    shape: tuple, colors_r: jax.Array, m_pos_r: jax.Array, gate_r: jax.Array
) -> jax.Array:
    """(n_colors, M, R) bool: entry (c, m) addressed by gated row r."""
    c_ax = jnp.arange(shape[0])[:, None, None]
    m_ax = jnp.arange(shape[1])[None, :, None]
    return (
        (c_ax == colors_r[None, None, :])
        & (m_ax == m_pos_r[None, None, :])
        & gate_r[None, None, :]
    )


def members_clear(
    color_members: jax.Array,
    color_mask: jax.Array,
    colors_r: jax.Array,
    m_pos_r: jax.Array,
    gate_r: jax.Array,
    sentinel: int,
) -> tuple[jax.Array, jax.Array]:
    """Clear R member-table entries ((colors_r[r], m_pos_r[r]) each).

    Realized as a full-table masked update (deterministic under any index
    collision of the gated-off rows); tables are (n_colors, M_max), so this
    is the same O(n_colors * M) budget class as one color plan row.
    """
    hit = _member_hits(color_members.shape, colors_r, m_pos_r, gate_r).any(-1)
    return (
        jnp.where(hit, jnp.asarray(sentinel, color_members.dtype), color_members),
        color_mask & ~hit,
    )


def members_set(
    color_members: jax.Array,
    color_mask: jax.Array,
    colors_r: jax.Array,
    m_pos_r: jax.Array,
    slots_r: jax.Array,
    gate_r: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Install R member-table entries (entry (colors_r[r], m_pos_r[r]) takes
    row id ``slots_r[r]``).  Gated target positions must be distinct and
    currently empty (the recolor pool / singleton-class contract)."""
    hit = _member_hits(color_members.shape, colors_r, m_pos_r, gate_r)
    val = jnp.sum(hit * slots_r[None, None, :], axis=-1)
    any_hit = hit.any(-1)
    return (
        jnp.where(any_hit, val.astype(color_members.dtype), color_members),
        color_mask | any_hit,
    )


def resolve_join_conflicts(
    color_of: jax.Array,
    color_mask: jax.Array,
    adopters: jax.Array,
    valid: jax.Array,
    recolor_start: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Conflict-aware recoloring of a symmetric join's adopters.

    Post-join, every adopter's neighborhood contains the newcomer's message
    slot, so any two same-color adopters violate the distance-2 rule (their
    color-step scatters would both write that slot).  No other pair is
    affected: non-adopters' neighborhoods are unchanged and the newcomer
    updates alone in its reserved singleton color.  The repair keeps the
    FIRST adopter of each color in place and moves the rest into empty
    reserved recolor classes (``recolor_start`` onward — build the topology
    with ``n_recolor`` budget): a sensor alone in a class can never
    conflict again, so each sensor moves at most once, and a class frees
    itself when its occupant leaves.

    Returns ``(new_colors (A,), moved (A,) bool, feasible () bool)`` —
    ``feasible`` is False when the pool has fewer empty classes than
    conflicts (the caller must then DROP the join).
    """
    a = adopters.shape[0]
    c = color_of[adopters]  # (A,)
    same = (c[:, None] == c[None, :]) & valid[:, None] & valid[None, :]
    earlier = jnp.tril(jnp.ones((a, a), bool), k=-1)
    moved = (same & earlier).any(axis=1)  # not the first of its color
    free = ~color_mask[recolor_start:].any(axis=1)  # (R,) empty pool classes
    rank = jnp.cumsum(moved.astype(jnp.int32))  # 1-based rank among moves
    csum = jnp.cumsum(free.astype(jnp.int32))
    pick = jnp.searchsorted(csum, rank)  # rank-th empty class (when feasible)
    new_c = jnp.where(moved, recolor_start + pick, c)
    feasible = jnp.sum(moved) <= jnp.sum(free)
    return new_c.astype(color_of.dtype), moved, feasible


def cells_remove(
    cells: jax.Array, cell_mask: jax.Array, slot: jax.Array, gate: jax.Array
) -> jax.Array:
    """Mask sensor ``slot`` out of every cell candidate list.

    One fixed-shape compare over the (C, K_max) table; the freed columns
    become holes a later ``cells_add`` reuses.
    """
    return cell_mask & ~((cells == slot) & gate)


def cells_add(
    cells: jax.Array,
    cell_mask: jax.Array,
    centers: jax.Array,
    radii: jax.Array,
    x: jax.Array,
    slot: jax.Array,
    gate: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Insert a joined sensor at ``x`` into every covering cell's list.

    A cell must list the sensor iff it can appear among the exact kNN of
    some in-cell query, i.e. iff ``|x - center| <= radius`` (the build-time
    covering bound; adds only shrink true kNN distances, so the bound stays
    valid).  The sensor takes the first free candidate column of each such
    cell; cells whose rows are full are skipped and counted in the returned
    ``overflowed`` scalar (build the plan with more ``spare`` columns if it
    is ever nonzero).
    """
    d2 = jnp.sum((centers - x[None, :]) ** 2, axis=-1)  # (C,)
    want = gate & (d2 <= radii**2)  # (C,)
    free_col = jnp.argmin(cell_mask, axis=1)  # first False per cell
    has_free = ~jnp.take_along_axis(
        cell_mask, free_col[:, None], axis=1
    )[:, 0]
    do = want & has_free
    rows = jnp.arange(cells.shape[0])
    cur = jnp.take_along_axis(cells, free_col[:, None], axis=1)[:, 0]
    new_id = jnp.where(do, slot, cur).astype(cells.dtype)
    cells = cells.at[rows, free_col].set(new_id)
    cur_m = jnp.take_along_axis(cell_mask, free_col[:, None], axis=1)[:, 0]
    cell_mask = cell_mask.at[rows, free_col].set(jnp.where(do, True, cur_m))
    return cells, cell_mask, jnp.sum(want & ~has_free)


def alive_slots(alive: jax.Array, slot_owner: jax.Array) -> jax.Array:
    """(n_z,) message-slot liveness from (n+1,) row liveness."""
    return alive[slot_owner]


def degree_headroom(
    degrees: jax.Array, alive: jax.Array, d_max: int
) -> jax.Array:
    """(n,) free reciprocal-anchor lanes per live row (0 for dead rows).

    A symmetric join adopts a candidate only if the candidate's row has a
    lane to spare for the reciprocal anchor (``degrees < d_max``); rows at
    zero headroom are skipped and the coupling is silently lost relative
    to a from-scratch build (``streaming.JoinReceipt.skipped`` reports
    them per event).  Check this BEFORE a churn campaign: any live row at
    0 means joins near it will drop edges — rebuild the topology with
    d_max headroom, or evict arrivals to free lanes.
    """
    alive = jnp.asarray(alive, bool)[: degrees.shape[0]]
    free = jnp.asarray(d_max, degrees.dtype) - degrees
    return jnp.where(alive, jnp.maximum(free, 0), 0).astype(degrees.dtype)
