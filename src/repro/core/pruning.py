"""Representer pruning — sparsify the serving path by coefficient energy.

The serving read-out answers a query by averaging the k nearest LIVE
sensors' local representers f_s(x) = sum_j c_{s,j} K(x, x_j) (paper
Eq. 19).  After training — and especially after beta-forgetting decay,
evictions, and churn — many sensors carry near-zero effective coefficients:
they still occupy candidate-list columns (``ServingPlan.cells`` is padded
to ``K_max`` = the widest cell, inflated further by ``spare``/``slack``
lifecycle capacity), so every query tile gathers and masks them for no
accuracy.  This module scores sensors by coefficient energy and drops the
dead weight — the sparse distributed-identification direction
(arXiv:2203.02737 in PAPERS.md) applied to the serving plan.

Energy and the pointwise bound
------------------------------
Per-sensor energy is the masked L1 norm of the TRUE representer
coefficients (``sn_train.effective_coef`` — beta-decay already applied),
maxed over fields:

    E_s = max_b sum_j |ecoef[b, s, j]| * nbr_mask[b, s, j]

For kernels with sup_x K(x, y) <= 1 (rbf, matern32 — the serving kernels)
this bounds the sensor's prediction everywhere: |f_s(x)| <= E_s.  Pruning
a sensor therefore behaves EXACTLY like the sensor dying (it is masked out
of selection; the next-nearest kept sensors take its slots), and the
answer perturbation is bounded by the energies of the sensors that enter
or leave the selected set — ``answer_bound`` computes that bound per query
from the two selections, and the hypothesis tests in
``tests/test_pruning.py`` hold serving to it at every liveness fraction.

Two pruning paths
-----------------
``prune_mask``   device-side fast path: a (n+1,) keep mask ANDed into the
                 ``alive`` gate of every serving engine.  ``energy_tau``
                 is a TRACED scalar, so a long-lived daemon re-prunes on
                 every snapshot publish — fresh coefficients, even a
                 changed tau — with ZERO recompiles.
``prune_plan``   host-side compaction: rebuild the per-cell candidate
                 lists with pruned/dead sensors removed and left-packed,
                 shrinking ``K_max`` to the widest SURVIVING cell (+
                 ``spare``).  Gather width and plan memory drop; use it
                 offline, at daemon startup, or whenever a smaller kernel
                 launch is worth a one-time host pass + recompile.

Composition with the lifecycle: churn repairs (``plan_add_sensor`` /
``plan_remove_sensor``) operate on the UNPRUNED capacity plan; the keep
mask is re-derived on top after every event (a compacted plan has no spare
columns for joins — treat it as serving-frozen).  ``prune_mask`` ANDs in
``alive``, so a pruned-out dead sensor can never be resurrected by later
churn: only a genuinely re-joined (alive, energetic) row re-enters.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .sn_train import SNTrainProblem, SNTrainState, effective_coef


@jax.jit
def _lane_energy(nbr_mask, ecoef):
    """(n+1, D) per-sensor per-lane |coef|, masked, maxed over fields."""
    e = jnp.abs(ecoef) * (nbr_mask != 0)
    return e.max(axis=0) if e.ndim == 3 else e


def representer_energy(
    problem: SNTrainProblem,
    state: SNTrainState | None = None,
    *,
    ecoef: jax.Array | None = None,
    per_lane: bool = False,
) -> jax.Array:
    """Per-sensor coefficient energy E_s, (n+1,) (or (n+1, D) per-lane).

    E_s = max over fields of the masked L1 norm of the sensor's effective
    coefficients.  For kernels bounded by 1 (rbf/matern32) E_s bounds the
    sensor's prediction magnitude everywhere: |f_s(x)| <= E_s.  Pass
    ``ecoef`` when a snapshot already precomputed ``effective_coef``.
    """
    if ecoef is None:
        if state is None:
            raise ValueError("representer_energy needs state or ecoef")
        ecoef = effective_coef(problem, state)
    lane = _lane_energy(problem.nbr_mask, ecoef)
    return lane if per_lane else lane.sum(axis=-1)


@jax.jit
def _keep_mask(nbr_mask, alive, ecoef, tau):
    e = _lane_energy(nbr_mask, ecoef).sum(axis=-1)
    return (e > tau.astype(e.dtype)) & (alive != 0)


def prune_mask(
    problem: SNTrainProblem,
    state: SNTrainState | None = None,
    *,
    energy_tau,
    ecoef: jax.Array | None = None,
) -> jax.Array:
    """(n+1,) bool keep mask: alive AND energy above ``energy_tau``.

    The device-side fast path: AND this into the serving ``alive`` gate
    (``serving.knn_fuse(..., prune=keep)`` does exactly that).  Shapes are
    static and ``energy_tau`` is traced, so re-pruning per snapshot publish
    — or sweeping tau — compiles nothing after the first call.  Dead rows
    (including the sentinel) are never kept, so pruning composes with
    churn: a pruned-out removed sensor stays out until an actual re-join
    makes it alive and energetic again.
    """
    if ecoef is None:
        if state is None:
            raise ValueError("prune_mask needs state or ecoef")
        ecoef = effective_coef(problem, state)
    # Cast to the energy dtype up front: ``jnp.result_type(float)`` is
    # float64 under JAX_ENABLE_X64 and would thread a strong f64 scalar
    # through an f32 problem.
    tau = jnp.asarray(energy_tau, ecoef.dtype)
    return _keep_mask(problem.nbr_mask, problem.alive, ecoef, tau)


class PruneReport(NamedTuple):
    """Host-side summary of a ``prune_plan`` compaction."""

    n_live: int          # live sensors before pruning
    n_kept: int          # live sensors surviving the energy threshold
    n_pruned: int        # n_live - n_kept
    k_max_before: int    # candidate-list width of the input plan
    k_max_after: int     # width of the compacted plan
    energy_tau: float
    keep: np.ndarray     # (n+1,) bool keep mask (host copy)


def prune_plan(
    problem: SNTrainProblem,
    state: SNTrainState | None,
    plan,
    *,
    energy_tau,
    ecoef: jax.Array | None = None,
    spare: int = 0,
):
    """Compact ``plan``'s candidate lists to the kept sensors only.

    Host-side: pulls the keep mask, drops pruned/dead entries from every
    cell's candidate row, left-packs the survivors, and re-pads to the new
    ``K_max`` = widest surviving cell + ``spare``.  Returns
    ``(compacted_plan, PruneReport)``.

    The compacted plan serves EXACT kNN over the kept subnetwork: pruning
    only deletes candidates, and every kept sensor inside a cell's
    exactness radius remains listed, so top-k over the survivors is the
    true top-k of the pruned network.  Answers are identical to the
    ``prune_mask`` fast path (same surviving candidate sets, same
    tie-breaking).  Compacted plans are serving-frozen: churn repairs
    belong on the unpruned capacity plan, with pruning re-derived on top.
    """
    import dataclasses

    keep_dev = prune_mask(
        problem, state, energy_tau=energy_tau, ecoef=ecoef
    )
    keep = np.asarray(keep_dev)
    cells = np.asarray(plan.cells)
    mask = np.asarray(plan.cell_mask).astype(bool)
    c, k_max = cells.shape
    sentinel = problem.n  # padded problem row n is always masked

    new_mask = mask & keep[cells]
    counts = new_mask.sum(axis=1)
    # never narrower than the plan's nominal k: top_k over the candidate
    # axis needs K_max >= k even when aggressive pruning empties cells
    k_floor = int(getattr(plan, "k", 1))
    k_new = int(max(counts.max(initial=0), k_floor, 1)) + int(spare)
    new_cells = np.full((c, k_new), sentinel, dtype=cells.dtype)
    packed = np.zeros((c, k_new), dtype=bool)
    for i in range(c):
        surv = cells[i, new_mask[i]]
        new_cells[i, : surv.size] = surv
        packed[i, : surv.size] = True

    compacted = dataclasses.replace(
        plan,
        cells=jnp.asarray(new_cells),
        cell_mask=jnp.asarray(packed),
    )
    alive = np.asarray(problem.alive) != 0
    n_live = int(alive[:sentinel].sum())
    n_kept = int(keep[:sentinel].sum())
    report = PruneReport(
        n_live=n_live,
        n_kept=n_kept,
        n_pruned=n_live - n_kept,
        k_max_before=k_max,
        k_max_after=k_new,
        energy_tau=float(energy_tau),
        keep=keep,
    )
    return compacted, report


def answer_bound(
    energy: np.ndarray,
    sel_u: np.ndarray,
    valid_u: np.ndarray,
    sel_p: np.ndarray,
    valid_p: np.ndarray,
) -> np.ndarray:
    """Per-query bound on |unpruned answer - pruned answer|, (Q,).

    Both answers are means of per-sensor predictions over their VALID
    selections; with U/P those selected sets, C = U ∩ P, and v_u/v_p the
    counts, the difference telescopes to

        |u - p| <= |1/v_u - 1/v_p| * sum_{s in C} E_s
                   + (1/v_u) * sum_{s in U \\ C} E_s
                   + (1/v_p) * sum_{s in P \\ C} E_s

    using |f_s(x)| <= E_s (``representer_energy``; exact for sup-1 kernels
    like rbf).  When pruning changes no selection the bound is exactly 0 —
    serving answers are then bitwise-identical.  An empty selection
    contributes 0 (the engines answer 0 there), which the safe reciprocal
    handles.  Host-side / numpy; this is the oracle the hypothesis
    property tests hold serving to, not a hot path.
    """
    energy = np.asarray(energy)
    sel_u, valid_u = np.asarray(sel_u), np.asarray(valid_u).astype(bool)
    sel_p, valid_p = np.asarray(sel_p), np.asarray(valid_p).astype(bool)
    q = sel_u.shape[0]
    out = np.zeros((q,), energy.dtype)
    for i in range(q):
        u = set(sel_u[i, valid_u[i]].tolist())
        p = set(sel_p[i, valid_p[i]].tolist())
        common = u & p
        vu, vp = len(u), len(p)
        inv_u = 1.0 / vu if vu else 0.0
        inv_p = 1.0 / vp if vp else 0.0
        e = lambda s: float(sum(energy[j] for j in s))
        out[i] = (
            abs(inv_u - inv_p) * e(common)
            + inv_u * e(u - common)
            + inv_p * e(p - common)
        )
    return out
