"""Static query plans for kNN-fusion serving (paper Sec. 3.3, Eq. 19).

The paper's testing phase answers a query x by averaging the k sensors
nearest x (kNN fusion — the rule their Sec. 4 simulations show wins for
field estimation).  The dense realization (``fusion.evaluate_sensors`` +
``fusion.knn_fusion``) evaluates ALL n sensors at ALL Q queries and
materializes a (Q, n) distance matrix: O(Q*n*D) compute and O(Q*n) HBM for
an answer that only ever reads k ~ 1..5 sensors per query.

This module applies the same locality that makes SN-Train itself local: a
query's k nearest sensors live in a bounded spatial neighborhood, so
per-query work should be independent of n.  Mirroring the static scatter
plans of ``plans.build_color_plans``, everything data-dependent is
precomputed host-side at problem-build time:

  * the sensor positions are bucketed into a uniform spatial grid;
  * every cell gets a padded **candidate list** — the sensors PROVABLY
    sufficient for exact kNN of any query inside the cell.  With cell
    center m, half-diagonal h and d_k = distance from m to its k-th
    nearest sensor, any in-cell query's k-th neighbor lies within
    d_k + h, and every sensor that close to the query lies within
    d_k + 2h of m — so the candidate set {s : |s - m| <= d_k + 2h}
    is exact, and on bounded-density networks its size is O(k), not O(n).

Serving then touches one cell's candidate row per query:

  ``knn_select``  query -> cell -> masked top-k over K_max candidates;
  ``knn_fuse``    + gather the selected sensors' (D,) representers and
                  evaluate f_s(x) = K(x, N_s) @ c_s locally, O(Q*k*D) total.

Engines (``fusion.fuse(rule="knn", engine=...)`` dispatches here):

  ``"plan"``    the jnp realization of the plan path (any kernel, any
                dtype — the reference the Pallas kernel is tested against);
  ``"pallas"``  the fused VMEM kernel ``repro.kernels.knn_fuse`` (RBF
                only): candidate gather, distance tile, masked top-k
                selection network and the k local (D,) contractions all
                happen per query tile in VMEM — the (n, Q) predictions
                and (Q, n) distances never exist in HBM;
  ``"dense"``   (in ``fusion``) the original all-sensors oracle.

Network lifecycle: the plan's candidate VALUES are device-side data, so
sensor joins/leaves repair them in place (``plan_add_sensor`` /
``plan_remove_sensor``, built on ``repro.core.plans``) with zero host work
and zero recompiles; build with ``spare=`` candidate columns and a
``slack=`` radius so exactness survives churn, and every select path also
gates candidates on the problem's ``alive`` mask.  Symmetric joins mean a
join changes MORE than the candidate lists: every adopting neighbor's
representer grows an anchor at the new position, so the repaired plan's
predictions track the dense oracle through the adopters' changed
functions too (tests/test_lifecycle.py).  When fewer than k candidates
are live, every engine averages the valid selections only — dense, plan
and pallas agree at all liveness fractions, all-dead included
(tests/test_serving.py).

Exactness contract: plans are exact for queries inside the plan's domain
[lo, hi] (default: the LIVE-sensor bounding box, which the paper's query
grids live in).  Queries outside are clipped to the boundary cell for candidate
lookup, so far-field queries degrade gracefully to approximate kNN rather
than erroring.  Distance ties are broken toward the lower sensor index by
every engine (top_k and the selection network both scan ascending), so
engines agree bit-for-bit on the selected set except on exact ties between
equidistant sensors at different indices.

Quantized + sparsified path: ``compute_dtype="bf16"`` stores the anchor
tables — serving's VMEM-dominant operand, O(B*n*D*d) vs O(n*d) for the
sensor positions — in bf16, halving the resident footprint so the Pallas
query tile doubles, with kernel-value arithmetic upconverted to >= f32 in
registers and the representer contraction accumulating in the COEFFICIENT
dtype (f32/f64 — ``ecoef`` is never downcast).  Selection is EXACT under
quantization: queries, positions, distances, and top-k keep full
precision, so both engines select the same sensors as the f32 path
(quantizing selection was measured at ~2.3% field RMSE at n=1000 — over
the 1% budget — vs ~0.1% for anchors-only; ``knn_select_valid`` keeps an
opt-in ``compute_dtype`` for measuring that trade).  ``prune=`` ANDs a
``pruning.prune_mask`` keep mask into the liveness gate so near-zero-energy
representers drop out of selection exactly like dead sensors.
``prune_plan`` (re-exported from ``core.pruning``) compacts the candidate
lists to the kept sensors for a smaller ``K_max``.  Cell lookup
(``query_cells``) always stays full-precision: candidate-list exactness
depends on the query landing in the right cell.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from . import plans
from .sn_train import SNTrainProblem, SNTrainState, effective_coef


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """Frozen-shape query-time plan: uniform grid + per-cell candidate lists.

    Built host-side by ``make_serving_plan``; all arrays are padded to fixed
    shapes so query answering is pure gathers (no data-dependent shapes).
    Under network lifecycle events the candidate VALUES are repaired on
    device (``plan_add_sensor`` / ``plan_remove_sensor`` — no host rebuild,
    no recompile); the shapes never change.

    Attributes:
      origin:    (d,) grid origin (domain lower corner).
      inv_cell:  (d,) reciprocal cell edge lengths.
      centers:   (C, d) cell centers (used by the lifecycle repairs).
      radii:     (C,) per-cell candidate radius (the exactness bound the
                 repairs re-apply when inserting a joined sensor).
      cells:     (C, K_max) int32 candidate sensor ids per flattened cell,
                 padded with n (the sentinel row of the padded problem
                 arrays — always masked).
      cell_mask: (C, K_max) bool validity of ``cells`` entries.
      grid_shape: static per-dim cell counts (prod == C).
      k:         static kNN order the plan guarantees exactness for
                 (queries inside the domain; any k' <= k is also exact).
    """

    origin: jnp.ndarray
    inv_cell: jnp.ndarray
    centers: jnp.ndarray
    radii: jnp.ndarray
    cells: jnp.ndarray
    cell_mask: jnp.ndarray
    grid_shape: tuple = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(default=1, metadata=dict(static=True))

    @property
    def n_cells(self) -> int:
        return int(self.cells.shape[0])

    @property
    def k_max(self) -> int:
        """Padded candidate-list width (max candidates over cells)."""
        return int(self.cells.shape[1])


def make_serving_plan(
    problem: SNTrainProblem,
    *,
    k: int = 8,
    cells_per_dim: int | None = None,
    lo=None,
    hi=None,
    spare: int = 0,
    slack: int = 0,
) -> ServingPlan:
    """Host-side precomputation of the kNN query plan for ``problem``.

    k: largest kNN order the plan must answer exactly (candidate radii are
    computed for this k; serving with any smaller k reuses the same plan).
    cells_per_dim: grid resolution; the default targets ~4 sensors per
    cell so K_max stays O(k) on uniform-density networks.  lo/hi override
    the plan domain (defaults: the LIVE-sensor bounding box) — widen them
    when query grids extend beyond the sensors.

    Lifecycle capacity: ``spare`` reserves extra padded candidate columns
    for ``plan_add_sensor`` inserts, and ``slack`` widens the per-cell
    radius to the (k+slack)-th neighbor so exactness survives up to
    ``slack`` removals from any one cell's candidate list (see
    ``plans.build_cell_lists``).  Dead rows (spares, removed sensors) are
    excluded at build.
    """
    n = problem.n
    k = int(min(k, int(np.asarray(problem.alive[:n]).sum())))
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    grid = plans.build_cell_lists(
        np.asarray(problem.topology.positions),
        np.asarray(problem.alive[:n]),
        k,
        cells_per_dim,
        lo,
        hi,
        spare=spare,
        slack=slack,
    )
    dt = problem.topology.positions.dtype
    return ServingPlan(
        origin=jnp.asarray(grid["origin"], dt),
        inv_cell=jnp.asarray(1.0 / grid["cell"], dt),
        centers=jnp.asarray(grid["centers"], dt),
        radii=jnp.asarray(grid["radii"], dt),
        cells=jnp.asarray(grid["cells"]),
        cell_mask=jnp.asarray(grid["mask"]),
        grid_shape=grid["grid_shape"],
        k=k,
    )


@jax.jit
def plan_remove_sensor(plan: ServingPlan, slot: jax.Array) -> ServingPlan:
    """Lifecycle repair: drop a removed sensor from every candidate list.

    Device-side, fixed shapes, O(C*K_max) compare — pairs with
    ``streaming.remove_sensor``.  Removals never shrink the per-cell
    radius, so exactness holds while at most the plan's build ``slack``
    candidates of any one cell have been removed.
    """
    mask = plans.cells_remove(
        plan.cells, plan.cell_mask, jnp.asarray(slot, plan.cells.dtype), True
    )
    return dataclasses.replace(plan, cell_mask=mask)


@jax.jit
def plan_add_sensor(
    plan: ServingPlan, x: jax.Array, slot: jax.Array
) -> tuple[ServingPlan, jax.Array]:
    """Lifecycle repair: insert a joined sensor into every covering cell.

    Pairs with ``streaming.add_sensor``: the sensor enters the candidate
    list of every cell whose build-time exactness radius covers ``x`` (adds
    only shrink true kNN distances, so the bound stays valid).  Returns
    ``(plan, overflowed)`` where ``overflowed`` counts cells whose candidate
    rows were full — build the plan with more ``spare`` columns if nonzero.
    """
    x = jnp.asarray(x, plan.centers.dtype).reshape(-1)
    cells, mask, overflowed = plans.cells_add(
        plan.cells, plan.cell_mask, plan.centers, plan.radii, x,
        jnp.asarray(slot, plan.cells.dtype), True,
    )
    return dataclasses.replace(plan, cells=cells, cell_mask=mask), overflowed


def query_cells(plan: ServingPlan, xq: jax.Array) -> jax.Array:
    """Flattened cell id per query, (Q,) int32 (out-of-domain clipped)."""
    rel = (xq - plan.origin[None, :]) * plan.inv_cell[None, :]
    idx = jnp.floor(rel).astype(jnp.int32)
    dims = jnp.asarray(plan.grid_shape, jnp.int32)
    idx = jnp.clip(idx, 0, dims[None, :] - 1)
    strides = np.concatenate(
        [np.cumprod(plan.grid_shape[::-1])[-2::-1], [1]]
    ).astype(np.int32)
    return idx @ jnp.asarray(strides)


def _norm_compute_dtype(compute_dtype):
    """Canonical static name for the serving compute dtype (None = native).

    Accepts None, "f32"/"float32", "bf16"/"bfloat16", or any float dtype
    object; returns the numpy dtype-name string (hashable, stable as a jit
    static argument) or None.
    """
    if compute_dtype is None:
        return None
    aliases = {"bf16": "bfloat16", "f32": "float32", "f64": "float64",
               "f16": "float16"}
    if isinstance(compute_dtype, str):
        compute_dtype = aliases.get(compute_dtype, compute_dtype)
    try:
        dt = jnp.dtype(compute_dtype)
    except TypeError as e:
        raise ValueError(
            f"compute_dtype must be None or a float dtype "
            f"(e.g. 'bf16', 'f32'); got {compute_dtype!r}"
        ) from e
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(
            f"compute_dtype must be a float dtype, got {dt.name!r}"
        )
    return dt.name


@partial(jax.jit, static_argnames=("k", "compute_dtype"))
def knn_select_valid(
    plan: ServingPlan, positions: jax.Array, xq: jax.Array, k: int,
    alive: jax.Array | None = None,
    compute_dtype: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """((Q, k) selected ids, (Q, k) validity) via the cell plan.

    When fewer than k live candidates exist, ``top_k`` must still return k
    indices; the overflow picks +inf-distance (dead / padded) entries and
    ``valid`` marks them False so callers average the live selections only
    — matching the dense oracle ``fusion.knn_fusion`` at every liveness
    fraction (all-dead included: zero predictions).  ``compute_dtype``
    (normalized name, e.g. "bfloat16") is an OPT-IN measurement knob that
    rounds the query/candidate coordinates to a storage dtype before the
    (>= f32) distance/top-k arithmetic — the production quantized path
    does NOT use it (selection-exact; see the module docstring), but the
    quant bench and tests use it to quantify the selection-flip cost.
    Cell lookup stays full-precision.
    """
    cid = query_cells(plan, xq)  # (Q,) — always full precision
    cand = plan.cells[cid]  # (Q, K_max)
    cmask = plan.cell_mask[cid]  # (Q, K_max)
    if alive is not None:
        cmask = cmask & (alive[cand] != 0)
    pos_pad = jnp.concatenate(
        [positions, jnp.zeros((1, positions.shape[1]), positions.dtype)]
    )
    cpos = pos_pad[cand]  # (Q, K_max, d)
    if compute_dtype is not None:
        cdt = jnp.dtype(compute_dtype)
        ar = cdt if cdt.itemsize >= 4 else jnp.dtype(jnp.float32)
        xq = xq.astype(cdt).astype(ar)  # round to storage, compute wide
        cpos = cpos.astype(cdt).astype(ar)
    d2 = jnp.sum((xq[:, None, :] - cpos) ** 2, axis=-1)
    d2 = jnp.where(cmask, d2, jnp.inf)
    neg, top = jax.lax.top_k(-d2, k)  # (Q, k) candidate positions
    return jnp.take_along_axis(cand, top, axis=1), jnp.isfinite(neg)


def knn_select(
    plan: ServingPlan, positions: jax.Array, xq: jax.Array, k: int,
    alive: jax.Array | None = None,
) -> jax.Array:
    """(Q, k) ids of each query's k nearest sensors via the cell plan.

    positions: the (n, d) sensor positions the plan was built from.  Ties
    break toward the lower sensor id, matching ``fusion.knn_fusion``.
    alive: optional (n+1,) row liveness — dead candidates are never
    selected, independent of the plan's repair state.  (When fewer than k
    live candidates exist the tail ids are dead/padded rows; use the
    validity mask of ``knn_select_valid`` to exclude them.)
    """
    return knn_select_valid(plan, positions, xq, k, alive)[0]


@partial(jax.jit, static_argnames=("kernel", "k", "compute_dtype"))
def _eval_selected(
    kernel, nbr_pos, nbr_mask, coef, sel, valid, xq, k: int,
    compute_dtype: str | None = None,
):
    """mean over VALID selections of f_{sel[q,j]}(xq[q]): O(Q*k*D).

    ``compute_dtype`` rounds the ANCHOR coordinates (the storage dtype of
    the quantized path's VMEM-dominant table) before evaluating K(x, x_j)
    at >= f32 (the Pallas kernel's register-level upconversion contract);
    queries stay full-precision and the representer contraction and the
    average accumulate in the coefficient dtype regardless.
    """
    d = xq.shape[-1]
    d_max = nbr_pos.shape[-2]
    cdt = None if compute_dtype is None else jnp.dtype(compute_dtype)

    def per_query(x, sel_q, valid_q):
        npos = nbr_pos[sel_q]  # (k, D, d)
        cf = jnp.where(nbr_mask[sel_q], coef[sel_q], 0.0)  # (k, D)
        if cdt is not None:
            ar = x.dtype if x.dtype.itemsize >= 4 else jnp.dtype(jnp.float32)
            npos = npos.astype(cdt).astype(ar)
        if cdt is not None and kernel.name == "rbf":
            # Direct (x - x_j)^2 form, not the matmul expansion the generic
            # kernel uses — matches the Pallas kernel bit-for-bit on the
            # same rounded inputs.
            dd = jnp.sum((x[None, None, :] - npos) ** 2, axis=-1)  # (k, D)
            kv = jnp.exp(-kernel.gamma * dd)
        else:
            kv = kernel(x[None, :], npos.reshape(k * d_max, d))[0].reshape(
                k, d_max
            )
        f = jnp.sum(kv.astype(cf.dtype) * cf, axis=-1)  # (k,) coef dtype
        cnt = jnp.sum(valid_q)
        return jnp.sum(jnp.where(valid_q, f, 0.0)) / jnp.maximum(cnt, 1)

    return jax.vmap(per_query)(xq, sel, valid)


def knn_fuse(
    problem: SNTrainProblem,
    state: SNTrainState,
    xq: jax.Array,
    k: int = 1,
    *,
    plan: ServingPlan | None = None,
    engine: str = "plan",
    ecoef: jax.Array | None = None,
    compute_dtype=None,
    prune: jax.Array | None = None,
    block_q: int | None = None,
) -> jax.Array:
    """Plan-based kNN fusion (paper Eq. 19) — O(Q*k*D) per field.

    Returns (Q,) for single-field problems, (B, Q) for batched ones (the
    selected sensor set depends only on the shared positions, so selection
    runs once and the B evaluations share it).  ``plan`` defaults to a
    fresh ``make_serving_plan(problem, k=k)``; serving processes build the
    plan once and pass it in.  ``ecoef`` optionally supplies the TRUE
    representer coefficients (``sn_train.effective_coef``) precomputed —
    a snapshot-serving process (``launch.daemon``) publishes an immutable
    (problem, state) pair and pays the anchor-weight rescale ONCE per
    published snapshot instead of once per query dispatch.

    ``compute_dtype`` ("bf16"/"f32"/None=native) sets the storage dtype of
    the anchor tables on both engines (selection-exact quantization — see
    the module docstring); accumulation and the output stay in the
    coefficient dtype.  ``prune`` is an optional (n+1,) keep mask
    (``pruning.prune_mask``) ANDed into the liveness gate — pruned sensors
    drop out of selection exactly like dead ones, with zero recompiles
    across tau changes (mask values only).  ``block_q`` overrides the
    Pallas query tile (None = ``default_block_q(compute_dtype)``): the
    latency-oriented default stays small so bucketed small requests pad
    little; bulk offline sweeps tune it up (see benchmarks/quant_bench).
    """
    if engine not in ("plan", "pallas"):
        raise ValueError(f"engine must be 'plan' or 'pallas', got {engine!r}")
    if block_q is not None and engine != "pallas":
        raise ValueError("block_q applies to engine='pallas' only")
    if k < 1 or k > problem.n:
        raise ValueError(f"k must be in [1, n={problem.n}], got {k}")
    if plan is None:
        plan = make_serving_plan(problem, k=k)
    if k > plan.k:
        raise ValueError(
            f"plan guarantees exact kNN only up to k={plan.k}; got k={k} "
            "(rebuild with make_serving_plan(problem, k=...))"
        )
    cdt_name = _norm_compute_dtype(compute_dtype)
    alive = problem.alive
    if prune is not None:
        alive = ((alive != 0) & (prune != 0)).astype(alive.dtype)
    dt = problem.nbr_pos.dtype
    xq = jnp.atleast_2d(jnp.asarray(xq, dt))
    positions = problem.topology.positions.astype(dt)

    # Serving reads the TRUE representer coefficients (the solved
    # coordinates rescaled by the forgetting anchor weights; all-ones for
    # static beta = 1 fields) — a value-level rescale, so both engines'
    # compiled programs and the Pallas kernel's operand shapes are
    # untouched by forgetting.
    if ecoef is None:
        ecoef = effective_coef(problem, state)

    if engine == "pallas":
        from repro.kernels.knn_fuse import knn_fuse_fused

        if problem.kernel.name != "rbf":
            raise NotImplementedError(
                "engine='pallas' fuses the RBF kernel only; use "
                "engine='plan' for other kernels"
            )
        cid = query_cells(plan, xq)
        pos_pad = jnp.concatenate([positions, jnp.zeros((1, xq.shape[1]), dt)])
        if problem.batched:
            nbr_pos, nbr_mask, coef = (
                problem.nbr_pos, problem.nbr_mask, ecoef,
            )
        else:
            nbr_pos = problem.nbr_pos[None]
            nbr_mask = problem.nbr_mask[None]
            coef = ecoef[None]
        out = knn_fuse_fused(
            xq, cid, plan.cells, plan.cell_mask, pos_pad,
            nbr_pos, nbr_mask, coef,
            alive=alive, gamma=problem.kernel.gamma, k=k,
            block_q=block_q, compute_dtype=cdt_name,
        )
        return out if problem.batched else out[0]

    # (Q, k) shared across fields (liveness is network-level, not per-field)
    # Selection is ALWAYS full-precision — the quantized path is
    # selection-exact (see the module docstring); compute_dtype reaches
    # only the anchor-table evaluation below.
    sel, valid = knn_select_valid(plan, positions, xq, k, alive)
    if problem.batched:
        return jax.vmap(
            lambda np_, nm, cf: _eval_selected(
                problem.kernel, np_, nm, cf, sel, valid, xq, k,
                compute_dtype=cdt_name,
            )
        )(problem.nbr_pos, problem.nbr_mask, ecoef)
    return _eval_selected(
        problem.kernel, problem.nbr_pos, problem.nbr_mask, ecoef,
        sel, valid, xq, k, compute_dtype=cdt_name,
    )


# Sparsified-serving surface (ISSUE: serving.prune_plan): implemented in
# core.pruning, re-exported here because they operate on ServingPlans.
from .pruning import (  # noqa: E402
    PruneReport, answer_bound, prune_mask, prune_plan, representer_energy,
)
