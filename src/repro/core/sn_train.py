"""SN-Train: distributed kernel regression by alternating projections.

Faithful implementation of the paper's Table 1 / Eq. 18.  Each sensor ``s``
keeps a local function ``f_s = sum_{j in N_s} c_{s,j} K(., x_j)`` (Lemma 3.3)
and a shared message vector ``z in R^n`` (the network's running estimate of
the field at sensor locations).  One projection step at sensor s:

    c_{s,t} = (K_s + lambda_s I)^{-1} (z_{N_s, t-1} + lambda_s c_{s,t-1})
    z_j <- f_{s,t}(x_j)   for j in N_s

Three execution engines, all with identical fixed points:

  * ``serial_sweep``   — the paper's Table-1 ordering, one sensor at a time
                         (lax.scan over sensors).
  * ``colored_sweep``  — the paper's Sec-3.3 "Parallelism": all sensors of one
                         distance-2 color class update simultaneously as a
                         single batched solve (MXU-shaped), colors sweep
                         serially.  This is the TPU-native engine.
  * ``sharded_sweep``  — ``colored_sweep`` distributed with shard_map over a
                         device axis.

Fixed shapes everywhere: neighborhoods are padded to D_max, color classes to
M_max, and the message vector carries one sentinel slot (its last index) so
padded scatters are harmless.

Message-slot layout and scatter plans
-------------------------------------
z has ``n + n_stream + 1`` slots:

  [0, n)                 one per sensor (the paper's z vector);
  [n, n + n_stream)      RESERVED slots: every free padded neighborhood slot
                         (s, k >= deg_s) owns the fixed global message id
                         ``n + offset(s) + (k - deg_s)``.  Streaming arrivals
                         (repro.core.streaming) occupy these in place;
  n + n_stream           the write sentinel.

Because the reserved ids are assigned at build time, ``nbr_idx`` NEVER
diverges across fields or over time, and the distance-2 coloring makes every
message slot touched by a color class have a UNIQUE ``(member, lane)`` owner
within that class.  The whole color-step message/coefficient update is
therefore a *static permutation* known at ``make_problem`` time, precomputed
host-side as two int32 **scatter plans** per color ``c``:

  ``plan_z[c]``    (n_z,)   for every message slot: its own index (keep), or
                            ``n_z + m*D + k`` — take the value sensor
                            ``members[c, m]`` just computed for its lane
                            ``k``.  One gather from
                            ``concat([z, z_new.reshape(B, -1)], -1)``
                            realizes the entire update in O(n_z);
  ``plan_coef[c]`` (n+1,)   the same for coefficient rows: keep, or
                            ``(n+1) + m`` from the color's fresh solves.

Engine selection (``colored_sweep(..., engine=...)``):

  ``"plan"``   (default)  the static-gather realization above — O(n·D) per
                          full sweep on bounded-degree networks;
  ``"onehot"`` (reference) materializes the one-hot matrix
                          ``(M·D, n_z)`` and applies the update as two dense
                          GEMMs — O(n²) per sweep, kept as the independently
                          simple oracle the plans are tested against;
  ``"pallas"``            the fused color-step kernel
                          (repro.kernels.color_step): gather → lane-blocked
                          forward/back substitution → local (D,D)@(D,) GEMM
                          → scatter, all in VMEM, blocked over the B·M lane
                          grid (interpret mode off-TPU).

All three produce identical fixed points (plan == onehot bit-for-bit; see
tests/test_scatter_plan.py).  ``sharded_sweep`` reuses the plans to shrink
its per-color transport to the (M·D,) touched slot values instead of full
(n_z,) + (n+1, D) deltas.

The serving half of the system applies the same static-plan idea to the
paper's *testing phase*: ``repro.core.serving.make_serving_plan``
precomputes per-cell kNN candidate lists so ``fusion.fuse(rule="knn",
engine="plan"/"pallas")`` answers queries in O(Q·k·D) instead of the dense
O(Q·n·D) oracle — see the query-plan taxonomy in ``repro.core.fusion``.

Multi-field batching
--------------------
``make_batch_problem`` runs B independent regression problems ("fields")
over the same network in one program: per-field arrays gain a leading
``(B, ...)`` axis (``y: (B, n)``, ``z: (B, n+S+1)``, ``coef: (B, n+1, D)``,
``gram``/``chol``: ``(B, n+1, D, D)``), while ``nbr_idx``, regularizers and
the coloring stay shared.  The colored engine's local solves run as
fixed-shape triangular substitution vectorized over all B*M lanes at once —
2D scan steps of batched row operations instead of B*M LAPACK calls (also
measurably MORE accurate than batched LAPACK cho_solve in f32 at the
paper's ill-conditioned lambdas) — and its message updates are one-hot
GEMMs, so throughput scales with B (see benchmarks/multifield_bench.py).
``sharded_sweep`` shards the *field* axis across devices (fields are
independent, so the transport is pure data parallelism).  With B = 1 the
batched path IS the single-field path (same core, vmapped), asserted in
tests/test_multifield.py.

Network lifecycle (paper Sec. 3.3 "Robustness")
-----------------------------------------------
``make_problem(..., n_max=...)`` builds at CAPACITY: spare sensor rows
(parked far away, each with a reserved singleton color — see
``repro.core.plans``) plus the reserved-slot streaming layout give every
membership operation a fixed-shape realization.  The problem carries a
device-side ``alive`` row mask and a ``layout`` (slot ownership, color
assignments, pristine slot tables); every sweep engine gates on it:

  * dead members never update (their scatters degrade to "keep" in all of
    plan/onehot/pallas — the Pallas kernels grew explicit alive operands);
  * dead rows' message slots — and, via the slot-owner map, their absorbed
    arrivals' slots — drop out of every gather;
  * at all-True liveness the gates are identities BIT-FOR-BIT.

PERSISTENT membership changes go through ``streaming.add_sensor`` /
``remove_sensor``.  Joins are SYMMETRIC (the paper's Eq. 10-12 coupling):
the newcomer adopts its live in-radius neighbors AND each adopter grows a
reciprocal anchor lane at the new position, so the post-join problem
encodes exactly the constraint sets a from-scratch ``make_problem`` on
the post-join topology would (tests pin the repaired scatter plans
bitwise against the host builder, and the training iterates to <= 1e-5
against a fresh build).  Reciprocal lanes can put two same-color adopters
in conflict under the distance-2 rule; the event resolves that on device
(``plans.resolve_join_conflicts``) by moving all but one adopter per
color into reserved empty recolor classes — which is why the color
member tables / row->color maps are mutable problem state (seeded from
the topology, patched by events, scanned by every colored engine).  Both
events repair O(degree) rows only: lane insertions/deletions plus ONE
batched masked refactorization of the affected factors — never all n
(benchmarks/churn_bench.py ``--per-event`` tracks the flat-in-n curve).
Each event also patches the query-plan candidate lists
(``serving.plan_add_sensor`` / ``plan_remove_sensor``), and an arbitrary
join/leave/absorb/sweep/query trace compiles a constant number of
programs (jit-cache-counted in tests/test_lifecycle.py).  TRANSIENT
failures go through ``robust_sweep``, which refactorizes the masked
systems per sweep (no event, no patched factors) but dispatches the same
alive-masked colored engines — batched, engine-selectable, and
bitwise-equal to ``colored_sweep`` at full liveness on arrival-free
problems.  The single-field extensions (``weighted_sweep``,
``robust_sweep_links``) thread the same liveness masks: dead sensors
neither update nor are read anywhere.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

from . import plans
from .kernels_math import Kernel
from .plans import LifecycleLayout
from .topology import SensorTopology, pad_topology


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SNTrainProblem:
    """Static per-network precomputation for SN-Train.

    All arrays are padded to fixed shapes. ``n`` below is the sensor count,
    ``D`` the padded neighborhood size, ``S`` the reserved streaming capacity
    (``n_stream``).  Single-field problems carry the shapes written below;
    batched problems (``make_batch_problem``) prepend a field axis ``B`` to
    ``y``, ``nbr_pos``, ``nbr_mask``, ``gram``, ``chol`` and ``stream_pos``
    (``nbr_idx`` and the scatter plans stay shared — reserved ids and the
    coloring are fixed).
    """

    topology: SensorTopology
    kernel: Kernel = dataclasses.field(metadata=dict(static=True))
    y: jnp.ndarray  # (n,) measurements
    lambdas: jnp.ndarray  # (n,) per-sensor regularizers
    nbr_pos: jnp.ndarray  # (n+1, D, d) neighbor positions (padded row n)
    nbr_idx: jnp.ndarray  # (n+1, D) message-slot ids (reserved ids on free slots)
    nbr_mask: jnp.ndarray  # (n+1, D)
    gram: jnp.ndarray  # (n+1, D, D) masked local Gram K_s (zeros off-mask)
    chol: jnp.ndarray  # (n+1, D, D) lower Cholesky of K_s + lambda_s I (padded dims get identity)
    lam_pad: jnp.ndarray  # (n+1,)
    stream_pos: jnp.ndarray  # (S, d) arrival positions (zeros until absorbed)
    plan_z: jnp.ndarray  # (n_colors, n_z) color-step gather plan for z
    plan_coef: jnp.ndarray  # (n_colors, n+1) color-step gather plan for coef
    # Mutable color assignment (shared across fields): symmetric joins can
    # recolor adopters into the reserved recolor classes, so the member
    # tables the colored engines scan — and the row -> (color, position)
    # maps the event repairs read — are problem state, seeded from the
    # topology's build-time tables.
    color_members: jnp.ndarray  # (n_colors, M) member rows per color class
    color_mask: jnp.ndarray  # (n_colors, M) validity of color_members
    color_of: jnp.ndarray  # (n+1,) color id per row (sentinel: n_colors)
    member_pos: jnp.ndarray  # (n+1,) position of each row in its color
    alive: jnp.ndarray  # (n+1,) bool row liveness, shared across fields; the
    # sentinel row n is PERMANENTLY dead — retired lanes point at its slot,
    # and its deadness keeps them retired when spare rows are recycled

    # Exponential forgetting (EW-RLS, Mateos & Giannakis arXiv:1109.4627)
    # for time-varying fields.  ``beta`` is the per-field forgetting factor
    # ((B,) batched, scalar single-field; 1.0 = the paper's static field).
    # ``anchor_w`` holds the per-lane representer anchor weight
    # omega = beta^(age/2): each absorb at (field, sensor) multiplies the
    # sensor's occupied STREAM lanes' omega by sqrt(beta) — structural
    # lanes never decay (they carry the network's live messages, not
    # time-stamped data).  The invariants the streaming tick maintains:
    #
    #   gram[b,s,i,j] = omega_i * omega_j * K(x_i, x_j)   (decay in place)
    #   chol[b,s]     = chol(gram + diag(occupied ? lambda_s : 1))
    #   z[b, slot_j]  = omega_j * (message value)          (stream slots)
    #
    # lambda is NEVER decayed, so every factor-rebuild path (evict's
    # downdate, rebuild_chol, robust_sweep's _masked_factors, the
    # lifecycle _refactor_rows) and every sweep engine (serial / colored
    # plan|onehot|pallas / sharded / robust) consumes the forgetting state
    # through these arrays UNCHANGED, and each local solve is exactly the
    # w-weighted regularized projection min_f sum_j w_j (z_j - f(x_j))^2
    # + lambda_s ||f||^2 with w_j = omega_j^2 (in omega-scaled coordinates
    # — the stored coef is v with TRUE representer coefficients
    # a = anchor_w * v; external evaluators multiply through, see
    # ``fusion``/``serving``).  With beta = 1.0 every tick multiplies by
    # exactly 1.0 and is gated bitwise (tests/test_streaming_beta.py).
    beta: jnp.ndarray  # () / (B,) per-field forgetting factor in (0, 1]
    anchor_w: jnp.ndarray  # (n+1, D) / (B, n+1, D) per-lane anchor weights

    layout: LifecycleLayout  # event-invariant lifecycle metadata (repro.core.plans)
    n_stream: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.topology.n

    @property
    def batched(self) -> bool:
        """True when arrays carry a leading field axis (multi-field batch)."""
        return self.y.ndim == 2

    @property
    def batch_size(self) -> int | None:
        return int(self.y.shape[0]) if self.batched else None

    @property
    def sentinel(self) -> int:
        """Index of the write-sentinel slot of z (== n + n_stream)."""
        return self.n + self.n_stream

    @property
    def n_z(self) -> int:
        """Length of the message vector including the sentinel."""
        return self.n + self.n_stream + 1

    @property
    def n_base(self) -> int:
        """Build-time sensor count; rows [n_base, n) are join capacity."""
        return self.layout.n_base

    @property
    def alive_z(self) -> jnp.ndarray:
        """(n_z,) message-slot liveness (a slot lives with its owning row)."""
        return plans.alive_slots(self.alive, self.layout.slot_owner)

    @property
    def recolor_start(self) -> int:
        """First reserved recolor class (the pool symmetric joins use)."""
        return int(self.color_members.shape[0]) - self.topology.n_recolor


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SNTrainState:
    z: jnp.ndarray  # (n+S+1,) messages; the last slot is a write sentinel
    coef: jnp.ndarray  # (n+1, D) per-sensor representer coefficients


def default_lambdas(topology: SensorTopology, kappa: float = 0.01) -> jnp.ndarray:
    """Paper Sec. 4.1: lambda_i = kappa / |N_i|^2 with kappa = 0.01.

    Spare rows (degree 0) get a placeholder of 1.0; ``streaming.add_sensor``
    installs the joined sensor's regularizer.
    """
    deg = topology.degrees.astype(jnp.float32)
    return jnp.where(deg > 0, kappa / jnp.maximum(deg, 1) ** 2, 1.0)


def _pad_per_sensor(arr: jax.Array, n: int, fill) -> jax.Array:
    """Pad an (n_base,)-shaped per-sensor vector to capacity ``n``."""
    short = n - arr.shape[-1]
    if short == 0:
        return arr
    if short < 0:
        raise ValueError(f"per-sensor array longer ({arr.shape[-1]}) than n={n}")
    pad = jnp.full(arr.shape[:-1] + (short,), fill, arr.dtype)
    return jnp.concatenate([arr, pad], axis=-1)


def make_problem(
    topology: SensorTopology,
    kernel: Kernel,
    y: jax.Array,
    lambdas: jax.Array | None = None,
    *,
    dtype=jnp.float32,
    n_max: int | None = None,
    beta: float = 1.0,
) -> SNTrainProblem:
    """Precompute the padded SN-Train problem.

    dtype: float32 is the TPU-friendly default, but the paper's own
    regularizers (lambda_i = 0.01/|N_i|^2 ~ 1e-5) make the local systems
    condition at ~1e9 where f32 solves systematically violate the projection
    property (the weighted norm grows and the sweep diverges).  Pass
    jnp.float64 (with JAX_ENABLE_X64) to reproduce the paper's numerics;
    alternatively raise lambda (see tests/test_sn_train.py).

    Streaming capacity is implied by the topology's padding: every free
    neighborhood slot (build the topology with ``d_max`` headroom to get
    more) owns a reserved message slot that arrivals can occupy
    (repro.core.streaming).

    n_max: lifecycle capacity — pads the topology with ``n_max - n`` spare
    sensor rows (reserved singleton colors, see ``topology.pad_topology``)
    so ``streaming.add_sensor`` / ``remove_sensor`` can churn membership at
    fixed shapes, recompile-free.  ``y``/``lambdas`` may be given at the
    base length and are padded (0 / 1.0) over the spare rows.

    beta: forgetting factor in (0, 1] for time-varying fields (see the
    ``SNTrainProblem`` field docs); 1.0 (default) reproduces the paper's
    static estimator bitwise.
    """
    if not 0.0 < float(beta) <= 1.0:
        raise ValueError(f"beta must be in (0, 1], got {beta}")
    if n_max is not None:
        topology = pad_topology(topology, n_max)
    n, d_max = topology.nbr_idx.shape
    d = topology.positions.shape[1]
    n_base = topology.n_base if topology.n_base >= 0 else n
    if lambdas is None:
        lambdas = default_lambdas(topology)
    lambdas = _pad_per_sensor(jnp.asarray(lambdas, dtype), n, 1.0)
    y = _pad_per_sensor(jnp.asarray(y, dtype), n, 0.0)

    # Assign every free padded slot its fixed reserved message id, and give
    # the sentinel row n the sentinel id (duplicate writes there carry 0s).
    # Spare rows are dead at build: their color plans start at "keep" and
    # their rows are fully reserved capacity.
    idx_full, n_stream = plans.assign_stream_slots(
        np.asarray(topology.nbr_idx), np.asarray(topology.degrees)
    )
    nbr_idx = jnp.asarray(idx_full, jnp.int32)
    # Row liveness: base rows alive, spare rows dead until a join claims
    # them.  The sentinel row n is DEAD: lanes retired by remove_sensor
    # point at the sentinel slot, and its deadness is what keeps them
    # retired when a spare row is recycled.  (Padded color members and
    # sentinel lanes are already occupancy-masked, so this costs nothing.)
    alive0 = np.arange(n + 1) < n_base
    plan_z, plan_coef = plans.build_color_plans(
        np.asarray(topology.color_members),
        np.asarray(topology.color_mask),
        idx_full,
        n_stream,
        alive0,
    )
    layout = plans.build_layout(idx_full, n_stream, n_base)
    color_of, member_pos = plans.color_assignments(
        np.asarray(topology.colors),
        np.asarray(topology.color_members),
        np.asarray(topology.color_mask),
    )
    nbr_mask = jnp.concatenate(
        [topology.nbr_mask, jnp.zeros((1, d_max), bool)], axis=0
    )
    # Positions of free slots are placeholders (the sensor's own position,
    # the topology's padding convention) until streaming overwrites them.
    pos_pad = jnp.concatenate(
        [topology.positions.astype(dtype), jnp.zeros((1, d), dtype)], axis=0
    )
    nbr_pos = pos_pad[
        jnp.concatenate([topology.nbr_idx, jnp.full((1, d_max), n, jnp.int32)])
    ]  # (n+1, D, d)
    lam_pad = jnp.concatenate([lambdas, jnp.ones((1,), dtype)])

    def local_system(pos_s, mask_s, lam_s):
        k = kernel(pos_s, pos_s)  # (D, D)
        outer = mask_s[:, None] & mask_s[None, :]
        k = jnp.where(outer, k, 0.0)
        # Solve matrix: valid block gets +lambda on the diagonal; padded
        # diagonal entries are set to 1 so the factorization is SPD and the
        # padded coefficients stay exactly 0 (their rhs is 0).
        diag = jnp.where(mask_s, lam_s, 1.0)
        a = k + jnp.diag(diag)
        return k, jsl.cholesky(a, lower=True)

    gram, chol = jax.vmap(local_system)(nbr_pos, nbr_mask, lam_pad)
    return SNTrainProblem(
        topology=topology,
        kernel=kernel,
        y=y,
        lambdas=lambdas,
        nbr_pos=nbr_pos,
        nbr_idx=nbr_idx,
        nbr_mask=nbr_mask,
        gram=gram,
        chol=chol,
        lam_pad=lam_pad,
        stream_pos=jnp.zeros((n_stream, d), dtype),
        plan_z=jnp.asarray(plan_z),
        plan_coef=jnp.asarray(plan_coef),
        # distinct buffers from the topology's tables (the problem pytree
        # carries both; aliased buffers would break donate=True dispatch)
        color_members=jnp.asarray(
            np.asarray(topology.color_members), jnp.int32
        ),
        color_mask=jnp.asarray(np.asarray(topology.color_mask), bool),
        color_of=jnp.asarray(color_of),
        member_pos=jnp.asarray(member_pos),
        alive=jnp.asarray(alive0),
        beta=jnp.asarray(beta, dtype),
        anchor_w=jnp.ones((n + 1, d_max), dtype),
        layout=layout,
        n_stream=n_stream,
    )


def make_batch_problem(
    topology: SensorTopology,
    kernel: Kernel,
    ys: jax.Array,
    lambdas: jax.Array | None = None,
    *,
    dtype=jnp.float32,
    n_max: int | None = None,
    beta: float | jax.Array = 1.0,
) -> SNTrainProblem:
    """B independent fields over one network: ``ys`` is (B, n).

    Geometry (topology, regularizers, message-slot ids, liveness) is
    shared; the per-field ``nbr_pos``/``nbr_mask``/``gram``/``chol``/
    ``stream_pos``/``anchor_w`` arrays start as B identical copies and
    diverge only under streaming absorption.  ``n_max`` reserves lifecycle
    capacity as in ``make_problem``.

    beta: per-field forgetting factors — a scalar (shared) or a (B,)
    vector, so one batch can mix static (beta = 1.0) and time-varying
    (beta < 1) fields; each field's absorbs decay that field only.
    """
    ys = jnp.asarray(ys, dtype)
    if ys.ndim != 2:
        raise ValueError(f"ys must be (B, n), got shape {ys.shape}")
    base = make_problem(topology, kernel, ys[0], lambdas, dtype=dtype, n_max=n_max)
    ys = _pad_per_sensor(ys, base.n, 0.0)
    b = ys.shape[0]
    beta = jnp.broadcast_to(jnp.asarray(beta, dtype), (b,))
    if not bool(jnp.all((beta > 0.0) & (beta <= 1.0))):
        raise ValueError(f"beta must be in (0, 1] per field, got {beta}")

    def tile(a):
        return jnp.broadcast_to(a[None], (b,) + a.shape)

    return dataclasses.replace(
        base,
        y=ys,
        nbr_pos=tile(base.nbr_pos),
        nbr_mask=tile(base.nbr_mask),
        gram=tile(base.gram),
        chol=tile(base.chol),
        stream_pos=tile(base.stream_pos),
        beta=beta,
        anchor_w=tile(base.anchor_w),
    )


def field_view(
    problem: SNTrainProblem, state: SNTrainState, b: int
) -> tuple[SNTrainProblem, SNTrainState]:
    """Single-field view of field ``b`` of a batched problem/state."""
    if not problem.batched:
        raise ValueError("field_view expects a batched problem")
    prob = dataclasses.replace(
        problem,
        y=problem.y[b],
        nbr_pos=problem.nbr_pos[b],
        nbr_mask=problem.nbr_mask[b],
        gram=problem.gram[b],
        chol=problem.chol[b],
        stream_pos=problem.stream_pos[b],
        beta=problem.beta[b],
        anchor_w=problem.anchor_w[b],
    )
    return prob, SNTrainState(z=state.z[b], coef=state.coef[b])


def weighted_norm_sq(problem: SNTrainProblem, state: SNTrainState) -> jax.Array:
    """The SOP product-space norm  ||z||^2 + sum_i lambda_i ||f_i||^2_{H_K}.

    By Lemma 2.1 (0 is in the intersection C, all C_i are subspaces) this is
    non-increasing along ANY admissible SOP ordering — the invariant the
    property tests assert.  Note ||f_i||^2 = c_i^T K_i c_i.  Batched inputs
    return one norm per field, shape (B,).

    Forgetting (beta < 1): ``gram`` and the stream slots of ``z`` carry the
    anchor weights in place, so this expression IS the w-weighted product
    norm sum_j w_j z_j^2 + sum_i lambda_i ||f_i||^2 — the norm each
    weighted projection is orthogonal in.  It stays non-increasing across
    sweeps BETWEEN forgetting ticks; each absorb tick rescales the norm
    itself (the steady-state-error bound of tests/test_streaming_beta.py
    replaces cross-tick Fejér monotonicity).
    """
    z_part = jnp.sum(state.z[..., :-1] ** 2, axis=-1)  # excludes the sentinel
    quad = jnp.einsum(
        "...sd,...sde,...se->...s", state.coef, problem.gram, state.coef
    )
    return z_part + jnp.sum(problem.lam_pad * quad, axis=-1)


def init_state(problem: SNTrainProblem) -> SNTrainState:
    """Paper Table 1 initialization: z_{s,0} = y_s, f_{s,0} = 0.

    Reserved stream slots and the sentinel start at 0 (they contribute
    nothing to the weighted norm until an arrival is absorbed).
    """
    n = problem.n
    d_max = problem.nbr_idx.shape[-1]
    dt = problem.y.dtype
    pad = problem.n_stream + 1
    if problem.batched:
        b = problem.batch_size
        z = jnp.concatenate([problem.y, jnp.zeros((b, pad), dt)], axis=-1)
        coef = jnp.zeros((b, n + 1, d_max), dt)
    else:
        z = jnp.concatenate([problem.y, jnp.zeros((pad,), dt)])
        coef = jnp.zeros((n + 1, d_max), dt)
    return SNTrainState(z=z, coef=coef)


def effective_coef(problem: SNTrainProblem, state: SNTrainState) -> jax.Array:
    """TRUE representer coefficients a = anchor_w * coef.

    The sweep engines store coefficients in omega-scaled coordinates (see
    the ``SNTrainProblem.anchor_w`` docs): the field estimate is
    f_s(x) = sum_j anchor_w[s, j] * coef[s, j] * K(x, x_j).  Everything
    INSIDE the training loop consumes gram/chol/z, which carry the weights
    in place; evaluators that expand f_s against raw kernel values
    (``fusion``, ``serving``, the Pallas knn_fuse / kernel_matvec serving
    kernels) must evaluate these effective coefficients instead.  With
    beta = 1.0 ``anchor_w`` is exactly 1.0 everywhere and this is a
    bitwise identity.
    """
    return state.coef * problem.anchor_w.astype(state.coef.dtype)


def _sensor_update(z, coef_s, nbr_idx_s, nbr_mask_s, gram_s, chol_s, lam_s):
    """One P_{C_s} projection (Eq. 18). Returns (coef_s', z-values at N_s)."""
    z_nbr = z[nbr_idx_s]  # (D,)
    rhs = jnp.where(nbr_mask_s, z_nbr + lam_s * coef_s, 0.0)
    coef_new = jsl.cho_solve((chol_s, True), rhs)
    z_new = gram_s @ coef_new  # f_s(x_j) for j in N_s (masked gram)
    return coef_new, z_new


# ---------------------------------------------------------------------------
# Serial engine (the paper's Table-1 ordering; cho_solve per sensor).
# ---------------------------------------------------------------------------


def _serial_core(
    nbr_idx, nbr_mask, gram, chol, lam_pad, sentinel, z, coef, order, n_sweeps,
    alive_row, alive_slot, delivered=None,
):
    def make_body(deliv_t):
        def body(carry, s):
            z, coef = carry
            # Effective neighborhood: padded occupancy & slot/row liveness (a
            # dead sensor neither updates nor is heard from; identity when the
            # network is fully alive).
            mask_s = nbr_mask[s] & alive_slot[nbr_idx[s]] & alive_row[s]
            coef_new, z_new = _sensor_update(
                z, coef[s], nbr_idx[s], mask_s, gram[s], chol[s], lam_pad[s]
            )
            coef = coef.at[s].set(jnp.where(alive_row[s], coef_new, coef[s]))
            # Unreliable links (repro.core.faults): a dropped lane's WRITE
            # never lands — the stale message persists (hold-last-value,
            # the dead-target-slot semantics) while the local coefficient
            # update above still runs (compute is local).
            send = mask_s if deliv_t is None else mask_s & deliv_t[s]
            scatter_idx = jnp.where(send, nbr_idx[s], sentinel)
            z = z.at[scatter_idx].set(jnp.where(send, z_new, z[sentinel]))
            return (z, coef), None

        return body

    if delivered is None:
        body = make_body(None)

        def sweep(carry, _):
            carry, _ = jax.lax.scan(body, carry, order)
            return carry, None

        (z, coef), _ = jax.lax.scan(sweep, (z, coef), None, length=n_sweeps)
    else:

        def sweep(carry, deliv_t):
            carry, _ = jax.lax.scan(make_body(deliv_t), carry, order)
            return carry, None

        (z, coef), _ = jax.lax.scan(sweep, (z, coef), delivered)
    return z, coef


@partial(jax.jit, static_argnames=("n_sweeps",))
def serial_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    n_sweeps: int = 1,
    *,
    delivered: jax.Array | None = None,
) -> SNTrainState:
    """The paper's Table-1 serial ordering: for t: for s: project.

    Batched problems run every field's serial sweep simultaneously (vmap over
    the field axis).

    delivered: optional (n_sweeps, n+1, D) bool per-sweep link-delivery
    mask (repro.core.faults), shared across fields; a dropped lane's
    message write never lands (hold-last-value).  All-True is bitwise
    the fault-free sweep."""
    order = jnp.arange(problem.n, dtype=jnp.int32)
    core = partial(
        _serial_core,
        nbr_idx=problem.nbr_idx,
        lam_pad=problem.lam_pad,
        sentinel=problem.sentinel,
        order=order,
        n_sweeps=n_sweeps,
        alive_row=problem.alive,
        alive_slot=problem.alive_z,
        delivered=delivered,
    )
    run = lambda nm, g, ch, z, c: core(
        nbr_mask=nm, gram=g, chol=ch, z=z, coef=c
    )
    if problem.batched:
        run = jax.vmap(run)
    z, coef = run(
        problem.nbr_mask, problem.gram, problem.chol, state.z, state.coef
    )
    return SNTrainState(z=z, coef=coef)


# ---------------------------------------------------------------------------
# Colored engine.  Field axis is explicit (B = 1 for single-field problems);
# local solves are fixed-shape triangular substitution vectorized over all
# B*M lanes (2D scan steps of batched row ops — no per-matrix LAPACK calls,
# and empirically tighter f32 error than batched cho_solve at the paper's
# ill-conditioned lambdas).  The message/coefficient updates are EXACT
# writes: within one color class every touched message slot has a unique
# owner (distance-2 coloring makes same-color neighborhoods disjoint;
# reserved slots are per-sensor), realized either as the precomputed static
# gather plans ("plan"/"pallas") or as the dense one-hot matmul reference
# ("onehot") — see the module docstring for the engine taxonomy.
# ---------------------------------------------------------------------------


def _tri_solve_spd(chol, rhs):
    """(L L^T)^{-1} rhs by forward+back substitution over the last axis.

    chol: (..., D, D) lower factors (padded rows identity), rhs: (..., D).
    Vectorized over every leading batch dim; each of the 2D scan steps is a
    batched row operation, so cost amortizes across B*M lanes.
    """
    d = chol.shape[-1]
    eye = jnp.eye(d, dtype=chol.dtype)
    rows = jnp.moveaxis(chol, -2, 0)  # (D, ..., D) rows of L
    cols = jnp.moveaxis(chol, -1, 0)  # (D, ..., D) rows of L^T
    rhs_r = jnp.moveaxis(rhs, -1, 0)  # (D, ...)

    def fwd(y, inp):
        li, bi, ei = inp
        yi = (bi - jnp.sum(li * y, axis=-1)) / jnp.sum(li * ei, axis=-1)
        return y + yi[..., None] * ei, None

    y, _ = jax.lax.scan(fwd, jnp.zeros_like(rhs), (rows, rhs_r, eye))

    def bwd(x, inp):
        ui, yi, ei = inp
        xi = (yi - jnp.sum(ui * x, axis=-1)) / jnp.sum(ui * ei, axis=-1)
        return x + xi[..., None] * ei, None

    x, _ = jax.lax.scan(
        bwd, jnp.zeros_like(rhs), (cols, jnp.moveaxis(y, -1, 0), eye),
        reverse=True,
    )
    return x


def _color_solve(
    nbr_idx, lam_pad, alive_row, alive_slot, nbr_mask, gram, chol, z, coef,
    members, member_mask,
):
    """Simultaneous P_{C_s} local solves for one color, all B fields.

    Shapes: z (B, NZ); coef (B, n+1, D); nbr_idx (n+1, D) shared;
    nbr_mask/gram/chol per-field; members (M,), member_mask (M,);
    alive_row (n+1,) / alive_slot (n_z,) shared liveness.  Dead members
    solve to exact zeros (masked rhs) and dead neighbors/slots drop out of
    every rhs; at all-True liveness the masks are identities and the floats
    are bit-for-bit those of the lifecycle-free engine.
    Returns (idx_m (M, D), coef_new (B, M, D), z_new (B, M, D)); the engines
    differ only in how they scatter these back.
    """
    idx_m = nbr_idx[members]  # (M, D) shared across fields
    live_m = member_mask & alive_row[members]  # (M,) updating members
    mask_m = (
        nbr_mask[:, members]
        & live_m[None, :, None]
        & alive_slot[idx_m][None]
    )  # (B, M, D)
    gram_m = gram[:, members]  # (B, M, D, D)
    chol_m = chol[:, members]  # (B, M, D, D)
    lam_m = lam_pad[members]  # (M,)
    coef_m = coef[:, members]  # (B, M, D)

    b = z.shape[0]
    z_nbr = z[:, idx_m.reshape(-1)].reshape(b, *idx_m.shape)  # (B, M, D)
    rhs = jnp.where(mask_m, z_nbr + lam_m[None, :, None] * coef_m, 0.0)
    coef_new = _tri_solve_spd(chol_m, rhs)  # (K_s + lambda_s I)^{-1} rhs
    z_new = jnp.einsum("bmij,bmj->bmi", gram_m, coef_new)  # f_s at N_s
    return idx_m, coef_new, z_new


def _apply_plan(
    z, coef, z_new, coef_new, plan_z_c, plan_coef_c, live_m, alive_slot,
    deliv_flat=None,
):
    """Static-gather realization of the color-step scatter: O(n_z + n*D).

    Scatter codes whose source member OR target message slot is DEAD
    degrade to "keep" at runtime (transient liveness — robust_sweep —
    never patches the plans; lifecycle events patch them too, in which
    case the gates agree).  Target gating matches the paper's physics: a
    down mote's own message slot is unreachable, so its last value
    persists (exactly what the serial engine's masked scatter does).
    Coefficient rows need no target gate — a row's only writer is its own
    sensor, so source and target liveness coincide.

    deliv_flat: optional (M*D,) per-lane delivery gate in the color's
    flat member order (repro.core.faults) — an UNDELIVERED lane's
    message code degrades to "keep" exactly like a dead slot, while the
    coefficient scatter is untouched (the local solve still happened).
    """
    b, n_z = z.shape
    d = z_new.shape[-1]
    zc = jnp.concatenate([z, z_new.reshape(b, -1)], axis=-1)[:, plan_z_c]
    src_m = jnp.clip((plan_z_c - n_z) // d, 0, live_m.shape[0] - 1)
    fresh_ok = live_m[src_m] & alive_slot
    if deliv_flat is not None:
        lane = jnp.clip(plan_z_c - n_z, 0, deliv_flat.shape[0] - 1)
        fresh_ok = fresh_ok & deliv_flat[lane]
    use = (plan_z_c < n_z) | fresh_ok
    z = jnp.where(use[None, :], zc, z)
    n_rows = coef.shape[1]
    cc = jnp.concatenate([coef, coef_new], axis=1)[:, plan_coef_c]
    srcc = jnp.clip(plan_coef_c - n_rows, 0, live_m.shape[0] - 1)
    usec = (plan_coef_c < n_rows) | live_m[srcc]
    coef = jnp.where(usec[None, :, None], cc, coef)
    return z, coef


def _apply_onehot(
    z, coef, z_new, coef_new, idx_m, members, n_z, n_rows, live_m, alive_slot,
    deliv_flat=None,
):
    """Dense one-hot reference realization: O(M*D*n_z) GEMMs per color.

    Exact because slot ids are unique within a color; the sentinel id may
    repeat but only ever receives zeros, 0 * (1-hit) == 0.  Dead members'
    one-hot ROWS and dead slots' one-hot COLUMNS are zeroed, realizing the
    same source/target "keep" gates as the plan gather; an undelivered
    lane (``deliv_flat``, repro.core.faults) zeroes its one-hot ROW the
    same way — the message never lands, the slot keeps its value.
    """
    b = z.shape[0]
    d = idx_m.shape[-1]
    flat_idx = idx_m.reshape(-1)  # (M*D,)
    live_f = jnp.repeat(live_m, d).astype(z.dtype)  # (M*D,)
    if deliv_flat is not None:
        live_f = live_f * deliv_flat.astype(z.dtype)
    oh = (flat_idx[:, None] == jnp.arange(n_z)[None, :]).astype(z.dtype)
    oh = oh * live_f[:, None] * alive_slot.astype(z.dtype)[None, :]
    hit = oh.sum(axis=0)  # (NZ,)
    z = z * (1.0 - hit)[None, :] + jnp.einsum(
        "kz,bk->bz", oh, z_new.reshape(b, -1)
    )
    # One-hot coefficient scatter over member rows (padded members are the
    # sentinel sensor row n whose update is exactly 0).
    ohm = (members[:, None] == jnp.arange(n_rows)[None, :]).astype(coef.dtype)
    ohm = ohm * live_m.astype(coef.dtype)[:, None]
    hitm = ohm.sum(axis=0)  # (n+1,)
    coef = coef * (1.0 - hitm)[None, :, None] + jnp.einsum(
        "mn,bmd->bnd", ohm, coef_new
    )
    return z, coef


ENGINES = ("plan", "onehot", "pallas")


def _colored_core(
    problem: SNTrainProblem, nbr_mask, gram, chol, z, coef, n_sweeps,
    engine: str = "plan",
    alive=None,
    delivered=None,
):
    """Batched colored sweep over explicitly-leading field axes.

    ``alive`` overrides the problem's persistent row liveness (used by
    ``robust_sweep`` for per-sweep transient liveness); all engines gate
    dead members' updates and dead slots' reads, reducing bit-for-bit to
    the lifecycle-free sweep at all-True liveness.

    ``delivered`` is the optional (n_sweeps, n+1, D) per-sweep
    link-delivery mask (repro.core.faults), shared across fields: an
    undelivered lane's message write degrades to "keep" in every engine
    (hold-last-value), the coefficient update is untouched, and
    all-True is bitwise the fault-free sweep.  ``None`` keeps the
    fault-free scan structure unchanged.
    """
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    alive_row = problem.alive if alive is None else alive
    alive_slot = plans.alive_slots(alive_row, problem.layout.slot_owner)
    solve = partial(
        _color_solve, problem.nbr_idx, problem.lam_pad, alive_row, alive_slot
    )
    # The member tables are problem state (symmetric joins recolor), so a
    # churned problem sweeps its CURRENT classes with zero recompilation.
    xs = (
        problem.color_members, problem.color_mask,
        problem.plan_z, problem.plan_coef,
    )

    def make_color_body(deliv_t):
        if engine == "pallas":
            from repro.kernels.color_step import color_step_fused

            def color_body(carry, cm):
                z, coef = carry
                members, member_mask, _, _ = cm
                idx_m = problem.nbr_idx[members]
                live_m = member_mask & alive_row[members]
                z, coef = color_step_fused(
                    z, coef, members, idx_m,
                    nbr_mask[:, members]
                    & live_m[None, :, None]
                    & alive_slot[idx_m][None],
                    gram[:, members], chol[:, members],
                    problem.lam_pad[members],
                    alive_row[members],
                    alive_slot,
                    None if deliv_t is None else deliv_t[members],
                )
                return (z, coef), None
        else:

            def color_body(carry, cm):
                z, coef = carry
                members, member_mask, plan_z_c, plan_coef_c = cm
                live_m = member_mask & alive_row[members]
                deliv_flat = (
                    None if deliv_t is None else deliv_t[members].reshape(-1)
                )
                idx_m, coef_new, z_new = solve(
                    nbr_mask, gram, chol, z, coef, members, member_mask
                )
                if engine == "plan":
                    z, coef = _apply_plan(
                        z, coef, z_new, coef_new, plan_z_c, plan_coef_c,
                        live_m, alive_slot, deliv_flat,
                    )
                else:
                    z, coef = _apply_onehot(
                        z, coef, z_new, coef_new, idx_m, members,
                        problem.n_z, problem.n + 1, live_m, alive_slot,
                        deliv_flat,
                    )
                return (z, coef), None

        return color_body

    if delivered is None:
        color_body = make_color_body(None)

        def sweep(carry, _):
            carry, _ = jax.lax.scan(color_body, carry, xs)
            return carry, None

        (z, coef), _ = jax.lax.scan(sweep, (z, coef), None, length=n_sweeps)
    else:

        def sweep(carry, deliv_t):
            carry, _ = jax.lax.scan(make_color_body(deliv_t), carry, xs)
            return carry, None

        (z, coef), _ = jax.lax.scan(sweep, (z, coef), delivered)
    return z, coef


@partial(jax.jit, static_argnames=("n_sweeps", "engine"))
def colored_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    n_sweeps: int = 1,
    *,
    engine: str = "plan",
    delivered: jax.Array | None = None,
) -> SNTrainState:
    """Distance-2-colored parallel SOP (paper Sec. 3.3 'Parallelism').

    Single-field problems run the same core with B = 1 (so batched B=1 and
    single-field results are identical by construction).

    engine: "plan" (static scatter plans, the O(n*D) default), "onehot"
    (dense one-hot GEMM reference, O(n^2)) or "pallas" (fused VMEM color-step
    kernel).  All three share the local solves and produce identical fixed
    points; see the module docstring.

    delivered: optional (n_sweeps, n+1, D) bool per-sweep link-delivery
    mask (repro.core.faults), shared across fields; dropped messages
    hold their last value.  All-True is bitwise the fault-free sweep,
    engine by engine.
    """
    if problem.batched:
        z, coef = _colored_core(
            problem, problem.nbr_mask, problem.gram, problem.chol,
            state.z, state.coef, n_sweeps, engine, delivered=delivered,
        )
        return SNTrainState(z=z, coef=coef)
    z, coef = _colored_core(
        problem,
        problem.nbr_mask[None], problem.gram[None], problem.chol[None],
        state.z[None], state.coef[None], n_sweeps, engine,
        delivered=delivered,
    )
    return SNTrainState(z=z[0], coef=coef[0])


def local_only(problem: SNTrainProblem) -> SNTrainState:
    """The paper's Sec-4.3 ablation: one local fit, no Update messages.

    Each sensor fits its neighborhood's raw measurements; information never
    propagates. Equivalent to SN-Train's first inner solve with the Update
    step removed.

    Pre-streaming ablation only: it rebuilds the measurement vector from
    ``problem.y``, which does not carry absorbed arrivals (their values live
    in the sweep state's z slots), so it refuses problems with occupied
    stream slots rather than silently fitting them as 0.
    """
    stream_used = problem.nbr_mask & (problem.nbr_idx >= problem.n)
    if bool(stream_used.any()):
        raise NotImplementedError(
            "local_only is the pre-streaming ablation; absorbed arrivals "
            "are not part of problem.y — run it before streaming.absorb"
        )
    pad = problem.n_stream + 1
    alive_row = problem.alive
    alive_slot = problem.alive_z

    def solve_field(y, nbr_mask, chol):
        y_pad = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])

        def solve_s(nbr_idx_s, nbr_mask_s, chol_s, alive_s):
            mask_s = nbr_mask_s & alive_slot[nbr_idx_s] & alive_s
            rhs = jnp.where(mask_s, y_pad[nbr_idx_s], 0.0)
            return jsl.cho_solve((chol_s, True), rhs)

        return y_pad, jax.vmap(solve_s)(
            problem.nbr_idx, nbr_mask, chol, alive_row
        )

    if problem.batched:
        z, coef = jax.vmap(solve_field)(
            problem.y, problem.nbr_mask, problem.chol
        )
    else:
        z, coef = solve_field(problem.y, problem.nbr_mask, problem.chol)
    return SNTrainState(z=z, coef=coef)


# ---------------------------------------------------------------------------
# Sharded engine: sensors (single-field) or fields (batched) distributed over
# a device axis via shard_map.
# ---------------------------------------------------------------------------


def sharded_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    mesh: Mesh,
    *,
    axis: str = "sensors",
    n_sweeps: int = 1,
    engine: str = "plan",
    delivered: jax.Array | None = None,
) -> SNTrainState:
    """colored_sweep distributed with shard_map over `axis`.

    Single-field: color members are sharded across devices.  Every device
    solves its shard of the current color class; because a color's
    neighborhoods are disjoint, the per-device updates touch disjoint slots,
    and the transport reduces to one all-gather of the color's TOUCHED
    values — shape (M*D,) of fresh z messages plus (M, D) of fresh
    coefficients — after which every device applies the color's static
    scatter plan locally.  This replaces the former full (n_z,) + (n+1, D)
    delta psum: per-color traffic is proportional to the color's work, not
    the network size.  z and coef are replicated; the heavy per-sensor
    solves are fully parallel.

    Batched: the *field* axis is sharded instead — fields are independent
    problems, so each device runs the colored engine on its own B/n_dev
    fields with no cross-device traffic at all (the serving-throughput
    configuration).

    delivered: optional (n_sweeps, n+1, D) bool link-delivery mask
    (repro.core.faults).  Delivery is a property of the physical lane,
    so the mask is REPLICATED in both sharding regimes (every device
    applies the same gates to its shard of the work); dropped messages
    hold their last value, all-True is bitwise fault-free.
    """
    if problem.batched:
        return _sharded_sweep_fields(
            problem, state, mesh, axis=axis, n_sweeps=n_sweeps, engine=engine,
            delivered=delivered,
        )

    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    if engine != "plan":
        raise NotImplementedError(
            "single-field sharded_sweep implements the plan transport only "
            "(the psum payload IS the plan's touched-slot buffer); engine "
            "selection applies to batched, field-sharded problems"
        )
    n_dev = mesh.shape[axis]
    n_colors, m_max = problem.color_members.shape
    m_pad = -(-m_max // n_dev) * n_dev  # round up to device multiple
    pad = m_pad - m_max
    members = jnp.pad(
        problem.color_members, ((0, 0), (0, pad)), constant_values=problem.n
    )
    mask = jnp.pad(problem.color_mask, ((0, 0), (0, pad)))
    # Full flat member order per color — the coordinate system of the
    # scatter plans AND of the runtime liveness gate on their codes.
    members_full = members  # (n_colors, m_pad)
    live_full = mask & problem.alive[members_full]  # (n_colors, m_pad)
    # (n_colors, n_dev, m_pad // n_dev): device axis second for sharding.
    # Padding is APPENDED, so a member's global flat position (m*D + k, the
    # coordinate system of the scatter plans) is dev*m_local*D + local.
    members = members.reshape(n_colors, n_dev, -1)
    mask = mask.reshape(n_colors, n_dev, -1)
    solve = partial(
        _color_solve, problem.nbr_idx, problem.lam_pad,
        problem.alive, problem.alive_z,
    )

    def device_fn(z, coef, members_l, mask_l):
        # members_l: (n_colors, 1, m_local) local shard.
        members_l = members_l[:, 0]
        mask_l = mask_l[:, 0]
        xs = (
            members_l, mask_l, problem.plan_z, problem.plan_coef,
            live_full, members_full,
        )

        def make_color_body(deliv_t):
            def color_body(carry, cm):
                z, coef = carry
                mem, mmask, plan_z_c, plan_coef_c, live_c, mem_full = cm
                _, coef_new, z_new = solve(
                    problem.nbr_mask[None], problem.gram[None],
                    problem.chol[None], z[None], coef[None], mem, mmask,
                )
                # Assemble the color's touched values: device order equals
                # the plans' flat member order (padding is appended), so one
                # tiled all-gather of each device's fresh slice IS the
                # (m_pad, D) buffer — no zero-padded psum, payload exactly
                # M*D.
                z_full = jax.lax.all_gather(
                    z_new[0].reshape(-1), axis, tiled=True
                )  # (m_pad*D,)
                c_full = jax.lax.all_gather(
                    coef_new[0], axis, tiled=True
                )  # (m_pad, D)
                # Link delivery gates the full flat buffer (replicated —
                # every device sees the same drops).
                deliv_flat = (
                    None if deliv_t is None
                    else deliv_t[mem_full].reshape(-1)
                )
                z, coef = _apply_plan(
                    z[None], coef[None], z_full[None], c_full[None],
                    plan_z_c, plan_coef_c, live_c, problem.alive_z,
                    deliv_flat,
                )
                return (z[0], coef[0]), None

            return color_body

        if delivered is None:
            body = make_color_body(None)

            def sweep(carry, _):
                carry, _ = jax.lax.scan(body, carry, xs)
                return carry, None

            (z, coef), _ = jax.lax.scan(
                sweep, (z, coef), None, length=n_sweeps
            )
        else:

            def sweep(carry, deliv_t):
                carry, _ = jax.lax.scan(make_color_body(deliv_t), carry, xs)
                return carry, None

            (z, coef), _ = jax.lax.scan(sweep, (z, coef), delivered)
        return z, coef

    fn = compat.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(P(), P(), P(None, axis, None), P(None, axis, None)),
        out_specs=(P(), P()),
    )
    z, coef = jax.jit(fn)(state.z, state.coef, members, mask)
    return SNTrainState(z=z, coef=coef)


def _sharded_sweep_fields(
    problem, state, mesh, *, axis, n_sweeps, engine="plan", delivered=None
):
    """Field-data-parallel sharding of the batched colored engine.

    ``delivered`` rides in by closure: link delivery is shared across
    fields, so the mask is replicated on every device shard."""
    b = problem.batch_size
    n_dev = mesh.shape[axis]
    if b % n_dev != 0:
        raise ValueError(f"batch size {b} must divide over {n_dev} devices")

    def device_fn(nbr_mask, gram, chol, z, coef):
        return _colored_core(
            problem, nbr_mask, gram, chol, z, coef, n_sweeps, engine,
            delivered=delivered,
        )

    spec = P(axis)
    fn = compat.shard_map(
        device_fn, mesh=mesh, in_specs=(spec,) * 5, out_specs=(spec, spec)
    )
    z, coef = jax.jit(fn)(
        problem.nbr_mask, problem.gram, problem.chol, state.z, state.coef
    )
    return SNTrainState(z=z, coef=coef)


# ---------------------------------------------------------------------------
# Paper Sec. 3.3 optional features: random orderings and robustness.
# (Single-field engines; batched problems use serial/colored/sharded above.)
# ---------------------------------------------------------------------------


def _require_single_field(problem: SNTrainProblem, fn_name: str) -> None:
    if problem.batched:
        raise NotImplementedError(
            f"{fn_name} supports single-field problems only; "
            "use serial_sweep/colored_sweep/sharded_sweep for batches"
        )


@partial(jax.jit, static_argnames=("n_sweeps",))
def random_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    key: jax.Array,
    n_sweeps: int = 1,
) -> SNTrainState:
    """ALOHA-style randomized control ordering (paper Sec. 3.3 'Parallelism').

    Each outer iteration visits the sensors in a fresh uniformly-random
    permutation.  Admissible under the Bauschke-Borwein generalized control
    conditions (every sensor appears once per sweep), so Lemma 3.2 carries
    over: same fixed point as the serial Table-1 ordering.
    """
    _require_single_field(problem, "random_sweep")
    n = problem.n

    def sweep(carry, k):
        order = jax.random.permutation(k, n).astype(jnp.int32)
        z, coef = _serial_core(
            problem.nbr_idx, problem.nbr_mask, problem.gram, problem.chol,
            problem.lam_pad, problem.sentinel, carry[0], carry[1], order, 1,
            problem.alive, problem.alive_z,
        )
        return (z, coef), None

    keys = jax.random.split(key, n_sweeps)
    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), keys)
    return SNTrainState(z=z, coef=coef)


def _dynamic_sensor_update(problem, z, coef_s, s, alive_s, alive_row, alive_slot):
    """P_{C_s} with the CURRENT neighborhood N_{s,t} = N_s & alive_s.

    Solves the masked system directly (no cached Cholesky — the active set
    changes per step).  Padded/dead entries keep coefficient 0; the
    PERSISTENT liveness of the problem (``alive_row``/``alive_slot``,
    lifecycle removals) intersects the transient per-sweep link mask, so
    dead sensors neither update nor are read as neighbors here either.
    """
    mask = (
        problem.nbr_mask[s] & alive_s
        & alive_slot[problem.nbr_idx[s]] & alive_row[s]
    )
    gram = jnp.where(mask[:, None] & mask[None, :], problem.gram[s], 0.0)
    lam = problem.lam_pad[s]
    diag = jnp.where(mask, lam, 1.0)
    a = gram + jnp.diag(diag)
    coef_prev = jnp.where(mask, coef_s, 0.0)
    z_nbr = z[problem.nbr_idx[s]]
    rhs = jnp.where(mask, z_nbr + lam * coef_prev, 0.0)
    coef_new = jnp.linalg.solve(a, rhs)
    z_new = gram @ coef_new
    return coef_new, z_new, mask


@partial(jax.jit, static_argnames=("n_sweeps",))
def robust_sweep_links(
    problem: SNTrainProblem,
    state: SNTrainState,
    link_alive: jax.Array,  # (n_sweeps, n, D) bool: per-sweep link liveness
    n_sweeps: int = 1,
) -> SNTrainState:
    """Legacy LINK-level robustness: the paper's Sec. 3.3 model verbatim.

    Each sweep t uses neighborhoods N_{s,t} = N_s intersected with the alive
    links AND the problem's persistent ``alive`` row/slot liveness (a
    lifecycle-removed sensor neither updates nor is read, exactly as in the
    masked serial engine), solved densely per sensor in the serial Table-1
    ordering.  Kept as the single-field reference for asymmetric link
    failures; SENSOR-level churn (the common case) goes through the batched
    alive-masked colored path of ``robust_sweep``.
    """
    _require_single_field(problem, "robust_sweep_links")
    n = problem.n
    sentinel = problem.sentinel
    assert link_alive.shape[0] == n_sweeps
    alive_row = problem.alive
    alive_slot = problem.alive_z

    def body(carry, inp):
        s, alive_s = inp
        z, coef = carry
        coef_new, z_new, mask = _dynamic_sensor_update(
            problem, z, coef[s], s, alive_s, alive_row, alive_slot
        )
        coef = coef.at[s].set(jnp.where(alive_row[s], coef_new, coef[s]))
        scatter_idx = jnp.where(mask, problem.nbr_idx[s], sentinel)
        z = z.at[scatter_idx].set(jnp.where(mask, z_new, z[sentinel]))
        return (z, coef), None

    def sweep(carry, alive_t):
        idxs = jnp.arange(n, dtype=jnp.int32)
        carry, _ = jax.lax.scan(body, carry, (idxs, alive_t))
        return carry, None

    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), link_alive)
    return SNTrainState(z=z, coef=coef)


def _masked_factors(problem: SNTrainProblem, nbr_mask, gram, alive_row):
    """Refactor every local system under the CURRENT liveness mask.

    Mirrors ``make_problem``'s build: mask the Gram to the effective
    (occupancy & liveness) lanes, put lambda on live diagonal entries and 1
    on dead/padded ones, and Cholesky-factor row-wise.  At all-True
    liveness the masked Gram IS the stored Gram (same floats), so on an
    ARRIVAL-FREE problem the recomputed factors equal ``problem.chol``
    bit-for-bit — which is what makes ``robust_sweep`` at full liveness
    bitwise-equal to ``colored_sweep`` there.  Rows that absorbed
    streaming arrivals carry grow-one-updated cached factors whose float
    history a fresh factorization cannot reproduce; for those the
    recomputation matches to factorization noise (the same ~1e-7-level
    bound ``streaming.rebuild_chol`` is tested to).  Shapes:
    nbr_mask/gram carry an explicit leading field axis.
    """
    alive_slot = plans.alive_slots(alive_row, problem.layout.slot_owner)
    lane_alive = alive_slot[problem.nbr_idx] & alive_row[:, None]  # (n+1, D)
    mask_eff = nbr_mask & lane_alive[None]  # (B, n+1, D)
    outer = mask_eff[..., :, None] & mask_eff[..., None, :]
    gram_eff = jnp.where(outer, gram, 0.0)
    d = gram.shape[-1]
    diag = jnp.where(mask_eff, problem.lam_pad[None, :, None], 1.0)
    a = gram_eff + diag[..., None] * jnp.eye(d, dtype=gram.dtype)
    chol_eff = jax.vmap(jax.vmap(lambda m: jsl.cholesky(m, lower=True)))(a)
    return gram_eff, chol_eff


@partial(jax.jit, static_argnames=("n_sweeps", "engine"))
def _robust_colored(problem, state, alive_tn, n_sweeps, engine, delivered=None):
    batched = problem.batched
    nbr_mask = problem.nbr_mask if batched else problem.nbr_mask[None]
    gram = problem.gram if batched else problem.gram[None]
    z = state.z if batched else state.z[None]
    coef = state.coef if batched else state.coef[None]

    def sweep_body(carry, inp):
        alive_t, deliv_t = inp
        z, coef = carry
        alive_row = problem.alive & jnp.concatenate(
            [alive_t, jnp.ones((1,), bool)]
        )
        gram_eff, chol_eff = _masked_factors(problem, nbr_mask, gram, alive_row)
        z, coef = _colored_core(
            problem, nbr_mask, gram_eff, chol_eff, z, coef, 1, engine,
            alive=alive_row,
            delivered=None if deliv_t is None else deliv_t[None],
        )
        return (z, coef), None

    (z, coef), _ = jax.lax.scan(sweep_body, (z, coef), (alive_tn, delivered))
    if batched:
        return SNTrainState(z=z, coef=coef)
    return SNTrainState(z=z[0], coef=coef[0])


def robust_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    alive: jax.Array,
    n_sweeps: int = 1,
    *,
    engine: str = "plan",
    delivered: jax.Array | None = None,
) -> SNTrainState:
    """SN-Train with a changing topology (paper Sec. 3.3 'Robustness').

    SENSOR-level liveness, batched: ``alive`` is (n,) or (n_sweeps, n)
    bool; sweep t runs the alive-masked colored engine under
    ``alive[t] & problem.alive`` — dead sensors neither update nor are
    heard from, and every engine's scatter is gated on BOTH the source
    member's and the target slot's liveness, so a down mote's messages and
    coefficients persist untouched and a healed sensor resumes from its
    last state (the paper's 'solution implied by the neighborhood
    occurring infinitely often').  Because liveness is TRANSIENT here (no
    lifecycle event patches the cached factors), every sweep refactorizes
    the masked local systems in one batched pass — O(n*D^3) per sweep, the
    robustness price — then dispatches the normal engines, so the call
    accepts a leading field axis and every
    ``engine={"plan","onehot","pallas"}`` like ``colored_sweep``:
    "plan" == "onehot" bit-for-bit at any liveness, and at all-True
    liveness on an ARRIVAL-FREE problem the recomputed factors equal the
    cached ones bit-for-bit, so ``robust_sweep == colored_sweep`` exactly,
    engine by engine (tests/test_lifecycle.py; after streaming absorption
    the cached factors carry grow-one float history, and the match is to
    ~1e-7 factorization noise instead — see ``_masked_factors``).
    ``alive`` is a traced operand: one compiled program serves every
    failure trace of a given length.

    PERSISTENT membership changes should use ``streaming.add_sensor`` /
    ``remove_sensor`` instead, which patch the factors once per event so
    ``colored_sweep`` keeps its cached-factor speed.

    ``delivered``: optional (n_sweeps, n+1, D) bool per-sweep
    link-delivery mask (repro.core.faults) composed ON TOP of the
    per-sweep liveness — a crashed-sensor schedule with lossy links is
    exactly this call (``faults.faulty_sweep`` dispatches here when the
    model crashes sensors).  All-True is the plain robust sweep bitwise.

    Legacy LINK-level traces — (n_sweeps, n, D) bool — route to the
    original serial dense path (``robust_sweep_links``), single-field
    only, unchanged (and without fault injection).
    """
    alive = jnp.asarray(alive)
    if alive.ndim == 3:
        if delivered is not None:
            raise NotImplementedError(
                "delivered masks compose with SENSOR-level alive traces; "
                "legacy link-level traces already encode per-lane loss"
            )
        return robust_sweep_links(problem, state, alive, n_sweeps)
    alive = alive.astype(bool)
    if alive.ndim == 1:
        alive = jnp.broadcast_to(alive[None], (n_sweeps,) + alive.shape)
    if alive.shape != (n_sweeps, problem.n):
        raise ValueError(
            f"alive must be (n,), (n_sweeps={n_sweeps}, n={problem.n}) "
            f"or legacy (n_sweeps, n, D); got {alive.shape}"
        )
    return _robust_colored(
        problem, state, alive, n_sweeps=n_sweeps, engine=engine,
        delivered=delivered,
    )


# ---------------------------------------------------------------------------
# Paper Sec. 5.2 extension: weighted (heteroscedastic) losses.
#
# The paper notes SOP generalizes to Bregman projections for other losses.
# The simplest non-trivial instance keeps orthogonality by reweighting the
# product-space norm:   sum_j w_j z_j^2 + sum_i lambda_i ||f_i||^2,
# i.e. per-sensor measurement confidences w_j (inverse noise variances).
# The local solve becomes  (W_s K_s + lambda_s I) c = W_s z + lambda_s c_prev
# (non-symmetric; solved directly, no cached Cholesky).
# ---------------------------------------------------------------------------


def _weighted_sensor_update(problem, z, coef_s, s, w_pad, alive_row, alive_slot):
    mask = (
        problem.nbr_mask[s] & alive_slot[problem.nbr_idx[s]] & alive_row[s]
    )
    gram = jnp.where(mask[:, None] & mask[None, :], problem.gram[s], 0.0)
    lam = problem.lam_pad[s]
    w_nbr = jnp.where(mask, w_pad[problem.nbr_idx[s]], 0.0)
    diag = jnp.where(mask, lam, 1.0)
    a = w_nbr[:, None] * gram + jnp.diag(diag)
    z_nbr = z[problem.nbr_idx[s]]
    rhs = jnp.where(mask, w_nbr * z_nbr + lam * coef_s, 0.0)
    coef_new = jnp.linalg.solve(a, rhs)
    z_new = gram @ coef_new
    return coef_new, z_new, mask


@partial(jax.jit, static_argnames=("n_sweeps",))
def weighted_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    weights: jax.Array,  # (n,) per-sensor measurement confidences w_j > 0
    n_sweeps: int = 1,
) -> SNTrainState:
    """SN-Train under the reweighted norm (heteroscedastic measurements).

    weights == 1 reduces exactly to serial_sweep.  Fejér monotonicity holds
    in the reweighted norm (see weighted_norm_sq_hetero).  Liveness is
    threaded exactly as in the serial engine: dead (removed) sensors
    neither update nor are read as neighbors, and their messages persist
    (tests/test_sn_train.py pins this to the masked serial engine)."""
    _require_single_field(problem, "weighted_sweep")
    n = problem.n
    sentinel = problem.sentinel
    w_pad = jnp.concatenate(
        [
            jnp.asarray(weights, state.z.dtype),
            jnp.zeros((problem.n_stream + 1,), state.z.dtype),
        ]
    )
    idxs = jnp.arange(n, dtype=jnp.int32)
    alive_row = problem.alive
    alive_slot = problem.alive_z

    def body(carry, s):
        z, coef = carry
        coef_new, z_new, mask = _weighted_sensor_update(
            problem, z, coef[s], s, w_pad, alive_row, alive_slot
        )
        coef = coef.at[s].set(jnp.where(alive_row[s], coef_new, coef[s]))
        scatter_idx = jnp.where(mask, problem.nbr_idx[s], sentinel)
        z = z.at[scatter_idx].set(jnp.where(mask, z_new, z[sentinel]))
        return (z, coef), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(body, carry, idxs)
        return carry, None

    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), None, length=n_sweeps)
    return SNTrainState(z=z, coef=coef)


def weighted_norm_sq_hetero(
    problem: SNTrainProblem, state: SNTrainState, weights: jax.Array
) -> jax.Array:
    """sum_j w_j z_j^2 + sum_i lambda_i ||f_i||^2 — the Fejér invariant of
    weighted_sweep."""
    n = problem.n
    z_part = jnp.sum(jnp.asarray(weights) * state.z[..., :n] ** 2, axis=-1)
    quad = jnp.einsum(
        "...sd,...sde,...se->...s", state.coef, problem.gram, state.coef
    )
    return z_part + jnp.sum(problem.lam_pad * quad, axis=-1)
