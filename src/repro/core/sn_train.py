"""SN-Train: distributed kernel regression by alternating projections.

Faithful implementation of the paper's Table 1 / Eq. 18.  Each sensor ``s``
keeps a local function ``f_s = sum_{j in N_s} c_{s,j} K(., x_j)`` (Lemma 3.3)
and a shared message vector ``z in R^n`` (the network's running estimate of
the field at sensor locations).  One projection step at sensor s:

    c_{s,t} = (K_s + lambda_s I)^{-1} (z_{N_s, t-1} + lambda_s c_{s,t-1})
    z_j <- f_{s,t}(x_j)   for j in N_s

Three execution engines, all with identical fixed points:

  * ``serial_sweep``   — the paper's Table-1 ordering, one sensor at a time
                         (lax.scan over sensors).
  * ``colored_sweep``  — the paper's Sec-3.3 "Parallelism": all sensors of one
                         distance-2 color class update simultaneously as a
                         single batched Cholesky solve (MXU-shaped), colors
                         sweep serially.  This is the TPU-native engine.
  * ``sharded_sweep``  — ``colored_sweep`` distributed with shard_map over a
                         device axis: each device solves its members of the
                         current color; the Update messages travel as a psum
                         of disjoint deltas (the all-reduce transport of the
                         paper's neighbor messages).

Fixed shapes everywhere: neighborhoods are padded to D_max, color classes to
M_max, and the message vector carries one sentinel slot (index n) so padded
scatters are harmless.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .kernels_math import Kernel
from .topology import SensorTopology


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SNTrainProblem:
    """Static per-network precomputation for SN-Train.

    All arrays are padded to fixed shapes. ``n`` below is the sensor count,
    ``D`` the padded neighborhood size, ``C``/``M`` colors and members.
    """

    topology: SensorTopology
    kernel: Kernel = dataclasses.field(metadata=dict(static=True))
    y: jnp.ndarray  # (n,) measurements
    lambdas: jnp.ndarray  # (n,) per-sensor regularizers
    nbr_pos: jnp.ndarray  # (n+1, D, d) neighbor positions (padded row n)
    nbr_idx: jnp.ndarray  # (n+1, D) neighbor indices (sentinel row n)
    nbr_mask: jnp.ndarray  # (n+1, D)
    gram: jnp.ndarray  # (n+1, D, D) masked local Gram K_s (zeros off-mask)
    chol: jnp.ndarray  # (n+1, D, D) lower Cholesky of K_s + lambda_s I (padded dims get identity)
    lam_pad: jnp.ndarray  # (n+1,)

    @property
    def n(self) -> int:
        return self.topology.n


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SNTrainState:
    z: jnp.ndarray  # (n+1,) messages; z[n] is a write sentinel
    coef: jnp.ndarray  # (n+1, D) per-sensor representer coefficients


def default_lambdas(topology: SensorTopology, kappa: float = 0.01) -> jnp.ndarray:
    """Paper Sec. 4.1: lambda_i = kappa / |N_i|^2 with kappa = 0.01."""
    deg = topology.degrees.astype(jnp.float32)
    return kappa / (deg**2)


def make_problem(
    topology: SensorTopology,
    kernel: Kernel,
    y: jax.Array,
    lambdas: jax.Array | None = None,
    *,
    dtype=jnp.float32,
) -> SNTrainProblem:
    """Precompute the padded SN-Train problem.

    dtype: float32 is the TPU-friendly default, but the paper's own
    regularizers (lambda_i = 0.01/|N_i|^2 ~ 1e-5) make the local systems
    condition at ~1e9 where f32 solves systematically violate the projection
    property (the weighted norm grows and the sweep diverges).  Pass
    jnp.float64 (with JAX_ENABLE_X64) to reproduce the paper's numerics;
    alternatively raise lambda (see tests/test_sn_train.py).
    """
    n, d_max = topology.nbr_idx.shape
    d = topology.positions.shape[1]
    if lambdas is None:
        lambdas = default_lambdas(topology)
    lambdas = jnp.asarray(lambdas, dtype)

    # Pad one sentinel row so color-member gathers at index n are in-bounds.
    nbr_idx = jnp.concatenate(
        [topology.nbr_idx, jnp.zeros((1, d_max), jnp.int32)], axis=0
    )
    nbr_mask = jnp.concatenate(
        [topology.nbr_mask, jnp.zeros((1, d_max), bool)], axis=0
    )
    pos_pad = jnp.concatenate(
        [topology.positions.astype(dtype), jnp.zeros((1, d), dtype)], axis=0
    )
    nbr_pos = pos_pad[nbr_idx]  # (n+1, D, d)
    lam_pad = jnp.concatenate([lambdas, jnp.ones((1,), dtype)])

    def local_system(pos_s, mask_s, lam_s):
        k = kernel(pos_s, pos_s)  # (D, D)
        outer = mask_s[:, None] & mask_s[None, :]
        k = jnp.where(outer, k, 0.0)
        # Solve matrix: valid block gets +lambda on the diagonal; padded
        # diagonal entries are set to 1 so the factorization is SPD and the
        # padded coefficients stay exactly 0 (their rhs is 0).
        diag = jnp.where(mask_s, lam_s, 1.0)
        a = k + jnp.diag(diag)
        return k, jsl.cholesky(a, lower=True)

    gram, chol = jax.vmap(local_system)(nbr_pos, nbr_mask, lam_pad)
    return SNTrainProblem(
        topology=topology,
        kernel=kernel,
        y=jnp.asarray(y, dtype),
        lambdas=lambdas,
        nbr_pos=nbr_pos,
        nbr_idx=nbr_idx,
        nbr_mask=nbr_mask,
        gram=gram,
        chol=chol,
        lam_pad=lam_pad,
    )


def weighted_norm_sq(problem: SNTrainProblem, state: SNTrainState) -> jax.Array:
    """The SOP product-space norm  ||z||^2 + sum_i lambda_i ||f_i||^2_{H_K}.

    By Lemma 2.1 (0 is in the intersection C, all C_i are subspaces) this is
    non-increasing along ANY admissible SOP ordering — the invariant the
    property tests assert.  Note ||f_i||^2 = c_i^T K_i c_i.
    """
    n = problem.n
    z_part = jnp.sum(state.z[:n] ** 2)
    quad = jnp.einsum("sd,sde,se->s", state.coef, problem.gram, state.coef)
    return z_part + jnp.sum(problem.lam_pad * quad)


def init_state(problem: SNTrainProblem) -> SNTrainState:
    """Paper Table 1 initialization: z_{s,0} = y_s, f_{s,0} = 0."""
    n = problem.n
    d_max = problem.nbr_idx.shape[1]
    dt = problem.y.dtype
    z = jnp.concatenate([problem.y, jnp.zeros((1,), dt)])
    coef = jnp.zeros((n + 1, d_max), dt)
    return SNTrainState(z=z, coef=coef)


def _sensor_update(z, coef_s, nbr_idx_s, nbr_mask_s, gram_s, chol_s, lam_s):
    """One P_{C_s} projection (Eq. 18). Returns (coef_s', z-values at N_s)."""
    z_nbr = z[nbr_idx_s]  # (D,)
    rhs = jnp.where(nbr_mask_s, z_nbr + lam_s * coef_s, 0.0)
    coef_new = jsl.cho_solve((chol_s, True), rhs)
    z_new = gram_s @ coef_new  # f_s(x_j) for j in N_s (masked gram)
    return coef_new, z_new


@partial(jax.jit, static_argnames=("n_sweeps",))
def serial_sweep(
    problem: SNTrainProblem, state: SNTrainState, n_sweeps: int = 1
) -> SNTrainState:
    """The paper's Table-1 serial ordering: for t: for s: project."""
    n = problem.n
    idxs = jnp.arange(n, dtype=jnp.int32)

    def body(carry, s):
        z, coef = carry
        coef_s = coef[s]
        coef_new, z_new = _sensor_update(
            z,
            coef_s,
            problem.nbr_idx[s],
            problem.nbr_mask[s],
            problem.gram[s],
            problem.chol[s],
            problem.lam_pad[s],
        )
        coef = coef.at[s].set(coef_new)
        scatter_idx = jnp.where(problem.nbr_mask[s], problem.nbr_idx[s], n)
        z = z.at[scatter_idx].set(jnp.where(problem.nbr_mask[s], z_new, z[n]))
        return (z, coef), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(body, carry, idxs)
        return carry, None

    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), None, length=n_sweeps)
    return SNTrainState(z=z, coef=coef)


def _color_update(problem: SNTrainProblem, z, coef, members, member_mask):
    """Simultaneous P_{C_s} for all sensors of one color (disjoint N_s)."""
    n = problem.n
    nbr_idx_m = problem.nbr_idx[members]  # (M, D)
    nbr_mask_m = problem.nbr_mask[members] & member_mask[:, None]
    gram_m = problem.gram[members]
    chol_m = problem.chol[members]
    lam_m = problem.lam_pad[members]
    coef_m = coef[members]

    coef_new, z_new = jax.vmap(
        lambda c, ni, nm, g, ch, lm: _sensor_update(z, c, ni, nm, g, ch, lm)
    )(coef_m, nbr_idx_m, nbr_mask_m, gram_m, chol_m, lam_m)

    coef = coef.at[members].set(jnp.where(member_mask[:, None], coef_new, coef[members]))
    scatter_idx = jnp.where(nbr_mask_m, nbr_idx_m, n)  # (M, D)
    z = z.at[scatter_idx.reshape(-1)].set(
        jnp.where(nbr_mask_m, z_new, z[n]).reshape(-1)
    )
    return z, coef


@partial(jax.jit, static_argnames=("n_sweeps",))
def colored_sweep(
    problem: SNTrainProblem, state: SNTrainState, n_sweeps: int = 1
) -> SNTrainState:
    """Distance-2-colored parallel SOP (paper Sec. 3.3 'Parallelism')."""
    topo = problem.topology

    def color_body(carry, cm):
        z, coef = carry
        members, member_mask = cm
        z, coef = _color_update(problem, z, coef, members, member_mask)
        return (z, coef), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(
            color_body, carry, (topo.color_members, topo.color_mask)
        )
        return carry, None

    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), None, length=n_sweeps)
    return SNTrainState(z=z, coef=coef)


def local_only(problem: SNTrainProblem) -> SNTrainState:
    """The paper's Sec-4.3 ablation: one local fit, no Update messages.

    Each sensor fits its neighborhood's raw measurements; information never
    propagates. Equivalent to SN-Train's first inner solve with the Update
    step removed.
    """
    n = problem.n
    y_pad = jnp.concatenate([problem.y, jnp.zeros((1,), jnp.float32)])

    def solve_s(nbr_idx_s, nbr_mask_s, chol_s):
        rhs = jnp.where(nbr_mask_s, y_pad[nbr_idx_s], 0.0)
        return jsl.cho_solve((chol_s, True), rhs)

    coef = jax.vmap(solve_s)(problem.nbr_idx, problem.nbr_mask, problem.chol)
    return SNTrainState(z=y_pad, coef=coef)


# ---------------------------------------------------------------------------
# Sharded engine: sensors distributed over a device axis via shard_map.
# ---------------------------------------------------------------------------


def sharded_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    mesh: Mesh,
    *,
    axis: str = "sensors",
    n_sweeps: int = 1,
) -> SNTrainState:
    """colored_sweep with color members sharded across `axis`.

    Every device updates its shard of the current color class; because a
    color's neighborhoods are disjoint, the per-device message updates are
    disjoint scatters, and the transport reduces to one psum of deltas per
    color step — the all-reduce realization of the paper's neighbor messages
    (DESIGN.md Sec. 2).  z and coef are replicated; the heavy per-sensor
    solves are fully parallel.
    """
    topo = problem.topology
    n = problem.n
    n_dev = mesh.shape[axis]
    n_colors, m_max = topo.color_members.shape
    m_pad = -(-m_max // n_dev) * n_dev  # round up to device multiple
    pad = m_pad - m_max
    members = jnp.pad(topo.color_members, ((0, 0), (0, pad)), constant_values=n)
    mask = jnp.pad(topo.color_mask, ((0, 0), (0, pad)))
    # (n_colors, n_dev, m_pad // n_dev): device axis second for sharding.
    members = members.reshape(n_colors, n_dev, -1)
    mask = mask.reshape(n_colors, n_dev, -1)

    def device_fn(z, coef, members_l, mask_l):
        # members_l: (n_colors, 1, m_local) local shard.
        members_l = members_l[:, 0]
        mask_l = mask_l[:, 0]

        def color_body(carry, cm):
            z, coef = carry
            mem, mmask = cm
            z_new, coef_new = _color_update(problem, z, coef, mem, mmask)
            dz = jax.lax.psum(z_new - z, axis)
            dcoef = jax.lax.psum(coef_new - coef, axis)
            return (z + dz, coef + dcoef), None

        def sweep(carry, _):
            carry, _ = jax.lax.scan(color_body, carry, (members_l, mask_l))
            return carry, None

        (z, coef), _ = jax.lax.scan(sweep, (z, coef), None, length=n_sweeps)
        return z, coef

    fn = jax.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(P(), P(), P(None, axis, None), P(None, axis, None)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    z, coef = jax.jit(fn)(state.z, state.coef, members, mask)
    return SNTrainState(z=z, coef=coef)


# ---------------------------------------------------------------------------
# Paper Sec. 3.3 optional features: random orderings and robustness.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_sweeps",))
def random_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    key: jax.Array,
    n_sweeps: int = 1,
) -> SNTrainState:
    """ALOHA-style randomized control ordering (paper Sec. 3.3 'Parallelism').

    Each outer iteration visits the sensors in a fresh uniformly-random
    permutation.  Admissible under the Bauschke-Borwein generalized control
    conditions (every sensor appears once per sweep), so Lemma 3.2 carries
    over: same fixed point as the serial Table-1 ordering.
    """
    n = problem.n

    def body(carry, s):
        z, coef = carry
        coef_new, z_new = _sensor_update(
            z, coef[s], problem.nbr_idx[s], problem.nbr_mask[s],
            problem.gram[s], problem.chol[s], problem.lam_pad[s],
        )
        coef = coef.at[s].set(coef_new)
        scatter_idx = jnp.where(problem.nbr_mask[s], problem.nbr_idx[s], n)
        z = z.at[scatter_idx].set(jnp.where(problem.nbr_mask[s], z_new, z[n]))
        return (z, coef), None

    def sweep(carry, k):
        order = jax.random.permutation(k, n).astype(jnp.int32)
        carry, _ = jax.lax.scan(body, carry, order)
        return carry, None

    keys = jax.random.split(key, n_sweeps)
    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), keys)
    return SNTrainState(z=z, coef=coef)


def _dynamic_sensor_update(problem, z, coef_s, s, alive_s):
    """P_{C_s} with the CURRENT neighborhood N_{s,t} = N_s & alive_s.

    Solves the masked system directly (no cached Cholesky — the active set
    changes per step).  Padded/dead entries keep coefficient 0.
    """
    n = problem.n
    mask = problem.nbr_mask[s] & alive_s
    gram = jnp.where(mask[:, None] & mask[None, :], problem.gram[s], 0.0)
    lam = problem.lam_pad[s]
    diag = jnp.where(mask, lam, 1.0)
    a = gram + jnp.diag(diag)
    coef_prev = jnp.where(mask, coef_s, 0.0)
    z_nbr = z[problem.nbr_idx[s]]
    rhs = jnp.where(mask, z_nbr + lam * coef_prev, 0.0)
    coef_new = jnp.linalg.solve(a, rhs)
    z_new = gram @ coef_new
    return coef_new, z_new, mask


@partial(jax.jit, static_argnames=("n_sweeps",))
def robust_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    link_alive: jax.Array,  # (n_sweeps, n, D) bool: per-sweep link liveness
    n_sweeps: int = 1,
) -> SNTrainState:
    """SN-Train with a changing topology (paper Sec. 3.3 'Robustness').

    Each sweep t uses neighborhoods N_{s,t} = N_s intersected with the alive
    links; per the paper, the iteration still makes progress every step and
    converges to the solution implied by the largest neighborhood occurring
    infinitely often.  With link_alive all-True this is exactly serial_sweep
    (up to solver choice) — asserted in tests.
    """
    n = problem.n
    assert link_alive.shape[0] == n_sweeps

    def body(carry, inp):
        s, alive_s = inp
        z, coef = carry
        coef_new, z_new, mask = _dynamic_sensor_update(problem, z, coef[s], s, alive_s)
        coef = coef.at[s].set(coef_new)
        scatter_idx = jnp.where(mask, problem.nbr_idx[s], n)
        z = z.at[scatter_idx].set(jnp.where(mask, z_new, z[n]))
        return (z, coef), None

    def sweep(carry, alive_t):
        idxs = jnp.arange(n, dtype=jnp.int32)
        carry, _ = jax.lax.scan(body, carry, (idxs, alive_t))
        return carry, None

    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), link_alive)
    return SNTrainState(z=z, coef=coef)


# ---------------------------------------------------------------------------
# Paper Sec. 5.2 extension: weighted (heteroscedastic) losses.
#
# The paper notes SOP generalizes to Bregman projections for other losses.
# The simplest non-trivial instance keeps orthogonality by reweighting the
# product-space norm:   sum_j w_j z_j^2 + sum_i lambda_i ||f_i||^2,
# i.e. per-sensor measurement confidences w_j (inverse noise variances).
# The local solve becomes  (W_s K_s + lambda_s I) c = W_s z + lambda_s c_prev
# (non-symmetric; solved directly, no cached Cholesky).
# ---------------------------------------------------------------------------


def _weighted_sensor_update(problem, z, coef_s, s, w_pad):
    n = problem.n
    mask = problem.nbr_mask[s]
    gram = problem.gram[s]
    lam = problem.lam_pad[s]
    w_nbr = jnp.where(mask, w_pad[problem.nbr_idx[s]], 0.0)
    diag = jnp.where(mask, lam, 1.0)
    a = w_nbr[:, None] * gram + jnp.diag(diag)
    z_nbr = z[problem.nbr_idx[s]]
    rhs = jnp.where(mask, w_nbr * z_nbr + lam * coef_s, 0.0)
    coef_new = jnp.linalg.solve(a, rhs)
    z_new = gram @ coef_new
    return coef_new, z_new


@partial(jax.jit, static_argnames=("n_sweeps",))
def weighted_sweep(
    problem: SNTrainProblem,
    state: SNTrainState,
    weights: jax.Array,  # (n,) per-sensor measurement confidences w_j > 0
    n_sweeps: int = 1,
) -> SNTrainState:
    """SN-Train under the reweighted norm (heteroscedastic measurements).

    weights == 1 reduces exactly to serial_sweep.  Fejér monotonicity holds
    in the reweighted norm (see weighted_norm_sq_hetero)."""
    n = problem.n
    w_pad = jnp.concatenate([jnp.asarray(weights, state.z.dtype), jnp.zeros((1,), state.z.dtype)])
    idxs = jnp.arange(n, dtype=jnp.int32)

    def body(carry, s):
        z, coef = carry
        coef_new, z_new = _weighted_sensor_update(problem, z, coef[s], s, w_pad)
        coef = coef.at[s].set(coef_new)
        scatter_idx = jnp.where(problem.nbr_mask[s], problem.nbr_idx[s], n)
        z = z.at[scatter_idx].set(jnp.where(problem.nbr_mask[s], z_new, z[n]))
        return (z, coef), None

    def sweep(carry, _):
        carry, _ = jax.lax.scan(body, carry, idxs)
        return carry, None

    (z, coef), _ = jax.lax.scan(sweep, (state.z, state.coef), None, length=n_sweeps)
    return SNTrainState(z=z, coef=coef)


def weighted_norm_sq_hetero(
    problem: SNTrainProblem, state: SNTrainState, weights: jax.Array
) -> jax.Array:
    """sum_j w_j z_j^2 + sum_i lambda_i ||f_i||^2 — the Fejér invariant of
    weighted_sweep."""
    n = problem.n
    z_part = jnp.sum(jnp.asarray(weights) * state.z[:n] ** 2)
    quad = jnp.einsum("sd,sde,se->s", state.coef, problem.gram, state.coef)
    return z_part + jnp.sum(problem.lam_pad * quad)
