"""Generic successive-orthogonal-projection (SOP) machinery (paper Sec. 2.1).

Given closed convex sets C_1..C_m with projections P_i, SOP iterates

    x_0 = x_hat,   x_k = P_{C_{k mod m + 1}}(x_{k-1})            (paper Eq. 1)

Lemma 2.1 (Fejer monotonicity): ||x_k - x|| <= ||x_{k-1} - x|| for any
x in C = intersection; for subspaces, x_k -> P_C(x_hat).

This module provides:
  * affine-subspace projectors P(x) = x - A^T (A A^T)^+ (A x - b),
  * a `sop_sweep` runner (lax control flow) over a stack of affine sets,
  * Fejer monitors used by the property tests.

These generic pieces back the property tests of the paper's lemmas; the
specialized, padded sensor instantiation lives in `sn_train.py`.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def project_affine(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Orthogonal projection of x onto {v : A v = b} (A full row rank-ish).

    Uses a pseudo-inverse-stable solve: P(x) = x - A^T (A A^T + eps I)^{-1}(Ax - b).
    """
    m = a.shape[0]
    gram = a @ a.T + 1e-10 * jnp.eye(m, dtype=x.dtype)
    resid = a @ x - b
    return x - a.T @ jnp.linalg.solve(gram, resid)


@partial(jax.jit, static_argnames=("n_sweeps",))
def sop_sweep(
    x0: jax.Array, a_stack: jax.Array, b_stack: jax.Array, n_sweeps: int = 1
) -> jax.Array:
    """Run `n_sweeps` full passes of SOP over m affine sets.

    a_stack: (m, k, dim), b_stack: (m, k). Serial by definition (Eq. 1).
    """

    def one_set(x, ab):
        a, b = ab
        return project_affine(x, a, b), None

    def one_sweep(x, _):
        x, _ = jax.lax.scan(one_set, x, (a_stack, b_stack))
        return x, None

    x, _ = jax.lax.scan(one_sweep, x0, None, length=n_sweeps)
    return x


@partial(jax.jit, static_argnames=("n_sweeps",))
def sop_sweep_with_trace(
    x0: jax.Array, a_stack: jax.Array, b_stack: jax.Array, n_sweeps: int = 1
) -> tuple[jax.Array, jax.Array]:
    """Like sop_sweep but also returns every post-projection iterate.

    Trace shape: (n_sweeps * m, dim) — used to verify Lemma 2.1 pointwise.
    """

    def one_set(x, ab):
        a, b = ab
        x = project_affine(x, a, b)
        return x, x

    def one_sweep(x, _):
        x, trace = jax.lax.scan(one_set, x, (a_stack, b_stack))
        return x, trace

    x, traces = jax.lax.scan(one_sweep, x0, None, length=n_sweeps)
    return x, traces.reshape(-1, x0.shape[-1])


def project_intersection(
    x0: jax.Array, a_stack: jax.Array, b_stack: jax.Array
) -> jax.Array:
    """Direct projection onto the intersection of all affine sets (oracle)."""
    a = a_stack.reshape(-1, a_stack.shape[-1])
    b = b_stack.reshape(-1)
    # Least-norm correction via pinv handles rank deficiency from overlap.
    return x0 - jnp.linalg.pinv(a) @ (a @ x0 - b)


def fejer_distances(trace: jax.Array, feasible_point: jax.Array) -> jax.Array:
    """||x_k - x*|| for every iterate in the trace (must be non-increasing)."""
    return jnp.linalg.norm(trace - feasible_point[None, :], axis=-1)
