"""Streaming measurement absorption for batched SN-Train problems.

Sensor networks do not observe a field once: readings keep arriving.  The
recursive-least-squares line of work (Mateos & Giannakis, arXiv:1109.4627)
absorbs each arrival into the running estimator with an O(D^2) update rather
than refitting from scratch; this module is that idea instantiated for the
paper's SN-Train local systems.

An arrival ``(field b, sensor s, location x, value y)`` becomes one more
data point owned by sensor s: it occupies the next free padded slot ``k`` of
N_s (build the topology with ``d_max`` headroom for capacity), whose FIXED
reserved message slot ``nbr_idx[s, k]`` was assigned at problem build (see
sn_train's message-slot layout).  The local system of sensor s grows by one
row/column:

    A_s' = [[A_s, a], [a^T, K(x,x) + lambda_s]]

whose Cholesky factor differs from chol[s] in a single new row — computed
with one triangular solve and a scalar square root (the classic rank-1
"grow" update):

    w = L_s^{-1} a,    d = sqrt(K(x,x) + lambda_s - w^T w)

O(D^2) instead of the O(D^3) refactorization, and exact: after any number of
absorptions ``problem.chol`` equals ``rebuild_chol(problem)`` to float
precision (asserted in tests/test_multifield.py).  Because the padded free
slots of ``chol`` are identity rows and arrivals fill slots left-to-right,
the fixed-shape masked triangular solve below IS the textbook update.

Other sensors never reference the new point (it joins N_s only), so the SOP
sweep machinery — serial, colored, sharded — runs unchanged on the absorbed
problem; a few post-arrival sweeps propagate the new information through the
network.  All constraint sets remain subspaces containing 0, so Fejér
monotonicity of the weighted norm (Lemma 2.1) is preserved across arrivals.

``absorb`` handles one arrival per dispatch; ``absorb_many`` runs a whole
arrival window through the identical per-step update under one
``lax.scan`` (one compiled program, one host round-trip — the serving
stream loop's configuration; equals repeated ``absorb`` exactly, see
tests/test_serving.py).

Over-capacity policy: by default an arrival at a FULL sensor is dropped.
``evict_oldest`` frees a full sensor's oldest arrival instead — remaining
arrivals shift down one slot (preserving the left-to-right == chronological
invariant the grow-one update relies on) and the sensor's factor is
downdated by a masked rebuild of its (D, D) Cholesky, O(D^3) for ONE sensor.
``absorb(..., on_full="evict")`` applies it automatically, turning each
sensor's stream slots into a sliding window over its most recent arrivals.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from . import plans
from .sn_train import SNTrainProblem, SNTrainState, _masked_factors


class AbsorbReceipt(NamedTuple):
    """Per-arrival outcome flags of ``absorb_many`` (both (A,) bool).

    ``absorbed``: the arrival was written (possibly after an eviction);
    ``evicted``: the ``on_full="evict"`` policy freed the sensor's oldest
    arrival first.  ``~absorbed`` arrivals were dropped (sensor full under
    the drop policy, zero-capacity window sensor, or dead sensor).
    """

    absorbed: jax.Array
    evicted: jax.Array


def capacity_left(problem: SNTrainProblem) -> jnp.ndarray:
    """(B, n) free neighborhood slots per (field, sensor)."""
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    return jnp.sum(~problem.nbr_mask[:, :-1, :], axis=-1)


def _absorb(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    x: jax.Array,
    y: jax.Array,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    n = problem.n
    field = jnp.asarray(field, jnp.int32)
    sensor = jnp.asarray(sensor, jnp.int32)
    dt = problem.nbr_pos.dtype
    x = jnp.asarray(x, dt).reshape(-1)  # (d,)
    y = jnp.asarray(y, state.z.dtype)

    mask_s = problem.nbr_mask[field, sensor]  # (D,)
    # A free slot must exist and the sensor must be ALIVE; else DROP.
    ok = jnp.any(~mask_s) & problem.alive[sensor]
    k = jnp.argmin(mask_s)  # first free slot (arrivals fill left-to-right)
    zid = problem.nbr_idx[sensor, k]  # fixed reserved message slot
    pos_s = problem.nbr_pos[field, sensor]  # (D, d)
    lam_s = problem.lam_pad[sensor]

    # The kernel vector is masked to the EFFECTIVE lanes (occupied & alive):
    # a removed neighbor's lane keeps its occupancy but is factored out of
    # the cached Cholesky, and must stay out of the grow-one update too.
    mask_eff = mask_s & problem.alive_z[problem.nbr_idx[sensor]]
    kvec = jnp.where(mask_eff, problem.kernel(x[None, :], pos_s)[0], 0.0)  # (D,)
    kself = problem.kernel(x[None, :], x[None, :])[0, 0]

    new_row = kvec.at[k].set(kself)
    gram_s = problem.gram[field, sensor]
    gram_s = gram_s.at[k, :].set(new_row).at[:, k].set(new_row)

    # Grow-one Cholesky: rows >= k of chol[s] are identity (padded), so the
    # full-shape triangular solve returns w on the valid prefix and zeros
    # elsewhere; only row k of the factor changes.
    chol_s = problem.chol[field, sensor]
    w = jsl.solve_triangular(chol_s, kvec, lower=True)
    d_new = jnp.sqrt(jnp.maximum(kself + lam_s - jnp.sum(w * w), 1e-12))
    chol_s = chol_s.at[k, :].set(w.at[k].set(d_new))

    # Every write is gated on `ok`: absorbing into a FULL sensor (argmin of
    # an all-True mask would alias slot 0, a live neighbor) degrades to a
    # no-op drop instead of corrupting the problem.  Callers that must not
    # lose data check `capacity_left` first.
    sp_idx = jnp.where(ok, zid - n, 0)
    problem = dataclasses.replace(
        problem,
        nbr_pos=problem.nbr_pos.at[field, sensor, k].set(
            jnp.where(ok, x, problem.nbr_pos[field, sensor, k])
        ),
        # gated: at a full sensor the bit was already True, but a DEAD
        # sensor's free slot must stay free when the arrival is dropped
        nbr_mask=problem.nbr_mask.at[field, sensor, k].set(
            jnp.where(ok, True, problem.nbr_mask[field, sensor, k])
        ),
        gram=problem.gram.at[field, sensor].set(
            jnp.where(ok, gram_s, problem.gram[field, sensor])
        ),
        chol=problem.chol.at[field, sensor].set(
            jnp.where(ok, chol_s, problem.chol[field, sensor])
        ),
        stream_pos=problem.stream_pos.at[field, sp_idx].set(
            jnp.where(ok, x, problem.stream_pos[field, sp_idx])
        ),
    )
    # The arrival seeds its own message slot (Table-1 init z_0 = y); the
    # sensor's coefficient for the new slot starts at 0.
    z_idx = jnp.where(ok, zid, problem.sentinel)
    state = SNTrainState(
        z=state.z.at[field, z_idx].set(jnp.where(ok, y, state.z[field, z_idx])),
        coef=state.coef,
    )
    return problem, state, ok


_absorb_copy = jax.jit(_absorb)
_absorb_donate = jax.jit(_absorb, donate_argnums=(0, 1))


def _absorb_evict(problem, state, field, sensor, x, y):
    """One fused program: evict the oldest arrival IF the sensor is full,
    then absorb — a single dispatch/copy per arrival, not two.  Returns
    ``(problem, state, absorbed, evicted)``."""
    full = jnp.all(problem.nbr_mask[field, sensor])
    problem, state, ev = _evict_core(problem, state, field, sensor, full)
    problem, state, ok = _absorb(problem, state, field, sensor, x, y)
    return problem, state, ok, ev


_absorb_evict_copy = jax.jit(_absorb_evict)
_absorb_evict_donate = jax.jit(_absorb_evict, donate_argnums=(0, 1))


def absorb(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Absorb one measurement (x, y) arriving at ``sensor`` of ``field``.

    Returns ``(problem, state, absorbed)``.  An arrival at a sensor with no
    free neighborhood slot is DROPPED (in-graph guard; no corruption) and
    ``absorbed`` — a traced scalar bool, inspectable without a device sync
    until the caller converts it — reports which happened.  Callers that
    must not lose data check ``capacity_left`` up front or accumulate the
    flags; capacity comes from building the topology with d_max headroom.
    jit-compiled; ``field`` and ``sensor`` may be traced ints, so one
    compiled program serves every arrival.

    on_full="evict" frees the sensor's OLDEST arrival first (see
    ``evict_oldest``) whenever the sensor is full, so its stream slots act
    as a sliding window over the most recent measurements.  The one fused
    program handles both cases (no extra dispatch when the sensor has
    room).  Note the window needs at least one stream slot: a sensor built
    with ZERO headroom (deg == d_max) holds no arrival to evict, so its
    arrivals are still dropped — check ``capacity_left`` at build time.

    donate=True hands the input buffers to XLA for in-place update — the
    per-arrival cost drops from a full copy of the per-field arrays to the
    touched rows.  The caller must not use the OLD problem/state afterwards
    (the serving/streaming hot loop rebinds them, so it can).
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    if on_full == "evict":
        fn = _absorb_evict_donate if donate else _absorb_evict_copy
        problem, state, ok, _ = fn(problem, state, field, sensor, x, y)
        return problem, state, ok
    fn = _absorb_donate if donate else _absorb_copy
    return fn(problem, state, field, sensor, x, y)


def _absorb_many_core(problem, state, fields, sensors, xs, ys, evict):
    def body(carry, arrival):
        p, s = carry
        f, sn, x, y = arrival
        if evict:
            p, s, ok, ev = _absorb_evict(p, s, f, sn, x, y)
        else:
            p, s, ok = _absorb(p, s, f, sn, x, y)
            ev = jnp.zeros((), bool)
        return (p, s), AbsorbReceipt(absorbed=ok, evicted=ev)

    (problem, state), receipt = jax.lax.scan(
        body, (problem, state), (fields, sensors, xs, ys)
    )
    return problem, state, receipt


_absorb_many_drop_copy = jax.jit(
    partial(_absorb_many_core, evict=False))
_absorb_many_drop_donate = jax.jit(
    partial(_absorb_many_core, evict=False), donate_argnums=(0, 1))
_absorb_many_evict_copy = jax.jit(
    partial(_absorb_many_core, evict=True))
_absorb_many_evict_donate = jax.jit(
    partial(_absorb_many_core, evict=True), donate_argnums=(0, 1))


def absorb_many(
    problem: SNTrainProblem,
    state: SNTrainState,
    fields: jax.Array,
    sensors: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    *,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, AbsorbReceipt]:
    """Absorb a BATCH of A arrivals in one dispatch (lax.scan over them).

    ``fields``/``sensors`` are (A,) ints, ``xs`` (A, d), ``ys`` (A,);
    arrivals apply in order with exactly the per-step math of ``absorb``
    (same grow-one Cholesky update, same over-capacity ``on_full``
    policy), so the result equals A sequential ``absorb`` calls — but as
    ONE compiled program instead of A host round-trips, which is what the
    serving stream loop wants (see ``launch/serve.py``).  Returns an
    ``AbsorbReceipt`` of per-arrival (A,) ``absorbed``/``evicted`` flag
    vectors so callers can surface capacity pressure (drops, evictions)
    instead of silently losing data.

    The compiled program is specialized on A; serving processes that batch
    arrivals into fixed-size windows reuse one program.  ``donate`` has
    the ``absorb`` contract: the caller rebinds and drops the old buffers.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    fields = jnp.asarray(fields, jnp.int32)
    sensors = jnp.asarray(sensors, jnp.int32)
    xs = jnp.asarray(xs, problem.nbr_pos.dtype)
    ys = jnp.asarray(ys, state.z.dtype)
    a = fields.shape[0]
    if xs.ndim != 2 or xs.shape[0] != a:
        raise ValueError(f"xs must be (A={a}, d), got {xs.shape}")
    if sensors.shape != (a,) or ys.shape != (a,):
        raise ValueError(
            f"fields/sensors/ys must share length A={a}, got "
            f"{sensors.shape} / {ys.shape}"
        )
    if on_full == "evict":
        fn = _absorb_many_evict_donate if donate else _absorb_many_evict_copy
    else:
        fn = _absorb_many_drop_donate if donate else _absorb_many_drop_copy
    return fn(problem, state, fields, sensors, xs, ys)


def _evict_core(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    gate: jax.Array,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    n = problem.n
    d_max = problem.nbr_idx.shape[-1]
    field = jnp.asarray(field, jnp.int32)
    sensor = jnp.asarray(sensor, jnp.int32)
    deg = problem.topology.degrees[sensor]  # structural |N_s| (self incl.)
    mask_s = problem.nbr_mask[field, sensor]  # (D,)
    ar = jnp.arange(d_max)
    occ = mask_s & (ar >= deg)  # occupied stream slots (contiguous from deg)
    ok = occ.any() & jnp.asarray(gate, bool) & problem.alive[sensor]
    last = deg + jnp.sum(occ) - 1  # last occupied stream slot (when ok)

    # Shift stream slots [deg+1, last] down one; slot `last` becomes free.
    # Every per-slot array is permuted the same way, so the left-to-right
    # chronological fill invariant (absorb's argmin and the grow-one update
    # both rely on it) is restored after the eviction.
    perm = jnp.where((ar >= deg) & (ar < last), ar + 1, ar)
    freed = ar == last

    pos_s = problem.nbr_pos[field, sensor]  # (D, d)
    own = problem.topology.positions[sensor].astype(pos_s.dtype)  # (d,)
    new_pos = jnp.where(freed[:, None], own[None, :], pos_s[perm])
    new_mask = jnp.where(freed, False, mask_s[perm])

    # Gram: permute rows/cols (exact — the kept entries are the very floats
    # the original absorptions computed), then zero the freed row/col.
    g = problem.gram[field, sensor]
    keep = ~freed
    g2 = jnp.where(keep[:, None] & keep[None, :], g[perm][:, perm], 0.0)

    # Downdate = masked rebuild of this ONE sensor's factor, O(D^3): padded
    # AND lifecycle-dead lanes get unit diagonal (matching the effective
    # occupied & alive mask of the cached factors) so the factor stays SPD
    # and the grow-one update keeps working on the evicted problem.
    lam_s = problem.lam_pad[sensor]
    lane_alive = problem.alive_z[problem.nbr_idx[sensor]]  # (D,)
    diag = jnp.where(new_mask & lane_alive, lam_s, jnp.ones((), lam_s.dtype))
    new_chol = jsl.cholesky(g2 + jnp.diag(diag), lower=True)

    # Messages and coefficients ride along with their slots; the freed
    # slot's message/coefficient reset to 0 (the unoccupied convention).
    zids = problem.nbr_idx[sensor]  # (D,) fixed slot ids
    zvals = state.z[field, zids]
    tvals = jnp.where(freed, 0.0, zvals[perm])
    z_write = jnp.where(ok & (ar >= deg), tvals, zvals)
    z = state.z.at[field, zids].set(z_write)

    coef_s = state.coef[field, sensor]
    c_new = jnp.where(freed, 0.0, coef_s[perm])
    c_write = jnp.where(ok & (ar >= deg), c_new, coef_s)
    coef = state.coef.at[field, sensor].set(c_write)

    # stream_pos entries of this sensor shift the same way (dump writes for
    # non-stream lanes and the not-ok case into a scratch row).
    s_cap = problem.n_stream
    spv = jnp.pad(problem.stream_pos[field], ((0, 1), (0, 0)))
    sp_gather = jnp.where(ar >= deg, jnp.clip(zids - n, 0, s_cap), s_cap)
    cur_sp = spv[sp_gather]  # (D, d); zeros for non-stream lanes
    sp_vals = jnp.where(freed[:, None], 0.0, cur_sp[perm])
    sp_idx = jnp.where(ok & (ar >= deg), zids - n, s_cap)
    new_sp = spv.at[sp_idx].set(sp_vals)[:s_cap]

    problem = dataclasses.replace(
        problem,
        nbr_pos=problem.nbr_pos.at[field, sensor].set(
            jnp.where(ok, new_pos, pos_s)
        ),
        nbr_mask=problem.nbr_mask.at[field, sensor].set(
            jnp.where(ok, new_mask, mask_s)
        ),
        gram=problem.gram.at[field, sensor].set(jnp.where(ok, g2, g)),
        chol=problem.chol.at[field, sensor].set(
            jnp.where(ok, new_chol, problem.chol[field, sensor])
        ),
        stream_pos=problem.stream_pos.at[field].set(new_sp),
    )
    return problem, SNTrainState(z=z, coef=coef), ok


_evict_jit = jax.jit(_evict_core)
_evict_donate = jax.jit(_evict_core, donate_argnums=(0, 1))


def evict_oldest(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    *,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Free the OLDEST occupied reserved slot of ``sensor`` in ``field``.

    Returns ``(problem, state, evicted)``; ``evicted`` is False (and the
    call is a no-op) when the sensor holds no absorbed arrival.  The
    remaining arrivals shift down one slot so absorb's left-to-right fill
    invariant survives, the sensor's Gram is permuted accordingly, and its
    Cholesky factor is downdated by a masked rebuild (O(D^3) for the one
    sensor; everything else is untouched).  After evict, an ``absorb`` at
    the same sensor reuses the freed slot — the round-trip equals building
    the window's problem from scratch (tests/test_multifield.py).

    donate=True hands the buffers to XLA in place, same contract as
    ``absorb``: the caller must rebind and drop the old problem/state.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    fn = _evict_donate if donate else _evict_jit
    return fn(problem, state, field, sensor, True)


def rebuild_chol(problem: SNTrainProblem) -> jnp.ndarray:
    """From-scratch Cholesky of every local system — the O(D^3) reference
    the streaming and lifecycle updates are tested against.  Factors over
    the EFFECTIVE lane mask (occupied & alive): lanes of removed neighbors
    keep their occupancy but drop out of the system, exactly as the event
    repairs patch the cached factors."""
    lam_pad = problem.lam_pad
    lane_alive = problem.alive_z[problem.nbr_idx] & problem.alive[:, None]

    def per_sensor(gram_s, mask_s, lam_s):
        diag = jnp.where(mask_s, lam_s, 1.0)
        return jsl.cholesky(gram_s + jnp.diag(diag), lower=True)

    per_field = jax.vmap(per_sensor, in_axes=(0, 0, 0))
    if problem.batched:
        return jax.vmap(lambda g, m: per_field(g, m, lam_pad))(
            problem.gram, problem.nbr_mask & lane_alive[None]
        )
    return per_field(problem.gram, problem.nbr_mask & lane_alive, lam_pad)


# ---------------------------------------------------------------------------
# Network lifecycle: sensor join / leave at fixed shapes (paper Sec. 3.3
# "Robustness" made persistent).  Siblings of absorb/evict_oldest: one
# jitted program each, every operand traced, so an arbitrary churn trace
# compiles a constant number of programs (tests/test_lifecycle.py counts).
# ---------------------------------------------------------------------------


def _add_sensor_core(problem, state, x, ys, lam):
    n = problem.n
    n_rows, d_max = problem.nbr_idx.shape
    dt = problem.nbr_pos.dtype
    lay = problem.layout
    n_base = lay.n_base
    x = jnp.asarray(x, dt).reshape(-1)  # (d,)
    ys = jnp.asarray(ys, state.z.dtype).reshape(-1)  # (B,)
    lam = jnp.asarray(lam, problem.lam_pad.dtype)

    # 1. Claim the first dead SPARE row (spares carry reserved singleton
    # colors, so a join never invalidates the frozen distance-2 coloring;
    # removed spare rows are recycled).  No free spare => DROP the join.
    spare_alive = problem.alive[n_base:n]
    ok = jnp.any(~spare_alive)
    slot = jnp.int32(n_base) + jnp.argmin(spare_alive).astype(jnp.int32)

    # 2. Adopt the nearest live in-radius sensors (up to D-1 of them plus
    # self; a denser-than-capacity neighborhood truncates to the nearest).
    pos = problem.topology.positions.astype(dt)  # (n, d)
    d2 = jnp.sum((pos - x[None, :]) ** 2, axis=-1)  # (n,)
    radius = jnp.asarray(problem.topology.radius, dt)
    cand = problem.alive[:n] & (d2 < radius * radius)
    neg = jnp.where(cand, -d2, -jnp.inf)
    k_n = min(d_max - 1, n)  # static lane budget for adopted neighbors
    vals, ids = jax.lax.top_k(neg, k_n)  # nearest live first
    valid = jnp.isfinite(vals)  # (k_n,)
    c = 1 + jnp.sum(valid)  # occupied lane count (self included)
    lam = jnp.where(lam >= 0, lam, 0.01 / c.astype(lam.dtype) ** 2)

    # 3. The row's new slot table: [self, adopted neighbor z-slots...],
    # free lanes restored from the pristine reserved ids (row recycling).
    pad_k = d_max - 1 - k_n
    sel_ids = jnp.concatenate(
        [slot[None], ids.astype(jnp.int32),
         jnp.zeros((pad_k,), jnp.int32)]
    )
    sel_valid = jnp.concatenate(
        [jnp.ones((1,), bool), valid, jnp.zeros((pad_k,), bool)]
    )
    new_idx = jnp.where(sel_valid, sel_ids, lay.nbr_idx0[slot])
    pos2 = pos.at[slot].set(jnp.where(ok, x, pos[slot]))
    pos_pad = jnp.concatenate([pos2, jnp.zeros((1, pos2.shape[1]), dt)])
    gathered = pos_pad[jnp.where(sel_valid, sel_ids, n)]
    new_pos = jnp.where(sel_valid[:, None], gathered, x[None, :])  # (D, d)

    # 4. The joined sensor's local system + factor (shared by all fields —
    # the row starts arrival-free).
    kmat = problem.kernel(new_pos, new_pos)  # (D, D)
    outer = sel_valid[:, None] & sel_valid[None, :]
    gram_row = jnp.where(outer, kmat, 0.0).astype(problem.gram.dtype)
    diag = jnp.where(sel_valid, lam, 1.0)
    chol_row = jsl.cholesky(gram_row + jnp.diag(diag), lower=True)

    b = problem.batch_size
    gate = lambda new, old: jnp.where(ok, new, old)
    topo = dataclasses.replace(
        problem.topology,
        positions=pos2.astype(problem.topology.positions.dtype),
        degrees=problem.topology.degrees.at[slot].set(
            gate(c.astype(problem.topology.degrees.dtype),
                 problem.topology.degrees[slot])
        ),
    )
    problem = dataclasses.replace(
        problem,
        topology=topo,
        y=problem.y.at[:, slot].set(gate(ys, problem.y[:, slot])),
        nbr_idx=problem.nbr_idx.at[slot].set(
            gate(new_idx, problem.nbr_idx[slot])
        ),
        nbr_mask=problem.nbr_mask.at[:, slot].set(
            gate(
                jnp.broadcast_to(sel_valid, (b, d_max)),
                problem.nbr_mask[:, slot],
            )
        ),
        nbr_pos=problem.nbr_pos.at[:, slot].set(
            gate(
                jnp.broadcast_to(new_pos, (b,) + new_pos.shape),
                problem.nbr_pos[:, slot],
            )
        ),
        gram=problem.gram.at[:, slot].set(
            gate(
                jnp.broadcast_to(gram_row, (b,) + gram_row.shape),
                problem.gram[:, slot],
            )
        ),
        chol=problem.chol.at[:, slot].set(
            gate(
                jnp.broadcast_to(chol_row, (b,) + chol_row.shape),
                problem.chol[:, slot],
            )
        ),
        lam_pad=problem.lam_pad.at[slot].set(gate(lam, problem.lam_pad[slot])),
        alive=problem.alive.at[slot].set(gate(True, problem.alive[slot])),
    )
    plan_z, plan_coef = plans.color_plans_add(
        problem.plan_z, problem.plan_coef, lay.color_of, lay.member_pos,
        slot, new_idx, ok,
    )
    problem = dataclasses.replace(problem, plan_z=plan_z, plan_coef=plan_coef)

    # 5. State: the recycled row's owned slots reset, the new sensor seeds
    # its own message slot with its measurements (Table-1 init z_0 = y).
    owned = (lay.slot_owner == slot) & ok  # (n_z,)
    z = jnp.where(owned[None, :], 0.0, state.z)
    z = z.at[:, slot].set(jnp.where(ok, ys, z[:, slot]))
    coef = state.coef.at[:, slot].set(
        jnp.where(ok, 0.0, state.coef[:, slot])
    )
    return problem, SNTrainState(z=z, coef=coef), slot, ok


_add_sensor_copy = jax.jit(_add_sensor_core)
_add_sensor_donate = jax.jit(_add_sensor_core, donate_argnums=(0, 1))


def add_sensor(
    problem: SNTrainProblem,
    state: SNTrainState,
    x: jax.Array,
    ys: jax.Array,
    *,
    lam: float | jax.Array = -1.0,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array, jax.Array]:
    """A sensor JOINS the network at position ``x`` with measurements ``ys``.

    Occupies the first free spare row (``make_problem(..., n_max=...)``
    reserves them) and, entirely on device at fixed shapes:

      * adopts the nearest live in-radius sensors into its padded
        neighborhood (their message slots become its lanes; free lanes keep
        the row's reserved streaming ids, so the joined sensor absorbs
        arrivals like any other);
      * builds its masked local Gram and Cholesky factor (one (D, D)
        factorization, shared across fields);
      * patches its reserved singleton color's scatter plans
        (``plans.color_plans_add``) so the colored engines sweep it with
        zero recompilation;
      * seeds its message slot with ``ys`` (the Table-1 init) and flips
        ``alive``.

    The join is ONE-DIRECTIONAL: the newcomer reads and writes its
    neighbors' message slots (information flows both ways through the
    shared slots — its singleton color makes the writes conflict-free),
    but existing sensors' representers do not grow an anchor at ``x``.
    Every constraint set stays a subspace containing 0, so Fejér
    monotonicity of the weighted norm survives the event
    (tests/test_lifecycle.py).

    ``lam``: the newcomer's regularizer; negative (default) applies the
    paper's 0.01/|N|^2 rule to its adopted degree.  Returns
    ``(problem, state, slot, joined)``; ``joined`` is False (no-op) when no
    spare row is free — size capacity with ``n_max``.  A serving process
    also patches its query plan: ``serving.plan_add_sensor(plan, x, slot)``.

    ``donate=True`` has the ``absorb`` contract (rebind, drop the old
    buffers).
    """
    if not problem.batched:
        raise ValueError("lifecycle ops require a batched problem (use B = 1)")
    if problem.topology.n_spare == 0:
        raise ValueError(
            "problem has no spare rows — build with "
            "make_problem(..., n_max=n + spares) (or build_topology n_max=)"
        )
    if float(problem.topology.radius) <= 0.0:
        raise ValueError(
            "add_sensor needs a geometric topology (radius > 0) to find "
            "the joining sensor's neighborhood"
        )
    fn = _add_sensor_donate if donate else _add_sensor_copy
    return fn(problem, state, x, ys, lam)


def _remove_sensor_core(problem, state, slot):
    n = problem.n
    lay = problem.layout
    slot = jnp.asarray(slot, jnp.int32)
    ok = (slot >= 0) & (slot < n) & problem.alive[slot]

    alive = problem.alive.at[slot].set(
        jnp.where(ok, False, problem.alive[slot])
    )
    # Every lane that referenced the sensor (its neighbors' rows + its own
    # row) drops out of the local systems: zero the Gram rows/cols and the
    # stale coefficients there, keep the OCCUPANCY mask (the lane is not
    # free streaming capacity — ``alive`` gates it everywhere).  Other
    # rows' referencing lanes are RETIRED for good — rewritten to the
    # sentinel slot, which belongs to the permanently dead sentinel row —
    # so recycling this row for a future join cannot resurrect them.
    rows = jnp.arange(n + 1, dtype=jnp.int32)
    hit = (problem.nbr_idx == slot) & ok
    lane_kill = (hit | (rows[:, None] == slot)) & ok
    retire = hit & (rows[:, None] != slot)
    sentinel_id = jnp.asarray(problem.sentinel, problem.nbr_idx.dtype)
    nbr_idx = jnp.where(retire, sentinel_id, problem.nbr_idx)
    keep = ~lane_kill  # (n+1, D)
    outer_keep = keep[:, :, None] & keep[:, None, :]
    gram = jnp.where(outer_keep[None], problem.gram, 0.0)
    coef = jnp.where(lane_kill[None], 0.0, state.coef)

    # Downdate the AFFECTED rows' factors by a masked rebuild against the
    # effective (occupied & alive) mask — one fused batched factorization
    # (the shared ``sn_train._masked_factors`` convention; the extra Gram
    # masking it applies is idempotent on the pre-zeroed ``gram``), selected
    # back onto the affected rows only (untouched rows keep their grow-one
    # float history bit-for-bit).
    affected = lane_kill.any(axis=-1)  # (n+1,)
    patched = dataclasses.replace(problem, nbr_idx=nbr_idx, alive=alive)
    _, chol_new = _masked_factors(patched, problem.nbr_mask, gram, alive)
    chol = jnp.where(affected[None, :, None, None], chol_new, problem.chol)

    # The departed sensor's messages (own slot + its absorbed arrivals) and
    # stream positions reset to the unoccupied convention.
    owned = (lay.slot_owner == slot) & ok  # (n_z,)
    z = jnp.where(owned[None, :], 0.0, state.z)
    sp_owned = owned[n:-1]  # (S,)
    stream_pos = jnp.where(
        sp_owned[None, :, None], 0.0, problem.stream_pos
    )

    plan_z, plan_coef = plans.color_plans_remove(
        problem.plan_z, problem.plan_coef, lay.color_of, slot,
        nbr_idx[slot], ok,
    )
    # The retired lanes' scatter codes live in OTHER colors and target the
    # departed sensor's z slot; only it and its (now retired) neighbors
    # ever write that slot, so reverting the whole plan column to "keep"
    # retires those codes in one write — a recycled row's fresh messages
    # can never be clobbered by a stale plan entry.
    plan_z = plan_z.at[:, slot].set(
        jnp.where(ok, slot.astype(plan_z.dtype), plan_z[:, slot])
    )
    problem = dataclasses.replace(
        problem,
        nbr_idx=nbr_idx,
        gram=gram,
        chol=chol,
        stream_pos=stream_pos,
        alive=alive,
        plan_z=plan_z,
        plan_coef=plan_coef,
    )
    return problem, SNTrainState(z=z, coef=coef), ok


_remove_sensor_copy = jax.jit(_remove_sensor_core)
_remove_sensor_donate = jax.jit(_remove_sensor_core, donate_argnums=(0, 1))


def remove_sensor(
    problem: SNTrainProblem,
    state: SNTrainState,
    slot: jax.Array,
    *,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """A sensor LEAVES the network (mote death, battery, redeployment).

    Entirely on device at fixed shapes: flips ``alive`` (which also kills
    the sensor's reserved streaming slots via the slot-owner map), zeroes
    the Gram rows/columns and stale coefficients of every lane that
    referenced it, downdates the affected neighbors' Cholesky factors by a
    masked rebuild (one fused batched pass, selected onto the O(degree)
    affected rows), reverts its color's scatter-plan codes to "keep"
    (``plans.color_plans_remove``) and resets its messages.  Neighbor
    OCCUPANCY is preserved — a dead lane is not streaming capacity — so
    ``absorb``'s left-to-right fill invariant survives.

    Works on any live row.  Removed SPARE rows are recycled by the next
    ``add_sensor``; removed base rows stay reserved for their original
    sensor (their static color/slot assignments are position-bound).
    Returns ``(problem, state, removed)``; removing a dead/out-of-range
    slot is a no-op with ``removed`` False.  A serving process also
    patches its query plan: ``serving.plan_remove_sensor(plan, slot)``.

    ``donate=True`` has the ``absorb`` contract (rebind, drop the old
    buffers).
    """
    if not problem.batched:
        raise ValueError("lifecycle ops require a batched problem (use B = 1)")
    fn = _remove_sensor_donate if donate else _remove_sensor_copy
    return fn(problem, state, slot)
