"""Streaming measurement absorption for batched SN-Train problems.

Sensor networks do not observe a field once: readings keep arriving.  The
recursive-least-squares line of work (Mateos & Giannakis, arXiv:1109.4627)
absorbs each arrival into the running estimator with an O(D^2) update rather
than refitting from scratch; this module is that idea instantiated for the
paper's SN-Train local systems.

An arrival ``(field b, sensor s, location x, value y)`` becomes one more
data point owned by sensor s: it occupies the next free padded slot ``k`` of
N_s (build the topology with ``d_max`` headroom for capacity), whose FIXED
reserved message slot ``nbr_idx[s, k]`` was assigned at problem build (see
sn_train's message-slot layout).  The local system of sensor s grows by one
row/column:

    A_s' = [[A_s, a], [a^T, K(x,x) + lambda_s]]

whose Cholesky factor differs from chol[s] in a single new row — computed
with one triangular solve and a scalar square root (the classic rank-1
"grow" update):

    w = L_s^{-1} a,    d = sqrt(K(x,x) + lambda_s - w^T w)

O(D^2) instead of the O(D^3) refactorization, and exact: after any number of
absorptions ``problem.chol`` equals ``rebuild_chol(problem)`` to float
precision (asserted in tests/test_multifield.py).  Because the padded free
slots of ``chol`` are identity rows and arrivals fill slots left-to-right,
the fixed-shape masked triangular solve below IS the textbook update.

Other sensors never reference the new point (it joins N_s only), so the SOP
sweep machinery — serial, colored, sharded — runs unchanged on the absorbed
problem; a few post-arrival sweeps propagate the new information through the
network.  All constraint sets remain subspaces containing 0, so Fejér
monotonicity of the weighted norm (Lemma 2.1) is preserved across arrivals.

``absorb`` handles one arrival per dispatch; ``absorb_many`` runs a whole
arrival window through the identical per-step update under one
``lax.scan`` (one compiled program, one host round-trip — the serving
stream loop's configuration; equals repeated ``absorb`` exactly, see
tests/test_serving.py).

Over-capacity policy: by default an arrival at a FULL sensor is dropped.
``evict_oldest`` frees a full sensor's oldest arrival instead — remaining
arrivals shift down one slot (preserving the left-to-right == chronological
invariant the grow-one update relies on) and the sensor's factor is
downdated by a masked rebuild of its (D, D) Cholesky, O(D^3) for ONE sensor.
``absorb(..., on_full="evict")`` applies it automatically, turning each
sensor's stream slots into a sliding window over its most recent arrivals.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .sn_train import SNTrainProblem, SNTrainState


def capacity_left(problem: SNTrainProblem) -> jnp.ndarray:
    """(B, n) free neighborhood slots per (field, sensor)."""
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    return jnp.sum(~problem.nbr_mask[:, :-1, :], axis=-1)


def _absorb(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    x: jax.Array,
    y: jax.Array,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    n = problem.n
    field = jnp.asarray(field, jnp.int32)
    sensor = jnp.asarray(sensor, jnp.int32)
    dt = problem.nbr_pos.dtype
    x = jnp.asarray(x, dt).reshape(-1)  # (d,)
    y = jnp.asarray(y, state.z.dtype)

    mask_s = problem.nbr_mask[field, sensor]  # (D,)
    ok = jnp.any(~mask_s)  # sensor has a free slot; else DROP the arrival
    k = jnp.argmin(mask_s)  # first free slot (arrivals fill left-to-right)
    zid = problem.nbr_idx[sensor, k]  # fixed reserved message slot
    pos_s = problem.nbr_pos[field, sensor]  # (D, d)
    lam_s = problem.lam_pad[sensor]

    kvec = jnp.where(mask_s, problem.kernel(x[None, :], pos_s)[0], 0.0)  # (D,)
    kself = problem.kernel(x[None, :], x[None, :])[0, 0]

    new_row = kvec.at[k].set(kself)
    gram_s = problem.gram[field, sensor]
    gram_s = gram_s.at[k, :].set(new_row).at[:, k].set(new_row)

    # Grow-one Cholesky: rows >= k of chol[s] are identity (padded), so the
    # full-shape triangular solve returns w on the valid prefix and zeros
    # elsewhere; only row k of the factor changes.
    chol_s = problem.chol[field, sensor]
    w = jsl.solve_triangular(chol_s, kvec, lower=True)
    d_new = jnp.sqrt(jnp.maximum(kself + lam_s - jnp.sum(w * w), 1e-12))
    chol_s = chol_s.at[k, :].set(w.at[k].set(d_new))

    # Every write is gated on `ok`: absorbing into a FULL sensor (argmin of
    # an all-True mask would alias slot 0, a live neighbor) degrades to a
    # no-op drop instead of corrupting the problem.  Callers that must not
    # lose data check `capacity_left` first.
    sp_idx = jnp.where(ok, zid - n, 0)
    problem = dataclasses.replace(
        problem,
        nbr_pos=problem.nbr_pos.at[field, sensor, k].set(
            jnp.where(ok, x, problem.nbr_pos[field, sensor, k])
        ),
        nbr_mask=problem.nbr_mask.at[field, sensor, k].set(True),
        gram=problem.gram.at[field, sensor].set(
            jnp.where(ok, gram_s, problem.gram[field, sensor])
        ),
        chol=problem.chol.at[field, sensor].set(
            jnp.where(ok, chol_s, problem.chol[field, sensor])
        ),
        stream_pos=problem.stream_pos.at[field, sp_idx].set(
            jnp.where(ok, x, problem.stream_pos[field, sp_idx])
        ),
    )
    # The arrival seeds its own message slot (Table-1 init z_0 = y); the
    # sensor's coefficient for the new slot starts at 0.
    z_idx = jnp.where(ok, zid, problem.sentinel)
    state = SNTrainState(
        z=state.z.at[field, z_idx].set(jnp.where(ok, y, state.z[field, z_idx])),
        coef=state.coef,
    )
    return problem, state, ok


_absorb_copy = jax.jit(_absorb)
_absorb_donate = jax.jit(_absorb, donate_argnums=(0, 1))


def _absorb_evict(problem, state, field, sensor, x, y):
    """One fused program: evict the oldest arrival IF the sensor is full,
    then absorb — a single dispatch/copy per arrival, not two."""
    full = jnp.all(problem.nbr_mask[field, sensor])
    problem, state, _ = _evict_core(problem, state, field, sensor, full)
    return _absorb(problem, state, field, sensor, x, y)


_absorb_evict_copy = jax.jit(_absorb_evict)
_absorb_evict_donate = jax.jit(_absorb_evict, donate_argnums=(0, 1))


def absorb(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Absorb one measurement (x, y) arriving at ``sensor`` of ``field``.

    Returns ``(problem, state, absorbed)``.  An arrival at a sensor with no
    free neighborhood slot is DROPPED (in-graph guard; no corruption) and
    ``absorbed`` — a traced scalar bool, inspectable without a device sync
    until the caller converts it — reports which happened.  Callers that
    must not lose data check ``capacity_left`` up front or accumulate the
    flags; capacity comes from building the topology with d_max headroom.
    jit-compiled; ``field`` and ``sensor`` may be traced ints, so one
    compiled program serves every arrival.

    on_full="evict" frees the sensor's OLDEST arrival first (see
    ``evict_oldest``) whenever the sensor is full, so its stream slots act
    as a sliding window over the most recent measurements.  The one fused
    program handles both cases (no extra dispatch when the sensor has
    room).  Note the window needs at least one stream slot: a sensor built
    with ZERO headroom (deg == d_max) holds no arrival to evict, so its
    arrivals are still dropped — check ``capacity_left`` at build time.

    donate=True hands the input buffers to XLA for in-place update — the
    per-arrival cost drops from a full copy of the per-field arrays to the
    touched rows.  The caller must not use the OLD problem/state afterwards
    (the serving/streaming hot loop rebinds them, so it can).
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    if on_full == "evict":
        fn = _absorb_evict_donate if donate else _absorb_evict_copy
    else:
        fn = _absorb_donate if donate else _absorb_copy
    return fn(problem, state, field, sensor, x, y)


def _absorb_many_core(problem, state, fields, sensors, xs, ys, evict):
    step = _absorb_evict if evict else _absorb

    def body(carry, arrival):
        p, s = carry
        f, sn, x, y = arrival
        p, s, ok = step(p, s, f, sn, x, y)
        return (p, s), ok

    (problem, state), flags = jax.lax.scan(
        body, (problem, state), (fields, sensors, xs, ys)
    )
    return problem, state, flags


_absorb_many_drop_copy = jax.jit(
    partial(_absorb_many_core, evict=False))
_absorb_many_drop_donate = jax.jit(
    partial(_absorb_many_core, evict=False), donate_argnums=(0, 1))
_absorb_many_evict_copy = jax.jit(
    partial(_absorb_many_core, evict=True))
_absorb_many_evict_donate = jax.jit(
    partial(_absorb_many_core, evict=True), donate_argnums=(0, 1))


def absorb_many(
    problem: SNTrainProblem,
    state: SNTrainState,
    fields: jax.Array,
    sensors: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    *,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Absorb a BATCH of A arrivals in one dispatch (lax.scan over them).

    ``fields``/``sensors`` are (A,) ints, ``xs`` (A, d), ``ys`` (A,);
    arrivals apply in order with exactly the per-step math of ``absorb``
    (same grow-one Cholesky update, same over-capacity ``on_full``
    policy), so the result equals A sequential ``absorb`` calls — but as
    ONE compiled program instead of A host round-trips, which is what the
    serving stream loop wants (see ``launch/serve.py``).  Returns the
    per-arrival absorbed flags as an (A,) bool vector.

    The compiled program is specialized on A; serving processes that batch
    arrivals into fixed-size windows reuse one program.  ``donate`` has
    the ``absorb`` contract: the caller rebinds and drops the old buffers.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    fields = jnp.asarray(fields, jnp.int32)
    sensors = jnp.asarray(sensors, jnp.int32)
    xs = jnp.asarray(xs, problem.nbr_pos.dtype)
    ys = jnp.asarray(ys, state.z.dtype)
    a = fields.shape[0]
    if xs.ndim != 2 or xs.shape[0] != a:
        raise ValueError(f"xs must be (A={a}, d), got {xs.shape}")
    if sensors.shape != (a,) or ys.shape != (a,):
        raise ValueError(
            f"fields/sensors/ys must share length A={a}, got "
            f"{sensors.shape} / {ys.shape}"
        )
    if on_full == "evict":
        fn = _absorb_many_evict_donate if donate else _absorb_many_evict_copy
    else:
        fn = _absorb_many_drop_donate if donate else _absorb_many_drop_copy
    return fn(problem, state, fields, sensors, xs, ys)


def _evict_core(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    gate: jax.Array,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    n = problem.n
    d_max = problem.nbr_idx.shape[-1]
    field = jnp.asarray(field, jnp.int32)
    sensor = jnp.asarray(sensor, jnp.int32)
    deg = problem.topology.degrees[sensor]  # structural |N_s| (self incl.)
    mask_s = problem.nbr_mask[field, sensor]  # (D,)
    ar = jnp.arange(d_max)
    occ = mask_s & (ar >= deg)  # occupied stream slots (contiguous from deg)
    ok = occ.any() & jnp.asarray(gate, bool)
    last = deg + jnp.sum(occ) - 1  # last occupied stream slot (when ok)

    # Shift stream slots [deg+1, last] down one; slot `last` becomes free.
    # Every per-slot array is permuted the same way, so the left-to-right
    # chronological fill invariant (absorb's argmin and the grow-one update
    # both rely on it) is restored after the eviction.
    perm = jnp.where((ar >= deg) & (ar < last), ar + 1, ar)
    freed = ar == last

    pos_s = problem.nbr_pos[field, sensor]  # (D, d)
    own = problem.topology.positions[sensor].astype(pos_s.dtype)  # (d,)
    new_pos = jnp.where(freed[:, None], own[None, :], pos_s[perm])
    new_mask = jnp.where(freed, False, mask_s[perm])

    # Gram: permute rows/cols (exact — the kept entries are the very floats
    # the original absorptions computed), then zero the freed row/col.
    g = problem.gram[field, sensor]
    keep = ~freed
    g2 = jnp.where(keep[:, None] & keep[None, :], g[perm][:, perm], 0.0)

    # Downdate = masked rebuild of this ONE sensor's factor, O(D^3): padded
    # rows get unit diagonal so the factor stays SPD and the grow-one update
    # keeps working on the evicted problem.
    lam_s = problem.lam_pad[sensor]
    diag = jnp.where(new_mask, lam_s, jnp.ones((), lam_s.dtype))
    new_chol = jsl.cholesky(g2 + jnp.diag(diag), lower=True)

    # Messages and coefficients ride along with their slots; the freed
    # slot's message/coefficient reset to 0 (the unoccupied convention).
    zids = problem.nbr_idx[sensor]  # (D,) fixed slot ids
    zvals = state.z[field, zids]
    tvals = jnp.where(freed, 0.0, zvals[perm])
    z_write = jnp.where(ok & (ar >= deg), tvals, zvals)
    z = state.z.at[field, zids].set(z_write)

    coef_s = state.coef[field, sensor]
    c_new = jnp.where(freed, 0.0, coef_s[perm])
    c_write = jnp.where(ok & (ar >= deg), c_new, coef_s)
    coef = state.coef.at[field, sensor].set(c_write)

    # stream_pos entries of this sensor shift the same way (dump writes for
    # non-stream lanes and the not-ok case into a scratch row).
    s_cap = problem.n_stream
    spv = jnp.pad(problem.stream_pos[field], ((0, 1), (0, 0)))
    sp_gather = jnp.where(ar >= deg, jnp.clip(zids - n, 0, s_cap), s_cap)
    cur_sp = spv[sp_gather]  # (D, d); zeros for non-stream lanes
    sp_vals = jnp.where(freed[:, None], 0.0, cur_sp[perm])
    sp_idx = jnp.where(ok & (ar >= deg), zids - n, s_cap)
    new_sp = spv.at[sp_idx].set(sp_vals)[:s_cap]

    problem = dataclasses.replace(
        problem,
        nbr_pos=problem.nbr_pos.at[field, sensor].set(
            jnp.where(ok, new_pos, pos_s)
        ),
        nbr_mask=problem.nbr_mask.at[field, sensor].set(
            jnp.where(ok, new_mask, mask_s)
        ),
        gram=problem.gram.at[field, sensor].set(jnp.where(ok, g2, g)),
        chol=problem.chol.at[field, sensor].set(
            jnp.where(ok, new_chol, problem.chol[field, sensor])
        ),
        stream_pos=problem.stream_pos.at[field].set(new_sp),
    )
    return problem, SNTrainState(z=z, coef=coef), ok


_evict_jit = jax.jit(_evict_core)
_evict_donate = jax.jit(_evict_core, donate_argnums=(0, 1))


def evict_oldest(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    *,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Free the OLDEST occupied reserved slot of ``sensor`` in ``field``.

    Returns ``(problem, state, evicted)``; ``evicted`` is False (and the
    call is a no-op) when the sensor holds no absorbed arrival.  The
    remaining arrivals shift down one slot so absorb's left-to-right fill
    invariant survives, the sensor's Gram is permuted accordingly, and its
    Cholesky factor is downdated by a masked rebuild (O(D^3) for the one
    sensor; everything else is untouched).  After evict, an ``absorb`` at
    the same sensor reuses the freed slot — the round-trip equals building
    the window's problem from scratch (tests/test_multifield.py).

    donate=True hands the buffers to XLA in place, same contract as
    ``absorb``: the caller must rebind and drop the old problem/state.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    fn = _evict_donate if donate else _evict_jit
    return fn(problem, state, field, sensor, True)


def rebuild_chol(problem: SNTrainProblem) -> jnp.ndarray:
    """From-scratch Cholesky of every local system — the O(D^3) reference
    the streaming update is tested against."""
    lam_pad = problem.lam_pad

    def per_sensor(gram_s, mask_s, lam_s):
        diag = jnp.where(mask_s, lam_s, 1.0)
        return jsl.cholesky(gram_s + jnp.diag(diag), lower=True)

    per_field = jax.vmap(per_sensor, in_axes=(0, 0, 0))
    if problem.batched:
        return jax.vmap(lambda g, m: per_field(g, m, lam_pad))(
            problem.gram, problem.nbr_mask
        )
    return per_field(problem.gram, problem.nbr_mask, lam_pad)
