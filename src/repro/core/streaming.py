"""Streaming measurement absorption for batched SN-Train problems.

Sensor networks do not observe a field once: readings keep arriving.  The
recursive-least-squares line of work (Mateos & Giannakis, arXiv:1109.4627)
absorbs each arrival into the running estimator with an O(D^2) update rather
than refitting from scratch; this module is that idea instantiated for the
paper's SN-Train local systems.

An arrival ``(field b, sensor s, location x, value y)`` becomes one more
data point owned by sensor s: it occupies the next free padded slot ``k`` of
N_s (build the topology with ``d_max`` headroom for capacity), whose FIXED
reserved message slot ``nbr_idx[s, k]`` was assigned at problem build (see
sn_train's message-slot layout).  The local system of sensor s grows by one
row/column:

    A_s' = [[A_s, a], [a^T, K(x,x) + lambda_s]]

whose Cholesky factor differs from chol[s] in a single new row — computed
with one triangular solve and a scalar square root (the classic rank-1
"grow" update):

    w = L_s^{-1} a,    d = sqrt(K(x,x) + lambda_s - w^T w)

O(D^2) instead of the O(D^3) refactorization, and exact: after any number of
absorptions ``problem.chol`` equals ``rebuild_chol(problem)`` to float
precision (asserted in tests/test_multifield.py).  Because the padded free
slots of ``chol`` are identity rows and arrivals fill slots left-to-right,
the fixed-shape masked triangular solve below IS the textbook update.

Other sensors never reference the new point (it joins N_s only), so the SOP
sweep machinery — serial, colored, sharded — runs unchanged on the absorbed
problem; a few post-arrival sweeps propagate the new information through the
network.  All constraint sets remain subspaces containing 0, so Fejér
monotonicity of the weighted norm (Lemma 2.1) is preserved across arrivals.

``absorb`` handles one arrival per dispatch; ``absorb_many`` runs a whole
arrival window through the identical per-step update under one
``lax.scan`` (one compiled program, one host round-trip — the serving
stream loop's configuration; equals repeated ``absorb`` exactly, see
tests/test_serving.py).

Over-capacity policy: by default an arrival at a FULL sensor is dropped.
``evict_oldest`` frees a full sensor's oldest arrival instead — remaining
arrivals shift down one slot (preserving the left-to-right == chronological
invariant the grow-one update relies on) and the sensor's factor is
downdated by a masked rebuild of its (D, D) Cholesky, O(D^3) for ONE sensor.
``absorb(..., on_full="evict")`` applies it automatically, turning each
sensor's stream slots into a sliding window over its most recent arrivals.

Time-varying fields (exponential forgetting / EW-RLS, the arXiv:1109.4627
recursion): a problem built with ``beta < 1`` for a field decays that
field's OLD arrivals one beta step per absorb — each absorb at (field,
sensor) multiplies the sensor's occupied stream lanes' anchor weights
omega by sqrt(beta) (``problem.anchor_w``), rescales the cached Gram /
message slots in place, and patches the cached Cholesky factor by
scale-then-update: a sqrt(beta) row scale followed by one rank-1 update
per ticked lane restoring the UNDECAYED +lambda on the matrix diagonal
(``_chol_diag_update``) — O(D^2) per ticked lane, no refactorization.
Because lambda never decays, every factor-rebuild path (``rebuild_chol``,
evict's masked downdate, the lifecycle ``_refactor_rows``, robust
re-factorization) and every sweep engine consumes the forgetting state
unchanged, and each local solve becomes the w-weighted projection
min_f sum_j w_j (z_j - f(x_j))^2 + lambda_s ||f||^2 with w_j = omega_j^2
— old measurements fade instead of anchoring the fit to the time-average.
Sliding-window RLS is the composition that already exists: ``absorb(...,
on_full="evict")`` plus ``beta < 1`` gives an exponentially-weighted
window over each sensor's most recent arrivals.  With ``beta = 1.0``
every tick multiplies by exactly 1.0 and the factor restore is gated, so
the static path is BITWISE identical to no forgetting at all
(tests/test_streaming_beta.py pins this engine by engine).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from . import plans
from .sn_train import SNTrainProblem, SNTrainState


class JoinReceipt(NamedTuple):
    """Outcome of one symmetric join (``add_sensor``), all fixed shapes.

    ``joined``: () bool — False means the join was a bitwise no-op (no
    spare row, or the recolor pool was exhausted).
    ``slot``: () int32 — the claimed row (meaningful when ``joined``).
    ``adopted``/``adopted_mask``: (A,) int32 / bool — the neighbor rows
    that adopted a reciprocal anchor lane (sentinel ``n`` padded).
    ``skipped``/``skipped_mask``: (A,) int32 / bool — live IN-RADIUS
    neighbors that were NOT adopted because their rows have no free lane
    (``degrees == d_max``).  Each is a silently lost coupling relative to
    a from-scratch build; callers rebalance (rebuild with d_max headroom,
    or evict arrivals to free lanes) — see ``plans.degree_headroom``.
    ``dropped_newest``: (B, A) bool — fields whose adopter row was
    completely FULL: growing the reciprocal anchor lane dropped that
    field's newest absorbed arrival (its orphaned slot is zeroed).
    """

    joined: jax.Array
    slot: jax.Array
    adopted: jax.Array
    adopted_mask: jax.Array
    skipped: jax.Array
    skipped_mask: jax.Array
    dropped_newest: jax.Array

    def to_json(self) -> dict:
        """Plain-JSON receipt (schema-tagged; device syncs happen here,
        at the caller's chosen reporting point, never inside jit)."""
        return {
            "schema": "join_receipt/1",
            "joined": bool(self.joined),
            "slot": int(self.slot),
            "adopted": np.asarray(self.adopted).tolist(),
            "adopted_mask": np.asarray(self.adopted_mask).astype(bool).tolist(),
            "skipped": np.asarray(self.skipped).tolist(),
            "skipped_mask": np.asarray(self.skipped_mask).astype(bool).tolist(),
            "dropped_newest": np.asarray(self.dropped_newest)
            .astype(bool).tolist(),
        }


class AbsorbReceipt(NamedTuple):
    """Per-arrival outcome flags of ``absorb_many`` (both (A,) bool).

    ``absorbed``: the arrival was written (possibly after an eviction);
    ``evicted``: the ``on_full="evict"`` policy freed the sensor's oldest
    arrival first.  ``~absorbed`` arrivals were dropped (sensor full under
    the drop policy, zero-capacity window sensor, or dead sensor).
    """

    absorbed: jax.Array
    evicted: jax.Array

    def to_json(self) -> dict:
        """Plain-JSON receipt (schema-tagged; syncs at the call site)."""
        return {
            "schema": "absorb_receipt/1",
            "absorbed": np.asarray(self.absorbed).astype(bool).tolist(),
            "evicted": np.asarray(self.evicted).astype(bool).tolist(),
        }


def capacity_left(problem: SNTrainProblem) -> jnp.ndarray:
    """(B, n) free ABSORBABLE neighborhood slots per (field, sensor).

    Free lanes retired to the sentinel id (a base-neighbor removal that had
    no reserved id left to restore) back no message slot and do not count.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    absorbable = problem.nbr_idx[:-1] != problem.sentinel  # (n, D)
    return jnp.sum(~problem.nbr_mask[:, :-1, :] & absorbable[None], axis=-1)


def _chol_diag_update(chol_s: jax.Array, alpha: jax.Array) -> jax.Array:
    """chol(L L^T + diag(alpha^2)) via one classic rank-1 update per lane.

    The "update" half of the forgetting tick's scale-then-update: row
    scaling the cached factor by sqrt(beta) decays the ticked stream
    lanes' ENTIRE matrix diagonal, lambda included; this restores the
    undecayed regularizer (+(1 - beta) * lambda per ticked lane), keeping
    every local system >= lambda I and every full-lambda rebuild path
    consistent with the cached factor.  ``alpha`` is (D,) with zeros on
    untouched lanes; a zero entry is neutral only in exact arithmetic
    (sqrt(l*l) costs an ulp), so callers gate the whole call on beta < 1
    to keep the static path bitwise.  Fixed-shape fori_loops, O(D^2) per
    nonzero lane.
    """
    d = chol_s.shape[-1]
    ar = jnp.arange(d)

    def one_lane(j, L):
        x0 = jnp.zeros((d,), L.dtype).at[j].set(alpha[j])

        def one_row(i, carry):
            L, x = carry
            lii = L[i, i]
            xi = x[i]
            r = jnp.sqrt(lii * lii + xi * xi)
            c = r / lii
            s = xi / lii
            below = ar > i
            col = L[:, i]
            new_col = jnp.where(below, (col + s * x) / c, col).at[i].set(r)
            x = jnp.where(below, c * x - s * new_col, x)
            return L.at[:, i].set(new_col), x

        L, _ = jax.lax.fori_loop(0, d, one_row, (L, x0))
        return L

    return jax.lax.fori_loop(0, d, one_lane, chol_s)


def _absorb(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    x: jax.Array,
    y: jax.Array,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    n = problem.n
    field = jnp.asarray(field, jnp.int32)
    sensor = jnp.asarray(sensor, jnp.int32)
    dt = problem.nbr_pos.dtype
    x = jnp.asarray(x, dt).reshape(-1)  # (d,)
    y = jnp.asarray(y, state.z.dtype)

    mask_s = problem.nbr_mask[field, sensor]  # (D,)
    # A free RESERVED slot must exist (sentinel-retired lanes back no
    # message slot) and the sensor must be ALIVE; else DROP.
    free = ~mask_s & (problem.nbr_idx[sensor] != problem.sentinel)
    ok = jnp.any(free) & problem.alive[sensor]
    k = jnp.argmax(free)  # first free slot (arrivals fill left-to-right)
    zid = problem.nbr_idx[sensor, k]  # fixed reserved message slot
    pos_s = problem.nbr_pos[field, sensor]  # (D, d)
    lam_s = problem.lam_pad[sensor]

    # ---- forgetting tick (scale-then-update, module docstring) --------
    # The sensor's occupied STREAM lanes age one beta step: anchor weights
    # omega *= sqrt(beta), the Gram rows/cols and the lanes' message slots
    # rescale to match, and the cached factor is row-scaled then patched
    # with a rank-1-per-lane diagonal restore of the undecayed lambda.
    # Structural lanes never decay.  beta = 1.0 multiplies by exactly 1.0
    # everywhere and the restore is gated: bitwise-identical static path.
    gdt = problem.gram.dtype
    ids_s = problem.nbr_idx[sensor]  # (D,)
    beta_b = problem.beta[field].astype(gdt)
    is_stream = mask_s & (ids_s >= n) & (ids_s != problem.sentinel)
    root = jnp.sqrt(beta_b)
    s_vec = jnp.where(is_stream, root, jnp.ones((), gdt))  # (D,)
    aw_old = problem.anchor_w[field, sensor]  # (D,)
    aw_s = aw_old * s_vec.astype(aw_old.dtype)
    gram_s = problem.gram[field, sensor] * (s_vec[:, None] * s_vec[None, :])
    chol_s = problem.chol[field, sensor] * s_vec[:, None].astype(
        problem.chol.dtype
    )
    alpha = jnp.where(
        is_stream, jnp.sqrt((1.0 - beta_b) * lam_s.astype(gdt)), 0.0
    )
    chol_s = jnp.where(
        beta_b < 1.0, _chol_diag_update(chol_s, alpha), chol_s
    )

    # The kernel vector is masked to the EFFECTIVE lanes (occupied & alive):
    # a removed neighbor's lane keeps its occupancy but is factored out of
    # the cached Cholesky, and must stay out of the grow-one update too.
    # Anchor weights ride along (gram row (new, j) = omega_j * K; the fresh
    # arrival enters at omega = 1).
    mask_eff = mask_s & problem.alive_z[problem.nbr_idx[sensor]]
    kvec = jnp.where(
        mask_eff,
        problem.kernel(x[None, :], pos_s)[0] * aw_s.astype(dt),
        0.0,
    )  # (D,)
    kself = problem.kernel(x[None, :], x[None, :])[0, 0]

    new_row = kvec.at[k].set(kself)
    gram_s = gram_s.at[k, :].set(new_row).at[:, k].set(new_row)

    # Grow-one Cholesky: rows >= k of chol[s] are identity (padded), so the
    # full-shape triangular solve returns w on the valid prefix and zeros
    # elsewhere; only row k of the factor changes.
    w = jsl.solve_triangular(chol_s, kvec, lower=True)
    d_new = jnp.sqrt(jnp.maximum(kself + lam_s - jnp.sum(w * w), 1e-12))
    chol_s = chol_s.at[k, :].set(w.at[k].set(d_new))

    # Every write is gated on `ok`: absorbing into a FULL sensor (argmin of
    # an all-True mask would alias slot 0, a live neighbor) degrades to a
    # no-op drop instead of corrupting the problem.  Callers that must not
    # lose data check `capacity_left` first.
    sp_idx = jnp.where(ok, zid - n, 0)
    problem = dataclasses.replace(
        problem,
        nbr_pos=problem.nbr_pos.at[field, sensor, k].set(
            jnp.where(ok, x, problem.nbr_pos[field, sensor, k])
        ),
        # gated: at a full sensor the bit was already True, but a DEAD
        # sensor's free slot must stay free when the arrival is dropped
        nbr_mask=problem.nbr_mask.at[field, sensor, k].set(
            jnp.where(ok, True, problem.nbr_mask[field, sensor, k])
        ),
        gram=problem.gram.at[field, sensor].set(
            jnp.where(ok, gram_s, problem.gram[field, sensor])
        ),
        chol=problem.chol.at[field, sensor].set(
            jnp.where(ok, chol_s, problem.chol[field, sensor])
        ),
        stream_pos=problem.stream_pos.at[field, sp_idx].set(
            jnp.where(ok, x, problem.stream_pos[field, sp_idx])
        ),
        anchor_w=problem.anchor_w.at[field, sensor].set(
            jnp.where(ok, aw_s.at[k].set(1.0), aw_old)
        ),
    )
    # The ticked lanes' message slots decay with their anchors (the stored
    # z invariant is omega_j * value; x1.0 writes when beta = 1 / not ok),
    # then the arrival seeds its own slot (Table-1 init z_0 = y); the
    # sensor's coefficient for the new slot starts at 0.
    z_scale = jnp.where(
        is_stream & ok, root, jnp.ones((), gdt)
    ).astype(state.z.dtype)
    z = state.z.at[field, ids_s].multiply(z_scale)
    z_idx = jnp.where(ok, zid, problem.sentinel)
    state = SNTrainState(
        z=z.at[field, z_idx].set(jnp.where(ok, y, z[field, z_idx])),
        coef=state.coef,
    )
    return problem, state, ok


_absorb_copy = jax.jit(_absorb)
_absorb_donate = jax.jit(_absorb, donate_argnums=(0, 1))


def _absorb_evict(problem, state, field, sensor, x, y):
    """One fused program: evict the oldest arrival IF the sensor is full,
    then absorb — a single dispatch/copy per arrival, not two.  Returns
    ``(problem, state, absorbed, evicted)``."""
    full = jnp.all(
        problem.nbr_mask[field, sensor]
        | (problem.nbr_idx[sensor] == problem.sentinel)
    )
    problem, state, ev = _evict_core(problem, state, field, sensor, full)
    problem, state, ok = _absorb(problem, state, field, sensor, x, y)
    return problem, state, ok, ev


_absorb_evict_copy = jax.jit(_absorb_evict)
_absorb_evict_donate = jax.jit(_absorb_evict, donate_argnums=(0, 1))


def absorb(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Absorb one measurement (x, y) arriving at ``sensor`` of ``field``.

    Returns ``(problem, state, absorbed)``.  An arrival at a sensor with no
    free neighborhood slot is DROPPED (in-graph guard; no corruption) and
    ``absorbed`` — a traced scalar bool, inspectable without a device sync
    until the caller converts it — reports which happened.  Callers that
    must not lose data check ``capacity_left`` up front or accumulate the
    flags; capacity comes from building the topology with d_max headroom.
    jit-compiled; ``field`` and ``sensor`` may be traced ints, so one
    compiled program serves every arrival.

    on_full="evict" frees the sensor's OLDEST arrival first (see
    ``evict_oldest``) whenever the sensor is full, so its stream slots act
    as a sliding window over the most recent measurements.  The one fused
    program handles both cases (no extra dispatch when the sensor has
    room).  Note the window needs at least one stream slot: a sensor built
    with ZERO headroom (deg == d_max) holds no arrival to evict, so its
    arrivals are still dropped — check ``capacity_left`` at build time.

    donate=True hands the input buffers to XLA for in-place update — the
    per-arrival cost drops from a full copy of the per-field arrays to the
    touched rows.  The caller must not use the OLD problem/state afterwards
    (the serving/streaming hot loop rebinds them, so it can).
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    if on_full == "evict":
        fn = _absorb_evict_donate if donate else _absorb_evict_copy
        problem, state, ok, _ = fn(problem, state, field, sensor, x, y)
        return problem, state, ok
    fn = _absorb_donate if donate else _absorb_copy
    return fn(problem, state, field, sensor, x, y)


def _absorb_many_core(problem, state, fields, sensors, xs, ys, evict):
    def body(carry, arrival):
        p, s = carry
        f, sn, x, y = arrival
        if evict:
            p, s, ok, ev = _absorb_evict(p, s, f, sn, x, y)
        else:
            p, s, ok = _absorb(p, s, f, sn, x, y)
            ev = jnp.zeros((), bool)
        return (p, s), AbsorbReceipt(absorbed=ok, evicted=ev)

    (problem, state), receipt = jax.lax.scan(
        body, (problem, state), (fields, sensors, xs, ys)
    )
    return problem, state, receipt


_absorb_many_drop_copy = jax.jit(
    partial(_absorb_many_core, evict=False))
_absorb_many_drop_donate = jax.jit(
    partial(_absorb_many_core, evict=False), donate_argnums=(0, 1))
_absorb_many_evict_copy = jax.jit(
    partial(_absorb_many_core, evict=True))
_absorb_many_evict_donate = jax.jit(
    partial(_absorb_many_core, evict=True), donate_argnums=(0, 1))


def absorb_many(
    problem: SNTrainProblem,
    state: SNTrainState,
    fields: jax.Array,
    sensors: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    *,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, AbsorbReceipt]:
    """Absorb a BATCH of A arrivals in one dispatch (lax.scan over them).

    ``fields``/``sensors`` are (A,) ints, ``xs`` (A, d), ``ys`` (A,);
    arrivals apply in order with exactly the per-step math of ``absorb``
    (same grow-one Cholesky update, same over-capacity ``on_full``
    policy), so the result equals A sequential ``absorb`` calls — but as
    ONE compiled program instead of A host round-trips, which is what the
    serving stream loop wants (see ``launch/serve.py``).  Returns an
    ``AbsorbReceipt`` of per-arrival (A,) ``absorbed``/``evicted`` flag
    vectors so callers can surface capacity pressure (drops, evictions)
    instead of silently losing data.

    The compiled program is specialized on A; serving processes that batch
    arrivals into fixed-size windows reuse one program.  ``donate`` has
    the ``absorb`` contract: the caller rebinds and drops the old buffers.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    fields = jnp.asarray(fields, jnp.int32)
    sensors = jnp.asarray(sensors, jnp.int32)
    xs = jnp.asarray(xs, problem.nbr_pos.dtype)
    ys = jnp.asarray(ys, state.z.dtype)
    a = fields.shape[0]
    if xs.ndim != 2 or xs.shape[0] != a:
        raise ValueError(f"xs must be (A={a}, d), got {xs.shape}")
    if sensors.shape != (a,) or ys.shape != (a,):
        raise ValueError(
            f"fields/sensors/ys must share length A={a}, got "
            f"{sensors.shape} / {ys.shape}"
        )
    if on_full == "evict":
        fn = _absorb_many_evict_donate if donate else _absorb_many_evict_copy
    else:
        fn = _absorb_many_drop_donate if donate else _absorb_many_drop_copy
    return fn(problem, state, fields, sensors, xs, ys)


def pad_arrivals(
    problem: SNTrainProblem,
    fields,
    sensors,
    xs,
    ys,
    a_pad: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, np.ndarray]:
    """Pad an arrival window to ``a_pad`` rows with guaranteed no-ops.

    ``absorb_many``'s compiled program is specialized on the window length
    A, so a long-lived serving process draining arbitrary arrival batches
    would compile one program per distinct size.  Padding each window to
    its power-of-two bucket (``kernels.ops.bucket_rows``) caps that at
    O(log A) programs — IF the padding rows provably change nothing.

    They do: padding arrivals target the SENTINEL row (``sensor ==
    problem.n``), which is permanently dead (``alive[n]`` is False by
    construction — retired lanes point at it).  ``_absorb`` gates every
    table write on ``ok = free-slot & alive[sensor]`` and ``_evict_core``
    on ``occupied & alive[sensor]``, so a sentinel-row arrival is a
    bitwise no-op under both ``on_full`` policies; its receipt row comes
    back ``absorbed=False`` (tests/test_daemon.py pins padded == unpadded
    bitwise).  Returns ``(fields, sensors, xs, ys, real)`` — ``real`` is
    the (a_pad,) bool mask of genuine arrivals for receipt accounting.
    """
    fields = jnp.asarray(fields, jnp.int32)
    sensors = jnp.asarray(sensors, jnp.int32)
    xs = jnp.atleast_2d(jnp.asarray(xs, problem.nbr_pos.dtype))
    ys = jnp.asarray(ys)
    a = int(fields.shape[0])
    if a > a_pad:
        raise ValueError(f"window of {a} arrivals exceeds a_pad={a_pad}")
    pad = a_pad - a
    real = np.arange(a_pad) < a
    if pad == 0:
        return fields, sensors, xs, ys, real
    return (
        jnp.concatenate([fields, jnp.zeros((pad,), jnp.int32)]),
        jnp.concatenate(
            [sensors, jnp.full((pad,), problem.n, jnp.int32)]
        ),
        jnp.concatenate([xs, jnp.zeros((pad, xs.shape[1]), xs.dtype)]),
        jnp.concatenate([ys, jnp.zeros((pad,), ys.dtype)]),
        real,
    )


def _absorb_wave_core(problem, state, xs, ys, amask, evict):
    """Batched arrival wave: one optional arrival per (field, sensor).

    The per-pair update of ``_absorb`` (and ``_evict_core`` under
    ``evict``) writes only (field, sensor)-local rows plus message/stream
    slots OWNED by that sensor, so a wave of arrivals at DISTINCT pairs
    — which the (B, n) operand layout enforces structurally — commutes:
    this computes every row's tick + evict + grow-one update as one
    batched tensor program (no scan), equal to absorbing the arrivals
    sequentially in any order.  O(B * n * D^3) fully parallel work; the
    serving configuration for dense per-round streams (every sensor
    measures every round — the drift-tracking regime), where the
    scan-based ``absorb_many`` would pay B*n sequential steps.
    """
    n = problem.n
    r_rows, d_max = problem.nbr_idx.shape  # R = n + 1 (sentinel row last)
    f = problem.batch_size
    s_cap = problem.n_stream
    dt = problem.nbr_pos.dtype
    gdt = problem.gram.dtype
    ar = jnp.arange(d_max)
    ids = problem.nbr_idx  # (R, D)
    sentinel_id = problem.sentinel
    absorbable = ids != sentinel_id  # (R, D)
    xs = jnp.asarray(xs, dt)  # (F, n, d)
    ys = jnp.asarray(ys, state.z.dtype)  # (F, n)
    amask = jnp.asarray(amask, bool)  # (F, n)
    # extend arrival operands to the R = n + 1 rows (sentinel row inert)
    pad_r = ((0, 0), (0, r_rows - xs.shape[1]), (0, 0))
    xs = jnp.pad(xs, pad_r)
    ys = jnp.pad(ys, pad_r[:2])
    amask = jnp.pad(amask, pad_r[:2])
    deg = jnp.pad(problem.topology.degrees, (0, r_rows - n))  # (R,)
    own_pos = jnp.pad(
        problem.topology.positions.astype(dt), pad_r[1:]
    )  # (R, d)
    lam_r = problem.lam_pad[None, :, None]  # (1, R, 1)
    lane_alive = problem.alive_z[ids]  # (R, D)
    chol2 = jax.vmap(jax.vmap(lambda m: jsl.cholesky(m, lower=True)))
    z = state.z
    coef = state.coef
    ev_ok = jnp.zeros((f, r_rows), bool)

    if evict:
        # ---- batched _evict_core, gated to FULL rows with an arrival --
        mask = problem.nbr_mask  # (F, R, D)
        full = jnp.all(mask | ~absorbable[None], axis=-1)  # (F, R)
        occ = mask & (ar[None, None] >= deg[None, :, None])
        ev_ok = (
            occ.any(-1) & full & amask & problem.alive[None]
        )  # (F, R)
        last = deg[None] + occ.sum(-1) - 1  # (F, R)
        above = ar[None, None] >= deg[None, :, None]  # lanes past structure
        perm = jnp.where(
            above & (ar[None, None] < last[..., None]),
            ar[None, None] + 1, ar[None, None],
        )  # (F, R, D)
        freed = ar[None, None] == last[..., None]  # (F, R, D)
        keep = ~freed

        pos_p = jnp.take_along_axis(
            problem.nbr_pos, perm[..., None], axis=2
        )
        new_pos = jnp.where(
            freed[..., None], own_pos[None, :, None, :], pos_p
        )
        new_mask = jnp.where(freed, False, jnp.take_along_axis(mask, perm, 2))
        g1 = jnp.take_along_axis(problem.gram, perm[..., None], axis=2)
        g2 = jnp.take_along_axis(g1, perm[..., None, :], axis=3)
        g2 = jnp.where(keep[..., None] & keep[..., None, :], g2, 0.0)
        aw_p = jnp.take_along_axis(problem.anchor_w, perm, axis=2)
        aw2 = jnp.where(freed, jnp.ones((), problem.anchor_w.dtype), aw_p)
        diag = jnp.where(
            new_mask & lane_alive[None], lam_r, jnp.ones((), gdt)
        )
        new_chol = chol2(g2 + diag[..., None] * jnp.eye(d_max, dtype=gdt))

        okB = ev_ok[..., None]
        problem = dataclasses.replace(
            problem,
            nbr_pos=jnp.where(okB[..., None], new_pos, problem.nbr_pos),
            nbr_mask=jnp.where(okB, new_mask, problem.nbr_mask),
            gram=jnp.where(okB[..., None], g2, problem.gram),
            chol=jnp.where(okB[..., None], new_chol, problem.chol),
            anchor_w=jnp.where(okB, aw2, problem.anchor_w),
        )
        # messages/coefficients/stream positions ride their slots; every
        # slot this writes is OWNED by its row (stream ids are unique to
        # one row; structural/sentinel lanes write their current values
        # back), so the flat scatter has no conflicting duplicates.
        zvals = z[:, ids.reshape(-1)].reshape(f, r_rows, d_max)
        tvals = jnp.where(freed, 0.0, jnp.take_along_axis(zvals, perm, 2))
        z_write = jnp.where(
            okB & above & absorbable[None], tvals, zvals
        )
        z = z.at[:, ids.reshape(-1)].set(z_write.reshape(f, -1))
        c_new = jnp.where(
            freed, 0.0, jnp.take_along_axis(coef, perm, 2)
        )
        coef = jnp.where(okB & above, c_new, coef)
        spv = jnp.pad(problem.stream_pos, ((0, 0), (0, 1), (0, 0)))
        sp_gather = jnp.where(
            ar[None, :] >= deg[:, None], jnp.clip(ids - n, 0, s_cap), s_cap
        )  # (R, D); sentinel-retired lanes land in the dump row
        cur_sp = spv[:, sp_gather.reshape(-1)].reshape(
            f, r_rows, d_max, -1
        )
        sp_vals = jnp.where(
            freed[..., None], 0.0,
            jnp.take_along_axis(cur_sp, perm[..., None], axis=2),
        )
        sp_idx = jnp.where(
            ev_ok[..., None] & above, jnp.clip(ids - n, 0, s_cap)[None],
            s_cap,
        )  # (F, R, D); everything not-ok dumps past the slice
        spv = spv.at[jnp.arange(f)[:, None, None], sp_idx].set(sp_vals)
        problem = dataclasses.replace(problem, stream_pos=spv[:, :s_cap])

    # ---- batched _absorb: tick + weighted grow-one per (field, row) ---
    mask = problem.nbr_mask  # (F, R, D)
    free = ~mask & absorbable[None]
    ok = free.any(-1) & problem.alive[None] & amask  # (F, R)
    k = jnp.argmax(free, axis=-1)  # (F, R) first free slot
    zid = jnp.take_along_axis(
        jnp.broadcast_to(ids[None], (f, r_rows, d_max)), k[..., None], 2
    )[..., 0]  # (F, R)
    at_k = ar[None, None] == k[..., None]  # (F, R, D)

    beta_b = problem.beta.astype(gdt)[:, None, None]  # (F, 1, 1)
    is_stream = mask & (ids >= n)[None] & absorbable[None]
    root = jnp.sqrt(beta_b)
    s_vec = jnp.where(is_stream, root, jnp.ones((), gdt))  # (F, R, D)
    aw_s = problem.anchor_w * s_vec.astype(problem.anchor_w.dtype)
    gram_s = problem.gram * (s_vec[..., :, None] * s_vec[..., None, :])
    chol_s = problem.chol * s_vec[..., :, None].astype(problem.chol.dtype)
    alpha = jnp.where(
        is_stream, jnp.sqrt((1.0 - beta_b) * lam_r.astype(gdt)), 0.0
    )
    chol_s = jnp.where(
        (beta_b < 1.0)[..., None],
        jax.vmap(jax.vmap(_chol_diag_update))(chol_s, alpha),
        chol_s,
    )

    mask_eff = mask & lane_alive[None]
    flat_x = xs.reshape(f * r_rows, -1)
    flat_p = problem.nbr_pos.reshape(f * r_rows, d_max, -1)
    kv = jax.vmap(lambda x, p: problem.kernel(x[None], p)[0])(
        flat_x, flat_p
    ).reshape(f, r_rows, d_max)
    kself = jax.vmap(lambda x: problem.kernel(x[None], x[None])[0, 0])(
        flat_x
    ).reshape(f, r_rows)
    kvec = jnp.where(mask_eff, kv * aw_s.astype(kv.dtype), 0.0)
    new_row = jnp.where(at_k, kself[..., None], kvec)
    gram_s = jnp.where(at_k[..., :, None], new_row[..., None, :], gram_s)
    gram_s = jnp.where(at_k[..., None, :], new_row[..., :, None], gram_s)

    w = jax.vmap(jax.vmap(
        lambda L, b: jsl.solve_triangular(L, b, lower=True)
    ))(chol_s, kvec)
    d_new = jnp.sqrt(jnp.maximum(
        kself + lam_r[..., 0] - jnp.sum(w * w, -1), 1e-12
    ))
    chol_row = jnp.where(at_k, d_new[..., None], w)
    chol_s = jnp.where(at_k[..., :, None], chol_row[..., None, :], chol_s)

    okB = ok[..., None]
    problem = dataclasses.replace(
        problem,
        nbr_pos=jnp.where(
            (okB & at_k)[..., None], xs[:, :, None, :], problem.nbr_pos
        ),
        nbr_mask=jnp.where(okB & at_k, True, problem.nbr_mask),
        gram=jnp.where(okB[..., None], gram_s, problem.gram),
        chol=jnp.where(okB[..., None], chol_s, problem.chol),
        anchor_w=jnp.where(
            okB, jnp.where(at_k, 1.0, aw_s), problem.anchor_w
        ),
    )
    sp_idx = jnp.where(ok, zid - n, s_cap)  # (F, R); dump past the slice
    spv = jnp.pad(problem.stream_pos, ((0, 0), (0, 1), (0, 0)))
    spv = spv.at[jnp.arange(f)[:, None], sp_idx].set(
        jnp.where(ok[..., None], xs, 0.0)
    )
    problem = dataclasses.replace(problem, stream_pos=spv[:, :s_cap])

    # z: decay the ticked lanes' message slots (owned by their rows), then
    # seed each arrival's slot (all writes owner-unique or value-neutral)
    z_scale = jnp.where(
        is_stream & okB, root, jnp.ones((), gdt)
    ).astype(z.dtype)
    z = z.at[:, ids.reshape(-1)].multiply(z_scale.reshape(f, -1))
    z_idx = jnp.where(ok, zid, sentinel_id)  # not-ok rows hit the sentinel
    cur = jnp.take_along_axis(z, z_idx, axis=1)
    z = z.at[jnp.arange(f)[:, None], z_idx].set(
        jnp.where(ok, ys, cur)
    )
    receipt = AbsorbReceipt(
        absorbed=ok[:, :n], evicted=ev_ok[:, :n]
    )
    return problem, SNTrainState(z=z, coef=coef), receipt


_absorb_wave_drop_copy = jax.jit(partial(_absorb_wave_core, evict=False))
_absorb_wave_drop_donate = jax.jit(
    partial(_absorb_wave_core, evict=False), donate_argnums=(0, 1))
_absorb_wave_evict_copy = jax.jit(partial(_absorb_wave_core, evict=True))
_absorb_wave_evict_donate = jax.jit(
    partial(_absorb_wave_core, evict=True), donate_argnums=(0, 1))


def absorb_wave(
    problem: SNTrainProblem,
    state: SNTrainState,
    xs: jax.Array,
    ys: jax.Array,
    *,
    mask: jax.Array | None = None,
    donate: bool = False,
    on_full: str = "drop",
) -> tuple[SNTrainProblem, SNTrainState, AbsorbReceipt]:
    """Absorb up to ONE arrival per (field, sensor) in one batched dispatch.

    ``xs`` is (B, n, d) arrival locations, ``ys`` (B, n) values, ``mask``
    an optional (B, n) bool selecting which pairs actually have an arrival
    (default: all).  Equal to absorbing the masked arrivals one
    ``absorb(..., on_full=...)`` at a time (every per-pair update touches
    only its own row and its own reserved message/stream slots, so the
    wave order cannot matter) — but as one O(B*n*D^3) data-parallel
    program instead of a B*n-step scan: the dense-stream configuration
    (every sensor measures every round) that drift tracking under
    ``beta < 1`` wants, where ``absorb_many`` would be quadratically
    slower.  Returns an ``AbsorbReceipt`` with (B, n) flag arrays.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    if on_full not in ("drop", "evict"):
        raise ValueError(f"on_full must be 'drop' or 'evict', got {on_full!r}")
    n, b = problem.n, problem.batch_size
    xs = jnp.asarray(xs, problem.nbr_pos.dtype)
    ys = jnp.asarray(ys, state.z.dtype)
    if xs.shape[:2] != (b, n) or ys.shape != (b, n):
        raise ValueError(
            f"xs must be (B={b}, n={n}, d) and ys (B, n), got "
            f"{xs.shape} / {ys.shape}"
        )
    if mask is None:
        mask = jnp.ones((b, n), bool)
    if on_full == "evict":
        fn = _absorb_wave_evict_donate if donate else _absorb_wave_evict_copy
    else:
        fn = _absorb_wave_drop_donate if donate else _absorb_wave_drop_copy
    return fn(problem, state, xs, ys, mask)


def _evict_core(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    gate: jax.Array,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    n = problem.n
    d_max = problem.nbr_idx.shape[-1]
    field = jnp.asarray(field, jnp.int32)
    sensor = jnp.asarray(sensor, jnp.int32)
    deg = problem.topology.degrees[sensor]  # structural |N_s| (self incl.)
    mask_s = problem.nbr_mask[field, sensor]  # (D,)
    ar = jnp.arange(d_max)
    occ = mask_s & (ar >= deg)  # occupied stream slots (contiguous from deg)
    ok = occ.any() & jnp.asarray(gate, bool) & problem.alive[sensor]
    last = deg + jnp.sum(occ) - 1  # last occupied stream slot (when ok)

    # Shift stream slots [deg+1, last] down one; slot `last` becomes free.
    # Every per-slot array is permuted the same way, so the left-to-right
    # chronological fill invariant (absorb's argmin and the grow-one update
    # both rely on it) is restored after the eviction.
    perm = jnp.where((ar >= deg) & (ar < last), ar + 1, ar)
    freed = ar == last

    pos_s = problem.nbr_pos[field, sensor]  # (D, d)
    own = problem.topology.positions[sensor].astype(pos_s.dtype)  # (d,)
    new_pos = jnp.where(freed[:, None], own[None, :], pos_s[perm])
    new_mask = jnp.where(freed, False, mask_s[perm])

    # Gram: permute rows/cols (exact — the kept entries are the very floats
    # the original absorptions computed), then zero the freed row/col.
    # Anchor weights ride the same permutation (forgetting state survives
    # the window slide); the freed lane resets to the fresh weight 1.
    g = problem.gram[field, sensor]
    keep = ~freed
    g2 = jnp.where(keep[:, None] & keep[None, :], g[perm][:, perm], 0.0)
    aw = problem.anchor_w[field, sensor]
    aw2 = jnp.where(freed, jnp.ones((), aw.dtype), aw[perm])

    # Downdate = masked rebuild of this ONE sensor's factor, O(D^3): padded
    # AND lifecycle-dead lanes get unit diagonal (matching the effective
    # occupied & alive mask of the cached factors) so the factor stays SPD
    # and the grow-one update keeps working on the evicted problem.
    lam_s = problem.lam_pad[sensor]
    lane_alive = problem.alive_z[problem.nbr_idx[sensor]]  # (D,)
    diag = jnp.where(new_mask & lane_alive, lam_s, jnp.ones((), lam_s.dtype))
    new_chol = jsl.cholesky(g2 + jnp.diag(diag), lower=True)

    # Messages and coefficients ride along with their slots; the freed
    # slot's message/coefficient reset to 0 (the unoccupied convention).
    zids = problem.nbr_idx[sensor]  # (D,) fixed slot ids
    zvals = state.z[field, zids]
    tvals = jnp.where(freed, 0.0, zvals[perm])
    z_write = jnp.where(ok & (ar >= deg), tvals, zvals)
    z = state.z.at[field, zids].set(z_write)

    coef_s = state.coef[field, sensor]
    c_new = jnp.where(freed, 0.0, coef_s[perm])
    c_write = jnp.where(ok & (ar >= deg), c_new, coef_s)
    coef = state.coef.at[field, sensor].set(c_write)

    # stream_pos entries of this sensor shift the same way (dump writes for
    # non-stream lanes and the not-ok case into a scratch row).
    s_cap = problem.n_stream
    spv = jnp.pad(problem.stream_pos[field], ((0, 1), (0, 0)))
    sp_gather = jnp.where(ar >= deg, jnp.clip(zids - n, 0, s_cap), s_cap)
    cur_sp = spv[sp_gather]  # (D, d); zeros for non-stream lanes
    sp_vals = jnp.where(freed[:, None], 0.0, cur_sp[perm])
    sp_idx = jnp.where(ok & (ar >= deg), zids - n, s_cap)
    new_sp = spv.at[sp_idx].set(sp_vals)[:s_cap]

    problem = dataclasses.replace(
        problem,
        nbr_pos=problem.nbr_pos.at[field, sensor].set(
            jnp.where(ok, new_pos, pos_s)
        ),
        nbr_mask=problem.nbr_mask.at[field, sensor].set(
            jnp.where(ok, new_mask, mask_s)
        ),
        gram=problem.gram.at[field, sensor].set(jnp.where(ok, g2, g)),
        chol=problem.chol.at[field, sensor].set(
            jnp.where(ok, new_chol, problem.chol[field, sensor])
        ),
        stream_pos=problem.stream_pos.at[field].set(new_sp),
        anchor_w=problem.anchor_w.at[field, sensor].set(
            jnp.where(ok, aw2, aw)
        ),
    )
    return problem, SNTrainState(z=z, coef=coef), ok


_evict_jit = jax.jit(_evict_core)
_evict_donate = jax.jit(_evict_core, donate_argnums=(0, 1))


def evict_oldest(
    problem: SNTrainProblem,
    state: SNTrainState,
    field: jax.Array,
    sensor: jax.Array,
    *,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """Free the OLDEST occupied reserved slot of ``sensor`` in ``field``.

    Returns ``(problem, state, evicted)``; ``evicted`` is False (and the
    call is a no-op) when the sensor holds no absorbed arrival.  The
    remaining arrivals shift down one slot so absorb's left-to-right fill
    invariant survives, the sensor's Gram is permuted accordingly, and its
    Cholesky factor is downdated by a masked rebuild (O(D^3) for the one
    sensor; everything else is untouched).  After evict, an ``absorb`` at
    the same sensor reuses the freed slot — the round-trip equals building
    the window's problem from scratch (tests/test_multifield.py).

    donate=True hands the buffers to XLA in place, same contract as
    ``absorb``: the caller must rebind and drop the old problem/state.
    """
    if not problem.batched:
        raise ValueError("streaming requires a batched problem (use B = 1)")
    if problem.n_stream == 0:
        raise ValueError(
            "problem has no streaming capacity — build the topology with "
            "d_max headroom (build_topology(pos, r, d_max=max_degree + k))"
        )
    fn = _evict_donate if donate else _evict_jit
    return fn(problem, state, field, sensor, True)


def rebuild_chol(problem: SNTrainProblem) -> jnp.ndarray:
    """From-scratch Cholesky of every local system — the O(D^3) reference
    the streaming and lifecycle updates are tested against.  Factors over
    the EFFECTIVE lane mask (occupied & alive): lanes of removed neighbors
    keep their occupancy but drop out of the system, exactly as the event
    repairs patch the cached factors."""
    lam_pad = problem.lam_pad
    lane_alive = problem.alive_z[problem.nbr_idx] & problem.alive[:, None]

    def per_sensor(gram_s, mask_s, lam_s):
        diag = jnp.where(mask_s, lam_s, 1.0)
        return jsl.cholesky(gram_s + jnp.diag(diag), lower=True)

    per_field = jax.vmap(per_sensor, in_axes=(0, 0, 0))
    if problem.batched:
        return jax.vmap(lambda g, m: per_field(g, m, lam_pad))(
            problem.gram, problem.nbr_mask & lane_alive[None]
        )
    return per_field(problem.gram, problem.nbr_mask & lane_alive, lam_pad)


# ---------------------------------------------------------------------------
# Network lifecycle: sensor join / leave at fixed shapes (paper Sec. 3.3
# "Robustness" made persistent).  Siblings of absorb/evict_oldest: one
# jitted program each, every operand traced, so an arbitrary churn trace
# compiles a constant number of programs (tests/test_lifecycle.py counts).
#
# Joins are SYMMETRIC: the newcomer adopts its neighbors AND each adopter
# grows a reciprocal anchor lane at the new position (with on-device
# conflict-aware recoloring when two same-color adopters would now share
# the newcomer's slot), so the post-join problem is the problem a fresh
# make_problem on the post-join topology would build.  Removal is the
# exact inverse (lane deletion + reserved-id restore).  Both events
# gather, repair and refactorize only the O(degree) affected rows.
# ---------------------------------------------------------------------------


def _refactor_rows(problem, alive_new, rows, idx_rows, mask_rows, gram_rows):
    """Masked Cholesky refactorization of O(degree) gathered rows.

    THE shared effective-lane convention of both event repairs (the
    row-gathered form of ``sn_train._masked_factors``): a lane is active
    iff occupied AND its slot and row are alive; live diagonal entries get
    lambda, everything else 1, so padded/dead blocks factor to identity.
    ``rows`` (R,) sensor ids (sentinel-padded), ``idx_rows`` (R, D) their
    post-event slot tables, ``mask_rows`` (B, R, D) occupancy,
    ``gram_rows`` (B, R, D, D).  Returns the (B, R, D, D) lower factors.
    """
    lane_alive = (
        plans.alive_slots(alive_new, problem.layout.slot_owner)[idx_rows]
        & alive_new[rows][:, None]
    )  # (R, D)
    mask_eff = mask_rows & lane_alive[None]  # (B, R, D)
    diag = jnp.where(mask_eff, problem.lam_pad[rows][None, :, None], 1.0)
    outer = mask_eff[..., :, None] & mask_eff[..., None, :]
    eye = jnp.eye(idx_rows.shape[-1], dtype=gram_rows.dtype)
    a = jnp.where(outer, gram_rows, 0.0) + diag[..., None] * eye
    return jax.vmap(jax.vmap(lambda m: jsl.cholesky(m, lower=True)))(a)


def _add_sensor_core(problem, state, x, ys, lam, repair, kappa):
    n = problem.n
    n_rows, d_max = problem.nbr_idx.shape
    dt = problem.nbr_pos.dtype
    lay = problem.layout
    topo = problem.topology
    n_base = lay.n_base
    b = problem.batch_size
    x = jnp.asarray(x, dt).reshape(-1)  # (d,)
    ys = jnp.asarray(ys, state.z.dtype).reshape(-1)  # (B,)
    lam = jnp.asarray(lam, problem.lam_pad.dtype)
    repair = jnp.asarray(repair, bool)
    kappa = jnp.asarray(kappa, problem.lam_pad.dtype)

    # 1. Claim the first dead SPARE row (spares carry reserved singleton
    # colors, so the NEWCOMER never invalidates the frozen distance-2
    # coloring; removed spare rows are recycled).  No free spare => DROP.
    spare_alive = problem.alive[n_base:n]
    have_spare = jnp.any(~spare_alive)
    slot = jnp.int32(n_base) + jnp.argmin(spare_alive).astype(jnp.int32)

    # 2. Adopt the nearest live in-radius sensors (up to D-1 of them plus
    # self; a denser-than-capacity neighborhood truncates to the nearest).
    # The join is SYMMETRIC: every adopted neighbor grows a reciprocal
    # anchor lane at x, so candidates must have a lane to spare —
    # capacity-exhausted rows are not adopted in either direction, keeping
    # the realized edge set symmetric.
    pos = topo.positions.astype(dt)  # (n, d)
    d2 = jnp.sum((pos - x[None, :]) ** 2, axis=-1)  # (n,)
    radius = jnp.asarray(topo.radius, dt)
    in_radius = problem.alive[:n] & (d2 < radius * radius)
    cand = in_radius & (topo.degrees < d_max)
    neg = jnp.where(cand, -d2, -jnp.inf)
    k_n = min(d_max - 1, n)  # static lane budget for adopted neighbors
    vals, ids = jax.lax.top_k(neg, k_n)  # nearest live first
    valid0 = jnp.isfinite(vals)  # (k_n,)
    c = 1 + jnp.sum(valid0)  # occupied lane count (self included)
    lam = jnp.where(lam >= 0, lam, kappa / c.astype(lam.dtype) ** 2)

    # Lane-exhausted in-radius sensors are NOT adopted in either direction
    # (the symmetric coupling would need a reciprocal lane they don't
    # have): each is a lost coupling relative to a from-scratch build on
    # the post-join positions.  Reported in the JoinReceipt so callers can
    # rebalance (plans.degree_headroom) instead of silently losing edges.
    exhausted = in_radius & (topo.degrees >= d_max)
    sk_vals, sk_ids = jax.lax.top_k(jnp.where(exhausted, -d2, -jnp.inf), k_n)
    sk_valid = jnp.isfinite(sk_vals)  # (k_n,)

    # 3. Conflict-aware recoloring: adopters all gain the newcomer's slot
    # as a shared neighbor, so same-color adopter pairs now violate the
    # distance-2 rule — move all but the first of each color into empty
    # reserved recolor classes.  Pool exhausted => DROP the join whole.
    new_colors, moved, feasible = plans.resolve_join_conflicts(
        problem.color_of, problem.color_mask, ids, valid0,
        problem.recolor_start,
    )
    ok = have_spare & feasible
    valid = valid0 & ok  # adopters actually repaired
    mv = moved & valid  # adopters actually recolored

    # 4. The newcomer's slot table: [self, adopted neighbor z-slots...],
    # free lanes restored from the pristine reserved ids (row recycling).
    pad_k = d_max - 1 - k_n
    sel_ids = jnp.concatenate(
        [slot[None], ids.astype(jnp.int32),
         jnp.zeros((pad_k,), jnp.int32)]
    )
    sel_valid = jnp.concatenate(
        [jnp.ones((1,), bool), valid0, jnp.zeros((pad_k,), bool)]
    )
    new_idx = jnp.where(sel_valid, sel_ids, lay.nbr_idx0[slot])
    pos2 = pos.at[slot].set(jnp.where(ok, x, pos[slot]))
    pos_pad = jnp.concatenate([pos2, jnp.zeros((1, pos2.shape[1]), dt)])
    gathered = pos_pad[jnp.where(sel_valid, sel_ids, n)]
    new_pos = jnp.where(sel_valid[:, None], gathered, x[None, :])  # (D, d)

    # 5. The joined sensor's local system + factor (shared by all fields —
    # the row starts arrival-free).
    kmat = problem.kernel(new_pos, new_pos)  # (D, D)
    outer = sel_valid[:, None] & sel_valid[None, :]
    gram_row = jnp.where(outer, kmat, 0.0).astype(problem.gram.dtype)
    diag = jnp.where(sel_valid, lam, 1.0)
    chol_row = jsl.cholesky(gram_row + jnp.diag(diag), lower=True)

    # 6. Reciprocal anchor lanes: each adopter's row grows a lane for the
    # newcomer at its stream boundary ``deg`` (so structural/anchor lanes
    # stay a contiguous prefix and absorb's left-to-right fill invariant
    # survives); absorbed arrivals shift up one lane, the LAST reserved id
    # falls out of the table (orphaned until a later lane deletion restores
    # it), and a field whose row was completely full drops its NEWEST
    # arrival.  O(degree) rows are gathered, repaired and refactored —
    # never all n.
    rows = jnp.where(valid, ids, n).astype(jnp.int32)  # (A,) pad: sentinel
    deg_r = topo.degrees[jnp.clip(rows, 0, n - 1)]  # (A,) pre-join degrees
    old_idx_r = problem.nbr_idx[rows]  # (A, D)
    ar = jnp.arange(d_max)
    at_new = ar[None, :] == deg_r[:, None]  # (A, D) the inserted lane
    src = jnp.where(
        ar[None, :] > deg_r[:, None], ar[None, :] - 1, ar[None, :]
    )
    shifted_idx = jnp.take_along_axis(old_idx_r, src, axis=1)
    new_idx_r = jnp.where(at_new, slot, shifted_idx).astype(
        problem.nbr_idx.dtype
    )
    orphan = old_idx_r[:, d_max - 1]  # (A,) reserved ids dropped

    old_pos_r = problem.nbr_pos[:, rows]  # (B, A, D, d)
    old_mask_r = problem.nbr_mask[:, rows]  # (B, A, D)
    old_gram_r = problem.gram[:, rows]  # (B, A, D, D)
    old_chol_r = problem.chol[:, rows]
    old_aw_r = problem.anchor_w[:, rows]  # (B, A, D)
    old_coef_r = state.coef[:, rows]
    # a field whose adopter row was completely FULL loses its newest
    # arrival to the inserted anchor lane — reported per (field, adopter)
    dropped = old_mask_r[:, :, d_max - 1] & valid[None, :]  # (B, A)
    pos_sh = jnp.take_along_axis(old_pos_r, src[None, :, :, None], axis=2)
    new_pos_r = jnp.where(
        at_new[None, :, :, None], x[None, None, None, :], pos_sh
    )
    mask_sh = jnp.take_along_axis(old_mask_r, src[None], axis=2)
    new_mask_r = jnp.where(at_new[None], True, mask_sh)
    coef_sh = jnp.take_along_axis(old_coef_r, src[None], axis=2)
    new_coef_r = jnp.where(at_new[None], 0.0, coef_sh)
    # anchor weights shift with their lanes; the inserted structural
    # anchor lane enters at the undecayed weight 1
    aw_sh = jnp.take_along_axis(old_aw_r, src[None], axis=2)
    new_aw_r = jnp.where(at_new[None], jnp.ones((), aw_sh.dtype), aw_sh)
    g1 = jnp.take_along_axis(old_gram_r, src[None, :, :, None], axis=2)
    g2 = jnp.take_along_axis(g1, src[None, :, None, :], axis=3)
    # the anchor's kernel row vs the row's occupied lanes (K(x,x) at deg);
    # decayed stream lanes carry their anchor weights into the new row
    # (gram invariant: entry (i, j) = omega_i * omega_j * K)
    kv = problem.kernel(x[None, :], new_pos_r.reshape(-1, x.shape[0]))[0]
    kv = kv.reshape(new_pos_r.shape[:-1])  # (B, A, D)
    krow = jnp.where(
        new_mask_r, kv * new_aw_r.astype(kv.dtype), 0.0
    ).astype(problem.gram.dtype)
    g3 = jnp.where(at_new[None, :, None, :], krow[..., None], g2)
    g3 = jnp.where(at_new[None, :, :, None], krow[..., None, :], g3)

    # Opt-in lambda repair (paper rule lambda_i = kappa / |N_i|^2): the
    # adopters' degrees grew by one, so their build-time regularizers are
    # stale relative to a from-scratch build.  Repairing rides the very
    # refactorization this event already pays — _refactor_rows reads
    # lam_pad, so patch it first.  repair=False writes the old floats
    # back (bitwise no-op).
    deg_new = (deg_r + 1).astype(problem.lam_pad.dtype)
    lam_fix = kappa / (deg_new * deg_new)
    do_fix = repair & valid
    lam_pad2 = problem.lam_pad.at[rows].set(
        jnp.where(do_fix, lam_fix, problem.lam_pad[rows])
    )
    problem = dataclasses.replace(problem, lam_pad=lam_pad2)

    # Affected-row refactorization (the adopters' factors gain a middle
    # row, so the rank-1 grow-one update does not apply): one batched
    # (B, A) masked Cholesky over the post-join effective lanes.
    alive2 = problem.alive.at[slot].set(
        jnp.where(ok, True, problem.alive[slot])
    )
    chol_r = _refactor_rows(problem, alive2, rows, new_idx_r, new_mask_r, g3)

    vB = valid[None, :, None]
    topo = dataclasses.replace(
        topo,
        positions=pos2.astype(topo.positions.dtype),
        degrees=topo.degrees.at[rows].add(
            jnp.where(valid, 1, 0).astype(topo.degrees.dtype)
        ).at[slot].set(
            jnp.where(
                ok,
                c.astype(topo.degrees.dtype),
                topo.degrees[slot],
            )
        ),
    )
    gate = lambda new, old: jnp.where(ok, new, old)
    nbr_idx2 = problem.nbr_idx.at[rows].set(
        jnp.where(valid[:, None], new_idx_r, old_idx_r)
    ).at[slot].set(gate(new_idx, problem.nbr_idx[slot]))
    nbr_mask2 = problem.nbr_mask.at[:, rows].set(
        jnp.where(vB, new_mask_r, old_mask_r)
    ).at[:, slot].set(
        gate(
            jnp.broadcast_to(sel_valid, (b, d_max)),
            problem.nbr_mask[:, slot],
        )
    )
    nbr_pos2 = problem.nbr_pos.at[:, rows].set(
        jnp.where(vB[..., None], new_pos_r, old_pos_r)
    ).at[:, slot].set(
        gate(
            jnp.broadcast_to(new_pos, (b,) + new_pos.shape),
            problem.nbr_pos[:, slot],
        )
    )
    gram2 = problem.gram.at[:, rows].set(
        jnp.where(vB[..., None], g3, old_gram_r)
    ).at[:, slot].set(
        gate(
            jnp.broadcast_to(gram_row, (b,) + gram_row.shape),
            problem.gram[:, slot],
        )
    )
    chol2 = problem.chol.at[:, rows].set(
        jnp.where(vB[..., None], chol_r, old_chol_r)
    ).at[:, slot].set(
        gate(
            jnp.broadcast_to(chol_row, (b,) + chol_row.shape),
            problem.chol[:, slot],
        )
    )
    anchor_w2 = problem.anchor_w.at[:, rows].set(
        jnp.where(vB, new_aw_r, old_aw_r)
    ).at[:, slot].set(
        gate(
            jnp.ones((b, d_max), problem.anchor_w.dtype),
            problem.anchor_w[:, slot],
        )
    )

    # 7. Color bookkeeping: recolored adopters change classes, the
    # newcomer (re)enters its reserved singleton class, and every repaired
    # row's scatter codes are rewritten for its post-join slot table.
    old_c = problem.color_of[rows]
    old_m = problem.member_pos[rows]
    cm, cmk = plans.members_clear(
        problem.color_members, problem.color_mask, old_c, old_m, mv, n
    )
    cm, cmk = plans.members_set(
        cm, cmk, new_colors, jnp.zeros_like(new_colors), rows, mv
    )
    cm, cmk = plans.members_set(
        cm, cmk, problem.color_of[slot][None],
        jnp.zeros((1,), jnp.int32), slot[None], jnp.asarray(ok)[None],
    )
    color_of2 = problem.color_of.at[rows].set(jnp.where(mv, new_colors, old_c))
    member_pos2 = problem.member_pos.at[rows].set(
        jnp.where(mv, 0, old_m).astype(problem.member_pos.dtype)
    )
    new_c_eff = jnp.where(mv, new_colors, old_c)
    new_m_eff = jnp.where(mv, 0, old_m).astype(old_m.dtype)
    plan_z, plan_coef = plans.plan_rows_remove(
        problem.plan_z, problem.plan_coef, old_c, rows, old_idx_r, valid
    )
    plan_z, plan_coef = plans.plan_rows_add(
        plan_z, plan_coef, new_c_eff, new_m_eff, rows, new_idx_r, valid
    )
    plan_z, plan_coef = plans.color_plans_add(
        plan_z, plan_coef, color_of2, member_pos2, slot, new_idx, ok
    )

    # 8. Orphaned reserved slots: their messages / arrival positions reset
    # (a full field's dropped newest arrival dies with its slot).
    s_cap = problem.n_stream
    z = state.z.at[:, orphan].set(
        jnp.where(valid[None, :], 0.0, state.z[:, orphan])
    )
    spv = jnp.pad(problem.stream_pos, ((0, 0), (0, 1), (0, 0)))
    sp_idx = jnp.where(valid, jnp.clip(orphan - n, 0, s_cap), s_cap)
    spv = spv.at[:, sp_idx].set(
        jnp.where(valid[None, :, None], 0.0, spv[:, sp_idx])
    )
    stream_pos2 = spv[:, :s_cap]

    problem = dataclasses.replace(
        problem,
        topology=topo,
        y=problem.y.at[:, slot].set(gate(ys, problem.y[:, slot])),
        nbr_idx=nbr_idx2,
        nbr_mask=nbr_mask2,
        nbr_pos=nbr_pos2,
        gram=gram2,
        chol=chol2,
        lam_pad=problem.lam_pad.at[slot].set(gate(lam, problem.lam_pad[slot])),
        stream_pos=stream_pos2,
        anchor_w=anchor_w2,
        plan_z=plan_z,
        plan_coef=plan_coef,
        color_members=cm,
        color_mask=cmk,
        color_of=color_of2,
        member_pos=member_pos2,
        alive=alive2,
    )

    # 9. State: the recycled row's owned slots reset, the new sensor seeds
    # its own message slot with its measurements (Table-1 init z_0 = y);
    # the adopters' shifted coefficient rows (0 at the new anchor lane)
    # were computed above.
    owned = (lay.slot_owner == slot) & ok  # (n_z,)
    z = jnp.where(owned[None, :], 0.0, z)
    z = z.at[:, slot].set(jnp.where(ok, ys, z[:, slot]))
    coef = state.coef.at[:, rows].set(
        jnp.where(vB, new_coef_r, old_coef_r)
    ).at[:, slot].set(jnp.where(ok, 0.0, state.coef[:, slot]))
    receipt = JoinReceipt(
        joined=ok,
        slot=slot,
        adopted=jnp.where(valid, ids, n).astype(jnp.int32),
        adopted_mask=valid,
        skipped=jnp.where(sk_valid & ok, sk_ids, n).astype(jnp.int32),
        skipped_mask=sk_valid & ok,
        dropped_newest=dropped,
    )
    return problem, SNTrainState(z=z, coef=coef), receipt


_add_sensor_copy = jax.jit(_add_sensor_core)
_add_sensor_donate = jax.jit(_add_sensor_core, donate_argnums=(0, 1))


def add_sensor(
    problem: SNTrainProblem,
    state: SNTrainState,
    x: jax.Array,
    ys: jax.Array,
    *,
    lam: float | jax.Array = -1.0,
    repair_lambda: bool = False,
    kappa: float = 0.01,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, JoinReceipt]:
    """A sensor JOINS the network at position ``x`` with measurements ``ys``.

    Occupies the first free spare row (``make_problem(..., n_max=...)``
    reserves them) and, entirely on device at fixed shapes:

      * adopts the nearest live in-radius sensors into its padded
        neighborhood (their message slots become its lanes; free lanes keep
        the row's reserved streaming ids, so the joined sensor absorbs
        arrivals like any other);
      * SYMMETRICALLY, every adopted neighbor grows a reciprocal anchor
        lane at ``x`` (inserted at its stream boundary; absorbed arrivals
        shift up one lane and its last reserved slot is orphaned until a
        later removal restores it) — exactly the bidirectional
        neighborhood coupling a from-scratch ``make_problem`` on the
        post-join topology would build, so post-join fits match a fresh
        build (tests/test_lifecycle.py pins the repaired scatter plans
        BITWISE against the host builder and the fit to <= 1e-5);
      * resolves the distance-2 conflicts the reciprocal lanes create
        (same-color adopters now share the newcomer's slot) by moving all
        but one adopter per color into reserved empty recolor classes
        (``plans.resolve_join_conflicts``; budget: ``build_topology(...,
        n_recolor=)``, default 2x the spare rows) — an exhausted pool
        DROPS the join rather than corrupting the coloring;
      * builds the newcomer's masked local Gram/Cholesky (one (D, D)
        factorization, shared across fields) and refactorizes the O(degree)
        ADOPTER rows only — one batched (B, degree) masked Cholesky, never
        all n rows;
      * patches the scatter plans of the newcomer AND every repaired
        adopter row so the colored engines sweep the post-join network
        with zero recompilation;
      * seeds its message slot with ``ys`` (the Table-1 init) and flips
        ``alive``.

    Every constraint set stays a subspace containing 0, so Fejér
    monotonicity of the weighted norm survives the event
    (tests/test_lifecycle.py).  Capacity caveats: candidates whose rows
    have no free lane (``degrees == d_max``) are not adopted in either
    direction (build with d_max headroom), and a field whose adopter row
    is completely full drops its NEWEST absorbed arrival to make room for
    the anchor lane.

    ``lam``: the newcomer's regularizer; negative (default) applies the
    paper's ``kappa``/|N|^2 rule to its adopted degree.  By default the
    ADOPTERS keep their build-time regularizers even though their degrees
    just grew — the paper rule says they are now stale.
    ``repair_lambda=True`` re-derives each adopter's lambda from its
    post-join degree (lambda_i = kappa / |N_i|^2, self included) inside
    the O(degree) refactorization this event already pays, so repaired
    joins match a from-scratch build's regularizers too (the accuracy
    drift of NOT repairing under sustained churn is recorded in
    tests/test_churn_soak.py).  Both settings share one compiled program
    (``repair_lambda``/``kappa`` are traced operands).

    Returns ``(problem, state, receipt)`` — a ``JoinReceipt`` whose
    ``joined`` is False (bitwise no-op) when no spare row is free or the
    recolor pool is exhausted (size capacity with ``n_max``/``n_recolor``),
    whose ``skipped`` lists the in-radius live sensors NOT adopted because
    their rows had no free lane, and whose ``dropped_newest`` flags the
    (field, adopter) pairs whose newest absorbed arrival was orphaned by
    the reciprocal anchor lane.  A serving process also patches its query
    plan: ``serving.plan_add_sensor(plan, x, receipt.slot)``.

    ``donate=True`` has the ``absorb`` contract (rebind, drop the old
    buffers).
    """
    if not problem.batched:
        raise ValueError("lifecycle ops require a batched problem (use B = 1)")
    if problem.topology.n_spare == 0:
        raise ValueError(
            "problem has no spare rows — build with "
            "make_problem(..., n_max=n + spares) (or build_topology n_max=)"
        )
    if float(problem.topology.radius) <= 0.0:
        raise ValueError(
            "add_sensor needs a geometric topology (radius > 0) to find "
            "the joining sensor's neighborhood"
        )
    fn = _add_sensor_donate if donate else _add_sensor_copy
    return fn(
        problem, state, x, ys, lam,
        jnp.asarray(repair_lambda, bool),
        jnp.asarray(kappa, problem.lam_pad.dtype),
    )


def _remove_sensor_core(problem, state, slot, repair, kappa):
    n = problem.n
    n_rows, d_max = problem.nbr_idx.shape
    dt = problem.nbr_pos.dtype
    lay = problem.layout
    topo = problem.topology
    slot = jnp.asarray(slot, jnp.int32)
    repair = jnp.asarray(repair, bool)
    kappa = jnp.asarray(kappa, problem.lam_pad.dtype)
    ok = (slot >= 0) & (slot < n) & problem.alive[slot]
    sl = jnp.clip(slot, 0, n - 1)  # safe READ index; writes are ok-gated

    alive = problem.alive.at[sl].set(
        jnp.where(ok, False, problem.alive[sl])
    )

    # Affected rows: joins are SYMMETRIC, so the rows referencing the
    # victim are exactly the live sensors its own slot table lists — a
    # static (D,)-padded gather, O(degree) rows repaired, never all n.
    victim_idx = problem.nbr_idx[sl]  # (D,)
    nb = (
        (victim_idx < n) & (victim_idx != sl)
        & problem.alive[jnp.clip(victim_idx, 0, n)] & ok
    )
    rows = jnp.where(nb, victim_idx, n).astype(jnp.int32)  # pad: sentinel

    # Each affected row DELETES its lane for the victim (the inverse of the
    # join's insertion): lanes above it shift down one — preserving the
    # [structural | arrivals | free] layout and absorb's fill invariant —
    # and the freed last lane restores the row's first orphaned reserved
    # id (none left => the lane is retired to the sentinel id and backs no
    # message slot; ``absorb`` skips such lanes).
    old_idx_r = problem.nbr_idx[rows]  # (R, D)
    lane = jnp.argmax(old_idx_r == sl, axis=1)  # (R,) the victim's lane
    ar = jnp.arange(d_max)
    src = jnp.where(
        ar[None, :] >= lane[:, None],
        jnp.minimum(ar[None, :] + 1, d_max - 1),
        ar[None, :],
    )
    shifted = jnp.take_along_axis(old_idx_r, src, axis=1)
    ids0 = lay.nbr_idx0[rows]  # (R, D) pristine table: the reserved pool
    owned0 = ids0 >= n
    present = (
        ids0[:, :, None] == shifted[:, None, : d_max - 1]
    ).any(-1)  # (R, D)
    cand_rest = owned0 & ~present
    pick = jnp.argmax(cand_rest, axis=1)
    restored = jnp.take_along_axis(ids0, pick[:, None], axis=1)[:, 0]
    sentinel_id = jnp.asarray(problem.sentinel, problem.nbr_idx.dtype)
    restored = jnp.where(cand_rest.any(axis=1), restored, sentinel_id)
    new_idx_r = shifted.at[:, d_max - 1].set(
        restored.astype(shifted.dtype)
    )
    freed = ar[None, :] == d_max - 1  # (1, D) uniform freed lane

    old_pos_r = problem.nbr_pos[:, rows]  # (B, R, D, d)
    old_mask_r = problem.nbr_mask[:, rows]
    old_gram_r = problem.gram[:, rows]
    old_chol_r = problem.chol[:, rows]
    old_aw_r = problem.anchor_w[:, rows]  # (B, R, D)
    old_coef_r = state.coef[:, rows]
    pos_sh = jnp.take_along_axis(old_pos_r, src[None, :, :, None], axis=2)
    own_pos = topo.positions[jnp.clip(rows, 0, n - 1)].astype(dt)  # (R, d)
    new_pos_r = jnp.where(
        freed[None, :, :, None], own_pos[None, :, None, :], pos_sh
    )
    mask_sh = jnp.take_along_axis(old_mask_r, src[None], axis=2)
    new_mask_r = jnp.where(freed[None], False, mask_sh)
    coef_sh = jnp.take_along_axis(old_coef_r, src[None], axis=2)
    new_coef_r = jnp.where(freed[None], 0.0, coef_sh)
    # anchor weights shift down with their lanes; freed lanes reset to 1
    aw_sh = jnp.take_along_axis(old_aw_r, src[None], axis=2)
    new_aw_r = jnp.where(freed[None], jnp.ones((), aw_sh.dtype), aw_sh)
    g1 = jnp.take_along_axis(old_gram_r, src[None, :, :, None], axis=2)
    g2 = jnp.take_along_axis(g1, src[None, :, None, :], axis=3)
    g3 = jnp.where(
        freed[None, :, :, None] | freed[None, :, None, :], 0.0, g2
    )

    # Opt-in lambda repair (the join-side mirror): the affected rows'
    # degrees shrank by one, so lambda_i = kappa / |N_i|^2 re-derives from
    # the post-removal degree before the refactorization reads lam_pad.
    # repair=False writes the old floats back (bitwise no-op).
    deg_post = jnp.maximum(
        topo.degrees[jnp.clip(rows, 0, n - 1)] - 1, 1
    ).astype(problem.lam_pad.dtype)
    lam_fix = kappa / (deg_post * deg_post)
    do_fix = repair & nb
    lam_pad2 = problem.lam_pad.at[rows].set(
        jnp.where(do_fix, lam_fix, problem.lam_pad[rows])
    )
    problem = dataclasses.replace(problem, lam_pad=lam_pad2)

    # O(degree) masked refactorization of the affected rows only (the
    # deleted lane sits mid-factor, so no rank-1 downdate applies); the
    # victim's own factor resets to the identity a masked rebuild of a
    # fully-dead row produces.
    chol_r = _refactor_rows(problem, alive, rows, new_idx_r, new_mask_r, g3)
    eye = jnp.eye(d_max, dtype=g3.dtype)

    nbB = nb[None, :, None]
    # The victim's own row resets to the pristine slot table with cleared
    # occupancy: a dead row references nothing (its mask gates every
    # consumer), and a recycled spare restores bitwise to its build state.
    nbr_idx2 = problem.nbr_idx.at[rows].set(
        jnp.where(nb[:, None], new_idx_r, old_idx_r)
    ).at[sl].set(jnp.where(ok, lay.nbr_idx0[sl], problem.nbr_idx[sl]))
    nbr_pos2 = problem.nbr_pos.at[:, rows].set(
        jnp.where(nbB[..., None], new_pos_r, old_pos_r)
    )
    nbr_mask2 = problem.nbr_mask.at[:, rows].set(
        jnp.where(nbB, new_mask_r, old_mask_r)
    ).at[:, sl].set(jnp.where(ok, False, problem.nbr_mask[:, sl]))
    gram2 = problem.gram.at[:, rows].set(
        jnp.where(nbB[..., None], g3, old_gram_r)
    ).at[:, sl].set(jnp.where(ok, 0.0, problem.gram[:, sl]))
    chol2 = problem.chol.at[:, rows].set(
        jnp.where(nbB[..., None], chol_r, old_chol_r)
    ).at[:, sl].set(jnp.where(ok, eye, problem.chol[:, sl]))
    # the victim's own anchor weights reset to the pristine build state
    # (bitwise spare-row recycling: make_problem inits anchor_w to ones)
    anchor_w2 = problem.anchor_w.at[:, rows].set(
        jnp.where(nbB, new_aw_r, old_aw_r)
    ).at[:, sl].set(
        jnp.where(
            ok,
            jnp.ones((), problem.anchor_w.dtype),
            problem.anchor_w[:, sl],
        )
    )
    coef2 = state.coef.at[:, rows].set(
        jnp.where(nbB, new_coef_r, old_coef_r)
    ).at[:, sl].set(jnp.where(ok, 0.0, state.coef[:, sl]))
    deg2 = topo.degrees.at[rows].add(
        jnp.where(nb, -1, 0).astype(topo.degrees.dtype)
    ).at[sl].set(
        jnp.where(ok, 0, topo.degrees[sl]).astype(topo.degrees.dtype)
    )

    # The departed sensor's messages (own slot + its absorbed arrivals) and
    # stream positions reset to the unoccupied convention.
    owned = (lay.slot_owner == sl) & ok  # (n_z,)
    z = jnp.where(owned[None, :], 0.0, state.z)
    sp_owned = owned[n:-1]  # (S,)
    stream_pos = jnp.where(
        sp_owned[None, :, None], 0.0, problem.stream_pos
    )

    # Scatter-plan + color bookkeeping: every affected row's codes are
    # rewritten for its post-removal slot table (distinct colors — two
    # same-color rows sharing the victim would violate the distance-2
    # coloring), the victim's own codes revert to "keep", and its class
    # membership clears (freeing its recolor class, if it sat in one, for
    # a later join's conflict repair).
    c_r = problem.color_of[rows]
    m_r = problem.member_pos[rows]
    plan_z, plan_coef = plans.plan_rows_remove(
        problem.plan_z, problem.plan_coef, c_r, rows, old_idx_r, nb
    )
    plan_z, plan_coef = plans.plan_rows_add(
        plan_z, plan_coef, c_r, m_r, rows, new_idx_r, nb
    )
    plan_z, plan_coef = plans.color_plans_remove(
        plan_z, plan_coef, problem.color_of, sl, victim_idx, ok
    )
    cm, cmk = plans.members_clear(
        problem.color_members, problem.color_mask,
        problem.color_of[sl][None], problem.member_pos[sl][None],
        jnp.asarray(ok)[None], n,
    )

    problem = dataclasses.replace(
        problem,
        topology=dataclasses.replace(topo, degrees=deg2),
        nbr_idx=nbr_idx2,
        nbr_pos=nbr_pos2,
        nbr_mask=nbr_mask2,
        gram=gram2,
        chol=chol2,
        stream_pos=stream_pos,
        anchor_w=anchor_w2,
        alive=alive,
        plan_z=plan_z,
        plan_coef=plan_coef,
        color_members=cm,
        color_mask=cmk,
    )
    return problem, SNTrainState(z=z, coef=coef2), ok


_remove_sensor_copy = jax.jit(_remove_sensor_core)
_remove_sensor_donate = jax.jit(_remove_sensor_core, donate_argnums=(0, 1))


def remove_sensor(
    problem: SNTrainProblem,
    state: SNTrainState,
    slot: jax.Array,
    *,
    repair_lambda: bool = False,
    kappa: float = 0.01,
    donate: bool = False,
) -> tuple[SNTrainProblem, SNTrainState, jax.Array]:
    """A sensor LEAVES the network (mote death, battery, redeployment).

    The exact inverse of the symmetric join, entirely on device at fixed
    shapes and O(degree) work: flips ``alive`` (which also kills the
    sensor's reserved streaming slots via the slot-owner map), then — for
    exactly the rows the victim's own slot table lists (symmetry makes
    that the complete set of referencing rows, a static (D,)-padded
    gather) — DELETES each row's lane for the victim: lanes above it shift
    down one (arrivals stay contiguous, so ``absorb``'s fill invariant
    survives), the freed last lane restores the row's first orphaned
    reserved id (or retires to the inert sentinel id when none is left),
    and the O(degree) affected factors are refactorized in one batched
    masked Cholesky — never all n rows.  Scatter-plan codes of every
    repaired row are rewritten, the victim's own codes revert to "keep",
    its class membership clears (freeing its recolor class for later
    joins) and its messages reset.

    Works on any live row.  Removed SPARE rows are recycled by the next
    ``add_sensor``; removed base rows stay reserved for their original
    sensor (their reserved slot ids are position-bound).  Returns
    ``(problem, state, removed)``; removing a dead/out-of-range slot is a
    BITWISE no-op with ``removed`` False (state, plans and serving
    candidates untouched — tests/test_lifecycle.py).  A serving process
    also patches its query plan: ``serving.plan_remove_sensor(plan, slot)``.

    ``repair_lambda=True`` re-derives each affected row's regularizer from
    its post-removal degree (the paper rule lambda_i = kappa / |N_i|^2;
    mirror of ``add_sensor``'s repair) inside the refactorization this
    event already pays; default keeps build-time regularizers.

    ``donate=True`` has the ``absorb`` contract (rebind, drop the old
    buffers).
    """
    if not problem.batched:
        raise ValueError("lifecycle ops require a batched problem (use B = 1)")
    fn = _remove_sensor_donate if donate else _remove_sensor_copy
    return fn(
        problem, state, slot,
        jnp.asarray(repair_lambda, bool),
        jnp.asarray(kappa, problem.lam_pad.dtype),
    )
