"""Sensor-network topology: geometric graphs, padded neighborhoods, coloring.

The paper's model (Sec. 3.1): sensors at positions ``x_i`` form an ad-hoc
graph; two sensors are neighbors iff within radius ``r``; every sensor is its
own neighbor (``i in N_i``).

Topology is *static program data*: it is computed host-side with numpy and
frozen into padded jnp arrays (fixed shapes) so the training sweeps are pure
``lax`` control flow.

Parallelism (paper Sec. 3.3): two sensors may update simultaneously iff they
share no neighbor, i.e. iff they are non-adjacent in the *square* of the
graph.  We greedily color G^2 and sweep color classes; this is the TPU
adaptation of the serial mote sweep (same fixed points, per the generalized
control orderings of Bauschke & Borwein cited by the paper).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SensorTopology:
    """Frozen, padded representation of a sensor network graph.

    Attributes:
      positions: (n, d) float32 sensor coordinates.
      adj: (n, n) bool adjacency WITH self loops (i in N_i).
      nbr_idx: (n, D) int32 neighbor indices, padded with the sensor's own
        index (padding entries are masked out everywhere they matter).
      nbr_mask: (n, D) bool validity of nbr_idx entries.
      degrees: (n,) int32 |N_i| (self loop included, as in the paper).
      colors: (n,) int32 distance-2 greedy coloring.
      n_colors: static int.
      color_members: (n_colors, M) int32 members per color, padded with n
        (one-past-the-end sentinel; callers scatter into an (n+1,) buffer).
      color_mask: (n_colors, M) bool.
    """

    positions: jnp.ndarray
    adj: jnp.ndarray
    nbr_idx: jnp.ndarray
    nbr_mask: jnp.ndarray
    degrees: jnp.ndarray
    colors: jnp.ndarray
    n_colors: int = dataclasses.field(metadata=dict(static=True))
    color_members: jnp.ndarray
    color_mask: jnp.ndarray

    @property
    def n(self) -> int:
        return int(self.positions.shape[0])

    @property
    def d_max(self) -> int:
        return int(self.nbr_idx.shape[1])


def geometric_adjacency(positions: np.ndarray, radius: float) -> np.ndarray:
    """Bool (n, n) adjacency: ||x_i - x_j|| < radius, self loops included."""
    pos = np.asarray(positions, dtype=np.float64)
    if pos.ndim == 1:
        pos = pos[:, None]
    d2 = np.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
    adj = d2 < radius**2
    np.fill_diagonal(adj, True)
    return adj


def greedy_coloring(conflict: np.ndarray) -> tuple[np.ndarray, int]:
    """Greedy coloring of an undirected conflict graph (bool adjacency).

    Orders vertices by decreasing degree (Welsh-Powell) for fewer colors.
    """
    n = conflict.shape[0]
    conflict = conflict.copy()
    np.fill_diagonal(conflict, False)
    order = np.argsort(-conflict.sum(axis=1), kind="stable")
    colors = -np.ones(n, dtype=np.int64)
    for v in order:
        used = set(colors[conflict[v]].tolist())
        c = 0
        while c in used:
            c += 1
        colors[v] = c
    return colors.astype(np.int32), int(colors.max()) + 1


def build_topology(
    positions: np.ndarray, radius: float, *, d_max: int | None = None
) -> SensorTopology:
    """Build the frozen topology for a geometric sensor graph."""
    pos = np.asarray(positions, dtype=np.float32)
    if pos.ndim == 1:
        pos = pos[:, None]
    n = pos.shape[0]
    adj = geometric_adjacency(pos, radius)
    degrees = adj.sum(axis=1).astype(np.int32)
    dm = int(degrees.max()) if d_max is None else int(d_max)
    if dm < int(degrees.max()):
        raise ValueError(f"d_max={dm} < max degree {int(degrees.max())}")

    nbr_idx = np.zeros((n, dm), dtype=np.int32)
    nbr_mask = np.zeros((n, dm), dtype=bool)
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        nbr_idx[i, : len(nbrs)] = nbrs
        nbr_idx[i, len(nbrs) :] = i  # pad with self (masked)
        nbr_mask[i, : len(nbrs)] = True

    # Sensors conflict iff they share a neighbor <=> adjacent in G^2.
    g2 = (adj.astype(np.int64) @ adj.astype(np.int64)) > 0
    colors, n_colors = greedy_coloring(g2)

    max_members = int(np.bincount(colors, minlength=n_colors).max())
    color_members = np.full((n_colors, max_members), n, dtype=np.int32)
    color_mask = np.zeros((n_colors, max_members), dtype=bool)
    for c in range(n_colors):
        members = np.nonzero(colors == c)[0]
        color_members[c, : len(members)] = members
        color_mask[c, : len(members)] = True

    return SensorTopology(
        positions=jnp.asarray(pos),
        adj=jnp.asarray(adj),
        nbr_idx=jnp.asarray(nbr_idx),
        nbr_mask=jnp.asarray(nbr_mask),
        degrees=jnp.asarray(degrees),
        colors=jnp.asarray(colors),
        n_colors=n_colors,
        color_members=jnp.asarray(color_members),
        color_mask=jnp.asarray(color_mask),
    )


def uniform_sensors(
    n: int, *, d: int = 1, lo: float = -1.0, hi: float = 1.0, seed: int = 0
) -> np.ndarray:
    """Paper Sec 4.1: n sensors uniform on [-1, 1]^d."""
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=(n, d)).astype(np.float32)


def ring_topology(n: int, *, hops: int = 1) -> SensorTopology:
    """A ring graph (ICI-like) — used by the SOP-consensus mapping and tests."""
    pos = np.stack(
        [
            np.cos(2 * np.pi * np.arange(n) / n),
            np.sin(2 * np.pi * np.arange(n) / n),
        ],
        axis=1,
    ).astype(np.float32)
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        for h in range(1, hops + 1):
            adj[i, (i + h) % n] = True
            adj[i, (i - h) % n] = True
    np.fill_diagonal(adj, True)
    # reuse builder internals by faking a radius via direct construction
    degrees = adj.sum(axis=1).astype(np.int32)
    dm = int(degrees.max())
    nbr_idx = np.zeros((n, dm), dtype=np.int32)
    nbr_mask = np.zeros((n, dm), dtype=bool)
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        nbr_idx[i, : len(nbrs)] = nbrs
        nbr_idx[i, len(nbrs) :] = i
        nbr_mask[i, : len(nbrs)] = True
    g2 = (adj.astype(np.int64) @ adj.astype(np.int64)) > 0
    colors, n_colors = greedy_coloring(g2)
    max_members = int(np.bincount(colors, minlength=n_colors).max())
    color_members = np.full((n_colors, max_members), n, dtype=np.int32)
    color_mask = np.zeros((n_colors, max_members), dtype=bool)
    for c in range(n_colors):
        members = np.nonzero(colors == c)[0]
        color_members[c, : len(members)] = members
        color_mask[c, : len(members)] = True
    return SensorTopology(
        positions=jnp.asarray(pos),
        adj=jnp.asarray(adj),
        nbr_idx=jnp.asarray(nbr_idx),
        nbr_mask=jnp.asarray(nbr_mask),
        degrees=jnp.asarray(degrees),
        colors=jnp.asarray(colors),
        n_colors=n_colors,
        color_members=jnp.asarray(color_members),
        color_mask=jnp.asarray(color_mask),
    )
