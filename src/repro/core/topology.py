"""Sensor-network topology: geometric graphs, padded neighborhoods, coloring.

The paper's model (Sec. 3.1): sensors at positions ``x_i`` form an ad-hoc
graph; two sensors are neighbors iff within radius ``r``; every sensor is its
own neighbor (``i in N_i``).

Topology is *static program data*: it is computed host-side with numpy and
frozen into padded jnp arrays (fixed shapes) so the training sweeps are pure
``lax`` control flow.  The padded representations (neighbor tables, color
classes, spare rows) come from the shared plan layer ``repro.core.plans``.

Parallelism (paper Sec. 3.3): two sensors may update simultaneously iff they
share no neighbor, i.e. iff they are non-adjacent in the *square* of the
graph.  We greedily color G^2 and sweep color classes; this is the TPU
adaptation of the serial mote sweep (same fixed points, per the generalized
control orderings of Bauschke & Borwein cited by the paper).

Lifecycle capacity (paper Sec. 3.3 "Robustness"): ``build_topology(...,
n_max=...)`` (or ``pad_topology``) reserves ``n_max - n`` SPARE sensor rows
— parked at ``plans.FAR``, isolated in the graph, each holding its own
reserved singleton color — so sensors can join/leave at runtime via
``streaming.add_sensor`` / ``remove_sensor`` without a host rebuild or an
XLA recompile.  Spare rows carry degree 0, so every lane of theirs backs a
reserved streaming slot until a join occupies it.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from . import plans


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SensorTopology:
    """Frozen, padded representation of a sensor network graph.

    Attributes:
      positions: (n, d) float32 sensor coordinates (spare rows parked FAR;
        patched in place by ``streaming.add_sensor``).
      adj: (n, n) bool adjacency WITH self loops (i in N_i) of the
        BUILD-TIME graph (spare rows isolated; not maintained under churn —
        lifecycle consumers read nbr_idx/nbr_mask + the problem's alive).
      nbr_idx: (n, D) int32 neighbor indices, padded with the sensor's own
        index (padding entries are masked out everywhere they matter).
      nbr_mask: (n, D) bool validity of nbr_idx entries.
      degrees: (n,) int32 |N_i| (self loop included, as in the paper);
        structural lane count — the boundary between neighbor lanes and
        reserved streaming lanes (patched for joined spare rows).
      colors: (n,) int32 distance-2 greedy coloring (spares: singletons).
      n_colors: static int (includes the spare- and recolor-class budgets).
      color_members: (n_colors, M) int32 BUILD-TIME members per color,
        padded with n (one-past-the-end sentinel; callers scatter into an
        (n+1,) buffer).  The runtime assignment is mutable
        ``SNTrainProblem`` state (symmetric joins recolor adopters); this
        table seeds it.
      color_mask: (n_colors, M) bool.
      n_base: static int — build-time sensor count; rows [n_base, n) are
        spare join capacity.
      radius: static float — the geometric connection radius (0.0 for
        non-geometric builds such as ``ring_topology``, which then cannot
        accept joins).
      n_recolor: static int — reserved EMPTY recolor classes (the last
        ``n_recolor`` rows of the member tables) symmetric joins move
        conflicting adopters into.
    """

    positions: jnp.ndarray
    adj: jnp.ndarray
    nbr_idx: jnp.ndarray
    nbr_mask: jnp.ndarray
    degrees: jnp.ndarray
    colors: jnp.ndarray
    n_colors: int = dataclasses.field(metadata=dict(static=True))
    color_members: jnp.ndarray
    color_mask: jnp.ndarray
    n_base: int = dataclasses.field(default=-1, metadata=dict(static=True))
    radius: float = dataclasses.field(default=0.0, metadata=dict(static=True))
    n_recolor: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return int(self.positions.shape[0])

    @property
    def d_max(self) -> int:
        return int(self.nbr_idx.shape[1])

    @property
    def n_spare(self) -> int:
        return self.n - (self.n_base if self.n_base >= 0 else self.n)


def geometric_adjacency(positions: np.ndarray, radius: float) -> np.ndarray:
    """Bool (n, n) adjacency: ||x_i - x_j|| < radius, self loops included."""
    pos = np.asarray(positions, dtype=np.float64)
    if pos.ndim == 1:
        pos = pos[:, None]
    d2 = np.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
    adj = d2 < radius**2
    np.fill_diagonal(adj, True)
    return adj


def greedy_coloring(conflict: np.ndarray) -> tuple[np.ndarray, int]:
    """Greedy coloring of an undirected conflict graph (bool adjacency).

    Orders vertices by decreasing degree (Welsh-Powell) for fewer colors.
    """
    n = conflict.shape[0]
    conflict = conflict.copy()
    np.fill_diagonal(conflict, False)
    order = np.argsort(-conflict.sum(axis=1), kind="stable")
    colors = -np.ones(n, dtype=np.int64)
    for v in order:
        used = set(colors[conflict[v]].tolist())
        c = 0
        while c in used:
            c += 1
        colors[v] = c
    return colors.astype(np.int32), int(colors.max()) + 1


def _assemble(
    pos: np.ndarray,
    adj: np.ndarray,
    d_max: int | None,
    n_spare: int,
    radius: float,
    n_recolor: int | None = None,
) -> SensorTopology:
    """Shared constructor over the plan-layer padded representations."""
    n_base = adj.shape[0]
    n = n_base + n_spare
    if n_recolor is None:
        # Default recolor budget: each join can displace at most a handful
        # of same-color adopters, classes recycle on removal, and any
        # sensor moves at most once — 2 classes per spare row covers the
        # traces the benches and tests replay (size explicitly for more).
        n_recolor = 2 * n_spare
    if n_spare:
        # Spare rows: parked far away at distinct points, isolated in the
        # graph (no self loop either — degree 0 means every lane of theirs
        # is reserved streaming/join capacity).
        spare_pos = np.full((n_spare, pos.shape[1]), plans.FAR, np.float32)
        spare_pos[:, 0] += np.arange(n_spare, dtype=np.float32)
        pos = np.concatenate([pos, spare_pos])
        adj_full = np.zeros((n, n), dtype=bool)
        adj_full[:n_base, :n_base] = adj
    else:
        adj_full = adj
    nbr_idx, nbr_mask, degrees = plans.padded_neighborhoods(adj_full, d_max)
    colors, n_colors, color_members, color_mask = plans.color_classes(
        adj, greedy_coloring, n_spare=n_spare, n_recolor=n_recolor
    )
    return SensorTopology(
        positions=jnp.asarray(pos),
        adj=jnp.asarray(adj_full),
        nbr_idx=jnp.asarray(nbr_idx),
        nbr_mask=jnp.asarray(nbr_mask),
        degrees=jnp.asarray(degrees),
        colors=jnp.asarray(colors),
        n_colors=n_colors,
        color_members=jnp.asarray(color_members),
        color_mask=jnp.asarray(color_mask),
        n_base=n_base,
        radius=float(radius),
        n_recolor=int(n_recolor),
    )


def build_topology(
    positions: np.ndarray,
    radius: float,
    *,
    d_max: int | None = None,
    n_max: int | None = None,
    n_recolor: int | None = None,
) -> SensorTopology:
    """Build the frozen topology for a geometric sensor graph.

    d_max: pad neighborhoods wider than the max degree — the headroom backs
    streaming-arrival capacity, the lanes a joined sensor adopts AND the
    anchor lane each adopting neighbor grows back (symmetric joins).
    n_max: total row capacity; ``n_max - len(positions)`` spare rows (with
    reserved singleton colors) accept runtime joins.
    n_recolor: reserved empty recolor classes for the symmetric-join
    conflict repair (default ``2 * n_spare``; see
    ``plans.resolve_join_conflicts``).
    """
    pos = np.asarray(positions, dtype=np.float32)
    if pos.ndim == 1:
        pos = pos[:, None]
    n = pos.shape[0]
    n_spare = 0 if n_max is None else int(n_max) - n
    if n_spare < 0:
        raise ValueError(f"n_max={n_max} < n={n}")
    adj = geometric_adjacency(pos, radius)
    return _assemble(pos, adj, d_max, n_spare, radius, n_recolor)


def pad_topology(
    topology: SensorTopology, n_max: int, n_recolor: int | None = None
) -> SensorTopology:
    """Re-pad an existing topology to ``n_max`` rows of join capacity.

    Host-side convenience used by ``make_problem(..., n_max=...)``; the
    base graph, coloring inputs and d_max are reused.
    """
    if topology.n_spare:
        raise ValueError("pad_topology expects an unpadded topology")
    n_spare = int(n_max) - topology.n
    if n_spare < 0:
        raise ValueError(f"n_max={n_max} < n={topology.n}")
    if n_spare == 0 and not n_recolor:
        return topology
    pos = np.asarray(topology.positions)
    adj = np.asarray(topology.adj)
    return _assemble(
        pos, adj, topology.d_max, n_spare, topology.radius, n_recolor
    )


def uniform_sensors(
    n: int, *, d: int = 1, lo: float = -1.0, hi: float = 1.0, seed: int = 0
) -> np.ndarray:
    """Paper Sec 4.1: n sensors uniform on [-1, 1]^d."""
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=(n, d)).astype(np.float32)


def ring_topology(n: int, *, hops: int = 1) -> SensorTopology:
    """A ring graph (ICI-like) — used by the SOP-consensus mapping and tests.

    Non-geometric (radius 0): carries no join capacity.
    """
    pos = np.stack(
        [
            np.cos(2 * np.pi * np.arange(n) / n),
            np.sin(2 * np.pi * np.arange(n) / n),
        ],
        axis=1,
    ).astype(np.float32)
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        for h in range(1, hops + 1):
            adj[i, (i + h) % n] = True
            adj[i, (i - h) % n] = True
    np.fill_diagonal(adj, True)
    return _assemble(pos, adj, None, 0, 0.0)
