"""Data pipelines: the paper's synthetic sensor fields and an LM token stream."""

from .fields import FieldCase, case1, case2, sample_field
from .lm import TokenStream, synthetic_lm_stream

__all__ = [
    "FieldCase",
    "TokenStream",
    "case1",
    "case2",
    "sample_field",
    "synthetic_lm_stream",
]
