"""The paper's simulated sensor fields (Sec. 4.1).

Case 1: eta(x) = 5x + 5,      noise sigma = 7, linear kernel.
Case 2: eta(x) = sin(pi x),   noise sigma = 1, Gaussian kernel.

Sensors are uniform on [-1, 1]; measurements y_i = eta(x_i) + n_i with
i.i.d. zero-mean Gaussian noise.  Generators are numpy-based (host-side
program data) and return float32 arrays ready for jnp.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.kernels_math import Kernel


@dataclasses.dataclass(frozen=True)
class FieldCase:
    name: str
    eta: Callable[[np.ndarray], np.ndarray]
    noise_sigma: float
    kernel: Kernel
    # paper Sec. 4.3 sweeps r over these ranges per case
    r_grid: tuple[float, ...]


def case1() -> FieldCase:
    return FieldCase(
        name="case1_linear",
        eta=lambda x: 5.0 * x + 5.0,
        noise_sigma=7.0,
        kernel=Kernel("linear", bias=1.0),
        r_grid=tuple(np.round(np.arange(0.1, 0.601, 0.05), 3).tolist()),
    )


def case2() -> FieldCase:
    return FieldCase(
        name="case2_sin",
        eta=lambda x: np.sin(np.pi * x),
        noise_sigma=1.0,
        kernel=Kernel("rbf", gamma=1.0),
        r_grid=tuple(np.round(np.arange(0.1, 2.101, 0.1), 3).tolist()),
    )


CASES = {"case1": case1, "case2": case2}


def sample_field(
    case: FieldCase,
    n_sensors: int,
    *,
    seed: int = 0,
    n_test: int = 500,
) -> dict[str, np.ndarray]:
    """One random draw of sensor positions, noisy measurements, and test set."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(n_sensors, 1)).astype(np.float32)
    y = (case.eta(x[:, 0]) + case.noise_sigma * rng.normal(size=n_sensors)).astype(
        np.float32
    )
    xt = rng.uniform(-1.0, 1.0, size=(n_test, 1)).astype(np.float32)
    yt = case.eta(xt[:, 0]).astype(np.float32)  # clean targets: E|f(X)-eta(X)|^2
    return {"x": x, "y": y, "x_test": xt, "y_test": yt}
