"""Deterministic synthetic LM token pipeline.

Offline container => no real corpus.  We synthesize a *learnable* stream from
a seeded order-1 Markov chain over a reduced alphabet embedded in the model's
vocab (sparse rows, Zipf-ish stationary mass), so cross-entropy has real
structure to learn: a model that learns the bigram statistics drops well
below the unigram entropy floor, which the training tests assert.

The stream is sharded by (host_id, n_hosts) for multi-host data loading and
is fully reproducible from (seed, step).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int  # per-host batch
    seed: int = 0
    alphabet: int = 256  # active symbols; rest of vocab unused (realistic tail)
    branching: int = 8  # successors per symbol (low entropy => learnable)
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        a = min(self.alphabet, self.vocab_size)
        rng = np.random.default_rng(self.seed)
        succ = np.stack(
            [rng.choice(a, size=self.branching, replace=True) for _ in range(a)]
        )  # (a, branching)
        probs = rng.dirichlet(np.ones(self.branching) * 0.5, size=a)
        self._succ = succ
        self._probs = probs.astype(np.float64)
        self._a = a

    def _gen_batch(self, rng: np.random.Generator) -> dict[str, np.ndarray]:
        b, s = self.batch_size, self.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self._a, size=b)
        for t in range(s):
            cur = toks[:, t]
            choice = np.array(
                [rng.choice(self.branching, p=self._probs[c]) for c in cur]
            )
            toks[:, t + 1] = self._succ[cur, choice]
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, s), dtype=np.float32),
        }

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Reproducible batch for a global step (host-sharded)."""
        rng = np.random.default_rng(
            (self.seed, step, self.host_id, self.n_hosts)
        )
        return self._gen_batch(rng)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def bigram_entropy(self) -> float:
        """Entropy rate of the chain in nats — the achievable CE floor."""
        # stationary distribution via power iteration
        trans = np.zeros((self._a, self._a))
        for i in range(self._a):
            np.add.at(trans[i], self._succ[i], self._probs[i])
        pi = np.ones(self._a) / self._a
        for _ in range(200):
            pi = pi @ trans
        pi /= pi.sum()
        with np.errstate(divide="ignore", invalid="ignore"):
            h_rows = -np.nansum(trans * np.log(np.where(trans > 0, trans, 1.0)), axis=1)
        return float((pi * h_rows).sum())


def synthetic_lm_stream(
    vocab_size: int, seq_len: int, batch_size: int, *, seed: int = 0, **kw
) -> TokenStream:
    return TokenStream(vocab_size, seq_len, batch_size, seed=seed, **kw)
