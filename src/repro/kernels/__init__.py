"""Pallas TPU kernels for the paper's compute hot spots, with jnp oracles.

kernel_matvec — fused Gram x coef streaming evaluation (testing phase);
                also the multi-field batched variant (B expansions against a
                shared query grid in one launch)
gram          — tiled RBF Gram materialization (training-side local solves)
ops           — general-shape jit wrappers (auto interpret off-TPU)
ref           — pure-jnp oracles used by tests and benchmarks
"""

from . import ops, ref
from .ops import kernel_matvec, rbf_gram, ssd_chunked_fused

__all__ = ["kernel_matvec", "ops", "rbf_gram", "ref", "ssd_chunked_fused"]
