"""Pallas TPU kernels for the paper's compute hot spots, with jnp oracles.

kernel_matvec — fused Gram x coef streaming evaluation (testing phase);
                also the multi-field batched variant (B expansions against a
                shared query grid in one launch)
gram          — tiled RBF Gram materialization (training-side local solves)
color_step    — fused colored-sweep step: gather -> lane-blocked triangular
                substitution -> local GEMM -> scatter, all in VMEM (the
                ``engine="pallas"`` path of sn_train.colored_sweep)
ops           — general-shape jit wrappers (auto interpret off-TPU)
ref           — pure-jnp oracles used by tests and benchmarks
"""

from . import color_step, ops, ref
from .color_step import color_step_fused
from .ops import kernel_matvec, rbf_gram, ssd_chunked_fused

__all__ = [
    "color_step",
    "color_step_fused",
    "kernel_matvec",
    "ops",
    "rbf_gram",
    "ref",
    "ssd_chunked_fused",
]
