"""Pallas TPU kernels for the paper's compute hot spots, with jnp oracles.

kernel_matvec — fused Gram x coef streaming evaluation (testing phase);
                also the multi-field batched variant (B expansions against a
                shared query grid in one launch)
gram          — tiled RBF Gram materialization (training-side local solves)
color_step    — fused colored-sweep step: gather -> lane-blocked triangular
                substitution -> local GEMM -> scatter, all in VMEM (the
                ``engine="pallas"`` path of sn_train.colored_sweep)
knn_fuse      — fused plan-based kNN-fusion serving step: candidate gather
                -> masked top-k selection network -> k local (D,)
                contractions per query tile in VMEM (the
                ``engine="pallas"`` path of fusion.fuse(rule="knn"))
ops           — general-shape jit wrappers (auto interpret off-TPU)
ref           — pure-jnp oracles used by tests and benchmarks
"""

from . import color_step, knn_fuse, ops, ref
from .color_step import color_step_fused
from .knn_fuse import knn_fuse_fused
from .ops import bucket_rows, kernel_matvec, rbf_gram, ssd_chunked_fused

__all__ = [
    "bucket_rows",
    "color_step",
    "color_step_fused",
    "kernel_matvec",
    "knn_fuse",
    "knn_fuse_fused",
    "ops",
    "rbf_gram",
    "ref",
    "ssd_chunked_fused",
]
