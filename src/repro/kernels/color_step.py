"""Fused Pallas color-step kernel for the colored SN-Train engine.

One color step of the paper's Sec-3.3 parallel SOP sweep, entirely in VMEM:

  gather   z at the color's (M, D) message-slot ids and the members' previous
           coefficient rows;
  solve    (L L^T)^{-1} rhs by lane-blocked forward/back triangular
           substitution (the same substitution math as
           ``sn_train._tri_solve_spd``, one lane per member of the block);
  GEMM     z_new = K_s @ coef_new per lane — a local (D, D) @ (D,) contract;
  scatter  the freshly solved messages/coefficients back into the full z and
           coef buffers.  Distance-2 coloring guarantees every touched slot
           has a unique owner, so the scatter is an exact write (the static
           scatter plan of sn_train, realized here as an in-VMEM ``.at.set``).

Grid: (B, M / block_m) with the lane-block axis innermost, so each field's
(1, NZ) / (1, n+1, D) output blocks stay resident in VMEM while the color's
lane blocks stream through — the same revisiting-accumulator pattern as
``kernels.kernel_matvec``.  Different lane blocks of one color touch disjoint
slots (the coloring again), so reading the output block between lane steps is
exact.

dtype follows the inputs (f32 or, under JAX_ENABLE_X64, f64 — the solver is
dtype-generic).  On non-TPU backends the wrapper runs in interpret mode (the
repo's validation mode, see ``kernels.ops``); the in-kernel gathers/scatters
use dynamic indices, which interpret mode executes exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _color_step_kernel(
    z_ref, coef_ref, mem_ref, idx_ref, mask_ref, gram_ref, chol_ref, lam_ref,
    alive_ref, alivez_ref, deliv_ref, zout_ref, cout_ref,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        zout_ref[...] = z_ref[...]
        cout_ref[...] = coef_ref[...]

    z = zout_ref[0, :]  # (NZ,) — prior lane blocks wrote disjoint slots
    coefv = cout_ref[0]  # (R, D)
    mem = mem_ref[...]  # (bm,)
    idx = idx_ref[...]  # (bm, D)
    mask = mask_ref[0] != 0  # (bm, D)
    gram = gram_ref[0]  # (bm, D, D)
    chol = chol_ref[0]  # (bm, D, D)
    lam = lam_ref[...]  # (bm,)
    alive = alive_ref[...] != 0  # (bm,) member liveness (network lifecycle)
    alivez = alivez_ref[...] != 0  # (NZ,) message-slot liveness
    deliv = deliv_ref[...] != 0  # (bm, D) per-lane link delivery (faults)
    d = idx.shape[-1]

    # Gather: this block's messages and previous coefficients.
    z_nbr = z[idx]  # (bm, D)
    coef_m = coefv[mem]  # (bm, D)
    rhs = jnp.where(mask, z_nbr + lam[:, None] * coef_m, 0.0)

    # Lane-blocked forward substitution  L y = rhs.
    def fwd(i, y):
        yi = (rhs[:, i] - jnp.sum(chol[:, i, :] * y, axis=-1)) / chol[:, i, i]
        return y.at[:, i].set(yi)

    y = jax.lax.fori_loop(0, d, fwd, jnp.zeros_like(rhs))

    # Lane-blocked back substitution  L^T x = y.
    def bwd(t, x):
        i = d - 1 - t
        xi = (y[:, i] - jnp.sum(chol[:, :, i] * x, axis=-1)) / chol[:, i, i]
        return x.at[:, i].set(xi)

    coef_new = jax.lax.fori_loop(0, d, bwd, jnp.zeros_like(rhs))

    # Local (D, D) @ (D,) GEMM per lane: f_s at the neighborhood points.
    z_new = jnp.einsum("mij,mj->mi", gram, coef_new)

    # Scatter (unique owners; padded lanes write zeros to the sentinels).
    # DEAD members (removed / transiently down sensors) redirect to the
    # sentinels, and so do lanes whose TARGET slot is dead (a down mote's
    # own message slot is unreachable) and lanes whose message was DROPPED
    # by the link (repro.core.faults): slots and coefficient rows KEEP
    # their values, matching the source/target/delivery gates of the plan
    # engine.  Coefficients are local compute, so ``deliv`` gates the
    # message scatter only.
    n_z = z.shape[0]
    r = coefv.shape[0]
    idx_eff = jnp.where(alive[:, None] & alivez[idx] & deliv, idx, n_z - 1)
    mem_eff = jnp.where(alive, mem, r - 1)
    zout_ref[0, :] = z.at[idx_eff.reshape(-1)].set(z_new.reshape(-1))
    cout_ref[0] = coefv.at[mem_eff].set(coef_new)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def color_step_pallas(
    z: jax.Array,
    coef: jax.Array,
    members: jax.Array,
    idx_m: jax.Array,
    mask_m: jax.Array,
    gram_m: jax.Array,
    chol_m: jax.Array,
    lam_m: jax.Array,
    alive_m: jax.Array,
    alive_z: jax.Array,
    deliv_m: jax.Array,
    *,
    block_m: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Padded inputs required: M % block_m == 0.  Use ``color_step_fused``
    for the general-shape wrapper."""
    b, n_z = z.shape
    _, r, d = coef.shape
    m = members.shape[0]
    assert idx_m.shape == (m, d), (idx_m.shape, m, d)
    assert gram_m.shape == (b, m, d, d) and chol_m.shape == (b, m, d, d)
    assert alive_m.shape == (m,), (alive_m.shape, m)
    assert alive_z.shape == (n_z,), (alive_z.shape, n_z)
    assert deliv_m.shape == (m, d), (deliv_m.shape, m, d)
    assert m % block_m == 0, (m, block_m)
    grid = (b, m // block_m)
    return pl.pallas_call(
        _color_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_z), lambda b, j: (b, 0)),
            pl.BlockSpec((1, r, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((block_m,), lambda b, j: (j,)),
            pl.BlockSpec((block_m, d), lambda b, j: (j, 0)),
            pl.BlockSpec((1, block_m, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_m, d, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_m, d, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((block_m,), lambda b, j: (j,)),
            pl.BlockSpec((block_m,), lambda b, j: (j,)),
            pl.BlockSpec((n_z,), lambda b, j: (0,)),
            pl.BlockSpec((block_m, d), lambda b, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_z), lambda b, j: (b, 0)),
            pl.BlockSpec((1, r, d), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(z.shape, z.dtype),
            jax.ShapeDtypeStruct(coef.shape, coef.dtype),
        ],
        interpret=interpret,
    )(
        z, coef, members, idx_m, mask_m, gram_m, chol_m, lam_m, alive_m,
        alive_z, deliv_m,
    )


def color_step_fused(
    z: jax.Array,
    coef: jax.Array,
    members: jax.Array,
    idx_m: jax.Array,
    mask_m: jax.Array,
    gram_m: jax.Array,
    chol_m: jax.Array,
    lam_m: jax.Array,
    alive_m: jax.Array | None = None,
    alive_z: jax.Array | None = None,
    deliv_m: jax.Array | None = None,
    *,
    block_m: int = 8,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """General-shape wrapper: one fused color step for all B fields.

    z (B, NZ); coef (B, n+1, D); members (M,) int; idx_m (M, D) int;
    mask_m (B, M, D) bool; gram_m/chol_m (B, M, D, D); lam_m (M,);
    alive_m (M,) bool member liveness and alive_z (NZ,) bool message-slot
    liveness (None = fully alive) — the network lifecycle's mask operands:
    scatters from dead members or onto dead slots redirect to the
    sentinels so those slots and coefficient rows KEEP their values.
    deliv_m (M, D) bool per-lane link delivery (None = all delivered,
    repro.core.faults): an undelivered lane redirects its MESSAGE write
    to the sentinel the same way (hold-last-value) while the
    coefficient row still updates — compute is local, only the radio
    drops.  Returns the updated (z, coef).

    The lane axis is padded to a block multiple with inert lanes (sentinel
    member row, sentinel slot ids, identity Cholesky): they solve to exact
    zeros and scatter them onto the sentinels, which are invariantly zero.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n_z = z.shape
    _, r, d = coef.shape
    m = members.shape[0]
    if alive_m is None:
        alive_m = jnp.ones((m,), bool)
    if alive_z is None:
        alive_z = jnp.ones((n_z,), bool)
    if deliv_m is None:
        deliv_m = jnp.ones((m, d), bool)
    block_m = min(block_m, max(1, m))
    pad = (-m) % block_m
    if pad:
        members = jnp.concatenate(
            [members, jnp.full((pad,), r - 1, members.dtype)]
        )
        idx_m = jnp.concatenate(
            [idx_m, jnp.full((pad, d), n_z - 1, idx_m.dtype)]
        )
        mask_m = jnp.concatenate(
            [mask_m, jnp.zeros((b, pad, d), mask_m.dtype)], axis=1
        )
        gram_m = jnp.concatenate(
            [gram_m, jnp.zeros((b, pad, d, d), gram_m.dtype)], axis=1
        )
        eye = jnp.broadcast_to(jnp.eye(d, dtype=chol_m.dtype), (b, pad, d, d))
        chol_m = jnp.concatenate([chol_m, eye], axis=1)
        lam_m = jnp.concatenate([lam_m, jnp.ones((pad,), lam_m.dtype)])
        alive_m = jnp.concatenate([alive_m, jnp.ones((pad,), alive_m.dtype)])
        deliv_m = jnp.concatenate(
            [deliv_m, jnp.ones((pad, d), deliv_m.dtype)]
        )
    return color_step_pallas(
        z, coef,
        members.astype(jnp.int32), idx_m.astype(jnp.int32),
        mask_m.astype(jnp.int8), gram_m, chol_m, lam_m,
        alive_m.astype(jnp.int8), alive_z.astype(jnp.int8),
        deliv_m.astype(jnp.int8),
        block_m=block_m, interpret=interpret,
    )
