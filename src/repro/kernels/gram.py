"""Tiled RBF Gram-matrix Pallas kernel.

Materializes K(x1, x2) = exp(-gamma ||x1_i - x2_j||^2) tile by tile — used on
the training side when the Gram block is consumed repeatedly (local solves),
where recomputation would waste FLOPs.  One (BM, BN) VMEM tile per grid step;
the pairwise term comes from the expanded-square form so the inner product
runs on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x1_ref, x2_ref, out_ref, *, gamma: float):
    x1 = x1_ref[...].astype(jnp.float32)  # (BM, d)
    x2 = x2_ref[...].astype(jnp.float32)  # (BN, d)
    sq1 = jnp.sum(x1 * x1, axis=-1)[:, None]
    sq2 = jnp.sum(x2 * x2, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        x1, x2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(sq1 + sq2 - 2.0 * cross, 0.0)
    out_ref[...] = jnp.exp(-gamma * d2)


@functools.partial(
    jax.jit, static_argnames=("gamma", "block_m", "block_n", "interpret")
)
def rbf_gram_pallas(
    x1: jax.Array,
    x2: jax.Array,
    *,
    gamma: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, d = x1.shape
    n, _ = x2.shape
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x1, x2)
