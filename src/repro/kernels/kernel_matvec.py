"""Fused RBF kernel-matvec Pallas kernel — the paper's testing-phase hot spot.

Computes  out[q] = sum_j coef[j] * exp(-gamma * ||xq[q] - anchors[j]||^2)
without materializing the (Q, N) Gram matrix in HBM.

TPU adaptation (DESIGN.md Sec. 2): FlashAttention-style streaming.  Queries
and anchors are tiled into VMEM blocks of (BQ, d) / (BN, d); the pairwise
squared distances for one (BQ, BN) tile are produced by two MXU matmuls
(expanded-square form), exponentiated on the VPU, and immediately contracted
against the coefficient block.  Only the (BQ,) accumulator ever returns to
HBM, so HBM traffic is O(Q + N) instead of O(Q * N).

Grid: (Q/BQ, N/BN) with the anchor dimension innermost so each output block
accumulates across anchor tiles in VMEM.  Block sizes default to 128/512 —
MXU-aligned (multiples of 128) with a VMEM working set of
BQ*d + BN*d + BQ*BN floats ≈ 0.3 MB, far under the ~16 MB v5e VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xq_ref, an_ref, coef_ref, out_ref, *, gamma: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xq = xq_ref[...].astype(jnp.float32)  # (BQ, d)
    an = an_ref[...].astype(jnp.float32)  # (BN, d)
    coef = coef_ref[...].astype(jnp.float32)  # (BN,)

    sq_q = jnp.sum(xq * xq, axis=-1)[:, None]  # (BQ, 1)
    sq_a = jnp.sum(an * an, axis=-1)[None, :]  # (1, BN)
    cross = jax.lax.dot_general(
        xq,
        an,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BQ, BN) on the MXU
    d2 = jnp.maximum(sq_q + sq_a - 2.0 * cross, 0.0)
    k = jnp.exp(-gamma * d2)
    out_ref[...] += k @ coef


def _batched_kernel(xq_ref, an_ref, coef_ref, out_ref, *, gamma: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xq = xq_ref[...].astype(jnp.float32)  # (BQ, d)
    an = an_ref[0].astype(jnp.float32)  # (BN, d) — this field's anchor tile
    coef = coef_ref[0].astype(jnp.float32)  # (BN,)

    sq_q = jnp.sum(xq * xq, axis=-1)[:, None]
    sq_a = jnp.sum(an * an, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        xq,
        an,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BQ, BN) on the MXU
    d2 = jnp.maximum(sq_q + sq_a - 2.0 * cross, 0.0)
    k = jnp.exp(-gamma * d2)
    out_ref[0, :] += k @ coef


@functools.partial(
    jax.jit, static_argnames=("gamma", "block_q", "block_n", "interpret")
)
def kernel_matvec_batched_pallas(
    xq: jax.Array,
    anchors: jax.Array,
    coef: jax.Array,
    *,
    gamma: float = 1.0,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Multi-field evaluation: out[b, q] = sum_j coef[b, j] K(xq[q], anchors[b, j]).

    Queries are shared across the B fields (the serving pattern: one request
    grid, many concurrent workloads); anchors/coefficients are per-field.
    Grid (B, Q/BQ, N/BN) with the anchor axis innermost so each (b, q-block)
    accumulator stays resident in VMEM across anchor tiles — the same
    streaming contraction as the single-field kernel, amortizing the query
    tile loads over all B fields.

    Padded inputs required: Q % block_q == 0, N % block_n == 0.  Use
    `repro.kernels.ops.kernel_matvec` for the general-shape wrapper.
    """
    q, d = xq.shape
    b, n, _ = anchors.shape
    assert coef.shape == (b, n), (coef.shape, b, n)
    assert q % block_q == 0 and n % block_n == 0, (q, n, block_q, block_n)
    grid = (b, q // block_q, n // block_n)
    return pl.pallas_call(
        functools.partial(_batched_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda b, i, j: (i, 0)),
            pl.BlockSpec((1, block_n, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_n), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((b, q), jnp.float32),
        interpret=interpret,
    )(xq, anchors, coef)


@functools.partial(
    jax.jit, static_argnames=("gamma", "block_q", "block_n", "interpret")
)
def kernel_matvec_pallas(
    xq: jax.Array,
    anchors: jax.Array,
    coef: jax.Array,
    *,
    gamma: float = 1.0,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Padded inputs required: Q % block_q == 0, N % block_n == 0.

    Use `repro.kernels.ops.kernel_matvec` for the general-shape wrapper.
    """
    q, d = xq.shape
    n, _ = anchors.shape
    assert q % block_q == 0 and n % block_n == 0, (q, n, block_q, block_n)
    grid = (q // block_q, n // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=interpret,
    )(xq, anchors, coef)
