"""Fused kNN-fusion serving kernel — plan-based testing phase in VMEM.

One launch answers a query grid under the paper's kNN fusion rule (Eq. 19)
for all B fields without ever materializing the dense intermediates the
oracle path builds in HBM (the (n, Q) per-sensor predictions and the (Q, n)
distance matrix).  Per (field, query-tile) grid step, entirely in VMEM:

  gather   the tile's cell candidate rows from the static serving plan
           (``repro.core.serving.make_serving_plan``) and the candidates'
           sensor positions;
  distance one (BQ, K_max) masked squared-distance tile;
  select   top-k by a k-step masked selection network: argmin, record,
           disable, repeat — k is tiny (1..8), so the unrolled network
           beats a full sort and ties break toward the lower sensor id
           exactly like ``lax.top_k`` on the dense path;
  evaluate for each selected sensor, gather its (D, d) neighborhood
           anchors + masked (D,) representer row and contract
           f_s(x) = sum_j c_{s,j} exp(-gamma ||x - x_j||^2) locally;
  average  the k local estimates into the (BQ,) output block.

Grid: (B, Q / block_q) with the query axis innermost, so each field's plan
tables / anchor tables / coefficients stay resident in VMEM while the query
tiles stream through — HBM traffic is O(B*n*D + Q), compute O(B*Q*k*D),
versus O(B*Q*n*D) compute and O(B*Q*n) HBM for the dense oracle.

Mixed precision (``compute_dtype=``): the neighborhood ANCHOR tables —
the VMEM-dominant operand at O(B*n*D*d) elements, an order of magnitude
above the O(n*d) sensor-position table — are STORED in the compute dtype
(bf16 for the quantized serving path), halving the resident footprint per
program so the default query tile doubles (``default_block_q``: 128 at
f32, 256 at bf16).  Gathered anchor tiles are upconverted at the register
level and all arithmetic runs at (at least) f32 — the same contract as a
bf16-in/f32-out MXU contraction — while the representer contraction and
the running average ALWAYS accumulate in the coefficient dtype (f32, or
f64 under JAX_ENABLE_X64 — ``ecoef`` is never downcast).  Selection stays
EXACT: queries, sensor positions, the distance tile, and the top-k
network keep full precision, so the quantized path selects the same
sensors as the f32 path and the only perturbation is the bf16 rounding of
the anchors inside exp(-gamma ||x - x_j||^2).  (Quantizing selection too
was measured and rejected: at n=1000 serving geometry, bf16 position
rounding flips ~5% of selected sets and costs ~2.3% field RMSE — over the
quantized path's 1% budget — while anchors-only costs ~0.1%; see
BENCH_quant.json and tests/test_quant_serving.py.)

The output dtype follows the COEFFICIENTS, not the queries — an f64
problem served with bf16 selection still answers in f64.  On non-TPU
backends the wrapper runs in interpret mode (the repo's validation mode,
see ``kernels.ops``); the in-kernel gathers use dynamic indices, which
interpret mode executes exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_block_q(compute_dtype=None) -> int:
    """Query-tile rows per program, derived from the VMEM footprint.

    The per-program footprint is dominated by the position tables and the
    query tile; halving their element width (f32 -> bf16) frees room to
    double the tile, halving the number of grid steps per launch.
    """
    if compute_dtype is not None and jnp.dtype(compute_dtype).itemsize <= 2:
        return 256
    return 128


def _knn_fuse_kernel(
    xq_ref, cid_ref, cells_ref, cmask_ref, alive_ref, spos_ref,
    npos_ref, nmask_ref, coef_ref, out_ref,
    *, gamma: float, k: int,
):
    raw = xq_ref[...]  # (BQ, d)
    # Arithmetic runs at (at least) f32; anchor refs may be stored
    # narrower (bf16) and are upconverted in registers after the gather.
    ar_dt = raw.dtype if raw.dtype.itemsize >= 4 else jnp.float32
    xq = raw.astype(ar_dt)
    cid = cid_ref[...]  # (BQ,)
    alive = alive_ref[...]  # (n+1,) row liveness (lifecycle AND pruning)
    cand = cells_ref[...][cid]  # (BQ, K) this tile's candidate rows
    # Candidate validity = plan mask & liveness: a removed (or pruned-out)
    # sensor drops out even before the serving plan's candidate lists are
    # repaired/compacted.
    cmask = (cmask_ref[...][cid] != 0) & (alive[cand] != 0)  # (BQ, K)
    cpos = spos_ref[...][cand].astype(ar_dt)  # (BQ, K, d) full precision
    # Upconvert the anchor block ONCE per program, right after the ref
    # load: the VMEM-resident copy is the narrow storage dtype; the wide
    # working copy lives only for this grid step (and the per-step cast is
    # one table-sized op instead of k gather-sized ones).
    npos = npos_ref[0].astype(ar_dt)  # (n+1, D, d)
    nmask = nmask_ref[0]  # (n+1, D)
    coef = coef_ref[0]  # (n+1, D) accumulation dtype — NEVER downcast

    bq, kmax = cand.shape
    acc_dt = coef.dtype
    inf = jnp.asarray(jnp.inf, ar_dt)
    d2 = jnp.sum((xq[:, None, :] - cpos) ** 2, axis=-1)  # (BQ, K)
    d2 = jnp.where(cmask, d2, inf)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, kmax), 1)

    acc = jnp.zeros((bq,), acc_dt)
    cnt = jnp.zeros((bq,), jnp.int32)
    for _ in range(k):  # masked selection network, k unrolled steps
        best = jnp.argmin(d2, axis=1)  # (BQ,) first-min == lowest id
        # Fewer than k live candidates: the overflow picks +inf entries —
        # count only VALID selections so the average matches the dense
        # oracle's live-only mean (all-dead cells predict exactly 0).
        ok = jnp.isfinite(
            jnp.take_along_axis(d2, best[:, None], axis=1)[:, 0]
        )
        sel = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
        d2 = jnp.where(cols == best[:, None], inf, d2)  # disable selected
        cf = jnp.where(nmask[sel] != 0, coef[sel], 0.0)  # (BQ, D) acc dtype
        dd = jnp.sum((xq[:, None, :] - npos[sel]) ** 2, axis=-1)  # (BQ, D)
        f = jnp.sum(jnp.exp(-gamma * dd).astype(acc_dt) * cf, axis=-1)
        acc += jnp.where(ok, f, 0.0)
        cnt += ok.astype(jnp.int32)
    out_ref[0, :] = acc / jnp.maximum(cnt, 1).astype(acc_dt)


@functools.partial(
    jax.jit, static_argnames=("gamma", "k", "block_q", "interpret")
)
def knn_fuse_pallas(
    xq: jax.Array,
    qcell: jax.Array,
    cells: jax.Array,
    cmask: jax.Array,
    alive: jax.Array,
    spos: jax.Array,
    nbr_pos: jax.Array,
    nbr_mask: jax.Array,
    coef: jax.Array,
    *,
    gamma: float = 1.0,
    k: int = 1,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Padded inputs required: Q % block_q == 0.  Use ``knn_fuse_fused``
    for the general-shape wrapper.

    xq (Q, d); qcell (Q,) int32 flattened cell ids; cells (C, K) int32;
    cmask (C, K) int8; alive (n+1,) int8 sensor-row liveness;
    spos (n+1, d) padded sensor positions; nbr_pos (B, n+1, D, d);
    nbr_mask (B, n+1, D) int8; coef (B, n+1, D).  Returns (B, Q) in the
    COEFFICIENT dtype.  ``nbr_pos`` may be stored in a narrower compute
    dtype (bf16) than the rest — its VMEM tiles stay narrow, gathers are
    upconverted in registers, and the arithmetic runs at >= f32 while the
    contraction accumulates in coef.dtype.
    """
    q, d = xq.shape
    c, kmax = cells.shape
    b, r, d_max, _ = nbr_pos.shape
    assert q % block_q == 0, (q, block_q)
    assert nbr_mask.shape == (b, r, d_max) and coef.shape == (b, r, d_max)
    assert alive.shape == (r,), (alive.shape, r)
    grid = (b, q // block_q)
    return pl.pallas_call(
        functools.partial(_knn_fuse_kernel, gamma=gamma, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda b, i: (i, 0)),
            pl.BlockSpec((block_q,), lambda b, i: (i,)),
            pl.BlockSpec((c, kmax), lambda b, i: (0, 0)),
            pl.BlockSpec((c, kmax), lambda b, i: (0, 0)),
            pl.BlockSpec((r,), lambda b, i: (0,)),
            pl.BlockSpec(spos.shape, lambda b, i: (0, 0)),
            pl.BlockSpec((1, r, d_max, d), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, r, d_max), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, r, d_max), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((b, q), coef.dtype),
        interpret=interpret,
    )(xq, qcell, cells, cmask, alive, spos, nbr_pos, nbr_mask, coef)


def knn_fuse_fused(
    xq: jax.Array,
    qcell: jax.Array,
    cells: jax.Array,
    cell_mask: jax.Array,
    spos: jax.Array,
    nbr_pos: jax.Array,
    nbr_mask: jax.Array,
    coef: jax.Array,
    *,
    alive: jax.Array | None = None,
    gamma: float = 1.0,
    k: int = 1,
    block_q: int | None = None,
    compute_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """General-shape wrapper: pad the query axis, launch, slice back.

    Queries are padded to the power-of-two bucket of Q (see
    ``kernels.ops.bucket_rows``) so a serving process with varied request
    sizes compiles O(log Q) programs; padded rows point at cell 0 and are
    sliced off.  ``alive`` is the (n+1,) sensor-row liveness mask (None =
    fully alive): dead candidates never get selected, independent of the
    serving plan's repair state.

    ``compute_dtype`` (e.g. ``jnp.bfloat16``) rounds the anchor tables
    (``nbr_pos``, the VMEM-dominant operand) to the storage dtype the
    kernel keeps in VMEM; queries, sensor positions, and the top-k
    selection stay full-precision (selection-exact quantization),
    arithmetic upconverts to >= f32 in registers, ``coef`` is never cast,
    and the contraction accumulates — and the output returns — in
    ``coef.dtype``.  ``block_q`` defaults to
    ``default_block_q(compute_dtype)`` (128 f32 / 256 bf16).
    """
    from .ops import _auto_interpret, bucket_rows

    if compute_dtype is not None:
        nbr_pos = nbr_pos.astype(jnp.dtype(compute_dtype))
    if block_q is None:
        block_q = default_block_q(compute_dtype)
    q = xq.shape[0]
    r = nbr_pos.shape[1]
    if alive is None:
        alive = jnp.ones((r,), jnp.int8)
    q_pad = bucket_rows(q)
    block_q = min(block_q, q_pad)
    q_pad = -(-q_pad // block_q) * block_q
    if q_pad != q:
        xq = jnp.pad(xq, ((0, q_pad - q), (0, 0)))
        qcell = jnp.pad(qcell, ((0, q_pad - q),))
    return knn_fuse_pallas(
        xq, qcell.astype(jnp.int32),
        cells.astype(jnp.int32), cell_mask.astype(jnp.int8),
        alive.astype(jnp.int8), spos,
        nbr_pos, nbr_mask.astype(jnp.int8), coef,
        gamma=gamma, k=k, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )[:, :q]
