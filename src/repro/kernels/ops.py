"""Jit'd general-shape wrappers around the Pallas kernels.

These handle padding to block multiples, choose interpret mode automatically
on non-TPU backends (this container is CPU: the kernel bodies execute in
Python via the Pallas interpreter, which is the validation mode), and slice
results back to the caller's shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .gram import rbf_gram_pallas
from .kernel_matvec import kernel_matvec_batched_pallas, kernel_matvec_pallas


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_dim(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    return _pad_dim(x, 0, mult)


def bucket_rows(q: int, min_rows: int = 8) -> int:
    """Round a row count up to its power-of-two bucket (min ``min_rows``).

    A serving process sees many distinct request sizes; padding each query
    grid to the next power of two means the padded shape — and therefore
    the lowered Pallas program — takes O(log Q) distinct values instead of
    one fresh compile per size (tests/test_serving.py counts the programs
    via the jit cache).  Padded rows are exact: they carry zeros and are
    sliced off by the callers.
    """
    return 1 << max(q - 1, min_rows - 1).bit_length()


def kernel_matvec(
    xq: jax.Array,
    anchors: jax.Array,
    coef: jax.Array,
    *,
    gamma: float = 1.0,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """f(xq) = sum_j coef_j exp(-gamma ||xq - x_j||^2) for arbitrary shapes.

    Multi-field batching: pass coef as (B, N) — and optionally anchors as
    (B, N, d) for per-field anchor sets (streaming problems) — to evaluate B
    kernel expansions against one shared query grid in a single fused Pallas
    launch; returns (B, Q).  Single-field (N,) coef returns (Q,) as before.

    Padding is exact: padded anchors carry coef 0 (zero contribution) and
    padded query rows are sliced off.  The query axis is padded to its
    power-of-two bucket (``bucket_rows``), so varied request sizes against
    one anchor set lower O(log Q) distinct programs, not O(#sizes).
    """
    q = xq.shape[0]
    q_pad = bucket_rows(q)
    coef = jnp.asarray(coef, jnp.float32)
    anchors = jnp.asarray(anchors, jnp.float32)
    if coef.ndim == 2:
        b, n = coef.shape
        if anchors.ndim == 2:
            anchors = jnp.broadcast_to(anchors[None], (b,) + anchors.shape)
        block_q = min(block_q, q_pad)
        block_n = min(block_n, max(8, n))
        # q <= q_pad, so padding to a q_pad multiple lands exactly on the
        # bucket; the outer pad only matters for non-power-of-two block_q.
        xq_p = _pad_rows(
            _pad_rows(jnp.asarray(xq, jnp.float32), q_pad), block_q
        )
        an_p = _pad_dim(anchors, 1, block_n)
        coef_p = _pad_dim(coef, 1, block_n)
        out = kernel_matvec_batched_pallas(
            xq_p,
            an_p,
            coef_p,
            gamma=gamma,
            block_q=block_q,
            block_n=block_n,
            interpret=_auto_interpret(interpret),
        )
        return out[:, :q]

    n = anchors.shape[0]
    block_q = min(block_q, q_pad)
    block_n = min(block_n, max(8, n))
    xq_p = _pad_rows(
        _pad_rows(jnp.asarray(xq, jnp.float32), q_pad), block_q
    )
    an_p = _pad_rows(anchors, block_n)
    coef_p = _pad_rows(coef, block_n)
    out = kernel_matvec_pallas(
        xq_p,
        an_p,
        coef_p,
        gamma=gamma,
        block_q=block_q,
        block_n=block_n,
        interpret=_auto_interpret(interpret),
    )
    return out[:q]


def rbf_gram(
    x1: jax.Array,
    x2: jax.Array,
    *,
    gamma: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    m, n = x1.shape[0], x2.shape[0]
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    x1_p = _pad_rows(jnp.asarray(x1, jnp.float32), block_m)
    x2_p = _pad_rows(jnp.asarray(x2, jnp.float32), block_n)
    out = rbf_gram_pallas(
        x1_p,
        x2_p,
        gamma=gamma,
        block_m=block_m,
        block_n=block_n,
        interpret=_auto_interpret(interpret),
    )
    return out[:m, :n]


def ssd_chunked_fused(
    x, dt, a, bmat, cmat, chunk: int, h0=None, *,
    block_h: int = 8, interpret: bool | None = None,
):
    """Drop-in replacement for `repro.models.ssm.ssd_chunked` whose
    intra-chunk term runs in the fused Pallas kernel (no O(S*cs*H) decay
    tensor in HBM).  The inter-chunk recurrence stays in jnp (tiny).

    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32).
    """
    import jax

    from .ssd_intra import ssd_intra_pallas

    b, s, h, p = x.shape
    n = bmat.shape[-1]
    pad_s = (-s) % chunk
    pad_h = (-h) % block_h
    if pad_s:
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad_s), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad_s), (0, 0)))
    if pad_h:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_h), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_h)))
        a = jnp.pad(a, ((0, pad_h),))
    sp, hp = s + pad_s, h + pad_h
    nc = sp // chunk

    da = dt * a[None, None, :]
    da_c = da.reshape(b, nc, chunk, hp)
    da_cum = jnp.cumsum(da_c, axis=2)
    da_sum = da_cum[:, :, -1, :]

    y_intra = ssd_intra_pallas(
        x, dt, da_cum.reshape(b, sp, hp), bmat, cmat,
        chunk=chunk, block_h=block_h, interpret=_auto_interpret(interpret),
    )

    # chunk boundary states + inter-chunk recurrence (same math as the ref)
    xc = x.reshape(b, nc, chunk, hp, p)
    dtc = dt.reshape(b, nc, chunk, hp)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)
    decay_to_end = jnp.exp(da_sum[:, :, None, :] - da_cum)
    states = jnp.einsum("bzmn,bzmh,bzmhp->bzhpn", bc, dtc * decay_to_end, xc)
    chunk_decay = jnp.exp(da_sum)
    if h0 is None:
        h0 = jnp.zeros((b, hp, p, n), jnp.float32)
    elif pad_h:
        h0 = jnp.pad(h0, ((0, 0), (0, pad_h), (0, 0), (0, 0)))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry

    last, h_prev = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)
    y_inter = jnp.einsum("bzln,bzhpn,bzlh->bzlhp", cc, h_prev, jnp.exp(da_cum))
    y = y_intra.reshape(b, nc, chunk, hp, p) + y_inter
    y = y.reshape(b, sp, hp, p)[:, :s, :h]
    return y, last[:, :h]
