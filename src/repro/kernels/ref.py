"""Pure-jnp oracles for the Pallas kernels (ground truth in tests/benches)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _sq_dists(x1: jax.Array, x2: jax.Array) -> jax.Array:
    sq1 = jnp.sum(x1 * x1, axis=-1)[:, None]
    sq2 = jnp.sum(x2 * x2, axis=-1)[None, :]
    return jnp.maximum(sq1 + sq2 - 2.0 * (x1 @ x2.T), 0.0)


def rbf_gram_ref(x1: jax.Array, x2: jax.Array, gamma: float) -> jax.Array:
    """(M, N) Gaussian Gram matrix K(x1_i, x2_j) = exp(-gamma ||.||^2)."""
    return jnp.exp(-gamma * _sq_dists(x1, x2))


def kernel_matvec_ref(
    xq: jax.Array, anchors: jax.Array, coef: jax.Array, gamma: float
) -> jax.Array:
    """f(xq_i) = sum_j coef_j exp(-gamma ||xq_i - anchors_j||^2), shape (Q,).

    Materializes the full (Q, N) Gram matrix — the thing the Pallas kernel
    avoids doing in HBM.
    """
    return rbf_gram_ref(xq, anchors, gamma) @ coef


def kernel_matvec_batched_ref(
    xq: jax.Array, anchors: jax.Array, coef: jax.Array, gamma: float
) -> jax.Array:
    """Multi-field oracle: out[b, q] = sum_j coef[b, j] K(xq[q], anchors[b, j]).

    anchors: (B, N, d) per-field anchor sets; coef: (B, N).  Materializes the
    full (B, Q, N) Gram tensor the batched Pallas kernel streams through VMEM.
    """
    return jax.vmap(lambda an, c: rbf_gram_ref(xq, an, gamma) @ c)(anchors, coef)


def local_batched_solve_ref(
    gram: jax.Array, lam: jax.Array, rhs: jax.Array, mask: jax.Array
) -> jax.Array:
    """Batched masked (K_s + lambda_s I)^{-1} rhs — SN-Train Eq. 18 oracle.

    gram: (B, D, D) masked local Gram blocks; lam: (B,); rhs: (B, D);
    mask: (B, D) neighborhood validity.
    """
    diag = jnp.where(mask, lam[:, None], 1.0)
    a = gram + jax.vmap(jnp.diag)(diag)
    rhs = jnp.where(mask, rhs, 0.0)
    return jnp.linalg.solve(a, rhs[..., None])[..., 0]
