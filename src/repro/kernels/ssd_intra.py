"""Fused Mamba2/SSD intra-chunk Pallas kernel (§Perf H2 'next lever').

The pure-jnp SSD dual form materializes the per-chunk decay tensor
L[l,m,h] = exp(dA_cum[l,h] - dA_cum[m,h]) (l >= m) in HBM —
O(S * cs * H) traffic that dominates the jamba/mamba2 training memory
roofline.  This kernel computes, entirely in VMEM per (batch, chunk,
head-block) grid step:

    CB   = C_chunk @ B_chunk^T                       (cs, cs)   MXU
    M    = CB * tril(exp(dA_cum[l] - dA_cum[m]))     (cs,cs,BH) VPU
    Y    = M (x) (dt * x)                            (BH batched matmul, MXU)

so only the O(S * H * P) output ever returns to HBM.

VMEM working set per step (cs=64, BH=8, P=64, N=128):
cs*N*2 + cs*BH*(P+2) + cs*cs*(1+BH) floats ~ 0.2 MB << 16 MB v5e VMEM.
The inter-chunk recurrence (O(S/cs) scan over (H,P,N) states) stays in jnp —
it is tiny by comparison.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dtx_ref, dacum_ref, b_ref, c_ref, out_ref):
    # block shapes (leading grid dims squeezed by indexing [0, 0]):
    #   x/dtx: (1, 1, cs, BH, P); dacum: (1, 1, cs, BH); b/c: (1, 1, cs, N)
    dtx = dtx_ref[0, 0].astype(jnp.float32)  # (cs, BH, P)  dt * x
    da = dacum_ref[0, 0].astype(jnp.float32)  # (cs, BH)
    bmat = b_ref[0, 0].astype(jnp.float32)  # (cs, N)
    cmat = c_ref[0, 0].astype(jnp.float32)  # (cs, N)
    cs = da.shape[0]

    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cs, cs) = C_l . B_m
    diff = da[:, None, :] - da[None, :, :]  # (cs, cs, BH), l index first
    tril = jnp.tril(jnp.ones((cs, cs), jnp.bool_))
    diff = jnp.where(tril[:, :, None], diff, -jnp.inf)
    m = cb[:, :, None] * jnp.exp(diff)  # (cs, cs, BH)

    # batched-by-head matmul: (BH, cs, cs) @ (BH, cs, P) -> (BH, cs, P)
    m_h = jnp.transpose(m, (2, 0, 1))
    v_h = jnp.transpose(dtx, (1, 0, 2))
    y = jax.lax.dot_general(
        m_h, v_h, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (BH, cs, P)
    out_ref[0, 0] = jnp.transpose(y, (1, 0, 2)).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_h", "interpret")
)
def ssd_intra_pallas(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) post-softplus
    da_cum: jax.Array,  # (B, S, H) within-chunk inclusive cumsum of dt*A
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    *,
    chunk: int,
    block_h: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Intra-chunk SSD term; S % chunk == 0 and H % block_h == 0 required
    (use repro.kernels.ops.ssd_chunked_fused for general shapes)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0 and h % block_h == 0, (s, chunk, h, block_h)
    nc = s // chunk
    dtx = (dt[..., None] * x).reshape(b, nc, chunk, h, p)
    dac = da_cum.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)
    grid = (b, nc, h // block_h)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, block_h, p), lambda i, z, j: (i, z, 0, j, 0)),
            pl.BlockSpec((1, 1, chunk, block_h), lambda i, z, j: (i, z, 0, j)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, z, j: (i, z, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, z, j: (i, z, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, chunk, block_h, p), lambda i, z, j: (i, z, 0, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, nc, chunk, h, p), jnp.float32),
        interpret=interpret,
    )(dtx, dac, bc, cc)
    return out.reshape(b, s, h, p)
