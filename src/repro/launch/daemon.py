"""Always-on serving daemon: snapshot-isolated queries under a supervised
trainer.

The paper's SOP trainer is an ongoing message-passing process, not a
batch job — sensors keep measuring (cs/0507039 Sec. 4), links keep
dropping (the cs/0601089 operating regime), and queries arrive while
training is mid-sweep.  ``serve.py --mode field`` replays that pipeline
once and exits; this module is the long-lived process production needs,
built entirely from machinery earlier PRs already landed:

  queue      arriving queries coalesce into the power-of-two buckets of
             ``kernels.ops.bucket_rows`` (O(log Q) compiled programs for
             any request-size mix), behind a BOUNDED queue with
             admission-control backpressure: when the estimated wait
             exceeds the deadline budget the request is shed at submit
             time with an explicit receipt (the ``AbsorbReceipt``
             pattern — pressure is observable, never silent).

  snapshot   every query reads a DOUBLE-BUFFERED coefficient snapshot:
             an immutable (problem, state, plan, effective_coef) tuple.
             Queries serve from snapshot t while sweeps/absorbs/churn
             build t+1 on separate (functionally-updated) buffers; the
             publish is one Python reference flip, which the plan/alive
             split already makes safe — a wedged, retrying, or diverging
             trainer can never block or corrupt a query.

  supervise  every training tick runs through ``monitor.watch_sweeps``:
             its receipt IS the health endpoint
             (``WatchdogReceipt.to_json``), divergence climbs the
             existing retry -> refactorize -> rollback ladder, and a tick
             that ends rolled-back or diverged simply isn't published —
             the daemon keeps serving the last good snapshot (graceful
             degradation) and restores the trainer's working copy from
             it.  Fault drills come from ``core.faults``: the drop rates
             are TRACED operands of one compiled program, so drills and
             recovery never compile anything.

  restart    ``checkpoint.save_train`` snapshots the PUBLISHED state
             every ``ckpt_every`` ticks; on construction the daemon
             restores the latest INTACT step (``checkpoint.latest_step``
             verifies npz integrity, so a crash mid-save is skipped) —
             crash-kill -> warm restart resumes bitwise.

Concurrency model: the daemon is a cooperative state machine —
``pump()`` drains queries, ``tick()`` advances training — which is what
the bench and tests drive deterministically.  Because a published
snapshot is immutable and the flip is a single reference assignment
(atomic under the GIL), a threaded deployment may run ``pump`` and
``tick`` on separate threads without locks around the read path; the
cooperative loop is the same code with the interleaving made explicit.

CLI (used by the CI kill-and-warm-restart smoke):

  PYTHONPATH=src python -m repro.launch.daemon --sensors 40 --fields 3 \
      --ticks 20 --ckpt-every 1 --snapshot-dir /tmp/snap
  # SIGKILL it mid-run, then:
  PYTHONPATH=src python -m repro.launch.daemon --sensors 40 --fields 3 \
      --ticks 0 --snapshot-dir /tmp/snap --verify-restart
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    fusion,
    make_serving_plan,
    monitor,
    pruning,
    streaming,
)
from repro.core import faults as faults_mod
from repro.core.serving import plan_add_sensor, plan_remove_sensor
from repro.core.sn_train import effective_coef
from repro.kernels.ops import bucket_rows


@dataclasses.dataclass(frozen=True)
class DaemonConfig:
    """Host-side knobs of the serving daemon (all static)."""

    k: int = 3  # kNN fusion order served
    engine: str = "plan"  # serving engine: "plan" | "pallas"
    train_engine: str = "plan"  # sweep engine for training ticks
    queue_rows: int = 1024  # hard cap on pending query rows
    max_batch_rows: int = 256  # rows coalesced into one dispatch
    deadline_ms: float = float("inf")  # admission budget (est. wait)
    sweeps_per_tick: int = 5  # sweeps per watchdog round
    rounds_per_tick: int = 2  # watchdog rounds per tick
    watch_tol: float = 1e-3  # per-round convergence tolerance
    arrival_rows: int = 32  # max arrivals absorbed per tick window
    on_full: str = "drop"  # over-capacity arrival policy
    ckpt_every: int = 0  # ticks between checkpoints (0 = off)
    snapshot_dir: str | None = None  # warm-restart / checkpoint home
    serve_dtype: str = "f32"  # anchor storage dtype: "f32" | "bf16"
    energy_tau: float = 0.0  # representer-pruning threshold (0 = off)


class Snapshot(NamedTuple):
    """One immutable published serving state (the double buffer's face).

    ``ecoef`` is ``effective_coef(problem, state)`` materialized at
    publish time, so every query dispatch against this snapshot skips
    the per-call anchor-weight rescale (``serving.knn_fuse(ecoef=...)``).
    ``ecoef`` stays in the COEFFICIENT dtype (f32/f64) regardless of the
    serving ``serve_dtype`` — bf16 rounds the stored anchor tables only
    (selection-exact; see ``core.serving``), never the coefficients or
    the accumulated contraction.  ``keep`` is the representer-prune
    mask re-derived from this snapshot's coefficients at publish time
    (``pruning.prune_mask``; None when pruning is off): values-only, so
    per-publish re-pruning compiles nothing.
    """

    version: int
    problem: object
    state: object
    plan: object
    ecoef: jax.Array
    serve_dtype: str = "f32"
    keep: object = None  # (n+1,) bool keep mask, or None
    pruned: int = 0  # live sensors pruned out of this snapshot


class QueryTicket(NamedTuple):
    """Admission receipt, returned by ``submit`` (AbsorbReceipt pattern).

    ``admitted`` False means the query was SHED at the door —
    ``shed_reason`` says why ("queue_full": the bounded queue is at
    capacity; "deadline": the estimated wait exceeds the deadline
    budget).  Shed requests are never silently dropped from the queue.
    """

    id: int
    admitted: bool
    shed_reason: str = ""


class QueryAnswer(NamedTuple):
    """One served query: values from the snapshot named by ``version``."""

    id: int
    values: np.ndarray  # (B, q) field estimates at the request's points
    version: int  # snapshot the answer was read from
    degraded: bool  # True: trainer unhealthy, snapshot is last-good
    latency_s: float  # submit -> answer wall time


class TickReceipt(NamedTuple):
    """What one training tick did (the health endpoint's raw material)."""

    tick: int
    published: bool  # a new snapshot went live
    degraded: bool  # trainer unhealthy; serving last good snapshot
    version: int  # currently PUBLISHED snapshot version
    absorbed: int  # arrivals absorbed this tick
    arrival_drops: int  # arrivals dropped by capacity pressure
    arrivals_rolled_back: int  # absorbed arrivals lost to a rollback
    joins: int
    leaves: int
    watchdog: monitor.WatchdogReceipt
    ckpt_step: int | None  # checkpoint written this tick (None: none)

    def to_json(self) -> dict:
        """Plain-JSON receipt (the /health payload's per-tick record)."""
        return {
            "schema": "tick_receipt/1",
            "tick": int(self.tick),
            "published": bool(self.published),
            "degraded": bool(self.degraded),
            "version": int(self.version),
            "absorbed": int(self.absorbed),
            "arrival_drops": int(self.arrival_drops),
            "arrivals_rolled_back": int(self.arrivals_rolled_back),
            "joins": int(self.joins),
            "leaves": int(self.leaves),
            "watchdog": self.watchdog.to_json(),
            "ckpt_step": None if self.ckpt_step is None else int(self.ckpt_step),
        }


_ecoef_jit = jax.jit(effective_coef)


def _state_digest(problem, state) -> str:
    """Order-stable sha256 over every problem/state leaf (bitwise id)."""
    h = hashlib.sha256()
    for leaf in jax.tree.leaves({"problem": problem, "state": state}):
        arr = np.asarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


class Daemon:
    """Long-lived field-serving process; see the module docstring.

    problem/state: a BATCHED ``SNTrainProblem``/``SNTrainState`` pair —
    the live templates for warm restart (array leaves are replaced by
    the restored snapshot; statics carry over).  plan: a prebuilt
    ``ServingPlan`` (default: ``make_serving_plan(problem, k=config.k)``
    — pass one built with ``spare=``/``slack=`` when churn events will
    arrive).  fault_model: the link-fault process training ticks run
    under; defaults to ``make_fault_model(0.0)`` rather than None so the
    fault-free and drilled paths share ONE compiled program (rates are
    traced operands) — ``set_fault_model`` swaps rates without a single
    recompile.
    """

    def __init__(
        self,
        problem,
        state,
        *,
        config: DaemonConfig = DaemonConfig(),
        plan=None,
        fault_model: faults_mod.FaultModel | None = None,
        key: jax.Array | None = None,
    ):
        if not problem.batched:
            raise ValueError("the daemon serves batched problems (use B=1)")
        if config.on_full not in ("drop", "evict"):
            raise ValueError(f"bad on_full {config.on_full!r}")
        if config.serve_dtype not in ("f32", "bf16"):
            raise ValueError(f"bad serve_dtype {config.serve_dtype!r}")
        self.config = config
        # "f32" means the problem's native dtype (f64 problems serve f64);
        # bf16 rounds the stored anchor tables only (selection-exact).
        self._compute_dtype = (
            None if config.serve_dtype == "f32" else config.serve_dtype
        )
        self._energy_tau = float(config.energy_tau)
        self.restored_step: int | None = None
        if config.snapshot_dir is not None:
            from repro import checkpoint as ckpt

            step = ckpt.latest_step(config.snapshot_dir)  # verified intact
            if step is not None:
                problem, state = ckpt.restore_train(
                    config.snapshot_dir, step, problem, state
                )
                self.restored_step = step
        self._work = (problem, state)
        self._plan = (
            plan if plan is not None
            else make_serving_plan(problem, k=config.k)
        )
        self._model = (
            fault_model if fault_model is not None
            else faults_mod.make_fault_model(0.0)
        )
        self._key = jax.random.PRNGKey(0) if key is None else key
        self._watch_cfg = monitor.WatchdogConfig(
            sweeps_per_round=config.sweeps_per_tick,
            tol=config.watch_tol,
            max_rounds=config.rounds_per_tick,
        )
        # queues (host-side; bounded by admission control)
        self._queries: deque = deque()  # (id, xq np, t_submit)
        self._pending_rows = 0
        self._arrivals: deque = deque()  # (field, sensor, x, y)
        self._events: deque = deque()  # ("join", x, ys, lam) | ("leave", s)
        # stats / receipts
        self._next_id = 0
        self.tick_count = 0
        self.served = 0
        self.shed = 0
        self.degraded = False
        self.last_tick: TickReceipt | None = None
        self.buckets_hit: set = set()  # padded dispatch sizes (tests)
        self._ema_batch_s: float | None = None
        # initial publish: version 0 serves the (possibly restored) state
        self._snap = self._make_snapshot(0, problem, state, self._plan)

    # -- snapshot plumbing -------------------------------------------------

    def _make_snapshot(self, version, problem, state, plan) -> Snapshot:
        ecoef = _ecoef_jit(problem, state)
        ecoef.block_until_ready()  # publish COMPLETE buffers only
        keep = None
        pruned = 0
        if self._energy_tau > 0.0:
            # Re-prune on EVERY publish: fresh coefficients (beta decay,
            # absorbs, churn) move sensor energies, and tau is a traced
            # operand of one compiled program — zero recompiles per
            # publish or per set_energy_tau change.
            keep = pruning.prune_mask(
                problem, ecoef=ecoef, energy_tau=self._energy_tau
            )
            keep.block_until_ready()
            n = problem.n
            pruned = int(
                np.asarray(problem.alive[:n]).astype(bool).sum()
                - np.asarray(keep[:n]).sum()
            )
        return Snapshot(
            version, problem, state, plan, ecoef,
            serve_dtype=self.config.serve_dtype, keep=keep, pruned=pruned,
        )

    def set_energy_tau(self, tau: float) -> None:
        """Change the pruning threshold; takes effect at the next publish.

        Values-only (the prune-mask program traces tau), so sweeping tau
        on a live daemon never compiles anything.
        """
        self._energy_tau = float(tau)

    @property
    def snapshot(self) -> Snapshot:
        """The currently published snapshot (immutable; safe to hold)."""
        return self._snap

    # -- query path --------------------------------------------------------

    def submit(self, xq, now: float | None = None) -> QueryTicket:
        """Enqueue a query grid (q, d); sheds instead of queueing unbounded.

        Admission control: a request is rejected when the queue is at
        ``queue_rows`` capacity, or when the estimated wait — pending
        dispatches times the EMA dispatch latency — exceeds
        ``deadline_ms``.  The ticket records the outcome; an admitted
        request is answered by a later ``pump`` with its latency stamped
        from this submit time.
        """
        now = time.perf_counter() if now is None else now
        xq = np.atleast_2d(np.asarray(xq))
        qid = self._next_id
        self._next_id += 1
        rows = xq.shape[0]
        cfg = self.config
        if self._pending_rows + rows > cfg.queue_rows:
            self.shed += 1
            return QueryTicket(qid, False, "queue_full")
        if self._ema_batch_s is not None and np.isfinite(cfg.deadline_ms):
            batches_ahead = 1 + self._pending_rows // cfg.max_batch_rows
            est_wait_ms = batches_ahead * self._ema_batch_s * 1e3
            if est_wait_ms > cfg.deadline_ms:
                self.shed += 1
                return QueryTicket(qid, False, "deadline")
        self._queries.append((qid, xq, now))
        self._pending_rows += rows
        return QueryTicket(qid, True)

    def pump(self) -> list[QueryAnswer]:
        """Drain the query queue against the published snapshot.

        Requests coalesce front-to-back into dispatches of at most
        ``max_batch_rows`` rows; each dispatch pads its row count to the
        power-of-two bucket (``bucket_rows``), so ANY interleaving of
        request sizes lowers O(log max_batch_rows) distinct programs
        (tests/test_daemon.py property-tests this with the jit cache).
        Every answer is read from one immutable snapshot — a concurrent
        ``tick`` can flip the pointer mid-drain and in-flight dispatches
        still see their snapshot's buffers.
        """
        answers: list[QueryAnswer] = []
        while self._queries:
            snap = self._snap  # one snapshot per dispatch
            batch = [self._queries.popleft()]
            rows = batch[0][1].shape[0]
            while (
                self._queries
                and rows + self._queries[0][1].shape[0]
                <= self.config.max_batch_rows
            ):
                nxt = self._queries.popleft()
                batch.append(nxt)
                rows += nxt[1].shape[0]
            self._pending_rows -= rows
            xq = np.concatenate([b[1] for b in batch], axis=0)
            q_pad = bucket_rows(rows)
            if q_pad > rows:  # padded rows are sliced off below: exact
                xq = np.concatenate(
                    [xq, np.repeat(xq[-1:], q_pad - rows, axis=0)], axis=0
                )
            self.buckets_hit.add(q_pad)
            t0 = time.perf_counter()
            out = fusion.fuse(
                snap.problem, snap.state, xq, "knn",
                k=self.config.k, engine=self.config.engine,
                plan=snap.plan, ecoef=snap.ecoef,
                compute_dtype=self._compute_dtype, prune=snap.keep,
            )
            out.block_until_ready()
            done = time.perf_counter()
            dt = done - t0
            self._ema_batch_s = (
                dt if self._ema_batch_s is None
                else 0.8 * self._ema_batch_s + 0.2 * dt
            )
            vals = np.asarray(out)
            off = 0
            for qid, grid, t_submit in batch:
                q = grid.shape[0]
                answers.append(QueryAnswer(
                    id=qid,
                    values=vals[:, off:off + q],
                    version=snap.version,
                    degraded=self.degraded,
                    latency_s=done - t_submit,
                ))
                off += q
            self.served += len(batch)
        return answers

    # -- trainer-side inputs -----------------------------------------------

    def offer_arrivals(self, fields, sensors, xs, ys) -> None:
        """Queue measurement arrivals for the next training ticks."""
        fields = np.asarray(fields).reshape(-1)
        sensors = np.asarray(sensors).reshape(-1)
        xs = np.atleast_2d(np.asarray(xs))
        ys = np.asarray(ys).reshape(-1)
        for f, s, x, y in zip(fields, sensors, xs, ys):
            self._arrivals.append((int(f), int(s), x, float(y)))

    def offer_join(self, x, ys, lam: float) -> None:
        """Queue a sensor join (position x, per-field targets ys)."""
        self._events.append(("join", np.asarray(x), np.asarray(ys), lam))

    def offer_leave(self, slot: int) -> None:
        """Queue a sensor leave by row slot."""
        self._events.append(("leave", int(slot)))

    def set_fault_model(self, model: faults_mod.FaultModel) -> None:
        """Swap the training fault process (degraded-mode drills).

        The model's rates are traced operands of the already-compiled
        training programs, so a drill changes VALUES only — zero
        recompiles (the bench counts the caches to prove it).
        """
        if model.has_crash != self._model.has_crash:
            raise ValueError(
                "crash-model structure is static (dispatches a different "
                "program); construct the daemon with the crash model"
            )
        self._model = model

    # -- training tick -----------------------------------------------------

    def _apply_events(self, problem, state, plan):
        joins = leaves = 0
        while self._events:
            ev = self._events.popleft()
            if ev[0] == "join":
                _, x, ys, lam = ev
                problem, state, rcpt = streaming.add_sensor(
                    problem, state, x, ys, lam=lam, donate=False,
                )
                if bool(rcpt.joined):
                    plan, _ = plan_add_sensor(plan, x, rcpt.slot)
                    joins += 1
            else:
                _, slot = ev
                problem, state, ok = streaming.remove_sensor(
                    problem, state, slot, donate=False,
                )
                plan = plan_remove_sensor(plan, slot)
                leaves += int(bool(ok))
        return problem, state, plan, joins, leaves

    def _absorb_pending(self, problem, state):
        """Drain queued arrivals in bucketed windows (O(log A) programs).

        Full windows run at exactly ``arrival_rows``; the final partial
        window pads to its power-of-two bucket with sentinel-row no-op
        arrivals (``streaming.pad_arrivals`` — bitwise-inert by the
        dead-sensor gates), so any arrival-traffic shape reuses a bounded
        program set.
        """
        absorbed = dropped = 0
        w = self.config.arrival_rows
        while self._arrivals:
            take = min(len(self._arrivals), w)
            window = [self._arrivals.popleft() for _ in range(take)]
            fs = np.array([a[0] for a in window], np.int32)
            ss = np.array([a[1] for a in window], np.int32)
            xs = np.stack([a[2] for a in window]).astype(
                problem.nbr_pos.dtype, copy=False
            )
            ys = np.array([a[3] for a in window])
            a_pad = take if take == w else min(bucket_rows(take), w)
            fs, ss, xs, ys, real = streaming.pad_arrivals(
                problem, fs, ss, xs, ys, a_pad
            )
            # donate=False ALWAYS: right after a publish the working pair
            # aliases the published snapshot's buffers — donating them
            # would delete the arrays queries are still reading.
            problem, state, rec = streaming.absorb_many(
                problem, state, fs, ss, xs, ys,
                donate=False, on_full=self.config.on_full,
            )
            ok = np.asarray(rec.absorbed)[real]
            absorbed += int(ok.sum())
            dropped += int((~ok).sum())
        return problem, state, absorbed, dropped

    def tick(self) -> TickReceipt:
        """One supervised training advance; publishes when healthy.

        Order: churn events -> arrival absorbs -> ``watch_sweeps`` under
        the current fault model.  A healthy tick publishes a fresh
        snapshot (pointer flip) and optionally checkpoints it.  A tick
        whose watchdog rolled back restores the working copy from the
        PUBLISHED snapshot — the trainer recovers from last-good while
        queries never left it; a diverged-but-not-rolled-back tick keeps
        its working state (it may recover next tick) but does not
        publish.  Either unhealthy outcome marks the daemon degraded.
        """
        cfg = self.config
        problem, state = self._work
        plan = self._plan
        problem, state, plan, joins, leaves = self._apply_events(
            problem, state, plan
        )
        problem, state, absorbed, arrival_drops = self._absorb_pending(
            problem, state
        )
        self._key, sub = jax.random.split(self._key)
        problem, state, receipt = monitor.watch_sweeps(
            problem, state, model=self._model, key=sub,
            engine=cfg.train_engine, config=self._watch_cfg,
        )
        self.tick_count += 1
        arrivals_rolled_back = 0
        ckpt_step = None
        if receipt.rolled_back:
            # watch_sweeps restored its entry state (post-absorb) bitwise,
            # but that state is what diverged past recovery — fall back to
            # the last PUBLISHED snapshot, losing this tick's inputs
            # (counted, not silent).
            snap = self._snap
            problem, state, plan = snap.problem, snap.state, snap.plan
            arrivals_rolled_back = absorbed
            absorbed = 0
            joins = leaves = 0
            self.degraded = True
            published = False
        elif bool(np.asarray(receipt.diverged).any()):
            self.degraded = True  # keep training state; serve last good
            published = False
        else:
            self.degraded = False
            published = True
            self._snap = self._make_snapshot(
                self._snap.version + 1, problem, state, plan
            )
            if (
                cfg.ckpt_every
                and cfg.snapshot_dir is not None
                and self.tick_count % cfg.ckpt_every == 0
            ):
                from repro import checkpoint as ckpt

                ckpt.save_train(
                    cfg.snapshot_dir, self.tick_count, problem, state
                )
                ckpt_step = self.tick_count
        self._work = (problem, state)
        self._plan = plan
        self.last_tick = TickReceipt(
            tick=self.tick_count,
            published=published,
            degraded=self.degraded,
            version=self._snap.version,
            absorbed=absorbed,
            arrival_drops=arrival_drops,
            arrivals_rolled_back=arrivals_rolled_back,
            joins=joins,
            leaves=leaves,
            watchdog=receipt,
            ckpt_step=ckpt_step,
        )
        return self.last_tick

    # -- health ------------------------------------------------------------

    def health(self) -> dict:
        """Machine-readable health endpoint (plain-JSON types only)."""
        t = self.last_tick
        return {
            "schema": "daemon_health/1",
            "version": int(self._snap.version),
            "degraded": bool(self.degraded),
            "ticks": int(self.tick_count),
            "served": int(self.served),
            "shed": int(self.shed),
            "queue_rows": int(self._pending_rows),
            "queued_arrivals": len(self._arrivals),
            "restored_step": self.restored_step,
            "serve_dtype": self.config.serve_dtype,
            "energy_tau": float(self._energy_tau),
            "pruned": int(self._snap.pruned),
            "last_tick": None if t is None else {
                "tick": t.tick,
                "published": t.published,
                "absorbed": t.absorbed,
                "arrival_drops": t.arrival_drops,
                "arrivals_rolled_back": t.arrivals_rolled_back,
                "joins": t.joins,
                "leaves": t.leaves,
                "ckpt_step": t.ckpt_step,
                "watchdog": t.watchdog.to_json(),
            },
        }

    def state_digest(self) -> str:
        """sha256 of the PUBLISHED snapshot's leaves (bitwise identity)."""
        return _state_digest(self._snap.problem, self._snap.state)


# ---------------------------------------------------------------------------
# CLI: the real long-lived process (and the CI kill/warm-restart smoke)
# ---------------------------------------------------------------------------


def _build_problem(args):
    """Deterministic problem build shared by cold start AND warm restart.

    Everything derives from ``--seed``; a restarted process rebuilds the
    same shapes/statics as templates and ``checkpoint.restore_train``
    replaces the array leaves bitwise.
    """
    from repro.core import Kernel, build_topology, init_state, \
        make_batch_problem, uniform_sensors

    rng = np.random.default_rng(args.seed)
    pos = uniform_sensors(args.sensors, seed=args.seed)
    freq = rng.uniform(0.5, 2.0, size=(args.fields, 1))
    phase = rng.uniform(0, 2 * np.pi, size=(args.fields, 1))
    ys = (
        np.sin(np.pi * freq * pos[None, :, 0] + phase)
        + 0.1 * rng.normal(size=(args.fields, args.sensors))
    ).astype(np.float32)
    topo = build_topology(pos, args.radius)
    per_sensor = -(-max(args.arrivals_per_tick, 1) // args.sensors) + 4
    deg_max = int(np.asarray(topo.degrees).max()) + per_sensor
    topo = build_topology(pos, args.radius, d_max=deg_max)
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=args.gamma), ys,
        jnp.full((args.sensors,), args.lam),
    )
    return pos, prob, init_state(prob), rng


def _probe_grid(args):
    xq = np.linspace(-0.9, 0.9, args.probe_points)[:, None].astype(np.float32)
    return xq


def main(argv=None):
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fields", type=int, default=4)
    ap.add_argument("--sensors", type=int, default=40)
    ap.add_argument("--radius", type=float, default=0.5)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--engine", default="plan", choices=["plan", "pallas"])
    ap.add_argument("--serve-dtype", default="f32", choices=["f32", "bf16"],
                    help="anchor-table storage dtype (bf16 rounds stored "
                         "anchors only; selection and accumulation stay "
                         "full precision)")
    ap.add_argument("--energy-tau", type=float, default=0.0,
                    help="representer-pruning energy threshold, re-derived "
                         "per publish (0 = off)")
    ap.add_argument("--ticks", type=int, default=10,
                    help="training ticks to run (0: restart-verify only)")
    ap.add_argument("--queries-per-tick", type=int, default=2)
    ap.add_argument("--query-rows", type=int, default=48)
    ap.add_argument("--arrivals-per-tick", type=int, default=8)
    ap.add_argument("--sweeps-per-tick", type=int, default=5)
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--faults", default="",
                    help="fault spec for training ticks (core.faults "
                         "syntax, e.g. drop=0.1)")
    ap.add_argument("--probe-points", type=int, default=32)
    ap.add_argument("--tick-sleep", type=float, default=0.0,
                    help="seconds to sleep between ticks (makes a "
                         "mid-run SIGKILL land mid-stream in CI)")
    ap.add_argument("--verify-restart", action="store_true",
                    help="after warm restart, assert the restored "
                         "snapshot matches the last checkpoint's probe "
                         "answers + state digest bitwise, then exit")
    args = ap.parse_args(argv)

    pos, prob, state, rng = _build_problem(args)
    cfg = DaemonConfig(
        k=args.k, engine=args.engine,
        sweeps_per_tick=args.sweeps_per_tick,
        ckpt_every=args.ckpt_every, snapshot_dir=args.snapshot_dir,
        serve_dtype=args.serve_dtype, energy_tau=args.energy_tau,
    )
    model = (
        faults_mod.parse_fault_spec(args.faults, dtype=state.z.dtype)
        if args.faults else None
    )
    if model is not None and model.has_crash:
        d = Daemon(prob, state, config=cfg, fault_model=model)
    else:
        d = Daemon(prob, state, config=cfg)
        if model is not None:
            d.set_fault_model(model)
    if d.restored_step is not None:
        print(f"warm restart: restored step {d.restored_step} from "
              f"{args.snapshot_dir}")

    probe = _probe_grid(args)

    def probe_answers():
        snap = d.snapshot
        out = fusion.fuse(
            snap.problem, snap.state, probe, "knn", k=args.k,
            engine=args.engine, plan=snap.plan, ecoef=snap.ecoef,
            compute_dtype=(None if snap.serve_dtype == "f32"
                           else snap.serve_dtype),
            prune=snap.keep,
        )
        return np.asarray(out)

    if args.verify_restart:
        if d.restored_step is None:
            raise SystemExit("--verify-restart: no intact checkpoint found")
        path = os.path.join(
            args.snapshot_dir, f"probe_{d.restored_step:08d}.npz"
        )
        ref = np.load(path)
        assert ref["digest"] == d.state_digest(), (
            "restored state digest mismatch (not bitwise)"
        )
        got = probe_answers()
        assert np.array_equal(got, ref["answers"]), (
            "served probe answers differ from the pre-kill snapshot"
        )
        print(f"warm restart verified: step {d.restored_step} bitwise "
              f"(digest + {probe.shape[0]}-point probe answers)")
        return

    for i in range(args.ticks):
        for _ in range(args.queries_per_tick):
            q = int(rng.integers(1, args.query_rows + 1))
            d.submit(rng.uniform(-0.9, 0.9, size=(q, pos.shape[1]))
                     .astype(np.float32))
        a = args.arrivals_per_tick
        if a:
            ss = rng.integers(0, args.sensors, size=a)
            d.offer_arrivals(
                rng.integers(0, args.fields, size=a), ss,
                (pos[ss] + 0.05 * rng.normal(size=(a, pos.shape[1])))
                .astype(np.float32),
                rng.normal(size=a).astype(np.float32),
            )
        d.pump()
        rcpt = d.tick()
        if rcpt.ckpt_step is not None and args.snapshot_dir:
            # probe file rides NEXT TO the checkpoint: the restart smoke
            # compares restored serving output against it bitwise
            np.savez(
                os.path.join(
                    args.snapshot_dir, f"probe_{rcpt.ckpt_step:08d}.npz"
                ),
                answers=probe_answers(),
                digest=np.asarray(d.state_digest()),
            )
        print(json.dumps(d.health()), flush=True)
        if args.tick_sleep:
            time.sleep(args.tick_sleep)


if __name__ == "__main__":
    main()
