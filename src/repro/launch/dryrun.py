import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, extract memory / cost / collective analyses, and emit
the per-combo JSON that EXPERIMENTS.md §Dry-run / §Roofline read from.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-370m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]

Method notes (DESIGN.md Sec. 7):
  * The FULL scanned model is lowered+compiled — that is the pass/fail proof
    that the sharding config is coherent (and the source of
    memory_analysis()).
  * XLA's HloCostAnalysis visits a while body ONCE regardless of trip count,
    so FLOPs/bytes/collective-bytes for the roofline are extracted from two
    small UNROLLED variants (1 super-block and 2 super-blocks) and
    extrapolated linearly:  total = c1 + (n_blocks - 1) * (c2 - c1).
    This is exact because every super-block is structurally identical.
  * Collective bytes are parsed from compiled.as_text(): the summed output
    sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute ops.
"""

import argparse
import dataclasses
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_config, input_specs, supports_shape
from repro.models import init_params, loss_fn
from repro.models import model as M
from repro.optim import adamw, apply_updates, cosine_warmup
from repro.sharding import batch_pspecs, cache_pspecs, opt_state_pspecs, param_pspecs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective op kind over the whole module."""
    out = {k: 0 for k in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for op in _COLL_OPS:
            # match `op(` or `op-start(` but not `op-done(`
            m = re.search(rf"\s{op}(-start)?\(", line)
            if m:
                lhs = line.split(f" {op}", 1)[0]
                out[op] += _shape_bytes(lhs)
                out["count"] += 1
                break
    return out


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def build_train(cfg, mesh, shape_name):
    opt = adamw(cosine_warmup(3e-4, 100, 10000))
    abstract_params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(cfg, abstract_params, mesh)
    abstract_opt = jax.eval_shape(opt.init, abstract_params)
    ospecs = opt_state_pspecs(cfg, abstract_opt, pspecs)
    batch = input_specs(cfg, shape_name)
    bspecs = batch_pspecs(cfg, batch, mesh)

    grad_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        if cfg.fsdp:
            # ZeRO-style: force gradients onto the parameter sharding so the
            # partitioner emits reduce-scatter instead of full all-reduce
            # before the (sharded) optimizer update (§Perf H2 iterC).
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    abstract_m = jax.eval_shape(train_step, abstract_params, abstract_opt, batch)[2]
    mspecs = jax.tree.map(lambda _: P(), abstract_m)
    args = (abstract_params, abstract_opt, batch)
    in_s = (pspecs, ospecs, bspecs)
    out_s = (pspecs, ospecs, mspecs)
    return train_step, args, in_s, out_s


def build_prefill(cfg, mesh, shape_name):
    abstract_params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(cfg, abstract_params, mesh)
    batch = input_specs(cfg, shape_name)
    bspecs = batch_pspecs(cfg, batch, mesh)
    shape = SHAPES[shape_name]
    b = shape.global_batch

    def prefill_step(params, batch):
        cache = M.init_cache(cfg, b, shape.seq_len)
        logits, cache = M.prefill(cfg, params, batch, cache)
        return logits, cache

    abstract_out = jax.eval_shape(prefill_step, abstract_params, batch)
    logits_spec = (
        None if abstract_out[0] is None else P(("pod", "data") if "pod" in mesh.shape else ("data",))
    )
    cspecs = cache_pspecs(cfg, abstract_out[1], mesh)
    args = (abstract_params, batch)
    return prefill_step, args, (pspecs, bspecs), (logits_spec, cspecs)


def build_decode(cfg, mesh, shape_name):
    abstract_params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_pspecs(cfg, abstract_params, mesh)
    spec = input_specs(cfg, shape_name)
    cspecs = cache_pspecs(cfg, spec["cache"], mesh)
    tok_spec = batch_pspecs(cfg, {"t": spec["token"]}, mesh)["t"]
    shape = SHAPES[shape_name]

    def serve_step(params, token, cache, position):
        logits, cache = M.decode_step(cfg, params, token, cache, position)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    args = (abstract_params, spec["token"], spec["cache"], spec["position"])
    in_s = (pspecs, tok_spec, cspecs, P())
    out_s = (tok_spec, cspecs)
    return serve_step, args, in_s, out_s


def build_step(cfg, mesh, shape_name):
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return build_train(cfg, mesh, shape_name)
    if kind == "prefill":
        return build_prefill(cfg, mesh, shape_name)
    return build_decode(cfg, mesh, shape_name)


# ---------------------------------------------------------------------------
# Lower / compile / analyze
# ---------------------------------------------------------------------------


def _as_shardings(mesh, tree):
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def lower_and_compile(cfg, mesh, shape_name):
    fn, args, in_s, out_s = build_step(cfg, mesh, shape_name)
    jitted = jax.jit(fn, in_shardings=_as_shardings(mesh, in_s), out_shardings=_as_shardings(mesh, out_s))
    t0 = time.time()
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    return compiled, time.time() - t0


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _scale_cfg(cfg, k: int):
    """k super-blocks, unrolled (whisper scales encoder layers too)."""
    over = dict(n_layers=k * cfg.block_len, unroll=True)
    if cfg.is_encoder_decoder:
        over["n_encoder_layers"] = k
    return dataclasses.replace(cfg, **over)


def extrapolated_costs(cfg, mesh, shape_name) -> dict:
    """Exact per-device roofline quantities via 1- vs 2-block unrolled compiles."""
    c1, _ = lower_and_compile(_scale_cfg(cfg, 1), mesh, shape_name)
    c2, _ = lower_and_compile(_scale_cfg(cfg, 2), mesh, shape_name)
    d1, d2 = _cost_dict(c1), _cost_dict(c2)
    n = cfg.n_blocks if not cfg.is_encoder_decoder else cfg.n_layers

    def ext(a, b):
        return a + (n - 1) * (b - a)

    coll = {
        k: int(max(0, ext(d1["coll"][k], d2["coll"][k]))) for k in _COLL_OPS
    }
    coll["count"] = int(ext(d1["coll"]["count"], d2["coll"]["count"]))
    return {
        "flops": max(0.0, ext(d1["flops"], d2["flops"])),
        "bytes": max(0.0, ext(d1["bytes"], d2["bytes"])),
        "coll": coll,
        "base": d1,
        "per_block": {
            "flops": d2["flops"] - d1["flops"],
            "bytes": d2["bytes"] - d1["bytes"],
        },
    }


def roofline_terms(costs: dict, n_chips: int, cfg, shape_name) -> dict:
    """Seconds per step for the three roofline terms (per-device program)."""
    coll_total = sum(costs["coll"][k] for k in _COLL_OPS)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        mult = 3.0  # fwd + bwd
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        mult = 1.0
    else:
        tokens = shape.global_batch  # one token per sequence
        mult = 1.0
    model_flops = 2.0 * mult * cfg.n_active_params() * tokens  # 6ND for train
    t_compute = costs["flops"] / PEAK_FLOPS_BF16
    t_memory = costs["bytes"] / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / costs["flops"] if costs["flops"] else 0.0,
        "collective_bytes": coll_total,
    }


def apply_overrides(cfg, overrides: list[str]):
    """--set key=value config overrides (ints/floats/bools auto-coerced)."""
    if not overrides:
        return cfg
    kv = {}
    for item in overrides:
        k, v = item.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kv[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            kv[k] = int(v)
        elif isinstance(cur, float):
            kv[k] = float(v)
        else:
            kv[k] = v
    return dataclasses.replace(cfg, **kv)


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str, *,
            skip_existing=False, overrides: list[str] | None = None) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    if not supports_shape(arch, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "skipped": True,
               "reason": "enc-dec has no long-context decode analogue (DESIGN.md §5)"}
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    variant = "long" if shape_name == "long_500k" else None
    cfg = apply_overrides(get_config(arch, variant=variant), overrides or [])
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    t0 = time.time()
    compiled, compile_s = lower_and_compile(cfg, mesh, shape_name)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
    }
    full_coll = collective_bytes(compiled.as_text())
    del compiled

    costs = extrapolated_costs(cfg, mesh, shape_name)
    roof = roofline_terms(costs, n_chips, cfg, shape_name)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "family": cfg.family,
        "params": cfg.n_params(),
        "active_params": cfg.n_active_params(),
        "compile_s": round(compile_s, 1),
        "total_s": round(time.time() - t0, 1),
        "memory": mem,
        "flops_per_chip": costs["flops"],
        "bytes_per_chip": costs["bytes"],
        "collectives": costs["coll"],
        "full_compile_collectives_raw": full_coll,
        "roofline": roof,
        "sliding_window": cfg.sliding_window,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="config override key=value (hillclimbing)")
    args = ap.parse_args()

    combos = []
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    failures = []
    for a, s, m in combos:
        tag = f"{a} x {s} x {'multipod' if m else 'pod'}"
        try:
            rec = run_one(a, s, m, args.out, skip_existing=args.skip_existing,
                          overrides=args.overrides)
            if rec.get("skipped"):
                print(f"[skip] {tag}: {rec['reason']}", flush=True)
            else:
                r = rec["roofline"]
                print(
                    f"[ok]   {tag}: compile={rec['compile_s']}s "
                    f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                    f"coll={r['collective_s']:.3e}s dominant={r['dominant']}",
                    flush=True,
                )
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures.append((tag, repr(e)))
            print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall combos lowered + compiled OK")


if __name__ == "__main__":
    main()
