#!/bin/sh
# Hardened launch environment for the serving processes (exec-style
# wrapper, after the HomebrewNLP run.sh pattern in SNIPPETS.md):
#
#   sh src/repro/launch/env.sh python -m repro.launch.serve --mode daemon ...
#
# Python twin: `python -m repro.launch.serve --hardened-env ...` re-execs
# itself under the same environment.  Everything is setdefault-style —
# values you exported beforehand win — and the tcmalloc preload is
# skipped (with a note) when the library is absent, so this wrapper is
# safe on any box.

# tcmalloc: long-lived serving churns many small host allocations; glibc
# malloc fragments under it.  Preload the first tcmalloc found.
if [ -z "${LD_PRELOAD:-}" ]; then
    for lib in \
        /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
        /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
        /usr/lib/libtcmalloc.so.4 \
        /usr/local/lib/libtcmalloc.so.4; do
        if [ -e "$lib" ]; then
            LD_PRELOAD="$lib"
            export LD_PRELOAD
            break
        fi
    done
    if [ -z "${LD_PRELOAD:-}" ]; then
        echo "env.sh: tcmalloc absent, preload skipped" >&2
    fi
fi

# Don't report individual large allocations below 60 GB — snapshot
# buffers at serving batch sizes trip the default threshold constantly.
: "${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:=60000000000}"
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD

# Keep XLA/TF C++ logging off the serving stdout (the daemon prints
# line-oriented JSON health there).
: "${TF_CPP_MIN_LOG_LEVEL:=4}"
export TF_CPP_MIN_LOG_LEVEL

# One host platform device: serving dispatches must never be sharded
# across virtual CPU devices (tests that WANT multiple set XLA_FLAGS
# themselves, which wins over this default).
: "${XLA_FLAGS:=--xla_force_host_platform_device_count=1}"
export XLA_FLAGS

exec "$@"
