"""Production mesh construction (functions only — importing this module
never touches jax device state)."""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256-chip v5e pod) or 2x16x16 (2 pods, 512 chips).

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The dry-run forces xla_force_host_platform_device_count=512 before any
    jax import so this works on the CPU container.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples on CPU hosts)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return compat.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (conservative single-link figure)
HBM_BYTES = 16 * 2**30  # 16 GiB
