"""Serving launcher.

Two workloads:

  * ``--mode lm``    — batched greedy decoding against a KV/SSM cache.
  * ``--mode field`` — multi-field sensor regression: B independent fields
                       over one network are trained with the batched SN-Train
                       engine, streaming arrivals are absorbed in ONE batched
                       dispatch (``streaming.absorb_many``, rank-1 Cholesky
                       updates under a lax.scan), and queries are answered
                       per request grid by the selected fusion rule:
                       ``--fusion conn`` collapses to global coefficients +
                       one fused batched Pallas kernel matvec;
                       ``--fusion knn`` (paper Eq. 19) routes through the
                       static cell-candidate query plan
                       (``core.serving.make_serving_plan``) with
                       ``--engine {plan,pallas,dense}``.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
    --variant smoke --batch 4 --prompt_len 32 --gen 64
  PYTHONPATH=src python -m repro.launch.serve --mode field \
    --fields 64 --sensors 50 --sweeps 30 --stream 128 --queries 512 \
    --fusion knn --k 3 --engine plan
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import decode_step, init_cache, init_params, prefill


def serve_lm(args):
    cfg = get_config(args.arch, variant=None if args.variant == "full" else "smoke")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M")

    b, s0 = args.batch, args.prompt_len
    max_seq = s0 + args.gen + 1
    prompt = jax.random.randint(key, (b, s0), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.is_encoder_decoder:
        batch = {"frames": jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))}
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))

    cache = init_cache(cfg, b, max_seq)
    jpre = jax.jit(lambda p, bt, c: prefill(cfg, p, bt, c))
    jdec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))

    t0 = time.time()
    logits, cache = jpre(params, batch, cache)
    if logits is None:
        tok = jnp.zeros((b, 1), jnp.int32)
        pos0 = 0
    else:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        pos0 = s0
    print(f"prefill: {time.time()-t0:.2f}s ({b}x{s0} tokens)")

    out = []
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = jdec(params, tok, cache, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"decode: {args.gen} steps in {dt:.2f}s -> {b*args.gen/dt:.1f} tok/s")
    print("sample row 0:", jax.device_get(seq[0])[:24].tolist())


def serve_fields(args):
    import numpy as np

    from repro.core import (
        Kernel,
        build_topology,
        colored_sweep,
        fusion,
        init_state,
        make_batch_problem,
        make_serving_plan,
        streaming,
        uniform_sensors,
    )
    from repro.kernels import kernel_matvec

    b, n = args.fields, args.sensors
    rng = np.random.default_rng(args.seed)
    pos = uniform_sensors(n, seed=args.seed)
    # Per-field targets: random-frequency/phase sinusoids + noise.
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    phase = rng.uniform(0, 2 * np.pi, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0] + phase) + 0.3 * rng.normal(size=(b, n))

    topo = build_topology(pos, args.radius)
    if args.stream:
        # headroom: streaming arrivals occupy free neighborhood slots
        per_sensor = -(-args.stream // n) + 4
        deg_max = int(np.asarray(topo.degrees).max()) + per_sensor
        topo = build_topology(pos, args.radius, d_max=deg_max)
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=args.gamma), ys, jnp.full((n,), args.lam)
    )
    state = init_state(prob)
    print(
        f"fields={b} sensors={n} D={topo.d_max} colors={topo.n_colors} "
        f"stream_capacity={prob.n_stream}"
    )

    # -- train: batched colored sweeps -------------------------------------
    # warm with the SAME n_sweeps: it is a static jit arg, so a different
    # value would compile a different program and the timing would include it
    colored_sweep(prob, state, n_sweeps=args.sweeps).z.block_until_ready()
    t0 = time.time()
    state = colored_sweep(prob, state, n_sweeps=args.sweeps)
    state.z.block_until_ready()
    dt = time.time() - t0
    print(f"train: {args.sweeps} sweeps x {b} fields in {dt:.3f}s -> {b/dt:.1f} fields/s")

    # -- streaming: batched absorb, ONE dispatch per arrival window --------
    if args.stream:
        # Two equal arrival windows (plus a single-arrival remainder when
        # --stream is odd, so exactly args.stream arrivals are absorbed):
        # the first window compiles the scan-based absorb_many program (A is
        # a static shape), the second reuses it, so the reported ms/update
        # is one warm dispatch over A arrivals — not A host round-trips.
        half = args.stream // 2

        def window(a):
            fs = rng.integers(0, b, size=a)
            ss = rng.integers(0, n, size=a)
            xs = (
                pos[ss] + 0.05 * rng.normal(size=(a, pos.shape[1]))
            ).astype(np.float32)
            return fs, ss, xs, rng.normal(size=a).astype(np.float32)

        flags = []
        if args.stream % 2:
            fs, ss, xs, vs = window(1)
            prob, state, ok = streaming.absorb(
                prob, state, int(fs[0]), int(ss[0]), xs[0], float(vs[0]),
                donate=True,
            )
            flags.append(jnp.reshape(ok, (1,)))
        dt = None
        if half:
            prob, state, flags0 = streaming.absorb_many(
                prob, state, *window(half), donate=True
            )
            timed_window = window(half)  # generated before the clock starts
            jax.block_until_ready(prob.chol)
            t0 = time.time()
            prob, state, flags1 = streaming.absorb_many(
                prob, state, *timed_window, donate=True
            )
            jax.block_until_ready(prob.chol)
            dt = time.time() - t0
            flags += [flags0, flags1]
        # the flags vector keeps the reported count honest about drops
        absorbed = int(jnp.sum(jnp.concatenate(flags)))
        dropped = args.stream - absorbed
        drop_note = f" ({dropped} over-capacity arrivals dropped)" if dropped else ""
        timing = (
            f", timed window of {half} in one dispatch: {dt:.3f}s -> "
            f"{dt/half*1e3:.3f} ms/update" if dt is not None else ""
        )
        print(f"stream: {absorbed} updates{timing}{drop_note}")
        state = colored_sweep(prob, state, n_sweeps=args.refresh_sweeps)

    # -- query: one dispatch per request grid ------------------------------
    xq = np.linspace(-1, 1, args.queries)[:, None].astype(np.float32)
    if pos.shape[1] > 1:
        xq = np.concatenate([xq] + [np.zeros_like(xq)] * (pos.shape[1] - 1), axis=1)
    if args.fusion == "knn":
        # kNN fusion (paper Eq. 19); plan/pallas route through the static
        # query plan — per-cell candidate lists, O(Q*k*D) per field instead
        # of O(Q*n*D) — while dense runs the all-sensors oracle.
        plan = (
            None if args.engine == "dense"
            else make_serving_plan(prob, k=args.k)
        )
        run = lambda: fusion.fuse(
            prob, state, xq, "knn", k=args.k, engine=args.engine, plan=plan
        )
        note = f"knn k={args.k} engine={args.engine}"
        if plan is not None:
            note += f" (plan: {plan.n_cells} cells, K_max={plan.k_max})"
    else:
        # conn fusion (Eq. 20) collapses to one batched Pallas kernel matvec
        anchors, coefs = fusion.global_coefficients(prob, state, rule="conn")
        run = lambda: kernel_matvec(xq, anchors, coefs, gamma=args.gamma)
        note = "conn (global coefficients + fused matvec)"
    out = run()
    out.block_until_ready()
    t0 = time.time()
    out = run()
    out.block_until_ready()
    dt = time.time() - t0
    print(
        f"query[{note}]: {args.queries} points x {b} fields in {dt*1e3:.2f}ms "
        f"-> {args.queries*b/dt:.0f} field-queries/s"
    )
    print("sample field 0:", np.asarray(out[0, :6]).round(3).tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "field"])
    # lm mode
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_NAMES)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    # field mode
    ap.add_argument("--fields", type=int, default=64, help="B concurrent fields")
    ap.add_argument("--sensors", type=int, default=50)
    ap.add_argument("--radius", type=float, default=0.8)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--sweeps", type=int, default=30)
    ap.add_argument("--refresh_sweeps", type=int, default=5)
    ap.add_argument("--stream", type=int, default=0, help="streaming arrivals to absorb")
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--fusion", default="conn", choices=["conn", "knn"],
                    help="query fusion rule (knn routes through the query plan)")
    ap.add_argument("--k", type=int, default=3, help="kNN order for --fusion knn")
    ap.add_argument("--engine", default="plan", choices=["dense", "plan", "pallas"],
                    help="kNN serving engine for --fusion knn")
    args = ap.parse_args()
    if args.mode == "field":
        serve_fields(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
