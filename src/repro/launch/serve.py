"""Serving launcher.

Three workloads:

  * ``--mode lm``     — batched greedy decoding against a KV/SSM cache.
  * ``--mode daemon`` — the long-lived serving loop of
                       ``repro.launch.daemon``: coalesced bucketed
                       queries against a double-buffered snapshot while
                       supervised training ticks (watchdog + checkpoints
                       + fault drills) run behind it.  All other flags
                       are the daemon's own
                       (``python -m repro.launch.daemon --help``).
  * ``--mode field`` — multi-field sensor regression: B independent fields
                       over one network are trained with the batched SN-Train
                       engine, streaming arrivals are absorbed in ONE batched
                       dispatch (``streaming.absorb_many``, rank-1 Cholesky
                       updates under a lax.scan), and queries are answered
                       per request grid by the selected fusion rule:
                       ``--fusion conn`` collapses to global coefficients +
                       one fused batched Pallas kernel matvec;
                       ``--fusion knn`` (paper Eq. 19) routes through the
                       static cell-candidate query plan
                       (``core.serving.make_serving_plan``) with
                       ``--engine {plan,pallas,dense}``.
                       ``--churn N`` additionally replays a membership churn
                       trace (SYMMETRIC sensor joins/leaves via
                       ``streaming.add_sensor`` / ``remove_sensor``: adopters
                       grow reciprocal anchor lanes, conflicting adopters are
                       recolored on device, and every event repairs only the
                       O(degree) affected rows) interleaved with arrival
                       windows, refresh sweeps and query rounds — all at the
                       fixed ``n_max`` capacity, so the whole trace compiles
                       a constant number of programs (the report prints the
                       jit-cache growth after warmup; it should be 0).
                       ``--faults drop=P[,burst=..][,crash=..]`` replays
                       training over unreliable links: every message draw
                       comes from the seeded ``core.faults`` process
                       (i.i.d. drops, Gilbert–Elliott bursts, crash/restart
                       schedules) and the ``core.monitor`` watchdog
                       supervises each round — retrying poisoned rounds
                       with fresh draws, refactorizing once, rolling back
                       bitwise if divergence persists — and its receipt is
                       printed.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
    --variant smoke --batch 4 --prompt_len 32 --gen 64
  PYTHONPATH=src python -m repro.launch.serve --mode field \
    --fields 64 --sensors 50 --sweeps 30 --stream 128 --queries 512 \
    --fusion knn --k 3 --engine plan
  PYTHONPATH=src python -m repro.launch.serve --mode field \
    --fields 16 --sensors 100 --stream 64 --churn 12 --spares 8 \
    --fusion knn --k 3 --engine plan
  PYTHONPATH=src python -m repro.launch.serve --mode field \
    --fields 8 --sensors 60 --sweeps 150 \
    --faults drop=0.1,burst=0.05:0.4:0.5
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import decode_step, init_cache, init_params, prefill

# Hardened launch environment (the HomebrewNLP run.sh pattern, see
# SNIPPETS.md): tcmalloc beats glibc malloc under the daemon's sustained
# small-allocation churn, the TCMALLOC threshold silences its large-alloc
# warnings at serving batch sizes, TF_CPP_MIN_LOG_LEVEL keeps XLA's C++
# logging off the serving stdout, and the XLA flag pins one host device so
# serving never shards a query dispatch across virtual CPU devices.  The
# shell twin is launch/env.sh (exec-style wrapper); both skip gracefully
# when tcmalloc is absent.
_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/local/lib/libtcmalloc.so.4",
)
_HARDENED_GUARD = "_REPRO_HARDENED_ENV"


def hardened_env(base=None) -> tuple[dict, list[str]]:
    """Build the hardened serving environment; returns (env, notes).

    Never overrides values the caller already exported (setdefault
    semantics), and skips the tcmalloc preload with a note — not an error
    — when no known library path exists.
    """
    env = dict(os.environ if base is None else base)
    notes = []
    lib = next((p for p in _TCMALLOC_PATHS if os.path.exists(p)), None)
    if lib is not None:
        pre = env.get("LD_PRELOAD", "")
        if lib not in pre.split(":"):
            env["LD_PRELOAD"] = f"{lib}:{pre}" if pre else lib
        env.setdefault(
            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000"
        )
        notes.append(f"tcmalloc={lib}")
    else:
        notes.append("tcmalloc absent (preload skipped)")
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")
    notes.append(f"XLA_FLAGS={env['XLA_FLAGS']!r}")
    return env, notes


def _reexec_hardened() -> None:
    """Replace this process with one running under the hardened env.

    LD_PRELOAD only takes effect at process start, so the flag re-execs
    the identical command line once (the guard variable stops the loop).
    """
    env, notes = hardened_env()
    env[_HARDENED_GUARD] = "1"
    print("hardened-env: " + "; ".join(notes), flush=True)
    os.execve(
        sys.executable,
        [sys.executable, "-m", "repro.launch.serve"] + sys.argv[1:],
        env,
    )


def serve_lm(args):
    cfg = get_config(args.arch, variant=None if args.variant == "full" else "smoke")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M")

    b, s0 = args.batch, args.prompt_len
    max_seq = s0 + args.gen + 1
    prompt = jax.random.randint(key, (b, s0), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.is_encoder_decoder:
        batch = {"frames": jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))}
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))

    cache = init_cache(cfg, b, max_seq)
    jpre = jax.jit(lambda p, bt, c: prefill(cfg, p, bt, c))
    jdec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))

    t0 = time.time()
    logits, cache = jpre(params, batch, cache)
    if logits is None:
        tok = jnp.zeros((b, 1), jnp.int32)
        pos0 = 0
    else:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        pos0 = s0
    print(f"prefill: {time.time()-t0:.2f}s ({b}x{s0} tokens)")

    out = []
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = jdec(params, tok, cache, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"decode: {args.gen} steps in {dt:.2f}s -> {b*args.gen/dt:.1f} tok/s")
    print("sample row 0:", jax.device_get(seq[0])[:24].tolist())


def serve_fields(args):
    import numpy as np

    from repro.core import (
        Kernel,
        build_topology,
        colored_sweep,
        fusion,
        init_state,
        make_batch_problem,
        make_serving_plan,
        streaming,
        uniform_sensors,
    )
    from repro.kernels import kernel_matvec

    b, n = args.fields, args.sensors
    rng = np.random.default_rng(args.seed)
    pos = uniform_sensors(n, seed=args.seed)
    # Per-field targets: random-frequency/phase sinusoids + noise.
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    phase = rng.uniform(0, 2 * np.pi, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0] + phase) + 0.3 * rng.normal(size=(b, n))

    topo = build_topology(pos, args.radius)
    if args.stream or args.churn:
        # headroom: streaming arrivals occupy free neighborhood slots,
        # joining sensors adopt them, and (symmetric joins) every adopting
        # neighbor spends one lane on its reciprocal anchor
        per_sensor = -(-max(args.stream, 1) // n) + 4 + (2 if args.churn else 0)
        deg_max = int(np.asarray(topo.degrees).max()) + per_sensor
        topo = build_topology(pos, args.radius, d_max=deg_max)
    n_max = n + args.spares if args.churn else None
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=args.gamma), ys, jnp.full((n,), args.lam),
        n_max=n_max, beta=args.beta,
    )
    state = init_state(prob)
    print(
        f"fields={b} sensors={n} (capacity {prob.n}) D={prob.topology.d_max} "
        f"colors={prob.topology.n_colors} stream_capacity={prob.n_stream}"
    )

    # -- train: batched colored sweeps -------------------------------------
    if args.faults:
        # Unreliable-link replay: train under the seeded fault process with
        # the convergence watchdog supervising every round (retry with fresh
        # draws -> refactorize -> bitwise rollback).  The fault rates are
        # traced operands, so the whole replay reuses the fault-free
        # programs — zero extra compiles.
        from repro.core import faults as faults_mod, monitor

        model = faults_mod.parse_fault_spec(args.faults, dtype=state.z.dtype)
        engine = "pallas" if args.engine == "pallas" else "plan"
        cfg = monitor.WatchdogConfig(
            sweeps_per_round=args.refresh_sweeps,
            tol=args.watch_tol,
            max_rounds=max(1, -(-args.sweeps // args.refresh_sweeps)),
        )
        t0 = time.time()
        prob, state, receipt = monitor.watch_sweeps(
            prob, state, model=model,
            key=jax.random.PRNGKey(args.seed + 1), engine=engine, config=cfg,
        )
        state.z.block_until_ready()
        dt = time.time() - t0
        print(
            f"train[faults {args.faults}, engine={engine}]: "
            f"{receipt.sweeps} supervised sweeps x {b} fields in {dt:.3f}s"
        )
        print(monitor.format_receipt(receipt))
        # machine-readable twin of the line above (stable schema; the
        # exact inverse is monitor.receipt_from_json)
        import json

        print("watchdog.json: " + json.dumps(receipt.to_json()))
    else:
        # warm with the SAME n_sweeps: it is a static jit arg, so a
        # different value would compile a different program and the timing
        # would include it
        colored_sweep(prob, state, n_sweeps=args.sweeps).z.block_until_ready()
        t0 = time.time()
        state = colored_sweep(prob, state, n_sweeps=args.sweeps)
        state.z.block_until_ready()
        dt = time.time() - t0
        print(
            f"train: {args.sweeps} sweeps x {b} fields in {dt:.3f}s "
            f"-> {b/dt:.1f} fields/s"
        )

    # -- streaming: batched absorb, ONE dispatch per arrival window --------
    if args.stream:
        # Two equal arrival windows (plus a single-arrival remainder when
        # --stream is odd, so exactly args.stream arrivals are absorbed):
        # the first window compiles the scan-based absorb_many program (A is
        # a static shape), the second reuses it, so the reported ms/update
        # is one warm dispatch over A arrivals — not A host round-trips.
        half = args.stream // 2

        def window(a):
            fs = rng.integers(0, b, size=a)
            ss = rng.integers(0, n, size=a)
            xs = (
                pos[ss] + 0.05 * rng.normal(size=(a, pos.shape[1]))
            ).astype(np.float32)
            return fs, ss, xs, rng.normal(size=a).astype(np.float32)

        absorbed_flags, evicted_flags = [], []
        if args.stream % 2:
            # via absorb_many so the remainder's receipt (incl. a possible
            # eviction) lands in the printed counts like everyone else's
            prob, state, rec = streaming.absorb_many(
                prob, state, *window(1), donate=True, on_full=args.on_full
            )
            absorbed_flags.append(rec.absorbed)
            evicted_flags.append(rec.evicted)
        dt = None
        if half:
            prob, state, rec0 = streaming.absorb_many(
                prob, state, *window(half), donate=True, on_full=args.on_full
            )
            timed_window = window(half)  # generated before the clock starts
            jax.block_until_ready(prob.chol)
            t0 = time.time()
            prob, state, rec1 = streaming.absorb_many(
                prob, state, *timed_window, donate=True, on_full=args.on_full
            )
            jax.block_until_ready(prob.chol)
            dt = time.time() - t0
            absorbed_flags += [rec0.absorbed, rec1.absorbed]
            evicted_flags += [rec0.evicted, rec1.evicted]
        # the receipt flags keep the reported counts honest about capacity
        # pressure: every arrival is absorbed, absorbed-after-evict, or
        # dropped — nothing disappears silently
        absorbed = int(jnp.sum(jnp.concatenate(absorbed_flags)))
        evicted = (
            int(jnp.sum(jnp.concatenate(evicted_flags)))
            if evicted_flags else 0
        )
        dropped = args.stream - absorbed
        pressure = (
            f" (capacity pressure: {dropped} dropped, {evicted} evicted)"
            if dropped or evicted else ""
        )
        timing = (
            f", timed window of {half} in one dispatch: {dt:.3f}s -> "
            f"{dt/half*1e3:.3f} ms/update" if dt is not None else ""
        )
        print(f"stream: {absorbed} absorbed{timing}{pressure}")
        state = colored_sweep(prob, state, n_sweeps=args.refresh_sweeps)

    # -- churn: replay a join/leave lifecycle trace at fixed capacity ------
    churn_plan = None
    if args.churn:
        from repro.core import add_sensor, remove_sensor
        from repro.core.serving import plan_add_sensor, plan_remove_sensor

        # Slack >= the worst-case removals keeps the repaired query plan's
        # kNN exactness bound valid across the whole trace.
        churn_plan = make_serving_plan(
            prob, k=args.k, spare=args.spares + 4, slack=args.churn
        )
        xq_c = np.linspace(-0.9, 0.9, 64)[:, None].astype(np.float32)
        if pos.shape[1] > 1:
            xq_c = np.concatenate(
                [xq_c] + [np.zeros_like(xq_c)] * (pos.shape[1] - 1), axis=1
            )
        stats = dict(joins=0, join_drops=0, leaves=0, cell_overflows=0,
                     absorbed=0, dropped=0, skipped_couplings=0,
                     dropped_newest=0)
        joined: list[int] = []

        def churn_round(prob, state, plan, i):
            x = rng.uniform(-0.9, 0.9, size=pos.shape[1]).astype(np.float32)
            prob, state, rcpt = add_sensor(
                prob, state, x, rng.normal(size=b).astype(np.float32),
                lam=args.lam, repair_lambda=args.repair_lambda, donate=True,
            )
            slot, ok = rcpt.slot, rcpt.joined
            # JoinReceipt fidelity counters: couplings lost to
            # lane-exhausted neighbors and newest arrivals orphaned by
            # reciprocal anchor-lane growth — capacity pressure that used
            # to be silent
            stats["skipped_couplings"] += int(np.asarray(rcpt.skipped_mask).sum())
            stats["dropped_newest"] += int(np.asarray(rcpt.dropped_newest).sum())
            if bool(ok):  # a dropped join must not touch the query plan
                plan, over = plan_add_sensor(plan, x, slot)
                joined.append(int(slot))
                stats["joins"] += 1
                stats["cell_overflows"] += int(over)
            else:
                stats["join_drops"] += 1
            a = 8
            fs = rng.integers(0, b, size=a)
            ss = rng.integers(0, n, size=a)
            xs = (pos[ss] + 0.05 * rng.normal(size=(a, pos.shape[1]))).astype(np.float32)
            prob, state, rec = streaming.absorb_many(
                prob, state, fs, ss, xs, rng.normal(size=a).astype(np.float32),
                donate=True, on_full=args.on_full,
            )
            stats["absorbed"] += int(np.asarray(rec.absorbed).sum())
            stats["dropped"] += a - int(np.asarray(rec.absorbed).sum())
            state = colored_sweep(prob, state, n_sweeps=args.refresh_sweeps)
            if i % 2 == 1:  # every other round a sensor leaves
                victim = joined.pop(0) if joined else int(rng.integers(0, n))
                prob, state, rok = remove_sensor(
                    prob, state, victim,
                    repair_lambda=args.repair_lambda, donate=True,
                )
                plan = plan_remove_sensor(plan, victim)
                stats["leaves"] += int(bool(rok))
                state = colored_sweep(prob, state, n_sweeps=args.refresh_sweeps)
            # query with the engine under test (dense ignores the plan)
            fusion.fuse(
                prob, state, xq_c, "knn", k=args.k, engine=args.engine,
                plan=None if args.engine == "dense" else plan,
            ).block_until_ready()
            return prob, state, plan

        # Warm with one even + one odd round so both the join-only and the
        # join+leave program sets are compiled before counting.
        prob, state, churn_plan = churn_round(prob, state, churn_plan, 0)
        prob, state, churn_plan = churn_round(prob, state, churn_plan, 1)
        from repro.analysis import compile_ledger

        snap = compile_ledger.snapshot(
            compile_ledger.churn_group(on_full=args.on_full, donate=True)
        )
        t0 = time.time()
        for i in range(2, args.churn):
            prob, state, churn_plan = churn_round(prob, state, churn_plan, i)
        dt = time.time() - t0
        recompiles = snap.total_growth()
        per_round = dt / max(args.churn - 2, 1) * 1e3
        from repro.core import plans as _plans

        headroom = np.asarray(
            _plans.degree_headroom(
                prob.topology.degrees, prob.alive[: prob.n],
                prob.topology.d_max,
            )
        )
        live = np.asarray(prob.alive[: prob.n])
        hr = headroom[live]
        min_headroom = int(hr.min()) if hr.size else 0
        p50_headroom = int(np.median(hr)) if hr.size else 0
        rows_at_0 = int((hr == 0).sum())
        print(
            f"churn: {args.churn} rounds ({stats['joins']} joins, "
            f"{stats['leaves']} leaves, {stats['join_drops']} join-drops, "
            f"{stats['absorbed']} absorbed / {stats['dropped']} dropped "
            f"arrivals, {stats['cell_overflows']} cell overflows) "
            f"{per_round:.1f} ms/round warm; "
            f"recompiles after warmup: {recompiles} (want 0)"
        )
        print(
            f"churn receipts: {stats['skipped_couplings']} couplings "
            f"skipped (lane-exhausted neighbors), "
            f"{stats['dropped_newest']} newest arrivals dropped to anchor "
            f"lanes; live degree headroom min={min_headroom} "
            f"p50={p50_headroom} rows_at_0={rows_at_0}"
            + (" -- joins near 0-headroom rows lose couplings"
               if rows_at_0 else "")
        )

    # -- query: one dispatch per request grid ------------------------------
    xq = np.linspace(-1, 1, args.queries)[:, None].astype(np.float32)
    if pos.shape[1] > 1:
        xq = np.concatenate([xq] + [np.zeros_like(xq)] * (pos.shape[1] - 1), axis=1)
    if args.fusion == "knn":
        # kNN fusion (paper Eq. 19); plan/pallas route through the static
        # query plan — per-cell candidate lists, O(Q*k*D) per field instead
        # of O(Q*n*D) — while dense runs the all-sensors oracle.  A churn
        # trace's plan was repaired in place and keeps serving as-is.
        plan = (
            None if args.engine == "dense"
            else (churn_plan if churn_plan is not None
                  else make_serving_plan(prob, k=args.k))
        )
        cdt = (
            None if args.engine == "dense" or args.serve_dtype == "f32"
            else args.serve_dtype
        )
        note = f"knn k={args.k} engine={args.engine}"
        if plan is not None and args.energy_tau > 0:
            # Offline compaction: drop representers under the energy
            # threshold and shrink the candidate-list gather width.  Churn
            # repairs happened on the UNPRUNED plan above; pruning is
            # derived on top of the repaired lists.
            from repro.core import pruning

            plan, rep = pruning.prune_plan(
                prob, state, plan, energy_tau=args.energy_tau
            )
            note += (
                f" tau={args.energy_tau:g} pruned {rep.n_pruned}/"
                f"{rep.n_live}"
            )
        run = lambda: fusion.fuse(
            prob, state, xq, "knn", k=args.k, engine=args.engine, plan=plan,
            compute_dtype=cdt,
        )
        if cdt is not None:
            note += f" dtype={args.serve_dtype}"
        if plan is not None:
            note += f" (plan: {plan.n_cells} cells, K_max={plan.k_max})"
    else:
        # conn fusion (Eq. 20) collapses to one batched Pallas kernel matvec
        anchors, coefs = fusion.global_coefficients(prob, state, rule="conn")
        run = lambda: kernel_matvec(xq, anchors, coefs, gamma=args.gamma)
        note = "conn (global coefficients + fused matvec)"
    out = run()
    out.block_until_ready()
    t0 = time.time()
    out = run()
    out.block_until_ready()
    dt = time.time() - t0
    print(
        f"query[{note}]: {args.queries} points x {b} fields in {dt*1e3:.2f}ms "
        f"-> {args.queries*b/dt:.0f} field-queries/s"
    )
    print("sample field 0:", np.asarray(out[0, :6]).round(3).tolist())


def main():
    # daemon mode has its own flag set — peel --mode (and the env re-exec
    # flag) off and delegate the rest of argv to repro.launch.daemon
    pre = argparse.ArgumentParser(add_help=False)
    pre.add_argument("--mode", default="lm",
                     choices=["lm", "field", "daemon"])
    pre.add_argument("--hardened-env", action="store_true")
    ns, rest = pre.parse_known_args()
    if ns.hardened_env and os.environ.get(_HARDENED_GUARD) != "1":
        _reexec_hardened()  # never returns
    if ns.mode == "daemon":
        from repro.launch import daemon

        return daemon.main(rest)

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "field", "daemon"])
    # lm mode
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_NAMES)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    # field mode
    ap.add_argument("--fields", type=int, default=64, help="B concurrent fields")
    ap.add_argument("--sensors", type=int, default=50)
    ap.add_argument("--radius", type=float, default=0.8)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--sweeps", type=int, default=30)
    ap.add_argument("--refresh_sweeps", type=int, default=5)
    ap.add_argument("--stream", type=int, default=0, help="streaming arrivals to absorb")
    ap.add_argument("--on_full", default="drop", choices=["drop", "evict"],
                    help="over-capacity arrival policy (evict = sliding window)")
    ap.add_argument("--beta", type=float, default=1.0,
                    help="per-field forgetting factor in (0, 1]; beta < 1 "
                         "decays old arrivals one step per absorb (EW-RLS) "
                         "so streams track time-varying fields; 1.0 is the "
                         "bitwise static path")
    ap.add_argument("--repair_lambda", action="store_true",
                    help="re-derive the paper rule lambda_i = 0.01/|N_i|^2 "
                         "for rows whose degree changes in churn events")
    ap.add_argument("--churn", type=int, default=0,
                    help="membership churn rounds to replay (symmetric "
                         "joins/leaves with O(degree) event repairs)")
    ap.add_argument("--spares", type=int, default=8,
                    help="spare sensor rows reserved for --churn joins "
                         "(n_max = sensors + spares; the recolor pool "
                         "defaults to 2x this)")
    ap.add_argument("--faults", default="",
                    help="unreliable-link replay spec for training: "
                         "drop=P[,burst=to_bad:to_good:drop_bad]"
                         "[,crash=p_crash:p_restart]; trains under the "
                         "seeded fault process with the convergence "
                         "watchdog supervising (retry / refactorize / "
                         "rollback) and prints the receipt")
    ap.add_argument("--watch_tol", type=float, default=1e-3,
                    help="--faults watchdog convergence tolerance "
                         "(max relative z-residual per round)")
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--fusion", default="conn", choices=["conn", "knn"],
                    help="query fusion rule (knn routes through the query plan)")
    ap.add_argument("--k", type=int, default=3, help="kNN order for --fusion knn")
    ap.add_argument("--engine", default="plan", choices=["dense", "plan", "pallas"],
                    help="kNN serving engine for --fusion knn")
    ap.add_argument("--serve_dtype", default="f32", choices=["f32", "bf16"],
                    help="anchor-table storage dtype for the plan/pallas "
                         "kNN engines (bf16 rounds the stored anchors "
                         "only; selection and accumulation stay in full "
                         "precision — selection-exact)")
    ap.add_argument("--energy_tau", type=float, default=0.0,
                    help="representer-pruning energy threshold: compact "
                         "the query plan to sensors with coefficient "
                         "energy above tau before serving (plan/pallas "
                         "engines; 0 = off)")
    ap.add_argument("--hardened-env", action="store_true",
                    help="re-exec under the hardened launch env (tcmalloc "
                         "LD_PRELOAD + XLA/logging flags; see launch/"
                         "env.sh), skipped gracefully when libs are absent")
    args = ap.parse_args()
    if args.mode == "field":
        serve_fields(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
