"""Serving launcher: batched greedy decoding against a KV/SSM cache.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
    --variant smoke --batch 4 --prompt_len 32 --gen 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import decode_step, init_cache, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_NAMES)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, variant=None if args.variant == "full" else "smoke")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M")

    b, s0 = args.batch, args.prompt_len
    max_seq = s0 + args.gen + 1
    prompt = jax.random.randint(key, (b, s0), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.is_encoder_decoder:
        batch = {"frames": jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))}
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))

    cache = init_cache(cfg, b, max_seq)
    jpre = jax.jit(lambda p, bt, c: prefill(cfg, p, bt, c))
    jdec = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))

    t0 = time.time()
    logits, cache = jpre(params, batch, cache)
    if logits is None:
        tok = jnp.zeros((b, 1), jnp.int32)
        pos0 = 0
    else:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        pos0 = s0
    print(f"prefill: {time.time()-t0:.2f}s ({b}x{s0} tokens)")

    out = []
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = jdec(params, tok, cache, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"decode: {args.gen} steps in {dt:.2f}s -> {b*args.gen/dt:.1f} tok/s")
    print("sample row 0:", jax.device_get(seq[0])[:24].tolist())


if __name__ == "__main__":
    main()
