"""Training launcher.

Runs on whatever devices exist (CPU hosts included: set
XLA_FLAGS=--xla_force_host_platform_device_count=8 to get 8 replicas).

Data parallelism is explicit via shard_map over the `data` axis, with the
paper's technique selectable as the transport:

  --dp_mode allreduce   gradients pmean'd every step — the centralized
                        special case (complete graph; paper Lemma 3.1)
  --dp_mode sop_gossip  local steps + one SOP pairwise-projection round per
                        step on a ring/hypercube pairing schedule — SN-Train's
                        relaxed neighbor coupling in parameter space

Params/opt state are stacked with a leading replica axis in BOTH modes (in
allreduce mode replicas provably stay bit-identical — asserted in tests).

Example:
  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m repro.launch.train --arch smollm-135m --variant smoke \
    --steps 50 --batch 8 --seq 128 --dp_mode sop_gossip
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.checkpoint import latest_step, restore, save
from repro.configs import ARCH_NAMES, get_config
from repro.core import consensus
from repro.data import synthetic_lm_stream
from repro.models import init_params, make_train_step
from repro.optim import adamw, cosine_warmup


def build(cfg, *, dp_mode: str, lr: float, steps: int):
    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("data",))
    opt = adamw(cosine_warmup(lr, min(100, steps // 10 + 1), steps))

    if dp_mode == "sop_gossip":
        name = "hypercube" if (n_dev & (n_dev - 1)) == 0 and n_dev > 1 else "ring"
        sched = consensus.schedule(name, n_dev) if n_dev > 1 else [[0]]
    else:
        sched = None
    step = make_train_step(cfg, opt, dp_axis="data", dp_mode=dp_mode, gossip_schedule=sched)

    def device_fn(params, opt_state, batch, ridx):
        p1 = jax.tree.map(lambda a: a[0], params)
        o1 = jax.tree.map(lambda a: a[0], opt_state)
        p1, o1, m = step(p1, o1, batch, ridx[0])
        m = jax.tree.map(lambda x: jax.lax.pmean(x, "data"), m)
        lift = lambda a: a[None]
        return jax.tree.map(lift, p1), jax.tree.map(lift, o1), m

    sharded = compat.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P()),
    )
    return mesh, opt, jax.jit(sharded), n_dev


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_NAMES)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dp_mode", default="allreduce", choices=["allreduce", "sop_gossip"])
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--log_every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, variant=None if args.variant == "full" else "smoke")
    mesh, opt, jstep, n_dev = build(cfg, dp_mode=args.dp_mode, lr=args.lr, steps=args.steps)
    assert args.batch % n_dev == 0, (args.batch, n_dev)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M devices={n_dev} dp={args.dp_mode}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    stack = lambda a: jnp.broadcast_to(a[None], (n_dev,) + a.shape)
    params = jax.tree.map(stack, params)
    opt_state = jax.tree.map(stack, opt_state)

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            params, opt_state = restore(args.ckpt_dir, last, (params, opt_state))
            start = last
            print(f"restored step {last}")

    stream = synthetic_lm_stream(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    print(f"achievable CE floor (bigram entropy): {stream.bigram_entropy():.3f} nats")
    t0 = time.time()
    for i in range(start, args.steps):
        b = stream.batch_at(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        ridx = jnp.full((n_dev,), i, jnp.int32)
        params, opt_state, metrics = jstep(params, opt_state, batch, ridx)
        if (i + 1) % args.log_every == 0 or i == start:
            m = jax.tree.map(float, jax.device_get(metrics))
            extra = f" consensus_sq={m['consensus_sq']:.3e}" if "consensus_sq" in m else ""
            print(
                f"step {i+1:5d}  loss={m['loss']:.4f} ce={m['ce']:.4f}"
                f"{extra}  ({(time.time()-t0)/(i-start+1):.2f}s/step)",
                flush=True,
            )
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, i + 1, (params, opt_state))
    print("done")


if __name__ == "__main__":
    main()
