"""Composable model zoo: dense / MoE / SSM / hybrid / VLM / audio families."""

from .config import ModelConfig, reduced
from .model import (
    decode_step,
    forward_logits,
    greedy_decode,
    init_cache,
    init_params,
    loss_fn,
    make_train_step,
    prefill,
)

__all__ = [
    "ModelConfig",
    "decode_step",
    "forward_logits",
    "greedy_decode",
    "init_cache",
    "init_params",
    "loss_fn",
    "make_train_step",
    "prefill",
    "reduced",
]
