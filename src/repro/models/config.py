"""Model configuration — one frozen dataclass drives every architecture
family (dense / moe / ssm / hybrid / vlm / audio).

The config is hashable so it can be a static jit argument; everything the
layer code branches on is compile-time constant.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0  # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    act: str = "silu"  # silu(-> SwiGLU) | squared_relu | gelu
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False

    # rope
    rope_theta: float = 10000.0
    rope_mode: str = "standard"  # standard | mrope
    mrope_sections: tuple[int, ...] = ()  # splits of head_dim//2, e.g. (16,24,24)

    # attention variants
    sliding_window: int = 0  # 0 = full causal attention

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_period: int = 1  # layer i uses MoE iff n_experts>0 and i % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512  # GShard dispatch group (tokens)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4
    # route the intra-chunk term through the fused Pallas kernel
    # (kernels/ssd_intra.py); interpret-mode on CPU, real kernel on TPU
    ssd_fused: bool = False

    # hybrid layer pattern, repeated to n_layers; 'a' = attention, 'm' = mamba
    layer_pattern: tuple[str, ...] = ()

    # encoder-decoder (audio)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # e.g. whisper-tiny: 1500 frames
    max_target_positions: int = 0  # learned decoder positions (whisper: 448)

    # vlm stub
    n_patches: int = 0  # vision tokens prepended to the text sequence

    # numerics / distribution
    dtype: str = "float32"
    remat: bool = False
    remat_policy: str = "full"  # full | dots (save matmul outputs only)
    fsdp: bool = False
    # unroll the layer scan into straight-line HLO (used by the dry-run's
    # cost extrapolation: XLA's cost_analysis counts while bodies once)
    unroll: bool = False

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.layer_pattern:
            return self.layer_pattern
        return ("m",) if self.family == "ssm" else ("a",)

    @property
    def block_len(self) -> int:
        """Layers per scanned super-block (pattern length, lcm'd with MoE period)."""
        p = len(self.pattern)
        if self.n_experts > 0 and self.moe_period > 1:
            # ensure the MoE period divides the super-block
            import math

            p = math.lcm(p, self.moe_period)
        return p

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_len == 0, (
            self.n_layers,
            self.block_len,
        )
        return self.n_layers // self.block_len

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def layer_is_moe(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_period) == self.moe_offset

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.d_inner % self.ssm_head_dim == 0
        return self.d_inner // self.ssm_head_dim

    @property
    def has_ffn(self) -> bool:
        """Pure-SSM stacks (mamba2) have no separate FFN sub-layer (d_ff==0)."""
        return self.d_ff > 0 or self.n_experts > 0

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included)."""
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "a":
                total += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                total += (self.n_heads * hd) * d
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
                total += d  # norm
            else:  # mamba
                di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * n + h)  # in_proj (z,x,B,C,dt)
                total += self.ssm_conv * (di + 2 * n)  # depthwise conv
                total += 2 * h + di  # A_log, D, gated norm
                total += di * d  # out_proj
                total += d  # norm
            if self.has_ffn:
                total += d  # norm
                if self.layer_is_moe(i):
                    e, f = self.n_experts, self.moe_d_ff or self.d_ff
                    total += d * e  # router
                    total += e * (3 * d * f if self.act == "silu" else 2 * d * f)
                    if self.n_shared_experts:
                        fs = f * self.n_shared_experts
                        total += 3 * d * fs if self.act == "silu" else 2 * d * fs
                else:
                    f = self.d_ff
                    total += 3 * d * f if self.act == "silu" else 2 * d * f
        total += d  # final norm
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += 4 * d * (self.n_heads * hd) + (
                    3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
                ) + 2 * d
            # cross attention per decoder layer
            total += self.n_layers * (4 * d * (self.n_heads * hd) + d)
            total += self.max_target_positions * d  # learned positions
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.n_params()
        total = self.n_params()
        f = self.moe_d_ff or self.d_ff
        per_expert = 3 * self.d_model * f if self.act == "silu" else 2 * self.d_model * f
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant of the same family: 2 blocks, d_model<=512, <=4 experts."""
    block = cfg.block_len
    small = dict(
        n_layers=2 * block if block > 1 else 2,
        d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        max_target_positions=min(cfg.max_target_positions, 64)
        if cfg.max_target_positions
        else 0,
        dtype="float32",
        name=cfg.name + "-smoke",
        mrope_sections=(4, 6, 6) if cfg.rope_mode == "mrope" else (),
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
