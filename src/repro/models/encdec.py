"""Encoder-decoder (Whisper-style) model — arXiv:2212.04356.

Per the assignment carve-out, the audio frontend (log-mel + conv downsampler)
is a stub: `input_specs()` supplies precomputed frame embeddings
(B, encoder_seq, d_model).  Everything downstream is real: sinusoidal
encoder positions, bidirectional encoder self-attention, causal decoder
self-attention with learned positions, cross-attention, GELU MLPs,
LayerNorm, tied output head — and a decode path with self-KV cache plus
precomputed cross-KV.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from .transformer import scan_blocks

Params = dict[str, Any]


def _sinusoid(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * i / dim))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _cross_attn_init(key, cfg: ModelConfig) -> Params:
    return L.attn_init(key, cfg)


def _enc_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.norm_init(cfg),
        "attn": L.attn_init(k1, cfg),
        "norm2": L.norm_init(cfg),
        "mlp": L.mlp_init(k2, cfg, cfg.d_ff),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.norm_init(cfg),
        "self_attn": L.attn_init(k1, cfg),
        "norm_x": L.norm_init(cfg),
        "cross_attn": _cross_attn_init(k2, cfg),
        "norm2": L.norm_init(cfg),
        "mlp": L.mlp_init(k3, cfg, cfg.d_ff),
    }


def init_encdec_params(key, cfg: ModelConfig) -> Params:
    ke, kd, kt, kp = jax.random.split(key, 4)
    dt = L.cdtype(cfg)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": L._normal(kt, (cfg.vocab_size, cfg.d_model), 0.02, dt),
        "dec_pos": L._normal(kp, (cfg.max_target_positions, cfg.d_model), 0.02, dt),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "enc_norm": L.norm_init(cfg),
        "dec_norm": L.norm_init(cfg),
    }


def _cross_attend(p: Params, cfg: ModelConfig, x, enc_k, enc_v):
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.hd)
    mask = jnp.ones((b, s, enc_k.shape[1]), bool)
    out = L._sdpa(q, enc_k, enc_v, mask, cfg)
    return L.dense(p["wo"], out)


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T, d) stub embeddings -> encoder states (B, T, d)."""
    x = frames.astype(L.cdtype(cfg)) + _sinusoid(frames.shape[1], cfg.d_model).astype(
        L.cdtype(cfg)
    )
    dummy = jnp.zeros((x.shape[0], x.shape[1], 1))

    def body(x, lp):
        h = L.apply_norm(lp["norm1"], cfg, x)
        h = L.attn_forward(lp["attn"], cfg, h, dummy, causal=False, rope=False)
        x = x + h
        h = L.apply_norm(lp["norm2"], cfg, x)
        x = x + L.mlp(lp["mlp"], cfg, h)
        return x, None

    x, _ = scan_blocks(cfg, body, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], cfg, x)


def _dec_positions(params, cfg, start: int, length: int):
    idx = jnp.clip(jnp.arange(start, start + length), 0, cfg.max_target_positions - 1)
    return params["dec_pos"][idx]


def encdec_forward(
    params: Params, cfg: ModelConfig, tokens: jax.Array, frames: jax.Array
) -> tuple[jax.Array, dict]:
    """Teacher-forced decoder logits (B, S, V)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = params["embed"][tokens] + _dec_positions(params, cfg, 0, s)[None]
    dummy = jnp.zeros((b, s, 1))

    def body(x, lp):
        h = L.apply_norm(lp["norm1"], cfg, x)
        h = L.attn_forward(lp["self_attn"], cfg, h, dummy, causal=True, rope=False)
        x = x + h
        h = L.apply_norm(lp["norm_x"], cfg, x)
        ek = L.dense(lp["cross_attn"]["wk"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, cfg.hd
        )
        ev = L.dense(lp["cross_attn"]["wv"], enc).reshape(
            b, enc.shape[1], cfg.n_kv_heads, cfg.hd
        )
        x = x + _cross_attend(lp["cross_attn"], cfg, h, ek, ev)
        h = L.apply_norm(lp["norm2"], cfg, x)
        x = x + L.mlp(lp["mlp"], cfg, h)
        return x, None

    x, _ = scan_blocks(cfg, body, x, params["dec_layers"])
    x = L.apply_norm(params["dec_norm"], cfg, x)
    logits = x @ params["embed"].T  # whisper ties embeddings
    zero = jnp.zeros((), jnp.float32)
    return logits, {"aux_loss": zero, "z_loss": zero}


def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Params:
    nl = cfg.n_layers
    t = cfg.encoder_seq
    return {
        "self": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (nl,) + a.shape).copy(),
            L.init_kv_cache(cfg, batch, max_seq, dtype),
        ),
        "cross_k": jnp.zeros((nl, batch, t, cfg.n_kv_heads, cfg.hd), dtype),
        "cross_v": jnp.zeros((nl, batch, t, cfg.n_kv_heads, cfg.hd), dtype),
    }


def encdec_prefill(
    params: Params, cfg: ModelConfig, frames: jax.Array, cache: Params
) -> Params:
    """Encode audio and precompute per-layer cross K/V into the cache."""
    enc = encode(params, cfg, frames)
    b, t, _ = enc.shape

    def per_layer(lp):
        ek = L.dense(lp["cross_attn"]["wk"], enc).reshape(b, t, cfg.n_kv_heads, cfg.hd)
        ev = L.dense(lp["cross_attn"]["wv"], enc).reshape(b, t, cfg.n_kv_heads, cfg.hd)
        return ek, ev

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return {"self": cache["self"], "cross_k": ck.astype(cache["cross_k"].dtype),
            "cross_v": cv.astype(cache["cross_v"].dtype)}


def encdec_decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B, 1)
    cache: Params,
    position: jax.Array,
) -> tuple[jax.Array, Params]:
    b = token.shape[0]
    pos_idx = jnp.clip(position, 0, cfg.max_target_positions - 1)
    x = params["embed"][token] + params["dec_pos"][pos_idx][None, None, :]

    def body(x, inp):
        lp, c_self, ck, cv = inp
        h = L.apply_norm(lp["norm1"], cfg, x)
        h, new_self = L.attn_decode(lp["self_attn"], cfg, h, c_self, position, rope=False)
        x = x + h
        h = L.apply_norm(lp["norm_x"], cfg, x)
        x = x + _cross_attend(lp["cross_attn"], cfg, h, ck, cv)
        h = L.apply_norm(lp["norm2"], cfg, x)
        x = x + L.mlp(lp["mlp"], cfg, h)
        return x, new_self

    x, new_self = scan_blocks(
        cfg, body, x, (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"])
    )
    x = L.apply_norm(params["dec_norm"], cfg, x)
    logits = x @ params["embed"].T
    return logits, {"self": new_self, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
