"""Shared neural-net layers: norms, RoPE/M-RoPE, GQA attention (+KV cache
with ring-buffer sliding window), MLPs, and GShard-style top-k MoE.

Conventions:
  * params are nested dicts of jnp arrays,
  * every init fn takes (key, cfg) and every apply fn takes (params, cfg, ...),
  * activations follow cfg.dtype; softmax/router/norm math runs in float32.

Shapes: B batch, S sequence, d model dim, H query heads, K kv heads,
hd head dim, E experts, C capacity, G dispatch-group tokens.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False) -> Params:
    p = {"w": _normal(key, (d_in, d_out), d_in**-0.5, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig) -> Params:
    p = {"scale": jnp.ones((cfg.d_model,), cdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), cdtype(cfg))
    return p


def apply_norm(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + 1e-6)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rms_norm_gated(scale: jax.Array, x: jax.Array, gate: jax.Array) -> jax.Array:
    """Mamba2's gated RMSNorm: norm(x * silu(gate)) * scale."""
    xf = (x * jax.nn.silu(gate)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _inv_freq(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def rope_angles(cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """Angles (B, S, hd//2).

    standard: positions (B, S).
    mrope:    positions (B, 3, S) — temporal/height/width streams; the hd//2
              frequency slots are partitioned by cfg.mrope_sections and each
              partition reads its own stream (Qwen2-VL Sec. 3).
    """
    inv = _inv_freq(cfg.hd, cfg.rope_theta)  # (hd/2,)
    if cfg.rope_mode == "mrope":
        sections = cfg.mrope_sections
        assert sum(sections) == cfg.hd // 2, (sections, cfg.hd)
        sec_id = jnp.concatenate(
            [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
        )  # (hd/2,)
        pos_sel = jnp.take(positions, sec_id, axis=1)  # (B, hd/2, S)
        return jnp.einsum("bks,k->bsk", pos_sel.astype(jnp.float32), inv)
    return positions.astype(jnp.float32)[..., None] * inv  # (B,S,hd/2)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, n, hd); angles: (B, S, hd//2). Half-rotation (NeoX) layout."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, sliding window, KV cache)
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, cdtype(cfg), bias=cfg.qkv_bias),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, cdtype(cfg), bias=cfg.qkv_bias),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, cdtype(cfg), bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.n_heads * hd, d, cdtype(cfg)),
    }


def _qkv(p, cfg, x, angles, *, rope: bool = True):
    b, s, _ = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if rope:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q:(B,Sq,H,hd) k/v:(B,Sk,K,hd) mask:(B,Sq,Sk) bool."""
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    rep = h // kheads
    q = q.reshape(b, sq, kheads, rep, hd)
    logits = jnp.einsum("bqkrh,bskh->bkrqs", q, k).astype(jnp.float32)
    logits = logits * (hd**-0.5)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(b, sq, h * hd)


def causal_mask(sq: int, sk: int, *, window: int = 0, offset: int = 0) -> jax.Array:
    """(sq, sk) bool; query i (absolute pos offset+i) sees key j iff j <= i
    and (window == 0 or i - j < window)."""
    qp = offset + jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    m = kp <= qp
    if window:
        m &= (qp - kp) < window
    return m


def attn_forward(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    angles: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, angles, rope=rope)
    if causal:
        mask = causal_mask(s, s, window=window)[None]
    else:
        mask = jnp.ones((1, s, s), bool)
    out = _sdpa(q, k, v, jnp.broadcast_to(mask, (b, s, s)), cfg)
    return dense(p["wo"], out)


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> Params:
    """Ring-buffer KV cache. `length` = full seq for dense, window for SWA."""
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),  # absolute positions
    }


def attn_decode(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    cache: Params,
    position: jax.Array,  # scalar int32: absolute position of the new token
    *,
    window: int = 0,
    rope: bool = True,
    rope_position: jax.Array | None = None,  # M-RoPE stream value if != position
) -> tuple[jax.Array, Params]:
    """One decode step against a ring-buffer cache (slot = pos % cache_len)."""
    b = x.shape[0]
    length = cache["k"].shape[1]
    angles_dummy = None
    if rope:
        rp = position if rope_position is None else rope_position
        if cfg.rope_mode == "mrope":
            pos = jnp.broadcast_to(rp, (b, 3, 1)).astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(rp, (b, 1)).astype(jnp.int32)
        angles_dummy = rope_angles(cfg, pos)
    q, k, v = _qkv(p, cfg, x, angles_dummy, rope=rope)
    slot = (position % length).astype(jnp.int32)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((b, 1), position, jnp.int32), slot, axis=1
        ),
    }
    kpos = cache["pos"]  # (B, length)
    valid = (kpos >= 0) & (kpos <= position)
    if window:
        valid &= (position - kpos) < window
    mask = valid[:, None, :]  # (B, 1, length)
    out = _sdpa(q, cache["k"], cache["v"], mask, cfg)
    return dense(p["wo"], out), cache


def prefill_into_cache(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    angles: jax.Array,
    cache: Params,
    *,
    window: int = 0,
) -> tuple[jax.Array, Params]:
    """Full-seq attention that also writes k/v into the cache (prefill).

    Assumes prefill length <= cache length and starts at position 0; for a
    ring cache with window W the last W positions land in their ring slots.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, angles)
    mask = jnp.broadcast_to(causal_mask(s, s, window=window)[None], (b, s, s))
    out = _sdpa(q, k, v, mask, cfg)
    length = cache["k"].shape[1]
    # keep the (at most `length`) most recent keys; static shapes (s, length
    # are trace-time Python ints) so this is plain slicing.
    start = max(0, s - length)
    kept_pos = jnp.arange(start, s, dtype=jnp.int32)
    slots = kept_pos % length
    upd_k = cache["k"].at[:, slots].set(k[:, start:])
    upd_v = cache["v"].at[:, slots].set(v[:, start:])
    upd_pos = cache["pos"].at[:, slots].set(kept_pos[None, :])
    cache = {"k": upd_k, "v": upd_v, "pos": upd_pos}
    return dense(p["wo"], out), cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int) -> Params:
    d = cfg.d_model
    if cfg.act == "silu":  # SwiGLU
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wg": dense_init(k1, d, d_ff, cdtype(cfg)),
            "wu": dense_init(k2, d, d_ff, cdtype(cfg)),
            "wd": dense_init(k3, d_ff, d, cdtype(cfg)),
        }
    k1, k2 = jax.random.split(key)
    return {
        "wu": dense_init(k1, d, d_ff, cdtype(cfg)),
        "wd": dense_init(k2, d_ff, d, cdtype(cfg)),
    }


def mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wu"], x)
    elif cfg.act == "squared_relu":
        h = jnp.square(jax.nn.relu(dense(p["wu"], x)))
    elif cfg.act == "gelu":
        h = jax.nn.gelu(dense(p["wu"], x))
    else:
        raise ValueError(cfg.act)
    return dense(p["wd"], h)


# ---------------------------------------------------------------------------
# MoE — GShard-style grouped top-k dispatch with capacity
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig) -> Params:
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p: Params = {
        "router": _normal(kr, (d, e), d**-0.5, jnp.float32),
        "wu": _normal(ku, (e, d, f), d**-0.5, cdtype(cfg)),
        "wd": _normal(kd, (e, f, d), f**-0.5, cdtype(cfg)),
    }
    if cfg.act == "silu":
        p["wg"] = _normal(kg, (e, d, f), d**-0.5, cdtype(cfg))
    if cfg.n_shared_experts:
        shared_cfg = cfg
        p["shared"] = mlp_init(ks, shared_cfg, f * cfg.n_shared_experts)
    return p


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(c, cfg.top_k)


def moe_apply(
    p: Params, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, {aux_loss, z_loss, expert_load})."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = x.reshape(b * s, d)
    n = tokens.shape[0]
    g = min(cfg.moe_group_size, n)
    pad = (-n) % g
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    ng = tokens.shape[0] // g
    xt = tokens.reshape(ng, g, d)
    cap = _capacity(cfg, g)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (ng,g,e)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # (ng, g, k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)  # renormalize

    counts = jnp.zeros((ng, e), jnp.float32)
    dispatch = jnp.zeros((ng, g, e, cap), cdtype(cfg))
    combine = jnp.zeros((ng, g, e, cap), jnp.float32)
    for j in range(k):
        oh = jax.nn.one_hot(topi[..., j], e, dtype=jnp.float32)  # (ng,g,e)
        pos_in = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos = jnp.einsum("nge,nge->ng", pos_in, oh).astype(jnp.int32)
        keep = (pos < cap).astype(jnp.float32)
        slot = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
        dj = oh[..., None] * slot[:, :, None, :]  # (ng,g,e,cap)
        dispatch = dispatch + dj.astype(cdtype(cfg))
        combine = combine + dj * topv[..., j][..., None, None]
        counts = counts + oh.sum(axis=1)

    expert_in = jnp.einsum("ngec,ngd->necd", dispatch, xt)  # (ng? no: n=ng)
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in, p["wg"]))
        h = h * jnp.einsum("necd,edf->necf", expert_in, p["wu"])
    elif cfg.act == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("necd,edf->necf", expert_in, p["wu"])))
    else:
        h = jax.nn.gelu(jnp.einsum("necd,edf->necf", expert_in, p["wu"]))
    expert_out = jnp.einsum("necf,efd->necd", h, p["wd"])
    y = jnp.einsum("ngec,necd->ngd", combine.astype(cdtype(cfg)), expert_out)
    y = y.reshape(-1, d)[:n].reshape(b, s, d)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], cfg, x)

    # load-balance aux (Switch/GShard): E * sum_e f_e * P_e
    me = probs.mean(axis=(0, 1))  # (e,)
    top1 = jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32).mean(axis=(0, 1))
    aux = e * jnp.sum(top1 * me)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    metrics = {"aux_loss": aux, "z_loss": z, "expert_load": counts.sum(0)}
    return y, metrics


def ffn_apply(
    p: Params, cfg: ModelConfig, x: jax.Array, *, is_moe: bool
) -> tuple[jax.Array, dict[str, jax.Array]]:
    if is_moe:
        return moe_apply(p, cfg, x)
    zero = jnp.zeros((), jnp.float32)
    return mlp(p, cfg, x), {
        "aux_loss": zero,
        "z_loss": zero,
        "expert_load": jnp.zeros((max(cfg.n_experts, 1),), jnp.float32),
    }
