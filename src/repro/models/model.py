"""Unified model API over all architecture families.

    params                    = init_params(cfg, key)
    loss, metrics             = loss_fn(cfg, params, batch)
    train_step                = make_train_step(cfg, optimizer[, dp_axis, gossip])
    logits, cache             = prefill(cfg, params, batch, cache)
    logits, cache             = decode_step(cfg, params, token, cache, position)

`batch` is a dict: tokens (B,S) / labels (B,S) / mask (B,S), plus
`patch_embeds` (VLM stub) or `frames` (audio stub) when the family needs it.

The train step optionally applies the paper's SOP-consensus gossip on the
data axis instead of all-reduce gradient averaging (DESIGN.md Sec. 3).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import consensus
from repro.optim import Optimizer, apply_updates

from .config import ModelConfig
from . import encdec as ED
from . import transformer as T

Params = dict[str, Any]


def init_params(cfg: ModelConfig, key) -> Params:
    if cfg.is_encoder_decoder:
        return ED.init_encdec_params(key, cfg)
    return T.init_decoder_params(key, cfg)


def forward_logits(cfg: ModelConfig, params: Params, batch: dict) -> tuple[jax.Array, dict]:
    if cfg.is_encoder_decoder:
        return ED.encdec_forward(params, cfg, batch["tokens"], batch["frames"])
    logits, metrics = T.decoder_forward(
        params, cfg, batch["tokens"], patch_embeds=batch.get("patch_embeds")
    )
    if cfg.n_patches and "patch_embeds" in batch:
        logits = logits[:, cfg.n_patches :]  # align back to text positions
    return logits, metrics


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    return ce.sum() / jnp.clip(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict) -> tuple[jax.Array, dict]:
    logits, m = forward_logits(cfg, params, batch)
    ce = cross_entropy(logits, batch["labels"], batch["mask"])
    total = ce
    if cfg.n_experts:
        total = (
            total
            + cfg.router_aux_weight * m["aux_loss"]
            + cfg.router_z_weight * m["z_loss"]
        )
    metrics = {"loss": total, "ce": ce}
    if cfg.n_experts:
        metrics["aux_loss"] = m["aux_loss"]
        metrics["z_loss"] = m["z_loss"]
    return total, metrics


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    *,
    dp_axis: str | None = None,
    dp_mode: str = "allreduce",  # allreduce | sop_gossip | none
    gossip_schedule: list[list[int]] | None = None,
):
    """Build a (params, opt_state, batch[, gossip_round]) -> ... step.

    dp_mode='allreduce': gradients pmean'd over dp_axis (the paper's
      fully-connected / centralized special case, Lemma 3.1).
    dp_mode='sop_gossip': gradients stay local; after the optimizer update the
      parameters take one SOP pairwise-projection round on dp_axis (SN-Train's
      relaxed neighbor coupling, round-robin over the schedule).
    """

    def step(params, opt_state, batch, gossip_round=0):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        if dp_axis is not None and dp_mode == "allreduce":
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axis), grads)
            metrics = jax.tree.map(lambda x: jax.lax.pmean(x, dp_axis), metrics)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        if dp_axis is not None and dp_mode == "sop_gossip":
            sched = gossip_schedule
            assert sched is not None, "sop_gossip needs a schedule"
            params = consensus.gossip_round(params, dp_axis, sched, gossip_round)
            metrics["consensus_sq"] = consensus.consensus_sq_distance(params, dp_axis)
        return params, opt_state, metrics

    return step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.is_encoder_decoder:
        return ED.init_encdec_cache(cfg, batch, max_seq, dtype)
    return T.init_decoder_cache(cfg, batch, max_seq, dtype)


def prefill(cfg: ModelConfig, params: Params, batch: dict, cache: Params):
    """Process the prompt; returns (last-position logits | None, cache)."""
    if cfg.is_encoder_decoder:
        return None, ED.encdec_prefill(params, cfg, batch["frames"], cache)
    return T.decoder_prefill(
        params, cfg, batch["tokens"], cache, patch_embeds=batch.get("patch_embeds")
    )


def decode_step(
    cfg: ModelConfig, params: Params, token: jax.Array, cache: Params, position
):
    """One-token serve step: returns (logits (B,1,V), new cache)."""
    position = jnp.asarray(position, jnp.int32)
    if cfg.is_encoder_decoder:
        return ED.encdec_decode_step(params, cfg, token, cache, position)
    return T.decoder_decode_step(params, cfg, token, cache, position)


def greedy_decode(
    cfg: ModelConfig,
    params: Params,
    prompt: jax.Array,  # (B, S0)
    n_steps: int,
    max_seq: int,
    *,
    batch_extra: dict | None = None,
):
    """Prefill + n greedy decode steps (lax.fori over steps)."""
    b, s0 = prompt.shape
    cache = init_cache(cfg, b, max_seq)
    batch = {"tokens": prompt, **(batch_extra or {})}
    logits, cache = prefill(cfg, params, batch, cache)
    if logits is None:  # enc-dec: start from BOS token 0 at position 0
        first = jnp.zeros((b, 1), jnp.int32)
        start_pos = 0
    else:
        first = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        start_pos = s0
    out = jnp.zeros((b, n_steps), jnp.int32)

    def body(i, carry):
        tok, cache, out = carry
        logits, cache = decode_step(cfg, params, tok, cache, start_pos + i)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out = jax.lax.dynamic_update_slice_in_dim(out, nxt, i, axis=1)
        return nxt, cache, out

    _, cache, out = jax.lax.fori_loop(0, n_steps, body, (first, cache, out))
    return out, cache
