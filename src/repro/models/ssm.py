"""Mamba2 / SSD (state-space duality) mixer — arXiv:2405.21060.

Chunked "dual" form for training/prefill (quadratic attention-like math
within chunks of length `cs`, linear recurrence across chunks) and an O(1)
single-step recurrence for decode.  This is what makes `long_500k` native
for the SSM/hybrid architectures: decode state is (B, H, P, N) regardless of
context length.

Shapes: B batch, S seq, H ssm heads, P head dim, N state dim, K conv width,
cs chunk, nc chunks.  n_groups = 1 (B/C shared across heads), as in the
Mamba2 reference config.

NOTE on memory: the intra-chunk term materializes (B, nc, cs, cs, H) decay
factors in HBM in this pure-jnp formulation — that is the dominant memory-
roofline term for mamba2/jamba in the dry-run and the motivation for the
fused Pallas variant (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _normal, cdtype, dense, dense_init, rms_norm_gated

Params = dict[str, Any]


def ssm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, d, 2 * di + 2 * n + h, cdtype(cfg)),
        "conv_w": _normal(k2, (cfg.ssm_conv, conv_dim), cfg.ssm_conv**-0.5, cdtype(cfg)),
        "conv_b": jnp.zeros((conv_dim,), cdtype(cfg)),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),  # A in [-16, -1]
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), cdtype(cfg)),
        "out_proj": dense_init(k3, di, d, cdtype(cfg)),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B, S, C), w: (K, C)."""
    c = xbc.shape[-1]
    out = jax.lax.conv_general_dilated(
        xbc,
        w[:, None, :],  # (K, 1, C)
        window_strides=(1,),
        padding=[(w.shape[0] - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return jax.nn.silu(out + b)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) post-softplus
    a: jax.Array,  # (H,) negative
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Recurrence being computed:  h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t,
    y_t = C_t . h_t  (the D-skip and gating live in the caller).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)

    da = dtc * a[None, None, None, :]  # (b,nc,cs,h) negative
    da_cum = jnp.cumsum(da, axis=2)  # inclusive
    da_sum = da_cum[:, :, -1, :]  # (b,nc,h)

    # --- intra-chunk (quadratic, attention-like) ---
    diff = da_cum[:, :, :, None, :] - da_cum[:, :, None, :, :]  # (b,nc,l,m,h)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: upper-triangle diffs are positive and would overflow
    diff = jnp.where(tril[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    cb = jnp.einsum("bzln,bzmn->bzlm", cc, bc)  # (b,nc,l,m)
    y_intra = jnp.einsum(
        "bzlm,bzlmh,bzmh,bzmhp->bzlhp", cb, decay, dtc, xc
    )

    # --- chunk boundary states ---
    decay_to_end = jnp.exp(da_sum[:, :, None, :] - da_cum)  # (b,nc,cs,h)
    states = jnp.einsum("bzmn,bzmh,bzmhp->bzhpn", bc, dtc * decay_to_end, xc)

    # --- inter-chunk linear recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(da_sum)  # (b,nc,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), x.dtype)

    def step(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state BEFORE this chunk

    last, h_prev = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (b,nc,h,p,n)

    y_inter = jnp.einsum(
        "bzln,bzhpn,bzlh->bzlhp", cc, h_prev, jnp.exp(da_cum)
    )
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y, last


def ssd_recurrent_ref(x, dt, a, bmat, cmat, h0=None):
    """Naive per-step recurrence — the oracle for ssd_chunked."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, t):
        da = jnp.exp(dt[:, t] * a[None, :])  # (b,h)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], bmat[:, t], x[:, t])
        carry = carry * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, t], carry)
        return carry, y

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1), hT  # (b,s,h,p), (b,h,p,n)


def ssm_forward(
    p: Params,
    cfg: ModelConfig,
    u: jax.Array,  # (B, S, d_model)
) -> jax.Array:
    """Training/prefill path (no state input/output; sequences start cold)."""
    y, _, _ = ssm_forward_with_state(p, cfg, u)
    return y


def ssm_forward_with_state(p: Params, cfg: ModelConfig, u: jax.Array):
    b, s, _ = u.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc_raw, dt_raw = _split_proj(cfg, dense(p["in_proj"], u))
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    x = xbc[..., :di].reshape(b, s, h, cfg.ssm_head_dim)
    bmat = xbc[..., di : di + n]
    cmat = xbc[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    if cfg.ssd_fused:
        from repro.kernels.ops import ssd_chunked_fused

        y, hT = ssd_chunked_fused(
            x.astype(jnp.float32), dt, a,
            bmat.astype(jnp.float32), cmat.astype(jnp.float32), cfg.ssm_chunk,
        )
    else:
        y, hT = ssd_chunked(
            x.astype(jnp.float32),
            dt,
            a,
            bmat.astype(jnp.float32),
            cmat.astype(jnp.float32),
            cfg.ssm_chunk,
        )
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(u.dtype)
    y = rms_norm_gated(p["norm_scale"], y, z)
    # conv tail state for decode continuation after prefill
    k = cfg.ssm_conv
    conv_state = xbc_raw[:, -(k - 1) :, :] if s >= k - 1 else jnp.pad(
        xbc_raw, ((0, 0), (k - 1 - s, 0), (0, 0))
    )
    return dense(p["out_proj"], y), hT, conv_state


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "state": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv": jnp.zeros(
            (batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype
        ),
    }


def ssm_decode(
    p: Params,
    cfg: ModelConfig,
    u: jax.Array,  # (B, 1, d_model)
    cache: Params,
) -> tuple[jax.Array, Params]:
    """One-token recurrent step; O(1) in context length."""
    b = u.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc_raw, dt_raw = _split_proj(cfg, dense(p["in_proj"], u))
    window = jnp.concatenate([cache["conv"], xbc_raw], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))  # (B, C)
    x = xbc[:, :di].reshape(b, h, cfg.ssm_head_dim)
    bmat = xbc[:, di : di + n]
    cmat = xbc[:, di + n :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,h)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a[None, :])
    state = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bmat, x
    )
    y = jnp.einsum("bn,bhpn->bhp", cmat, state) + p["D"][None, :, None] * x
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = rms_norm_gated(p["norm_scale"], y, z)
    new_cache = {"state": state, "conv": window[:, 1:, :].astype(cache["conv"].dtype)}
    return dense(p["out_proj"], y), new_cache
