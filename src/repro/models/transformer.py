"""Decoder-only stack assembly for dense / MoE / SSM / hybrid / VLM families.

The stack is a `lax.scan` over "super-blocks": the layer pattern
(cfg.pattern, lcm'd with the MoE period) is unrolled inside the scan body and
the parameter/cache pytrees carry a leading (n_blocks, ...) axis.  This keeps
HLO size O(pattern) instead of O(n_layers) — essential for the 48-72 layer
dry-run compiles — and gives remat a natural boundary.

Three entry points per model: `forward` (train/prefill logits),
`prefill` (forward + cache fill), `decode_step` (one token vs cache).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import ssm as S

Params = dict[str, Any]


def scan_blocks(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked blocks, or a python loop when cfg.unroll.

    Unrolling exists for the dry-run cost analysis: XLA's HloCostAnalysis
    visits a while body once regardless of trip count, so roofline numbers
    are extracted from small unrolled variants (launch/dryrun.py).
    """
    if not cfg.unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys[0] is None:
        return carry, None
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, j: int) -> Params:
    """One layer (position j inside the super-block pattern)."""
    kind = cfg.layer_kind(j)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": L.norm_init(cfg)}
    if kind == "a":
        p["attn"] = L.attn_init(k1, cfg)
    else:
        p["ssm"] = S.ssm_init(k1, cfg)
    if cfg.has_ffn:
        p["norm2"] = L.norm_init(cfg)
        if cfg.layer_is_moe(j):
            p["moe"] = L.moe_init(k2, cfg)
        else:
            p["mlp"] = L.mlp_init(k3, cfg, cfg.d_ff)
    return p


def _superblock_init(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.block_len)
    return {f"layer{j}": _layer_init(keys[j], cfg, j) for j in range(cfg.block_len)}


def init_decoder_params(key, cfg: ModelConfig) -> Params:
    ke, kh, kb = jax.random.split(key, 3)
    dt = L.cdtype(cfg)
    p: Params = {
        "embed": L._normal(ke, (cfg.vocab_size, cfg.d_model), 0.02, dt),
        "final_norm": L.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L._normal(kh, (cfg.d_model, cfg.vocab_size), cfg.d_model**-0.5, dt)
    block_keys = jax.random.split(kb, cfg.n_blocks)
    p["blocks"] = jax.vmap(lambda k: _superblock_init(k, cfg))(block_keys)
    return p


# ---------------------------------------------------------------------------
# Positions (standard + M-RoPE with a vision-patch prefix)
# ---------------------------------------------------------------------------


def build_positions(
    cfg: ModelConfig, batch: int, seq: int, *, offset: int = 0
) -> jax.Array:
    """(B, S) standard or (B, 3, S) M-RoPE position ids.

    For the VLM stub the first cfg.n_patches tokens are vision patches laid
    out on a ~square grid: temporal id 0, spatial ids (row, col); text tokens
    then advance all three streams together (Qwen2-VL M-RoPE).
    """
    if cfg.rope_mode != "mrope":
        return jnp.broadcast_to(jnp.arange(offset, offset + seq), (batch, seq))
    npatch = min(cfg.n_patches, seq)
    side = max(int(npatch**0.5), 1)
    idx = jnp.arange(seq)
    is_text = idx >= npatch
    text_pos = idx - npatch
    t_stream = jnp.where(is_text, text_pos + side, 0)
    h_stream = jnp.where(is_text, text_pos + side, idx // side)
    w_stream = jnp.where(is_text, text_pos + side, idx % side)
    pos = jnp.stack([t_stream, h_stream, w_stream], axis=0) + offset
    return jnp.broadcast_to(pos, (batch, 3, seq))


# ---------------------------------------------------------------------------
# Forward (train / logits)
# ---------------------------------------------------------------------------


def _zero_metrics(cfg: ModelConfig):
    return {
        "aux_loss": jnp.zeros((), jnp.float32),
        "z_loss": jnp.zeros((), jnp.float32),
        "expert_load": jnp.zeros((max(cfg.n_experts, 1),), jnp.float32),
    }


def _apply_layer(bp: Params, cfg: ModelConfig, j: int, x, angles):
    kind = cfg.layer_kind(j)
    h = L.apply_norm(bp["norm1"], cfg, x)
    if kind == "a":
        h = L.attn_forward(bp["attn"], cfg, h, angles, window=cfg.sliding_window)
    else:
        h = S.ssm_forward(bp["ssm"], cfg, h)
    x = x + h
    metrics = _zero_metrics(cfg)
    if cfg.has_ffn:
        h = L.apply_norm(bp["norm2"], cfg, x)
        is_moe = cfg.layer_is_moe(j)
        h, metrics = L.ffn_apply(
            bp["moe"] if is_moe else bp["mlp"], cfg, h, is_moe=is_moe
        )
        x = x + h
    return x, metrics


def embed_inputs(
    params: Params, cfg: ModelConfig, tokens: jax.Array, patch_embeds=None
) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.n_patches and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def decoder_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S_text)
    *,
    patch_embeds: jax.Array | None = None,  # (B, n_patches, d) VLM stub
    positions: jax.Array | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Returns (logits (B, S_total, V), moe metrics summed over layers)."""
    x = embed_inputs(params, cfg, tokens, patch_embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = build_positions(cfg, b, s)
    needs_rope = "a" in cfg.pattern
    angles = L.rope_angles(cfg, positions) if needs_rope else jnp.zeros((b, s, 1))

    def block_body(carry, bp):
        x, acc = carry
        for j in range(cfg.block_len):
            x, m = _apply_layer(bp[f"layer{j}"], cfg, j, x, angles)
            acc = jax.tree.map(jnp.add, acc, m)
        return (x, acc), None

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_saveable
            if cfg.remat_policy == "dots"
            else None
        )
        body = jax.checkpoint(block_body, policy=policy)
    else:
        body = block_body
    (x, metrics), _ = scan_blocks(cfg, body, (x, _zero_metrics(cfg)), params["blocks"])
    x = L.apply_norm(params["final_norm"], cfg, x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, metrics


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------


def attn_cache_len(cfg: ModelConfig, max_seq: int) -> int:
    return min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq


def init_decoder_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype
) -> Params:
    """Cache pytree with leading (n_blocks,) axis per layer slot."""
    nb = cfg.n_blocks
    cache: Params = {}
    for j in range(cfg.block_len):
        if cfg.layer_kind(j) == "a":
            length = attn_cache_len(cfg, max_seq)
            one = L.init_kv_cache(cfg, batch, length, dtype)
        else:
            one = S.init_ssm_cache(cfg, batch, dtype)
        cache[f"layer{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (nb,) + a.shape).copy(), one
        )
    return cache


def decoder_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    cache: Params,
    *,
    patch_embeds: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """Run the full prompt, fill the cache, return last-position logits."""
    x = embed_inputs(params, cfg, tokens, patch_embeds)
    b, s, _ = x.shape
    positions = build_positions(cfg, b, s)
    needs_rope = "a" in cfg.pattern
    angles = L.rope_angles(cfg, positions) if needs_rope else jnp.zeros((b, s, 1))

    def block_body(x, inp):
        bp, c = inp
        new_c = {}
        for j in range(cfg.block_len):
            lp = bp[f"layer{j}"]
            kind = cfg.layer_kind(j)
            h = L.apply_norm(lp["norm1"], cfg, x)
            if kind == "a":
                h, new_c[f"layer{j}"] = L.prefill_into_cache(
                    lp["attn"], cfg, h, angles, c[f"layer{j}"],
                    window=cfg.sliding_window,
                )
            else:
                h, state, conv = S.ssm_forward_with_state(lp["ssm"], cfg, h)
                new_c[f"layer{j}"] = {"state": state, "conv": conv.astype(c[f"layer{j}"]["conv"].dtype)}
            x = x + h
            if cfg.has_ffn:
                h = L.apply_norm(lp["norm2"], cfg, x)
                is_moe = cfg.layer_is_moe(j)
                h, _ = L.ffn_apply(
                    lp["moe"] if is_moe else lp["mlp"], cfg, h, is_moe=is_moe
                )
                x = x + h
        return x, new_c

    x, new_cache = scan_blocks(cfg, block_body, x, (params["blocks"], cache))
    x = L.apply_norm(params["final_norm"], cfg, x[:, -1:])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


def decoder_decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B, 1)
    cache: Params,
    position: jax.Array,  # scalar int32 absolute position
) -> tuple[jax.Array, Params]:
    """One token through the stack against the cache. Returns (logits, cache).

    `position` is the absolute sequence index (cache bookkeeping).  For
    M-RoPE (VLM) the rotary streams advance as text_pos + grid_side after the
    vision prefix (matching build_positions), so the rope position is derived
    from it here — decode tokens are assumed to be text (after the prefix).
    """
    x = params["embed"][token]
    if cfg.rope_mode == "mrope":
        side = max(int(cfg.n_patches**0.5), 1)
        rope_position = position - cfg.n_patches + side
    else:
        rope_position = position

    def block_body(x, inp):
        bp, c = inp
        new_c = {}
        for j in range(cfg.block_len):
            lp = bp[f"layer{j}"]
            kind = cfg.layer_kind(j)
            h = L.apply_norm(lp["norm1"], cfg, x)
            if kind == "a":
                h, new_c[f"layer{j}"] = L.attn_decode(
                    lp["attn"], cfg, h, c[f"layer{j}"], position,
                    window=cfg.sliding_window, rope_position=rope_position,
                )
            else:
                h, new_c[f"layer{j}"] = S.ssm_decode(lp["ssm"], cfg, h, c[f"layer{j}"])
            x = x + h
            if cfg.has_ffn:
                h = L.apply_norm(lp["norm2"], cfg, x)
                is_moe = cfg.layer_is_moe(j)
                h, _ = L.ffn_apply(
                    lp["moe"] if is_moe else lp["mlp"], cfg, h, is_moe=is_moe
                )
                x = x + h
        return x, new_c

    x, new_cache = scan_blocks(cfg, block_body, x, (params["blocks"], cache))
    x = L.apply_norm(params["final_norm"], cfg, x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache
