"""Optimizers and LR schedules, from scratch (optax is not available).

Functional style: an `Optimizer` is (init_fn, update_fn) where
  state = init_fn(params)
  updates, state = update_fn(grads, state, params)
  params = apply_updates(params, updates)
"""

from .optimizers import (
    Optimizer,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    lion,
    sgd,
)
from .schedules import constant, cosine_warmup, linear_warmup

__all__ = [
    "Optimizer",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "constant",
    "cosine_warmup",
    "global_norm",
    "linear_warmup",
    "lion",
    "sgd",
]
