"""AdamW / SGD-momentum / Lion, plus global-norm clipping.

States are plain pytrees (dicts) so they checkpoint and shard like params:
the sharding rules in `repro.sharding` propagate a parameter's PartitionSpec
to its optimizer moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(
        jax.tree.reduce(
            jnp.add, jax.tree.map(lambda x: jnp.sum(jnp.square(x)), tree), 0.0
        )
    )


def clip_by_global_norm(tree: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def adamw(
    schedule: Schedule,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float | None = 1.0,
) -> Optimizer:
    """AdamW with decoupled weight decay; moments kept in f32."""

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
        }

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr = schedule(step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            return -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init=init, update=update)


def sgd(schedule: Schedule, *, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr = schedule(step)
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads
        )
        if nesterov:
            updates = jax.tree.map(
                lambda m, g: -lr * (momentum * m + g.astype(jnp.float32)), mom, grads
            )
        else:
            updates = jax.tree.map(lambda m: -lr * m, mom)
        return updates, {"step": step, "mom": mom}

    return Optimizer(init=init, update=update)


def lion(
    schedule: Schedule,
    *,
    b1: float = 0.9,
    b2: float = 0.99,
    weight_decay: float = 0.1,
) -> Optimizer:
    """Lion (sign-momentum) — cheap state (one moment), handy for huge models."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(step)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        def upd(m, g, p):
            return -lr * (
                jnp.sign(b1 * m + (1 - b1) * g) + weight_decay * p.astype(jnp.float32)
            )

        updates = jax.tree.map(upd, state["mu"], grads, params)
        mu = jax.tree.map(lambda m, g: b2 * m + (1 - b2) * g, state["mu"], grads)
        return updates, {"step": step, "mu": mu}

    return Optimizer(init=init, update=update)
