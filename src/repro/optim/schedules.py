"""Learning-rate schedules as step -> lr callables (jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    """Linear warmup then linear decay to final_frac * lr."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        decay = lr * (1.0 - (1.0 - final_frac) * frac)
        return jnp.where(step < warmup, warm, decay)

    return fn


def cosine_warmup(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac * lr."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, lr * cos)

    return fn
