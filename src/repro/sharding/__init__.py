"""Sharding rules: parameter/activation PartitionSpecs for the production mesh."""

from .rules import (
    batch_pspecs,
    cache_pspecs,
    data_axes,
    opt_state_pspecs,
    param_pspecs,
    token_pspec,
)

__all__ = [
    "batch_pspecs",
    "cache_pspecs",
    "data_axes",
    "opt_state_pspecs",
    "param_pspecs",
    "token_pspec",
]
