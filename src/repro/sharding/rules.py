"""Divisibility-aware sharding rules (DESIGN.md Sec. 6).

Policy:
  * tensor parallelism over the `model` axis: attention heads, FFN hidden,
    experts (expert parallelism), vocab;
  * data parallelism over (`pod`, `data`) for activations / batch dims;
  * optional FSDP (cfg.fsdp): the complementary weight dim additionally
    sharded over `data`;
  * every proposed axis is dropped if it does not divide the dim (e.g.
    smollm's 9 heads vs model=16 -> attention replicated on `model`), which
    guarantees all 10 x 4 combos lower while keeping sharding maximal
    elsewhere.

Optimizer moments inherit the parameter specs (so AdamW state shards
identically to weights).
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig

Pytree = Any

# parameter collections that carry a leading stacked-layer axis
_STACKED_ROOTS = ("blocks", "enc_layers", "dec_layers")


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def _fit(dim: int, mesh: Mesh, axes):
    """Return `axes` if it divides dim, else None (replicate fallback).

    Single-element tuples are unwrapped to the bare axis name: PartitionSpec
    treats ``("data",)`` and ``"data"`` as distinct entries, and downstream
    spec comparisons expect the scalar form.
    """
    if axes is None:
        return None

    def norm(a):
        if isinstance(a, tuple) and len(a) == 1:
            return a[0]
        return a

    if dim % _axis_size(mesh, axes) == 0:
        return norm(axes)
    if isinstance(axes, tuple) and len(axes) > 1:
        # try a prefix (e.g. drop 'pod' but keep 'data')
        for k in range(len(axes) - 1, 0, -1):
            sub = axes[:k]
            if dim % _axis_size(mesh, sub) == 0:
                return norm(sub)
    return None


def _keys(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def _param_spec(keys: list[str], shape: tuple[int, ...], mesh: Mesh, cfg: ModelConfig):
    """Spec for one parameter leaf, EXCLUDING any stacked-layer leading axis."""
    name = keys[-1]
    ctx = keys[-2] if len(keys) >= 2 else ""
    ctx2 = keys[-3] if len(keys) >= 3 else ""
    fsdp = "data" if cfg.fsdp else None

    def fit(dim, axes):
        return _fit(dim, mesh, axes)

    # --- embeddings / heads ---
    if name == "embed":
        return P(fit(shape[0], "model"), fit(shape[1], fsdp))
    if name == "lm_head":
        return P(fit(shape[0], fsdp), fit(shape[1], "model"))
    if name == "dec_pos":
        return P(None, None)

    # --- MoE expert weights: (E, d, f) / (E, f, d); expert parallel on model
    if ctx == "moe" and name in ("wg", "wu") and len(shape) == 3:
        return P(fit(shape[0], "model"), fit(shape[1], fsdp), None)
    if ctx == "moe" and name == "wd" and len(shape) == 3:
        return P(fit(shape[0], "model"), None, fit(shape[2], fsdp))
    if name == "router":
        return P(None, None)

    # --- attention projections ---
    if ctx in ("wq", "wk", "wv") and ctx2 in ("attn", "self_attn", "cross_attn"):
        if name == "w":
            return P(fit(shape[0], fsdp), fit(shape[1], "model"))
        return P(fit(shape[0], "model"))  # bias
    if ctx == "wo" and ctx2 in ("attn", "self_attn", "cross_attn"):
        if name == "w":
            return P(fit(shape[0], "model"), fit(shape[1], fsdp))
        return P(None)

    # --- dense MLP / shared expert: {wg,wu}: (d,f), wd: (f,d) ---
    if ctx in ("wg", "wu") and name == "w":
        return P(fit(shape[0], fsdp), fit(shape[1], "model"))
    if ctx == "wd" and name == "w":
        return P(fit(shape[0], "model"), fit(shape[1], fsdp))
    if ctx in ("wg", "wu", "wd") and name == "b":
        return P(fit(shape[0], "model") if ctx != "wd" else None)

    # --- SSM mixer ---
    if ctx == "in_proj" and name == "w":
        return P(fit(shape[0], fsdp), fit(shape[1], "model"))
    if ctx == "in_proj" and name == "b":
        return P(fit(shape[0], "model"))
    if ctx == "out_proj" and name == "w":
        return P(fit(shape[0], "model"), fit(shape[1], fsdp))
    if ctx == "out_proj" and name == "b":
        return P(None)
    if name == "conv_w":
        return P(None, fit(shape[1], "model"))
    if name == "conv_b":
        return P(fit(shape[0], "model"))
    if name in ("A_log", "D", "dt_bias"):
        return P(fit(shape[0], "model"))
    if name == "norm_scale":
        return P(fit(shape[0], "model"))

    # --- norms and anything else: replicate ---
    return P(*([None] * len(shape)))


def param_pspecs(cfg: ModelConfig, abstract_params: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpec pytree matching the params pytree."""

    def one(path, leaf):
        keys = _keys(path)
        shape = tuple(leaf.shape)
        stacked = bool(keys) and keys[0] in _STACKED_ROOTS
        if stacked:
            inner = _param_spec(keys, shape[1:], mesh, cfg)
            return P(None, *inner)
        return _param_spec(keys, shape, mesh, cfg)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_pspecs(cfg: ModelConfig, abstract_opt: Pytree, param_specs: Pytree) -> Pytree:
    """Moments inherit param specs; scalars replicate."""

    def build_with_key(k, v):
        if k in ("mu", "nu", "mom"):
            return param_specs
        return P()

    return {k: build_with_key(k, v) for k, v in abstract_opt.items()}


def token_pspec(mesh: Mesh, ndim: int = 2) -> P:
    """Batch-sharded spec for (B, S[, ...]) arrays."""
    return P(data_axes(mesh), *([None] * (ndim - 1)))


def batch_pspecs(cfg: ModelConfig, abstract_batch: Pytree, mesh: Mesh) -> Pytree:
    dp = data_axes(mesh)

    def one(path, leaf):
        b = leaf.shape[0]
        fitted = _fit(b, mesh, dp)
        return P(fitted, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(one, abstract_batch)


def cache_pspecs(cfg: ModelConfig, abstract_cache: Pytree, mesh: Mesh) -> Pytree:
    """KV / SSM cache specs.

    kv k/v:   (nb, B, L, K, hd)  -> (None, dp, None, model?, None)
    kv pos:   (nb, B, L)         -> (None, dp, None)
    ssm state:(nb, B, H, P, N)   -> (None, dp, model?, None, None)
    ssm conv: (nb, B, K-1, C)    -> (None, dp, None, model?)
    cross k/v:(nl, B, T, K, hd)  -> like kv without ring dim semantics
    """
    dp = data_axes(mesh)

    def one(path, leaf):
        keys = _keys(path)
        name = keys[-1]
        shape = tuple(leaf.shape)
        bdim = _fit(shape[1], mesh, dp)
        if name in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
            head_ax = _fit(shape[3], mesh, "model")
            if head_ax is not None:
                return P(None, bdim, None, head_ax, None)
            # kv heads don't divide the model axis (e.g. qwen1.5's 40 vs 16):
            # shard the cache LENGTH dim instead.  Attention over a
            # length-sharded cache stays local up to tiny softmax-stat and
            # output psums, vs all-gathering the entire cache (§Perf H1).
            return P(None, bdim, _fit(shape[2], mesh, "model"), None, None)
        if name == "pos":
            return P(None, bdim, _fit(shape[2], mesh, "model"))
        if name == "state" and len(shape) == 5:
            return P(None, bdim, _fit(shape[2], mesh, "model"), None, None)
        if name == "conv" and len(shape) == 4:
            return P(None, bdim, None, _fit(shape[3], mesh, "model"))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)
