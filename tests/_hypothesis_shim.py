"""Import indirection for `hypothesis` with a deterministic fallback.

The tier-1 property tests are written against the real hypothesis API
(declared in requirements-dev.txt).  On machines where hypothesis is not
installed, this shim provides a tiny deterministic stand-in so the suite
still collects and runs: each `@given` test executes `max_examples` examples
drawn from a PRNG seeded by the test's qualified name (stable across runs —
no shrinking, no database, no health checks).

Usage in test modules:

    from _hypothesis_shim import given, settings, strategies as st
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies

except ImportError:
    import random

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rnd: random.Random):
            return self._draw_fn(rnd)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=2**30):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda r: [
                    elements.draw(r)
                    for _ in range(r.randint(min_size, max_size))
                ]
            )

    def settings(max_examples: int = 10, **_ignored):
        """Record max_examples; every other hypothesis knob is a no-op here."""

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            def wrapper():
                n = getattr(
                    wrapper, "_shim_max_examples",
                    getattr(fn, "_shim_max_examples", 10),
                )
                rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    kwargs = {k: s.draw(rnd) for k, s in strategy_kwargs.items()}
                    fn(**kwargs)

            # Deliberately NOT functools.wraps: pytest must see a zero-arg
            # signature, or it would try to resolve the strategy parameters
            # as fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco


st = strategies

__all__ = ["given", "settings", "strategies", "st"]
