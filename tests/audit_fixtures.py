"""Seeded-violation fixtures for the static auditor's self-tests.

Each entry below plants exactly one violation class the jaxpr auditor
must catch — a constant-folded sweep rate, an ungated table write, a
host sync in a hot path, an implicit precision narrowing, a
cache-signature change across a value grid — plus one clean entry that
must produce no findings.  ``tests/test_audit.py`` runs them through
:func:`repro.analysis.jaxpr_audit.audit_entry` and asserts detection.

The AST-rule fixtures (which are parsed, never imported) live in
``tests/fixtures/core/``.
"""

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_audit import MAGIC, Built, EntrySpec


# --- seeded violations -----------------------------------------------------


def _synced(x):
    jax.debug.print("x = {}", x)  # seeded host sync
    return x * 2.0


def _narrowed(x):
    return x.astype(jnp.float16).astype(jnp.float32) * 2.0  # seeded narrow


def _gate_dropped(alive, x):
    del alive  # seeded: mask accepted, never used
    return x * 2.0


def _ungated_write(alive, table):
    out = table.at[0].set(1.0)  # seeded: write independent of the mask
    return out + alive.sum()  # (output still depends on alive)


def _baked_rate(rate):
    del rate  # seeded: the swept value was closed over instead
    return jnp.ones((3,), jnp.float32) * MAGIC


def _concretized_rate(rate):
    if rate > 0.5:  # seeded: Python branch on a traced value
        return jnp.ones((3,), jnp.float32)
    return jnp.zeros((3,), jnp.float32)


def _shape_varying_args(v):
    # seeded: the call signature (shape) depends on the swept value
    n = 2 if v < 0.5 else 3
    return (jnp.zeros((n,), jnp.float32),)


# --- one clean entry -------------------------------------------------------


def _clean(alive, table, rate):
    gated = table.at[0].set(alive[0].astype(table.dtype) * rate)
    return jnp.where(alive[:, None] != 0, gated, table)


def _clean_built():
    alive = jnp.ones((4,), jnp.int32)
    table = jnp.zeros((4, 3), jnp.float32)
    rate = jnp.float32(0.1)
    return Built(
        fn=_clean,
        args=(alive, table, rate),
        alive=(_clean, (alive, table, rate)),
        param=lambda r: _clean(alive, table, r),
        grid=(0.0, MAGIC, 0.9),
        build_call=lambda v: (alive, table, jnp.float32(v)),
    )


def _x():
    return jnp.arange(4, dtype=jnp.float32)


def _mask_and_table():
    return jnp.ones((4,), jnp.int32), jnp.zeros((4, 3), jnp.float32)


FULL = ("host-sync", "dtype", "alive", "alive-scatter", "param")

# (spec, rules the auditor MUST report for it)
SEEDED: list[tuple[EntrySpec, set[str]]] = [
    (
        EntrySpec(
            "fixture.host_sync",
            lambda: Built(fn=_synced, args=(_x(),)),
            checks=FULL,
        ),
        {"host-sync"},
    ),
    (
        EntrySpec(
            "fixture.narrow",
            lambda: Built(fn=_narrowed, args=(_x(),)),
            checks=FULL,
        ),
        {"dtype-narrow"},
    ),
    (
        EntrySpec(
            "fixture.gate_dropped",
            lambda: Built(alive=(_gate_dropped, (*_mask_and_table(),))),
            checks=FULL,
        ),
        {"alive-dead"},
    ),
    (
        EntrySpec(
            "fixture.ungated_write",
            lambda: Built(alive=(_ungated_write, (*_mask_and_table(),))),
            checks=FULL,
        ),
        {"alive-scatter"},
    ),
    (
        EntrySpec(
            "fixture.baked_rate",
            lambda: Built(param=_baked_rate),
            checks=FULL,
        ),
        {"const-leak"},
    ),
    (
        EntrySpec(
            "fixture.concretized_rate",
            lambda: Built(param=_concretized_rate),
            checks=FULL,
        ),
        {"const-leak"},
    ),
    (
        EntrySpec(
            "fixture.shape_varying_grid",
            lambda: Built(
                param=lambda r: jnp.zeros((2,), jnp.float32) * r,
                grid=(0.1, 0.9),
                build_call=_shape_varying_args,
            ),
            checks=FULL,
        ),
        {"grid-recompile"},
    ),
]

CLEAN = EntrySpec("fixture.clean", _clean_built, checks=FULL)
