import numpy as np
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only launch/dryrun.py forces
# 512 placeholder devices (and tests that need multiple devices run in a
# subprocess).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
