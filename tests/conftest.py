import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running subprocess smoke tests"
    )

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only launch/dryrun.py forces
# 512 placeholder devices (and tests that need multiple devices run in a
# subprocess).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module", autouse=True)
def _clear_jax_caches_between_modules():
    # The suite jit-compiles hundreds of programs across modules; on the
    # single-CPU container the accumulated XLA compiler state can segfault
    # a later module's backend_compile. Dropping compiled executables at
    # module boundaries keeps each module's compile pressure independent.
    yield
    try:
        import jax

        jax.clear_caches()
    except Exception:
        pass
