"""AST-lint fixture: every rule violated once.  Parsed, never imported."""

import numpy as np

import jax


@jax.jit
def synced_step(x):
    host = float(x)  # ast-host-sync: float
    val = x.item()  # ast-host-sync: item
    arr = np.asarray(x)  # ast-host-sync: np.asarray
    return x * host + val + arr.sum()


def dropped_gate(z, alive=None):
    if alive is None:
        pass
    return z * 2  # ast-alive-thread: mask accepted, never read


class LostReceipt:  # ast-receipt-json: no to_json
    pass
