"""AST-lint fixture: conventions followed — must lint clean."""

import jax
import jax.numpy as jnp


@jax.jit
def gated_step(z, alive):
    return jnp.where(alive != 0, z * 2, z)


def threaded(z, alive=None):
    if alive is None:
        alive = jnp.ones_like(z)
    return gated_step(z, alive)


class TraceReceipt:
    def to_json(self):
        return {"schema": "trace_receipt/1"}
