"""Per-architecture smoke tests: reduced variant of the same family runs one
forward + one train step on CPU; output shapes + finiteness asserted."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, concrete_batch, get_config
from repro.models import forward_logits, init_params, loss_fn, make_train_step
from repro.optim import apply_updates, sgd, constant

SEQ = 32
BATCH = 2


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_config(arch, variant="smoke")
    assert cfg.d_model <= 512 and cfg.n_experts <= 4 and cfg.n_blocks <= 2
    params = init_params(cfg, key)
    batch = concrete_batch(cfg, SEQ, BATCH)

    logits, _ = jax.jit(lambda p, b: forward_logits(cfg, p, b))(params, batch)
    s_text = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, s_text, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    opt = sgd(constant(1e-2))
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    p2, _, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()), params, p2),
    )
    assert moved > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_loss_decreases(arch, key):
    """A few steps on a fixed batch must reduce the loss (system sanity)."""
    cfg = get_config(arch, variant="smoke")
    params = init_params(cfg, key)
    batch = concrete_batch(cfg, SEQ, BATCH)
    opt = sgd(constant(5e-2), momentum=0.0)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    l0 = float(loss_fn(cfg, params, batch)[0])
    for _ in range(5):
        params, opt_state, m = step(params, opt_state, batch)
    l1 = float(loss_fn(cfg, params, batch)[0])
    assert l1 < l0, f"{arch}: loss did not decrease ({l0} -> {l1})"
