"""Static-auditor self-tests (ISSUE 10).

The load-bearing pins:

  * every seeded violation fixture (constant-folded rate, ungated
    scatter, dropped liveness gate, host sync, implicit narrowing,
    grid-signature drift) is DETECTED, and the clean fixture is not;
  * the AST rules fire on the parsed-only fixture tree and stay quiet
    on the conventions-followed one;
  * the real registry audits clean at float32 against the shrink-only
    baseline — in particular the traced-parameter checks statically
    prove the zero-recompile claim for the fault-rate / tau / beta
    grids without executing a sweep;
  * the compile ledger resolves every declared program, and snapshot /
    assert_within enforce the FROZEN and BUCKETS budgets.
"""

import os

import jax.numpy as jnp
import pytest

from repro.analysis import ast_lint, compile_ledger, jaxpr_audit
from repro.analysis.report import (
    Finding, compare_with_baseline, load_baseline,
)

import audit_fixtures

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "tools", "audit_baseline.json")
FIXTURE_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "fixtures")


# --- seeded jaxpr violations ----------------------------------------------


@pytest.mark.parametrize(
    "spec,expected",
    [(s, e) for s, e in audit_fixtures.SEEDED],
    ids=[s.name for s, _ in audit_fixtures.SEEDED],
)
def test_seeded_violation_detected(spec, expected):
    rules = {f.rule for f in jaxpr_audit.audit_entry(spec)}
    missing = expected - rules
    assert not missing, (
        f"{spec.name}: auditor missed seeded rule(s) {missing}; got {rules}"
    )


def test_clean_fixture_has_no_findings():
    findings = jaxpr_audit.audit_entry(audit_fixtures.CLEAN)
    assert findings == [], [str(f) for f in findings]


# --- AST rules -------------------------------------------------------------


def test_ast_rules_fire_on_bad_fixture():
    path = os.path.join(FIXTURE_ROOT, "core", "bad_ast.py")
    keys = {f.key for f in ast_lint.lint_file(path, FIXTURE_ROOT)}
    assert keys == {
        "ast-host-sync:core/bad_ast.py:synced_step:float",
        "ast-host-sync:core/bad_ast.py:synced_step:item",
        "ast-host-sync:core/bad_ast.py:synced_step:np.asarray",
        "ast-alive-thread:core/bad_ast.py:dropped_gate",
        "ast-receipt-json:core/bad_ast.py:LostReceipt",
    }


def test_ast_rules_quiet_on_clean_fixture():
    path = os.path.join(FIXTURE_ROOT, "core", "clean_ast.py")
    findings = ast_lint.lint_file(path, FIXTURE_ROOT)
    assert findings == [], [str(f) for f in findings]


def test_repo_ast_lint_is_baselined():
    findings = ast_lint.lint_paths(repo_root=ROOT)
    new, _ = compare_with_baseline(findings, load_baseline(BASELINE))
    assert new == [], [str(f) for f in new]


# --- the real registry at float32 -----------------------------------------


def test_registry_audits_clean_at_f32():
    findings = jaxpr_audit.run(trace_dtype="float32")
    new, _ = compare_with_baseline(findings, load_baseline(BASELINE))
    assert new == [], [str(f) for f in new]


def test_zero_recompile_grids_proven_statically():
    """Fault-rate / tau / beta sweeps: one program per shape, proven
    from jaxpr + cache signatures alone — nothing is executed."""
    entries = {
        e.name: e for e in jaxpr_audit.default_entries("float32")
    }
    swept = [
        "faults.plan", "faults.serial", "faults.crash",
        "pruning.keep", "stream.absorb",
    ]
    for name in swept:
        spec = entries[name]
        assert "param" in spec.checks, f"{name} lost its param check"
        bad = [
            f for f in jaxpr_audit.audit_entry(spec)
            if f.rule in ("const-leak", "grid-recompile")
        ]
        assert bad == [], [str(f) for f in bad]


# --- compile ledger --------------------------------------------------------


def test_ledger_audits_clean():
    findings = compile_ledger.audit()
    assert findings == [], [str(f) for f in findings]


def test_ledger_snapshot_frozen_budget():
    fn = compile_ledger.LEDGER["pruning.keep"].resolve()
    nbr_mask = jnp.ones((3, 2), bool)
    alive = jnp.ones((3,), jnp.int32)
    ecoef = jnp.ones((3, 2), jnp.float32)
    fn(nbr_mask, alive, ecoef, jnp.float32(0.1))  # warmup
    snap = compile_ledger.snapshot(("pruning.keep",))
    for tau in (0.0, 0.25, 0.9):  # value sweep: FROZEN ⇒ no growth
        fn(nbr_mask, alive, ecoef, jnp.float32(tau))
    growth = snap.assert_within(context="tau sweep")
    assert growth == {"pruning.keep": 0}
    assert snap.total_growth() == 0


def test_ledger_buckets_budget_requires_count():
    snap = compile_ledger.snapshot("daemon")  # BUCKETS-budgeted group
    with pytest.raises(ValueError, match="bucket"):
        snap.assert_within()
    snap.assert_within(buckets=0)  # no traffic since snapshot: within


def test_ledger_rejects_unknown_names():
    with pytest.raises(KeyError, match="not in the compile ledger"):
        compile_ledger.snapshot(("no.such.program",))


def test_churn_group_tracks_policy_variants():
    g = compile_ledger.churn_group(on_full="evict", donate=False)
    assert "stream.absorb_many.evict.copy" in g
    assert all(n in compile_ledger.LEDGER for n in g)


# --- baseline mechanics ----------------------------------------------------


def test_baseline_compare_shrink_only():
    f1 = Finding("rule-a", "spot", "t")
    f2 = Finding("rule-b", "other")
    baseline = {f1.key: "justified"}
    new, stale = compare_with_baseline([f1, f2], baseline)
    assert [f.key for f in new] == [f2.key]
    assert stale == []
    new, stale = compare_with_baseline([], baseline)
    assert new == [] and stale == [f1.key]
