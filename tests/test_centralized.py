"""Centralized regularized kernel least squares (paper Eq. 4/6)."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, strategies as st

from repro.core import Kernel, fit_krr
from repro.core.centralized import mse, predict


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10_000), n=st.integers(5, 40))
def test_normal_equations(seed, n):
    """c solves (K + lam I) c = y exactly."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(n, 2)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    kern = Kernel("rbf", gamma=0.7)
    lam = 0.1
    m = fit_krr(x, y, kern, lam)
    k = np.asarray(kern(jnp.asarray(x), jnp.asarray(x)))
    resid = (k + lam * np.eye(n)) @ np.asarray(m.coef) - y
    assert np.abs(resid).max() < 1e-3


def test_interpolation_limit():
    """lam -> 0 reproduces training targets (kernel matrix well conditioned)."""
    rng = np.random.default_rng(0)
    x = np.linspace(-1, 1, 10)[:, None].astype(np.float32)
    y = rng.normal(size=10).astype(np.float32)
    # gamma=20 keeps the Gram matrix well conditioned in f32
    m = fit_krr(x, y, Kernel("rbf", gamma=20.0), lam=1e-5)
    pred = predict(m, x)
    np.testing.assert_allclose(np.asarray(pred), y, atol=1e-3)


def test_regularization_shrinks_norm():
    rng = np.random.default_rng(1)
    x = rng.uniform(-1, 1, (30, 1)).astype(np.float32)
    y = rng.normal(size=30).astype(np.float32)
    kern = Kernel("rbf", gamma=1.0)
    small = fit_krr(x, y, kern, 1e-4)
    big = fit_krr(x, y, kern, 10.0)
    assert float(jnp.linalg.norm(big.coef)) < float(jnp.linalg.norm(small.coef))


def test_predict_via_pallas_matches_dense():
    rng = np.random.default_rng(2)
    x = rng.uniform(-1, 1, (40, 2)).astype(np.float32)
    y = rng.normal(size=40).astype(np.float32)
    m = fit_krr(x, y, Kernel("rbf", gamma=1.3), 0.05)
    xq = rng.uniform(-1, 1, (33, 2)).astype(np.float32)
    a = predict(m, xq, use_pallas=False)
    b = predict(m, xq, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_linear_kernel_recovers_line():
    """Case-1 sanity: linear kernel fits eta(x)=5x+5 with low noise."""
    rng = np.random.default_rng(3)
    x = rng.uniform(-1, 1, (60, 1)).astype(np.float32)
    y = (5 * x[:, 0] + 5 + 0.01 * rng.normal(size=60)).astype(np.float32)
    m = fit_krr(x, y, Kernel("linear", bias=1.0), lam=1e-3)
    xq = np.linspace(-1, 1, 21)[:, None].astype(np.float32)
    err = mse(m, xq, 5 * xq[:, 0] + 5)
    assert float(err) < 1e-2
