"""Churn soak (ISSUE-5 satellite): random interleavings of symmetric
joins, removals, streaming absorptions and sweeps must

  (a) preserve the Fejér monotonicity invariant after every event (each
      constraint set stays a subspace containing 0), and
  (b) leave a problem EQUIVALENT to a from-scratch ``make_batch_problem``
      at the trace's terminal membership: replaying the surviving
      measurements into a fresh build and running the serial engine from
      the same canonical init produces the same iterates to float noise
      (the incremental problem encodes the same constraint sets — the
      symmetric-join guarantee, extended across whole traces).

The mapping between the two builds: live incremental rows in ascending
row order become the fresh problem's sensors 0..n_live-1 (the serial
visit order is preserved), and surviving arrivals replay in absorption
order (per-sensor chronology — the slot-assignment invariant — is
preserved).
"""

import numpy as np
import jax.numpy as jnp
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    absorb_wave,
    add_sensor,
    build_topology,
    colored_sweep,
    default_lambdas,
    fusion,
    init_state,
    make_batch_problem,
    remove_sensor,
    serial_sweep,
    streaming,
    uniform_sensors,
    weighted_norm_sq,
)

KERN = Kernel("rbf", gamma=1.0)
LAM = 0.3
RADIUS = 0.55
N, B, SPARES = 12, 2, 3


def _build(seed):
    pos = uniform_sensors(N, d=1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.2 * rng.normal(size=(B, N))
    topo = build_topology(pos, RADIUS)
    d_max = int(np.asarray(topo.degrees).max()) + 6
    topo = build_topology(pos, RADIUS, d_max=d_max, n_max=N + SPARES)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((N,), LAM))
    return prob, colored_sweep(prob, init_state(prob), n_sweeps=3), d_max


def _assert_fejer_sweeps(prob, state, slack=1.06):
    prev = np.asarray(weighted_norm_sq(prob, state))
    for _ in range(2):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert np.isfinite(cur).all()
        assert (cur <= prev * slack + 1e-5).all(), (cur, prev)
        prev = cur
    return state


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 1000))
def test_churn_soak_fejer_and_terminal_rebuild_equivalence(seed):
    prob, state, d_max = _build(seed % 5)
    ev = np.random.default_rng(seed)
    arrivals = []  # (order, field, row, x, y) of absorbed arrivals

    for step in range(8):
        kind = int(ev.integers(0, 4))
        n_live = int(np.asarray(prob.alive[: prob.n]).sum())
        if kind == 0:  # symmetric join
            x = ev.uniform(-0.8, 0.8, size=1).astype(np.float32)
            ys_new = ev.normal(size=B).astype(np.float32)
            prob, state, _rec = add_sensor(prob, state, x, ys_new, lam=LAM)
            slot, ok = _rec.slot, _rec.joined
        elif kind == 1 and n_live > 6:  # removal of a random live sensor
            live = np.nonzero(np.asarray(prob.alive[: prob.n]))[0]
            victim = int(ev.choice(live))
            prob2, state2, ok = remove_sensor(prob, state, victim)
            if bool(ok):
                prob, state = prob2, state2
                arrivals = [a for a in arrivals if a[2] != victim]
        else:  # streaming absorption at a live sensor with headroom
            live = np.nonzero(np.asarray(prob.alive[: prob.n]))[0]
            s = int(ev.choice(live))
            f = int(ev.integers(0, B))
            cap = int(streaming.capacity_left(prob)[f, s])
            if cap >= 2:  # never run a row full: keeps the replay exact
                xa = (
                    np.asarray(prob.topology.positions[s])
                    + 0.05 * ev.normal(size=1)
                ).astype(np.float32)
                ya = float(ev.normal())
                prob, state, ok = streaming.absorb(prob, state, f, s, xa, ya)
                if bool(ok):
                    arrivals.append((len(arrivals), f, s, xa, ya))
        state = _assert_fejer_sweeps(prob, state)

    # ---- terminal membership: from-scratch rebuild + measurement replay
    alive = np.asarray(prob.alive[: prob.n])
    live = np.nonzero(alive)[0]
    row_to_fresh = {int(r): i for i, r in enumerate(live)}
    pos_f = np.asarray(prob.topology.positions)[live]
    ys_f = np.asarray(prob.y)[:, live]
    topo_f = build_topology(pos_f, RADIUS, d_max=d_max)
    prob_f = make_batch_problem(
        topo_f, KERN, ys_f, jnp.full((len(live),), LAM)
    )
    state_f = init_state(prob_f)
    # canonical init of the INCREMENTAL problem: Table-1 z0 = y plus the
    # surviving arrivals seeded at their reserved slots
    state_i = init_state(prob)
    zi = state_i.z
    for _, f, s, xa, ya in sorted(arrivals):
        prob_f, state_f, ok = streaming.absorb(
            prob_f, state_f, f, row_to_fresh[s], xa, ya
        )
        assert bool(ok)
        # the incremental problem already holds this arrival's system rows;
        # seed its message slot (what absorb's z-init did at event time)
        mask_s = np.asarray(prob.nbr_mask[f, s])
        idx_s = np.asarray(prob.nbr_idx[s])
        lanes = np.nonzero(
            mask_s & (idx_s >= prob.n)
            & np.isclose(
                np.asarray(prob.nbr_pos[f, s, :, 0]), xa[0], atol=1e-6
            )
        )[0]
        assert len(lanes) >= 1
        zi = zi.at[f, idx_s[lanes[0]]].set(ya)
    state_i = type(state_i)(z=zi, coef=state_i.coef)

    # same constraint sets, same init, same visit order => the serial
    # iterates themselves agree to float noise
    si = serial_sweep(prob, state_i, n_sweeps=3)
    sf = serial_sweep(prob_f, state_f, n_sweeps=3)
    z_i = np.asarray(si.z)
    z_f = np.asarray(sf.z)
    np.testing.assert_allclose(
        z_f[:, : len(live)], z_i[:, live], atol=2e-4,
        err_msg=f"terminal membership {live}",
    )


def test_lambda_repair_paper_rule_vs_unrepaired_drift():
    """ISSUE-6 satellite (a): joins grow adopters' degrees, so the paper's
    lambda_i = kappa / |N_i|^2 rule (Sec. 4.1) changes for them — but the
    join path historically never re-derived it.  With ``repair_lambda=True``
    every adopter's regularizer is re-derived per event (reusing the same
    O(degree) refactorization the join already does); without it the
    regularizers DRIFT off the paper rule under sustained churn.  This pins
    the repaired problem exactly to the rule, quantifies the unrepaired
    deviation, and records the accuracy drift between the two solutions."""
    kappa = 0.01
    pos = uniform_sensors(N, d=1, seed=2)
    rng = np.random.default_rng(3)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.1 * rng.normal(size=(B, N))
    topo0 = build_topology(pos, RADIUS)
    d_max = int(np.asarray(topo0.degrees).max()) + 6
    topo = build_topology(pos, RADIUS, d_max=d_max, n_max=N + SPARES)
    lam0 = default_lambdas(topo)[:N]
    probR = make_batch_problem(topo, KERN, ys, lam0)
    probU = make_batch_problem(topo, KERN, ys, lam0)
    stateR = colored_sweep(probR, init_state(probR), n_sweeps=3)
    stateU = colored_sweep(probU, init_state(probU), n_sweeps=3)

    adopted_any = np.zeros((N + SPARES,), bool)
    for xj in (-0.5, 0.1, 0.6):  # sustained churn: three joins, no leaves
        x = np.asarray([xj], np.float32)
        yn = rng.normal(size=B).astype(np.float32)
        probR, stateR, recR = add_sensor(
            probR, stateR, x, yn, lam=-1.0, repair_lambda=True, kappa=kappa
        )
        probU, stateU, recU = add_sensor(probU, stateU, x, yn, lam=-1.0)
        assert bool(recR.joined) and bool(recU.joined)
        assert np.array_equal(
            np.asarray(recR.adopted_mask), np.asarray(recU.adopted_mask)
        )
        ad = np.asarray(recR.adopted)[np.asarray(recR.adopted_mask)]
        adopted_any[np.unique(ad)] = True

    # repaired: every LIVE sensor sits exactly on the paper rule for its
    # CURRENT degree (adopters included — their degrees grew per join)
    deg = np.asarray(probR.topology.degrees).astype(np.float32)
    alive = np.asarray(probR.alive[:-1]) & (deg > 0)
    rule = kappa / np.maximum(deg, 1.0) ** 2
    np.testing.assert_allclose(
        np.asarray(probR.lam_pad[:-1])[alive], rule[alive], rtol=1e-6,
        err_msg="repair_lambda must re-derive kappa/|N_i|^2 per event",
    )

    # unrepaired: the adopters kept their BUILD-time regularizers, which
    # now violate the rule for their grown degrees
    lamU = np.asarray(probU.lam_pad[:-1])
    grown = adopted_any & alive
    assert grown.any()
    rel_dev = np.abs(lamU[grown] - rule[grown]) / rule[grown]
    assert rel_dev.max() > 0.15, rel_dev  # (deg/(deg+1))^2 >= ~17% off

    # record the accuracy drift of NOT repairing: both problems converge
    # (Fejér holds either way — lambda only reweights the projections) but
    # to different solutions; the repaired one follows the paper's rule.
    stateR = colored_sweep(probR, stateR, n_sweeps=6)
    stateU = colored_sweep(probU, stateU, n_sweeps=6)
    truth = np.sin(np.pi * pos[:, 0])[None]
    rmse = {}
    for tag, (p, s) in (("repaired", (probR, stateR)),
                        ("unrepaired", (probU, stateU))):
        preds = fusion.evaluate_sensors(p, s, pos)
        fused = fusion.knn_fusion(
            preds, p.topology.positions, pos, k=3, alive=p.alive[:-1]
        )
        rmse[tag] = np.sqrt(np.mean((np.asarray(fused) - truth) ** 2))
        assert np.isfinite(rmse[tag])
    gap = abs(rmse["repaired"] - rmse["unrepaired"])
    print(f"lambda-repair accuracy drift under 3-join churn: "
          f"repaired={rmse['repaired']:.4f} unrepaired={rmse['unrepaired']:.4f} "
          f"gap={gap:.2e}")
    # the two solutions genuinely diverged (the drift is real, if small
    # at this scale — it compounds with churn volume)
    assert not np.array_equal(np.asarray(stateR.z), np.asarray(stateU.z))


@settings(deadline=None, max_examples=3)
@given(seed=st.integers(0, 1000))
def test_drift_soak_beta_tracking_under_churn(seed):
    """ISSUE-6 drift soak: random interleavings of dense measurement waves,
    join/leave churn and sweep bursts on a DRIFTING field, with a static
    (beta=1) and a forgetting (beta=0.5) field sharing the batch.  Pins:
    the factors stay exactly factorized, sweeps stay Fejér between ticks,
    and the forgetting field's steady-state tracking error stays bounded
    while at least matching the static field."""
    n, b, v = 30, 2, 0.06
    rng = np.random.default_rng(seed)
    pos = np.sort(rng.uniform(-1, 1, size=(n, 1)).astype(np.float32), axis=0)
    topo = build_topology(pos, 0.25)
    d_max = int(np.asarray(topo.degrees).max()) + 10
    topo = build_topology(pos, 0.25, d_max=d_max, n_max=n + 2)

    def truth(x, t):
        return np.sin(np.pi * (x[..., 0] - v * t)).astype(np.float32)

    ys0 = truth(pos, 0)[None] + 0.01 * rng.normal(size=(b, n)).astype(
        np.float32
    )
    prob = make_batch_problem(
        topo, Kernel("rbf", gamma=10.0), ys0, jnp.full((n,), 0.01),
        beta=np.asarray([1.0, 0.5], np.float32),
    )
    state = colored_sweep(prob, init_state(prob), n_sweeps=4)

    hist = []
    for t in range(1, 15):
        kind = int(rng.integers(0, 3))
        if kind == 2:  # join/leave churn event with lambda repair
            x = rng.uniform(-0.8, 0.8, size=1).astype(np.float32)
            yn = truth(x[None], t)[0] * np.ones((b,), np.float32)
            prob, state, rec = add_sensor(
                prob, state, x, yn, lam=0.01, repair_lambda=True
            )
            prob, state, _ = remove_sensor(
                prob, state, rec.slot, repair_lambda=True
            )
        # dense measurement wave at the current truth (every round: the
        # forgetting regime needs fresh arrivals to outvote stale lanes)
        xs = np.zeros((b, prob.n, 1), np.float32)
        xs[:, :n] = pos[None] + rng.normal(
            scale=0.01, size=(b, n, 1)
        ).astype(np.float32)
        ysw = np.zeros((b, prob.n), np.float32)
        ysw[:, :n] = truth(xs[:, :n], t) + 0.01 * rng.normal(
            size=(b, n)
        ).astype(np.float32)
        amask = np.zeros((b, prob.n), bool)
        amask[:, :n] = True
        prob, state, _ = absorb_wave(
            prob, state, xs, ysw, mask=amask, on_full="evict"
        )
        state = colored_sweep(
            prob, state, n_sweeps=8 if kind != 1 else 12
        )
        preds = fusion.evaluate_sensors(prob, state, pos)
        fused = fusion.knn_fusion(
            preds, prob.topology.positions, pos, k=3, alive=prob.alive[:-1]
        )
        hist.append(np.sqrt(np.mean(
            (np.asarray(fused) - truth(pos, t)[None]) ** 2, axis=-1
        )))

    err = float(jnp.max(jnp.abs(streaming.rebuild_chol(prob) - prob.chol)))
    assert err < 5e-5, err
    _assert_fejer_sweeps(prob, state)
    ss = np.mean(np.stack(hist[-4:]), axis=0)  # (B,) steady-state
    assert np.isfinite(ss).all()
    # pinned steady-state tracking bound for the forgetting field, and it
    # never does worse than the static field it shares the trace with
    assert ss[1] < 0.45, f"beta=0.5 steady-state RMSE {ss}"
    assert ss[1] <= ss[0] + 0.05, f"forgetting must not hurt tracking {ss}"


@settings(deadline=None, max_examples=3)
@given(seed=st.integers(0, 1000))
def test_fault_soak_identity_monotone_degradation_rollback(seed):
    """ISSUE-7 fault soak on CHURNED problems (a join and a leave first, so
    the delivered gates compose with real liveness masks).  Pins:

      (i)   an all-delivered mask reproduces the fault-free iterates
            BITWISE for every engine;
      (ii)  degradation is monotone in the drop rate: the key-averaged
            distance to the converged fault-free solution only grows as
            the rate rises (delivery masks are monotonically coupled
            under one key — u >= p thresholding);
      (iii) checkpoint -> faulty training -> rollback restores every
            problem/state table bitwise.
    """
    import tempfile

    import jax

    from repro import checkpoint as ckpt
    from repro.core import faults

    prob, state, _ = _build(seed % 5)
    ev = np.random.default_rng(seed)
    x = ev.uniform(-0.8, 0.8, size=1).astype(np.float32)
    prob, state, rec = add_sensor(
        prob, state, x, ev.normal(size=B).astype(np.float32), lam=LAM
    )
    live = np.nonzero(np.asarray(prob.alive[: prob.n]))[0]
    prob2, state2, ok = remove_sensor(prob, state, int(ev.choice(live)))
    if bool(ok):
        prob, state = prob2, state2

    # (i) all-delivered == fault-free, bitwise, engine by engine
    ones = jnp.ones((2,) + prob.nbr_idx.shape, bool)
    for engine in ("serial", "plan", "onehot", "pallas"):
        if engine == "serial":
            ref = serial_sweep(prob, state, n_sweeps=2)
            out = serial_sweep(prob, state, n_sweeps=2, delivered=ones)
        else:
            ref = colored_sweep(prob, state, n_sweeps=2, engine=engine)
            out = colored_sweep(
                prob, state, n_sweeps=2, engine=engine, delivered=ones
            )
        assert np.array_equal(np.asarray(out.z), np.asarray(ref.z)), engine
        assert np.array_equal(
            np.asarray(out.coef), np.asarray(ref.coef)
        ), engine

    # (ii) monotone degradation vs the converged fault-free solution
    zstar = colored_sweep(prob, state, n_sweeps=60).z
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    dist = []
    for p in (0.0, 0.25, 0.6):
        dist.append(np.mean([
            float(jnp.linalg.norm(
                faults.faulty_sweep(
                    prob, state, faults.make_fault_model(p), k, n_sweeps=8
                ).z - zstar
            ))
            for k in keys
        ]))
    assert dist[0] <= dist[1] * 1.05 + 1e-6, dist
    assert dist[1] <= dist[2] * 1.05 + 1e-6, dist

    # (iii) checkpoint -> faulty training -> rollback, bitwise
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_train(d, 0, prob, state)
        mutated = faults.faulty_sweep(
            prob, state, faults.make_fault_model(0.5), keys[0], n_sweeps=4
        )
        assert not np.array_equal(np.asarray(mutated.z), np.asarray(state.z))
        prob_r, state_r = ckpt.restore_train(d, 0, prob, mutated)
    for a, b in zip(jax.tree.leaves(prob), jax.tree.leaves(prob_r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state_r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
