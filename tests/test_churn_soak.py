"""Churn soak (ISSUE-5 satellite): random interleavings of symmetric
joins, removals, streaming absorptions and sweeps must

  (a) preserve the Fejér monotonicity invariant after every event (each
      constraint set stays a subspace containing 0), and
  (b) leave a problem EQUIVALENT to a from-scratch ``make_batch_problem``
      at the trace's terminal membership: replaying the surviving
      measurements into a fresh build and running the serial engine from
      the same canonical init produces the same iterates to float noise
      (the incremental problem encodes the same constraint sets — the
      symmetric-join guarantee, extended across whole traces).

The mapping between the two builds: live incremental rows in ascending
row order become the fresh problem's sensors 0..n_live-1 (the serial
visit order is preserved), and surviving arrivals replay in absorption
order (per-sensor chronology — the slot-assignment invariant — is
preserved).
"""

import numpy as np
import jax.numpy as jnp
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    add_sensor,
    build_topology,
    colored_sweep,
    init_state,
    make_batch_problem,
    remove_sensor,
    serial_sweep,
    streaming,
    uniform_sensors,
    weighted_norm_sq,
)

KERN = Kernel("rbf", gamma=1.0)
LAM = 0.3
RADIUS = 0.55
N, B, SPARES = 12, 2, 3


def _build(seed):
    pos = uniform_sensors(N, d=1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.2 * rng.normal(size=(B, N))
    topo = build_topology(pos, RADIUS)
    d_max = int(np.asarray(topo.degrees).max()) + 6
    topo = build_topology(pos, RADIUS, d_max=d_max, n_max=N + SPARES)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((N,), LAM))
    return prob, colored_sweep(prob, init_state(prob), n_sweeps=3), d_max


def _assert_fejer_sweeps(prob, state, slack=1.06):
    prev = np.asarray(weighted_norm_sq(prob, state))
    for _ in range(2):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert np.isfinite(cur).all()
        assert (cur <= prev * slack + 1e-5).all(), (cur, prev)
        prev = cur
    return state


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 1000))
def test_churn_soak_fejer_and_terminal_rebuild_equivalence(seed):
    prob, state, d_max = _build(seed % 5)
    ev = np.random.default_rng(seed)
    arrivals = []  # (order, field, row, x, y) of absorbed arrivals

    for step in range(8):
        kind = int(ev.integers(0, 4))
        n_live = int(np.asarray(prob.alive[: prob.n]).sum())
        if kind == 0:  # symmetric join
            x = ev.uniform(-0.8, 0.8, size=1).astype(np.float32)
            ys_new = ev.normal(size=B).astype(np.float32)
            prob, state, slot, ok = add_sensor(prob, state, x, ys_new, lam=LAM)
        elif kind == 1 and n_live > 6:  # removal of a random live sensor
            live = np.nonzero(np.asarray(prob.alive[: prob.n]))[0]
            victim = int(ev.choice(live))
            prob2, state2, ok = remove_sensor(prob, state, victim)
            if bool(ok):
                prob, state = prob2, state2
                arrivals = [a for a in arrivals if a[2] != victim]
        else:  # streaming absorption at a live sensor with headroom
            live = np.nonzero(np.asarray(prob.alive[: prob.n]))[0]
            s = int(ev.choice(live))
            f = int(ev.integers(0, B))
            cap = int(streaming.capacity_left(prob)[f, s])
            if cap >= 2:  # never run a row full: keeps the replay exact
                xa = (
                    np.asarray(prob.topology.positions[s])
                    + 0.05 * ev.normal(size=1)
                ).astype(np.float32)
                ya = float(ev.normal())
                prob, state, ok = streaming.absorb(prob, state, f, s, xa, ya)
                if bool(ok):
                    arrivals.append((len(arrivals), f, s, xa, ya))
        state = _assert_fejer_sweeps(prob, state)

    # ---- terminal membership: from-scratch rebuild + measurement replay
    alive = np.asarray(prob.alive[: prob.n])
    live = np.nonzero(alive)[0]
    row_to_fresh = {int(r): i for i, r in enumerate(live)}
    pos_f = np.asarray(prob.topology.positions)[live]
    ys_f = np.asarray(prob.y)[:, live]
    topo_f = build_topology(pos_f, RADIUS, d_max=d_max)
    prob_f = make_batch_problem(
        topo_f, KERN, ys_f, jnp.full((len(live),), LAM)
    )
    state_f = init_state(prob_f)
    # canonical init of the INCREMENTAL problem: Table-1 z0 = y plus the
    # surviving arrivals seeded at their reserved slots
    state_i = init_state(prob)
    zi = state_i.z
    for _, f, s, xa, ya in sorted(arrivals):
        prob_f, state_f, ok = streaming.absorb(
            prob_f, state_f, f, row_to_fresh[s], xa, ya
        )
        assert bool(ok)
        # the incremental problem already holds this arrival's system rows;
        # seed its message slot (what absorb's z-init did at event time)
        mask_s = np.asarray(prob.nbr_mask[f, s])
        idx_s = np.asarray(prob.nbr_idx[s])
        lanes = np.nonzero(
            mask_s & (idx_s >= prob.n)
            & np.isclose(
                np.asarray(prob.nbr_pos[f, s, :, 0]), xa[0], atol=1e-6
            )
        )[0]
        assert len(lanes) >= 1
        zi = zi.at[f, idx_s[lanes[0]]].set(ya)
    state_i = type(state_i)(z=zi, coef=state_i.coef)

    # same constraint sets, same init, same visit order => the serial
    # iterates themselves agree to float noise
    si = serial_sweep(prob, state_i, n_sweeps=3)
    sf = serial_sweep(prob_f, state_f, n_sweeps=3)
    z_i = np.asarray(si.z)
    z_f = np.asarray(sf.z)
    np.testing.assert_allclose(
        z_f[:, : len(live)], z_i[:, live], atol=2e-4,
        err_msg=f"terminal membership {live}",
    )
