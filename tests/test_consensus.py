"""SOP-consensus (gossip) properties — the paper's technique in parameter
space (DESIGN.md Sec. 3)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, strategies as st

from repro.core import consensus


def _stacked(seed, n, shapes=((4, 3), (5,))):
    rng = np.random.default_rng(seed)
    return {
        f"p{i}": jnp.asarray(rng.normal(size=(n,) + s).astype(np.float32))
        for i, s in enumerate(shapes)
    }


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 1000), logn=st.integers(1, 4))
def test_hypercube_sweep_equals_global_mean(seed, logn):
    """Lemma 3.1 analogue: the complete pairing sweep == all-reduce mean."""
    n = 2**logn
    tree = _stacked(seed, n)
    out = consensus.sim_gossip_sweep(tree, consensus.hypercube_schedule(n))
    for k, v in out.items():
        mean = jnp.mean(tree[k], axis=0, keepdims=True)
        np.testing.assert_allclose(
            np.asarray(v), np.broadcast_to(np.asarray(mean), v.shape), atol=1e-5
        )


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 1000), n=st.sampled_from([4, 6, 8]), rounds=st.integers(1, 12))
def test_ring_gossip_fejer_monotone(seed, n, rounds):
    """Disagreement sum_i ||theta_i - mean||^2 never increases (Lemma 2.1)."""
    tree = _stacked(seed, n)
    sched = consensus.ring_schedule(n)
    d_prev = float(consensus.sim_consensus_sq_distance(tree))
    for r in range(rounds):
        tree = consensus.sim_pairwise_project(tree, sched[r % 2])
        d = float(consensus.sim_consensus_sq_distance(tree))
        assert d <= d_prev * (1 + 1e-5) + 1e-7
        d_prev = d


def test_ring_gossip_converges_to_mean():
    tree = _stacked(3, 8)
    means = {k: jnp.mean(v, axis=0, keepdims=True) for k, v in tree.items()}
    for r in range(200):
        tree = consensus.sim_pairwise_project(
            tree, consensus.ring_schedule(8)[r % 2]
        )
    for k, v in tree.items():
        np.testing.assert_allclose(
            np.asarray(v), np.broadcast_to(np.asarray(means[k]), v.shape), atol=1e-4
        )


def test_pairwise_projection_preserves_sum():
    """Averaging projections conserve the replica sum (mass conservation)."""
    tree = _stacked(5, 8)
    total0 = {k: np.asarray(v.sum(0)) for k, v in tree.items()}
    tree2 = consensus.sim_gossip_sweep(tree, consensus.ring_schedule(8))
    for k, v in tree2.items():
        np.testing.assert_allclose(np.asarray(v.sum(0)), total0[k], atol=1e-4)


def test_device_gossip_matches_sim_subprocess():
    """ppermute-based device implementation == host simulator (4 devices)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import consensus

n = 4
rng = np.random.default_rng(0)
stacked = {"w": jnp.asarray(rng.normal(size=(n, 3, 2)).astype(np.float32))}
sched = consensus.hypercube_schedule(n)
sim = consensus.sim_gossip_sweep(stacked, sched)

mesh = compat.make_mesh((n,), ("data",))
def dev(tree):
    t = jax.tree.map(lambda a: a[0], tree)
    for s in sched:
        t = consensus.pairwise_project(t, "data", s)
    return jax.tree.map(lambda a: a[None], t)
out = jax.jit(compat.shard_map(dev, mesh=mesh, in_specs=(P("data"),), out_specs=P("data")))(stacked)
assert np.allclose(np.asarray(out["w"]), np.asarray(sim["w"]), atol=1e-5)
d = jax.jit(compat.shard_map(
    lambda t: consensus.consensus_sq_distance(jax.tree.map(lambda a: a[0], t), "data")[None],
    mesh=mesh, in_specs=(P("data"),), out_specs=P("data")))(out)
assert float(np.asarray(d)[0]) < 1e-8
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         env=env, cwd=root)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_allreduce_mode_keeps_replicas_identical_subprocess():
    """dp_mode=allreduce: stacked replicas stay bit-identical across steps."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs import get_config
from repro.models import init_params, make_train_step
from repro.optim import sgd, constant
from repro.data import synthetic_lm_stream

cfg = get_config("smollm-135m", variant="smoke")
opt = sgd(constant(1e-2))
step = make_train_step(cfg, opt, dp_axis="data", dp_mode="allreduce")
n = 4
mesh = compat.make_mesh((n,), ("data",))
params = init_params(cfg, jax.random.PRNGKey(0))
opt_state = opt.init(params)
stack = lambda a: jnp.broadcast_to(a[None], (n,) + a.shape)
params = jax.tree.map(stack, params); opt_state = jax.tree.map(stack, opt_state)
stream = synthetic_lm_stream(cfg.vocab_size, 32, 8, seed=0)

def dev(p, o, b):
    p1 = jax.tree.map(lambda a: a[0], p); o1 = jax.tree.map(lambda a: a[0], o)
    p1, o1, m = step(p1, o1, b)
    return jax.tree.map(lambda a: a[None], p1), jax.tree.map(lambda a: a[None], o1)
j = jax.jit(compat.shard_map(dev, mesh=mesh, in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"))))
for i in range(3):
    b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
    params, opt_state = j(params, opt_state, b)
w = np.asarray(jax.tree.leaves(params)[0])
for r in range(1, 4):
    assert np.array_equal(w[0], w[r]), r
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         env=env, cwd=root)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_schedule_dispatch_and_validation():
    """`schedule()` is the named entry point serve/bench configs use; the
    pairings it returns must be involutions (pairwise_project's contract)."""
    assert consensus.schedule("hypercube", 8) == consensus.hypercube_schedule(8)
    assert consensus.schedule("ring", 6) == consensus.ring_schedule(6)
    for name, n in (("hypercube", 8), ("ring", 6)):
        for partners in consensus.schedule(name, n):
            assert [partners[p] for p in partners] == list(range(n)), (
                name, partners,
            )
    import pytest

    with pytest.raises(ValueError):
        consensus.schedule("bogus", 4)
    with pytest.raises(ValueError):
        consensus.hypercube_schedule(6)  # not a power of two
    with pytest.raises(ValueError):
        consensus.ring_schedule(5)  # odd


def test_one_sided_ring_schedule_shifts():
    """The Cimmino-style schedule is a pair of mutually inverse shifts."""
    n = 6
    fwd, bwd = consensus.one_sided_ring_schedule(n)
    assert fwd == [(i + 1) % n for i in range(n)]
    assert [fwd[b] for b in bwd] == list(range(n))


def test_gossip_round_and_neighborhood_average_device_subprocess():
    """Device-mode coverage of the collectives the stacked trainer uses:
    gossip_round's lax.switch pairing == the host sim of the same pairing;
    neighborhood_average == the explicit (x_{i-1}+x_i+x_{i+1})/3 stencil
    and contracts the disagreement; allreduce_average == the global mean
    (paper Lemma 3.1's complete-graph special case)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import consensus

n = 4
rng = np.random.default_rng(1)
stacked = {"w": jnp.asarray(rng.normal(size=(n, 3, 2)).astype(np.float32))}
sched = consensus.ring_schedule(n)
mesh = compat.make_mesh((n,), ("data",))
sm = lambda f: jax.jit(compat.shard_map(
    f, mesh=mesh, in_specs=(P("data"),), out_specs=P("data")))

for r in range(3):  # round-robin switch over the schedule
    dev = sm(lambda t, r=r: jax.tree.map(
        lambda a: a[None],
        consensus.gossip_round(
            jax.tree.map(lambda a: a[0], t), "data", sched, jnp.int32(r)
        ),
    ))(stacked)
    sim = consensus.sim_pairwise_project(stacked, sched[r % len(sched)])
    assert np.allclose(np.asarray(dev["w"]), np.asarray(sim["w"]), atol=1e-6), r

out = sm(lambda t: jax.tree.map(
    lambda a: a[None],
    consensus.neighborhood_average(jax.tree.map(lambda a: a[0], t), "data", n),
))(stacked)
w = np.asarray(stacked["w"])
stencil = (w + np.roll(w, 1, axis=0) + np.roll(w, -1, axis=0)) / 3.0
assert np.allclose(np.asarray(out["w"]), stencil, atol=1e-6)

def disagreement(tree):
    v = np.asarray(tree["w"])
    return float(np.sum((v - v.mean(0, keepdims=True)) ** 2))
tree = stacked
for _ in range(40):  # repeated averaging drives consensus to the mean
    prev = disagreement(tree)
    tree = sm(lambda t: jax.tree.map(
        lambda a: a[None],
        consensus.neighborhood_average(
            jax.tree.map(lambda a: a[0], t), "data", n
        ),
    ))(tree)
    assert disagreement(tree) <= prev * (1 + 1e-6) + 1e-9
assert np.allclose(
    np.asarray(tree["w"]),
    np.asarray(stacked["w"]).mean(0, keepdims=True), atol=1e-4,
)

avg = sm(lambda t: jax.tree.map(
    lambda a: a[None],
    consensus.allreduce_average(jax.tree.map(lambda a: a[0], t), "data"),
))(stacked)
assert np.allclose(
    np.asarray(avg["w"]),
    np.asarray(stacked["w"]).mean(0, keepdims=True), atol=1e-6,
)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=root)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
