"""Serving daemon (ISSUE 8 tentpole): bucketed coalescing queue,
double-buffered snapshot isolation, supervised degraded mode, and
crash-kill -> warm-restart.

The load-bearing pins:

  * ANY interleaving of request sizes drains through the daemon queue
    with <= O(log max_batch_rows) distinct compiled serving programs —
    the PR-3 power-of-two bucketing property, extended to the coalescing
    dispatcher and counted via the jit cache (hypothesis drives the
    interleavings);
  * answers are EXACT under coalescing + padding: each request's slice
    matches the dense oracle regardless of which batch it rode in;
  * a held snapshot keeps serving its own answers bitwise while training
    ticks publish new versions (double buffering — no torn reads);
  * admission control sheds with explicit receipts (queue_full /
    deadline), never silently;
  * a poisoned training tick rolls back, does NOT publish, flags the
    daemon degraded, and queries keep flowing from the last good
    snapshot; the next healthy tick recovers;
  * ``pad_arrivals`` sentinel padding is a bitwise no-op on the absorbed
    problem/state (the dead-row gates make padded windows exact);
  * a daemon rebuilt over the same templates warm-restarts from the
    latest intact checkpoint bitwise (digest + served answers), straight
    through a SIGKILLed serving process (subprocess).
"""

import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    build_topology,
    fusion,
    init_state,
    make_batch_problem,
    make_serving_plan,
    streaming,
    uniform_sensors,
)
from repro.analysis import compile_ledger
from repro.core import faults
from repro.kernels.ops import bucket_rows
from repro.launch.daemon import Daemon, DaemonConfig

KERN = Kernel("rbf", gamma=1.0)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(n=24, b=3, seed=0, headroom=4, n_max=None):
    pos = uniform_sensors(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    ys = (
        np.sin(np.pi * freq * pos[None, :, 0])
        + 0.2 * rng.normal(size=(b, n))
    ).astype(np.float32)
    topo = build_topology(pos, 0.6)
    d_max = int(np.asarray(topo.degrees).max()) + headroom
    topo = build_topology(pos, 0.6, d_max=d_max, n_max=n_max)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), 0.1))
    return prob, init_state(prob), pos, rng


# One problem shared by every hypothesis example: the jit caches are
# process-global, so the bucket-count bound must hold ACROSS examples —
# exactly the sustained-traffic property the daemon claims.
_FIX = None
_CACHE_BASE: dict = {}
_BUCKETS_SEEN: set = set()


def _fix():
    global _FIX
    if _FIX is None:
        _FIX = _build()
    return _FIX


@settings(deadline=None, max_examples=15)
@given(sizes=st.lists(st.integers(1, 60), min_size=1, max_size=12))
def test_any_interleaving_drains_through_buckets(sizes):
    """The daemon queue inherits the O(log Q) program bound: over ALL
    interleavings of request sizes, the serving programs compiled grow at
    most one per distinct power-of-two bucket — and every request's
    answer slice is exact vs the dense oracle."""
    prob, state, pos, _ = _fix()
    if not _CACHE_BASE:
        _CACHE_BASE["snap"] = compile_ledger.snapshot("daemon")
    d = Daemon(prob, state, config=DaemonConfig(k=3, max_batch_rows=64))
    rng = np.random.default_rng(sum(sizes))
    grids = [
        rng.uniform(-0.9, 0.9, size=(q, 1)).astype(np.float32)
        for q in sizes
    ]
    tickets = [d.submit(g) for g in grids]
    assert all(t.admitted for t in tickets)
    answers = {a.id: a for a in d.pump()}
    assert len(answers) == len(sizes)
    _BUCKETS_SEEN.update(d.buckets_hit)
    for t, g in zip(tickets, grids):
        got = answers[t.id].values
        want = np.asarray(fusion.fuse(prob, state, g, "knn", k=3))
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, atol=1e-5)
    # every bucket is a power of two no larger than the batch cap's bucket
    assert all(
        b & (b - 1) == 0 and b <= bucket_rows(64) for b in _BUCKETS_SEEN
    )
    _CACHE_BASE["snap"].assert_within(
        buckets=len(_BUCKETS_SEEN), context="daemon interleavings"
    )


def test_pad_arrivals_is_bitwise_noop():
    """Absorbing a window padded with sentinel-row arrivals must equal the
    unpadded absorb bitwise — problem, state, and real-row receipt flags."""
    prob, state, pos, rng = _build(seed=3)
    a = 5
    fs = rng.integers(0, 3, size=a).astype(np.int32)
    ss = rng.integers(0, prob.n, size=a).astype(np.int32)
    xs = (pos[ss] + 0.05 * rng.normal(size=(a, 1))).astype(np.float32)
    ys = rng.normal(size=a).astype(np.float32)
    p0, s0, r0 = streaming.absorb_many(prob, state, fs, ss, xs, ys)
    fp, sp, xp, yp, real = streaming.pad_arrivals(prob, fs, ss, xs, ys, 8)
    assert real.sum() == a and real.shape == (8,)
    p1, s1, r1 = streaming.absorb_many(prob, state, fp, sp, xp, yp)
    for l0, l1 in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        assert np.array_equal(np.asarray(l0), np.asarray(l1))
    for l0, l1 in zip(jax.tree.leaves(s0), jax.tree.leaves(s1)):
        assert np.array_equal(np.asarray(l0), np.asarray(l1))
    assert np.array_equal(np.asarray(r0.absorbed), np.asarray(r1.absorbed)[real])
    # padding rows are no-op non-absorbs, never spurious writes
    assert not np.asarray(r1.absorbed)[~real].any()
    with pytest.raises(ValueError):
        streaming.pad_arrivals(prob, fs, ss, xs, ys, a - 1)


def test_snapshot_isolation_across_ticks():
    """A held snapshot serves its own answers bitwise while ticks publish
    new versions behind it (the double buffer never tears)."""
    prob, state, pos, rng = _build(seed=4)
    d = Daemon(prob, state, config=DaemonConfig(k=3))
    xq = rng.uniform(-0.9, 0.9, size=(16, 1)).astype(np.float32)
    snap0 = d.snapshot
    d.submit(xq)
    (a0,) = d.pump()
    assert a0.version == 0
    for _ in range(2):
        ss = rng.integers(0, prob.n, size=6)
        d.offer_arrivals(
            rng.integers(0, 3, size=6), ss,
            (pos[ss] + 0.02 * rng.normal(size=(6, 1))).astype(np.float32),
            rng.normal(size=6).astype(np.float32),
        )
        rcpt = d.tick()
        assert rcpt.published
    assert d.snapshot.version == 2
    d.submit(xq)
    (a2,) = d.pump()
    assert a2.version == 2
    assert not np.array_equal(a0.values, a2.values)  # training moved
    # the old snapshot's buffers are intact and reproduce a0 bitwise
    # (same padded grid -> same program -> deterministic replay)
    pad = bucket_rows(16) - 16
    xq_pad = np.concatenate([xq, np.repeat(xq[-1:], pad, axis=0)])
    again = fusion.fuse(
        snap0.problem, snap0.state, xq_pad,
        "knn", k=3, engine="plan", plan=snap0.plan, ecoef=snap0.ecoef,
    )
    assert np.array_equal(np.asarray(again)[:, :16], a0.values)


def test_admission_control_sheds_with_receipts():
    prob, state, _, rng = _build(seed=5)
    d = Daemon(prob, state, config=DaemonConfig(k=3, queue_rows=16))
    t1 = d.submit(np.zeros((12, 1), np.float32))
    t2 = d.submit(np.zeros((12, 1), np.float32))
    assert t1.admitted and not t2.admitted
    assert t2.shed_reason == "queue_full" and d.shed == 1
    assert len(d.pump()) == 1  # the admitted one still drains

    # deadline shedding: after one dispatch calibrates the EMA, a zero
    # budget rejects everything with the deadline receipt
    d2 = Daemon(prob, state, config=DaemonConfig(k=3, deadline_ms=0.0))
    assert d2.submit(np.zeros((4, 1), np.float32)).admitted  # EMA unset yet
    d2.pump()
    t = d2.submit(np.zeros((4, 1), np.float32))
    assert not t.admitted and t.shed_reason == "deadline"


def test_degraded_tick_serves_last_good_then_recovers():
    """A poisoned working state exhausts the watchdog ladder: the tick
    rolls back, nothing is published, the daemon flags degraded, queries
    keep serving the last good snapshot — and the next tick recovers
    because the working copy was restored from it."""
    import dataclasses

    prob, state, pos, rng = _build(seed=6)
    d = Daemon(
        prob, state,
        config=DaemonConfig(k=3, rounds_per_tick=14, arrival_rows=8),
    )
    assert d.tick().published  # version 1, known good
    xq = rng.uniform(-0.9, 0.9, size=(9, 1)).astype(np.float32)
    d.submit(xq)
    (good,) = d.pump()
    assert good.version == 1 and not good.degraded

    wp, ws = d._work
    d._work = (wp, dataclasses.replace(ws, z=ws.z.at[0, 0].set(jnp.nan)))
    ss = rng.integers(0, prob.n, size=3)
    d.offer_arrivals(
        rng.integers(0, 3, size=3), ss,
        (pos[ss]).astype(np.float32), rng.normal(size=3).astype(np.float32),
    )
    bad = d.tick()
    assert bad.watchdog.rolled_back and not bad.published
    assert bad.degraded and bad.version == 1
    assert bad.arrivals_rolled_back == 3 and bad.absorbed == 0
    assert d.health()["degraded"] is True

    d.submit(xq)
    (during,) = d.pump()
    assert during.degraded and during.version == 1
    assert np.array_equal(during.values, good.values)  # last good, bitwise

    rec = d.tick()  # working copy was restored from the published snapshot
    assert rec.published and not rec.degraded and rec.version == 2


def test_churn_events_apply_through_ticks():
    prob, state, pos, rng = _build(seed=7, n_max=28)
    plan = make_serving_plan(prob, k=3, spare=4, slack=2)
    d = Daemon(prob, state, config=DaemonConfig(k=3), plan=plan)
    d.offer_join(
        np.array([0.15], np.float32), np.zeros(3, np.float32), lam=0.1
    )
    r = d.tick()
    assert r.joins == 1 and r.published
    d.offer_leave(2)
    r = d.tick()
    assert r.leaves == 1 and r.published
    d.submit(rng.uniform(-0.9, 0.9, size=(7, 1)).astype(np.float32))
    (a,) = d.pump()
    assert np.isfinite(a.values).all()


def test_fault_drill_zero_recompiles():
    """Flipping drill rates on and off reuses the already-compiled
    training programs — rates are traced operands, structure is static."""
    prob, state, _, _ = _build(seed=8)
    d = Daemon(prob, state, config=DaemonConfig(k=3))
    d.tick()  # warm the training program set
    snap = compile_ledger.snapshot("faults")
    d.set_fault_model(faults.make_fault_model(0.25))
    d.tick()
    d.set_fault_model(faults.make_fault_model(0.0))
    d.tick()
    snap.assert_within(context="fault drill rate flips")
    # crash structure is static — swapping it in is a refused recompile
    with pytest.raises(ValueError):
        d.set_fault_model(faults.make_fault_model(0.1, crash=(0.1, 0.5)))


def test_warm_restart_is_bitwise():
    prob, state, _, rng = _build(seed=9)
    with tempfile.TemporaryDirectory() as snap:
        cfg = DaemonConfig(k=3, ckpt_every=1, snapshot_dir=snap)
        d = Daemon(prob, state, config=cfg)
        for _ in range(3):
            assert d.tick().published
        xq = rng.uniform(-0.9, 0.9, size=(11, 1)).astype(np.float32)
        d.submit(xq)
        (before,) = d.pump()
        digest = d.state_digest()

        d2 = Daemon(prob, state, config=cfg)  # same templates, fresh build
        assert d2.restored_step == 3
        assert d2.state_digest() == digest
        d2.submit(xq)
        (after,) = d2.pump()
        assert np.array_equal(before.values, after.values)


@pytest.mark.slow
def test_cli_sigkill_then_warm_restart_bitwise():
    """The CI smoke, in-process: run the daemon CLI with per-tick
    checkpoints, SIGKILL it mid-stream, restart over the same
    snapshot_dir, and assert the restored snapshot reproduces the
    pre-kill probe answers + state digest bitwise (--verify-restart)."""
    env = dict(os.environ, PYTHONPATH="src")
    with tempfile.TemporaryDirectory() as snap:
        argv = [
            sys.executable, "-m", "repro.launch.daemon",
            "--sensors", "16", "--fields", "2", "--ticks", "200",
            "--ckpt-every", "1", "--snapshot-dir", snap,
            "--queries-per-tick", "1", "--arrivals-per-tick", "4",
            "--tick-sleep", "0.2",
        ]
        proc = subprocess.Popen(
            argv, env=env, cwd=ROOT,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            deadline = time.time() + 240
            while time.time() < deadline:
                steps = [f for f in os.listdir(snap) if f.startswith("step_")]
                if len(steps) >= 2:
                    break
                if proc.poll() is not None:
                    _, err = proc.communicate()
                    pytest.fail(f"daemon exited early: {err[-2000:]}")
                time.sleep(0.5)
            else:
                pytest.fail("no checkpoints appeared before the deadline")
            proc.send_signal(signal.SIGKILL)  # crash, not a clean exit
        finally:
            proc.kill()
            proc.wait()
        out = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.daemon",
                "--sensors", "16", "--fields", "2", "--ticks", "0",
                "--snapshot-dir", snap, "--verify-restart",
            ],
            env=env, cwd=ROOT, capture_output=True, text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "warm restart verified" in out.stdout


def test_health_is_json_and_carries_the_watchdog_receipt():
    from repro.core import monitor

    prob, state, _, _ = _build(seed=10)
    d = Daemon(prob, state, config=DaemonConfig(k=3))
    h0 = json.loads(json.dumps(d.health()))
    assert h0["schema"] == "daemon_health/1" and h0["last_tick"] is None
    d.tick()
    h = json.loads(json.dumps(d.health()))
    assert h["version"] == 1 and h["ticks"] == 1
    wd = monitor.receipt_from_json(h["last_tick"]["watchdog"])
    assert wd.rounds >= 1 and not wd.rolled_back
