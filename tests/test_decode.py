"""Serving-path consistency: prefill+decode must reproduce the full forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, concrete_batch, get_config
from repro.models import (
    decode_step,
    forward_logits,
    greedy_decode,
    init_cache,
    init_params,
    prefill,
)

DECODE_ARCHS = [a for a in ARCH_NAMES if a != "whisper-tiny"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, variant="smoke")
    if cfg.n_experts:
        # capacity-based MoE dispatch drops tokens in a group-order-dependent
        # way (inherent to GShard); give generous capacity so the routing is
        # drop-free and prefill/decode are exactly comparable.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    s = 12
    batch = concrete_batch(cfg, s + cfg.n_patches, 2, seed=2)
    toks = batch["tokens"]
    full, _ = forward_logits(cfg, params, batch)

    cache = init_cache(cfg, 2, 64)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, : s - 3]
    logits, cache = prefill(cfg, params, pre_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, s - 4]), atol=2e-4, rtol=2e-4
    )
    pos0 = cfg.n_patches + s - 3
    for t in range(3):
        logits, cache = decode_step(
            cfg, params, toks[:, s - 3 + t : s - 2 + t], cache, pos0 + t
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(full[:, s - 3 + t]),
            atol=3e-4,
            rtol=3e-4,
            err_msg=f"{arch} step {t}",
        )


def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer SWA cache == full forward with the same window mask."""
    cfg = get_config("internlm2-1.8b", variant="smoke")
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 20), 0, cfg.vocab_size)
    full, _ = forward_logits(cfg, params, {"tokens": toks})

    cache = init_cache(cfg, 2, 20)  # ring length = window (8)
    logits, cache = prefill(cfg, params, {"tokens": toks[:, :16]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, 15]), atol=3e-4, rtol=3e-4
    )
    for t in range(4):
        logits, cache = decode_step(cfg, params, toks[:, 16 + t : 17 + t], cache, 16 + t)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, 16 + t]), atol=4e-4, rtol=4e-4,
            err_msg=f"step {t}",
        )


def test_greedy_decode_all_families_run():
    for arch in ["smollm-135m", "mamba2-370m", "jamba-1.5-large-398b", "whisper-tiny"]:
        cfg = get_config(arch, variant="smoke")
        params = init_params(cfg, jax.random.PRNGKey(0))
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = jnp.zeros((2, cfg.encoder_seq, cfg.d_model))
        if cfg.n_patches:
            extra["patch_embeds"] = jnp.zeros((2, cfg.n_patches, cfg.d_model))
        prompt = jnp.ones((2, 8), jnp.int32)
        out, _ = greedy_decode(cfg, params, prompt, 4, 64, batch_extra=extra)
        assert out.shape == (2, 4)
        assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())
