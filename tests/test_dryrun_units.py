"""Unit tests for the dry-run's HLO collective parser and config overrides.

These import launch.dryrun, which sets the 512-device XLA flag at import —
safe here because the flag only takes effect if jax has NOT been initialized
yet, and other tests in the session already initialize it with 1 device.
The pure-python helpers under test never touch devices.
"""

import dataclasses

from repro.configs import get_config
from repro.launch.dryrun import apply_overrides, collective_bytes


HLO = """
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[4,256]{1,0} all-gather(%conv), dimensions={0}
  %ags = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-gather-start(%x), dimensions={0}
  %agd = f32[8,8]{1,0} all-gather-done(%ags)
  %rs = f32[2,128]{1,0} reduce-scatter(%y), dimensions={0}, to_apply=%add
  %a2a = s32[64]{0} all-to-all(%z), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_collective = f32[999]{0} add(%p0, %p0)
}
"""


def test_collective_parser_categories():
    out = collective_bytes(HLO)
    assert out["all-reduce"] == 16 * 128 * 4
    # plain all-gather + the -start line (tuple of two f32[8,8]); -done excluded
    assert out["all-gather"] == 4 * 256 * 2 + 2 * (8 * 8 * 4)
    assert out["reduce-scatter"] == 2 * 128 * 4
    assert out["all-to-all"] == 64 * 4
    assert out["collective-permute"] == 1024 * 1
    assert out["count"] == 6


def test_collective_parser_ignores_noise():
    out = collective_bytes("%x = f32[10]{0} add(%a, %b)\n")
    assert out["count"] == 0 and sum(v for k, v in out.items() if k != "count") == 0


def test_apply_overrides_coercion():
    cfg = get_config("mamba2-370m")
    out = apply_overrides(cfg, ["ssm_chunk=64", "remat=true", "capacity_factor=2.5"])
    assert out.ssm_chunk == 64 and out.remat is True
    assert out.capacity_factor == 2.5
    # untouched fields preserved
    assert out.vocab_size == cfg.vocab_size


def test_apply_overrides_empty_is_identity():
    cfg = get_config("smollm-135m")
    assert apply_overrides(cfg, []) is cfg
