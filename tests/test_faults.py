"""Unreliable-link execution layer (ISSUE 7): seeded fault injection,
stale-message-tolerant sweeps, the convergence watchdog, and the
checkpoint/rollback anchor.

The load-bearing pins:

  * all-delivered is a BITWISE identity, engine by engine — the
    ``delivered`` operand threads through serial/plan/onehot/pallas and
    the robust path without perturbing a single bit when nothing drops;
  * a dropped message is hold-last-value: the target slot keeps its
    stale z (the sender's local coefficient still updates — compute is
    local, only the radio drops);
  * delivery masks are monotonically coupled across rates under one key
    (u >= p thresholding), and Gilbert–Elliott bursts are genuinely
    bursty (P(drop | prev dropped) > marginal);
  * ``watch_sweeps`` converges fault-free and at 10% drop, and rolls
    back BITWISE from a poisoned state after the retry -> refactorize
    escalation ladder is exhausted;
  * ``save_train``/``restore_train`` round-trip the full problem+state
    bitwise;
  * one compiled program serves every fault rate (rates are traced).
"""

import dataclasses
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import checkpoint as ckpt
from repro.analysis import compile_ledger
from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    faults,
    init_state,
    make_batch_problem,
    monitor,
    robust_sweep,
    serial_sweep,
    uniform_sensors,
)

KERN = Kernel("rbf", gamma=1.0)
LAM = 0.3
RADIUS = 0.55
N, B = 12, 2
ENGINES = ("serial", "plan", "onehot", "pallas")


def _build(seed=0):
    pos = uniform_sensors(N, d=1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.2 * rng.normal(size=(B, N))
    topo = build_topology(pos, RADIUS)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((N,), LAM))
    return prob, colored_sweep(prob, init_state(prob), n_sweeps=2)


def _sweep(prob, state, engine, n_sweeps, delivered=None):
    if engine == "serial":
        return serial_sweep(
            prob, state, n_sweeps=n_sweeps, delivered=delivered
        )
    return colored_sweep(
        prob, state, n_sweeps=n_sweeps, engine=engine, delivered=delivered
    )


def _assert_state_equal(a, b, msg=""):
    assert np.array_equal(np.asarray(a.z), np.asarray(b.z)), f"z {msg}"
    assert np.array_equal(np.asarray(a.coef), np.asarray(b.coef)), f"coef {msg}"


def test_all_delivered_is_bitwise_identity_per_engine():
    """Explicit all-ones mask AND a drop=0 FaultModel both reproduce the
    fault-free iterates bit for bit, for every engine."""
    prob, state = _build()
    ones = jnp.ones((3,) + prob.nbr_idx.shape, bool)
    model0 = faults.make_fault_model(0.0)
    key = jax.random.PRNGKey(0)
    for engine in ENGINES:
        ref = _sweep(prob, state, engine, 3)
        via_mask = _sweep(prob, state, engine, 3, delivered=ones)
        _assert_state_equal(ref, via_mask, f"{engine} explicit ones")
        via_model = faults.faulty_sweep(
            prob, state, model0, key, n_sweeps=3, engine=engine
        )
        _assert_state_equal(ref, via_model, f"{engine} drop=0 model")
    # robust path (per-sweep masked refactorization) under all-alive +
    # all-delivered == the colored engine's fault-free iterates
    alive = jnp.ones((3, prob.n), bool)
    ref = colored_sweep(prob, state, n_sweeps=3)
    rob = robust_sweep(prob, state, alive, n_sweeps=3, delivered=ones)
    np.testing.assert_allclose(
        np.asarray(rob.z), np.asarray(ref.z), atol=1e-5
    )


def test_drop_all_is_hold_last_value():
    """drop=1.0 never lands a message write: z is bitwise frozen while the
    local coefficients still move (compute is local)."""
    prob, state = _build()
    model = faults.make_fault_model(1.0)
    for engine in ENGINES:
        out = faults.faulty_sweep(
            prob, state, model, jax.random.PRNGKey(1), n_sweeps=2,
            engine=engine,
        )
        assert np.array_equal(np.asarray(out.z), np.asarray(state.z)), engine
        assert not np.array_equal(
            np.asarray(out.coef), np.asarray(state.coef)
        ), engine


def test_engines_agree_under_random_drops():
    """One shared delivered mask: plan == onehot bitwise, pallas and serial
    to float tolerance (different projection order for serial is exact at
    matching visit order only; colored engines share it)."""
    prob, state = _build(3)
    delivered = (
        jax.random.uniform(jax.random.PRNGKey(7), (4,) + prob.nbr_idx.shape)
        >= 0.3
    )
    plan = _sweep(prob, state, "plan", 4, delivered=delivered)
    onehot = _sweep(prob, state, "onehot", 4, delivered=delivered)
    _assert_state_equal(plan, onehot, "plan vs onehot")
    pallas = _sweep(prob, state, "pallas", 4, delivered=delivered)
    np.testing.assert_allclose(
        np.asarray(pallas.z), np.asarray(plan.z), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(pallas.coef), np.asarray(plan.coef), atol=1e-5
    )


def test_link_masks_monotone_coupling_and_bursts():
    prob, _ = _build()
    lane_shape = prob.nbr_idx.shape
    key = jax.random.PRNGKey(11)
    low = faults.link_masks(
        faults.make_fault_model(0.1), key, 50, lane_shape
    )
    high = faults.link_masks(
        faults.make_fault_model(0.4), key, 50, lane_shape
    )
    low, high = np.asarray(low), np.asarray(high)
    frac = lambda m: m.mean()
    assert 0.83 < frac(low) < 0.97 and 0.5 < frac(high) < 0.7
    # same key, higher rate => the delivered set only shrinks
    assert not (high & ~low).any()

    # Gilbert–Elliott bursts: conditional drop probability given the lane
    # dropped last sweep well exceeds the marginal
    bursty = np.asarray(
        faults.link_masks(
            faults.make_fault_model(0.02, burst=(0.05, 0.3, 0.7)),
            key, 400, lane_shape,
        )
    )
    dropped = ~bursty
    marginal = dropped.mean()
    cond = dropped[1:][dropped[:-1]].mean()
    assert cond > 1.5 * marginal, (cond, marginal)


def test_crash_schedule_and_robust_dispatch():
    prob, state = _build(5)
    # crash present but probability 0 (and certain restart): the robust
    # dispatch must reproduce the crash-free colored path exactly
    model_null = faults.make_fault_model(0.2, crash=(0.0, 1.0))
    model_free = faults.make_fault_model(0.2)
    key = jax.random.PRNGKey(13)
    assert model_null.has_crash and not model_free.has_crash
    # identical delivered draws: sample_faults splits the key the same way
    d_null, alive = faults.sample_faults(model_null, key, 3, prob)
    d_free, none = faults.sample_faults(model_free, key, 3, prob)
    assert none is None
    assert np.array_equal(np.asarray(d_null), np.asarray(d_free))
    assert np.asarray(alive).all()
    out_r = faults.faulty_sweep(
        prob, state, model_null, key, n_sweeps=3, engine="plan"
    )
    out_c = faults.faulty_sweep(
        prob, state, model_free, key, n_sweeps=3, engine="plan"
    )
    np.testing.assert_allclose(
        np.asarray(out_r.z), np.asarray(out_c.z), atol=1e-5
    )

    # a real crash rate takes sensors down and brings them back
    trace = np.asarray(
        faults.crash_schedule(
            faults.make_fault_model(0.0, crash=(0.3, 0.5)),
            jax.random.PRNGKey(17), 60, N,
        )
    )
    assert (~trace).any() and trace.any()
    came_back = (~trace[:-1] & trace[1:]).any()
    assert came_back
    # serial has no robust path — the dispatch must say so
    with pytest.raises(NotImplementedError):
        faults.faulty_sweep(
            prob, state, model_null, key, n_sweeps=1, engine="serial"
        )


def test_parse_fault_spec():
    m = faults.parse_fault_spec("drop=0.1,burst=0.05:0.4:0.5,crash=0.01:0.2")
    assert float(m.drop) == pytest.approx(0.1)
    assert float(m.burst_to_bad) == pytest.approx(0.05)
    assert float(m.drop_bad) == pytest.approx(0.5)
    assert m.has_crash and float(m.restart) == pytest.approx(0.2)
    assert not faults.parse_fault_spec("drop=0.3").has_crash
    with pytest.raises(ValueError):
        faults.parse_fault_spec("drop=0.1,bogus=1")
    with pytest.raises(ValueError):
        faults.parse_fault_spec("burst=0.1")


@pytest.mark.parametrize("spec", [
    "",                       # empty
    "drop",                   # missing '='
    "drop=",                  # empty value
    "drop=abc",               # non-numeric
    "drop=-0.1",              # negative rate
    "drop=1.5",               # rate > 1
    "drop=nan",               # NaN sneaks past naive range checks
    "drop=0.1,drop=0.2",      # repeated key
    "burst=0.1:0.2",          # wrong arity (wants 3)
    "burst=0.1:0.2:0.3:0.4",  # wrong arity (wants 3)
    "crash=0.1",              # wrong arity (wants 2)
    "crash=0.1:0.2:0.3",      # wrong arity (wants 2)
    "jitter=0.1",             # unknown key
])
def test_parse_fault_spec_rejects_with_usage(spec):
    """Every malformed spec fails fast with the usage line — a daemon
    launched with a typo'd --faults must die at argv parse, not mid-run."""
    with pytest.raises(ValueError) as ei:
        faults.parse_fault_spec(spec)
    assert "usage:" in str(ei.value)


def test_watchdog_receipt_json_roundtrip():
    """to_json is a STABLE machine-readable schema (the daemon health
    endpoint and serve.py --faults both emit it); receipt_from_json is its
    exact inverse through a real JSON wire trip."""
    import json

    prob, state = _build(12)
    _, _, receipt = monitor.watch_sweeps(
        prob, state, model=faults.make_fault_model(0.1),
        key=jax.random.PRNGKey(5),
        config=monitor.WatchdogConfig(max_rounds=4),
    )
    payload = json.loads(json.dumps(receipt.to_json()))
    assert payload["schema"] == monitor.RECEIPT_SCHEMA
    back = monitor.receipt_from_json(payload)
    assert np.array_equal(back.converged, receipt.converged)
    assert np.array_equal(back.diverged, receipt.diverged)
    np.testing.assert_allclose(back.residual, receipt.residual)
    np.testing.assert_allclose(back.norm, receipt.norm)
    for f in ("rounds", "sweeps", "retries", "refactorized", "rolled_back"):
        assert getattr(back, f) == getattr(receipt, f), f
    # schema drift is detected, not silently misparsed
    with pytest.raises(ValueError):
        monitor.receipt_from_json({**payload, "schema": "watchdog_receipt/0"})


def test_watchdog_converges_fault_free_and_at_10pct():
    prob, state = _build(8)
    cfg = monitor.WatchdogConfig(tol=1e-3, max_rounds=60)
    _, _, r0 = monitor.watch_sweeps(prob, state, config=cfg)
    assert r0.converged.all() and not r0.rolled_back
    _, _, r1 = monitor.watch_sweeps(
        prob, state, model=faults.make_fault_model(0.1),
        key=jax.random.PRNGKey(2), config=cfg,
    )
    assert r1.converged.all() and not r1.rolled_back
    # receipts enumerate the fields
    assert r0.converged.shape == (B,) and r0.residual.shape == (B,)
    assert "converged" in monitor.format_receipt(r1)


def test_watchdog_rollback_restores_bitwise():
    """A non-finite state defeats retries AND refactorization; the ladder
    must end in a bitwise restore of the entry snapshot."""
    prob, state = _build(9)
    bad = dataclasses.replace(state, z=state.z.at[0, 0].set(jnp.nan))
    cfg = monitor.WatchdogConfig(max_rounds=14)
    p_mem, s_mem, r_mem = monitor.watch_sweeps(
        prob, bad, model=faults.make_fault_model(0.05),
        key=jax.random.PRNGKey(3), config=cfg,
    )
    assert r_mem.rolled_back and r_mem.refactorized == 1
    assert np.array_equal(
        np.asarray(s_mem.z), np.asarray(bad.z), equal_nan=True
    )
    assert "ROLLED BACK" in monitor.format_receipt(r_mem)
    # same ladder through the on-disk snapshot path
    with tempfile.TemporaryDirectory() as d:
        _, s_disk, r_disk = monitor.watch_sweeps(
            prob, bad, snapshot_dir=d + "/wd", config=cfg
        )
    assert r_disk.rolled_back
    assert np.array_equal(
        np.asarray(s_disk.z), np.asarray(bad.z), equal_nan=True
    )


def test_checkpoint_train_roundtrip_bitwise():
    prob, state = _build(10)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_train(d, 3, prob, state)
        assert ckpt.latest_step(d) == 3
        p2, s2 = ckpt.restore_train(d, 3, prob, state)
    for a, b in zip(jax.tree.leaves(prob), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(s2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert p2.kernel == prob.kernel  # static fields carry over


def test_latest_step_skips_crash_corrupted_checkpoints():
    """Crash-mid-save atomicity: ``latest_step`` verifies each candidate
    (manifest parses, npz passes CRC, every leaf present) and falls back
    to the newest INTACT step, which restores bitwise — a kill during
    ``save_train`` can never poison a warm restart."""
    import os

    prob, state = _build(13)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_train(d, 1, prob, state)
        ckpt.save_train(d, 2, prob, state)
        assert ckpt.latest_step(d) == 2

        # truncated npz (the classic kill-mid-write): CRC check fails
        arrays2 = os.path.join(d, "step_00000002", "arrays.npz")
        size = os.path.getsize(arrays2)
        with open(arrays2, "r+b") as f:
            f.truncate(size // 2)
        assert not ckpt.step_valid(d, 2)
        assert ckpt.step_valid(d, 1)
        assert ckpt.latest_step(d) == 1  # verify=True is the default
        assert ckpt.latest_step(d, verify=False) == 2  # raw newest, opt-in

        p2, s2 = ckpt.restore_train(d, ckpt.latest_step(d), prob, state)
        for a, b in zip(jax.tree.leaves(prob), jax.tree.leaves(p2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(s2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

        # a step dir that never got its manifest (killed even earlier)
        ckpt.save_train(d, 3, prob, state)
        os.remove(os.path.join(d, "step_00000003", "manifest.json"))
        assert not ckpt.step_valid(d, 3)
        assert ckpt.latest_step(d) == 1

        # a fully-missing npz
        ckpt.save_train(d, 4, prob, state)
        os.remove(os.path.join(d, "step_00000004", "arrays.npz"))
        assert ckpt.latest_step(d) == 1

        # all steps corrupted -> None, not a crash
        with open(os.path.join(d, "step_00000001", "arrays.npz"), "r+b") as f:
            f.truncate(10)
        assert ckpt.latest_step(d) is None


def test_one_program_serves_all_fault_rates():
    """Rates are traced operands: after one warm call, sweeping the whole
    drop grid must not add a single compiled program."""
    prob, state = _build(11)
    key = jax.random.PRNGKey(4)
    faults.faulty_sweep(
        prob, state, faults.make_fault_model(0.05), key, n_sweeps=2,
        engine="plan",
    ).z.block_until_ready()
    snap = compile_ledger.snapshot("faults")
    for p in (0.0, 0.1, 0.3, 0.6, 0.9):
        faults.faulty_sweep(
            prob, state, faults.make_fault_model(p), key, n_sweeps=2,
            engine="plan",
        ).z.block_until_ready()
    snap.assert_within(context="drop-rate grid")
