"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import kernel_matvec, rbf_gram
from repro.kernels.ref import kernel_matvec_ref, local_batched_solve_ref, rbf_gram_ref

SHAPES = [
    (1, 1, 1),
    (7, 13, 1),
    (128, 512, 2),
    (130, 600, 3),
    (64, 64, 4),
    (257, 129, 2),
]


@pytest.mark.parametrize("q,n,d", SHAPES)
@pytest.mark.parametrize("gamma", [0.5, 2.0])
def test_kernel_matvec_matches_ref(q, n, d, gamma):
    rng = np.random.default_rng(q * 1000 + n + d)
    xq = rng.normal(size=(q, d)).astype(np.float32)
    an = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(n,)).astype(np.float32)
    out = kernel_matvec(xq, an, c, gamma=gamma)
    ref = kernel_matvec_ref(jnp.asarray(xq), jnp.asarray(an), jnp.asarray(c), gamma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("q,n,d", SHAPES)
def test_rbf_gram_matches_ref(q, n, d):
    rng = np.random.default_rng(q + 7 * n + d)
    x1 = rng.normal(size=(q, d)).astype(np.float32)
    x2 = rng.normal(size=(n, d)).astype(np.float32)
    g = rbf_gram(x1, x2, gamma=1.1)
    ref = rbf_gram_ref(jnp.asarray(x1), jnp.asarray(x2), 1.1)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_kernel_matvec_dtype_sweep(dtype):
    """Lower-precision inputs: kernel computes in f32 internally."""
    rng = np.random.default_rng(0)
    xq = rng.normal(size=(33, 2)).astype(dtype)
    an = rng.normal(size=(77, 2)).astype(dtype)
    c = rng.normal(size=(77,)).astype(dtype)
    out = kernel_matvec(xq, an, c, gamma=1.0)
    ref = kernel_matvec_ref(
        jnp.asarray(xq, jnp.float32), jnp.asarray(an, jnp.float32),
        jnp.asarray(c, jnp.float32), 1.0,
    )
    tol = 1e-5 if dtype == np.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@settings(deadline=None, max_examples=12)
@given(
    q=st.integers(1, 140),
    n=st.integers(1, 300),
    d=st.integers(1, 4),
    block_q=st.sampled_from([8, 32, 128]),
    block_n=st.sampled_from([16, 64, 512]),
)
def test_kernel_matvec_block_size_invariance(q, n, d, block_q, block_n):
    """Result must not depend on BlockSpec tiling choices."""
    rng = np.random.default_rng(q * 31 + n * 7 + d)
    xq = rng.normal(size=(q, d)).astype(np.float32)
    an = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(n,)).astype(np.float32)
    out = kernel_matvec(xq, an, c, gamma=0.9, block_q=block_q, block_n=block_n)
    ref = kernel_matvec_ref(jnp.asarray(xq), jnp.asarray(an), jnp.asarray(c), 0.9)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_local_batched_solve_ref_consistency():
    """The SN-Train local-solve oracle agrees with an explicit masked solve."""
    rng = np.random.default_rng(5)
    bsz, d = 4, 6
    pts = rng.normal(size=(bsz, d, 1)).astype(np.float32)
    gram = np.exp(-((pts[:, :, None, 0] - pts[:, None, :, 0]) ** 2))
    mask = np.ones((bsz, d), bool)
    mask[:, 4:] = False
    gram = gram * (mask[:, :, None] & mask[:, None, :])
    lam = np.full((bsz,), 0.3, np.float32)
    rhs = rng.normal(size=(bsz, d)).astype(np.float32)
    out = local_batched_solve_ref(
        jnp.asarray(gram), jnp.asarray(lam), jnp.asarray(rhs), jnp.asarray(mask)
    )
    for i in range(bsz):
        a = gram[i][:4, :4] + 0.3 * np.eye(4)
        expect = np.linalg.solve(a, rhs[i, :4])
        np.testing.assert_allclose(np.asarray(out)[i, :4], expect, atol=1e-4)
        np.testing.assert_allclose(np.asarray(out)[i, 4:], 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Fused SSD intra-chunk kernel (kernels/ssd_intra.py)
# ---------------------------------------------------------------------------

import jax

from repro.kernels.ops import ssd_chunked_fused
from repro.models.ssm import ssd_recurrent_ref


def _ssd_inputs(seed, b, s, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    return x, dt, a, bm, cm


@pytest.mark.parametrize(
    "b,s,h,p,n,chunk,block_h",
    [
        (1, 16, 4, 8, 8, 8, 4),
        (2, 48, 6, 8, 16, 16, 4),   # h % block_h != 0 path via padding
        (2, 41, 5, 4, 8, 16, 8),    # both paddings
        (1, 64, 8, 16, 32, 32, 8),
    ],
)
def test_ssd_fused_matches_recurrence(b, s, h, p, n, chunk, block_h):
    x, dt, a, bm, cm = _ssd_inputs(s * 7 + h, b, s, h, p, n)
    y1, h1 = ssd_chunked_fused(x, dt, a, bm, cm, chunk, block_h=block_h)
    y2, h2 = ssd_recurrent_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=3e-4, rtol=3e-4)


def test_ssd_fused_initial_state_threading():
    x, dt, a, bm, cm = _ssd_inputs(3, 1, 32, 4, 8, 8)
    y_full, h_full = ssd_chunked_fused(x, dt, a, bm, cm, 8, block_h=4)
    y1, h1 = ssd_chunked_fused(x[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16], 8, block_h=4)
    y2, h2 = ssd_chunked_fused(x[:, 16:], dt[:, 16:], a, bm[:, 16:], cm[:, 16:], 8,
                               h0=h1, block_h=4)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), atol=2e-4)


def test_ssd_fused_end_to_end_model():
    """mamba2 smoke model produces identical logits with ssd_fused on/off."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import forward_logits, init_params

    cfg = get_config("mamba2-370m", variant="smoke")
    cfg_f = dataclasses.replace(cfg, ssd_fused=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    l0, _ = forward_logits(cfg, params, {"tokens": toks})
    l1, _ = forward_logits(cfg_f, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-3, rtol=2e-3)
