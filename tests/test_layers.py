"""Layer-level unit tests: norms, RoPE/M-RoPE, attention masks, MLPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models import layers as L


def _cfg(**kw):
    base = dict(
        name="l", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_rmsnorm_unit_scale():
    cfg = _cfg()
    p = L.norm_init(cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 32)) * 10
    y = L.apply_norm(p, cfg, x)
    ms = jnp.mean(jnp.square(y), axis=-1)
    np.testing.assert_allclose(np.asarray(ms), 1.0, atol=1e-3)


def test_layernorm_zero_mean():
    cfg = _cfg(norm="layernorm")
    p = L.norm_init(cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 32)) + 3
    y = L.apply_norm(p, cfg, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)


def test_rope_preserves_norm_and_relative_shift():
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 2, 8))
    pos = jnp.arange(6)[None, :]
    ang = L.rope_angles(cfg, pos)
    y = L.apply_rope(x, ang)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-5,
    )
    # relative property: <q_i, k_j> depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 1, 8))
    q0 = jnp.tile(q[:, :1], (1, 8, 1, 1))
    k0 = jnp.tile(k[:, :1], (1, 8, 1, 1))
    angs = L.rope_angles(cfg, jnp.arange(8)[None, :])
    qr, kr = L.apply_rope(q0, angs), L.apply_rope(k0, angs)
    dots = jnp.einsum("bshd,bshd->bs", qr[:, 2:], kr[:, :-2])
    np.testing.assert_allclose(np.asarray(dots), np.asarray(dots)[0, 0], rtol=1e-4)


def test_mrope_matches_standard_when_streams_equal():
    """If t/h/w position streams coincide, M-RoPE must equal standard RoPE."""
    cfg_m = _cfg(rope_mode="mrope", mrope_sections=(1, 1, 2))
    cfg_s = _cfg()
    pos = jnp.arange(5)[None, :]
    pos3 = jnp.broadcast_to(pos[:, None, :], (1, 3, 5))
    a_m = L.rope_angles(cfg_m, pos3)
    a_s = L.rope_angles(cfg_s, pos)
    np.testing.assert_allclose(np.asarray(a_m), np.asarray(a_s), rtol=1e-6)


def test_causal_mask_and_window():
    m = np.asarray(L.causal_mask(5, 5))
    assert m[0, 1] == False and m[4, 0] == True and m[2, 2] == True
    mw = np.asarray(L.causal_mask(5, 5, window=2))
    assert mw[4, 3] == True and mw[4, 2] == False


def test_attention_causality():
    """Changing a future token must not change past outputs."""
    cfg = _cfg()
    p = L.attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    ang = L.rope_angles(cfg, jnp.arange(8)[None, :])
    y1 = L.attn_forward(p, cfg, x, ang)
    x2 = x.at[0, 6].set(99.0)
    y2 = L.attn_forward(p, cfg, x2, ang)
    np.testing.assert_allclose(np.asarray(y1[0, :6]), np.asarray(y2[0, :6]), atol=1e-5)
    assert float(jnp.abs(y1[0, 6:] - y2[0, 6:]).max()) > 1e-4


def test_gqa_heads_share_kv():
    """With n_kv_heads=1, all query heads attend to identical K/V."""
    cfg = _cfg(n_heads=4, n_kv_heads=1)
    p = L.attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    ang = L.rope_angles(cfg, jnp.arange(4)[None, :])
    y = L.attn_forward(p, cfg, x, ang)
    assert y.shape == (1, 4, 32)


@pytest.mark.parametrize("act", ["silu", "squared_relu", "gelu"])
def test_mlp_variants(act):
    cfg = _cfg(act=act)
    p = L.mlp_init(jax.random.PRNGKey(0), cfg, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32))
    y = L.mlp(p, cfg, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    if act == "squared_relu":
        # squared-relu MLP output is 0 for inputs mapping to negative preacts
        zero = L.mlp(p, cfg, jnp.zeros_like(x))
        np.testing.assert_allclose(np.asarray(zero), 0.0, atol=1e-6)


def test_qkv_bias_config():
    cfg = _cfg(qkv_bias=True)
    p = L.attn_init(jax.random.PRNGKey(0), cfg)
    assert "b" in p["wq"] and "b" in p["wk"] and "b" in p["wv"]
    assert "b" not in p["wo"]
