"""Network-lifecycle plan layer guarantees (ISSUE-4 tentpole, extended by
the ISSUE-5 symmetric-join tentpole).

Covers:
  (a) join/leave events (``streaming.add_sensor`` / ``remove_sensor``)
      keep every cached factor consistent with the masked-rebuild reference
      and keep the engine equalities (plan == onehot BIT-FOR-BIT, pallas
      close) on the churned problem — including spare-row recycling;
  (a') SYMMETRIC joins: adopters grow reciprocal anchor lanes, the
      patched scatter plans equal the host builder BITWISE on the
      post-join tables, the training iterates equal a from-scratch
      ``make_problem`` build to <= 1e-5, same-color adopter conflicts
      recolor on device (and an exhausted pool drops the join bitwise),
      and leave is the exact inverse (join -> leave restores every
      table bitwise);
  (b) the refactored ``robust_sweep``: batched (B > 1), engine-dispatched,
      bitwise-equal to ``colored_sweep`` at all-True liveness and
      plan == onehot bitwise under arbitrary liveness traces; the legacy
      3D link-liveness path still routes;
  (c) recompile-freeness: a join -> leave -> absorb -> sweep -> query trace
      at fixed ``n_max`` compiles ZERO additional programs after warmup
      (jit-cache-counted, the PR-3 query-grid pattern);
  (d) serving-plan repair: ``plan_add_sensor`` / ``plan_remove_sensor``
      keep the plan/pallas kNN engines on the alive-masked dense oracle
      across churn (exactness slack >= removals);
  (e) Fejér monotonicity of the weighted norm (Lemma 2.1) is preserved
      across interleaved join/leave/absorb events (hypothesis property).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    add_sensor,
    build_topology,
    colored_sweep,
    field_view,
    fusion,
    init_state,
    make_batch_problem,
    make_serving_plan,
    plan_add_sensor,
    plan_remove_sensor,
    remove_sensor,
    ring_topology,
    robust_sweep,
    serial_sweep,
    streaming,
    uniform_sensors,
    weighted_norm_sq,
)

KERN = Kernel("rbf", gamma=1.0)


def _lifecycle_problem(
    n=24, b=2, spares=4, radius=0.7, seed=0, headroom=4, lam=0.1, sweeps=5
):
    pos = uniform_sensors(n, d=1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.2 * rng.normal(size=(b, n))
    topo = build_topology(pos, radius)
    d_max = int(np.asarray(topo.degrees).max()) + headroom
    topo = build_topology(pos, radius, d_max=d_max, n_max=n + spares)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), lam))
    state = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
    return prob, state, pos, rng


def _assert_engines_agree(prob, state, n_sweeps=3, pallas_atol=1e-5):
    a = colored_sweep(prob, state, n_sweeps=n_sweeps, engine="plan")
    b = colored_sweep(prob, state, n_sweeps=n_sweeps, engine="onehot")
    np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))
    np.testing.assert_array_equal(np.asarray(a.coef), np.asarray(b.coef))
    c = colored_sweep(prob, state, n_sweeps=n_sweeps, engine="pallas")
    np.testing.assert_allclose(
        np.asarray(a.z), np.asarray(c.z), atol=pallas_atol, err_msg="pallas"
    )
    return a


# ---------------------------------------------------------------------------
# (a) join / leave event correctness
# ---------------------------------------------------------------------------


def test_add_sensor_structural():
    prob, state, pos, rng = _lifecycle_problem()
    n_base = prob.n_base
    x = np.array([0.15], np.float32)
    ys_new = np.array([0.4, -0.2], np.float32)
    prob2, state2, _rec = add_sensor(prob, state, x, ys_new, lam=0.1)
    slot, ok = _rec.slot, _rec.joined
    assert bool(ok) and int(slot) == n_base
    assert bool(prob2.alive[int(slot)])
    # the row adopted its live in-radius neighborhood, self first
    s = int(slot)
    idx = np.asarray(prob2.nbr_idx[s])
    mask = np.asarray(prob2.nbr_mask[0, s])
    assert idx[0] == s and mask[0]
    deg = int(np.asarray(prob2.topology.degrees)[s])
    assert deg == 1 + mask[1:].sum()
    adopted = idx[1:deg]
    d = np.abs(pos[adopted, 0] - x[0])
    assert (d < 0.7).all()
    # SYMMETRIC: every adopter grew a reciprocal anchor lane at x, at its
    # pre-join stream boundary, and its degree bumped by one
    deg0 = np.asarray(prob.topology.degrees)
    deg2 = np.asarray(prob2.topology.degrees)
    idx_all = np.asarray(prob2.nbr_idx)
    for a in adopted:
        assert deg2[a] == deg0[a] + 1
        la = idx_all[a].tolist().index(s)
        assert la == deg0[a]
        np.testing.assert_allclose(
            np.asarray(prob2.nbr_pos[:, a, la]),
            np.broadcast_to(x, (2, 1)), atol=1e-7,
        )
        assert np.asarray(prob2.nbr_mask)[:, a, la].all()
    # its position is live program data now
    np.testing.assert_allclose(
        np.asarray(prob2.topology.positions[s]), x, atol=1e-7
    )
    # message slot seeded with the measurements (Table-1 init), per field
    np.testing.assert_allclose(np.asarray(state2.z[:, s]), ys_new)
    # the cached factor equals the masked-rebuild reference
    np.testing.assert_allclose(
        np.asarray(prob2.chol), np.asarray(streaming.rebuild_chol(prob2)),
        atol=1e-5,
    )
    # untouched arrays: NON-adopter rows identical (adopters grew an anchor)
    others = [i for i in range(n_base) if i not in adopted.tolist()]
    np.testing.assert_array_equal(
        np.asarray(prob2.gram[:, others]), np.asarray(prob.gram[:, others])
    )
    np.testing.assert_array_equal(
        np.asarray(prob2.chol[:, others]), np.asarray(prob.chol[:, others])
    )
    _assert_engines_agree(prob2, state2)


def test_symmetric_join_matches_from_scratch():
    """ISSUE-5 acceptance: the post-join problem IS the problem a fresh
    ``make_problem`` on the post-join topology would build — the patched
    scatter plans match the host builder BITWISE on the post-join tables,
    and the training iterates match a genuinely from-scratch build to
    <= 1e-5 (same constraint sets, same canonical Table-1 init)."""
    from repro.core import plans

    prob, state, pos, rng = _lifecycle_problem()
    n = prob.n_base
    x = np.array([0.15], np.float32)
    ys_new = np.array([0.4, -0.2], np.float32)
    prob2, state2, _rec = add_sensor(prob, state, x, ys_new, lam=0.1)
    slot, ok = _rec.slot, _rec.joined
    assert bool(ok)
    s = int(slot)

    # (a) device-patched plans == host build_color_plans on current tables
    pz, pc = plans.build_color_plans(
        np.asarray(prob2.color_members), np.asarray(prob2.color_mask),
        np.asarray(prob2.nbr_idx), prob2.n_stream, np.asarray(prob2.alive),
    )
    np.testing.assert_array_equal(pz, np.asarray(prob2.plan_z))
    np.testing.assert_array_equal(pc, np.asarray(prob2.plan_coef))

    # (b) fit equivalence vs a true from-scratch build on the post-join
    # topology: the serial engine visits identical local systems in
    # identical order, so the iterates themselves match to float noise
    from repro.core import make_batch_problem as mbp

    pos2 = np.concatenate([pos, x[None]], axis=0)
    ys2 = np.concatenate([np.asarray(prob.y[:, :n]), ys_new[:, None]], axis=1)
    topoF = build_topology(pos2, 0.7, d_max=prob.topology.d_max)
    probF = mbp(topoF, KERN, ys2, jnp.full((n + 1,), 0.1))
    for sweeps in (1, 5):
        sF = serial_sweep(probF, init_state(probF), n_sweeps=sweeps)
        sI = serial_sweep(prob2, init_state(prob2), n_sweeps=sweeps)
        zF, zI = np.asarray(sF.z), np.asarray(sI.z)
        np.testing.assert_allclose(zF[:, :n], zI[:, :n], atol=1e-5)
        np.testing.assert_allclose(zF[:, n], zI[:, s], atol=1e-5)

    # (c) leave is the exact inverse: every plan/color/neighbor table
    # restores BITWISE (the adopters' deleted anchor lanes restore their
    # orphaned reserved ids, the recycled spare row its pristine table)
    prob3, state3, rok = remove_sensor(prob2, state2, s)
    assert bool(rok)
    for f in (
        "nbr_idx", "nbr_mask", "plan_z", "plan_coef", "color_members",
        "color_mask", "color_of", "member_pos", "alive",
    ):
        np.testing.assert_array_equal(
            np.asarray(getattr(prob3, f)), np.asarray(getattr(prob, f)),
            err_msg=f,
        )
    np.testing.assert_array_equal(
        np.asarray(prob3.topology.degrees), np.asarray(prob.topology.degrees)
    )
    np.testing.assert_allclose(
        np.asarray(prob3.chol), np.asarray(streaming.rebuild_chol(prob3)),
        atol=1e-5,
    )


def test_symmetric_join_recolors_conflicting_adopters():
    """Two far-apart adjacent pairs reuse colors across components; a
    newcomer adopting all four creates two same-color conflicts, resolved
    on device by moving one adopter of each pair into a reserved recolor
    class.  plan == onehot bitwise is the conflict detector (an unresolved
    conflict double-writes the newcomer's slot and the engines diverge)."""
    from repro.core import plans

    pos = np.array([[-0.45], [-0.35], [0.35], [0.45]], np.float32)
    topo = build_topology(pos, 0.46, d_max=6, n_max=6)
    ys = np.array([[0.5, 0.2, -0.1, 0.3], [0.1, -0.3, 0.2, 0.0]], np.float32)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((4,), 0.2))
    state = colored_sweep(prob, init_state(prob), n_sweeps=4)
    rs = prob.recolor_start
    assert topo.n_recolor == 4  # default 2x spares
    prob2, state2, _rec = add_sensor(
        prob, state, np.zeros(1, np.float32),
        np.array([0.1, -0.1], np.float32), lam=0.2,
    )
    slot, ok = _rec.slot, _rec.joined
    assert bool(ok)
    co = np.asarray(prob2.color_of)
    moved = [i for i in range(4) if co[i] >= rs]
    assert len(moved) == 2, (moved, co[:5])
    _assert_engines_agree(prob2, state2)
    # host rebuild of the plans from the recolored tables is bitwise equal
    pz, pc = plans.build_color_plans(
        np.asarray(prob2.color_members), np.asarray(prob2.color_mask),
        np.asarray(prob2.nbr_idx), prob2.n_stream, np.asarray(prob2.alive),
    )
    np.testing.assert_array_equal(pz, np.asarray(prob2.plan_z))
    np.testing.assert_array_equal(pc, np.asarray(prob2.plan_coef))
    # removing a recolored adopter frees its class for later joins
    prob3, state3, rok = remove_sensor(prob2, state2, moved[0])
    assert bool(rok)
    free = int((~np.asarray(prob3.color_mask)[rs:].any(1)).sum())
    assert free == topo.n_recolor - 1
    _assert_engines_agree(prob3, state3)
    # an exhausted recolor pool DROPS the join bitwise instead of
    # corrupting the coloring
    topoZ = build_topology(pos, 0.46, d_max=6, n_max=6, n_recolor=0)
    probZ = make_batch_problem(topoZ, KERN, ys, jnp.full((4,), 0.2))
    stateZ = colored_sweep(probZ, init_state(probZ), n_sweeps=2)
    probZ2, stateZ2, _rec = add_sensor(
        probZ, stateZ, np.zeros(1, np.float32),
        np.array([0.1, -0.1], np.float32), lam=0.2,
    )
    _, okZ = _rec.slot, _rec.joined
    assert not bool(okZ)
    for f in ("nbr_idx", "nbr_mask", "gram", "chol", "plan_z", "plan_coef",
              "alive", "color_members", "color_of"):
        np.testing.assert_array_equal(
            np.asarray(getattr(probZ2, f)), np.asarray(getattr(probZ, f)),
            err_msg=f,
        )


def test_symmetric_join_shifts_adopter_arrivals():
    """An adopter with absorbed arrivals keeps them: the anchor lane is
    inserted at its stream boundary and the arrivals shift up one lane (a
    completely FULL field drops its newest arrival); the factor repair is
    an O(degree) batched refactorization that matches the rebuild."""
    prob, state, pos, rng = _lifecycle_problem(headroom=3)
    target = 5
    d_max = prob.topology.d_max
    deg0 = int(np.asarray(prob.topology.degrees)[target])
    # fill field 0 of the target COMPLETELY, field 1 partially
    for k in range(d_max - deg0):
        x = (pos[target] + 0.02 * (k + 1)).astype(np.float32)
        prob, state, aok = streaming.absorb(prob, state, 0, target, x, 0.5 + k)
        assert bool(aok)
    prob, state, aok = streaming.absorb(
        prob, state, 1, target, (pos[target] + 0.01).astype(np.float32), -0.3
    )
    assert bool(aok)
    zid_first = int(np.asarray(prob.nbr_idx)[target, deg0])
    zid_last = int(np.asarray(prob.nbr_idx)[target, d_max - 1])
    z_first0 = float(state.z[0, zid_first])
    z_last0 = float(state.z[0, zid_last])
    assert z_last0 != 0.0
    x_new = (pos[target] + 0.005).astype(np.float32)  # adopts `target` first
    prob2, state2, _rec = add_sensor(
        prob, state, x_new, np.zeros(2, np.float32), lam=0.1
    )
    slot, ok = _rec.slot, _rec.joined
    assert bool(ok)
    s = int(slot)
    idx2 = np.asarray(prob2.nbr_idx)
    assert idx2[target, deg0] == s  # anchor at the old stream boundary
    assert idx2[target, deg0 + 1] == zid_first  # arrivals shifted up
    # the arrival VALUES ride with their fixed slot ids
    assert float(state2.z[0, zid_first]) == z_first0
    # field 0 was full: its newest arrival (the orphaned last slot) dropped,
    # and the row stays full (anchor + one-fewer arrivals fill all lanes)
    assert float(state2.z[0, zid_last]) == 0.0
    assert bool(prob2.nbr_mask[0, target].all())
    assert zid_last not in np.asarray(prob2.nbr_idx)[target].tolist()
    # field 1 had room: nothing lost, its arrival rides at lane deg0 + 1
    assert bool(prob2.nbr_mask[1, target, deg0 + 1])
    np.testing.assert_allclose(
        np.asarray(prob2.chol), np.asarray(streaming.rebuild_chol(prob2)),
        atol=1e-4,
    )
    # near-duplicate anchors (stacked arrivals + the new anchor) make this
    # row deliberately ill-conditioned; give the f32 Pallas solve slack
    _assert_engines_agree(prob2, state2, pallas_atol=5e-5)
    # absorb still lands at the adopter post-join (field 1 has room)
    prob3, state3, aok = streaming.absorb(
        prob2, state2, 1, target, (pos[target] - 0.01).astype(np.float32), 0.7
    )
    assert bool(aok)
    np.testing.assert_allclose(
        np.asarray(prob3.chol), np.asarray(streaming.rebuild_chol(prob3)),
        atol=1e-4,
    )


def test_remove_sensor_structural():
    prob, state, pos, rng = _lifecycle_problem()
    victim = 5
    prob2, state2, ok = remove_sensor(prob, state, victim)
    assert bool(ok)
    assert not bool(prob2.alive[victim])
    # its messages and coefficients reset; neighbors' referencing lanes dead
    assert float(jnp.abs(state2.z[:, victim]).max()) == 0.0
    assert float(jnp.abs(state2.coef[:, victim]).max()) == 0.0
    np.testing.assert_allclose(
        np.asarray(prob2.chol), np.asarray(streaming.rebuild_chol(prob2)),
        atol=1e-5,
    )
    # removing a dead slot is a no-op
    prob3, state3, ok3 = remove_sensor(prob2, state2, victim)
    assert not bool(ok3)
    np.testing.assert_array_equal(np.asarray(prob3.gram), np.asarray(prob2.gram))
    state_after = _assert_engines_agree(prob2, state2)
    # the dead sensor never updates again
    assert float(jnp.abs(state_after.z[:, victim]).max()) == 0.0
    assert float(jnp.abs(state_after.coef[:, victim]).max()) == 0.0
    # serial engine agrees it is gone (stays finite, keeps it at zero)
    ser = serial_sweep(prob2, state2, n_sweeps=2)
    assert float(jnp.abs(ser.coef[:, victim]).max()) == 0.0


def test_spare_recycling_round_trip():
    """join -> leave -> join again reuses the spare row cleanly (the stale
    lanes other joiners bound to the first generation stay retired)."""
    prob, state, pos, rng = _lifecycle_problem(spares=2)
    prob, state, _rec = add_sensor(
        prob, state, np.array([0.1], np.float32), np.zeros(2, np.float32),
        lam=0.1,
    )
    s1, ok1 = _rec.slot, _rec.joined
    # second joiner adopts the first (they are within radius)
    prob, state, _rec = add_sensor(
        prob, state, np.array([0.12], np.float32), np.zeros(2, np.float32),
        lam=0.1,
    )
    s2, ok2 = _rec.slot, _rec.joined
    assert bool(ok1) and bool(ok2)
    assert int(s1) in np.asarray(prob.nbr_idx[int(s2)]).tolist()
    # no third spare row: the join is DROPPED, not corrupted
    probX, stateX, _rec = add_sensor(
        prob, state, np.array([0.2], np.float32), np.zeros(2, np.float32),
        lam=0.1,
    )
    _, ok3 = _rec.slot, _rec.joined
    assert not bool(ok3)
    np.testing.assert_array_equal(np.asarray(probX.gram), np.asarray(prob.gram))
    # remove the first generation, recycle its row elsewhere
    prob, state, ok = remove_sensor(prob, state, int(s1))
    assert bool(ok)
    prob, state, _rec = add_sensor(
        prob, state, np.array([-0.4], np.float32), np.ones(2, np.float32),
        lam=0.1,
    )
    s3, ok = _rec.slot, _rec.joined
    assert bool(ok) and int(s3) == int(s1)
    np.testing.assert_allclose(
        np.asarray(prob.chol), np.asarray(streaming.rebuild_chol(prob)),
        atol=1e-5,
    )
    state = _assert_engines_agree(prob, state)
    # the recycled sensor's messages survive sweeps (stale plan codes of the
    # first generation were retired, not left pointing at its z slot)
    assert float(jnp.abs(state.z[:, int(s3)]).max()) > 0.0
    # absorb still works on the churned problem, incl. at the joined sensor
    prob, state, ok = streaming.absorb(
        prob, state, 0, int(s3), np.array([-0.38], np.float32), 0.5
    )
    assert bool(ok)
    np.testing.assert_allclose(
        np.asarray(prob.chol), np.asarray(streaming.rebuild_chol(prob)),
        atol=1e-4,
    )


def test_absorb_drops_at_dead_sensor():
    prob, state, pos, rng = _lifecycle_problem()
    prob, state, ok = remove_sensor(prob, state, 3)
    assert bool(ok)
    prob2, state2, aok = streaming.absorb(
        prob, state, 0, 3, pos[3] + 0.01, 1.0
    )
    assert not bool(aok)
    np.testing.assert_array_equal(
        np.asarray(prob2.nbr_mask), np.asarray(prob.nbr_mask)
    )


def test_lifecycle_requires_capacity_and_geometry():
    pos = uniform_sensors(12, seed=0)
    topo = build_topology(pos, 0.8)
    prob = make_batch_problem(topo, KERN, np.zeros((1, 12)), jnp.full((12,), 0.1))
    with pytest.raises(ValueError, match="spare"):
        add_sensor(prob, init_state(prob), np.zeros(1), np.zeros(1))
    ring = ring_topology(8)
    prob_r = make_batch_problem(
        ring, KERN, np.zeros((1, 8)), jnp.full((8,), 0.1), n_max=10
    )
    with pytest.raises(ValueError, match="geometric"):
        add_sensor(prob_r, init_state(prob_r), np.zeros(2), np.zeros(1))


# ---------------------------------------------------------------------------
# (b) robust_sweep: batched, engine-dispatched, alive-masked colored
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["plan", "onehot", "pallas"])
def test_robust_all_alive_equals_colored_bitwise(engine):
    """Acceptance: at all-True liveness the per-sweep masked refactorization
    reproduces the cached factors EXACTLY, so robust == colored bitwise."""
    prob, state, _, _ = _lifecycle_problem(b=3)
    alive = jnp.ones((prob.n,), bool)
    r = robust_sweep(prob, state, alive, n_sweeps=4, engine=engine)
    c = colored_sweep(prob, state, n_sweeps=4, engine=engine)
    np.testing.assert_array_equal(np.asarray(r.z), np.asarray(c.z))
    np.testing.assert_array_equal(np.asarray(r.coef), np.asarray(c.coef))


def test_robust_batched_equals_per_field():
    """Satellite: robust_sweep accepts a leading field axis (the old
    _require_single_field guard is gone)."""
    prob, state, _, rng = _lifecycle_problem(b=3)
    alive = np.ones((4, prob.n), bool)
    alive[1, rng.integers(0, prob.n_base, 5)] = False
    alive[3, rng.integers(0, prob.n_base, 5)] = False
    out_b = robust_sweep(prob, state, jnp.asarray(alive), n_sweeps=4)
    assert out_b.z.shape == state.z.shape
    for b in range(3):
        pv, sv = field_view(prob, state, b)
        out_1 = robust_sweep(pv, sv, jnp.asarray(alive), n_sweeps=4)
        np.testing.assert_allclose(
            np.asarray(out_b.z[b]), np.asarray(out_1.z), atol=1e-6
        )


def test_robust_plan_equals_onehot_bitwise_under_churn_trace():
    prob, state, _, rng = _lifecycle_problem(b=2)
    alive = rng.random((5, prob.n)) > 0.2
    alive[:, prob.n_base:] = False  # spares stay dead
    a = robust_sweep(prob, state, jnp.asarray(alive), n_sweeps=5, engine="plan")
    b = robust_sweep(prob, state, jnp.asarray(alive), n_sweeps=5, engine="onehot")
    np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))
    np.testing.assert_array_equal(np.asarray(a.coef), np.asarray(b.coef))
    c = robust_sweep(prob, state, jnp.asarray(alive), n_sweeps=5, engine="pallas")
    np.testing.assert_allclose(np.asarray(a.z), np.asarray(c.z), atol=1e-5)
    # dead sensors made no update; their stale state persists (heal model)
    dead = ~alive.all(axis=0)
    dead_rows = np.nonzero(dead[: prob.n_base])[0]
    if len(dead_rows):
        always_dead = [r for r in dead_rows if not alive[:, r].any()]
        for r in always_dead:
            np.testing.assert_array_equal(
                np.asarray(a.coef[:, r]), np.asarray(state.coef[:, r])
            )


def test_robust_dead_sensor_messages_persist_all_engines():
    """A down mote's own message slot is unreachable: its z value (not just
    its coefficients) must persist through other sensors' sweeps, in every
    engine — matching the serial engine's masked scatter."""
    prob, state, _, _ = _lifecycle_problem(b=2)
    dead = 3
    alive = np.ones((prob.n,), bool)
    alive[dead] = False
    z0 = np.asarray(state.z[:, dead])
    assert np.abs(z0).max() > 0
    for engine in ("plan", "onehot", "pallas"):
        out = robust_sweep(
            prob, state, jnp.asarray(alive), n_sweeps=3, engine=engine
        )
        np.testing.assert_array_equal(
            np.asarray(out.z[:, dead]), z0, err_msg=engine
        )
        np.testing.assert_array_equal(
            np.asarray(out.coef[:, dead]), np.asarray(state.coef[:, dead]),
            err_msg=engine,
        )


def test_robust_transient_death_fejer_and_heal():
    prob, state, _, rng = _lifecycle_problem(b=2)
    alive = np.ones((prob.n,), bool)
    alive[[2, 7, 11]] = False
    prev = np.asarray(weighted_norm_sq(prob, state))
    s = state
    for _ in range(4):
        s = robust_sweep(prob, s, jnp.asarray(alive), n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, s))
        assert np.isfinite(cur).all()
        assert (cur <= prev * 1.06 + 1e-5).all(), (cur, prev)
        prev = cur
    # heal: further all-alive robust sweeps keep converging
    healed = robust_sweep(prob, s, jnp.ones((prob.n,), bool), n_sweeps=30)
    again = colored_sweep(prob, healed, n_sweeps=1)
    np.testing.assert_allclose(
        np.asarray(again.z), np.asarray(healed.z), atol=5e-3
    )


def test_robust_legacy_link_trace_still_routes():
    pos = uniform_sensors(15, seed=2)
    topo = build_topology(pos, 0.8)
    from repro.core import make_problem

    prob = make_problem(topo, KERN, np.sin(pos[:, 0]), jnp.full((15,), 0.1))
    st0 = init_state(prob)
    link_alive = jnp.ones((3, 15, topo.d_max), bool)
    r = robust_sweep(prob, st0, link_alive, n_sweeps=3)
    s = serial_sweep(prob, st0, n_sweeps=3)
    np.testing.assert_allclose(np.asarray(r.z), np.asarray(s.z), atol=1e-3)


# ---------------------------------------------------------------------------
# (c) recompile-freeness: the churn trace compiles a constant program set
# ---------------------------------------------------------------------------


def test_churn_trace_compiles_zero_programs_after_warmup():
    """Acceptance: a join -> leave -> absorb -> sweep -> query trace at
    fixed n_max triggers zero recompilations after warmup."""
    from repro.analysis import compile_ledger

    prob, state, pos, rng = _lifecycle_problem(n=30, b=2, spares=4)
    plan = make_serving_plan(prob, k=3, spare=6, slack=8)
    xq = np.linspace(-0.8, 0.8, 32)[:, None].astype(np.float32)

    def trace_round(prob, state, plan, i):
        x = np.array([0.1 + 0.04 * i], np.float32)
        prob, state, _rec = add_sensor(
            prob, state, x, rng.normal(size=2).astype(np.float32), lam=0.1
        )
        slot, _ = _rec.slot, _rec.joined
        plan, _ = plan_add_sensor(plan, x, slot)
        a = 4
        fs = rng.integers(0, 2, size=a)
        ss = rng.integers(0, 30, size=a)
        xs = (pos[ss] + 0.02 * rng.normal(size=(a, 1))).astype(np.float32)
        prob, state, _ = streaming.absorb_many(
            prob, state, fs, ss, xs, rng.normal(size=a).astype(np.float32)
        )
        state = colored_sweep(prob, state, n_sweeps=2)
        prob, state, _ = remove_sensor(prob, state, 5 + i)
        plan = plan_remove_sensor(plan, 5 + i)
        state = colored_sweep(prob, state, n_sweeps=1)
        out = fusion.fuse(prob, state, xq, "knn", k=3, engine="plan", plan=plan)
        out.block_until_ready()
        return prob, state, plan

    prob, state, plan = trace_round(prob, state, plan, 0)  # warmup
    snap = compile_ledger.snapshot(
        compile_ledger.churn_group(on_full="drop", donate=False)
    )
    for i in range(1, 4):
        prob, state, plan = trace_round(prob, state, plan, i)
    # buckets=0: the warmup round already compiled the only query bucket
    snap.assert_within(buckets=0, context="churn trace")
    assert snap.total_growth() == 0, snap.growth()


# ---------------------------------------------------------------------------
# (d) serving-plan repair keeps the kNN engines exact across churn
# ---------------------------------------------------------------------------


def test_serving_plan_repair_matches_alive_masked_dense():
    prob, state, pos, rng = _lifecycle_problem(n=30, b=3, spares=4, sweeps=8)
    plan = make_serving_plan(prob, k=3, spare=6, slack=4)
    xq = rng.uniform(-0.85, 0.85, size=(41, 1)).astype(np.float32)
    removed = [4, 11, 17]
    for i, rm in enumerate(removed):
        x = np.array([-0.3 + 0.25 * i], np.float32)
        prob, state, _rec = add_sensor(
            prob, state, x, rng.normal(size=3).astype(np.float32), lam=0.1
        )
        slot, ok = _rec.slot, _rec.joined
        assert bool(ok)
        plan, over = plan_add_sensor(plan, x, slot)
        assert int(over) == 0
        prob, state, rok = remove_sensor(prob, state, rm)
        assert bool(rok)
        plan = plan_remove_sensor(plan, rm)
        state = colored_sweep(prob, state, n_sweeps=3)

    dense = np.asarray(fusion.fuse(prob, state, xq, "knn", k=3))
    assert dense.shape == (3, 41)
    for engine in ("plan", "pallas"):
        out = fusion.fuse(
            prob, state, xq, "knn", k=3, engine=engine, plan=plan
        )
        np.testing.assert_allclose(
            np.asarray(out), dense, atol=1e-5, err_msg=engine
        )
    # the conn/avg rules weight live sensors only on the churned network
    for rule in ("conn", "avg"):
        out = np.asarray(fusion.fuse(prob, state, xq, rule))
        assert np.isfinite(out).all()
    # a fresh host plan on the churned problem agrees with the repaired one
    fresh = make_serving_plan(prob, k=3)
    out_fresh = np.asarray(
        fusion.fuse(prob, state, xq, "knn", k=3, engine="plan", plan=fresh)
    )
    np.testing.assert_allclose(out_fresh, dense, atol=1e-5)


def test_dense_knn_averages_live_sensors_only_when_k_exceeds_live():
    """top_k must return k rows even when fewer sensors are alive; the dense
    oracle averages only the live selections instead of diluting with dead
    rows' zero predictions."""
    pos = np.array([[-0.5], [0.0], [0.5]], np.float32)
    topo = build_topology(pos, 2.0, d_max=4)
    prob = make_batch_problem(
        topo, KERN, np.array([[1.0, 1.0, 1.0]]), jnp.full((3,), 0.1)
    )
    state = colored_sweep(prob, init_state(prob), n_sweeps=20)
    prob, state, _ = remove_sensor(prob, state, 2)
    xq = np.array([[0.1]], np.float32)
    preds = np.asarray(fusion.evaluate_sensors(prob, state, xq))  # (1, 3, 1)
    out = np.asarray(fusion.fuse(prob, state, xq, "knn", k=3))
    np.testing.assert_allclose(out, preds[:, :2, 0].mean(axis=1, keepdims=True))


def test_global_coefficients_exclude_dead_rows():
    from repro.kernels import kernel_matvec

    prob, state, pos, rng = _lifecycle_problem(n=25, b=2, spares=3, sweeps=6)
    prob, state, _rec = add_sensor(
        prob, state, np.array([0.22], np.float32),
        rng.normal(size=2).astype(np.float32), lam=0.1,
    )
    slot, _ = _rec.slot, _rec.joined
    prob, state, _ = remove_sensor(prob, state, 6)
    state = colored_sweep(prob, state, n_sweeps=4)
    xq = np.linspace(-0.9, 0.9, 21)[:, None].astype(np.float32)
    anchors, coefs = fusion.global_coefficients(prob, state, rule="conn")
    fused = kernel_matvec(xq, anchors, coefs, gamma=1.0)
    direct = fusion.fuse(prob, state, xq, "conn")
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(direct), atol=2e-5
    )


# ---------------------------------------------------------------------------
# (e) Fejér monotonicity across interleaved lifecycle events (Lemma 2.1)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 1000))
def test_fejer_preserved_across_interleaved_churn(seed):
    """Every constraint set stays a subspace containing 0 through joins,
    leaves and absorptions, so each post-event sweep sequence decreases the
    weighted norm (f32 slack as in the other Fejér tests)."""
    prob, state, pos, rng = _lifecycle_problem(
        n=20, b=2, spares=3, seed=seed % 7, sweeps=2
    )
    ev_rng = np.random.default_rng(seed)
    joined = []
    for step in range(6):
        kind = ev_rng.integers(0, 3)
        if kind == 0:
            x = ev_rng.uniform(-0.8, 0.8, size=1).astype(np.float32)
            prob, state, _rec = add_sensor(
                prob, state, x, ev_rng.normal(size=2).astype(np.float32),
                lam=0.1,
            )
            slot, ok = _rec.slot, _rec.joined
            if bool(ok):
                joined.append(int(slot))
        elif kind == 1 and step > 1:
            victim = (
                joined.pop() if joined else int(ev_rng.integers(0, 20))
            )
            prob, state, _ = remove_sensor(prob, state, victim)
        else:
            s = int(ev_rng.integers(0, 20))
            x = (pos[s] + 0.05 * ev_rng.normal(size=1)).astype(np.float32)
            prob, state, _ = streaming.absorb(
                prob, state, int(ev_rng.integers(0, 2)), s, x,
                float(ev_rng.normal()),
            )
        prev = np.asarray(weighted_norm_sq(prob, state))
        for _ in range(2):
            state = colored_sweep(prob, state, n_sweeps=1)
            cur = np.asarray(weighted_norm_sq(prob, state))
            assert np.isfinite(cur).all()
            assert (cur <= prev * 1.06 + 1e-5).all(), (step, cur, prev)
            prev = cur
