"""MoE dispatch invariants (GShard-style grouped top-k with capacity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.layers import _capacity, moe_apply, moe_init


def _cfg(e=4, k=2, group=16, cap=2.0, shared=0):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64, n_experts=e, top_k=k,
        moe_d_ff=48, moe_group_size=group, capacity_factor=cap,
        n_shared_experts=shared,
    )


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    y, m = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(m["aux_loss"]) >= 1.0 - 1e-3  # aux >= 1 at optimum (E*sum f*P >= 1)


def test_generous_capacity_conserves_token_mass():
    """With capacity >> needed, every token reaches all its top-k experts:
    combine weights per token sum to 1 after renormalization."""
    cfg = _cfg(cap=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32))

    # recompute dispatch internals via a probe: uniform expert weights ->
    # output equals weighted mix; easier: check no-drop via expert_load
    _, m = moe_apply(p, cfg, x)
    assert float(m["expert_load"].sum()) == pytest.approx(16 * cfg.top_k, abs=1e-3)


def test_tight_capacity_drops_tokens():
    cfg = _cfg(e=2, k=1, group=16, cap=0.5)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 32))
    y, m = moe_apply(p, cfg, x)
    cap = _capacity(cfg, 16)
    # at most e*cap slots can be filled per group
    assert float(m["expert_load"].sum()) == pytest.approx(16.0, abs=1e-3)  # routed mass
    # dropped tokens produce zero output rows (identity-less residual path)
    assert bool(jnp.isfinite(y).all())


def test_shared_expert_adds_dense_path():
    cfg0, cfg1 = _cfg(shared=0), _cfg(shared=1)
    p1 = moe_init(jax.random.PRNGKey(0), cfg1)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 32))
    y1, _ = moe_apply(p1, cfg1, x)
    # zero the shared expert -> output changes
    p0 = dict(p1)
    p0["shared"] = jax.tree.map(jnp.zeros_like, p1["shared"])
    y0, _ = moe_apply(p0, cfg1, x)
    assert float(jnp.abs(y1 - y0).max()) > 1e-6


@settings(deadline=None, max_examples=10)
@given(
    e=st.sampled_from([2, 4]),
    k=st.integers(1, 2),
    s=st.integers(1, 33),
    group=st.sampled_from([8, 512]),
)
def test_moe_arbitrary_token_counts(e, k, s, group):
    """Group padding must handle any (B*S) % group remainder exactly."""
    cfg = _cfg(e=e, k=k, group=group)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(s), (2, s, 32))
    y, _ = moe_apply(p, cfg, x)
    assert y.shape == (2, s, 32)
    assert bool(jnp.isfinite(y).all())


def test_decode_single_token_moe():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 1, 32))
    y, _ = moe_apply(p, cfg, x)
    assert y.shape == (4, 1, 32)


def test_router_gradient_flows():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 32))

    def loss(params):
        y, m = moe_apply(params, cfg, x)
        return jnp.sum(y**2) + 0.01 * m["aux_loss"]

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0.0
