"""Batched multi-field SN-Train engine + streaming absorption properties.

Covers the ISSUE-1 tentpole guarantees:
  (a) per-field Fejér monotonicity (Lemma 2.1) under the batched sweeps;
  (b) a full hypercube gossip sweep equals pmean (Lemma 3.1) with a batch
      axis;
  (c) the streaming rank-1 Cholesky update matches a from-scratch rebuild
      after many arrivals;
plus B=1 equivalence with the single-field path and the fused multi-field
serving evaluation.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    consensus,
    field_view,
    fusion,
    init_state,
    local_only,
    make_batch_problem,
    make_problem,
    serial_sweep,
    streaming,
    uniform_sensors,
    weighted_norm_sq,
)
from repro.kernels import kernel_matvec
from repro.kernels.ref import kernel_matvec_batched_ref

KERN = Kernel("rbf", gamma=1.0)


def _setup(n=30, b=3, radius=0.8, seed=0, lam=0.1, headroom=0):
    pos = uniform_sensors(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    topo = build_topology(pos, radius)
    if headroom:
        d_max = int(np.asarray(topo.degrees).max()) + headroom
        topo = build_topology(pos, radius, d_max=d_max)
    lams = None if lam is None else jnp.full((n,), lam)
    return topo, ys, make_batch_problem(topo, KERN, ys, lams), pos


# ---------------------------------------------------------------------------
# B = 1 and per-field equivalence with the single-field engine
# ---------------------------------------------------------------------------


def test_batched_b1_colored_identical_to_single_field():
    """Acceptance: batched colored_sweep at B=1 == single-field path <=1e-5.

    (They share one core, so the match is exact.)"""
    topo, ys, prob_b, _ = _setup(b=1)
    prob_1 = make_problem(topo, KERN, ys[0], jnp.full((topo.n,), 0.1))
    out_b = colored_sweep(prob_b, init_state(prob_b), n_sweeps=30)
    out_1 = colored_sweep(prob_1, init_state(prob_1), n_sweeps=30)
    np.testing.assert_allclose(
        np.asarray(out_b.z[0]), np.asarray(out_1.z), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(out_b.coef[0]), np.asarray(out_1.coef), atol=1e-5
    )


def test_batched_b1_serial_matches_single_field():
    topo, ys, prob_b, _ = _setup(b=1)
    prob_1 = make_problem(topo, KERN, ys[0], jnp.full((topo.n,), 0.1))
    out_b = serial_sweep(prob_b, init_state(prob_b), n_sweeps=30)
    out_1 = serial_sweep(prob_1, init_state(prob_1), n_sweeps=30)
    # the vmapped lowering may reassociate reductions: tiny f32 drift allowed
    np.testing.assert_allclose(
        np.asarray(out_b.z[0]), np.asarray(out_1.z), atol=1e-4
    )


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 500))
def test_batched_colored_equals_per_field_singles(seed):
    """Each field of a B=4 batch solves ITS problem, untouched by the rest."""
    topo, ys, prob_b, _ = _setup(b=4, seed=seed)
    out_b = colored_sweep(prob_b, init_state(prob_b), n_sweeps=10)
    for b in range(4):
        prob_1 = make_problem(topo, KERN, ys[b], jnp.full((topo.n,), 0.1))
        out_1 = colored_sweep(prob_1, init_state(prob_1), n_sweeps=10)
        np.testing.assert_allclose(
            np.asarray(out_b.z[b]), np.asarray(out_1.z), atol=1e-5
        )


def test_local_only_batched_matches_per_field():
    topo, ys, prob_b, _ = _setup(b=3)
    out_b = local_only(prob_b)
    for b in range(3):
        prob_1 = make_problem(topo, KERN, ys[b], jnp.full((topo.n,), 0.1))
        out_1 = local_only(prob_1)
        np.testing.assert_allclose(
            np.asarray(out_b.coef[b]), np.asarray(out_1.coef), atol=1e-6
        )


# ---------------------------------------------------------------------------
# (a) Per-field Fejér monotonicity under the batched sweeps (Lemma 2.1)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 1000))
def test_batched_fejer_monotone_per_field_paper_lambdas(seed):
    """||z_b||^2 + sum_i lambda_i ||f_{b,i}||^2 never increases, per field,
    with the paper's own lambda_i = kappa/|N_i|^2 (see test_sn_train for the
    f32 slack rationale)."""
    _, _, prob, _ = _setup(b=4, seed=seed, lam=None)  # paper default lambdas
    state = init_state(prob)
    prev = np.asarray(weighted_norm_sq(prob, state))
    assert prev.shape == (4,)
    for _ in range(5):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert (cur <= prev * 1.06 + 1e-5).all(), (cur, prev)
        prev = cur


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 1000))
def test_batched_serial_fejer_monotone_per_field(seed):
    _, _, prob, _ = _setup(b=3, seed=seed, lam=1e-2)
    state = init_state(prob)
    prev = np.asarray(weighted_norm_sq(prob, state))
    for _ in range(4):
        state = serial_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert (cur <= prev * 1.03 + 1e-5).all(), (cur, prev)
        prev = cur


# ---------------------------------------------------------------------------
# (b) Hypercube gossip sweep == pmean with a batch axis (Lemma 3.1)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 1000), logn=st.integers(1, 4), batch=st.integers(1, 5))
def test_hypercube_gossip_equals_pmean_with_batch_axis(seed, logn, batch):
    """The complete pairing sweep averages every replica — independently for
    every field of a leading batch axis on each leaf."""
    n = 2**logn
    rng = np.random.default_rng(seed)
    tree = {
        "w": jnp.asarray(rng.normal(size=(n, batch, 4, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n, batch, 5)).astype(np.float32)),
    }
    out = consensus.sim_gossip_sweep(tree, consensus.hypercube_schedule(n))
    for k, v in out.items():
        mean = jnp.mean(tree[k], axis=0, keepdims=True)  # per-field mean
        np.testing.assert_allclose(
            np.asarray(v), np.broadcast_to(np.asarray(mean), v.shape), atol=1e-5
        )


# ---------------------------------------------------------------------------
# (c) Streaming rank-1 absorption vs from-scratch rebuild
# ---------------------------------------------------------------------------


def _absorb_many(prob, state, pos, n_events, seed, b):
    rng = np.random.default_rng(seed)
    n = prob.n
    for _ in range(n_events):
        f = int(rng.integers(0, b))
        s = int(rng.integers(0, n))
        x = (pos[s] + 0.1 * rng.normal(size=pos.shape[1])).astype(np.float32)
        prob, state, _ = streaming.absorb(prob, state, f, s, x, float(rng.normal()))
    return prob, state


def test_streaming_chol_matches_rebuild_after_50_arrivals():
    """Acceptance: 50 rank-1 grow updates == full refactorization <= 1e-4."""
    topo, ys, prob, pos = _setup(b=3, headroom=8)
    state = init_state(prob)
    prob, state = _absorb_many(prob, state, pos, 50, seed=7, b=3)
    ref = streaming.rebuild_chol(prob)
    np.testing.assert_allclose(
        np.asarray(prob.chol), np.asarray(ref), atol=1e-4
    )
    # gram stays symmetric with zeros off the occupancy mask
    g = np.asarray(prob.gram)
    np.testing.assert_allclose(g, np.swapaxes(g, -1, -2), atol=1e-6)
    mask = np.asarray(prob.nbr_mask)
    outer = mask[..., :, None] & mask[..., None, :]
    assert (g[~outer] == 0).all()


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 1000))
def test_streaming_preserves_fejer_and_converges(seed):
    """Absorption keeps every constraint set a subspace containing 0: sweeps
    after arrivals still Fejér-decrease, and the iterates stay finite."""
    topo, ys, prob, pos = _setup(b=2, headroom=6)
    state = colored_sweep(prob, init_state(prob), n_sweeps=3)
    prob, state = _absorb_many(prob, state, pos, 12, seed=seed, b=2)
    prev = np.asarray(weighted_norm_sq(prob, state))
    for _ in range(4):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert np.isfinite(cur).all()
        assert (cur <= prev * 1.06 + 1e-5).all(), (cur, prev)
        prev = cur


def test_streaming_overflow_drops_instead_of_corrupting():
    """An arrival at a FULL sensor must be a no-op, not an aliased write."""
    import pytest

    topo, ys, prob, pos = _setup(b=1, headroom=2)
    state = init_state(prob)
    s = 0
    free = int(np.asarray(streaming.capacity_left(prob))[0, s])
    for i in range(free):  # fill sensor 0 of field 0 to capacity
        prob, state, ok = streaming.absorb(
            prob, state, 0, s, pos[s] + 0.01 * (i + 1), 1.0
        )
        assert bool(ok)
    assert int(np.asarray(streaming.capacity_left(prob))[0, s]) == 0
    over_p, over_s, ok = streaming.absorb(prob, state, 0, s, pos[s] + 0.5, 9.9)
    assert not bool(ok)
    np.testing.assert_array_equal(np.asarray(over_p.gram), np.asarray(prob.gram))
    np.testing.assert_array_equal(
        np.asarray(over_p.nbr_mask), np.asarray(prob.nbr_mask)
    )
    np.testing.assert_array_equal(
        np.asarray(over_s.z[:, :-1]), np.asarray(state.z[:, :-1])
    )

    # zero-capacity problems are rejected statically
    topo0 = build_topology(uniform_sensors(6, seed=0), 5.0)  # complete graph
    prob0 = make_batch_problem(topo0, KERN, np.zeros((1, 6)), jnp.full((6,), 0.1))
    with pytest.raises(ValueError, match="streaming capacity"):
        streaming.absorb(prob0, init_state(prob0), 0, 0, np.zeros(1), 0.0)


def test_local_only_refuses_absorbed_problems():
    import pytest

    topo, ys, prob, pos = _setup(b=2, headroom=3)
    local_only(prob)  # fine pre-streaming
    prob, state, _ = streaming.absorb(prob, init_state(prob), 0, 1, pos[1] + 0.1, 1.0)
    with pytest.raises(NotImplementedError, match="pre-streaming"):
        local_only(prob)


def test_streaming_arrival_seeds_its_message_slot():
    topo, ys, prob, pos = _setup(b=2, headroom=4)
    state = init_state(prob)
    n = prob.n
    x = (pos[5] + 0.05).astype(np.float32)
    prob2, state2, _ = streaming.absorb(prob, state, 1, 5, x, 2.5)
    # sensor 5 of field 1 gained exactly one slot; field 0 untouched
    d_mask = np.asarray(prob2.nbr_mask[1]) != np.asarray(prob.nbr_mask[1])
    assert d_mask.sum() == 1 and d_mask[5].sum() == 1
    assert (np.asarray(prob2.nbr_mask[0]) == np.asarray(prob.nbr_mask[0])).all()
    k = int(np.argmax(d_mask[5]))
    zid = int(np.asarray(prob2.nbr_idx)[5, k])
    assert zid >= n
    assert float(state2.z[1, zid]) == 2.5
    assert float(state2.z[0, zid]) == 0.0
    np.testing.assert_allclose(np.asarray(prob2.stream_pos[1, zid - n]), x)


def test_evict_oldest_round_trip_matches_scratch():
    """Over-capacity policy (ROADMAP): absorb A,B,C -> evict_oldest ->
    absorb D equals building the B,C,D window from scratch — exactly for
    every permuted array, to float noise for the downdated factor."""
    topo, ys, prob0, pos = _setup(b=2, headroom=3)
    rng = np.random.default_rng(11)
    s = 4
    events = [
        ((pos[s] + 0.1 * rng.normal(size=pos.shape[1])).astype(np.float32),
         float(rng.normal()))
        for _ in range(4)
    ]
    a, b, c, d = events

    prob1, st1 = prob0, init_state(prob0)
    for x, y in (a, b, c):
        prob1, st1, ok = streaming.absorb(prob1, st1, 0, s, x, y)
        assert bool(ok)
    prob1, st1, ev = streaming.evict_oldest(prob1, st1, 0, s)
    assert bool(ev)
    prob1, st1, ok = streaming.absorb(prob1, st1, 0, s, *d)
    assert bool(ok)

    prob2, st2 = prob0, init_state(prob0)
    for x, y in (b, c, d):
        prob2, st2, ok = streaming.absorb(prob2, st2, 0, s, x, y)
        assert bool(ok)

    for name in ("nbr_pos", "nbr_mask", "gram", "stream_pos"):
        np.testing.assert_array_equal(
            np.asarray(getattr(prob1, name)), np.asarray(getattr(prob2, name)),
            err_msg=name,
        )
    np.testing.assert_array_equal(np.asarray(st1.z), np.asarray(st2.z))
    # the masked-rebuild downdate vs three grow-one updates: same factor up
    # to float noise, and still consistent with a from-scratch rebuild
    np.testing.assert_allclose(
        np.asarray(prob1.chol), np.asarray(prob2.chol), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(prob1.chol), np.asarray(streaming.rebuild_chol(prob1)),
        atol=1e-5,
    )


def test_evict_oldest_empty_sensor_is_noop():
    topo, ys, prob, pos = _setup(b=2, headroom=2)
    state = init_state(prob)
    prob2, state2, ev = streaming.evict_oldest(prob, state, 1, 7)
    assert not bool(ev)
    np.testing.assert_array_equal(np.asarray(prob2.gram), np.asarray(prob.gram))
    np.testing.assert_array_equal(
        np.asarray(prob2.nbr_mask), np.asarray(prob.nbr_mask)
    )
    np.testing.assert_array_equal(np.asarray(state2.z), np.asarray(state.z))


def test_absorb_on_full_evicts_sliding_window():
    """on_full="evict": a full sensor absorbs by dropping its OLDEST
    arrival; sweeps on the evicted problem stay finite and Fejér-decrease."""
    topo, ys, prob, pos = _setup(b=1, headroom=2)
    state = init_state(prob)
    s = 0
    cap = int(np.asarray(streaming.capacity_left(prob))[0, s])
    xs = [pos[s] + np.float32(0.01 * (i + 1)) for i in range(cap + 1)]
    for i in range(cap):
        prob, state, ok = streaming.absorb(prob, state, 0, s, xs[i], float(i))
        assert bool(ok)
    prob, state, ok = streaming.absorb(
        prob, state, 0, s, xs[cap], 99.0, on_full="evict"
    )
    assert bool(ok)  # absorbed, not dropped
    assert int(np.asarray(streaming.capacity_left(prob))[0, s]) == 0
    # the window now holds arrivals 1..cap: the sensor's stream positions
    # match xs[1:], in order
    deg = int(np.asarray(topo.degrees)[s])
    zids = np.asarray(prob.nbr_idx)[s, deg:]
    got = np.asarray(prob.stream_pos)[0, zids - prob.n]
    np.testing.assert_allclose(got, np.asarray(xs[1:]), atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(prob.chol), np.asarray(streaming.rebuild_chol(prob)),
        atol=1e-4,
    )
    prev = np.asarray(weighted_norm_sq(prob, state))
    for _ in range(3):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert np.isfinite(cur).all()
        assert (cur <= prev * 1.06 + 1e-5).all()
        prev = cur


# ---------------------------------------------------------------------------
# Batched serving path: sharded fields + fused multi-field evaluation
# ---------------------------------------------------------------------------


def test_sharded_fields_matches_batched_colored_subprocess():
    """Field-sharded engine (4 devices) == batched colored engine."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import *
pos = uniform_sensors(24, seed=0)
rng = np.random.default_rng(1)
ys = np.sin(np.pi*rng.uniform(0.5,2,(8,1))*pos[None,:,0]) + 0.3*rng.normal(size=(8,24))
topo = build_topology(pos, 0.8)
prob = make_batch_problem(topo, Kernel("rbf", gamma=1.0), ys, jnp.full((24,), 1e-2))
st0 = init_state(prob)
ref = colored_sweep(prob, st0, n_sweeps=7)
mesh = compat.make_mesh((4,), ("fields",))
sh = sharded_sweep(prob, st0, mesh, axis="fields", n_sweeps=7)
assert np.allclose(np.asarray(ref.z), np.asarray(sh.z), atol=1e-5), np.abs(np.asarray(ref.z)-np.asarray(sh.z)).max()
assert np.allclose(np.asarray(ref.coef), np.asarray(sh.coef), atol=1e-5)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_global_coefficients_fused_eval_matches_fusion_rules():
    """One batched kernel_matvec over the collapsed expansions == per-field
    conn/avg fusion of the per-sensor estimates (including stream anchors)."""
    topo, ys, prob, pos = _setup(b=3, headroom=4)
    state = colored_sweep(prob, init_state(prob), n_sweeps=10)
    prob, state = _absorb_many(prob, state, pos, 9, seed=3, b=3)
    state = colored_sweep(prob, state, n_sweeps=3)
    xq = np.linspace(-1, 1, 33)[:, None].astype(np.float32)
    for rule in ("conn", "avg"):
        anchors, coefs = fusion.global_coefficients(prob, state, rule=rule)
        fused = kernel_matvec(xq, anchors, coefs, gamma=1.0)  # (B, Q) Pallas
        for b in range(3):
            pv, sv = field_view(prob, state, b)
            direct = fusion.fuse(pv, sv, xq, rule)
            np.testing.assert_allclose(
                np.asarray(fused[b]), np.asarray(direct), atol=2e-5
            )


@settings(deadline=None, max_examples=6)
@given(
    q=st.sampled_from([1, 7, 130]),
    n=st.sampled_from([1, 13, 600]),
    b=st.integers(1, 6),
)
def test_batched_kernel_matvec_matches_ref(q, n, b):
    rng = np.random.default_rng(q * 7 + n + b)
    xq = rng.normal(size=(q, 2)).astype(np.float32)
    an = rng.normal(size=(b, n, 2)).astype(np.float32)
    c = rng.normal(size=(b, n)).astype(np.float32)
    out = kernel_matvec(xq, an, c, gamma=1.3)
    ref = kernel_matvec_batched_ref(
        jnp.asarray(xq), jnp.asarray(an), jnp.asarray(c), 1.3
    )
    assert out.shape == (b, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)
