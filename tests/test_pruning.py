"""Representer pruning (ISSUE-9 sparsified serving path).

Covers:
  (a) the energy bound itself: |f_s(x)| <= E_s for the sup-1 serving
      kernel (``representer_energy``);
  (b) hypothesis property: pruned serving stays within ``answer_bound``
      of unpruned serving — mask path AND compacted path — across dead
      fractions {0, 1/n, k/n, 1} and drawn tau;
  (c) mask path == compacted plan (same surviving candidates -> identical
      answers), and tau = 0 compaction is EXACT while reclaiming the
      spare/dead candidate columns;
  (d) lifecycle composition: a pruned-out sensor that then DIES can never
      be resurrected by pruning alone — only a real re-join (alive +
      energetic) re-enters selection or a recompacted plan;
  (e) tau monotonicity and PruneReport bookkeeping.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    add_sensor,
    build_topology,
    colored_sweep,
    fusion,
    init_state,
    make_batch_problem,
    make_serving_plan,
    pruning,
    remove_sensor,
    serving,
    uniform_sensors,
)

KERN = Kernel("rbf", gamma=1.0)


def _problem(n=24, b=2, spares=4, radius=0.7, seed=0, lam=0.1, sweeps=5):
    pos = uniform_sensors(n, d=1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.2 * rng.normal(size=(b, n))
    topo = build_topology(pos, radius)
    d_max = int(np.asarray(topo.degrees).max()) + 2
    topo = build_topology(pos, radius, d_max=d_max, n_max=n + spares)
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), lam))
    state = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
    return prob, state, pos, rng


def _kill(prob, dead_ids):
    """Serving-level death: flip alive rows (factors untouched — serving
    only reads alive + tables, so this is valid for read-out tests)."""
    alive = np.asarray(prob.alive).copy()
    alive[np.asarray(dead_ids, dtype=int)] = 0
    return dataclasses.replace(prob, alive=jnp.asarray(alive))


def test_energy_bounds_prediction():
    """|f_s(x)| <= E_s everywhere (sup-1 kernel), per field."""
    prob, state, pos, rng = _problem()
    xq = np.linspace(-1.2, 1.2, 301)[:, None].astype(np.float32)
    energy = np.asarray(pruning.representer_energy(prob, state))
    preds = np.asarray(fusion.evaluate_sensors(prob, state, xq))
    # (B, n, Q) or (n, Q); reduce over fields and queries
    worst = np.abs(preds).max(axis=-1)
    if worst.ndim == 2:
        worst = worst.max(axis=0)
    assert (worst <= energy[: worst.shape[0]] + 1e-5).all()


def test_lane_energy_shape_and_sum():
    prob, state, _, _ = _problem()
    lane = np.asarray(
        pruning.representer_energy(prob, state, per_lane=True)
    )
    total = np.asarray(pruning.representer_energy(prob, state))
    assert lane.ndim == 2 and lane.shape[0] == total.shape[0]
    np.testing.assert_allclose(lane.sum(axis=-1), total, rtol=1e-6)
    assert total[-1] == 0.0  # sentinel row carries no energy


@settings(max_examples=12, deadline=None)
@given(
    dead_mode=st.sampled_from(["none", "one", "k", "all"]),
    tau_frac=st.floats(min_value=0.0, max_value=0.6),
    seed=st.integers(min_value=0, max_value=3),
)
def test_pruned_within_answer_bound(dead_mode, tau_frac, seed):
    """|unpruned - pruned| <= answer_bound, mask AND compacted paths,
    at dead fractions {0, 1/n, k/n, 1}."""
    k = 3
    prob, state, pos, rng = _problem(seed=seed)
    # plan built on the all-alive network; deaths flow through the alive
    # gate (the serving invariant the churn tests pin)
    plan = make_serving_plan(prob, k=k, spare=2, slack=1)
    live_ids = np.flatnonzero(np.asarray(prob.alive)[:-1])
    count = {"none": 0, "one": 1, "k": k, "all": live_ids.size}[dead_mode]
    dead = np.random.default_rng(seed + 7).choice(
        live_ids, size=count, replace=False
    )
    prob = _kill(prob, dead)
    energy = np.asarray(pruning.representer_energy(prob, state))
    tau = tau_frac * float(energy.max())
    keep = pruning.prune_mask(prob, state, energy_tau=tau)

    xq = rng.uniform(-1, 1, size=(64, 1)).astype(np.float32)
    u = np.asarray(
        serving.knn_fuse(prob, state, xq, k=k, plan=plan, engine="plan")
    )
    p_mask = np.asarray(
        serving.knn_fuse(
            prob, state, xq, k=k, plan=plan, engine="plan", prune=keep
        )
    )
    positions = prob.topology.positions
    sel_u, val_u = serving.knn_select_valid(plan, positions, xq, k, prob.alive)
    alive_p = ((np.asarray(prob.alive) != 0) & np.asarray(keep)).astype(np.int8)
    sel_p, val_p = serving.knn_select_valid(
        plan, positions, xq, k, jnp.asarray(alive_p)
    )
    bound = pruning.answer_bound(energy, sel_u, val_u, sel_p, val_p)
    gap = np.abs(u - p_mask).max(axis=0)  # worst field per query
    assert (gap <= bound + 1e-5).all(), (gap - bound).max()

    # compacted path obeys the same bound (identical answers to the mask
    # path: same surviving candidate sets)
    plan_c, rep = pruning.prune_plan(prob, state, plan, energy_tau=tau)
    p_comp = np.asarray(
        serving.knn_fuse(prob, state, xq, k=k, plan=plan_c, engine="plan")
    )
    np.testing.assert_allclose(p_comp, p_mask, atol=1e-6)
    assert rep.k_max_after <= rep.k_max_before


def test_tau0_compaction_exact_and_reclaims_capacity():
    """tau = 0 drops only dead/spare candidate entries: answers are
    bitwise the capacity plan's, and the gather width shrinks."""
    prob, state, pos, rng = _problem(spares=6)
    k = 3
    plan = make_serving_plan(prob, k=k, spare=6, slack=2)
    plan0, rep = pruning.prune_plan(prob, state, plan, energy_tau=0.0)
    assert rep.n_pruned == 0
    assert rep.k_max_after < rep.k_max_before
    xq = rng.uniform(-1, 1, size=(128, 1)).astype(np.float32)
    for engine in ("plan", "pallas"):
        a = np.asarray(
            serving.knn_fuse(prob, state, xq, k=k, plan=plan, engine=engine)
        )
        b = np.asarray(
            serving.knn_fuse(prob, state, xq, k=k, plan=plan0, engine=engine)
        )
        np.testing.assert_array_equal(a, b, err_msg=engine)


def test_no_resurrection_after_leave():
    """prune -> leave: the dead sensor stays out of the keep mask (even at
    tau = 0 with nonzero coefficients), out of every compacted candidate
    list, and out of every selection; a true re-join re-enters."""
    prob, state, pos, rng = _problem()
    k = 2
    victim = 5
    plan = make_serving_plan(prob, k=k, spare=2, slack=1)
    prob2, state2, ok = remove_sensor(prob, state, victim)
    assert bool(ok)
    keep = np.asarray(pruning.prune_mask(prob2, state2, energy_tau=0.0))
    assert not keep[victim]  # dead -> never kept, energy is irrelevant
    plan_c, _ = pruning.prune_plan(prob2, state2, plan, energy_tau=0.0)
    cells = np.asarray(plan_c.cells)[np.asarray(plan_c.cell_mask).astype(bool)]
    assert victim not in cells
    xq = rng.uniform(-1, 1, size=(64, 1)).astype(np.float32)
    sel, valid = serving.knn_select_valid(
        plan_c, prob2.topology.positions, xq, k,
        jnp.asarray(keep.astype(np.int8)),
    )
    assert victim not in np.asarray(sel)[np.asarray(valid)]

    # a REAL re-join (alive + energetic) is eligible again
    x_new = np.asarray(pos[victim], np.float32)
    ys_new = np.array([0.4, -0.2], np.float32)  # one per field (b = 2)
    prob3, state3, rec = add_sensor(prob2, state2, x_new, ys_new, lam=0.1)
    assert bool(rec.joined)
    # the row joins with zero coefficients — it earns energy by training
    state3 = colored_sweep(prob3, state3, n_sweeps=3)
    keep3 = np.asarray(pruning.prune_mask(prob3, state3, energy_tau=0.0))
    assert keep3[int(rec.slot)]


def test_tau_monotone_and_report():
    prob, state, _, _ = _problem()
    plan = make_serving_plan(prob, k=3, spare=2, slack=1)
    energy = np.asarray(pruning.representer_energy(prob, state))
    prev_kept = None
    prev_kmax = None
    for tau_frac in (0.0, 0.1, 0.3, 0.6):
        tau = tau_frac * float(energy.max())
        keep = np.asarray(pruning.prune_mask(prob, state, energy_tau=tau))
        plan_c, rep = pruning.prune_plan(prob, state, plan, energy_tau=tau)
        assert rep.n_live == rep.n_kept + rep.n_pruned
        assert rep.n_kept == int(keep[:-1].sum())
        np.testing.assert_array_equal(rep.keep, keep)
        if prev_kept is not None:
            # larger tau keeps a SUBSET, and the compacted width shrinks
            assert not np.any(keep & ~prev_kept)
            assert rep.k_max_after <= prev_kmax
        prev_kept, prev_kmax = keep, rep.k_max_after


def test_prune_needs_state_or_ecoef():
    prob, state, _, _ = _problem()
    try:
        pruning.prune_mask(prob, energy_tau=0.0)
    except ValueError as e:
        assert "state or ecoef" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected ValueError")
