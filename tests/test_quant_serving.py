"""Quantized serving path (ISSUE-9): bf16 anchors, f32 accumulation.

Covers:
  (a) engine agreement under quantization: bf16 plan == bf16 pallas
      (tight — both engines round the SAME anchors the same way) and both
      stay within a small relative RMSE of the f32 dense oracle
      (anchors-only rounding — selection is exact by construction, so the
      only perturbation is bf16 rounding inside exp(-gamma*||x - x_j||^2));
  (b) selection-exactness: the production quantized path never flips a
      selected set (quantized output deviates from f32 by far less than
      one representer swap would cost), while the OPT-IN
      ``knn_select_valid(compute_dtype=...)`` measurement knob CAN flip
      near-ties — the decomposition the design is built on;
  (c) output dtype: quantized serving accumulates and returns in the
      coefficient dtype (f32/f64), never bf16;
  (d) zero-recompile contract: after one warmup per query bucket, sweeping
      taus (traced), dtypes already seen, and query sizes inside a bucket
      compiles NOTHING new (jit-cache-counted);
  (e) x64 subprocess: an f64 problem served with bf16 anchors keeps f64
      output and stays close to its f32-anchor answer (satellite of the
      f64-through-pallas dtype fix);
  (f) argument validation (bad compute_dtype; dense-engine rejections;
      block_q on non-pallas engines).
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    fusion,
    init_state,
    make_batch_problem,
    make_serving_plan,
    pruning,
    serving,
    uniform_sensors,
)

KERN = Kernel("rbf", gamma=1.0)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _batched(n=40, b=3, radius=0.6, seed=0, d=2, sweeps=10):
    pos = uniform_sensors(n, d=d, seed=seed)
    topo = build_topology(pos, radius)
    rng = np.random.default_rng(seed + 1)
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), 0.1))
    state = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
    return prob, state, pos, rng


def test_bf16_engines_agree_and_track_dense():
    prob, state, pos, rng = _batched()
    k = 3
    plan = make_serving_plan(prob, k=k)
    xq = rng.uniform(-1, 1, size=(97, 2)).astype(np.float32)
    dense = np.asarray(fusion.fuse(prob, state, xq, "knn", k=k, engine="dense"))
    rms = float(np.sqrt(np.mean(dense**2)))
    outs = {}
    for engine in ("plan", "pallas"):
        out = fusion.fuse(
            prob, state, xq, "knn", k=k, engine=engine, plan=plan,
            compute_dtype="bf16",
        )
        assert out.dtype == jnp.float32, (engine, out.dtype)  # (c)
        outs[engine] = np.asarray(out)
        rel = np.sqrt(np.mean((outs[engine] - dense) ** 2)) / rms
        assert rel < 0.01, (engine, rel)  # anchors-only: ~0.1% observed
    # both engines round the same stored anchors -> tight cross-agreement
    np.testing.assert_allclose(outs["plan"], outs["pallas"], atol=2e-5)


def test_selection_exact_vs_optin_knob():
    """Production path: quantized answers deviate from f32 by eval-rounding
    only — orders of magnitude below one representer swap.  The opt-in
    selection-quantization knob on near-tie geometry CAN flip sets."""
    prob, state, pos, rng = _batched(seed=3)
    k = 3
    plan = make_serving_plan(prob, k=k)
    xq = rng.uniform(-1, 1, size=(257, 2)).astype(np.float32)
    f32 = np.asarray(
        fusion.fuse(prob, state, xq, "knn", k=k, engine="plan", plan=plan)
    )
    q = np.asarray(
        fusion.fuse(
            prob, state, xq, "knn", k=k, engine="plan", plan=plan,
            compute_dtype="bf16",
        )
    )
    # one selection flip replaces a representer in a k-mean: cost
    # ~E_s / k.  Eval-only rounding is ~1e-3 relative — far below it.
    energy = np.asarray(pruning.representer_energy(prob, state))
    swap_cost = float(np.median(energy[energy > 0])) / k
    assert np.abs(q - f32).max() < 0.05 * swap_cost

    # the measurement knob: bf16 coordinate rounding collapses near-ties.
    # Two candidates equidistant to within bf16 resolution around x ~ 1.
    sel_f32, _ = serving.knn_select_valid(
        plan, prob.topology.positions, xq, k, prob.alive
    )
    sel_b16, _ = serving.knn_select_valid(
        plan, prob.topology.positions, xq, k, prob.alive,
        compute_dtype="bfloat16",
    )
    # sets may or may not flip on this geometry — the knob must at least
    # run the quantized distances without changing shapes/ids validity
    assert sel_b16.shape == sel_f32.shape
    assert (np.asarray(sel_b16) <= prob.n).all()


def test_quant_zero_recompiles_across_taus_and_buckets():
    from repro.analysis import compile_ledger
    from repro.kernels import bucket_rows

    prob, state, pos, rng = _batched(seed=5)
    k = 3
    plan = make_serving_plan(prob, k=k)
    sizes = [5, 33, 100, 180]
    # warmup: one call per (engine, size) at one tau; tau is TRACED so a
    # single tau warms every tau
    for s in sizes:
        xq = rng.uniform(-1, 1, size=(s, 2)).astype(np.float32)
        keep = pruning.prune_mask(prob, state, energy_tau=0.0)
        for engine in ("plan", "pallas"):
            fusion.fuse(
                prob, state, xq, "knn", k=k, engine=engine, plan=plan,
                compute_dtype="bf16", prune=keep,
            ).block_until_ready()
    snap = compile_ledger.snapshot("quant")
    for i, s in enumerate(sizes):
        xq = rng.uniform(-1, 1, size=(s, 2)).astype(np.float32)
        keep = pruning.prune_mask(prob, state, energy_tau=0.003 * i)
        for engine in ("plan", "pallas"):
            fusion.fuse(
                prob, state, xq, "knn", k=k, engine=engine, plan=plan,
                compute_dtype="bf16", prune=keep,
            ).block_until_ready()
    # buckets=0: the warmup above already covered every query bucket
    snap.assert_within(buckets=0, context="tau sweep")

    # the Pallas KERNEL additionally buckets query sizes: fresh sizes in
    # already-warmed buckets lower zero new programs
    snap2 = compile_ledger.snapshot(("serving.knn_kernel",))
    for s in (7, 40, 101, 170):
        assert any(bucket_rows(s) == bucket_rows(w) for w in sizes), s
        xq = rng.uniform(-1, 1, size=(s, 2)).astype(np.float32)
        fusion.fuse(
            prob, state, xq, "knn", k=k, engine="pallas", plan=plan,
            compute_dtype="bf16", prune=keep,
        ).block_until_ready()
    snap2.assert_within(buckets=0, context="warm-bucket fresh sizes")


def test_bf16_anchors_keep_f64_output_subprocess():
    """Satellite of the f64-through-pallas fix: x64 problems served with
    bf16 anchor storage keep f64 outputs (accumulation dtype = coef
    dtype), and the quantization error stays at anchors-only scale."""
    code = r"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
import numpy as np, jax.numpy as jnp
from repro.core import (Kernel, build_topology, colored_sweep, fusion,
                        init_state, make_problem, make_serving_plan,
                        pruning, uniform_sensors)
n = 25
pos = uniform_sensors(n, seed=0)
topo = build_topology(pos, 0.8)
y = np.sin(np.pi * pos[:, 0])
prob = make_problem(topo, Kernel("rbf", gamma=1.0), y, dtype=jnp.float64)
state = colored_sweep(prob, init_state(prob), n_sweeps=20)
xq = np.linspace(-0.9, 0.9, 17)[:, None]
plan = make_serving_plan(prob, k=3)
dense = np.asarray(fusion.fuse(prob, state, xq, "knn", k=3))
# anchor rounding perturbs each representer by ~bf16 eps relative to its
# coefficient energy (large cancelling coefs on the ill-conditioned
# paper-lambda fit), so that is the scale the error lives on
e_max = float(np.max(np.asarray(pruning.representer_energy(prob, state))))
for engine in ("plan", "pallas"):
    exact = fusion.fuse(prob, state, xq, "knn", k=3, engine=engine,
                        plan=plan)
    assert exact.dtype == jnp.float64, (engine, exact.dtype)
    assert np.abs(np.asarray(exact) - dense).max() < 1e-10
    q = fusion.fuse(prob, state, xq, "knn", k=3, engine=engine, plan=plan,
                    compute_dtype="bf16")
    assert q.dtype == jnp.float64, (engine, q.dtype)
    err = np.abs(np.asarray(q) - dense).max()
    assert 0 < err < 0.01 * e_max, (engine, err, e_max)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_quant_argument_validation():
    prob, state, pos, rng = _batched(n=20, sweeps=3)
    xq = rng.uniform(-1, 1, size=(8, 2)).astype(np.float32)
    plan = make_serving_plan(prob, k=2)
    keep = pruning.prune_mask(prob, state, energy_tau=0.0)
    with pytest.raises(ValueError, match="compute_dtype"):
        fusion.fuse(prob, state, xq, "knn", k=2, engine="plan", plan=plan,
                    compute_dtype="not-a-dtype")
    with pytest.raises(ValueError, match="float dtype"):
        fusion.fuse(prob, state, xq, "knn", k=2, engine="plan", plan=plan,
                    compute_dtype="int32")
    for kw in ({"compute_dtype": "bf16"}, {"prune": keep}, {"block_q": 128}):
        with pytest.raises(ValueError, match="plan/pallas|pallas"):
            fusion.fuse(prob, state, xq, "knn", k=2, engine="dense", **kw)
    with pytest.raises(ValueError, match="pallas"):
        fusion.fuse(prob, state, xq, "knn", k=2, engine="plan", plan=plan,
                    block_q=128)
