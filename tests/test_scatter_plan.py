"""Scatter-plan engine guarantees (ISSUE-2 tentpole).

The colored engine's color-step update is a static permutation known at
make_problem time.  These tests pin the contract:

  * plan-gather == dense one-hot update BIT-FOR-BIT (same floats, not just
    close) on random geometric topologies, including a B > 1 problem whose
    per-field masks have diverged under streaming absorption;
  * the plan codes themselves are well-formed (every touched slot's source
    is its unique owner lane);
  * the fused Pallas color-step engine reaches the same fixed point;
  * the single-field sharded engine (plan-based (M*D,) transport) matches
    the colored engine on 8 host devices;
  * the lane-vectorized substitution solver is dtype-generic (f64 under
    JAX_ENABLE_X64, run in a subprocess because x64 is process-global).
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    init_state,
    make_batch_problem,
    make_problem,
    serial_sweep,
    streaming,
    uniform_sensors,
)

KERN = Kernel("rbf", gamma=1.0)
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(n=25, b=2, radius=0.6, seed=0, headroom=0, lam=0.1):
    pos = uniform_sensors(n, d=2, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    topo = build_topology(pos, radius)
    if headroom:
        topo = build_topology(
            pos, radius, d_max=int(np.asarray(topo.degrees).max()) + headroom
        )
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), lam))
    return prob, pos


def _assert_engines_bitwise_equal(prob, state, n_sweeps=3):
    a = colored_sweep(prob, state, n_sweeps=n_sweeps, engine="onehot")
    b = colored_sweep(prob, state, n_sweeps=n_sweeps, engine="plan")
    np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))
    np.testing.assert_array_equal(np.asarray(a.coef), np.asarray(b.coef))


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 1000), radius=st.sampled_from([0.4, 0.6, 0.9]))
def test_plan_equals_onehot_bitwise_random_topologies(seed, radius):
    """Acceptance: the static gather produces the SAME floats as the dense
    one-hot GEMM reference on random geometric graphs."""
    prob, _ = _problem(n=30, b=2, radius=radius, seed=seed)
    state = serial_sweep(prob, init_state(prob), n_sweeps=1)  # non-trivial z
    _assert_engines_bitwise_equal(prob, state)


def test_plan_equals_onehot_bitwise_streaming_diverged():
    """B > 1 with per-field masks diverged by absorption: the plans are
    shared across fields, yet the update stays exact for every field."""
    prob, pos = _problem(n=24, b=3, radius=0.7, seed=5, headroom=4)
    state = colored_sweep(prob, init_state(prob), n_sweeps=2)
    rng = np.random.default_rng(9)
    for _ in range(10):  # different sensors/fields -> diverged nbr_mask
        f = int(rng.integers(0, 3))
        s = int(rng.integers(0, prob.n))
        x = (pos[s] + 0.1 * rng.normal(size=2)).astype(np.float32)
        prob, state, _ = streaming.absorb(prob, state, f, s, x, float(rng.normal()))
    assert bool((~np.asarray(prob.nbr_mask[0]) & np.asarray(prob.nbr_mask[1])).any() or
                (np.asarray(prob.nbr_mask[0]) & ~np.asarray(prob.nbr_mask[1])).any())
    _assert_engines_bitwise_equal(prob, state)


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 1000))
def test_plan_codes_are_the_unique_owners(seed):
    """Host-side invariant: plan_z[c] maps slot j either to itself or to
    n_z + m*D + k with nbr_idx[members[c, m], k] == j — the one owner the
    distance-2 coloring guarantees — and every real member's every slot is
    covered exactly once."""
    prob, _ = _problem(n=28, b=1, radius=0.5, seed=seed)
    topo = prob.topology
    n_z, d_max = prob.n_z, topo.d_max
    plan_z = np.asarray(prob.plan_z)
    plan_coef = np.asarray(prob.plan_coef)
    members = np.asarray(topo.color_members)
    cmask = np.asarray(topo.color_mask)
    nbr_idx = np.asarray(prob.nbr_idx)
    for c in range(topo.n_colors):
        taken = plan_z[c] >= n_z
        flat = plan_z[c][taken] - n_z
        m, k = flat // d_max, flat % d_max
        assert (cmask[c][m]).all()  # sources are real members only
        np.testing.assert_array_equal(
            nbr_idx[members[c][m], k], np.nonzero(taken)[0]
        )
        # every real member's full neighborhood row is consumed
        assert taken.sum() == cmask[c].sum() * d_max
        # coef plan: exactly the color's members take, everyone else keeps
        rows = plan_coef[c] >= prob.n + 1
        np.testing.assert_array_equal(
            np.sort(members[c][cmask[c]]), np.nonzero(rows)[0]
        )
        assert plan_z[c][n_z - 1] == n_z - 1  # sentinel always keeps
        assert plan_coef[c][prob.n] == prob.n


def test_pallas_engine_same_fixed_point():
    """Acceptance: engine="pallas" (fused VMEM color step) lands on the same
    fixed point as plan/onehot within 1e-5 (f32) on a tier-1 topology."""
    prob, _ = _problem(n=30, b=2, radius=0.8, seed=0)
    st0 = init_state(prob)
    ref = colored_sweep(prob, st0, n_sweeps=30, engine="plan")
    pal = colored_sweep(prob, st0, n_sweeps=30, engine="pallas")
    np.testing.assert_allclose(np.asarray(ref.z), np.asarray(pal.z), atol=1e-5)
    # coefficients are a non-unique parameterization (see test_sn_train);
    # compare them loosely and the message fixed point tightly.
    np.testing.assert_allclose(
        np.asarray(ref.coef), np.asarray(pal.coef), atol=1e-3
    )


def test_pallas_engine_single_field_and_streaming():
    prob, pos = _problem(n=20, b=2, radius=0.7, seed=3, headroom=3)
    state = colored_sweep(prob, init_state(prob), n_sweeps=2, engine="pallas")
    rng = np.random.default_rng(1)
    for _ in range(5):
        s = int(rng.integers(0, prob.n))
        x = (pos[s] + 0.1 * rng.normal(size=2)).astype(np.float32)
        prob, state, _ = streaming.absorb(prob, state, 0, s, x, float(rng.normal()))
    a = colored_sweep(prob, state, n_sweeps=4, engine="plan")
    b = colored_sweep(prob, state, n_sweeps=4, engine="pallas")
    np.testing.assert_allclose(np.asarray(a.z), np.asarray(b.z), atol=2e-5)
    # single-field problems run the same kernel with B = 1
    prob1 = make_problem(
        prob.topology, KERN, np.asarray(prob.y[0]), jnp.full((prob.n,), 0.1)
    )
    s1 = colored_sweep(prob1, init_state(prob1), n_sweeps=5, engine="pallas")
    s2 = colored_sweep(prob1, init_state(prob1), n_sweeps=5, engine="plan")
    np.testing.assert_allclose(np.asarray(s1.z), np.asarray(s2.z), atol=1e-5)


def test_unknown_engine_rejected():
    import pytest
    import jax
    from repro import compat
    from repro.core import sharded_sweep

    prob, _ = _problem(n=10, b=1, radius=0.9)
    with pytest.raises(ValueError, match="engine"):
        colored_sweep(prob, init_state(prob), n_sweeps=1, engine="dense")
    # single-field sharded transport IS the plan: other engines are an error,
    # not a silent fallback
    pos = uniform_sensors(10, d=2, seed=0)
    topo = build_topology(pos, 0.9)
    prob1 = make_problem(topo, KERN, np.zeros(10), jnp.full((10,), 0.1))
    mesh = compat.make_mesh((len(jax.devices()),), ("sensors",))
    with pytest.raises(ValueError, match="engine"):
        sharded_sweep(prob1, init_state(prob1), mesh, engine="dense")
    with pytest.raises(NotImplementedError, match="plan transport"):
        sharded_sweep(prob1, init_state(prob1), mesh, engine="onehot")


def test_sharded_plan_transport_8_devices_subprocess():
    """Single-field sharded_sweep (psum of the color's (M*D,) touched values
    + local plan gather) == colored_sweep, on 8 host devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro import compat
pos = uniform_sensors(40, d=2, seed=0)
rng = np.random.default_rng(1)
y = np.sin(np.pi*pos[:,0]) + 0.5*rng.normal(size=40)
topo = build_topology(pos, 0.6)
prob = make_problem(topo, Kernel("rbf", gamma=1.0), y, lambdas=jnp.full((40,), 1e-2))
st0 = init_state(prob)
ref = colored_sweep(prob, st0, n_sweeps=9)
mesh = compat.make_mesh((8,), ("sensors",))
sh = sharded_sweep(prob, st0, mesh, axis="sensors", n_sweeps=9)
err_z = np.abs(np.asarray(ref.z) - np.asarray(sh.z)).max()
err_c = np.abs(np.asarray(ref.coef) - np.asarray(sh.coef)).max()
# the per-device solves run on m_local-wide lanes (different XLA fusion
# than the M_max-wide reference) — identical math, f32 rounding drift only
assert err_z <= 2e-4, err_z
# coefficients are a non-unique parameterization: f32 noise random-walks
# on null(K_s) components (update eigenvalue exactly 1, see test_sn_train)
assert err_c <= 2e-2, err_c
print("OK", err_z, err_c)
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=_REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_f64_solver_and_engines_subprocess():
    """ROADMAP open item: the lane-vectorized substitution solver and the
    color-step engines are dtype-generic.  Under x64 with the paper's own
    lambda = 0.01/|N_i|^2 the sweep stays finite (the documented f32 NaN)
    and plan == onehot stays bit-for-bit in f64; the Pallas kernel solves
    f64 systems to f64 accuracy."""
    code = r"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.core.sn_train import _tri_solve_spd
import jax.scipy.linalg as jsl

# substitution solver in f64: matches the exact solve to ~1e-12
rng = np.random.default_rng(0)
a = rng.normal(size=(5, 9, 9))
spd = a @ np.swapaxes(a, -1, -2) + 9 * np.eye(9)
chol = np.linalg.cholesky(spd)
rhs = rng.normal(size=(5, 9))
x = _tri_solve_spd(jnp.asarray(chol), jnp.asarray(rhs))
assert x.dtype == jnp.float64, x.dtype
ref = np.linalg.solve(spd, rhs[..., None])[..., 0]
assert np.abs(np.asarray(x) - ref).max() < 1e-12

pos = uniform_sensors(30, d=2, seed=0)
rng = np.random.default_rng(1)
ys = np.sin(np.pi*pos[None,:,0]) + 0.3*rng.normal(size=(2, 30))
topo = build_topology(pos, 0.6)
prob = make_batch_problem(topo, Kernel("rbf", gamma=1.0), ys, dtype=jnp.float64)  # paper lambdas
st = init_state(prob)
assert st.z.dtype == jnp.float64
a = colored_sweep(prob, st, n_sweeps=8, engine="onehot")
b = colored_sweep(prob, st, n_sweeps=8, engine="plan")
c = colored_sweep(prob, st, n_sweeps=8, engine="pallas")
assert np.isfinite(np.asarray(a.z)).all()
np.testing.assert_array_equal(np.asarray(a.z), np.asarray(b.z))
np.testing.assert_array_equal(np.asarray(a.coef), np.asarray(b.coef))
assert c.z.dtype == jnp.float64
np.testing.assert_allclose(np.asarray(b.z), np.asarray(c.z), atol=1e-10)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=_REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
