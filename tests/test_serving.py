"""Plan-based kNN-fusion serving engines (ISSUE-3 tentpole guarantees).

Covers:
  (a) plan/pallas kNN fusion == the dense oracle on random geometric
      topologies, k in {1, 3}, single-field and B > 1 (including
      streaming-diverged per-field anchors);
  (b) the plan's structural guarantees (every cell holds >= k valid
      candidates; ids in range);
  (c) ``streaming.absorb_many`` == repeated ``absorb`` EXACTLY (drop and
      evict policies, flags included);
  (d) the x64 dtype threading fix for the serving path (subprocess);
  (e) power-of-two query bucketing: a serving process with varied request
      sizes lowers O(log Q) Pallas programs, counted via the jit cache.
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    field_view,
    fusion,
    init_state,
    make_batch_problem,
    make_problem,
    make_serving_plan,
    serving,
    streaming,
    uniform_sensors,
)

KERN = Kernel("rbf", gamma=1.0)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _single(n=35, radius=0.7, seed=0, d=1, sweeps=15):
    pos = uniform_sensors(n, d=d, seed=seed)
    topo = build_topology(pos, radius)
    rng = np.random.default_rng(seed + 1)
    y = np.sin(np.pi * pos[:, 0]) + 0.2 * rng.normal(size=n)
    prob = make_problem(topo, KERN, y, jnp.full((n,), 0.1))
    state = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
    return prob, state, pos, rng


def _batched(n=30, b=3, radius=0.7, seed=0, d=1, headroom=0, sweeps=10):
    pos = uniform_sensors(n, d=d, seed=seed)
    topo = build_topology(pos, radius)
    if headroom:
        d_max = int(np.asarray(topo.degrees).max()) + headroom
        topo = build_topology(pos, radius, d_max=d_max)
    rng = np.random.default_rng(seed + 1)
    freq = rng.uniform(0.5, 2.0, size=(b, 1))
    ys = np.sin(np.pi * freq * pos[None, :, 0]) + 0.3 * rng.normal(size=(b, n))
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), 0.1))
    state = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
    return prob, state, pos, rng


# ---------------------------------------------------------------------------
# (a) engine agreement: dense == plan == pallas
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 500), k=st.sampled_from([1, 3]))
def test_plan_and_pallas_match_dense_single_field(seed, k):
    """Acceptance: the three engines agree within 1e-5 on random geometric
    topologies (queries inside the plan domain)."""
    prob, state, pos, rng = _single(seed=seed)
    lo, hi = pos[:, 0].min(), pos[:, 0].max()
    xq = rng.uniform(lo, hi, size=(61, 1)).astype(np.float32)
    dense = np.asarray(fusion.fuse(prob, state, xq, "knn", k=k))
    plan = make_serving_plan(prob, k=k)
    for engine in ("plan", "pallas"):
        out = fusion.fuse(prob, state, xq, "knn", k=k, engine=engine, plan=plan)
        assert out.shape == dense.shape
        np.testing.assert_allclose(np.asarray(out), dense, atol=1e-5, err_msg=engine)


def test_plan_and_pallas_match_dense_2d():
    prob, state, pos, rng = _single(n=60, radius=0.5, seed=3, d=2)
    xq = rng.uniform(pos.min(), pos.max(), size=(47, 2)).astype(np.float32)
    plan = make_serving_plan(prob, k=3)
    dense = np.asarray(fusion.fuse(prob, state, xq, "knn", k=3))
    for engine in ("plan", "pallas"):
        out = fusion.fuse(prob, state, xq, "knn", k=3, engine=engine, plan=plan)
        np.testing.assert_allclose(np.asarray(out), dense, atol=1e-5, err_msg=engine)


def test_nn_rule_routes_through_plan_engines():
    prob, state, pos, rng = _single(seed=9)
    xq = rng.uniform(-0.8, 0.8, size=(33, 1)).astype(np.float32)
    dense = np.asarray(fusion.fuse(prob, state, xq, "nn"))
    for engine in ("plan", "pallas"):
        out = fusion.fuse(prob, state, xq, "nn", engine=engine)
        np.testing.assert_allclose(np.asarray(out), dense, atol=1e-5, err_msg=engine)


def test_batched_with_streaming_diverged_anchors():
    """B > 1 where streaming absorption made nbr_pos/coef diverge per field:
    the shared top-k selection + per-field evaluation still matches dense."""
    prob, state, pos, rng = _batched(b=3, headroom=5)
    for _ in range(12):
        f = int(rng.integers(0, 3))
        s = int(rng.integers(0, prob.n))
        x = (pos[s] + 0.1 * rng.normal(size=pos.shape[1])).astype(np.float32)
        prob, state, _ = streaming.absorb(prob, state, f, s, x, float(rng.normal()))
    state = colored_sweep(prob, state, n_sweeps=4)
    xq = rng.uniform(-0.9, 0.9, size=(41, 1)).astype(np.float32)
    dense_b = np.asarray(fusion.fuse(prob, state, xq, "knn", k=3))
    assert dense_b.shape == (3, 41)
    # the batched dense path itself equals the per-field single-field oracle
    for b in range(3):
        pv, sv = field_view(prob, state, b)
        np.testing.assert_allclose(
            dense_b[b], np.asarray(fusion.fuse(pv, sv, xq, "knn", k=3)),
            atol=1e-6,
        )
    plan = make_serving_plan(prob, k=3)
    for engine in ("plan", "pallas"):
        out = fusion.fuse(prob, state, xq, "knn", k=3, engine=engine, plan=plan)
        np.testing.assert_allclose(np.asarray(out), dense_b, atol=1e-5, err_msg=engine)


def test_other_rules_reject_plan_engines():
    prob, state, _, rng = _single()
    xq = np.zeros((4, 1), np.float32)
    with pytest.raises(ValueError, match="kNN rules"):
        fusion.fuse(prob, state, xq, "conn", engine="plan")
    with pytest.raises(ValueError, match="k="):
        plan = make_serving_plan(prob, k=1)
        fusion.fuse(prob, state, xq, "knn", k=3, engine="plan", plan=plan)


# ---------------------------------------------------------------------------
# (b) plan structure
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 500), k=st.sampled_from([1, 3, 5]))
def test_plan_cells_hold_enough_valid_candidates(seed, k):
    prob, _, _, _ = _single(n=45, seed=seed, d=2, radius=0.6, sweeps=1)
    plan = make_serving_plan(prob, k=k)
    cells = np.asarray(plan.cells)
    mask = np.asarray(plan.cell_mask)
    assert (mask.sum(axis=1) >= k).all()  # exact top-k always has k sources
    assert (cells[mask] < prob.n).all() and (cells[mask] >= 0).all()
    assert (cells[~mask] == prob.n).all()  # padding points at the sentinel
    assert plan.n_cells == int(np.prod(plan.grid_shape))


def test_knn_select_matches_dense_argsort():
    prob, _, pos, rng = _single(n=50, seed=4, d=2, radius=0.6, sweeps=1)
    plan = make_serving_plan(prob, k=3)
    xq = rng.uniform(pos.min(), pos.max(), size=(29, 2)).astype(np.float32)
    sel = np.asarray(serving.knn_select(plan, prob.topology.positions, jnp.asarray(xq), 3))
    d2 = ((xq[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    ref = np.argsort(d2, axis=1, kind="stable")[:, :3]
    np.testing.assert_array_equal(sel, ref)


# ---------------------------------------------------------------------------
# (c) absorb_many == repeated absorb, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("on_full", ["drop", "evict"])
def test_absorb_many_equals_repeated_absorb(on_full):
    prob0, state0, pos, _ = _batched(b=2, headroom=2, sweeps=3)
    rng = np.random.default_rng(17)
    a = 14
    fields = rng.integers(0, 2, size=a)
    sensors = rng.integers(0, prob0.n, size=a)
    # overflow the max-degree sensor (streaming capacity exactly 2) of
    # field 0 so the on_full policy actually fires mid-scan
    s_full = int(np.argmax(np.asarray(prob0.topology.degrees)))
    fields[:4] = 0
    sensors[:4] = s_full
    xs = (pos[sensors] + 0.05 * rng.normal(size=(a, pos.shape[1]))).astype(np.float32)
    ys = rng.normal(size=a).astype(np.float32)

    p1, s1 = prob0, state0
    flags_seq = []
    for i in range(a):
        p1, s1, ok = streaming.absorb(
            p1, s1, int(fields[i]), int(sensors[i]), xs[i], float(ys[i]),
            on_full=on_full,
        )
        flags_seq.append(bool(ok))
    p2, s2, receipt = streaming.absorb_many(
        prob0, state0, fields, sensors, xs, ys, on_full=on_full
    )
    assert receipt.absorbed.shape == (a,) and receipt.evicted.shape == (a,)
    assert [bool(f) for f in np.asarray(receipt.absorbed)] == flags_seq
    evicted = np.asarray(receipt.evicted)
    if on_full == "drop":
        assert not all(flags_seq)  # capacity 2/sensor: some drops occurred
        assert not evicted.any()  # the drop policy never evicts
    else:
        # the sliding window absorbed everything; over-capacity arrivals
        # are flagged as evictions (observable capacity pressure)
        assert all(flags_seq)
        assert evicted.any()
        assert (~evicted | np.asarray(receipt.absorbed)).all()
    for name in ("nbr_pos", "nbr_mask", "gram", "chol", "stream_pos"):
        np.testing.assert_array_equal(
            np.asarray(getattr(p1, name)), np.asarray(getattr(p2, name)),
            err_msg=name,
        )
    np.testing.assert_array_equal(np.asarray(s1.z), np.asarray(s2.z))
    np.testing.assert_array_equal(np.asarray(s1.coef), np.asarray(s2.coef))


def test_absorb_many_validates_like_absorb():
    prob, state, _, _ = _batched(b=2, headroom=2, sweeps=1)
    with pytest.raises(ValueError, match="xs must be"):
        streaming.absorb_many(
            prob, state, np.zeros(3, np.int32), np.zeros(3, np.int32),
            np.zeros((2, 1), np.float32), np.zeros(3, np.float32),
        )
    with pytest.raises(ValueError, match="on_full"):
        streaming.absorb_many(
            prob, state, np.zeros(1, np.int32), np.zeros(1, np.int32),
            np.zeros((1, 1), np.float32), np.zeros(1, np.float32),
            on_full="explode",
        )


# ---------------------------------------------------------------------------
# (d) dtype threading through the serving path (x64 subprocess)
# ---------------------------------------------------------------------------


def test_serving_path_preserves_f64_subprocess():
    """The fusion/serving path must not silently truncate x64 problems (the
    paper-lambda configuration) to f32."""
    code = r"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
import numpy as np, jax.numpy as jnp
from repro.core import (Kernel, build_topology, colored_sweep, fusion,
                        init_state, make_problem, make_serving_plan,
                        uniform_sensors)
n = 25
pos = uniform_sensors(n, seed=0)
topo = build_topology(pos, 0.8)
y = np.sin(np.pi * pos[:, 0])
prob = make_problem(topo, Kernel("rbf", gamma=1.0), y, dtype=jnp.float64)
state = colored_sweep(prob, init_state(prob), n_sweeps=20)
xq = np.linspace(-0.9, 0.9, 17)[:, None]
preds = fusion.evaluate_sensors(prob, state, xq)
assert preds.dtype == jnp.float64, preds.dtype
for rule in ("nn", "conn", "avg", "single"):
    out = fusion.fuse(prob, state, xq, rule)
    assert out.dtype == jnp.float64, (rule, out.dtype)
plan = make_serving_plan(prob, k=3)
dense = fusion.fuse(prob, state, xq, "knn", k=3)
assert dense.dtype == jnp.float64
for engine in ("plan", "pallas"):
    out = fusion.fuse(prob, state, xq, "knn", k=3, engine=engine, plan=plan)
    assert out.dtype == jnp.float64, (engine, out.dtype)
    assert np.abs(np.asarray(out) - np.asarray(dense)).max() < 1e-10
anchors, coefs = fusion.global_coefficients(prob, state, rule="conn")
assert coefs.dtype == jnp.float64 and anchors.dtype == jnp.float64
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# (e) recompile bucketing: O(log Q) lowered programs for varied request sizes
# ---------------------------------------------------------------------------


def test_kernel_matvec_buckets_query_sizes():
    from repro.analysis import compile_ledger
    from repro.kernels import bucket_rows, kernel_matvec
    from repro.kernels.ref import kernel_matvec_ref

    rng = np.random.default_rng(0)
    an = rng.normal(size=(40, 2)).astype(np.float32)
    cf = rng.normal(size=(40,)).astype(np.float32)
    sizes = list(range(1, 230, 11))
    buckets = {bucket_rows(q) for q in sizes}
    snap = compile_ledger.snapshot(("serving.matvec",))
    for q in sizes:
        xq = rng.normal(size=(q, 2)).astype(np.float32)
        out = kernel_matvec(xq, an, cf, gamma=1.0)
        assert out.shape == (q,)
        ref = kernel_matvec_ref(jnp.asarray(xq), jnp.asarray(an), jnp.asarray(cf), 1.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
    snap.assert_within(buckets=len(buckets), context="matvec query sizes")


def test_knn_fuse_buckets_query_sizes():
    from repro.analysis import compile_ledger
    from repro.kernels import bucket_rows

    prob, state, pos, rng = _single(n=30, seed=6)
    plan = make_serving_plan(prob, k=1)
    dense = lambda xq: np.asarray(fusion.fuse(prob, state, xq, "nn"))
    snap = compile_ledger.snapshot(("serving.knn_kernel",))
    sizes = [3, 9, 17, 33, 65, 100]
    for q in sizes:
        xq = rng.uniform(-0.9, 0.9, size=(q, 1)).astype(np.float32)
        out = fusion.fuse(prob, state, xq, "nn", engine="pallas", plan=plan)
        np.testing.assert_allclose(np.asarray(out), dense(xq), atol=1e-5)
    snap.assert_within(
        buckets=len({bucket_rows(q) for q in sizes}),
        context="knn_fuse query sizes",
    )


# ---------------------------------------------------------------------------
# ISSUE-5 satellite: dense / plan / pallas agree at EVERY liveness fraction
# (all-dead, one-alive, exactly-k-alive, fully-alive) — when fewer than k
# live sensors exist, every engine averages the live selections only.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("live_count", [0, 1, 3, None])
def test_knn_engines_agree_at_liveness_fractions(live_count):
    from repro.core import remove_sensor

    n, b, k = 8, 2, 3
    pos = np.linspace(-0.8, 0.8, n)[:, None].astype(np.float32)
    topo = build_topology(pos, 2.0, d_max=n + 2, n_max=n + 1)
    rng = np.random.default_rng(0)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.1 * rng.normal(size=(b, n))
    prob = make_batch_problem(topo, KERN, ys, jnp.full((n,), 0.2))
    state = colored_sweep(prob, init_state(prob), n_sweeps=15)
    # plan built at full liveness, then repaired through the removals
    plan = make_serving_plan(prob, k=k, spare=2, slack=n)
    if live_count is not None:
        for s in range(live_count, n):
            prob, state, ok = remove_sensor(prob, state, s)
            assert bool(ok)
            plan = serving.plan_remove_sensor(plan, s)
    xq = rng.uniform(-0.9, 0.9, size=(13, 1)).astype(np.float32)
    dense = np.asarray(fusion.fuse(prob, state, xq, "knn", k=k))
    out_plan = np.asarray(
        fusion.fuse(prob, state, xq, "knn", k=k, engine="plan", plan=plan)
    )
    out_pal = np.asarray(
        fusion.fuse(prob, state, xq, "knn", k=k, engine="pallas", plan=plan)
    )
    np.testing.assert_allclose(out_plan, dense, atol=1e-5, err_msg="plan")
    np.testing.assert_allclose(out_pal, dense, atol=1e-5, err_msg="pallas")
    if live_count == 0:
        # all dead: the kNN average is exactly zero in every engine
        assert np.abs(dense).max() == 0.0
        assert np.abs(out_plan).max() == 0.0
        assert np.abs(out_pal).max() == 0.0
    elif live_count is not None and live_count < k:
        # k exceeds the live count: predictions average the live sensors
        # only (no zero-dilution), so they are NOT scaled by live/k
        assert np.abs(dense).max() > 0.0
