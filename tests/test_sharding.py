"""Sharding-rule unit tests (PartitionSpec logic; no multi-device needed)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, input_specs
from repro.models import init_params
from repro.models import model as M
from repro.sharding import batch_pspecs, cache_pspecs, param_pspecs


class FakeMesh:
    """Just enough of a Mesh for the divisibility logic."""

    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _abstract(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def test_dense_param_specs_internlm():
    cfg = get_config("internlm2-1.8b")
    specs = param_pspecs(cfg, _abstract(cfg), MESH)
    # embed (V, d): vocab over model
    assert specs["embed"] == P("model", None)
    blk = specs["blocks"]["layer0"]
    # attn wq (1, d, H*hd): stacked leading None, heads over model
    assert blk["attn"]["wq"]["w"] == P(None, None, "model")
    assert blk["attn"]["wo"]["w"] == P(None, "model", None)
    assert blk["mlp"]["wg"]["w"] == P(None, None, "model")
    assert blk["mlp"]["wd"]["w"] == P(None, "model", None)
    assert blk["norm1"]["scale"] == P(None, None)


def test_divisibility_fallback_smollm():
    """smollm: 9 heads, but the flattened head projection 9*64=576 divides
    model=16, so the projection weight CAN shard (GSPMD reshards around the
    per-head reshape); vocab 49152 shards too."""
    cfg = get_config("smollm-135m")
    specs = param_pspecs(cfg, _abstract(cfg), MESH)
    blk = specs["blocks"]["layer0"]
    assert blk["attn"]["wq"]["w"] == P(None, None, "model")  # 576 % 16 == 0
    assert specs["embed"] == P("model", None)


def test_fallback_on_truly_indivisible_dims():
    import dataclasses
    cfg = get_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=92545)  # prime-ish
    abstract = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, abstract, MESH)
    assert specs["embed"] == P(None, None)


def test_expert_parallel_specs():
    cfg = get_config("qwen3-moe-30b-a3b")
    specs = param_pspecs(cfg, _abstract(cfg), MESH)
    moe = specs["blocks"]["layer0"]["moe"]
    # (1, E, d, f): experts over model, d over data (fsdp=True)
    assert moe["wu"] == P(None, "model", "data", None)
    assert moe["wd"] == P(None, "model", None, "data")
    assert moe["router"] == P(None, None, None)


def test_fsdp_shards_complementary_dim():
    cfg = get_config("nemotron-4-15b")  # fsdp=True
    specs = param_pspecs(cfg, _abstract(cfg), MESH)
    blk = specs["blocks"]["layer0"]
    assert blk["mlp"]["wu"]["w"] == P(None, "data", "model")
    assert blk["attn"]["wo"]["w"] == P(None, "model", "data")


def test_batch_specs_multipod():
    cfg = get_config("internlm2-1.8b")
    batch = input_specs(cfg, "train_4k")
    specs = batch_pspecs(cfg, batch, MESH3)
    assert specs["tokens"] == P(("pod", "data"), None)


def test_batch_fallback_batch1():
    cfg = get_config("mamba2-370m")
    spec = input_specs(cfg, "long_500k")
    tok = batch_pspecs(cfg, {"t": spec["token"]}, MESH)["t"]
    assert tok == P(None, None)  # B=1 cannot shard


def test_cache_specs_ssm_and_attn():
    cfg = get_config("jamba-1.5-large-398b")
    spec = input_specs(cfg, "decode_32k")
    cspecs = cache_pspecs(cfg, spec["cache"], MESH)
    # mamba layer state (nb, B, H=256, P, N): heads over model
    assert cspecs["layer0"]["state"] == P(None, "data", "model", None, None)
    # attention layer at pattern index 3: kv heads 8 don't divide 16 ->
    # fall back to sharding the cache LENGTH dim (32768 % 16 == 0), which
    # keeps decode attention local up to tiny softmax-stat psums (§Perf H1)
    assert cspecs["layer3"]["k"] == P(None, "data", "model", None, None)
