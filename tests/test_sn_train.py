"""SN-Train behaviour tests against the paper's lemmas and claims."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    fit_krr,
    init_state,
    local_only,
    make_problem,
    serial_sweep,
    uniform_sensors,
    weighted_norm_sq,
)
from repro.core import fusion
from repro.core.centralized import predict


def _setup(n=30, radius=0.8, seed=0, kernel=Kernel("rbf", gamma=1.0)):
    pos = uniform_sensors(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    y = np.sin(np.pi * pos[:, 0]) + 0.5 * rng.normal(size=n)
    topo = build_topology(pos, radius)
    return topo, kernel, y


def test_serial_and_colored_share_fixed_point():
    """The two engines implement the same SOP (different admissible orderings)
    and must converge to the same solution of the relaxation.

    Uses a well-conditioned lambda: with the paper's tiny kappa/|N|^2 the
    subspace angles are O(lambda) and convergence needs ~1e5 sweeps (the
    weighted norm is still monotone — tested separately below)."""
    topo, kern, y = _setup()
    # lambda=0.1 keeps cond(K_s + lambda I) ~ 3e2 so the f32 engines track
    # the exact SOP to high precision (tiny paper-lambdas are exercised by
    # the Fejer-monotonicity property test instead).
    lams = jnp.full((topo.n,), 0.1)
    prob = make_problem(topo, kern, y, lambdas=lams)
    st0 = init_state(prob)
    s = serial_sweep(prob, st0, n_sweeps=600)
    c = colored_sweep(prob, st0, n_sweeps=600)
    # tolerance covers the slow O(lambda) tail + f32 solve noise
    np.testing.assert_allclose(np.asarray(s.z), np.asarray(c.z), atol=5e-3)
    # Coefficients are a NON-unique parameterization when K_s is singular
    # (null-space components represent the zero function: c^T K c = 0 =>
    # f == 0 in H_K), so the engines are compared in function space.
    # Near-null coef components have update eigenvalue exactly 1
    # (c <- (K+lI)^{-1} l c == c on null(K)), so f32 noise random-walks
    # there and evaluates off-grid at ~sqrt(eig)*||c|| ~ 0.05 — hence the
    # loose functional tolerance; z (above) is the tight invariant.
    xq = np.linspace(-1, 1, 60)[:, None].astype(np.float32)
    fs = np.asarray(fusion.evaluate_sensors(prob, s, xq))
    fc = np.asarray(fusion.evaluate_sensors(prob, c, xq))
    np.testing.assert_allclose(fs, fc, atol=0.15)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 1000))
def test_weighted_norm_fejer_monotone_paper_lambdas(seed):
    """Lemma 2.1 in the product space, with the paper's own lambda_i =
    kappa/|N_i|^2: ||z||^2 + sum_i lambda_i ||f_i||^2 never increases,
    even on instances whose transients look wild in z-space."""
    topo, kern, y = _setup(seed=seed)
    prob = make_problem(topo, kern, y)  # paper default lambdas
    state = init_state(prob)
    prev = float(weighted_norm_sq(prob, state))
    for _ in range(6):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = float(weighted_norm_sq(prob, state))
        # 6% slack: the local solves run at cond(K_s+lambda I) ~ 1e5 in f32
        # (worse when sensors nearly coincide), so the computed projection is
        # accurate to ~cond * eps_f32; a 0..1000 seed scan of the engine
        # peaks at +3.1% (the batched LAPACK path of the seed repo peaked at
        # +32% on the same scan — the substitution solver is tighter).
        assert cur <= prev * 1.06 + 1e-5, (cur, prev)
        prev = cur


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 1000))
def test_lemma_3_1_fully_connected_equals_centralized(seed):
    """Complete graph + sum(lambda_i) = lambda  ==>  f_s == centralized f."""
    n = 20
    pos = uniform_sensors(n, seed=seed)
    rng = np.random.default_rng(seed)
    y = 2.0 * pos[:, 0] + 0.3 * rng.normal(size=n)
    kern = Kernel("rbf", gamma=1.0)
    topo = build_topology(pos, radius=10.0)  # complete
    lam = 0.5
    prob = make_problem(topo, kern, y, lambdas=jnp.full((n,), lam / n))
    state = colored_sweep(prob, init_state(prob), n_sweeps=600)
    model = fit_krr(pos, y, kern, lam=lam)
    xq = np.linspace(-1, 1, 50)[:, None].astype(np.float32)
    dist = fusion.fuse(prob, state, xq, "single", sensor=0)
    cent = predict(model, xq)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(cent), atol=5e-2)


def test_lemma_3_3_estimate_lies_in_neighborhood_span():
    """Padded coefficients outside N_s must stay exactly zero."""
    topo, kern, y = _setup(radius=0.3)
    prob = make_problem(topo, kern, y)
    state = colored_sweep(prob, init_state(prob), n_sweeps=20)
    mask = np.asarray(prob.nbr_mask)
    coef = np.asarray(state.coef)
    assert (coef[~mask] == 0).all()


def test_monotone_message_convergence():
    """Messages z converge (Cauchy-ish) as T grows — Lemma 3.2 in practice."""
    topo, kern, y = _setup()
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), 1e-2))
    st0 = init_state(prob)
    s10 = colored_sweep(prob, st0, n_sweeps=10)
    s200 = colored_sweep(prob, st0, n_sweeps=200)
    s400 = colored_sweep(prob, s200, n_sweeps=200)
    d_late = float(jnp.linalg.norm(s400.z - s200.z))
    d_early = float(jnp.linalg.norm(s200.z - s10.z))
    # linear convergence: each 200-sweep window contracts the tail
    assert d_late < 0.5 * max(d_early, 1e-6) + 1e-5


def test_sn_train_beats_local_only():
    """Sec 4.3: message passing improves single-sensor global estimates."""
    topo, kern, y = _setup(n=40, radius=0.8, seed=3)
    prob = make_problem(topo, kern, y)
    trained = colored_sweep(prob, init_state(prob), n_sweeps=100)
    local = local_only(prob)
    xq = np.linspace(-1, 1, 200)[:, None].astype(np.float32)
    target = np.sin(np.pi * xq[:, 0])
    mse_t = float(jnp.mean((fusion.fuse(prob, trained, xq, "single") - target) ** 2))
    mse_l = float(jnp.mean((fusion.fuse(prob, local, xq, "single") - target) ** 2))
    assert mse_t < mse_l


def test_nn_fusion_competitive_with_centralized():
    """Sec 4.2: nearest-neighbor fusion ~ centralized estimator."""
    topo, kern, y = _setup(n=50, radius=0.8, seed=5)
    lam_i = 1e-3
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), lam_i))
    state = colored_sweep(prob, init_state(prob), n_sweeps=100)
    xq = np.linspace(-1, 1, 300)[:, None].astype(np.float32)
    target = np.sin(np.pi * xq[:, 0])
    mse_nn = float(jnp.mean((fusion.fuse(prob, state, xq, "nn") - target) ** 2))
    model = fit_krr(np.asarray(topo.positions), y, kern, lam=50 * lam_i)
    mse_c = float(jnp.mean((predict(model, xq) - target) ** 2))
    assert mse_nn < 3.0 * mse_c + 0.05


def test_fusion_rules_shapes_and_special_cases():
    topo, kern, y = _setup()
    prob = make_problem(topo, kern, y)
    state = colored_sweep(prob, init_state(prob), n_sweeps=5)
    xq = np.linspace(-1, 1, 17)[:, None].astype(np.float32)
    preds = fusion.evaluate_sensors(prob, state, xq)
    assert preds.shape == (topo.n, 17)
    # knn with k = n equals the plain average
    avg = fusion.network_average(preds)
    knn_all = fusion.knn_fusion(preds, topo.positions, xq, k=topo.n)
    np.testing.assert_allclose(np.asarray(avg), np.asarray(knn_all), rtol=1e-5)
    # connectivity-averaged uses degree weights
    conn = fusion.connectivity_averaged(preds, topo.degrees)
    assert conn.shape == (17,)


def test_sharded_sweep_matches_colored_subprocess():
    """Sharded engine == colored engine (bitwise-ish), on 4 fake devices."""
    import subprocess, sys, os

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
pos = uniform_sensors(30, seed=0)
rng = np.random.default_rng(1)
y = np.sin(np.pi*pos[:,0]) + 0.5*rng.normal(size=30)
topo = build_topology(pos, 0.8)
prob = make_problem(topo, Kernel("rbf", gamma=1.0), y, lambdas=jnp.full((30,), 1e-2))
st0 = init_state(prob)
ref = colored_sweep(prob, st0, n_sweeps=7)
from repro import compat
mesh = compat.make_mesh((4,), ("sensors",))
sh = sharded_sweep(prob, st0, mesh, axis="sensors", n_sweeps=7)
assert np.allclose(np.asarray(ref.z), np.asarray(sh.z), atol=1e-3), np.abs(np.asarray(ref.z)-np.asarray(sh.z)).max()
assert np.allclose(np.asarray(ref.coef), np.asarray(sh.coef), atol=2e-2)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# Paper Sec. 3.3 optional features: random orderings + robustness
# ---------------------------------------------------------------------------

import jax

from repro.core import random_sweep, robust_sweep


def test_random_ordering_same_fixed_point():
    """ALOHA-style random sweeps converge to the serial fixed point (z)."""
    topo, kern, y = _setup()
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), 0.1))
    st0 = init_state(prob)
    s = serial_sweep(prob, st0, n_sweeps=400)
    r = random_sweep(prob, st0, jax.random.PRNGKey(0), n_sweeps=400)
    np.testing.assert_allclose(np.asarray(s.z), np.asarray(r.z), atol=5e-3)


def test_random_ordering_fejer_monotone():
    topo, kern, y = _setup(seed=4)
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), 1e-2))
    state = init_state(prob)
    prev = float(weighted_norm_sq(prob, state))
    for t in range(5):
        state = random_sweep(prob, state, jax.random.PRNGKey(t), n_sweeps=1)
        cur = float(weighted_norm_sq(prob, state))
        assert cur <= prev * 1.03 + 1e-5
        prev = cur


def test_robust_sweep_all_alive_equals_serial():
    topo, kern, y = _setup()
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), 0.1))
    st0 = init_state(prob)
    t = 20
    alive = jnp.ones((t, topo.n, topo.d_max), bool)
    s = serial_sweep(prob, st0, n_sweeps=t)
    r = robust_sweep(prob, st0, alive, n_sweeps=t)
    np.testing.assert_allclose(np.asarray(s.z), np.asarray(r.z), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s.coef), np.asarray(r.coef), atol=1e-2)


def test_robust_sweep_converges_after_failures_heal():
    """Paper Sec. 3.3 'Robustness': the iteration converges to the solution
    implied by the neighborhood occurring infinitely often.  We drop 20% of
    links for the first 60 sweeps, then heal the network for 300 sweeps: the
    messages must land on the full-topology fixed point, and every iterate
    stays finite ('progress is made at each iteration')."""
    topo, kern, y = _setup(n=40, radius=0.8, seed=3)
    prob = make_problem(topo, kern, y, lambdas=jnp.full((40,), 0.1))
    st0 = init_state(prob)
    t_fail, t_heal = 60, 300
    key = jax.random.PRNGKey(7)
    drop = jax.random.bernoulli(key, 0.8, (t_fail, topo.n, topo.d_max))
    # self link always alive (a sensor can talk to itself)
    self_mask = np.zeros((topo.n, topo.d_max), bool)
    idx = np.asarray(prob.nbr_idx[: topo.n])
    for i in range(topo.n):
        self_mask[i] = idx[i] == i
    alive_fail = jnp.asarray(np.asarray(drop) | self_mask[None])
    # 'progress at each iteration': the degraded sets C_i^t (fewer
    # constraints) CONTAIN C_i, so projections onto them still Fejér-
    # decrease the weighted norm (0 lies in every set).
    state = st0
    prev = float(weighted_norm_sq(prob, state))
    for t in range(0, t_fail, 10):
        state = robust_sweep(prob, state, alive_fail[t : t + 10], n_sweeps=10)
        cur = float(weighted_norm_sq(prob, state))
        assert cur <= prev * 1.03 + 1e-5
        prev = cur
    assert bool(jnp.isfinite(state.z).all()) and bool(jnp.isfinite(state.coef).all())

    # After healing, the iterates land in the ORIGINAL intersection C.
    # Note: SOP converges to the projection of its CURRENT point, so the
    # post-failure solution is a (legitimately) different point of C than
    # the canonical-init one — the paper's 'solution implied by the
    # neighborhood occurring infinitely often'.  Feasibility == a further
    # full sweep is a no-op.
    alive_heal = jnp.ones((t_heal, topo.n, topo.d_max), bool)
    final = robust_sweep(prob, state, alive_heal, n_sweeps=t_heal)
    again = serial_sweep(prob, final, n_sweeps=1)
    np.testing.assert_allclose(np.asarray(again.z), np.asarray(final.z), atol=2e-3)


# ---------------------------------------------------------------------------
# Paper Sec. 5.2 extension: weighted (heteroscedastic) losses
# ---------------------------------------------------------------------------

from repro.core import weighted_norm_sq_hetero, weighted_sweep


def test_weighted_sweep_unit_weights_equals_serial():
    topo, kern, y = _setup()
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), 0.1))
    st0 = init_state(prob)
    a = serial_sweep(prob, st0, n_sweeps=50)
    b = weighted_sweep(prob, st0, jnp.ones((topo.n,)), n_sweeps=50)
    np.testing.assert_allclose(np.asarray(a.z), np.asarray(b.z), atol=1e-4)


def test_weighted_sweep_fejer_monotone_in_reweighted_norm():
    topo, kern, y = _setup(seed=2)
    prob = make_problem(topo, kern, y, lambdas=jnp.full((topo.n,), 1e-2))
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.uniform(0.2, 5.0, topo.n).astype(np.float32))
    state = init_state(prob)
    prev = float(weighted_norm_sq_hetero(prob, state, w))
    for _ in range(6):
        state = weighted_sweep(prob, state, w, n_sweeps=1)
        cur = float(weighted_norm_sq_hetero(prob, state, w))
        assert cur <= prev * 1.03 + 1e-5, (cur, prev)
        prev = cur


def test_weighted_sweep_high_confidence_fits_tighter():
    """Sensors with large w_j keep z_j closer to their own measurement."""
    topo, kern, y = _setup(n=30, radius=0.8, seed=6)
    prob = make_problem(topo, kern, y, lambdas=jnp.full((30,), 0.1))
    st0 = init_state(prob)
    w_hi = jnp.ones((30,)).at[5].set(100.0)
    w_lo = jnp.ones((30,)).at[5].set(0.01)
    hi = weighted_sweep(prob, st0, w_hi, n_sweeps=200)
    lo = weighted_sweep(prob, st0, w_lo, n_sweeps=200)
    res_hi = abs(float(hi.z[5]) - float(prob.y[5]))
    res_lo = abs(float(lo.z[5]) - float(prob.y[5]))
    assert res_hi < res_lo


# ---------------------------------------------------------------------------
# ISSUE-5 satellite: the single-field extensions thread the alive mask
# (ROADMAP follow-up (c)) — pinned to the masked serial engine.
# ---------------------------------------------------------------------------


def _partially_alive_single_field(n=20, radius=0.6, seed=3, dead=(4, 11)):
    """A single-field view of a lifecycle problem with removed sensors."""
    from repro.core import (
        field_view, make_batch_problem, remove_sensor, uniform_sensors,
    )
    from repro.core.topology import build_topology as bt

    pos = uniform_sensors(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    y = np.sin(np.pi * pos[:, 0]) + 0.2 * rng.normal(size=n)
    topo = bt(pos, radius, n_max=n + 2)
    kern = Kernel("rbf", gamma=1.0)
    prob = make_batch_problem(
        topo, kern, y[None, :], jnp.full((n,), 0.1)
    )
    state = serial_sweep(prob, init_state(prob), n_sweeps=3)
    for s in dead:
        prob, state, ok = remove_sensor(prob, state, s)
        assert bool(ok)
    return field_view(prob, state, 0)


def test_weighted_sweep_threads_alive_mask():
    """Unit weights on a partially-alive problem == the masked serial
    engine: dead sensors neither update nor are read as neighbors, and
    their (zeroed) messages persist."""
    dead = (4, 11)
    prob1, state1 = _partially_alive_single_field(dead=dead)
    a = serial_sweep(prob1, state1, n_sweeps=30)
    b = weighted_sweep(prob1, state1, jnp.ones((prob1.n,)), n_sweeps=30)
    np.testing.assert_allclose(np.asarray(a.z), np.asarray(b.z), atol=1e-4)
    for s in dead:
        assert float(jnp.abs(b.z[s])) == 0.0
        assert float(jnp.abs(b.coef[s]).max()) == 0.0
    # finite + Fejér-sane under non-trivial weights too
    w = jnp.asarray(
        np.random.default_rng(0).uniform(0.5, 2.0, prob1.n).astype(np.float32)
    )
    c = weighted_sweep(prob1, state1, w, n_sweeps=5)
    assert bool(jnp.isfinite(c.z).all()) and bool(jnp.isfinite(c.coef).all())
    for s in dead:
        assert float(jnp.abs(c.coef[s]).max()) == 0.0


def test_robust_sweep_links_threads_alive_mask():
    """An all-True link trace on a partially-alive problem == the masked
    serial engine (the legacy link path no longer resurrects removed
    sensors)."""
    dead = (4, 11)
    prob1, state1 = _partially_alive_single_field(dead=dead)
    link_alive = jnp.ones((3, prob1.n, prob1.topology.d_max), bool)
    from repro.core import robust_sweep_links

    a = serial_sweep(prob1, state1, n_sweeps=3)
    b = robust_sweep_links(prob1, state1, link_alive, n_sweeps=3)
    np.testing.assert_allclose(np.asarray(a.z), np.asarray(b.z), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(a.coef), np.asarray(b.coef), atol=1e-4
    )
    for s in dead:
        assert float(jnp.abs(b.z[s])) == 0.0
        assert float(jnp.abs(b.coef[s]).max()) == 0.0
