"""Property tests for the generic SOP machinery (paper Sec. 2.1, Lemma 2.1)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, strategies as st

from repro.core.sop import (
    fejer_distances,
    project_affine,
    project_intersection,
    sop_sweep,
    sop_sweep_with_trace,
)


def _random_affine_sets(seed, m, k, dim):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k, dim)).astype(np.float32)
    # guarantee a common feasible point x*: b_i = A_i x*
    xstar = rng.normal(size=(dim,)).astype(np.float32)
    b = np.einsum("mkd,d->mk", a, xstar).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(xstar)


def test_projection_is_idempotent_and_feasible():
    a, b, _ = _random_affine_sets(0, 1, 2, 6)
    x = jnp.asarray(np.random.default_rng(1).normal(size=6), jnp.float32)
    p = project_affine(x, a[0], b[0])
    np.testing.assert_allclose(a[0] @ p, b[0], atol=1e-4)
    p2 = project_affine(p, a[0], b[0])
    np.testing.assert_allclose(p, p2, atol=1e-5)


@settings(deadline=None, max_examples=20)
@given(
    seed=st.integers(0, 10_000),
    m=st.integers(2, 5),
    k=st.integers(1, 3),
    dim=st.integers(4, 10),
)
def test_lemma_2_1_fejer_monotonicity(seed, m, k, dim):
    """||x_n - x|| <= ||x_{n-1} - x|| for every feasible x (Lemma 2.1)."""
    a, b, xstar = _random_affine_sets(seed, m, k, dim)
    x0 = jnp.asarray(np.random.default_rng(seed + 1).normal(size=dim), jnp.float32)
    _, trace = sop_sweep_with_trace(x0, a, b, n_sweeps=3)
    d = np.asarray(fejer_distances(jnp.concatenate([x0[None], trace]), xstar))
    assert (np.diff(d) <= 1e-4 + 1e-4 * d[:-1]).all(), d


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10_000))
def test_sop_converges_to_projection_for_subspaces(seed):
    """For affine sets, SOP -> P_C(x0) (Lemma 2.1 last claim)."""
    a, b, _ = _random_affine_sets(seed, 3, 1, 5)
    x0 = jnp.asarray(np.random.default_rng(seed + 7).normal(size=5), jnp.float32)
    x_inf = sop_sweep(x0, a, b, n_sweeps=400)
    # iterate is (nearly) feasible for every set
    for i in range(3):
        np.testing.assert_allclose(a[i] @ x_inf, b[i], atol=5e-3)
    # and close to the direct least-norm projection
    direct = project_intersection(x0, a, b)
    np.testing.assert_allclose(np.asarray(x_inf), np.asarray(direct), atol=5e-3)
