"""Mamba2 / SSD invariants: chunked dual form vs naive recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.ssm import (
    init_ssm_cache,
    ssd_chunked,
    ssd_recurrent_ref,
    ssm_decode,
    ssm_forward_with_state,
    ssm_init,
)


def _inputs(seed, b, s, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    return x, dt, a, bm, cm


@settings(deadline=None, max_examples=10)
@given(
    seed=st.integers(0, 1000),
    s=st.integers(1, 40),
    chunk=st.sampled_from([4, 8, 16]),
)
def test_ssd_chunked_matches_recurrence(seed, s, chunk):
    """Chunk-size invariance + agreement with the step-by-step oracle,
    including sequences that do not divide the chunk."""
    x, dt, a, bm, cm = _inputs(seed, 2, s, 3, 4, 8)
    y1, h1 = ssd_chunked(x, dt, a, bm, cm, chunk)
    y2, h2 = ssd_recurrent_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4, rtol=2e-4)


def test_ssd_initial_state_threading():
    """Splitting a sequence in half and passing the state across == one shot."""
    x, dt, a, bm, cm = _inputs(7, 1, 32, 2, 4, 6)
    y_full, h_full = ssd_chunked(x, dt, a, bm, cm, 8)
    y1, h1 = ssd_chunked(x[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16], 8)
    y2, h2 = ssd_chunked(x[:, 16:], dt[:, 16:], a, bm[:, 16:], cm[:, 16:], 8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, :16]), np.asarray(y1), atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), atol=2e-4)


def _ssm_cfg():
    return ModelConfig(
        name="ssm-test", family="ssm", n_layers=1, d_model=32, d_ff=0,
        vocab_size=64, ssm_state=8, ssm_head_dim=16, ssm_chunk=8,
    )


def test_mixer_decode_continues_prefill():
    """ssm_decode steps after a prefill must match the full-sequence mixer."""
    cfg = _ssm_cfg()
    p = ssm_init(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 20, cfg.d_model))
    y_full, _, _ = ssm_forward_with_state(p, cfg, u)

    y_pre, state, conv = ssm_forward_with_state(p, cfg, u[:, :16])
    np.testing.assert_allclose(
        np.asarray(y_pre), np.asarray(y_full[:, :16]), atol=2e-4, rtol=2e-4
    )
    cache = {"state": state, "conv": conv}
    for t in range(16, 20):
        y_t, cache = ssm_decode(p, cfg, u[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(y_t[:, 0]), np.asarray(y_full[:, t]), atol=3e-4, rtol=3e-4,
            err_msg=f"t={t}",
        )


def test_state_decays_without_input():
    """Zero input, positive dt -> state norm strictly decays (A < 0)."""
    cfg = _ssm_cfg()
    p = ssm_init(jax.random.PRNGKey(0), cfg)
    cache = init_ssm_cache(cfg, 1, jnp.float32)
    cache["state"] = cache["state"] + 1.0
    u = jnp.zeros((1, 1, cfg.d_model))
    norms = [float(jnp.linalg.norm(cache["state"]))]
    for _ in range(3):
        _, cache = ssm_decode(p, cfg, u, cache)
        norms.append(float(jnp.linalg.norm(cache["state"])))
    assert norms[-1] < norms[0]


def test_long_context_is_constant_memory():
    """Decode cache size is independent of context length (long_500k claim)."""
    cfg = _ssm_cfg()
    c1 = init_ssm_cache(cfg, 1, jnp.float32)
    sizes = jax.tree.map(lambda a: a.size, c1)
    total = sum(jax.tree.leaves(sizes))
    assert total == (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                     + (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state))
