"""Forgetting-factor streaming (ISSUE 6).

Pins the two contracts of the exponential-forgetting tick:

  (a) ``beta = 1.0`` is the EXACT static path: every streaming op
      (absorb / evict / wave / join / leave) and every sweep engine
      produces bitwise-identical arrays for a ``beta = 1`` field even
      when it shares a batch with decaying fields — the tick multiplies
      by exactly 1.0 and the Cholesky diagonal restore is gated out.
  (b) ``beta < 1`` stays exactly factorized: the cached Cholesky always
      equals the factorization of the decayed Gram plus the UNDECAYED
      regularizer (scale-then-update), so ``rebuild_chol`` agrees after
      any interleaving, and fresh arrivals dominate stale lanes — a
      drifting field is tracked instead of averaged into its history.

Plus the ``absorb_wave`` vectorization contract: one batched wave over
distinct (field, sensor) pairs equals absorbing them sequentially.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    Kernel,
    absorb_wave,
    add_sensor,
    build_topology,
    colored_sweep,
    effective_coef,
    fusion,
    init_state,
    make_batch_problem,
    remove_sensor,
    serial_sweep,
    streaming,
    uniform_sensors,
    weighted_norm_sq,
)

KERN = Kernel("rbf", gamma=1.0)
LAM = 0.3
RADIUS = 0.55
N, B, SPARES = 12, 2, 3

PROBLEM_FIELDS = ("nbr_pos", "nbr_mask", "gram", "chol", "anchor_w",
                  "stream_pos", "lam_pad", "alive", "alive_z")


def _build(seed, betas=1.0):
    pos = uniform_sensors(N, d=1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ys = np.sin(np.pi * pos[None, :, 0]) + 0.2 * rng.normal(size=(B, N))
    topo = build_topology(pos, RADIUS)
    d_max = int(np.asarray(topo.degrees).max()) + 6
    topo = build_topology(pos, RADIUS, d_max=d_max, n_max=N + SPARES)
    prob = make_batch_problem(
        topo, KERN, ys, jnp.full((N,), LAM), beta=betas
    )
    return pos, prob, colored_sweep(prob, init_state(prob), n_sweeps=2)


def _trace(prob, state, pos, seed, rounds=6):
    """A fixed streaming trace: dense evicting absorbs + one join/leave."""
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        for s in range(N):
            xa = (pos[s] + 0.05 * rng.normal(size=1)).astype(np.float32)
            ya = float(rng.normal())
            for f in range(B):
                prob, state, _ = streaming.absorb(
                    prob, state, f, s, xa, ya, on_full="evict"
                )
        if r == 2:
            x = np.asarray([0.11], np.float32)
            yn = rng.normal(size=B).astype(np.float32)
            prob, state, rec = add_sensor(prob, state, x, yn, lam=LAM)
            assert bool(rec.joined)
            prob, state, _ = remove_sensor(prob, state, rec.slot)
    return prob, state


def test_beta1_field_bitwise_in_mixed_batch():
    """A beta=1 field sharing a batch with a decaying field is untouched:
    the whole trace (absorb, evict, join, leave) and every engine's sweep
    match the all-static problem BITWISE, field by field."""
    pos, prob_s, state_s = _build(3, betas=1.0)
    _, prob_m, state_m = _build(3, betas=np.asarray([1.0, 0.5], np.float32))

    prob_s, state_s = _trace(prob_s, state_s, pos, seed=7)
    prob_m, state_m = _trace(prob_m, state_m, pos, seed=7)

    for f in PROBLEM_FIELDS:
        a = np.asarray(getattr(prob_s, f))
        b = np.asarray(getattr(prob_m, f))
        if a.shape and a.shape[0] == B and f != "lam_pad":
            a, b = a[0], b[0]
        assert np.array_equal(a, b), f"{f} diverged for the beta=1 field"
    assert np.array_equal(np.asarray(state_s.z)[0], np.asarray(state_m.z)[0])
    assert np.array_equal(
        np.asarray(state_s.coef)[0], np.asarray(state_m.coef)[0]
    )

    # engine by engine on the post-trace problems: bitwise per sweep
    for name, run in (
        ("plan", lambda p, s: colored_sweep(p, s, n_sweeps=2)),
        ("onehot", lambda p, s: colored_sweep(p, s, n_sweeps=2,
                                              engine="onehot")),
        ("serial", lambda p, s: serial_sweep(p, s, n_sweeps=2)),
    ):
        zs = np.asarray(run(prob_s, state_s).z)
        zm = np.asarray(run(prob_m, state_m).z)
        assert np.array_equal(zs[0], zm[0]), f"{name} engine diverged"

    # the decaying field really did decay (this is not a trivial test)
    assert not np.array_equal(
        np.asarray(prob_s.anchor_w)[1], np.asarray(prob_m.anchor_w)[1]
    )
    assert np.asarray(prob_m.anchor_w).min() < 0.9


def test_beta_lt1_factors_stay_consistent():
    """Scale-then-update: after any interleaving of ticks, evictions and
    lifecycle events, the cached factor equals the from-scratch
    factorization of the decayed Gram + full lambda."""
    pos, prob, state = _build(5, betas=np.asarray([0.7, 0.4], np.float32))
    prob, state = _trace(prob, state, pos, seed=11)
    err = float(jnp.max(jnp.abs(streaming.rebuild_chol(prob) - prob.chol)))
    assert err < 5e-5, err
    # anchors decay but never below sqrt(beta)^window or above 1
    aw = np.asarray(prob.anchor_w)
    assert aw.max() <= 1.0 + 1e-6
    assert (aw > 0.0).all()
    # sweeps on the decayed problem remain Fejér monotone between ticks
    prev = np.asarray(weighted_norm_sq(prob, state))
    for _ in range(2):
        state = colored_sweep(prob, state, n_sweeps=1)
        cur = np.asarray(weighted_norm_sq(prob, state))
        assert (cur <= prev * 1.06 + 1e-5).all()
        prev = cur


def test_absorb_wave_equals_sequential():
    """One wave over distinct (field, sensor) pairs == sequential absorbs
    (bitwise except the factor, which batched trsm perturbs at ulp)."""
    pos, prob, state = _build(0, betas=np.asarray([1.0, 0.7], np.float32))
    n_cap = prob.n
    rng = np.random.default_rng(2)

    def seq(prob, state, xs, ys, amask, on_full):
        for b in range(B):
            for s in range(n_cap):
                if amask[b, s]:
                    prob, state, _ = streaming.absorb(
                        prob, state, b, s, xs[b, s], ys[b, s],
                        on_full=on_full,
                    )
        return prob, state

    def compare(pw, sw, ps, ss):
        for f in ("nbr_pos", "nbr_mask", "gram", "anchor_w", "stream_pos"):
            assert np.array_equal(
                np.asarray(getattr(pw, f)), np.asarray(getattr(ps, f))
            ), f
        np.testing.assert_allclose(
            np.asarray(pw.chol), np.asarray(ps.chol), atol=1e-5
        )
        # z equal everywhere but the sentinel scratch slot
        assert np.array_equal(
            np.asarray(sw.z)[:, :-1], np.asarray(ss.z)[:, :-1]
        )
        assert np.array_equal(np.asarray(sw.coef), np.asarray(ss.coef))

    # round 1: partial mask, drop policy
    xs = np.zeros((B, n_cap, 1), np.float32)
    ys = np.zeros((B, n_cap), np.float32)
    amask = np.zeros((B, n_cap), bool)
    for b in range(B):
        for s in range(N):
            if (b + s) % 3 != 0:
                amask[b, s] = True
                xs[b, s] = pos[s] + rng.normal(scale=0.05, size=1)
                ys[b, s] = float(rng.normal())
    pw, sw, rc = absorb_wave(prob, state, xs, ys, mask=amask)
    ps, ss = seq(prob, state, xs, ys, amask, "drop")
    compare(pw, sw, ps, ss)
    assert int(np.asarray(rc.absorbed).sum()) == int(amask.sum())

    # dense evicting rounds until the windows wrap
    prob, state = pw, sw
    total_evicted = 0
    for _ in range(7):
        xs = np.zeros((B, n_cap, 1), np.float32)
        xs[:, :N] = pos[None] + rng.normal(
            scale=0.03, size=(B, N, 1)
        ).astype(np.float32)
        ys = rng.normal(size=(B, n_cap)).astype(np.float32)
        amask = np.zeros((B, n_cap), bool)
        amask[:, :N] = True
        pw, sw, rc = absorb_wave(
            prob, state, xs, ys, mask=amask, on_full="evict"
        )
        ps, ss = seq(prob, state, xs, ys, amask, "evict")
        compare(pw, sw, ps, ss)
        total_evicted += int(np.asarray(rc.evicted).sum())
        prob, state = pw, sw
    assert total_evicted > 0  # the wave really exercised batched eviction
    err = float(jnp.max(jnp.abs(streaming.rebuild_chol(prob) - prob.chol)))
    assert err < 5e-5, err


def test_drift_tracking_smoke():
    """On a drifting field, a tuned beta < 1 tracks where beta = 1 stalls:
    steady-state fused RMSE is at least 1.5x lower (the full acceptance
    run — benchmarks/drift_bench.py — pins >= 5x at n=1000, B=16)."""
    n, b = 40, 2
    rng = np.random.default_rng(0)
    pos = rng.uniform(-1, 1, size=(n, 1)).astype(np.float32)
    topo = build_topology(pos, 0.2)
    d_max = int(np.asarray(topo.degrees).max()) + 8
    topo = build_topology(pos, 0.2, d_max=d_max, n_max=n + 2)
    kern = Kernel("rbf", gamma=10.0)
    betas = np.asarray([1.0, 0.4], np.float32)

    def truth(x, t, v=0.08):
        return np.sin(np.pi * (x[..., 0] - v * t)).astype(np.float32)

    ys0 = truth(pos, 0)[None] + 0.01 * rng.normal(size=(b, n)).astype(
        np.float32
    )
    prob = make_batch_problem(
        topo, kern, ys0, jnp.full((n,), 0.01), beta=betas
    )
    state = colored_sweep(prob, init_state(prob), n_sweeps=4)

    hist = []
    for t in range(1, 17):
        xs = np.zeros((b, prob.n, 1), np.float32)
        xs[:, :n] = pos[None] + rng.normal(
            scale=0.01, size=(b, n, 1)
        ).astype(np.float32)
        ys = np.zeros((b, prob.n), np.float32)
        ys[:, :n] = truth(xs[:, :n], t) + 0.01 * rng.normal(
            size=(b, n)
        ).astype(np.float32)
        amask = np.zeros((b, prob.n), bool)
        amask[:, :n] = True
        prob, state, _ = absorb_wave(
            prob, state, xs, ys, mask=amask, on_full="evict"
        )
        state = colored_sweep(prob, state, n_sweeps=8)
        preds = fusion.evaluate_sensors(prob, state, pos)
        fused = fusion.knn_fusion(
            preds, prob.topology.positions, pos, k=3, alive=prob.alive[:-1]
        )
        rmse = np.sqrt(
            np.mean((np.asarray(fused) - truth(pos, t)[None]) ** 2, axis=-1)
        )
        hist.append(rmse)
    ss = np.mean(np.stack(hist[-5:]), axis=0)
    assert np.isfinite(ss).all()
    assert ss[1] * 1.5 < ss[0], (
        f"beta=0.4 should track >=1.5x better: rmse={ss}"
    )


def test_effective_coef_is_the_representer():
    """Serving reads anchor-weighted coefficients: effective_coef equals
    coef * anchor_w, and a decayed problem's evaluation uses it."""
    pos, prob, state = _build(9, betas=np.asarray([0.6, 0.6], np.float32))
    prob, state = _trace(prob, state, pos, seed=4, rounds=4)
    # solve so the stream lanes carry nonzero coefficients, then tick them
    # once more so their anchors sit strictly below 1
    state = colored_sweep(prob, state, n_sweeps=2)
    prob, state = _trace(prob, state, pos, seed=5, rounds=1)
    ec = np.asarray(effective_coef(prob, state))
    ref = np.asarray(state.coef) * np.asarray(prob.anchor_w)
    assert np.array_equal(ec, ref.astype(ec.dtype))
    assert not np.array_equal(ec, np.asarray(state.coef))  # really decayed
