"""Data pipeline, optimizers, checkpointing, topology coloring."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.checkpoint import latest_step, restore, save
from repro.core.topology import build_topology, geometric_adjacency, greedy_coloring, uniform_sensors
from repro.data import case1, case2, sample_field, synthetic_lm_stream
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine_warmup, lion, sgd, constant


# ---------------- topology ----------------


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 1000), n=st.integers(5, 60), r=st.floats(0.05, 1.5))
def test_coloring_is_proper_distance2(seed, n, r):
    pos = uniform_sensors(n, seed=seed)
    adj = geometric_adjacency(pos, r)
    g2 = (adj.astype(np.int64) @ adj.astype(np.int64)) > 0
    colors, n_colors = greedy_coloring(g2)
    np.fill_diagonal(g2, False)
    same = colors[:, None] == colors[None, :]
    assert not (same & g2).any(), "distance-2 conflict in coloring"
    assert n_colors <= n


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 500), n=st.integers(5, 40))
def test_topology_padding_invariants(seed, n):
    pos = uniform_sensors(n, seed=seed)
    topo = build_topology(pos, 0.5)
    idx = np.asarray(topo.nbr_idx)
    mask = np.asarray(topo.nbr_mask)
    deg = np.asarray(topo.degrees)
    assert (mask.sum(1) == deg).all()
    # self in own neighborhood
    for i in range(n):
        assert i in idx[i][mask[i]]
    # color members partition the sensors
    members = np.asarray(topo.color_members)[np.asarray(topo.color_mask)]
    assert sorted(members.tolist()) == list(range(n))


# ---------------- data ----------------


def test_field_cases_match_paper():
    c1, c2 = case1(), case2()
    assert c1.noise_sigma == 7.0 and c1.kernel.name == "linear"
    assert c2.noise_sigma == 1.0 and c2.kernel.name == "rbf"
    d = sample_field(c2, 50, seed=1)
    assert d["x"].shape == (50, 1) and d["y"].shape == (50,)
    np.testing.assert_allclose(d["y_test"], np.sin(np.pi * d["x_test"][:, 0]), atol=1e-5)


def test_token_stream_determinism_and_sharding():
    s = synthetic_lm_stream(1000, 16, 4, seed=9)
    a, b = s.batch_at(3), s.batch_at(3)
    assert (a["tokens"] == b["tokens"]).all()
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    # host sharding gives different data
    s0 = synthetic_lm_stream(1000, 16, 4, seed=9, host_id=0, n_hosts=2)
    s1 = synthetic_lm_stream(1000, 16, 4, seed=9, host_id=1, n_hosts=2)
    assert not (s0.batch_at(0)["tokens"] == s1.batch_at(0)["tokens"]).all()
    assert 0.0 < s.bigram_entropy() < np.log(1000)


# ---------------- optimizers ----------------


@pytest.mark.parametrize("maker", [
    lambda: adamw(constant(0.05), weight_decay=0.0),
    lambda: sgd(constant(0.05)),
    lambda: lion(constant(0.02), weight_decay=0.0),
])
def test_optimizers_minimize_quadratic(maker):
    opt = maker()
    params = {"w": jnp.full((8,), 5.0)}
    state = opt.init(params)
    best = float("inf")
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        up, state = opt.update(g, state, params)
        params = apply_updates(params, up)
        best = min(best, float(jnp.linalg.norm(params["w"])))
    # Lion's sign updates oscillate around the optimum on this toy problem,
    # so assert on the best iterate (all three must pass well below start).
    assert best < 0.3
    assert float(jnp.linalg.norm(params["w"])) < 0.25 * (8 * 25) ** 0.5


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_schedule_warmup_then_decay():
    f = cosine_warmup(1.0, 10, 100, final_frac=0.1)
    assert float(f(5)) == pytest.approx(0.5)
    assert float(f(10)) == pytest.approx(1.0, abs=1e-2)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-2)


# ---------------- checkpointing ----------------


def test_checkpoint_roundtrip_nested():
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": [jnp.zeros((2,), jnp.int32), {"mu": jnp.ones((3,))}],
    }
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, tree)
        save(d, 10, tree)
        assert latest_step(d) == 10
        back = restore(d, 10, tree)
        chk = jax.tree.map(lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)), tree, back)
        assert all(jax.tree.leaves(chk))


def test_checkpoint_shape_mismatch_raises():
    tree = {"w": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        with pytest.raises(ValueError):
            restore(d, 1, {"w": jnp.zeros((3,))})
