"""End-to-end behaviour tests for the paper's system."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import (
    Kernel,
    build_topology,
    colored_sweep,
    fit_krr,
    init_state,
    local_only,
    make_problem,
)
from repro.core import fusion
from repro.core.centralized import predict
from repro.data import case1, case2, sample_field

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fidelity_code(snippet):
    return f"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
import numpy as np, jax.numpy as jnp
from repro.core import (build_topology, colored_sweep, fit_krr, init_state,
                        local_only, make_problem)
from repro.core import fusion
from repro.core.centralized import predict
from repro.data import case1, case2, sample_field

def run_case(case, n=50, radius=None, sweeps=60, seed=0):
    d = sample_field(case, n, seed=seed)
    r = radius or (0.4 if case.name.startswith("case1") else 0.8)
    topo = build_topology(d["x"], r)
    prob = make_problem(topo, case.kernel, d["y"], dtype=jnp.float64)
    state = colored_sweep(prob, init_state(prob), n_sweeps=sweeps)
    xq, yq = d["x_test"], d["y_test"]
    err = lambda pred: float(jnp.mean((pred - yq) ** 2))
    cent = fit_krr(d["x"], d["y"], case.kernel, lam=0.01 / n**2, dtype=jnp.float64)
    return dict(
        nn=err(fusion.fuse(prob, state, xq, "nn")),
        single=err(fusion.fuse(prob, state, xq, "single")),
        conn=err(fusion.fuse(prob, state, xq, "conn")),
        local_single=err(fusion.fuse(prob, local_only(prob), xq, "single")),
        centralized=err(predict(cent, xq)),
        noise_floor=case.noise_sigma**2,
    )

{snippet}
print("OK")
"""


def _run_fidelity(snippet):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _fidelity_code(snippet)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=560,
    )
    assert out.returncode == 0, (out.stderr[-3000:], out.stdout[-500:])
    assert "OK" in out.stdout


def test_case2_end_to_end_matches_paper_claims():
    """Paper Sec. 4 (f64, faithful lambdas): NN fusion ~ centralized;
    SN-Train single >> local-only; estimates denoise below sigma^2."""
    _run_fidelity("""
r = run_case(case2(), sweeps=100)
assert r["nn"] < 2 * r["centralized"] + 0.05, r
assert r["single"] < r["local_single"], r
assert r["nn"] < r["noise_floor"], r
assert r["single"] < 0.2, r
""")


def test_case1_end_to_end():
    _run_fidelity("""
r = run_case(case1(), sweeps=100)
assert r["nn"] < 2 * r["centralized"] + 2.0, r
assert r["single"] <= r["local_single"] * 1.05, r
assert r["nn"] < r["noise_floor"], r   # sigma^2 = 49
""")


def test_connectivity_improves_sn_train_case2():
    """Paper Fig. 6: single-sensor error decreases with radius for SN-Train."""
    _run_fidelity("""
errs = [run_case(case2(), radius=r, sweeps=120, seed=1)["single"]
        for r in (0.3, 1.0, 2.0)]
assert errs[2] < errs[0], errs
""")


def test_dryrun_smoke_subprocess():
    """The dry-run driver runs end to end on the production mesh for one
    cheap combo (the full 40-combo sweep is executed separately)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-tiny",
         "--shape", "train_4k", "--mesh", "pod", "--out",
         os.path.join(ROOT, "experiments", "dryrun_test")],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "all combos lowered + compiled OK" in out.stdout


def test_train_launcher_smoke_subprocess():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-135m",
         "--variant", "smoke", "--steps", "3", "--batch", "4", "--seq", "32",
         "--dp_mode", "sop_gossip", "--log_every", "1"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "done" in out.stdout


def test_serve_launcher_smoke_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mamba2-370m",
         "--variant", "smoke", "--batch", "2", "--prompt_len", "8", "--gen", "4"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "tok/s" in out.stdout
