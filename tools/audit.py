#!/usr/bin/env python
"""Static invariant audit: jaxpr checks + compile ledger + AST lint.

Runs the three auditors in :mod:`repro.analysis` and compares the union
of findings against the shrink-only baseline
(``tools/audit_baseline.json``).  Exit status:

  0  every finding is baselined and every baseline entry still fires
  1  NEW findings (not baselined) or STALE baseline entries (fix the
     code or delete the entry — the baseline only shrinks)
  2  the audit itself crashed

By default JAX_ENABLE_X64 is switched on and the jaxpr audit traces the
registry at BOTH float32 and float64 canonical dtypes: the f32-under-x64
trace catches Python/NumPy float64 scalar contamination (``weak-promo``)
and the f64 trace catches silent truncation (``dtype-narrow``) — the
"f64 problems are never downcast" claim.  ``--no-x64`` restricts to the
f32 trace (what the test suite runs in-process).

  python tools/audit.py -v                 # full audit
  python tools/audit.py --skip jaxpr       # AST + ledger only
  python tools/audit.py --write-baseline   # re-pin current findings
"""

from __future__ import annotations

import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(ROOT, "tools", "audit_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="overwrite the baseline with the current findings",
    )
    ap.add_argument(
        "--skip", default="",
        help="comma list of auditors to skip: jaxpr,ast,ledger",
    )
    ap.add_argument(
        "--no-x64", action="store_true",
        help="trace float32 only (skip the x64 promotion/truncation runs)",
    )
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    skip = {s.strip() for s in args.skip.split(",") if s.strip()}

    if not args.no_x64:
        # must precede the first jax import anywhere in the process
        os.environ.setdefault("JAX_ENABLE_X64", "1")
    sys.path.insert(0, os.path.join(ROOT, "src"))

    from repro.analysis import ast_lint, compile_ledger, jaxpr_audit
    from repro.analysis.report import (
        compare_with_baseline, load_baseline, save_baseline,
    )

    findings = []
    if "ast" not in skip:
        findings += ast_lint.lint_paths(repo_root=ROOT)
    if "ledger" not in skip:
        findings += compile_ledger.audit()
    if "jaxpr" not in skip:
        import jax

        dtypes = ["float32"]
        if jax.config.jax_enable_x64:
            dtypes.append("float64")
        for dt in dtypes:
            findings += jaxpr_audit.run(trace_dtype=dt)
    # one finding per key across dtype runs
    findings = list({f.key: f for f in findings}.values())

    if args.write_baseline:
        save_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    new, stale = compare_with_baseline(findings, baseline)
    # Staleness (a baselined finding that no longer fires) is only
    # provable on a FULL audit — a --skip / --no-x64 run never traces
    # the paths some baseline entries live on.
    if skip or args.no_x64:
        stale = []

    if args.verbose or new or stale:
        print(
            f"audit: {len(findings)} finding(s), "
            f"{len(findings) - len(new)} baselined, {len(new)} new, "
            f"{len(stale)} stale baseline entr(y/ies)"
        )
    for f in new:
        print(f"  NEW   {f}")
    for k in stale:
        print(f"  STALE {k}  (fixed? delete it from the baseline)")
    if new or stale:
        return 1
    if args.verbose:
        print("audit: clean")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except SystemExit:
        raise
    except Exception:  # pragma: no cover
        import traceback

        traceback.print_exc()
        sys.exit(2)
